"""Wacky-weights characterization across all six treatments (paper §4.2).

    PYTHONPATH=src python examples/wacky_analysis.py
"""
import jax.numpy as jnp

from repro.core import build_impact_index, pad_queries
from repro.core.wacky import full_report
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.models.treatments import MODEL_NAMES, apply_treatment


def main():
    corpus = generate_corpus(CorpusConfig(n_docs=3000, n_queries=80))
    print(f"{'model':>14} {'cv':>6} {'gini':>6} {'tight':>6} {'skip%':>6} {'ovfl16':>6}")
    for model in MODEL_NAMES:
        enc = apply_treatment(corpus, model)
        idx = build_impact_index(enc.doc_idx, enc.term_idx, enc.weights, corpus.n_docs, enc.n_terms)
        max_q = max(len(t) for t in enc.query_terms)
        qt, qw = pad_queries(enc.query_terms, enc.query_weights, max_q, enc.n_terms)
        rep = full_report(model, idx, enc.weights, jnp.asarray(qt), jnp.asarray(qw), k=10)
        print(
            f"{model:>14} {rep['weights']['cv']:6.2f} {rep['weights']['gini']:6.2f} "
            f"{rep['blockmax']['tightness']:6.2f} "
            f"{100 * rep['skip']['skippable_fraction_mean']:6.1f} "
            f"{str(rep['accumulator']['overflows']):>6}"
        )
    print("\nlower cv/gini = flatter ('wackier') weights; lower skip% = less "
          "DAAT headroom; ovfl16 = 16-bit accumulator overflow (paper §3.2).")


if __name__ == "__main__":
    main()
