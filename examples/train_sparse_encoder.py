"""End-to-end driver (deliverable b): train a learned-sparse encoder, encode
the corpus, build the impact index, and compare SAAT serving against BM25.

This closes the paper's full loop — gradient descent on the FLOPS-regularized
contrastive objective (the paper's "efficiency in the training objective"
future-work item) all the way to query-evaluation latency behaviour.

    PYTHONPATH=src python examples/train_sparse_encoder.py [--steps 300]
"""
import argparse
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import build_impact_index, exact_rho, pad_queries, saat_search
from repro.core.saat import max_segments_per_term
from repro.data.pipeline import TripleSampler
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.metrics.ir_metrics import mrr_at_k
from repro.models.sparse_encoder import (
    SparseEncoderConfig,
    encode,
    encode_corpus_to_coo,
    encoder_backbone,
    encoder_loss,
    init_encoder_params,
)
from repro.models.treatments import apply_treatment
from repro.train import AdamWConfig, init_train_state, make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--flops-weight", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    corpus = generate_corpus(CorpusConfig(n_docs=2000, n_queries=150, n_concepts=150, seed=5))
    cfg = SparseEncoderConfig(
        backbone=encoder_backbone(d_model=128, n_layers=3, vocab=corpus.config.n_surface_terms),
        flops_weight=args.flops_weight,
        query_flops_weight=args.flops_weight * 3,
    )
    params = init_encoder_params(jax.random.PRNGKey(0), cfg)
    print(f"encoder params: {sum(x.size for x in jax.tree.leaves(params)):,}")

    sampler = TripleSampler(corpus, q_len=12, d_len=48)
    step = make_train_step(
        lambda p, b: encoder_loss(p, b, cfg),
        AdamWConfig(lr=2e-3, warmup_steps=20, total_steps=args.steps),
    )
    hooks = []
    cm = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
    if cm:
        hooks.append(cm.every_n_steps_hook(100))
    state, hist = train_loop(
        step,
        init_train_state(params),
        itertools.islice(sampler.batches(args.batch), args.steps),
        hooks=hooks,
    )
    if cm:
        cm.wait()
    print(
        f"training: rank_loss {hist[0]['rank_loss']:.3f} -> {hist[-1]['rank_loss']:.3f}, "
        f"pair_acc {hist[0]['pair_acc']:.2f} -> {hist[-1]['pair_acc']:.2f}, "
        f"doc_nnz {hist[-1]['doc_nnz']:.0f}, query_nnz {hist[-1]['query_nnz']:.0f}"
    )

    print("encoding corpus + building impact index ...")
    toks, masks = [], []
    for t, m, _ in sampler.doc_token_batches(64):
        toks.append(t)
        masks.append(m)
    d, t, w, n = encode_corpus_to_coo(state.params, toks, masks, cfg)
    d_keep = d < corpus.n_docs  # drop padded batch rows
    idx = build_impact_index(d[d_keep], t[d_keep], w[d_keep], corpus.n_docs, cfg.vocab)

    # encode the queries with the trained model
    enc_q = jax.jit(lambda t, m: encode(state.params, t, m, cfg))
    q_terms, q_weights = [], []
    for qi in range(corpus.n_queries):
        qt_pad, qm = sampler._pad(corpus.query_terms[qi], 12)
        rep = np.asarray(enc_q(jnp.asarray(qt_pad[None]), jnp.asarray(qm[None])))[0]
        nz = np.nonzero(rep > 1e-4)[0]
        q_terms.append(nz.astype(np.int32))
        q_weights.append(rep[nz].astype(np.float32))
    max_q = max(max(len(x) for x in q_terms), 1)
    qt, qw = pad_queries(q_terms, q_weights, max_q, cfg.vocab)

    res = saat_search(
        idx, jnp.asarray(qt), jnp.asarray(qw), k=10, rho=exact_rho(idx),
        max_segs_per_term=max_segments_per_term(idx),
    )
    mrr_learned = mrr_at_k(np.asarray(res.doc_ids), corpus.qrels, 10)

    # BM25 reference on the same corpus
    enc_bm = apply_treatment(corpus, "bm25")
    idx_bm = build_impact_index(
        enc_bm.doc_idx, enc_bm.term_idx, enc_bm.weights, corpus.n_docs, enc_bm.n_terms
    )
    mq = max(len(x) for x in enc_bm.query_terms)
    qtb, qwb = pad_queries(enc_bm.query_terms, enc_bm.query_weights, mq, enc_bm.n_terms)
    res_bm = saat_search(
        idx_bm, jnp.asarray(qtb), jnp.asarray(qwb), k=10, rho=exact_rho(idx_bm),
        max_segs_per_term=max_segments_per_term(idx_bm),
    )
    mrr_bm = mrr_at_k(np.asarray(res_bm.doc_ids), corpus.qrels, 10)
    print(f"RR@10: trained sparse encoder = {mrr_learned:.3f} | bm25 = {mrr_bm:.3f}")
    print(f"index postings: learned = {idx.n_postings:,} | bm25 = {idx_bm.n_postings:,} "
          f"(FLOPS regularizer controls this knob)")


if __name__ == "__main__":
    main()
