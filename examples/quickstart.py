"""Quickstart: corpus -> treatment -> impact index -> SAAT/DAAT/exhaustive.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    blockmax_search,
    build_impact_index,
    exact_rho,
    exhaustive_search,
    pad_queries,
    saat_search,
)
from repro.core.daat import max_blocks_per_term
from repro.core.saat import max_segments_per_term
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.metrics.ir_metrics import mrr_at_k
from repro.models.treatments import apply_treatment


def main():
    print("1. generating a vocabulary-mismatch corpus (2k docs) ...")
    corpus = generate_corpus(CorpusConfig(n_docs=2000, n_queries=100))

    print("2. encoding under two treatments: bm25 (skewed) vs spladev2 (wacky) ...")
    for model in ("bm25", "spladev2"):
        enc = apply_treatment(corpus, model)
        index = build_impact_index(
            enc.doc_idx, enc.term_idx, enc.weights, corpus.n_docs, enc.n_terms
        )
        max_q = max(len(t) for t in enc.query_terms)
        qt, qw = pad_queries(enc.query_terms, enc.query_weights, max_q, enc.n_terms)
        qt, qw = jnp.asarray(qt), jnp.asarray(qw)

        ex = exhaustive_search(index, qt, qw, k=10)
        sa = saat_search(
            index, qt, qw, k=10, rho=max(exact_rho(index) // 10, 500),
            max_segs_per_term=max_segments_per_term(index),
        )
        da = blockmax_search(
            index, qt, qw, k=10, est_blocks=4, block_budget=8,
            max_bm_per_term=max_blocks_per_term(index),
        )
        print(
            f"   {model:>9}: postings={index.n_postings:>8} "
            f"RR@10 exhaustive={mrr_at_k(np.asarray(ex.doc_ids), corpus.qrels):.3f} "
            f"saat(rho=10%)={mrr_at_k(np.asarray(sa.doc_ids), corpus.qrels):.3f} "
            f"daat={mrr_at_k(np.asarray(da.doc_ids), corpus.qrels):.3f} "
            f"daat-blocks-scored={int(np.asarray(da.blocks_scored).mean())}/{index.n_blocks}"
        )
    print("done. note how spladev2 scores more blocks (skipping collapses) "
          "while saat keeps a fixed budget.")


if __name__ == "__main__":
    main()
