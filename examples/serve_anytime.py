"""Anytime serving under a latency deadline (paper §4.3 + tail-latency story).

Runs the same query stream at several deadlines; the controller picks the
posting budget rho per batch, trading effectiveness for bounded latency.

    PYTHONPATH=src python examples/serve_anytime.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import build_impact_index, pad_queries
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.metrics.ir_metrics import mrr_at_k
from repro.models.treatments import apply_treatment
from repro.serving import AnytimeServer, ServingConfig, run_query_stream


def main():
    corpus = generate_corpus(CorpusConfig(n_docs=4000, n_queries=120))
    enc = apply_treatment(corpus, "spladev2")  # the wackiest treatment
    index = build_impact_index(enc.doc_idx, enc.term_idx, enc.weights, corpus.n_docs, enc.n_terms)
    max_q = max(len(t) for t in enc.query_terms)
    qt, qw = pad_queries(enc.query_terms, enc.query_weights, max_q, enc.n_terms)
    print(f"spladev2 index: {index.n_postings:,} postings over {corpus.n_docs} docs")

    ladder = tuple(
        sorted({max(index.n_postings // f, 1000) for f in (100, 20, 4, 1)})
    )
    for deadline in (None, 50.0, 5.0):
        srv = AnytimeServer(
            index,
            ServingConfig(k=100, rho_ladder=ladder, batch_size=16, deadline_ms=deadline),
        )
        srv.warmup(jnp.asarray(qt[:16]), jnp.asarray(qw[:16]))
        srv.reset_stats()
        _, ids = run_query_stream(srv, qt, qw)
        stats = srv.stats()
        rho_used = int(np.median(srv._rhos)) if srv._rhos else 0
        print(
            f"deadline={str(deadline):>6} ms | median rho={rho_used:>9,} | "
            f"RR@10={mrr_at_k(ids, corpus.qrels, 10):.3f} | "
            f"p50={stats.p50_ms:.1f}ms p99={stats.p99_ms:.1f}ms "
            f"tail-ratio={stats.tail_ratio:.2f}"
        )
    print("smaller deadlines -> smaller budgets -> bounded latency, graceful "
          "effectiveness loss (the paper's anytime tradeoff).")


if __name__ == "__main__":
    main()
