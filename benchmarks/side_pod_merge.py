"""Side experiment: pod-scale cross-host k-merge vs the unsharded engine.

The pod serve step answers every query on every document shard of a
(pod, model) mesh, then k-merges the per-rank candidate pools with the
id-canonical ``canonical_topk_merge``. This bench measures what that buys
and costs on a simulated multi-host mesh (CPU devices stand in for hosts —
run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to unlock
the larger layouts; on a plain 1-device CPU only the (1, 1) layout runs):

  * **parity first**: pod-merged doc ids are asserted BIT-IDENTICAL to the
    unsharded exact-SAAT oracle on every layout before any timing — the
    speed numbers cannot come from a wrong-answer merge;
  * **merge fan-in**: candidates entering each merge (ranks * k) — the
    all-gather payload the rank-safe merge pays per query;
  * **throughput**: per-query wall ms and qps per layout. CPU wall times
    are RELATIVE as everywhere in benchmarks/; the faithful signal is the
    fan-in column and the layout-to-layout ratio, not the absolute ms.

``REPRO_BENCH_TINY=1`` shrinks batches/repeats to CI-sized work.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from benchmarks import common as C
from repro.core.saat import max_segments_per_term, saat_search
from repro.serving.sharded import make_pod_serve_step, shard_corpus, stack_indexes

TINY = os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0")

MODEL = "bm25"
K = 10
B = 8
LAYOUTS = ((1, 1), (2, 1), (2, 2), (4, 2))  # (pod hosts, model ranks)
N_BATCHES = 1 if TINY else 3
REPEATS = 1 if TINY else 5
PARITY_ASSERTED = True  # pod ids bitwise == unsharded oracle, pre-timing


def _query_batches(qt: np.ndarray, qw: np.ndarray):
    out = []
    for i in range(N_BATCHES):
        rows = (np.arange(B) + i * B) % qt.shape[0]
        out.append((np.ascontiguousarray(qt[rows]), np.ascontiguousarray(qw[rows])))
    return out


def run() -> list[dict]:
    enc = C.encoded(MODEL)
    index = C.index_for(MODEL)
    n_docs = C.corpus().n_docs
    qt, qw = C.queries_for(MODEL)
    batches = _query_batches(np.asarray(qt), np.asarray(qw))

    ms = max_segments_per_term(index)
    oracle = [
        saat_search(
            index, jnp.asarray(bt), jnp.asarray(bw), k=K,
            rho=index.n_postings, max_segs_per_term=ms,
        )
        for bt, bw in batches
    ]

    rows = []
    for n_pod, n_model in LAYOUTS:
        ranks = n_pod * n_model
        if jax.device_count() < ranks:
            continue
        mesh = Mesh(np.array(jax.devices()[:ranks]).reshape(n_pod, n_model), ("pod", "model"))
        shards, dps = shard_corpus(
            enc.doc_idx, enc.term_idx, enc.weights, n_docs, enc.n_terms, ranks
        )
        stacked = stack_indexes(shards)
        serve, _, _ = make_pod_serve_step(
            mesh, k=K,
            rho_per_shard=int(stacked.doc_ids.shape[1]),
            max_segs_per_term=max(max_segments_per_term(s) for s in shards),
            docs_per_shard=dps, n_docs_total=n_docs,
        )
        step = jax.jit(serve)

        # parity BEFORE timing, every batch, ids bit-identical
        for (bt, bw), ref in zip(batches, oracle):
            _, ids = step(stacked, jnp.asarray(bt), jnp.asarray(bw))
            np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref.doc_ids))

        samples = []
        for _ in range(REPEATS):
            for bt, bw in batches:
                t0 = time.perf_counter()
                ss, _ = step(stacked, jnp.asarray(bt), jnp.asarray(bw))
                jax.block_until_ready(ss)
                samples.append((time.perf_counter() - t0) * 1e3 / B)
        per_q = float(np.median(samples))
        rows.append(
            {
                "layout": f"{n_pod}x{n_model}",
                "hosts": n_pod,
                "model_ranks": n_model,
                "docs_per_shard": dps,
                "merge_fanin": serve.statics["merge_fanin"],
                "ms_per_query": round(per_q, 4),
                "qps": round(1e3 / per_q, 1) if per_q > 0 else float("inf"),
                "ids_bit_identical": True,
            }
        )
    return rows


def main() -> None:
    from benchmarks.common import print_csv

    print_csv(
        "side: pod cross-host k-merge vs unsharded oracle (id parity asserted)",
        run(),
    )


if __name__ == "__main__":
    main()
