"""Figure 3 analogue: global Pareto frontier over (model x system x rho)."""
from __future__ import annotations

from benchmarks import common as C
from repro.core import exact_rho, exhaustive_search, saat_search
from repro.core.pareto import OperatingPoint, frontier_table
from repro.core.saat import max_segments_per_term
from repro.models.treatments import MODEL_NAMES

K = 100
BATCH = 16
RHO_FRACS = (0.05, 0.25, 1.0)


def run() -> list[dict]:
    points = []
    for model in MODEL_NAMES:
        idx = C.index_for(model)
        qt, qw = C.queries_for(model)
        ms = max_segments_per_term(idx)
        _, ex_secs = C.timed(lambda q, w: exhaustive_search(idx, q, w, k=K), qt[:BATCH], qw[:BATCH])
        ex_full = exhaustive_search(idx, qt, qw, k=K)
        points.append(
            OperatingPoint(
                name=f"{model}/exhaustive", model=model, system="exhaustive",
                effectiveness=C.mrr(ex_full.doc_ids), latency_ms=ex_secs / BATCH * 1e3,
            )
        )
        for frac in RHO_FRACS:
            rho = max(int(exact_rho(idx) * frac), 500)
            fn = lambda q, w: saat_search(idx, q, w, k=K, rho=rho, max_segs_per_term=ms, scatter_impl="sort")
            _, secs = C.timed(fn, qt[:BATCH], qw[:BATCH])
            full = fn(qt, qw)
            points.append(
                OperatingPoint(
                    name=f"{model}/saat-{frac}", model=model, system=f"saat-rho{frac}",
                    effectiveness=C.mrr(full.doc_ids), latency_ms=secs / BATCH * 1e3,
                )
            )
    return frontier_table(points)


def main():
    C.print_csv("Fig 3: Pareto frontier over model x system", run())


if __name__ == "__main__":
    main()
