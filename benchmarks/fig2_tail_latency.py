"""Figure 2 analogue: latency DISTRIBUTIONS, DAAT vs SAAT.

The paper's claim: budgeted SAAT has structurally bounded latency while
DAAT's depends on how prunable the query is. On TPU our SAAT executes the
identical instruction stream for every query (rho is a static shape), so the
distribution collapses by construction; DAAT's while-loop trip count is data
dependent. We report per-query wall times AND the work distribution
(chunks / postings) that drives them.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core import blockmax_search, exact_rho, saat_search
from repro.core.daat import max_blocks_per_term
from repro.core.saat import max_segments_per_term
from repro.metrics.latency import summarize_latencies

K = 100
MODELS = ("bm25", "deepimpact", "spladev2")


def run() -> list[dict]:
    rows = []
    for model in MODELS:
        idx = C.index_for(model)
        qt, qw = C.queries_for(model)
        ms = max_segments_per_term(idx)
        mb = max_blocks_per_term(idx)
        rho = max(exact_rho(idx) // 10, 1000)
        systems = {
            "saat-approx": lambda q, w: saat_search(
                idx, q, w, k=K, rho=rho, max_segs_per_term=ms, scatter_impl="sort"
            ),
            "daat-bmw": lambda q, w: blockmax_search(
                idx, q, w, k=K, est_blocks=8, block_budget=16, max_bm_per_term=mb, exact=True
            ),
        }
        for sys_name, fn in systems.items():
            times = C.per_query_timings(fn, qt, qw)
            stats = summarize_latencies(times)
            full = fn(qt, qw)
            work = (
                np.asarray(full.chunks) if sys_name == "daat-bmw"
                else np.asarray(full.postings_processed)
            )
            rows.append(
                {
                    "model": model,
                    "system": sys_name,
                    "p50_ms": round(stats.p50_ms, 3),
                    "p95_ms": round(stats.p95_ms, 3),
                    "p99_ms": round(stats.p99_ms, 3),
                    "max_ms": round(stats.max_ms, 3),
                    "tail_ratio_p99_p50": round(stats.tail_ratio, 2),
                    "work_p50": int(np.percentile(work, 50)),
                    "work_max": int(work.max()),
                    "work_cv": round(float(work.std() / max(work.mean(), 1e-9)), 3),
                }
            )
    return rows


def main():
    C.print_csv("Fig 2: tail latency, DAAT vs SAAT", run())


if __name__ == "__main__":
    main()
