"""Side experiment: degrade rho vs violate the deadline under overload.

The paper's serving argument is that a score-at-a-time posting budget (rho)
is an *anytime* knob: when load makes the full budget miss its SLO, serving
a smaller calibrated budget trades a bounded effectiveness loss for a met
deadline. This bench runs the same deterministic overload replay through the
``AdmissionQueue`` twice — ``degrade_rho=False`` (the flush blows the
deadline at the full budget) and ``degrade_rho=True`` (the flush serves the
largest calibrated rho that still fits) — and prices the trade with the
``repro.metrics.ir_metrics`` effectiveness sweep (Recall/MRR/NDCG per ladder
level vs the exact budget, plus the smallest rho within 3% MRR loss).

Determinism: the replay runs on a ``SimulatedClock`` with SCRIPTED per-
``(shape, rho)`` service-time calibrations (the same scenario the serving
suite locks down in tests/test_queue.py) — the burst's third arrival jumps
the covering batch shape, the full-budget prediction no longer fits the
remaining deadline budget, and the policy contrast is structural rather than
a property of this container's wall clock. Effectiveness numbers are real
(actual engine results on the labeled synthetic corpus); CPU wall times are
deliberately NOT reported.

Doc-id parity is asserted before any rows are emitted: at max rho, ids
served through the queue are bitwise-identical to direct
``AnytimeServer.search_batch`` on the same requests.

REPRO_BENCH_TINY=1 shrinks the corpus/query set to CI-sized shapes; the
policy contrast and the parity assert are the lane's value, not scale.
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from repro.core import build_impact_index, pad_queries
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.metrics.ir_metrics import cheapest_rho_within_loss, rho_effectiveness_sweep
from repro.metrics.latency import SimulatedClock
from repro.models.treatments import apply_treatment
from repro.serving import AdmissionQueue, AnytimeServer, ServingConfig
from repro.serving.queue import replay_arrivals

TINY = os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0")

MODEL = "spladev2"
RHO_BASE_LADDER = (500, 2000)  # the exact level is appended by the server
K = 10
BATCH_SHAPES = (2, 4)
DEADLINE_MS = 100.0
# scripted service-time calibrations, ms per (batch shape, ladder position):
# small rho fits the post-jump budget, the full budget does not
SCRIPTED_MS = {"small": (5.0, 15.0), "mid": (10.0, 30.0), "full": (20.0, 60.0)}
# burst arrivals (s): the third request jumps the covering shape 2 -> 4,
# moving the due instant into the past -> flush with ~25 ms remaining
ARRIVALS_S = (0.0, 0.070, 0.075)
MAX_LOSS = 0.03
PARITY_ASSERTED = True  # max-rho queue ids bitwise == direct serving, pre-rows


def _corpus():
    if TINY:
        return generate_corpus(CorpusConfig(n_docs=400, n_queries=30, n_concepts=80, seed=3))
    return generate_corpus(CorpusConfig(n_docs=6000, n_queries=160, n_concepts=400, seed=11))


def _server(index, L, clock):
    srv = AnytimeServer(
        index,
        ServingConfig(k=K, rho_ladder=RHO_BASE_LADDER, lq_buckets=(L,)),
        clock=clock,
    )
    small, mid, full = srv.rho_ladder[0], srv.rho_ladder[1], srv.rho_ladder[-1]
    for (rho, name) in ((small, "small"), (mid, "mid"), (full, "full")):
        for shape, ms in zip(BATCH_SHAPES, SCRIPTED_MS[name]):
            srv._bucket_ms[("saat", L, shape, rho)] = ms
    return srv


def _replay(index, L, qt, qw, order, *, degrade: bool):
    clock = SimulatedClock()
    srv = _server(index, L, clock)
    q = AdmissionQueue(srv, batch_shapes=BATCH_SHAPES, clock=clock, degrade_rho=degrade)
    comps = replay_arrivals(
        q,
        list(ARRIVALS_S),
        [qt[i] for i in order],
        [qw[i] for i in order],
        [DEADLINE_MS] * len(order),
    )
    return q, sorted(comps, key=lambda c: c.rid)


def run() -> list[dict]:
    corpus = _corpus()
    enc = apply_treatment(corpus, MODEL)
    index = build_impact_index(
        enc.doc_idx, enc.term_idx, enc.weights, corpus.n_docs, enc.n_terms
    )
    max_q = max(len(t) for t in enc.query_terms)
    qt, qw = pad_queries(enc.query_terms, enc.query_weights, max_q, enc.n_terms)
    L = qt.shape[1]
    order = list(range(len(ARRIVALS_S)))

    # ---- parity BEFORE any rows: at max rho the queue is a batching layer,
    # not a different engine — served ids must be bitwise-identical to
    # direct serving of the same requests
    q_off, comps = _replay(index, L, qt, qw, order, degrade=False)
    ref = AnytimeServer(index, ServingConfig(k=K, rho_ladder=RHO_BASE_LADDER, lq_buckets=(L,)))
    direct = ref.search_batch(
        jnp.asarray(qt[order]), jnp.asarray(qw[order]), rho=ref.rho_ladder[-1]
    )
    direct_ids = np.asarray(direct.doc_ids)
    for i, c in enumerate(comps):
        assert c.rho == ref.rho_ladder[-1]  # degrade off: full budget served
        assert np.array_equal(c.doc_ids, direct_ids[i]), (
            f"queue-served ids diverged from direct serving (rid={c.rid})"
        )

    q_on, _ = _replay(index, L, qt, qw, order, degrade=True)
    rows = []
    for policy, q in (("violate", q_off), ("degrade", q_on)):
        rows.append(
            {
                "policy": policy,
                "deadline_ms": DEADLINE_MS,
                "requests": q.n_completed,
                "violations": q.n_violations,
                "degraded_flushes": q.n_degraded,
                "served_rhos": "/".join(
                    str(f.rho) for f in q.flush_log if f.reason != "drain"
                ),
            }
        )
    assert rows[0]["violations"] >= 1, "overload replay must violate without degradation"
    assert rows[1]["violations"] == 0, "degradation must replace violation"
    assert rows[1]["degraded_flushes"] >= 1

    # ---- what each ladder level costs: real engine results vs exact budget
    srv = AnytimeServer(index, ServingConfig(k=K, rho_ladder=RHO_BASE_LADDER, batch_size=8))
    sweep = rho_effectiveness_sweep(srv, qt, qw, np.asarray(corpus.qrels), recall_k=K)
    for row in sweep:
        rows.append(
            {
                "policy": "sweep",
                "rho": row["rho"],
                "exact": row["exact"],
                "mrr": round(row["mrr"], 4),
                "recall": round(row["recall"], 4),
                "ndcg": round(row["ndcg"], 4),
                "loss_mrr": round(row["loss_mrr"], 4),
            }
        )
    rows.append(
        {
            "policy": "autopilot_pick",
            "max_loss": MAX_LOSS,
            "rho": cheapest_rho_within_loss(sweep, max_loss=MAX_LOSS),
        }
    )
    return rows


def main() -> None:
    from benchmarks.common import print_csv

    rows = run()
    print_csv(
        "side: degrade rho vs violate deadline under overload (id parity asserted)",
        [r for r in rows if r["policy"] in ("violate", "degrade")],
    )
    print_csv(
        "side: effectiveness per rho level vs exact (+ 3%-loss autopilot pick)",
        [r for r in rows if r["policy"] in ("sweep", "autopilot_pick")],
    )


if __name__ == "__main__":
    main()
