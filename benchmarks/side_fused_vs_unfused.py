"""Side experiment: fused vs unfused hot paths at the Fig. 2 batch shapes.

Two fusions land in PR 3, both changing what crosses the HBM boundary on the
hottest path in the repo:

  * SAAT ``fused_topk``: ``impact_scatter_topk`` emits per-block top-k
    candidates straight from the VMEM accumulator blocks — ``[B, blocks*k]``
    leaves the kernel instead of the ``[B, n_docs]`` accumulator (which the
    unfused path writes out and immediately reads back for ``top_k``);
  * DAAT ``use_kernels``: phase 2 routes through ``block_prune_batched`` +
    ``block_topk_batched`` + ``sparse_score_batched`` instead of the jnp
    scatter/top_k/gather-reduce formulation.

Every config is ONE executable over the whole ``[B, Lq]`` batch, timed at
B ∈ {1, 8, 32}. On CPU the Pallas kernels run in interpret mode, so absolute
times favor the jnp/unfused paths — what is faithful here is the shape of the
comparison harness and the parity of the work metrics; the HBM-traffic win is
a TPU property (see the roofline bench). Both engines' fused/unfused variants
must agree on doc ids — the run asserts it before timing.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common as C
from repro.core import daat_search_batched, saat_search
from repro.core.daat import max_blocks_per_term
from repro.core.saat import max_segments_per_term

K = 100
RHO = 20_000
MODELS = ("bm25", "spladev2")
BATCH_SIZES = (1, 8, 32)
SCATTER = "pallas"  # unfused baseline with the same (Pallas) scatter kernel
EST_BLOCKS = 8
BLOCK_BUDGET = 16
# interpret-mode kernels on CPU run seconds per call for the wacky models
# (skipping collapses -> long while_loop of interpreted launches), so keep
# the sample count small; on TPU raise this freely
REPEATS = 5
PARITY_ASSERTED = True  # run() bitwise-compares doc ids before any timing


def _timed_samples(fn, qt, qw, repeats: int) -> np.ndarray:
    jax.block_until_ready(fn(qt, qw))  # compile
    out = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(qt, qw))
        out.append((time.perf_counter() - t0) * 1e3)
    return np.asarray(out)


def _stats(samples: np.ndarray) -> tuple[float, float]:
    return round(float(samples.mean()), 3), round(float(np.percentile(samples, 99)), 3)


def run() -> list[dict]:
    rows = []
    for model in MODELS:
        idx = C.index_for(model)
        qt_all, qw_all = C.queries_for(model)
        ms = max_segments_per_term(idx)
        mb = max_blocks_per_term(idx)
        rho = min(RHO, idx.n_postings)
        for bs in BATCH_SIZES:
            reps = -(-bs // qt_all.shape[0])
            qt = np.tile(np.asarray(qt_all), (reps, 1))[:bs]
            qw = np.tile(np.asarray(qw_all), (reps, 1))[:bs]
            qt, qw = jax.numpy.asarray(qt), jax.numpy.asarray(qw)

            def saat(q, w, fused):
                return saat_search(
                    idx, q, w, k=K, rho=rho, max_segs_per_term=ms,
                    scatter_impl=SCATTER, fused_topk=fused,
                )

            def daat(q, w, kernels):
                return daat_search_batched(
                    idx, q, w, k=K, est_blocks=EST_BLOCKS, block_budget=BLOCK_BUDGET,
                    max_bm_per_term=mb, exact=True, use_kernels=kernels,
                )

            # the fusion must be invisible in results before it is timed
            su, sf = saat(qt, qw, False), saat(qt, qw, True)
            assert (np.asarray(su.doc_ids) == np.asarray(sf.doc_ids)).all()
            du, dk = daat(qt, qw, False), daat(qt, qw, True)
            assert (np.asarray(du.doc_ids) == np.asarray(dk.doc_ids)).all()

            t_su = _stats(_timed_samples(lambda q, w: saat(q, w, False), qt, qw, REPEATS))
            t_sf = _stats(_timed_samples(lambda q, w: saat(q, w, True), qt, qw, REPEATS))
            t_du = _stats(_timed_samples(lambda q, w: daat(q, w, False), qt, qw, REPEATS))
            t_dk = _stats(_timed_samples(lambda q, w: daat(q, w, True), qt, qw, REPEATS))
            n_blocks_scatter = -(-idx.doc_terms.shape[0] // 512)  # fused block_d
            rows.append(
                {
                    "model": model,
                    "batch": bs,
                    "saat_unfused_mean_ms": t_su[0],
                    "saat_unfused_p99_ms": t_su[1],
                    "saat_fused_mean_ms": t_sf[0],
                    "saat_fused_p99_ms": t_sf[1],
                    "daat_jnp_mean_ms": t_du[0],
                    "daat_jnp_p99_ms": t_du[1],
                    "daat_kernels_mean_ms": t_dk[0],
                    "daat_kernels_p99_ms": t_dk[1],
                    # HBM-boundary accounting for the SAAT fusion
                    "hbm_floats_unfused": int(bs * idx.doc_terms.shape[0]),
                    "hbm_floats_fused": int(bs * n_blocks_scatter * min(K, 512)),
                }
            )
    return rows


def main():
    C.print_csv("Side experiment: fused vs unfused (SAAT scatter-topk, DAAT kernels)", run())


if __name__ == "__main__":
    main()
