"""Run every paper-table/figure benchmark + the roofline aggregation.

    PYTHONPATH=src python -m benchmarks.run            # CSV to stdout (as before)
    PYTHONPATH=src python -m benchmarks.run --json     # + BENCH_<name>.json each
    PYTHONPATH=src python -m benchmarks.run --json --out-dir results/

JSON mode wraps each benchmark's ``run()`` rows in a machine-readable record:
the module's UPPERCASE config constants (so a result can never be read apart
from the knobs that produced it), wall time, and ``parity_asserted`` — True
when the module bitwise-compares engine results *before* timing them
(``PARITY_ASSERTED`` tag), i.e. the speed numbers are provably not from a
wrong-answer fast path.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def _module_config(mod) -> dict:
    """The benchmark's UPPERCASE constants, JSON-ready (tuples -> lists)."""
    out = {}
    for k, v in vars(mod).items():
        if not k.isupper() or k.startswith("_") or k == "PARITY_ASSERTED":
            continue
        if isinstance(v, (list, tuple)):
            v = list(v)
            if not all(isinstance(x, (int, float, str, bool)) for x in v):
                continue
        elif not isinstance(v, (int, float, str, bool)):
            continue
        out[k] = v
    return out


def main(argv=None) -> None:
    p = argparse.ArgumentParser(prog="python -m benchmarks.run")
    p.add_argument("--json", action="store_true",
                   help="also write BENCH_<name>.json per benchmark")
    p.add_argument("--out-dir", default=".", metavar="DIR",
                   help="directory for the JSON records (default: cwd)")
    p.add_argument("--only", action="append", metavar="NAME",
                   help="run only the named benchmark(s)")
    args = p.parse_args(argv)

    from benchmarks import (
        fig1_rho_tradeoff,
        fig2_tail_latency,
        fig3_pareto,
        roofline,
        side_batched_vs_vmap,
        side_blockmax_vs_exhaustive,
        side_bucketed_vs_padded,
        side_daat_vs_saat_batched,
        side_degrade_vs_violate,
        side_delta_vs_rebuild,
        side_fused_chunk_vs_split,
        side_fused_vs_unfused,
        side_pod_merge,
        table1_models_systems,
        table2_term_stats,
    )

    benches = [
        ("table2_term_stats", table2_term_stats),
        ("table1_models_systems", table1_models_systems),
        ("fig1_rho_tradeoff", fig1_rho_tradeoff),
        ("fig2_tail_latency", fig2_tail_latency),
        ("fig3_pareto", fig3_pareto),
        ("side_blockmax_vs_exhaustive", side_blockmax_vs_exhaustive),
        ("side_batched_vs_vmap", side_batched_vs_vmap),
        ("side_daat_vs_saat_batched", side_daat_vs_saat_batched),
        ("side_fused_vs_unfused", side_fused_vs_unfused),
        ("side_fused_chunk_vs_split", side_fused_chunk_vs_split),
        ("side_bucketed_vs_padded", side_bucketed_vs_padded),
        ("side_degrade_vs_violate", side_degrade_vs_violate),
        ("side_delta_vs_rebuild", side_delta_vs_rebuild),
        ("side_pod_merge", side_pod_merge),
        ("roofline", roofline),
    ]
    if args.only:
        known = {name for name, _ in benches}
        unknown = sorted(set(args.only) - known)
        if unknown:
            p.error(f"unknown benchmark(s) {unknown}; have {sorted(known)}")
        benches = [(n, m) for n, m in benches if n in args.only]

    out_dir = Path(args.out_dir)
    if args.json:
        out_dir.mkdir(parents=True, exist_ok=True)

    t_all = time.time()
    failures = 0
    for name, mod in benches:
        t0 = time.time()
        try:
            if args.json:
                from benchmarks.common import print_csv

                rows = mod.run()
                print_csv(name, rows)
                record = {
                    "name": name,
                    "config": _module_config(mod),
                    "parity_asserted": bool(getattr(mod, "PARITY_ASSERTED", False)),
                    "elapsed_s": round(time.time() - t0, 3),
                    "rows": rows,
                }
                path = out_dir / f"BENCH_{name}.json"
                path.write_text(json.dumps(record, indent=2) + "\n")
                print(f"-- wrote {path}", flush=True)
            else:
                mod.main()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"!! {name} FAILED: {type(e).__name__}: {e}\n", flush=True)
        print(f"-- {name} took {time.time() - t0:.1f}s\n", flush=True)
    print(f"== all benchmarks done in {time.time() - t_all:.1f}s, failures={failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
