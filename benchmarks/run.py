"""Run every paper-table/figure benchmark + the roofline aggregation.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        fig1_rho_tradeoff,
        fig2_tail_latency,
        fig3_pareto,
        roofline,
        side_batched_vs_vmap,
        side_blockmax_vs_exhaustive,
        side_bucketed_vs_padded,
        side_daat_vs_saat_batched,
        side_fused_chunk_vs_split,
        side_fused_vs_unfused,
        table1_models_systems,
        table2_term_stats,
    )

    benches = [
        ("table2_term_stats", table2_term_stats.main),
        ("table1_models_systems", table1_models_systems.main),
        ("fig1_rho_tradeoff", fig1_rho_tradeoff.main),
        ("fig2_tail_latency", fig2_tail_latency.main),
        ("fig3_pareto", fig3_pareto.main),
        ("side_blockmax_vs_exhaustive", side_blockmax_vs_exhaustive.main),
        ("side_batched_vs_vmap", side_batched_vs_vmap.main),
        ("side_daat_vs_saat_batched", side_daat_vs_saat_batched.main),
        ("side_fused_vs_unfused", side_fused_vs_unfused.main),
        ("side_fused_chunk_vs_split", side_fused_chunk_vs_split.main),
        ("side_bucketed_vs_padded", side_bucketed_vs_padded.main),
        ("roofline", roofline.main),
    ]
    t_all = time.time()
    failures = 0
    for name, fn in benches:
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"!! {name} FAILED: {type(e).__name__}: {e}\n", flush=True)
        print(f"-- {name} took {time.time() - t0:.1f}s\n", flush=True)
    print(f"== all benchmarks done in {time.time() - t_all:.1f}s, failures={failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
