"""Figure 1 analogue: per-model SAAT rho sweep (effectiveness vs speedup).

Effectiveness is % of the rank-safe (exhaustive) RR@10; the work axis is both
relative time and postings processed (hardware-independent).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core import exact_rho, exhaustive_search, saat_search
from repro.core.saat import max_segments_per_term
from repro.models.treatments import MODEL_NAMES

K = 100
BATCH = 16
RHO_FRACS = (0.01, 0.05, 0.1, 0.25, 0.5, 1.0)


def run() -> list[dict]:
    rows = []
    for model in MODEL_NAMES:
        idx = C.index_for(model)
        qt, qw = C.queries_for(model)
        ms = max_segments_per_term(idx)
        ref = exhaustive_search(idx, qt, qw, k=K)
        ref_mrr = C.mrr(ref.doc_ids)
        _, ref_secs = C.timed(lambda q, w: exhaustive_search(idx, q, w, k=K), qt[:BATCH], qw[:BATCH])
        for frac in RHO_FRACS:
            rho = max(int(exact_rho(idx) * frac), 500)
            fn = lambda q, w: saat_search(idx, q, w, k=K, rho=rho, max_segs_per_term=ms, scatter_impl="sort")
            res, secs = C.timed(fn, qt[:BATCH], qw[:BATCH])
            full = fn(qt, qw)
            m = C.mrr(full.doc_ids)
            rows.append(
                {
                    "model": model,
                    "rho_frac": frac,
                    "rho": rho,
                    "rr@10": round(m, 4),
                    "rr@10_pct_of_exact": round(100 * m / max(ref_mrr, 1e-9), 1),
                    "speedup_vs_exhaustive": round(ref_secs / max(secs, 1e-9), 2),
                    "postings_processed": int(np.asarray(full.postings_processed).mean()),
                }
            )
    return rows


def main():
    C.print_csv("Fig 1: SAAT rho tradeoff per model", run())


if __name__ == "__main__":
    main()
