"""Table 1 analogue: retrieval model x evaluation system -> quality/time/space.

Systems (TPU-native translations, DESIGN.md §2):
  exhaustive   rank-safe dense disjunction (the PISA-MaxScore role at k=1000
               on wacky weights — the paper found pruning loses there)
  daat-bmw     vectorized Block-Max pruning (the WAND/BMW role)
  saat-exact   impact-ordered SAAT, rho = all postings (JASS exact)
  saat-approx  anytime SAAT, rho = 10% of postings (JASS rho=1m role)
Work metrics (postings, blocks scored) are hardware-independent; times are
relative CPU µs/query at batch 16.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core import blockmax_search, exact_rho, exhaustive_search, saat_search
from repro.core.daat import max_blocks_per_term
from repro.core.saat import max_segments_per_term
from repro.models.treatments import MODEL_NAMES

K = 100
BATCH = 16


def run() -> list[dict]:
    rows = []
    for model in MODEL_NAMES:
        idx = C.index_for(model)
        qt, qw = C.queries_for(model)
        qt_b, qw_b = qt[:BATCH], qw[:BATCH]
        ms = max_segments_per_term(idx)
        mb = max_blocks_per_term(idx)
        rho_exact = exact_rho(idx)
        rho_approx = max(rho_exact // 10, 1000)

        systems = {
            "exhaustive": lambda q, w: exhaustive_search(idx, q, w, k=K),
            "daat-bmw": lambda q, w: blockmax_search(
                idx, q, w, k=K, est_blocks=8, block_budget=16, max_bm_per_term=mb, exact=True
            ),
            "saat-exact": lambda q, w: saat_search(
                idx, q, w, k=K, rho=rho_exact, max_segs_per_term=ms, scatter_impl="sort"
            ),
            "saat-approx": lambda q, w: saat_search(
                idx, q, w, k=K, rho=rho_approx, max_segs_per_term=ms, scatter_impl="sort"
            ),
        }
        for sys_name, fn in systems.items():
            res, secs = C.timed(fn, qt_b, qw_b)
            full = fn(qt, qw)
            row = {
                "model": model,
                "system": sys_name,
                "rr@10": round(C.mrr(full.doc_ids), 4),
                "us_per_query": round(secs / BATCH * 1e6, 1),
                "index_mb": round(idx.posting_store_nbytes() / 1e6, 1),
                "postings_total": idx.n_postings,
            }
            if sys_name.startswith("saat"):
                row["postings_processed_mean"] = int(np.asarray(full.postings_processed).mean())
            if sys_name == "daat-bmw":
                row["blocks_scored_mean"] = int(np.asarray(full.blocks_scored).mean())
                row["blocks_total"] = idx.n_blocks
            rows.append(row)
    return rows


def main():
    C.print_csv("Table 1: model x system -> quality/time/space", run())


if __name__ == "__main__":
    main()
