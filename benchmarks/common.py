"""Shared benchmark substrate: corpus, encodings, indexes, timed search runs.

CPU wall-times here are RELATIVE (this container is not the target hardware);
the absolute performance story lives in the dry-run roofline
(benchmarks/roofline.py + EXPERIMENTS.md). What IS faithful on CPU are the
*work* metrics the paper's mechanisms act through: postings processed, blocks
survived/skipped, effectiveness, index sizes, and latency *distributions*
shapes (budget-bounded SAAT vs data-dependent DAAT).
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_impact_index, exact_rho, pad_queries
from repro.core.impact_index import ImpactIndex
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.metrics.ir_metrics import mrr_at_k
from repro.models.treatments import MODEL_NAMES, apply_treatment

BENCH_CORPUS = CorpusConfig(n_docs=6000, n_queries=160, n_concepts=400, seed=11)


@functools.lru_cache(maxsize=1)
def corpus():
    return generate_corpus(BENCH_CORPUS)


@functools.lru_cache(maxsize=None)
def encoded(model: str):
    return apply_treatment(corpus(), model)


@functools.lru_cache(maxsize=None)
def index_for(model: str) -> ImpactIndex:
    enc = encoded(model)
    return build_impact_index(enc.doc_idx, enc.term_idx, enc.weights, corpus().n_docs, enc.n_terms)


@functools.lru_cache(maxsize=None)
def queries_for(model: str):
    enc = encoded(model)
    max_q = max(len(t) for t in enc.query_terms)
    qt, qw = pad_queries(enc.query_terms, enc.query_weights, max_q, enc.n_terms)
    return jnp.asarray(qt), jnp.asarray(qw)


def timed(fn, *args, repeats: int = 3, **kwargs):
    """(result, best_seconds) with jit warmup excluded."""
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


def per_query_timings(fn, qt, qw, n: int = 40):
    """Per-query latency samples (batch=1 serving, tail-latency benches)."""
    fn(qt[:1], qw[:1])  # compile
    times = []
    for i in range(min(n, qt.shape[0])):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(qt[i : i + 1], qw[i : i + 1]))
        times.append((time.perf_counter() - t0) * 1e3)
    return np.asarray(times)


def mrr(ids, k: int = 10) -> float:
    return mrr_at_k(np.asarray(ids), corpus().qrels, k)


def print_csv(title: str, rows: list[dict]):
    print(f"# {title}")
    if not rows:
        print("(no rows)")
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
    print()
