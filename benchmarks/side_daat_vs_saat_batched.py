"""Side experiment: batched SAAT vs batched DAAT — the paper's Fig. 2 regime.

After PR 1 only SAAT ran as one natively batched executable, so the repo's
headline SAAT-vs-DAAT numbers compared a batched engine against B vmapped
programs — apples to oranges at serving scale. With ``daat_search_batched``
both engines now execute the whole ``[B, Lq]`` batch as ONE executable each,
so this bench finally reports an apples-to-apples throughput / tail-latency
comparison:

  * SAAT: rho-budgeted cost, identical instruction stream per batch — mean
    and p99 should sit on top of each other (predictable latency);
  * DAAT: the single while_loop runs until the SLOWEST query in the batch is
    rank-safe — mean/p99 spread is the paper's data-dependent tail, now
    measured per batched executable.

Run across models: BM25's skewed weights keep the DAAT loop short; wacky
learned weights (spladev2) collapse skipping and stretch its tail.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common as C
from repro.core import daat_search_batched, saat_search
from repro.core.daat import max_blocks_per_term
from repro.core.saat import max_segments_per_term

K = 100
RHO = 20_000
MODELS = ("bm25", "spladev2")
BATCH_SIZES = (1, 8, 32)
SCATTER = "sort"
EST_BLOCKS = 8
BLOCK_BUDGET = 16
REPEATS = 30


def _timed_samples(fn, qt, qw, repeats: int) -> np.ndarray:
    jax.block_until_ready(fn(qt, qw))  # compile
    out = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(qt, qw))
        out.append((time.perf_counter() - t0) * 1e3)
    return np.asarray(out)


def run() -> list[dict]:
    rows = []
    for model in MODELS:
        idx = C.index_for(model)
        qt_all, qw_all = C.queries_for(model)
        ms = max_segments_per_term(idx)
        mb = max_blocks_per_term(idx)
        rho = min(RHO, idx.n_postings)
        for bs in BATCH_SIZES:
            reps = -(-bs // qt_all.shape[0])
            qt = np.tile(np.asarray(qt_all), (reps, 1))[:bs]
            qw = np.tile(np.asarray(qw_all), (reps, 1))[:bs]
            qt, qw = jax.numpy.asarray(qt), jax.numpy.asarray(qw)

            saat = lambda q, w: saat_search(
                idx, q, w, k=K, rho=rho, max_segs_per_term=ms, scatter_impl=SCATTER
            )
            daat = lambda q, w: daat_search_batched(
                idx, q, w, k=K, est_blocks=EST_BLOCKS, block_budget=BLOCK_BUDGET,
                max_bm_per_term=mb, exact=True,
            )
            ts = _timed_samples(saat, qt, qw, REPEATS)
            td = _timed_samples(daat, qt, qw, REPEATS)
            work = daat(qt, qw)
            rows.append(
                {
                    "model": model,
                    "batch": bs,
                    "saat_mean_ms": round(float(ts.mean()), 3),
                    "saat_p99_ms": round(float(np.percentile(ts, 99)), 3),
                    "daat_mean_ms": round(float(td.mean()), 3),
                    "daat_p99_ms": round(float(np.percentile(td, 99)), 3),
                    "daat_chunks_max": int(np.asarray(work.chunks).max()),
                    "daat_blocks_scored_mean": int(np.asarray(work.blocks_scored).mean()),
                    "blocks_total": idx.n_blocks,
                    "saat_faster": bool(ts.mean() < td.mean()),
                }
            )
    return rows


def main():
    C.print_csv("Side experiment: batched SAAT vs batched DAAT", run())


if __name__ == "__main__":
    main()
