"""Side experiment: fused vs split DAAT phase-2 chunk step (PR 5 tentpole).

Both configs run ``daat_search_batched(use_kernels=True)``; what differs is
what one while_loop trip does to the HBM boundary:

  * **split** (``fused_chunk=False``): three launches per trip —
    ``block_topk_batched`` selection, ``sparse_score_batched`` scoring, and
    the jnp ``merge_topk`` — with the gathered ``[B, budget, bs, Tmax]`` doc
    tiles, the ``[B, budget, bs]`` score tensor, and the selection finalists
    all written to HBM by one stage and re-read by the next;
  * **fused** (``fused_chunk=True``): ONE ``chunk_step`` launch per trip;
    pool/theta/candidate-tile/processed-row state stays in VMEM scratch, the
    selected doc blocks stream HBM->VMEM once via double-buffered async-copy
    DMA, and only the updated per-query state (the candidate output) crosses
    back;
  * **multi** (``fused_chunk=True, trips_per_launch=N``): up to N trips run
    inside ONE launch (scalar-prefetched trip budget, in-kernel early exit),
    so the per-query state crosses HBM once per N trips instead of once per
    trip and the outer while_loop dispatches ``ceil(trips / N)`` launches.

The paper's wacky-weight regime multiplies exactly this per-trip traffic:
when skipping collapses, the trip count tracks the worst query in the batch
(PAPER.md §4.2), so the split path's round-trips scale with the collapse.

The ``hbm_roundtrip_floats_per_trip_*`` columns count f32-equivalents that
are *written by one stage and re-read by another* inside a single trip
(read-once streaming of the doc-major rows is excluded — both paths must
read the postings): the split path pays the gathered doc tiles twice
(gather write + kernel read), the score tensor twice (scorer write + merge
read), and the remaining-ub vector once; the fused path pays only the
per-query state output — pool scores/ids, theta, processed row. The run
asserts doc-id AND WorkStats parity between the two configs before timing.

On CPU the Pallas kernels run in interpret mode, so absolute times favor
whichever path launches fewer interpreted kernels; what is faithful here is
the harness shape and the parity/accounting — the HBM-traffic win is a TPU
property (see the roofline bench).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks import common as C
from repro.core import daat_search_batched
from repro.core.daat import max_blocks_per_term

# REPRO_BENCH_TINY=1 shrinks the sweep to CI-sized CPU shapes: the point of
# the lane is the parity assert + launch accounting, not the wall times
TINY = os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0")

K = 100
MODELS = ("bm25",) if TINY else ("bm25", "spladev2")
BATCH_SIZES = (1, 4) if TINY else (1, 8, 32)
EST_BLOCKS = 8
BLOCK_BUDGET = 16
TRIPS_PER_LAUNCH = 4  # the multi config's in-launch trip budget
# interpret-mode kernels on CPU run tens of seconds per call for the wacky
# models at B=32 (skipping collapses -> long while_loop of interpreted
# launches), so keep the sample count small; on TPU raise this freely
REPEATS = 1 if TINY else 3
PARITY_ASSERTED = True  # run() bitwise-compares doc ids before any timing


def _timed_samples(fn, qt, qw, repeats: int) -> np.ndarray:
    jax.block_until_ready(fn(qt, qw).scores)  # compile
    out = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(qt, qw).scores)
        out.append((time.perf_counter() - t0) * 1e3)
    return np.asarray(out)


def _stats(samples: np.ndarray) -> tuple[float, float]:
    return round(float(samples.mean()), 3), round(float(np.percentile(samples, 99)), 3)


def run() -> list[dict]:
    rows = []
    for model in MODELS:
        idx = C.index_for(model)
        qt_all, qw_all = C.queries_for(model)
        mb = max_blocks_per_term(idx)
        budget = min(BLOCK_BUDGET, idx.n_blocks)
        bs = idx.block_size
        tmax = idx.max_doc_terms
        for n in BATCH_SIZES:
            reps = -(-n // qt_all.shape[0])
            qt = np.tile(np.asarray(qt_all), (reps, 1))[:n]
            qw = np.tile(np.asarray(qw_all), (reps, 1))[:n]
            qt, qw = jax.numpy.asarray(qt), jax.numpy.asarray(qw)

            def daat(q, w, fused, trips=1):
                return daat_search_batched(
                    idx, q, w, k=K, est_blocks=EST_BLOCKS, block_budget=BLOCK_BUDGET,
                    max_bm_per_term=mb, exact=True,
                    use_kernels=True, fused_chunk=fused, trips_per_launch=trips,
                )

            # the fusion must be invisible in results before it is timed:
            # ids bitwise AND the per-query work metrics (trip counts drive
            # the comparison, so they must be identical by construction) —
            # and the multi-trip batching must be invisible on top of that
            split, fused = daat(qt, qw, False), daat(qt, qw, True)
            multi = daat(qt, qw, True, trips=TRIPS_PER_LAUNCH)
            assert (np.asarray(split.doc_ids) == np.asarray(fused.doc_ids)).all()
            assert (np.asarray(split.doc_ids) == np.asarray(multi.doc_ids)).all()
            for field in ("n_survivors", "blocks_scored", "chunks", "rank_safe"):
                ref = np.asarray(getattr(split.stats, field))
                for other in (fused, multi):
                    assert (
                        ref == np.asarray(getattr(other.stats, field))
                    ).all(), f"WorkStats.{field} diverged"

            t_split = _stats(_timed_samples(lambda q, w: daat(q, w, False), qt, qw, REPEATS))
            t_fused = _stats(_timed_samples(lambda q, w: daat(q, w, True), qt, qw, REPEATS))
            t_multi = _stats(
                _timed_samples(
                    lambda q, w: daat(q, w, True, trips=TRIPS_PER_LAUNCH), qt, qw, REPEATS
                )
            )
            k_eff = min(K, idx.n_docs)
            split_floats = n * (
                2 * budget * bs * tmax  # gathered doc tiles: gather write + kernel read
                + 2 * budget * bs  # score tensor: scorer write + merge read
                + idx.n_blocks  # remaining-ub vector read by the select kernel
            )
            fused_floats = n * (2 * k_eff + 1 + idx.n_blocks)  # pool + theta + bitmap
            # launch accounting: per-trip modes dispatch one chunk_step (or
            # three split stages) per trip; multi-trip dispatches one launch
            # per ceil(trips / T) — the batch runs to its slowest row, so the
            # batch launch count is the max over rows
            chunks = np.asarray(fused.chunks)
            trips_max = int(chunks.max())
            launches_multi = int(np.ceil(chunks / TRIPS_PER_LAUNCH).max())
            assert launches_multi <= -(-trips_max // TRIPS_PER_LAUNCH), (
                f"multi-trip dispatched {launches_multi} launches for "
                f"trips_max={trips_max}, budget={TRIPS_PER_LAUNCH}"
            )
            rows.append(
                {
                    "model": model,
                    "batch": n,
                    "trips_max": trips_max,
                    "split_mean_ms": t_split[0],
                    "split_p99_ms": t_split[1],
                    "fused_mean_ms": t_fused[0],
                    "fused_p99_ms": t_fused[1],
                    "multi_mean_ms": t_multi[0],
                    "multi_p99_ms": t_multi[1],
                    "launches_per_query_fused": trips_max,
                    "launches_per_query_multi": launches_multi,
                    "hbm_roundtrip_floats_per_trip_split": int(split_floats),
                    "hbm_roundtrip_floats_per_trip_fused": int(fused_floats),
                }
            )
    return rows


def main():
    C.print_csv("Side experiment: fused vs split DAAT chunk step", run())


if __name__ == "__main__":
    main()
