"""Table 2 analogue: term statistics per retrieval-model treatment."""
from __future__ import annotations

from benchmarks import common as C
from repro.core.wacky import term_statistics, weight_distribution_stats
from repro.models.treatments import MODEL_NAMES, PROFILES


def run() -> list[dict]:
    rows = []
    for model in MODEL_NAMES:
        enc = C.encoded(model)
        ts = term_statistics(
            enc.doc_idx, enc.term_idx, enc.weights, C.corpus().n_docs,
            enc.query_terms, enc.query_weights,
        )
        dist = weight_distribution_stats(enc.weights)
        targets = PROFILES[model].table2_targets
        rows.append(
            {
                "model": model,
                "vocab": ts.vocab_size,
                "doc_total_terms": round(ts.doc_total_terms, 1),
                "doc_unique_terms": round(ts.doc_unique_terms, 1),
                "q_total_terms": round(ts.query_total_terms, 1),
                "q_unique_terms": round(ts.query_unique_terms, 1),
                "weight_cv": round(dist["cv"], 3),
                "weight_gini": round(dist["gini"], 3),
                "paper_doc_unique": targets.get("doc_unique"),
                "paper_q_unique": targets.get("q_unique"),
            }
        )
    return rows


def main():
    C.print_csv("Table 2: term statistics per treatment", run())


if __name__ == "__main__":
    main()
