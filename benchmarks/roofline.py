"""§Roofline table: aggregates results/dryrun/*.json into the per-cell report.

Reads the dry-run artifacts (memory fit, analytic FLOPs/bytes, loop-aware
collective census) and emits, per (arch x shape x mesh): the three roofline
terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs utilization, and the
projected step time.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load_cells(mesh: str | None = None) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        cells.append(rec)
    return cells


def run(mesh: str = "single") -> list[dict]:
    rows = []
    for rec in load_cells(mesh):
        base = {"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"]}
        if rec["status"] == "skipped":
            rows.append({**base, "status": "skipped", "note": rec["skip_reason"][:60]})
            continue
        if rec["status"] != "ok":
            rows.append({**base, "status": "ERROR", "note": rec.get("error", "")[:60]})
            continue
        r = rec["roofline"]
        rows.append(
            {
                **base,
                "status": "ok",
                "mem_gib_per_chip": round(rec["memory"]["total_bytes"] / 2**30, 2),
                "compute_s": f"{r['compute_s']:.3e}",
                "memory_s": f"{r['memory_s']:.3e}",
                "collective_s": f"{r['collective_s']:.3e}",
                "bottleneck": r["bottleneck"].replace("_s", ""),
                "step_lower_bound_s": f"{r['step_time_lower_bound_s']:.3e}",
                "roofline_fraction": round(r["roofline_fraction"], 3),
                "useful_flops_ratio": round(rec.get("useful_flops_ratio") or 0, 3),
            }
        )
    return rows


def main():
    from benchmarks.common import print_csv

    for mesh in ("single", "multi"):
        rows = run(mesh)
        if rows:
            print_csv(f"Roofline table ({mesh}-pod)", rows)


if __name__ == "__main__":
    main()
