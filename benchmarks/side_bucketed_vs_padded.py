"""Side experiment: Lq-bucketed vs max-Lq-padded serving.

Both engines pad a ``[B, Lq]`` batch to one width and run one executable, so
a stream whose longest query has 40 terms makes a 4-term query pay a 10x
wider plan sort + posting gather. ``ServingConfig.lq_buckets`` pads each
batch only to the smallest bucket covering its live terms instead.

This bench serves three traffic mixes at B in {8, 32}:

  * ``short``  — every request truncated to 4 live terms: bucketing should
    win by roughly the width ratio on the plan/gather stages;
  * ``long``   — full-width requests: bucketing must cost ~nothing (same
    executable as the padded baseline);
  * ``mixed``  — short and long requests interleaved *in one batch*: the
    batch's widest member drags everyone to the wide bucket, so bucketing
    alone barely helps — this is exactly the traffic the admission queue
    (``repro.serving.queue``) fixes by partitioning requests into per-bucket
    lanes before batching.

Doc-id parity between the two servers is asserted on every batch BEFORE any
timing (bucketing is bit-identity-preserving; see tests/test_queue.py for
the score-level property). CPU wall times are relative, as everywhere in
benchmarks/ — the faithful signal is the bucketed/padded ratio per mix.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.serving import AnytimeServer, ServingConfig
from repro.serving.bucketing import pad_to_width

K = 100
MODELS = ("bm25", "spladev2")
BATCH_SIZES = (8, 32)
MIXES = ("short", "long", "mixed")
SHORT_W = 4
N_BATCHES = 4
REPEATS = 5
PARITY_ASSERTED = True  # run() bitwise-compares doc ids before any timing


def _batches(qt: np.ndarray, qw: np.ndarray, B: int, mix: str):
    """Deterministic request batches for one traffic mix (host arrays)."""
    L = qt.shape[1]
    out = []
    for i in range(N_BATCHES):
        rows = (np.arange(B) + i * B) % qt.shape[0]
        bt, bw = qt[rows], qw[rows]
        if mix == "short":
            bt, bw = bt[:, :SHORT_W], bw[:, :SHORT_W]
        elif mix == "mixed":
            # half the batch truncated short, half full width: the wide half
            # drags the whole batch to the wide bucket
            bt = bt.copy()
            bw = bw.copy()
            half = B // 2
            bw[:half, SHORT_W:] = 0.0  # zero weight = dead slot in both engines
        out.append((np.ascontiguousarray(bt), np.ascontiguousarray(bw)))
    return out


def _per_query_samples(server: AnytimeServer, batches, rho: int) -> np.ndarray:
    for bt, bw in batches:  # compile every shape first
        server.search_batch(jnp.asarray(bt), jnp.asarray(bw), rho=rho)
    samples = []
    for _ in range(REPEATS):
        for bt, bw in batches:
            t0 = time.perf_counter()
            res = server.search_batch(jnp.asarray(bt), jnp.asarray(bw), rho=rho)
            jax.block_until_ready(res.scores)
            samples.append((time.perf_counter() - t0) * 1e3 / bt.shape[0])
    return np.asarray(samples)


def run() -> list[dict]:
    rows = []
    for model in MODELS:
        index = C.index_for(model)
        qt, qw = C.queries_for(model)
        qt, qw = np.asarray(qt), np.asarray(qw)
        L = qt.shape[1]
        buckets = tuple(sorted({SHORT_W, max(2 * SHORT_W, SHORT_W + 1), L}))
        padded = AnytimeServer(index, ServingConfig(k=K, rho_ladder=(20_000,)))
        bucketed = AnytimeServer(
            index, ServingConfig(k=K, rho_ladder=(20_000,), lq_buckets=buckets)
        )
        rho = padded.rho_ladder[0]
        for B in BATCH_SIZES:
            for mix in MIXES:
                batches = _batches(qt, qw, B, mix)
                # ---- id parity BEFORE timing: bucketing must be invisible
                for bt, bw in batches:
                    pt, pw = pad_to_width(bt, bw, L, index.n_terms)
                    r_pad = padded.search_batch(jnp.asarray(pt), jnp.asarray(pw), rho=rho)
                    r_buk = bucketed.search_batch(jnp.asarray(bt), jnp.asarray(bw), rho=rho)
                    assert np.array_equal(
                        np.asarray(r_pad.doc_ids), np.asarray(r_buk.doc_ids)
                    ), f"bucketed ids diverged ({model}, B={B}, mix={mix})"
                padded_batches = [pad_to_width(bt, bw, L, index.n_terms) for bt, bw in batches]
                s_pad = _per_query_samples(padded, padded_batches, rho)
                s_buk = _per_query_samples(bucketed, batches, rho)
                rows.append(
                    {
                        "model": model,
                        "B": B,
                        "mix": mix,
                        "max_lq": L,
                        "buckets": "/".join(map(str, buckets)),
                        "padded_mean_ms": round(float(s_pad.mean()), 3),
                        "padded_p99_ms": round(float(np.percentile(s_pad, 99)), 3),
                        "bucketed_mean_ms": round(float(s_buk.mean()), 3),
                        "bucketed_p99_ms": round(float(np.percentile(s_buk, 99)), 3),
                        "speedup_mean": round(float(s_pad.mean() / s_buk.mean()), 2),
                    }
                )
    return rows


def main() -> None:
    rows = run()
    C.print_csv("side: Lq-bucketed vs max-Lq-padded serving (id parity asserted)", rows)


if __name__ == "__main__":
    main()
