"""Side experiment: natively batched SAAT engine vs the legacy vmap path.

The batched engine runs the whole ``[B, Lq]`` batch as one executable — one
batched plan argsort, one batched binary-search gather, one batch-aware
scatter — where the legacy formulation vmaps a single-query program B times.
Guided-traversal follow-ups show evaluator-level batching dominates learned
sparse latency; this bench records mean and p99 per-batch latency at several
batch sizes so the win (and where it starts) is visible on any backend.

Both paths share rho, k, and scatter_impl, and return identical doc ids
(asserted below), so the timing difference is pure execution strategy.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common as C
from repro.core import saat_search, saat_search_vmap
from repro.core.saat import max_segments_per_term

K = 100
RHO = 20_000
MODEL = "bm25"
BATCH_SIZES = (1, 8, 32, 64)
SCATTER = "sort"
REPEATS = 30
PARITY_ASSERTED = True  # run() bitwise-compares doc ids before any timing


def _timed_samples(fn, qt, qw, repeats: int) -> np.ndarray:
    jax.block_until_ready(fn(qt, qw))  # compile
    out = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(qt, qw))
        out.append((time.perf_counter() - t0) * 1e3)
    return np.asarray(out)


def run() -> list[dict]:
    idx = C.index_for(MODEL)
    qt_all, qw_all = C.queries_for(MODEL)
    ms = max_segments_per_term(idx)
    rho = min(RHO, idx.n_postings)
    rows = []
    for bs in BATCH_SIZES:
        reps = -(-bs // qt_all.shape[0])
        qt = np.tile(np.asarray(qt_all), (reps, 1))[:bs]
        qw = np.tile(np.asarray(qw_all), (reps, 1))[:bs]
        qt, qw = jax.numpy.asarray(qt), jax.numpy.asarray(qw)

        batched = lambda q, w: saat_search(
            idx, q, w, k=K, rho=rho, max_segs_per_term=ms, scatter_impl=SCATTER
        )
        vmapped = lambda q, w: saat_search_vmap(
            idx, q, w, k=K, rho=rho, max_segs_per_term=ms, scatter_impl=SCATTER
        )
        # identical doc ids, or the timing comparison is meaningless
        rb, rv = batched(qt, qw), vmapped(qt, qw)
        assert (np.asarray(rb.doc_ids) == np.asarray(rv.doc_ids)).all()

        tb = _timed_samples(batched, qt, qw, REPEATS)
        tv = _timed_samples(vmapped, qt, qw, REPEATS)
        rows.append(
            {
                "batch": bs,
                "rho": rho,
                "batched_mean_ms": round(float(tb.mean()), 3),
                "batched_p99_ms": round(float(np.percentile(tb, 99)), 3),
                "vmap_mean_ms": round(float(tv.mean()), 3),
                "vmap_p99_ms": round(float(np.percentile(tv, 99)), 3),
                "mean_speedup": round(float(tv.mean() / tb.mean()), 3),
                "batched_faster": bool(tb.mean() < tv.mean()),
            }
        )
    return rows


def main():
    C.print_csv("Side experiment: natively batched SAAT vs vmap", run())


if __name__ == "__main__":
    main()
