"""Side experiment: pruned DAAT vs exhaustive on wacky weights.

The paper found WAND/BMW *slower* than exhaustive disjunction for SPLADEv2 —
when bounds can't prune, pruning machinery is pure overhead. We reproduce the
mechanism: the skippable fraction collapses and blockmax-DAAT's scored-block
count approaches the total, while its bound-evaluation overhead stays.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core import daat_search_batched, daat_search_vmap, exhaustive_search
from repro.core.daat import max_blocks_per_term
from repro.core.wacky import blockmax_tightness, skip_opportunity

K = 100
BATCH = 16
MODELS = ("bm25", "bm25-t5", "deepimpact", "unicoil-t5", "spladev2")


def run() -> list[dict]:
    rows = []
    for model in MODELS:
        idx = C.index_for(model)
        qt, qw = C.queries_for(model)
        mb = max_blocks_per_term(idx)
        _, ex_secs = C.timed(lambda q, w: exhaustive_search(idx, q, w, k=K), qt[:BATCH], qw[:BATCH])
        daat = lambda q, w: daat_search_batched(
            idx, q, w, k=K, est_blocks=8, block_budget=16, max_bm_per_term=mb, exact=True
        )
        daat_vmap = lambda q, w: daat_search_vmap(
            idx, q, w, k=K, est_blocks=8, block_budget=16, max_bm_per_term=mb, exact=True
        )
        full, daat_secs = C.timed(daat, qt[:BATCH], qw[:BATCH])
        _, vmap_secs = C.timed(daat_vmap, qt[:BATCH], qw[:BATCH])
        skip = skip_opportunity(idx, qt, qw, k=K, max_bm_per_term=mb)
        tight = blockmax_tightness(idx)
        rows.append(
            {
                "model": model,
                "skippable_fraction": round(skip["skippable_fraction_mean"], 3),
                "blockmax_tightness": round(tight["tightness"], 3),
                "blocks_scored_mean": int(np.asarray(daat(qt, qw).blocks_scored).mean()),
                "blocks_total": idx.n_blocks,
                "daat_us_per_q": round(daat_secs / BATCH * 1e6, 1),
                "daat_vmap_us_per_q": round(vmap_secs / BATCH * 1e6, 1),
                "exhaustive_us_per_q": round(ex_secs / BATCH * 1e6, 1),
                "daat_slower": bool(daat_secs > ex_secs),
            }
        )
    return rows


def main():
    C.print_csv("Side experiment: pruned DAAT vs exhaustive", run())


if __name__ == "__main__":
    main()
