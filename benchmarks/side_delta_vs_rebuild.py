"""Side experiment: delta/tombstone mutation vs from-scratch rebuild.

The index-lifecycle claim: an ``IndexHandle`` absorbs corpus churn at a
per-mutation cost that is tiny and roughly constant (rebuild a small delta
segment, flip a tombstone bit), while serving answers id-identical to a
brute-force rebuild of the post-mutation corpus — whose cost grows with
the whole corpus, not the churn. This bench applies the SAME mutation
batches to both paths and times (a) applying one batch + serving one query
batch through the handle vs (b) rebuilding the full index from the
mutated corpus + serving the same queries over it.

Doc-id parity is asserted before any rows are emitted: after EVERY
mutation batch, handle-served ids must be bitwise-identical to the
brute-force-rebuilt oracle (same pinned quantization grid, handle's live
mask) — the bench refuses to time two paths that disagree.

REPRO_BENCH_TINY=1 shrinks the corpus/churn to CI-sized shapes; the
parity assert and the growth contrast are the lane's value there, not the
absolute wall times.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_impact_index, pad_queries, saat
from repro.core.index_handle import IndexHandle
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.models.treatments import apply_treatment

TINY = os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0")

MODEL = "spladev2"
K = 10
N_BATCHES = 3 if TINY else 6
MUTATIONS_PER_BATCH = 8 if TINY else 64
PARITY_ASSERTED = True  # handle ids bitwise == rebuilt-oracle ids, pre-rows


def _corpus():
    if TINY:
        return generate_corpus(CorpusConfig(n_docs=400, n_queries=24, n_concepts=80, seed=5))
    return generate_corpus(CorpusConfig(n_docs=8000, n_queries=120, n_concepts=500, seed=13))


def _mutation_batches(rng, n_docs, n_terms):
    """Deterministic add/update/delete batches, handle-gid-order faithful."""
    alive = list(range(n_docs))
    next_gid = n_docs
    batches = []
    for _ in range(N_BATCHES):
        ops = []
        for _ in range(MUTATIONS_PER_BATCH):
            op = rng.choice(["add", "update", "delete"], p=[0.5, 0.25, 0.25])
            if op != "add" and not alive:
                op = "add"
            if op == "add":
                n = int(rng.integers(3, 9))
                terms = rng.choice(n_terms, n, replace=False).astype(np.int64)
                weights = rng.uniform(0.2, 4.0, n)
                ops.append(("add", next_gid, terms, weights))
                alive.append(next_gid)
                next_gid += 1
            elif op == "update":
                gid = int(alive[int(rng.integers(len(alive)))])
                n = int(rng.integers(3, 9))
                terms = rng.choice(n_terms, n, replace=False).astype(np.int64)
                weights = rng.uniform(0.2, 4.0, n)
                ops.append(("update", gid, terms, weights))
            else:
                gid = alive.pop(int(rng.integers(len(alive))))
                ops.append(("delete", gid, None, None))
        batches.append(ops)
    return batches


class _Mirror:
    """Raw post-mutation corpus: the oracle's build input."""

    def __init__(self, d, t, w, n_docs):
        self.docs = {}
        for gid in range(n_docs):
            sel = d == gid
            self.docs[int(gid)] = (t[sel], w[sel])
        self.n_docs = n_docs
        self.dead: set[int] = set()

    def apply(self, ops):
        for op, gid, terms, weights in ops:
            if op == "delete":
                self.dead.add(gid)
            else:
                self.docs[gid] = (terms, weights)
                self.n_docs = max(self.n_docs, gid + 1)

    def coo(self):
        d, t, w = [], [], []
        for gid, (terms, weights) in self.docs.items():
            if gid in self.dead:
                continue
            d.append(np.full(len(terms), gid, np.int64))
            t.append(np.asarray(terms, np.int64))
            w.append(np.asarray(weights, np.float64))
        return np.concatenate(d), np.concatenate(t), np.concatenate(w)


def _apply_to_handle(handle, ops):
    for op, gid, terms, weights in ops:
        if op == "add":
            got = handle.add(terms, weights)
            assert got == gid, "bench gid schedule diverged from handle"
        elif op == "update":
            handle.update(gid, terms, weights)
        else:
            handle.delete(gid)


def _oracle_ids(mirror, handle, qt, qw):
    d, t, w = mirror.coo()
    index = build_impact_index(
        d, t, w, mirror.n_docs, handle.n_terms,
        quant_max_weight=handle.quant_max_weight,
        block_size=handle.main.block_size,
    )
    live = jnp.asarray(handle.live_mask_full(int(index.doc_n_terms.shape[0])))
    res = saat.saat_search(
        index, qt, qw, k=K, rho=saat.exact_rho(index),
        max_segs_per_term=saat.max_segments_per_term(index), live_mask=live,
    )
    return np.asarray(res.scores), np.asarray(res.doc_ids)


def run() -> list[dict]:
    corpus = _corpus()
    enc = apply_treatment(corpus, MODEL)
    handle = IndexHandle.from_corpus(
        enc.doc_idx, enc.term_idx, enc.weights, corpus.n_docs, enc.n_terms
    )
    mirror = _Mirror(enc.doc_idx, enc.term_idx, enc.weights, corpus.n_docs)
    max_q = max(len(t) for t in enc.query_terms)
    qt_np, qw_np = pad_queries(enc.query_terms, enc.query_weights, max_q, enc.n_terms)
    B = 8 if TINY else 16
    qt, qw = jnp.asarray(qt_np[:B]), jnp.asarray(qw_np[:B])

    rng = np.random.default_rng(17)
    batches = _mutation_batches(rng, corpus.n_docs, enc.n_terms)

    rows = []
    for i, ops in enumerate(batches):
        # ---- delta path: apply to the handle, serve
        t0 = time.perf_counter()
        _apply_to_handle(handle, ops)
        apply_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        res = handle.saat_search(qt, qw, k=K)
        jax.block_until_ready(res.scores)
        serve_ms = (time.perf_counter() - t0) * 1e3

        # ---- rebuild path: fold the mutated corpus from scratch, serve
        mirror.apply(ops)
        t0 = time.perf_counter()
        oracle_scores, oracle_ids = _oracle_ids(mirror, handle, qt, qw)
        rebuild_ms = (time.perf_counter() - t0) * 1e3

        # ---- parity BEFORE the row lands: finite counts equal, ids bitwise
        hs, hi = np.asarray(res.scores), np.asarray(res.doc_ids)
        fin, fino = np.isfinite(hs), np.isfinite(oracle_scores)
        assert np.array_equal(fin.sum(1), fino.sum(1)), (
            f"batch {i}: live result count diverged from rebuilt oracle"
        )
        for b in range(hs.shape[0]):
            assert np.array_equal(hi[b][fino[b]], oracle_ids[b][fino[b]]), (
                f"batch {i} query {b}: handle ids diverged from rebuilt oracle"
            )

        rows.append(
            {
                "batch": i,
                "mutations": len(ops),
                "delta_docs": handle.delta_docs,
                "tombstones": handle.tombstone_count,
                "delta_apply_ms": round(apply_ms, 2),
                "delta_serve_ms": round(serve_ms, 2),
                "rebuild_and_serve_ms": round(rebuild_ms, 2),
                "ids_bit_identical": True,
            }
        )

    # ---- compaction epilogue: fold, re-verify, report the fold cost
    t0 = time.perf_counter()
    handle.compact()
    compact_ms = (time.perf_counter() - t0) * 1e3
    res = handle.saat_search(qt, qw, k=K)
    oracle_scores, oracle_ids = _oracle_ids(mirror, handle, qt, qw)
    hs, hi = np.asarray(res.scores), np.asarray(res.doc_ids)
    fino = np.isfinite(oracle_scores)
    assert np.array_equal(np.isfinite(hs).sum(1), fino.sum(1))
    for b in range(hs.shape[0]):
        assert np.array_equal(hi[b][fino[b]], oracle_ids[b][fino[b]]), (
            f"post-compaction query {b}: ids diverged from rebuilt oracle"
        )
    rows.append(
        {
            "batch": "compact",
            "mutations": 0,
            "delta_docs": handle.delta_docs,
            "tombstones": handle.tombstone_count,
            "delta_apply_ms": round(compact_ms, 2),
            "delta_serve_ms": "",
            "rebuild_and_serve_ms": "",
            "ids_bit_identical": True,
        }
    )
    return rows


def main() -> None:
    from benchmarks.common import print_csv

    rows = run()
    print_csv(
        "side: delta/tombstone mutation vs from-scratch rebuild "
        "(id parity asserted per batch)",
        rows,
    )


if __name__ == "__main__":
    main()
