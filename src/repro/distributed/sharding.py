"""Per-arch PartitionSpec rules (DP / TP / EP / sequence / doc sharding).

One rule table maps param-leaf paths to logical layouts; logical layouts map
to mesh axes for whichever mesh is in play — so the same model code serves
the single-pod ``(data=16, model=16)`` and the multi-pod
``(pod=2, data=16, model=16)`` meshes (the ``pod`` axis joins the
data-parallel group).

Layout conventions (MaxText-style ZeRO/TP hybrid):
  * 2D weights: one dim over ``model`` (tensor parallel), the other over the
    data axes (FSDP-style param/optimizer-state sharding — this is what lets
    a 34B model's AdamW moments fit 256 chips);
  * column-parallel in (wq/wk/wv/w_gate/w_up/unembed: out-dim over model),
    row-parallel out (wo/w_down: in-dim over model) — the classic Megatron
    pairing that keeps activations model-sharded through the block with one
    reduce per projection pair;
  * MoE experts: expert axis over ``model`` (expert parallelism); dispatch
    becomes GSPMD all-to-all;
  * embeddings: vocab/row axis over ``model`` (vocab- / row-sharded tables;
    recsys tables are exactly the classic row-sharded EmbeddingBag);
  * KV caches: sequence axis over ``model`` (decode attention reduces over
    the cache; GSPMD inserts the score psum) — batch over data;
  * GNN: node/edge arrays over ALL axes flattened (the edge work dominates);
  * retrieval: documents/candidates over ``model``, queries over data —
    per-shard top-k + k-sized all-gather merge (repro.core.topk).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Axes:
    """Resolved mesh axis names."""

    data: tuple[str, ...]  # all data-parallel axes ("pod" folds in here)
    model: str = "model"

    @property
    def all(self) -> tuple[str, ...]:
        return self.data + (self.model,)


def mesh_axes(mesh: Mesh) -> Axes:
    names = mesh.axis_names
    data = tuple(n for n in names if n != "model")
    return Axes(data=data)


def _right_align(spec_tail: tuple, ndim: int) -> P:
    """Pad a trailing-dims spec with None for any leading (stack) axes."""
    pad = ndim - len(spec_tail)
    return P(*((None,) * pad + tuple(spec_tail)))


# --------------------------------------------------------------------------
# rule tables: (regex on key path, trailing-dims logical spec)
# logical tokens: "model" | "data" | None
# --------------------------------------------------------------------------

# Each rule maps a path regex to a list of candidate trailing-dim layouts,
# in preference order; the first candidate whose sharded dims are all
# divisible by their axis sizes wins (jit *input* shardings must divide
# evenly — internal constraints may be uneven, inputs may not). Non-divisible
# dims inside the winning candidate degrade to None individually.
LM_RULES: list[tuple[str, list]] = [
    # vocab over model ONLY: sharding D (the logits contraction dim) over
    # data makes SPMD emit a [tokens, vocab]-sized partial-sum all-reduce
    # per loss chunk — measured 62 GB/step on gemma3 (EXPERIMENTS.md §Perf)
    (r"embed$", [("model", None)]),  # [V, D] vocab-sharded
    (r"unembed$", [(None, "model")]),  # [D, V]
    (r"(wq|wk|wv)$", [("data", "model")]),  # column-parallel
    (r"wo$", [("model", "data")]),  # row-parallel
    # MoE (before the dense FFN rules): prefer EP on the expert axis; if E
    # doesn't divide the model axis (granite: 40 experts / 16 chips), fall
    # back to TP on the expert-ff dim
    (r"moe.*(w_gate|w_up)$", [("model", "data", None), (None, "data", "model")]),
    (r"moe.*w_down$", [("model", None, "data"), (None, "model", "data")]),
    (r"(w_gate|w_up)$", [("data", "model")]),
    (r"w_down$", [("model", "data")]),
    (r"router$", [("data", None)]),
    (r"(scale|bias)$", [()]),  # norms replicated
    (r"pos_embed$", [()]),
]

GNN_RULES: list[tuple[str, list]] = [
    (r"w1$", [(None, "model")]),
    (r"w2$", [("model", None)]),
    (r"(b1|b2)$", [()]),
]

RECSYS_RULES: list[tuple[str, list]] = [
    # rows over EVERY axis: the table grad scatter + AdamW moments then shard
    # 256/512-ways (a model-only sharded 2B-row table's dense grad would blow
    # HBM); falls back to model-only for tiny test tables
    (r"table$", [("all", None), ("model", None), ()]),
    (r"wide$", [("all",), ("model",), ()]),  # row-sharded linear weights
    (r"pos_embed$", [()]),
    (r"(wq|wk|wv)$", [(None, "model")]),
    (r"wo$", [("model", None)]),
    (r"\.w$", [("data", "model"), (None, "model"), ()]),  # MLP / cross weights
    (r"\.b$", [()]),
    (r"(scale|bias)$", [()]),
]

RULES_BY_FAMILY = {"lm": LM_RULES, "gnn": GNN_RULES, "recsys": RECSYS_RULES}


def _resolve(token, axes: Axes):
    if token == "model":
        return axes.model
    if token == "data":
        return axes.data if len(axes.data) > 1 else axes.data[0]
    if token == "all":
        return axes.data + (axes.model,)
    return None


def _axis_size(token, axes: Axes, mesh_shape: dict) -> int:
    if token == "model":
        return mesh_shape[axes.model]
    if token == "data":
        n = 1
        for a in axes.data:
            n *= mesh_shape[a]
        return n
    if token == "all":
        n = 1
        for a in axes.data + (axes.model,):
            n *= mesh_shape[a]
        return n
    return 1


def _fits(tail: tuple, shape: tuple, axes: Axes, mesh_shape: dict) -> bool:
    off = len(shape) - len(tail)
    return all(
        shape[off + i] % _axis_size(t, axes, mesh_shape) == 0 for i, t in enumerate(tail)
    )


# Leaves smaller than this keep TP ('model') sharding but drop the
# FSDP/ZeRO 'data' dim: for small weights the all-gather/partial-reduce
# traffic SPMD emits outweighs the memory saved (measured: 62 GB/step of
# all-reduce on gemma3 train_4k before this guard). Large weights (yi-34b
# 7168x7168 = 205 MB) keep both axes — there ZeRO is what makes the
# optimizer state fit at all.
FSDP_MIN_BYTES = 32 * 1024 * 1024


def spec_for_path(
    path: str, shape: tuple, rules, axes: Axes, mesh_shape: dict, nbytes: int | None = None
) -> P:
    ndim = len(shape)
    for pat, candidates in rules:
        if not re.search(pat, path):
            continue
        usable = [c for c in candidates if len(c) <= ndim]
        if not usable:
            return P()
        tail = next((c for c in usable if _fits(c, shape, axes, mesh_shape)), None)
        if tail is None:  # best candidate, degrading non-divisible dims
            tail = usable[0]
            off = ndim - len(tail)
            tail = tuple(
                t if shape[off + i] % _axis_size(t, axes, mesh_shape) == 0 else None
                for i, t in enumerate(tail)
            )
        if nbytes is not None and nbytes < FSDP_MIN_BYTES:
            tail = tuple(None if t == "data" else t for t in tail)
        return _right_align(tuple(_resolve(t, axes) for t in tail), ndim)
    return P()  # default: replicated


def normalize_path(keystr_path: str) -> str:
    """``['blocks'][0]['attn']['wq']`` -> ``.blocks.0.attn.wq``."""
    return keystr_path.replace("'", "").replace("[", ".").replace("]", "")


def param_specs(params, family: str, mesh: Mesh):
    """PartitionSpec pytree mirroring ``params`` (works on abstract trees)."""
    axes = mesh_axes(mesh)
    rules = RULES_BY_FAMILY[family]
    mesh_shape = dict(mesh.shape)

    def one(path, leaf):
        import numpy as np

        key = normalize_path(jax.tree_util.keystr(path))
        nbytes = int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        return spec_for_path(key, tuple(leaf.shape), rules, axes, mesh_shape, nbytes)

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, family: str, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params, family, mesh)
    )


# --------------------------------------------------------------------------
# batch / cache / state shardings
# --------------------------------------------------------------------------


def batch_dim_sharding(mesh: Mesh, extra_dims: int = 1) -> NamedSharding:
    """Leading dim over all data axes, rest replicated: [B, ...]."""
    axes = mesh_axes(mesh)
    return NamedSharding(mesh, P(_resolve("data", axes), *((None,) * extra_dims)))


def fully_sharded_dim(mesh: Mesh, extra_dims: int = 0) -> NamedSharding:
    """Leading dim over ALL mesh axes (GNN edges, retrieval candidates)."""
    axes = mesh_axes(mesh)
    flat = axes.data + (axes.model,)
    return NamedSharding(mesh, P(flat, *((None,) * extra_dims)))


def batch_shardings(batch_specs: dict, mesh: Mesh, *, fully_shard: bool = False):
    """Shard every batch array on its leading dim (data axes, or all axes)."""

    def one(leaf):
        fn = fully_sharded_dim if fully_shard else batch_dim_sharding
        return fn(mesh, max(len(leaf.shape) - 1, 0))

    return jax.tree.map(one, batch_specs)


def cache_shardings(cache_specs, mesh: Mesh):
    """KV cache: k/v [(R,) B, T, K, hd] -> batch over data, seq over model.

    Per-dim divisibility fallback (batch=1 long-context decode cannot shard
    its batch dim; 1k-slot ring buffers shard T only when it divides).
    """
    axes = mesh_axes(mesh)
    mesh_shape = dict(mesh.shape)

    def one(path, leaf):
        nd = len(leaf.shape)
        key = jax.tree_util.keystr(path)
        tail_tok = ("data", "model") if key.endswith("['pos']") else ("data", "model", None, None)
        off = nd - len(tail_tok)
        tok = tuple(
            t if t is None or leaf.shape[off + i] % _axis_size(t, axes, mesh_shape) == 0 else None
            for i, t in enumerate(tail_tok)
        )
        tail = tuple(_resolve(t, axes) for t in tok)
        return NamedSharding(mesh, _right_align(tail, nd))

    return jax.tree_util.tree_map_with_path(one, cache_specs)


def train_state_shardings(abstract_state, family: str, mesh: Mesh):
    """TrainState shardings: opt moments mirror the param specs (ZeRO)."""
    from repro.train.trainer import TrainState
    from repro.train.optim import AdamWState

    p_shard = param_shardings(abstract_state.params, family, mesh)
    return TrainState(
        params=p_shard,
        opt=AdamWState(
            m=jax.tree.map(lambda s: s, p_shard),
            v=jax.tree.map(lambda s: s, p_shard),
            count=NamedSharding(mesh, P()),
        ),
        step=NamedSharding(mesh, P()),
    )


def constraint(x, mesh: Optional[Mesh], *spec):
    """with_sharding_constraint that no-ops without a mesh (CPU tests)."""
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# --------------------------------------------------------------------------
# ambient-mesh activation constraints (model code calls these; they no-op
# outside a `jax.set_mesh(...)` scope, so CPU unit tests are unaffected)
# --------------------------------------------------------------------------


def _get_abstract_mesh():
    """Version-tolerant ambient-mesh lookup.

    ``jax.sharding.get_abstract_mesh`` only exists in newer JAX releases; on
    older ones the ambient mesh set by ``with mesh:`` lives in
    ``jax._src.mesh.thread_resources``. When the new API exists but reports
    no mesh (e.g. the scope was entered via the legacy ``with mesh:``
    context rather than ``jax.set_mesh``), fall through to the
    thread-resources lookup rather than trusting the empty answer. Returns
    ``None`` when no mesh scope is active (or the private fallback is
    unavailable), so callers degrade to the documented no-op.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        m = get()
        if m is not None and not m.empty:
            return m
    try:
        from jax._src import mesh as _mesh_lib

        m = _mesh_lib.thread_resources.env.physical_mesh
    except (ImportError, AttributeError):
        return None
    if m is None or m.empty:
        return None
    return m


def current_axes() -> Optional[Axes]:
    m = _get_abstract_mesh()
    if m is None or m.empty or not m.axis_names:
        return None
    data = tuple(n for n in m.axis_names if n != "model")
    model = "model" if "model" in m.axis_names else None
    if model is None:
        return None
    return Axes(data=data, model=model)


def ambient_axis_size(token: str) -> int:
    """Size of a logical axis group under the ambient mesh (1 if none)."""
    m = _get_abstract_mesh()
    axes = current_axes()
    if axes is None:
        return 1
    shape = dict(m.shape)
    names = {"model": (axes.model,), "data": axes.data, "all": axes.data + (axes.model,)}[token]
    n = 1
    for a in names:
        n *= shape[a]
    return n


def act(x, *logical):
    """Constrain an activation by logical dim tokens.

    Tokens: ``"data"`` (all data axes), ``"model"``, ``"all"`` (every axis,
    flattened — GNN edge/node arrays), or None. No-op without an ambient
    mesh. Dims not divisible by their axis-group size are silently dropped
    (padded/uneven constraints trigger SPMD's involuntary-full-remat path).
    """
    axes = current_axes()
    if axes is None:
        return x

    def tok(t, dim):
        if t is None:
            return None
        if t == "all" and dim % max(ambient_axis_size("all"), 1) != 0:
            t = "data"  # degrade: 1M candidates shard 16-way, not 256-way
        if dim % max(ambient_axis_size(t), 1) != 0:
            return None
        if t == "data":
            return axes.data if len(axes.data) > 1 else axes.data[0]
        if t == "model":
            return axes.model
        if t == "all":
            return axes.data + (axes.model,)
        return None

    spec = tuple(tok(t, d) for t, d in zip(logical, x.shape))
    return jax.lax.with_sharding_constraint(x, P(*spec))
