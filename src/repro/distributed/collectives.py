"""Distributed-optimization primitives: gradient compression + overlap helpers.

``int8`` block-quantized gradient compression with error feedback: at 1000+
node scale the data-parallel all-reduce of f32 gradients is the dominant
inter-pod collective; quantizing to int8 cuts those bytes 4x. Error feedback
(residual carried into the next step) keeps SGD/Adam convergence — the
standard result from the gradient-compression literature.

Two integration modes:
  * **transform mode** (`make_error_feedback_transform`): quantize->dequantize
    inside the jitted step; GSPMD still moves f32 but the *information*
    content matches what a wire-compressed implementation computes, so
    convergence effects are testable on CPU.
  * **wire mode** (`compressed_psum`): inside ``shard_map``, psum the int8
    payload + per-block scales explicitly — this is the lowering that
    actually saves inter-pod bytes, used by the explicit-collectives trainer
    variant and counted in the §Roofline collective term.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    block: int = 256  # scale granularity (elements)
    enabled: bool = True


def _pad_len(n: int, block: int) -> int:
    return (n + block - 1) // block * block


def quantize_int8(x: jax.Array, block: int = 256) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization. Returns (q[i8], scales[f32])."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    padded = jnp.zeros((_pad_len(n, block),), jnp.float32).at[:n].set(flat)
    blocks = padded.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scales: jax.Array, shape, dtype=jnp.float32) -> jax.Array:
    import numpy as np

    n = int(np.prod(shape))
    deq = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)[:n]
    return deq.reshape(shape).astype(dtype)


def compress_decompress(x: jax.Array, block: int = 256) -> jax.Array:
    q, s = quantize_int8(x, block)
    return dequantize_int8(q, s, x.shape, x.dtype)


def make_error_feedback_transform(cfg: CompressionConfig = CompressionConfig()):
    """Stateful (functional) error-feedback compressor for grad pytrees.

    Usage::

        compress, init_residual = make_error_feedback_transform()
        residual = init_residual(params)
        grads, residual = compress(grads, residual)
    """

    def init_residual(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(grads, residual):
        def one(g, r):
            if not cfg.enabled:
                return g, r
            corrected = g.astype(jnp.float32) + r
            sent = compress_decompress(corrected, cfg.block)
            return sent.astype(g.dtype), corrected - sent

        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = treedef.flatten_up_to(residual)
        pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return (
            jax.tree.unflatten(treedef, [p[0] for p in pairs]),
            jax.tree.unflatten(treedef, [p[1] for p in pairs]),
        )

    return compress, init_residual


def compressed_psum(x: jax.Array, axis_name: str, block: int = 256) -> jax.Array:
    """int8-wire psum (shard_map context): quantize -> psum int32 -> rescale.

    The payload crossing the interconnect is int8-worth of mantissa (summed in
    i32 to avoid overflow across shards) + one f32 scale per block: ~4x fewer
    bytes than an f32 psum for large tensors.
    """
    q, s = quantize_int8(x, block)
    # shared scale: max over shards so summed int8 values stay comparable
    s_max = jax.lax.pmax(s, axis_name)
    requant = jnp.round(
        q.astype(jnp.float32) * (s / jnp.maximum(s_max, 1e-12))[:, None]
    ).astype(jnp.int32)
    total = jax.lax.psum(requant, axis_name)
    return dequantize_int8(total, s_max, x.shape, x.dtype)


def reduce_scatter_grads(grads, axis_name: str):
    """ZeRO-style grad sync: reduce-scatter instead of all-reduce.

    Each shard keeps only its slice of the summed gradient (the slice its
    optimizer partition owns); 2x fewer bytes than all-reduce and it overlaps
    with the backward pass under XLA latency-hiding scheduling.
    """

    def one(g):
        return jax.lax.psum_scatter(g, axis_name, scatter_dimension=0, tiled=True)

    return jax.tree.map(one, grads)
