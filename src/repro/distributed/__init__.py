"""Distribution layer: sharding rules, collectives, elastic/fault tolerance."""
from repro.distributed.collectives import (  # noqa: F401
    CompressionConfig,
    compress_decompress,
    compressed_psum,
    dequantize_int8,
    make_error_feedback_transform,
    quantize_int8,
    reduce_scatter_grads,
)
from repro.distributed.elastic import (  # noqa: F401
    MeshTopology,
    best_effort_mesh,
    data_parallel_liveness,
    reshard_state,
)
from repro.distributed.sharding import (  # noqa: F401
    batch_dim_sharding,
    batch_shardings,
    cache_shardings,
    constraint,
    fully_sharded_dim,
    mesh_axes,
    param_shardings,
    param_specs,
    train_state_shardings,
)
