"""Elastic scaling + straggler/failure handling (1000+-node posture).

The recovery model:
  * **Training**: state lives in sharded checkpoints (repro.checkpoint). On
    node failure the job restarts on whatever slice survives;
    ``reshard_state`` device_puts the restored pytree onto the *new* mesh's
    shardings — shard counts need not match (the checkpoint stores full
    logical arrays per leaf, host-side; resharding is a placement decision).
  * **Serving**: stateless — each chip owns a doc shard of the impact index;
    losing a pod shrinks the corpus until re-shard, never corrupts results.
    The SAAT rho budget doubles as straggler mitigation: work per chip is
    fixed by construction (repro.serving).
  * **Liveness**: `data_parallel_liveness` is the psum-of-ones barrier used
    to detect and exclude failed data-parallel ranks between steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.distributed import sharding as shlib


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """Declarative mesh request; ``build`` degrades to the devices present."""

    pods: int
    data: int
    model: int

    def shape(self, multi_pod: bool) -> tuple:
        return (self.pods, self.data, self.model) if multi_pod else (self.data, self.model)

    def axis_names(self, multi_pod: bool) -> tuple:
        return ("pod", "data", "model") if multi_pod else ("data", "model")


def best_effort_mesh(topo: MeshTopology, *, multi_pod: bool = False) -> Mesh:
    """Build the requested mesh, shrinking the data axis if devices are lost.

    Elastic policy: the model axis is load-bearing (params are TP-sharded at
    a fixed degree) so it is preserved; lost capacity comes out of the
    data-parallel axes (smaller global batch, same model math).
    """
    n = len(jax.devices())
    want = topo.shape(multi_pod)
    need = 1
    for s in want:
        need *= s
    if n >= need:
        return jax.make_mesh(want, topo.axis_names(multi_pod))
    # shrink data axis to the largest degree that fits
    model = topo.model
    pods = topo.pods if multi_pod else 1
    data = max(1, n // (model * pods))
    shape = (pods, data, model) if multi_pod else (data, model)
    return jax.make_mesh(shape, topo.axis_names(multi_pod))


def reshard_state(state: Any, family: str, new_mesh: Mesh):
    """Place a (restored, host-resident) TrainState onto a new mesh."""
    from repro.distributed.sharding import train_state_shardings

    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
    )
    sh = train_state_shardings(abstract, family, new_mesh)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh)


def data_parallel_liveness(axis_name: str = "data") -> jax.Array:
    """Inside shard_map: count live data-parallel ranks (barrier + census)."""
    return jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
