"""Ranking + regularization losses for the learned-sparse encoder path.

The paper's models are trained with (variants of) a pairwise loss between
relevant and non-relevant passages (DeepImpact), plus distillation
(SPLADEv2's MarginMSE) and the SPLADE FLOPS regularizer, which is the
published "efficiency in the training objective" mechanism the paper's
conclusion calls for — we implement all three so the trainable encoder
(deliverable b) is faithful.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_hinge(pos_scores: jax.Array, neg_scores: jax.Array, margin: float = 1.0):
    """max(0, margin - (s+ - s-)), mean over the batch."""
    return jnp.maximum(0.0, margin - (pos_scores - neg_scores)).mean()


def pairwise_softmax(pos_scores: jax.Array, neg_scores: jax.Array):
    """Contrastive log-softmax over (pos, neg) pairs (DeepImpact-style)."""
    logits = jnp.stack([pos_scores, neg_scores], axis=-1)
    return -jax.nn.log_softmax(logits, axis=-1)[..., 0].mean()


def margin_mse(
    pos_scores: jax.Array,
    neg_scores: jax.Array,
    teacher_pos: jax.Array,
    teacher_neg: jax.Array,
):
    """SPLADEv2 distillation: match the teacher's score *margin*."""
    return jnp.mean(((pos_scores - neg_scores) - (teacher_pos - teacher_neg)) ** 2)


def flops_regularizer(sparse_reps: jax.Array):
    """SPLADE FLOPS loss: sum_t (mean_d |w_{d,t}|)^2.

    Penalizes the *expected* number of floating point ops a query term incurs
    — i.e. exactly the posting-density term that drives the paper's latency
    blow-up. ``sparse_reps: [B, V]`` non-negative term weights.
    """
    mean_act = jnp.abs(sparse_reps).mean(axis=0)  # [V]
    return jnp.sum(mean_act * mean_act)


def l1_regularizer(sparse_reps: jax.Array):
    return jnp.abs(sparse_reps).sum(axis=-1).mean()
