"""Training substrate: from-scratch AdamW, ranking losses, generic trainer."""
from repro.train.losses import (  # noqa: F401
    flops_regularizer,
    l1_regularizer,
    margin_mse,
    pairwise_hinge,
    pairwise_softmax,
)
from repro.train.optim import (  # noqa: F401
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    schedule_lr,
)
from repro.train.trainer import (  # noqa: F401
    TrainState,
    abstract_train_state,
    init_train_state,
    make_train_step,
    train_loop,
)
