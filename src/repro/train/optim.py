"""AdamW + LR schedules, built from scratch (no optax dependency).

Optimizer state is a plain pytree mirroring the params, so the distributed
layer can shard ``m``/``v`` with the *same* PartitionSpecs as the weights —
that is the ZeRO/FSDP property that lets a 34B model's optimizer state fit
256 chips (DESIGN.md §5). Moments are always f32 regardless of param dtype
(bf16-safe mixed precision).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    schedule: str = "warmup_cosine"  # constant | warmup_cosine | warmup_linear
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    m: Any  # pytree like params, f32
    v: Any  # pytree like params, f32
    count: jax.Array  # i32[]


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(m=zeros, v=jax.tree.map(jnp.copy, zeros), count=jnp.zeros((), jnp.int32))


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        frac = jnp.float32(1.0)
    elif cfg.schedule == "warmup_linear":
        t = jnp.clip((s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        frac = 1.0 - (1.0 - cfg.min_lr_frac) * t
    else:  # warmup_cosine
        t = jnp.clip((s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads, state: AdamWState, params, cfg: AdamWConfig
) -> tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics). Decoupled weight decay."""
    if cfg.grad_clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    else:
        gnorm = global_norm(grads)
    count = state.count + 1
    lr = schedule_lr(cfg, count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        step = (m_new / c1) / (jnp.sqrt(v_new / c2) + cfg.eps)
        p_new = p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new, v_new

    # flatten/unflatten (rather than a tuple-leaf tree_map) so params pytrees
    # may themselves contain tuples without confusing is_leaf
    p_flat, treedef = jax.tree.flatten(params)
    g_flat = treedef.flatten_up_to(grads)
    m_flat = treedef.flatten_up_to(state.m)
    v_flat = treedef.flatten_up_to(state.v)
    triples = [upd(p, g, m, v) for p, g, m, v in zip(p_flat, g_flat, m_flat, v_flat)]
    new_params = jax.tree.unflatten(treedef, [t[0] for t in triples])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in triples])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in triples])
    return new_params, AdamWState(new_m, new_v, count), {"lr": lr, "grad_norm": gnorm}
