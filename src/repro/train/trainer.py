"""Generic trainer: TrainState + train-step factory.

Works for every arch family (the loss_fn closure decides the model). The
returned step is a pure jittable function — the launcher binds it to a mesh
with in/out shardings, so the same code runs the CPU smoke tests and the
512-chip dry-run.

Features:
  * gradient accumulation via ``lax.scan`` over microbatches (static count);
  * mixed precision: params may be bf16, moments are f32 (optim.py);
  * optional gradient transform hook (e.g. int8 compression with error
    feedback from ``repro.distributed.collectives``);
  * loss scaling for bf16 stability (static, unscaled before the update).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.train.optim import AdamWConfig, AdamWState, adamw_init, adamw_update


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["params", "opt", "step"],
    meta_fields=[],
)
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: jax.Array  # i32[]


def init_train_state(params) -> TrainState:
    return TrainState(params=params, opt=adamw_init(params), step=jnp.zeros((), jnp.int32))


def abstract_train_state(abstract_params) -> TrainState:
    """ShapeDtypeStruct TrainState from abstract params (dry-run input)."""
    return jax.eval_shape(init_train_state, abstract_params)


def _split_microbatches(batch, n: int):
    def split(x):
        assert x.shape[0] % n == 0, (x.shape, n)
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(
    loss_fn: Callable[[Any, Any], tuple[jax.Array, dict]],
    opt_cfg: AdamWConfig,
    *,
    grad_accum: int = 1,
    grad_transform: Optional[Callable[[Any], Any]] = None,
):
    """Returns ``step(state, batch) -> (state, metrics)``.

    ``loss_fn(params, batch) -> (scalar_loss, metrics_dict)``.
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if grad_accum > 1:
            micro = _split_microbatches(batch, grad_accum)

            def body(carry, mb):
                acc, loss_acc = carry
                loss, metrics, grads = grads_of(state.params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / grad_accum, acc, grads
                )
                return (acc, loss_acc + loss / grad_accum), metrics

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (grads, loss), metrics = jax.lax.scan(body, (zero, jnp.float32(0.0)), micro)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            loss, metrics, grads = grads_of(state.params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, opt_metrics = adamw_update(grads, state.opt, state.params, opt_cfg)
        new_state = TrainState(params=new_params, opt=new_opt, step=state.step + 1)
        return new_state, {"loss": loss, **metrics, **opt_metrics}

    return step


def train_loop(
    step_fn,
    state: TrainState,
    batches,
    *,
    hooks: Optional[list[Callable[[int, TrainState, dict], None]]] = None,
    jit: bool = True,
):
    """Simple host-side loop (examples + integration tests).

    ``batches`` is any iterable of pytrees; hooks receive (step, state,
    metrics) — the checkpoint manager's ``maybe_save`` slots in here.
    """
    fn = jax.jit(step_fn) if jit else step_fn
    history = []
    for i, batch in enumerate(batches):
        state, metrics = fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items() if jnp.ndim(v) == 0}
        history.append(metrics)
        for h in hooks or ():
            h(i, state, metrics)
    return state, history
