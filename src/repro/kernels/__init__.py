"""Pallas TPU kernels for the paper's compute hot spots.

  impact_scatter  SAAT accumulation: one-hot-matmul scatter-add (MXU)
  impact_scatter_topk  fused SAAT scatter + per-block top-k (accumulator
                  stays in VMEM; only [B, n_blocks * k] candidates hit HBM)
  sparse_score    DAAT/exhaustive: match-and-accumulate block scoring
  block_prune     DAAT: fused block upper-bound matmul + theta threshold
  block_topk      tiled two-stage top-k over huge accumulator/candidate sets

Each subpackage ships ``kernel.py`` (pl.pallas_call + BlockSpec VMEM tiling),
``ops.py`` (jit'd wrapper, padding, interpret-mode selection) and ``ref.py``
(pure-jnp oracle used by the allclose sweep tests).
"""
from repro.kernels.block_prune import block_prune, block_prune_batched  # noqa: F401
from repro.kernels.block_topk import block_topk, block_topk_batched  # noqa: F401
from repro.kernels.impact_scatter import impact_scatter, impact_scatter_batched  # noqa: F401
from repro.kernels.impact_scatter_topk import (  # noqa: F401
    impact_scatter_topk,
    impact_scatter_topk_batched,
)
from repro.kernels.sparse_score import sparse_score, sparse_score_batched  # noqa: F401
