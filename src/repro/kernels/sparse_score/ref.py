"""Pure-jnp oracle for the match-and-accumulate document scorer."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sparse_score_ref(
    doc_terms: jax.Array,  # i32[N, Tmax] (pad slot = any id with weight 0)
    doc_weights: jax.Array,  # f32[N, Tmax]
    q_terms: jax.Array,  # i32[Lq]
    q_weights: jax.Array,  # f32[Lq] (0 for padding slots)
) -> jax.Array:
    """score_d = sum_j w_dj * sum_i [term_dj == q_i] * qw_i. f32[N]."""
    eq = doc_terms[:, :, None] == q_terms[None, None, :]
    qv = jnp.sum(jnp.where(eq, q_weights[None, None, :].astype(jnp.float32), 0.0), axis=-1)
    return jnp.sum(qv * doc_weights.astype(jnp.float32), axis=-1)


def sparse_score_batched_ref(
    doc_terms: jax.Array,  # i32[B, N, Tmax]
    doc_weights: jax.Array,  # f32[B, N, Tmax]
    q_terms: jax.Array,  # i32[B, Lq]
    q_weights: jax.Array,  # f32[B, Lq] (0 for padding slots)
) -> jax.Array:
    """Batched oracle: each query scores its own doc rows. f32[B, N]."""
    eq = doc_terms[..., None] == q_terms[:, None, None, :]
    qv = jnp.sum(jnp.where(eq, q_weights[:, None, None, :].astype(jnp.float32), 0.0), axis=-1)
    return jnp.sum(qv * doc_weights.astype(jnp.float32), axis=-1)
