"""jit'd wrapper around the match-and-accumulate scorer kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_default, pad_axis
from repro.kernels.sparse_score.kernel import (
    sparse_score_batched_kernel,
    sparse_score_kernel,
)


@partial(jax.jit, static_argnames=("block_d", "interpret"))
def sparse_score(
    doc_terms: jax.Array,
    doc_weights: jax.Array,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    block_d: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Scores for N docs vs one query via the Pallas kernel. f32[N].

    Pads N to the doc-block multiple and Lq to the lane width; padded query
    slots must carry weight 0 (ops enforces it), padded doc rows score 0 and
    are sliced off.
    """
    if interpret is None:
        interpret = interpret_default()
    n = doc_terms.shape[0]
    dt = pad_axis(doc_terms.astype(jnp.int32), 0, block_d, fill=-1)
    dw = pad_axis(doc_weights.astype(jnp.float32), 0, block_d, fill=0.0)
    qt = pad_axis(q_terms.astype(jnp.int32), 0, 128, fill=-2)
    qw = pad_axis(q_weights.astype(jnp.float32), 0, 128, fill=0.0)
    qw = jnp.where(qt == -2, 0.0, qw)
    scores = sparse_score_kernel(dt, dw, qt, qw, block_d=block_d, interpret=interpret)
    return scores[:n]


@partial(jax.jit, static_argnames=("block_d", "interpret"))
def sparse_score_batched(
    doc_terms: jax.Array,
    doc_weights: jax.Array,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    block_d: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-query scores for ``doc_terms [B, N, Tmax]`` vs queries ``[B, Lq]``.

    One (query, doc-block)-gridded launch — the DAAT phase-2 chunk scorer.
    Padding mirrors the single-query wrapper: doc rows to the block multiple
    with sentinel term -1, query slots to the lane width with sentinel -2 and
    weight forced to 0. f32[B, N].
    """
    if interpret is None:
        interpret = interpret_default()
    n = doc_terms.shape[1]
    dt = pad_axis(doc_terms.astype(jnp.int32), 1, block_d, fill=-1)
    dw = pad_axis(doc_weights.astype(jnp.float32), 1, block_d, fill=0.0)
    qt = pad_axis(q_terms.astype(jnp.int32), 1, 128, fill=-2)
    qw = pad_axis(q_weights.astype(jnp.float32), 1, 128, fill=0.0)
    qw = jnp.where(qt == -2, 0.0, qw)
    scores = sparse_score_batched_kernel(dt, dw, qt, qw, block_d=block_d, interpret=interpret)
    return scores[:, :n]
