"""jit'd wrapper around the match-and-accumulate scorer kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.kernel_contracts import KernelContract, ShapeCase
from repro.kernels.common import interpret_default, pad_axis
from repro.kernels.sparse_score.kernel import (
    sparse_score_batched_kernel,
    sparse_score_kernel,
)


@partial(jax.jit, static_argnames=("block_d", "interpret"))
def sparse_score(
    doc_terms: jax.Array,
    doc_weights: jax.Array,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    block_d: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Scores for N docs vs one query via the Pallas kernel. f32[N].

    Pads N to the doc-block multiple and Lq to the lane width; padded query
    slots must carry weight 0 (ops enforces it), padded doc rows score 0 and
    are sliced off.
    """
    if interpret is None:
        interpret = interpret_default()
    n = doc_terms.shape[0]
    dt = pad_axis(doc_terms.astype(jnp.int32), 0, block_d, fill=-1)
    dw = pad_axis(doc_weights.astype(jnp.float32), 0, block_d, fill=0.0)
    qt = pad_axis(q_terms.astype(jnp.int32), 0, 128, fill=-2)
    qw = pad_axis(q_weights.astype(jnp.float32), 0, 128, fill=0.0)
    qw = jnp.where(qt == -2, 0.0, qw)
    scores = sparse_score_kernel(dt, dw, qt, qw, block_d=block_d, interpret=interpret)
    return scores[:n]


@partial(jax.jit, static_argnames=("block_d", "interpret"))
def sparse_score_batched(
    doc_terms: jax.Array,
    doc_weights: jax.Array,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    block_d: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-query scores for ``doc_terms [B, N, Tmax]`` vs queries ``[B, Lq]``.

    One (query, doc-block)-gridded launch — the DAAT phase-2 chunk scorer.
    Padding mirrors the single-query wrapper: doc rows to the block multiple
    with sentinel term -1, query slots to the lane width with sentinel -2 and
    weight forced to 0. f32[B, N].
    """
    if interpret is None:
        interpret = interpret_default()
    n = doc_terms.shape[1]
    dt = pad_axis(doc_terms.astype(jnp.int32), 1, block_d, fill=-1)
    dw = pad_axis(doc_weights.astype(jnp.float32), 1, block_d, fill=0.0)
    qt = pad_axis(q_terms.astype(jnp.int32), 1, 128, fill=-2)
    qw = pad_axis(q_weights.astype(jnp.float32), 1, 128, fill=0.0)
    qw = jnp.where(qt == -2, 0.0, qw)
    scores = sparse_score_batched_kernel(dt, dw, qt, qw, block_d=block_d, interpret=interpret)
    return scores[:, :n]


def _contract_call(dims):
    """Trace target for the static checker: abstract inputs, sweep tiling."""
    sds = jax.ShapeDtypeStruct
    n, tmax, lq = dims["n"], dims["tmax"], dims["lq"]
    kw = dict(block_d=dims["block_d"], interpret=True)
    if "batch" in dims:
        b = dims["batch"]
        return partial(sparse_score_batched, **kw), (
            sds((b, n, tmax), jnp.int32), sds((b, n, tmax), jnp.float32),
            sds((b, lq), jnp.int32), sds((b, lq), jnp.float32))
    return partial(sparse_score, **kw), (
        sds((n, tmax), jnp.int32), sds((n, tmax), jnp.float32),
        sds((lq,), jnp.int32), sds((lq,), jnp.float32))


# Single source of truth for the sweep shapes in tests/test_kernels.py and
# the checker's trace grid: doc counts ragged vs the block and sub-lane Lq.
CONTRACT = KernelContract(
    name="sparse_score",
    description="match-and-accumulate sparse scorer (DAAT chunk scoring)",
    make_call=_contract_call,
    shape_grid=(
        ShapeCase("small", dict(n=100, tmax=16, lq=8, block_d=128)),
        ShapeCase("aligned", dict(n=512, tmax=64, lq=32, block_d=128)),
        ShapeCase("ragged", dict(n=130, tmax=7, lq=3, block_d=128)),
        ShapeCase("b1", dict(batch=1, n=100, tmax=16, lq=8, block_d=128)),
        ShapeCase("b3_ragged", dict(batch=3, n=130, tmax=7, lq=3, block_d=128)),
        ShapeCase("b4_aligned", dict(batch=4, n=512, tmax=64, lq=32, block_d=128)),
    ),
)
