"""Pallas TPU kernel: match-and-accumulate sparse document scoring.

The DAAT phase-2 / exhaustive hot loop is, per document d:

    score_d = sum_j w_dj * qweight(term_dj)

On CPU this is a gather through a query hash table. The TPU-native version
avoids the vocab-sized gather entirely: the (tiny) query lives in VMEM as
``(q_terms[Lq], q_weights[Lq])`` and term matching becomes an equality
compare + contraction over Lq:

    qv[BD, Tmax]   = (doc_terms[BD, Tmax, 1] == q_terms[Lq]) @ q_weights
    score[BD]      = sum_j qv * w

Both contractions are MXU/VPU friendly; the working set per grid step is the
``(BD, Tmax)`` doc tile + the ``(BD*Tmax, Lq)`` one-hot — BlockSpec sizes are
chosen so this fits VMEM (default 128x64x32 fp32 = 1 MiB). Vocabulary size
never appears in the kernel: the same code serves the 27k-term SPLADE index
and the 3.9M-term BM25-T5 index.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel_batched(dt_ref, dw_ref, qt_ref, qw_ref, out_ref):
    terms = dt_ref[0]  # i32[BD, Tmax] — one (query, doc-block) cell
    w = dw_ref[0].astype(jnp.float32)
    qt = qt_ref[0, 0, :]  # i32[Lq]
    qw = qw_ref[0, 0, :].astype(jnp.float32)
    bd, tmax = terms.shape
    onehot = (terms.reshape(bd * tmax, 1) == qt[None, :]).astype(jnp.float32)
    qv = jnp.dot(onehot, qw[:, None], preferred_element_type=jnp.float32)
    scores = jnp.sum(qv.reshape(bd, tmax) * w, axis=-1, keepdims=True)
    out_ref[0] = scores


def sparse_score_batched_kernel(
    doc_terms: jax.Array,  # i32[B, N, Tmax]
    doc_weights: jax.Array,  # f32[B, N, Tmax]
    q_terms: jax.Array,  # i32[B, Lq]
    q_weights: jax.Array,  # f32[B, Lq]
    *,
    block_d: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Per-query document scores for a whole batch: grid over (query, block).

    Each query scores its OWN doc tile (the DAAT phase-2 chunks differ per
    query); the tiny (q_terms, q_weights) rows ride along per grid cell, so
    the batch is one launch — the scoring analogue of
    ``impact_scatter_batched`` / ``block_topk_batched``. Returns f32[B, N].
    """
    b, n, tmax = doc_terms.shape
    assert n % block_d == 0, (n, block_d)
    lq = q_terms.shape[-1]
    grid = (b, n // block_d)
    out = pl.pallas_call(
        _score_kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_d, tmax), lambda q, i: (q, i, 0)),
            pl.BlockSpec((1, block_d, tmax), lambda q, i: (q, i, 0)),
            pl.BlockSpec((1, 1, lq), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, 1, lq), lambda q, i: (q, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_d, 1), lambda q, i: (q, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, 1), jnp.float32),
        interpret=interpret,
    )(doc_terms, doc_weights, q_terms.reshape(b, 1, lq), q_weights.reshape(b, 1, lq))
    return out[:, :, 0]


def _score_kernel(dt_ref, dw_ref, qt_ref, qw_ref, out_ref):
    terms = dt_ref[...]  # i32[BD, Tmax]
    w = dw_ref[...].astype(jnp.float32)  # [BD, Tmax]
    qt = qt_ref[0, :]  # i32[Lq]
    qw = qw_ref[0, :].astype(jnp.float32)  # [Lq]
    bd, tmax = terms.shape
    lq = qt.shape[0]
    onehot = (terms.reshape(bd * tmax, 1) == qt[None, :]).astype(jnp.float32)  # [BD*Tmax, Lq]
    qv = jnp.dot(onehot, qw[:, None], preferred_element_type=jnp.float32)  # [BD*Tmax, 1]
    scores = jnp.sum(qv.reshape(bd, tmax) * w, axis=-1, keepdims=True)  # [BD, 1]
    out_ref[...] = scores


def sparse_score_kernel(
    doc_terms: jax.Array,
    doc_weights: jax.Array,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    block_d: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Scores for N docs against one query. N % block_d == 0. f32[N]."""
    n, tmax = doc_terms.shape
    assert n % block_d == 0, (n, block_d)
    lq = q_terms.shape[0]
    grid = (n // block_d,)
    out = pl.pallas_call(
        functools.partial(_score_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_d, tmax), lambda i: (i, 0)),
            pl.BlockSpec((block_d, tmax), lambda i: (i, 0)),
            pl.BlockSpec((1, lq), lambda i: (0, 0)),
            pl.BlockSpec((1, lq), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_d, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(doc_terms, doc_weights, q_terms.reshape(1, lq), q_weights.reshape(1, lq))
    return out[:, 0]
