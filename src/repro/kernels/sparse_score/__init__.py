from repro.kernels.sparse_score.ops import sparse_score  # noqa: F401
