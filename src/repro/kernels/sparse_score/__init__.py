from repro.kernels.sparse_score.ops import sparse_score, sparse_score_batched  # noqa: F401
