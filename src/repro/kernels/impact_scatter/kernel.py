"""Pallas TPU kernel: SAAT accumulator scatter-add as one-hot matmul.

TPUs have no fast random scatter; the idiomatic translation is a *one-hot
matmul*: for a VMEM tile of postings ``(doc_ids[TP], contribs[TP])`` and an
accumulator block of ``BD`` documents, the partial update is

    acc[BD] += onehot(doc_ids - block_start)[BD, TP] @ contribs[TP, 1]

which runs on the MXU. The grid is (doc_blocks x posting_tiles); the
accumulator block stays resident in VMEM across the inner posting-tile loop
(output revisiting), so HBM traffic is one read of the postings plus one
write of the accumulator.

Skip optimization (the SAAT analogue of postings being doc-sorted inside a
segment): when the caller pre-sorts postings by doc id it also passes per-tile
[min_doc, max_doc+1) ranges; tiles that do not overlap the current accumulator
block skip the matmul entirely via ``pl.when``. For contribution-ordered
(unsorted) postings the ranges degenerate to [0, n_docs) and every (block,
tile) cell does work — correct, just slower, mirroring CPU JASS where the
accumulator table absorbs the random access.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scatter_kernel(ranges_ref, docs_ref, contribs_ref, acc_ref, *, block_d: int):
    d = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    block_start = d * block_d
    tile_lo = ranges_ref[0, 0]
    tile_hi = ranges_ref[0, 1]
    overlaps = (tile_lo < block_start + block_d) & (tile_hi > block_start)

    @pl.when(overlaps)
    def _accumulate():
        docs = docs_ref[0, :]  # i32[TP]
        c = contribs_ref[0, :]  # f32[TP]
        local = docs - block_start
        bd = acc_ref.shape[1]
        tp = docs.shape[0]
        row_ids = jax.lax.broadcasted_iota(jnp.int32, (bd, tp), 0)
        onehot = (row_ids == local[None, :]).astype(jnp.float32)
        partial = jnp.dot(onehot, c[:, None], preferred_element_type=jnp.float32)  # [BD, 1]
        acc_ref[0, :] += partial[:, 0]


def _scatter_kernel_batched(ranges_ref, docs_ref, contribs_ref, acc_ref, *, block_d: int):
    d = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    block_start = d * block_d
    tile_lo = ranges_ref[0, 0, 0]
    tile_hi = ranges_ref[0, 0, 1]
    overlaps = (tile_lo < block_start + block_d) & (tile_hi > block_start)

    @pl.when(overlaps)
    def _accumulate():
        docs = docs_ref[0, 0, :]  # i32[TP]
        c = contribs_ref[0, 0, :]  # f32[TP]
        local = docs - block_start
        bd = acc_ref.shape[2]
        tp = docs.shape[0]
        row_ids = jax.lax.broadcasted_iota(jnp.int32, (bd, tp), 0)
        onehot = (row_ids == local[None, :]).astype(jnp.float32)
        partial = jnp.dot(onehot, c[:, None], preferred_element_type=jnp.float32)  # [BD, 1]
        acc_ref[0, 0, :] += partial[:, 0]


def impact_scatter_batched_kernel(
    doc_ids: jax.Array,
    contribs: jax.Array,
    tile_ranges: jax.Array,
    *,
    n_docs: int,
    block_d: int = 512,
    tile_p: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Batched scatter-add: one grid axis over queries, then (blocks x tiles).

    The per-query accumulator block is revisited across the innermost tile
    axis exactly as in the single-query kernel, so VMEM residency and the
    skip-range optimization carry over unchanged; queries never share an
    accumulator, so no cross-query reduction is needed.

    Args:
      doc_ids: i32[B, P], P % tile_p == 0, values in [0, n_docs).
      contribs: f32[B, P].
      tile_ranges: i32[B, P // tile_p, 2] per-(query, tile) doc-id bounds.
      n_docs: accumulator length; must be % block_d == 0.

    Returns:
      f32[B, n_docs] accumulators.
    """
    B, P = doc_ids.shape
    assert P % tile_p == 0, (P, tile_p)
    assert n_docs % block_d == 0, (n_docs, block_d)
    n_tiles = P // tile_p
    n_blocks = n_docs // block_d

    grid = (B, n_blocks, n_tiles)
    docs3d = doc_ids.reshape(B, n_tiles, tile_p)
    c3d = contribs.astype(jnp.float32).reshape(B, n_tiles, tile_p)

    out = pl.pallas_call(
        functools.partial(_scatter_kernel_batched, block_d=block_d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 2), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, 1, tile_p), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, 1, tile_p), lambda b, d, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_d), lambda b, d, t: (b, d, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_blocks, block_d), jnp.float32),
        interpret=interpret,
    )(tile_ranges, docs3d, c3d)
    return out.reshape(B, n_docs)


def impact_scatter_kernel(
    doc_ids: jax.Array,
    contribs: jax.Array,
    tile_ranges: jax.Array,
    *,
    n_docs: int,
    block_d: int = 512,
    tile_p: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Scatter-add ``contribs`` into a dense accumulator. See module docstring.

    Args:
      doc_ids: i32[P], P % tile_p == 0, values in [0, n_docs).
      contribs: f32[P].
      tile_ranges: i32[P // tile_p, 2] per-tile [min_doc, max_doc+1) bounds.
      n_docs: accumulator length; must be % block_d == 0.
    """
    P = doc_ids.shape[0]
    assert P % tile_p == 0, (P, tile_p)
    assert n_docs % block_d == 0, (n_docs, block_d)
    n_tiles = P // tile_p
    n_blocks = n_docs // block_d

    grid = (n_blocks, n_tiles)
    docs2d = doc_ids.reshape(n_tiles, tile_p)
    c2d = contribs.astype(jnp.float32).reshape(n_tiles, tile_p)

    out = pl.pallas_call(
        functools.partial(_scatter_kernel, block_d=block_d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda d, t: (t, 0)),
            pl.BlockSpec((1, tile_p), lambda d, t: (t, 0)),
            pl.BlockSpec((1, tile_p), lambda d, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda d, t: (d, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block_d), jnp.float32),
        interpret=interpret,
    )(tile_ranges, docs2d, c2d)
    return out.reshape(n_docs)
