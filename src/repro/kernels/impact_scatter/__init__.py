from repro.kernels.impact_scatter.ops import impact_scatter  # noqa: F401
