from repro.kernels.impact_scatter.ops import impact_scatter, impact_scatter_batched  # noqa: F401
