"""jit'd wrapper around the impact-scatter Pallas kernel.

Handles padding, the optional doc-sort (which enables the kernel's
(block x tile) skip ranges), and interpret-mode selection so the same call
site works on CPU tests and TPU deployments.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_default, pad_axis, round_up
from repro.kernels.impact_scatter.kernel import (
    impact_scatter_batched_kernel,
    impact_scatter_kernel,
)


@partial(
    jax.jit,
    static_argnames=("n_docs", "block_d", "tile_p", "sort_by_doc", "interpret"),
)
def impact_scatter(
    doc_ids: jax.Array,
    contribs: jax.Array,
    n_docs: int,
    *,
    block_d: int = 512,
    tile_p: int = 512,
    sort_by_doc: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """acc[d] = sum of contribs with doc_id == d, via the Pallas kernel.

    ``sort_by_doc=True`` sorts postings by doc id first so each posting tile
    covers a narrow doc range and the kernel skips non-overlapping accumulator
    blocks — turning the O(blocks x tiles) grid into an effectively linear
    pass. The sort itself is a standard XLA sort (fused, HBM-bandwidth bound).
    """
    if interpret is None:
        interpret = interpret_default()
    n_docs_pad = round_up(max(n_docs, block_d), block_d)
    docs = doc_ids.astype(jnp.int32)
    c = contribs.astype(jnp.float32)
    if sort_by_doc:
        order = jnp.argsort(docs)
        docs, c = docs[order], c[order]
    docs = pad_axis(docs, 0, tile_p, fill=0)
    c = pad_axis(c, 0, tile_p, fill=0.0)
    n_tiles = docs.shape[0] // tile_p
    tiles = docs.reshape(n_tiles, tile_p)
    if sort_by_doc:
        ranges = jnp.stack([tiles.min(axis=1), tiles.max(axis=1) + 1], axis=1)
    else:
        ranges = jnp.stack(
            [jnp.zeros((n_tiles,), jnp.int32), jnp.full((n_tiles,), n_docs_pad, jnp.int32)],
            axis=1,
        )
    acc = impact_scatter_kernel(
        docs,
        c,
        ranges.astype(jnp.int32),
        n_docs=n_docs_pad,
        block_d=block_d,
        tile_p=tile_p,
        interpret=interpret,
    )
    return acc[:n_docs]


@partial(
    jax.jit,
    static_argnames=("n_docs", "block_d", "tile_p", "sort_by_doc", "interpret"),
)
def impact_scatter_batched(
    doc_ids: jax.Array,
    contribs: jax.Array,
    n_docs: int,
    *,
    block_d: int = 512,
    tile_p: int = 512,
    sort_by_doc: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """acc[b, d] = sum of contribs[b] with doc_ids[b] == d, natively batched.

    The whole batch runs as ONE kernel launch with a grid axis over queries —
    the batched SAAT engine's hot loop. ``sort_by_doc=True`` applies a single
    batched argsort along the posting axis so each (query, tile) covers a
    narrow doc range and the kernel skips non-overlapping accumulator blocks,
    exactly as in the single-query path.
    """
    if interpret is None:
        interpret = interpret_default()
    n_docs_pad = round_up(max(n_docs, block_d), block_d)
    docs = doc_ids.astype(jnp.int32)
    c = contribs.astype(jnp.float32)
    if sort_by_doc:
        # multi-operand sort: docs key, contribs payload (one fused pass
        # instead of argsort + two gathers)
        docs, c = jax.lax.sort((docs, c), dimension=-1, num_keys=1)
    docs = pad_axis(docs, 1, tile_p, fill=0)
    c = pad_axis(c, 1, tile_p, fill=0.0)
    B = docs.shape[0]
    n_tiles = docs.shape[1] // tile_p
    tiles = docs.reshape(B, n_tiles, tile_p)
    if sort_by_doc:
        ranges = jnp.stack([tiles.min(axis=2), tiles.max(axis=2) + 1], axis=2)
    else:
        ranges = jnp.stack(
            [
                jnp.zeros((B, n_tiles), jnp.int32),
                jnp.full((B, n_tiles), n_docs_pad, jnp.int32),
            ],
            axis=2,
        )
    acc = impact_scatter_batched_kernel(
        docs,
        c,
        ranges.astype(jnp.int32),
        n_docs=n_docs_pad,
        block_d=block_d,
        tile_p=tile_p,
        interpret=interpret,
    )
    return acc[:, :n_docs]
