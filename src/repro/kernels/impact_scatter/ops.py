"""jit'd wrapper around the impact-scatter Pallas kernel.

Handles padding, the optional doc-sort (which enables the kernel's
(block x tile) skip ranges), and interpret-mode selection so the same call
site works on CPU tests and TPU deployments.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.kernel_contracts import KernelContract, ShapeCase
from repro.kernels.common import interpret_default, round_up, sorted_posting_tiles
from repro.kernels.impact_scatter.kernel import (
    impact_scatter_batched_kernel,
    impact_scatter_kernel,
)


@partial(
    jax.jit,
    static_argnames=("n_docs", "block_d", "tile_p", "sort_by_doc", "interpret"),
)
def impact_scatter(
    doc_ids: jax.Array,
    contribs: jax.Array,
    n_docs: int,
    *,
    block_d: int = 512,
    tile_p: int = 512,
    sort_by_doc: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """acc[d] = sum of contribs with doc_id == d, via the Pallas kernel.

    ``sort_by_doc=True`` sorts postings by doc id first so each posting tile
    covers a narrow doc range and the kernel skips non-overlapping accumulator
    blocks — turning the O(blocks x tiles) grid into an effectively linear
    pass. The sort itself is a standard XLA sort (fused, HBM-bandwidth bound).
    """
    if interpret is None:
        interpret = interpret_default()
    n_docs_pad = round_up(max(n_docs, block_d), block_d)
    docs, c, ranges, _ = sorted_posting_tiles(doc_ids, contribs, n_docs_pad, tile_p, sort_by_doc)
    acc = impact_scatter_kernel(
        docs,
        c,
        ranges,
        n_docs=n_docs_pad,
        block_d=block_d,
        tile_p=tile_p,
        interpret=interpret,
    )
    return acc[:n_docs]


@partial(
    jax.jit,
    static_argnames=("n_docs", "block_d", "tile_p", "sort_by_doc", "interpret"),
)
def impact_scatter_batched(
    doc_ids: jax.Array,
    contribs: jax.Array,
    n_docs: int,
    *,
    block_d: int = 512,
    tile_p: int = 512,
    sort_by_doc: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """acc[b, d] = sum of contribs[b] with doc_ids[b] == d, natively batched.

    The whole batch runs as ONE kernel launch with a grid axis over queries —
    the batched SAAT engine's hot loop. ``sort_by_doc=True`` applies a single
    batched argsort along the posting axis so each (query, tile) covers a
    narrow doc range and the kernel skips non-overlapping accumulator blocks,
    exactly as in the single-query path.
    """
    if interpret is None:
        interpret = interpret_default()
    n_docs_pad = round_up(max(n_docs, block_d), block_d)
    docs, c, ranges, _ = sorted_posting_tiles(doc_ids, contribs, n_docs_pad, tile_p, sort_by_doc)
    acc = impact_scatter_batched_kernel(
        docs,
        c,
        ranges,
        n_docs=n_docs_pad,
        block_d=block_d,
        tile_p=tile_p,
        interpret=interpret,
    )
    return acc[:, :n_docs]


def _contract_call(dims):
    """Trace target for the static checker: abstract inputs, sweep tiling."""
    sds = jax.ShapeDtypeStruct
    kw = dict(
        n_docs=dims["n_docs"], block_d=dims["block_d"], tile_p=dims["tile_p"],
        sort_by_doc=True, interpret=True,
    )
    if "batch" in dims:
        shape = (dims["batch"], dims["n_postings"])
        return partial(impact_scatter_batched, **kw), (
            sds(shape, jnp.int32), sds(shape, jnp.float32))
    shape = (dims["n_postings"],)
    return partial(impact_scatter, **kw), (sds(shape, jnp.int32), sds(shape, jnp.float32))


# The single source of truth for the interpret-mode sweep shapes in
# tests/test_kernels.py AND the static checker's trace grid: ragged
# (non-divisible pre-pad) posting/doc counts included on purpose.
CONTRACT = KernelContract(
    name="impact_scatter",
    description="batch-gridded scatter-add accumulator (SAAT hot loop)",
    make_call=_contract_call,
    shape_grid=(
        ShapeCase("single_tile", dict(n_postings=128, n_docs=512, block_d=256, tile_p=128)),
        ShapeCase("ragged", dict(n_postings=1000, n_docs=1000, block_d=256, tile_p=128)),
        ShapeCase("multi_tile", dict(n_postings=4096, n_docs=512, block_d=256, tile_p=128)),
        ShapeCase("b1", dict(batch=1, n_postings=128, n_docs=700, block_d=256, tile_p=128)),
        ShapeCase("b3_ragged", dict(batch=3, n_postings=1000, n_docs=700, block_d=256, tile_p=128)),
        ShapeCase("b8", dict(batch=8, n_postings=1000, n_docs=700, block_d=256, tile_p=128)),
    ),
)
