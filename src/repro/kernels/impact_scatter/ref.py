"""Pure-jnp oracle for the SAAT impact-scatter accumulation."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def impact_scatter_ref(doc_ids: jax.Array, contribs: jax.Array, n_docs: int) -> jax.Array:
    """acc[d] = sum of contribs whose doc_id == d. f32[n_docs].

    ``doc_ids`` entries must lie in [0, n_docs); masked-out postings are
    expected to carry contribution 0 (they may alias doc 0 harmlessly).
    """
    acc = jnp.zeros((n_docs,), jnp.float32)
    return acc.at[doc_ids].add(contribs.astype(jnp.float32))


def impact_scatter_batched_ref(
    doc_ids: jax.Array, contribs: jax.Array, n_docs: int
) -> jax.Array:
    """Batched oracle: acc[b, d] = sum of contribs[b] whose doc_ids[b] == d."""
    B = doc_ids.shape[0]
    acc = jnp.zeros((B, n_docs), jnp.float32)
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    return acc.at[rows, doc_ids].add(contribs.astype(jnp.float32))
