"""Pure-jnp oracle for the fused block upper-bound + prune pass."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_prune_ref(
    blockmax: jax.Array,  # f32[Lq, n_blocks] per-query-term block maxima
    q_weights: jax.Array,  # f32[Lq]
    theta: jax.Array,  # f32[] current top-k threshold
) -> tuple[jax.Array, jax.Array]:
    """Returns (ub[n_blocks], survive_mask[n_blocks]).

    ub[b] = sum_i qw_i * blockmax[i, b]; survive = ub > theta. Blocks with
    ub == 0 (no query term present) never survive.
    """
    ub = jnp.einsum("i,ib->b", q_weights.astype(jnp.float32), blockmax.astype(jnp.float32))
    survive = (ub > theta) & (ub > 0)
    return ub, survive


def block_prune_batched_ref(
    blockmax: jax.Array,  # f32[B, Lq, n_blocks]
    q_weights: jax.Array,  # f32[B, Lq]
    theta: jax.Array,  # f32[B] per-query thresholds
) -> tuple[jax.Array, jax.Array]:
    """Batched oracle: (ub[B, n_blocks], survive_mask[B, n_blocks])."""
    ub = jnp.einsum(
        "qi,qib->qb", q_weights.astype(jnp.float32), blockmax.astype(jnp.float32)
    )
    survive = (ub > theta[:, None]) & (ub > 0)
    return ub, survive
