"""jit'd wrapper around the fused block-prune kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.kernel_contracts import KernelContract, ShapeCase
from repro.kernels.block_prune.kernel import block_prune_batched_kernel, block_prune_kernel
from repro.kernels.common import interpret_default, pad_axis


@partial(jax.jit, static_argnames=("block_nb", "interpret"))
def block_prune(
    blockmax: jax.Array,
    q_weights: jax.Array,
    theta: jax.Array,
    *,
    block_nb: int = 2048,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """(ub, survive_mask) over doc blocks; see kernel module docstring."""
    if interpret is None:
        interpret = interpret_default()
    lq, nb = blockmax.shape
    block_nb = min(block_nb, max(128, nb))
    bm = pad_axis(blockmax.astype(jnp.float32), 1, block_nb, fill=0.0)
    ub, mask = block_prune_kernel(
        bm,
        q_weights.astype(jnp.float32),
        jnp.asarray(theta, jnp.float32),
        block_nb=block_nb,
        interpret=interpret,
    )
    return ub[:nb], mask[:nb].astype(jnp.bool_)


@partial(jax.jit, static_argnames=("block_nb", "interpret"))
def block_prune_batched(
    blockmax: jax.Array,
    q_weights: jax.Array,
    theta: jax.Array,
    *,
    block_nb: int = 2048,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched (ub, survive_mask): ``blockmax [B, Lq, NB]``, per-query theta.

    One kernel launch grids over (query, block-tile); each query is pruned
    against its own threshold. Rows/thetas never mix across queries.
    """
    if interpret is None:
        interpret = interpret_default()
    b, lq, nb = blockmax.shape
    block_nb = min(block_nb, max(128, nb))
    bm = pad_axis(blockmax.astype(jnp.float32), 2, block_nb, fill=0.0)
    ub, mask = block_prune_batched_kernel(
        bm,
        q_weights.astype(jnp.float32),
        jnp.asarray(theta, jnp.float32),
        block_nb=block_nb,
        interpret=interpret,
    )
    return ub[:, :nb], mask[:, :nb].astype(jnp.bool_)


def _contract_call(dims):
    """Trace target for the static checker: abstract inputs, sweep tiling."""
    sds = jax.ShapeDtypeStruct
    lq, nb = dims["lq"], dims["nb"]
    kw = dict(block_nb=dims["block_nb"], interpret=True)
    if "batch" in dims:
        b = dims["batch"]
        return partial(block_prune_batched, **kw), (
            sds((b, lq, nb), jnp.float32), sds((b, lq), jnp.float32), sds((b,), jnp.float32))
    return partial(block_prune, **kw), (
        sds((lq, nb), jnp.float32), sds((lq,), jnp.float32), sds((), jnp.float32))


# Single source of truth for the sweep shapes in tests/test_kernels.py and
# the checker's trace grid: block counts below/above/ragged vs the tile.
CONTRACT = KernelContract(
    name="block_prune",
    description="fused block-upper-bound + threshold prune (DAAT phase 0)",
    make_call=_contract_call,
    shape_grid=(
        ShapeCase("narrow", dict(lq=8, nb=100, block_nb=256)),
        ShapeCase("wide", dict(lq=32, nb=2048, block_nb=256)),
        ShapeCase("tiny_ragged", dict(lq=5, nb=17, block_nb=256)),
        ShapeCase("b1", dict(batch=1, lq=8, nb=100, block_nb=256)),
        ShapeCase("b4_wide", dict(batch=4, lq=32, nb=2048, block_nb=256)),
        ShapeCase("b3_tiny", dict(batch=3, lq=5, nb=17, block_nb=256)),
    ),
)
