"""jit'd wrapper around the fused block-prune kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.block_prune.kernel import block_prune_batched_kernel, block_prune_kernel
from repro.kernels.common import interpret_default, pad_axis


@partial(jax.jit, static_argnames=("block_nb", "interpret"))
def block_prune(
    blockmax: jax.Array,
    q_weights: jax.Array,
    theta: jax.Array,
    *,
    block_nb: int = 2048,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """(ub, survive_mask) over doc blocks; see kernel module docstring."""
    if interpret is None:
        interpret = interpret_default()
    lq, nb = blockmax.shape
    block_nb = min(block_nb, max(128, nb))
    bm = pad_axis(blockmax.astype(jnp.float32), 1, block_nb, fill=0.0)
    ub, mask = block_prune_kernel(
        bm,
        q_weights.astype(jnp.float32),
        jnp.asarray(theta, jnp.float32),
        block_nb=block_nb,
        interpret=interpret,
    )
    return ub[:nb], mask[:nb].astype(jnp.bool_)


@partial(jax.jit, static_argnames=("block_nb", "interpret"))
def block_prune_batched(
    blockmax: jax.Array,
    q_weights: jax.Array,
    theta: jax.Array,
    *,
    block_nb: int = 2048,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched (ub, survive_mask): ``blockmax [B, Lq, NB]``, per-query theta.

    One kernel launch grids over (query, block-tile); each query is pruned
    against its own threshold. Rows/thetas never mix across queries.
    """
    if interpret is None:
        interpret = interpret_default()
    b, lq, nb = blockmax.shape
    block_nb = min(block_nb, max(128, nb))
    bm = pad_axis(blockmax.astype(jnp.float32), 2, block_nb, fill=0.0)
    ub, mask = block_prune_batched_kernel(
        bm,
        q_weights.astype(jnp.float32),
        jnp.asarray(theta, jnp.float32),
        block_nb=block_nb,
        interpret=interpret,
    )
    return ub[:, :nb], mask[:, :nb].astype(jnp.bool_)
