"""Pallas TPU kernel: fused Block-Max upper bound + threshold prune.

The whole WAND/BMW "pivot" machinery collapses, on TPU, into one fused pass
per query: a [1, Lq] x [Lq, NB] matmul producing every block's additive score
upper bound, immediately compared against the running top-k threshold theta.
The survive mask drives which blocks the ``sparse_score`` kernel actually
scores — so the *measured* number of surviving blocks is precisely the
paper's "how much can DAAT skip" quantity.

Grid tiles the block axis; the query column (Lq) stays resident in VMEM.

The batched variant grids over (query, block-tile): each grid cell prunes one
query's tile of blocks against that query's own theta, so a whole ``[B, Lq]``
batch is one kernel launch — the DAAT analogue of ``impact_scatter_batched``.
Queries never share state, so no cross-query reduction is needed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prune_kernel(bm_ref, qw_ref, theta_ref, ub_ref, mask_ref):
    bm = bm_ref[...].astype(jnp.float32)  # [Lq, NBt]
    qw = qw_ref[...].astype(jnp.float32)  # [1, Lq]
    theta = theta_ref[0, 0]
    ub = jnp.dot(qw, bm, preferred_element_type=jnp.float32)  # [1, NBt]
    ub_ref[...] = ub
    mask_ref[...] = ((ub > theta) & (ub > 0)).astype(jnp.int32)


def _prune_kernel_batched(bm_ref, qw_ref, theta_ref, ub_ref, mask_ref):
    bm = bm_ref[0].astype(jnp.float32)  # [Lq, NBt]
    qw = qw_ref[0].astype(jnp.float32)  # [1, Lq]
    theta = theta_ref[0, 0, 0]
    ub = jnp.dot(qw, bm, preferred_element_type=jnp.float32)  # [1, NBt]
    ub_ref[...] = ub
    mask_ref[...] = ((ub > theta) & (ub > 0)).astype(jnp.int32)


def block_prune_batched_kernel(
    blockmax: jax.Array,  # f32[B, Lq, NB]
    q_weights: jax.Array,  # f32[B, Lq]
    theta: jax.Array,  # f32[B]
    *,
    block_nb: int = 2048,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, lq, nb = blockmax.shape
    assert nb % block_nb == 0, (nb, block_nb)
    grid = (b, nb // block_nb)
    ub, mask = pl.pallas_call(
        _prune_kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, lq, block_nb), lambda q, i: (q, 0, i)),
            pl.BlockSpec((1, 1, lq), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda q, i: (q, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_nb), lambda q, i: (q, i)),
            pl.BlockSpec((1, block_nb), lambda q, i: (q, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nb), jnp.float32),
            jax.ShapeDtypeStruct((b, nb), jnp.int32),
        ],
        interpret=interpret,
    )(blockmax, q_weights.reshape(b, 1, lq), theta.reshape(b, 1, 1))
    return ub, mask


def block_prune_kernel(
    blockmax: jax.Array,  # f32[Lq, NB]
    q_weights: jax.Array,  # f32[Lq]
    theta: jax.Array,  # f32[]
    *,
    block_nb: int = 2048,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    lq, nb = blockmax.shape
    assert nb % block_nb == 0, (nb, block_nb)
    grid = (nb // block_nb,)
    ub, mask = pl.pallas_call(
        _prune_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((lq, block_nb), lambda i: (0, i)),
            pl.BlockSpec((1, lq), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_nb), lambda i: (0, i)),
            pl.BlockSpec((1, block_nb), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, nb), jnp.float32),
            jax.ShapeDtypeStruct((1, nb), jnp.int32),
        ],
        interpret=interpret,
    )(blockmax, q_weights.reshape(1, lq), theta.reshape(1, 1))
    return ub[0], mask[0]
