from repro.kernels.block_prune.ops import block_prune, block_prune_batched  # noqa: F401
