from repro.kernels.block_prune.ops import block_prune  # noqa: F401
