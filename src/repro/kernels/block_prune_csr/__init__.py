from repro.kernels.block_prune_csr.ops import block_prune_csr_batched  # noqa: F401
