"""Pallas TPU kernel: CSR-native Block-Max upper bound + threshold prune.

``block_prune_batched`` eats a densified ``[B, Lq, n_blocks]`` block-max
matrix — ``Lq`` x the footprint of the CSR lists it expands from, written to
HBM by the engine's scatter just to be re-read by the kernel. This kernel
walks the CSR block-max lists directly: the per-(query, slot) window offsets
and entry counts arrive via scalar prefetch (``PrefetchScalarGridSpec`` SMEM
operands — DMA source offsets must be known before the body runs), each
slot's ``[M]`` window of ``bm_block``/``bm_weight`` streams HBM->VMEM with
double-buffered async copies (slot ``l+1`` prefetches while slot ``l``
densifies), and the densified ``[Lq, NBp]`` tile exists only as VMEM scratch.

Parity contract: the tile is densified with the exact masked-gather
semantics of ``repro.core.daat._gather_blockmax_lists`` (a block id appears
at most once per per-term list, so the masked one-hot sum reproduces the
scatter-add), and the bound is the same ``[1, Lq] x [Lq, NB]`` MXU dot the
dense kernel runs — ``ub`` is bit-identical to ``block_prune_batched`` on the
densified rows, so engine ids and WorkStats cannot move.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _prune_csr_kernel_batched(
    base_ref,  # SMEM i32[B, Lq] — scalar-prefetched window starts
    cnt_ref,  # SMEM i32[B, Lq] — scalar-prefetched valid entry counts
    qw_ref,  # f32[1, Lq]
    theta_ref,  # f32[1, 1]
    bm_block_hbm,  # i32[n_bm_pad] — stays in HBM, DMA'd per slot
    bm_weight_hbm,  # f32[n_bm_pad] — stays in HBM, DMA'd per slot
    ub_ref,  # out f32[1, NBp]
    mask_ref,  # out i32[1, NBp]
    bm_tile,  # VMEM f32[Lq, NBp] — the densified tile, never leaves VMEM
    blk_buf,  # VMEM i32[2, M] — double-buffered block-id windows
    w_buf,  # VMEM f32[2, M] — double-buffered block-max windows
    sems,  # DMA semaphores (slot, block/weight)
):
    b = pl.program_id(0)
    lq, nbp = bm_tile.shape
    m = blk_buf.shape[1]

    def window_dma(slot, l):
        start = base_ref[b, l]
        return (
            pltpu.make_async_copy(
                bm_block_hbm.at[pl.ds(start, m)], blk_buf.at[slot], sems.at[slot, 0]
            ),
            pltpu.make_async_copy(
                bm_weight_hbm.at[pl.ds(start, m)], w_buf.at[slot], sems.at[slot, 1]
            ),
        )

    for c in window_dma(0, 0):  # warm up the pipeline
        c.start()
    for l in range(lq):
        slot = l % 2
        if l + 1 < lq:  # prefetch the next slot's window while densifying
            for c in window_dma((l + 1) % 2, l + 1):
                c.start()
        for c in window_dma(slot, l):
            c.wait()
        blk = blk_buf[slot]  # i32[M]
        w = w_buf[slot].astype(jnp.float32)  # f32[M]
        valid = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)[:, 0] < cnt_ref[b, l]
        # a block id appears at most once per per-term list, so the masked
        # one-hot sum IS the engine's scatter-add densification
        onehot = (
            blk[:, None] == jax.lax.broadcasted_iota(jnp.int32, (m, nbp), 1)
        ) & valid[:, None]
        bm_tile[l, :] = jnp.sum(jnp.where(onehot, w[:, None], 0.0), axis=0)

    # the dense kernel's exact contraction: [1, Lq] x [Lq, NBp] on the MXU
    qw = qw_ref[...].astype(jnp.float32)
    theta = theta_ref[0, 0]
    ub = jnp.dot(qw, bm_tile[...], preferred_element_type=jnp.float32)
    ub_ref[...] = ub
    mask_ref[...] = ((ub > theta) & (ub > 0)).astype(jnp.int32)


def block_prune_csr_batched_kernel(
    bm_block: jax.Array,  # i32[n_bm_pad] — padded so every window is in-bounds
    bm_weight: jax.Array,  # f32[n_bm_pad]
    base: jax.Array,  # i32[B, Lq]
    cnt: jax.Array,  # i32[B, Lq] (already clamped to M)
    q_weights: jax.Array,  # f32[B, Lq]
    theta: jax.Array,  # f32[B]
    *,
    m: int,
    nbp: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """CSR-walking (ub, survive_mask) over doc blocks: grid over B.

    ``base``/``cnt`` ride in as scalar-prefetch operands; ``bm_block`` /
    ``bm_weight`` stay HBM-resident and are windowed in by DMA.
    """
    B, lq = base.shape
    row = lambda b, *_: (b, 0)  # noqa: E731 — scalar refs trail the index args
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, lq), row),
            pl.BlockSpec((1, 1), row),
            pl.BlockSpec(memory_space=pltpu.ANY),  # CSR block ids: DMA only
            pl.BlockSpec(memory_space=pltpu.ANY),  # CSR block maxima: DMA only
        ],
        out_specs=[
            pl.BlockSpec((1, nbp), row),
            pl.BlockSpec((1, nbp), row),
        ],
        scratch_shapes=[
            pltpu.VMEM((lq, nbp), jnp.float32),  # densified tile (VMEM-only)
            pltpu.VMEM((2, m), jnp.int32),  # double-buffered id windows
            pltpu.VMEM((2, m), jnp.float32),  # double-buffered max windows
            pltpu.SemaphoreType.DMA((2, 2)),  # (slot, block/weight)
        ],
    )
    ub, mask = pl.pallas_call(
        _prune_csr_kernel_batched,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, nbp), jnp.float32),
            jax.ShapeDtypeStruct((B, nbp), jnp.int32),
        ],
        interpret=interpret,
    )(
        base, cnt, q_weights.reshape(B, lq), theta.reshape(B, 1),
        bm_block, bm_weight,
    )
    return ub, mask
