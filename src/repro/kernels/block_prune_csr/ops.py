"""jit'd wrapper around the CSR-native block-prune kernel.

Handles the engine <-> kernel impedance: the CSR arrays get ``M`` trailing
zero entries so every scalar-prefetched window ``[base, base + M)`` is
in-bounds (the sentinel term's empty list starts at the old array end), the
block axis pads to the 128-lane multiple (pad columns densify to 0 ->
``ub = 0`` -> never survive), and counts clamp to ``M`` defensively — the
engine's :func:`repro.core.daat.csr_blockmax_offsets` already clamps, this
keeps the op safe standalone.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.kernel_contracts import KernelContract, ShapeCase
from repro.kernels.block_prune_csr.kernel import block_prune_csr_batched_kernel
from repro.kernels.common import interpret_default, round_up


@partial(
    jax.jit, static_argnames=("n_blocks", "max_bm_per_term", "interpret")
)
def block_prune_csr_batched(
    bm_block: jax.Array,
    bm_weight: jax.Array,
    base: jax.Array,
    cnt: jax.Array,
    q_weights: jax.Array,
    theta: jax.Array,
    *,
    n_blocks: int,
    max_bm_per_term: int,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched (ub, survive_mask) straight off the CSR block-max lists.

    Args:
      bm_block/bm_weight: the index's CSR block-max arrays (``i32[n_bm]`` /
        ``f32[n_bm]``), HBM-resident; the wrapper appends the window pad.
      base/cnt: ``i32[B, Lq]`` per-(query, slot) window starts and valid
        entry counts (sentinel-mapped pad slots carry an empty window) —
        see :func:`repro.core.daat.csr_blockmax_offsets`.
      q_weights: ``f32[B, Lq]`` raw query weights (``<= 0`` slots already
        map to empty windows, so they contribute exactly 0).
      theta: ``f32[B]`` per-query prune thresholds (``-inf`` = pure ub pass).

    Returns ``(ub f32[B, n_blocks], survive bool[B, n_blocks])`` —
    bit-identical ``ub`` to ``block_prune_batched`` over the densified rows.
    """
    if interpret is None:
        interpret = interpret_default()
    m = max_bm_per_term
    if m < 1:
        raise ValueError(f"max_bm_per_term={m} must be >= 1")
    nbp = round_up(max(n_blocks, 1), 128)
    pad = jnp.zeros((m,), bm_block.dtype)
    bm_block_p = jnp.concatenate([bm_block.astype(jnp.int32), pad.astype(jnp.int32)])
    bm_weight_p = jnp.concatenate(
        [bm_weight.astype(jnp.float32), jnp.zeros((m,), jnp.float32)]
    )
    ub, mask = block_prune_csr_batched_kernel(
        bm_block_p,
        bm_weight_p,
        base.astype(jnp.int32),
        jnp.minimum(cnt.astype(jnp.int32), m),
        q_weights.astype(jnp.float32),
        jnp.asarray(theta, jnp.float32),
        m=m,
        nbp=nbp,
        interpret=interpret,
    )
    return ub[:, :n_blocks], mask[:, :n_blocks].astype(jnp.bool_)


def _contract_call(dims):
    """Trace target for the static checker: abstract CSR inputs."""
    sds = jax.ShapeDtypeStruct
    B, lq = dims["batch"], dims["lq"]
    n_bm = dims["n_bm"]
    fn = partial(
        block_prune_csr_batched,
        n_blocks=dims["nb"], max_bm_per_term=dims["m"], interpret=True,
    )
    args = (
        sds((n_bm,), jnp.int32), sds((n_bm,), jnp.float32),  # CSR lists
        sds((B, lq), jnp.int32), sds((B, lq), jnp.int32),  # base / cnt
        sds((B, lq), jnp.float32), sds((B,), jnp.float32),  # qw / theta
    )
    return fn, args


# Single source of truth for the sweep shapes in tests/test_kernels.py and
# the checker's trace grid. expect_dma + expect_scalar_prefetch: the CSR
# windows MUST stream in via double-buffered make_async_copy from offsets
# that only scalar prefetch can deliver — a fall-back to pipelined blocks
# would silently reintroduce the densified HBM intermediate.
CONTRACT = KernelContract(
    name="block_prune_csr",
    description="CSR-walking block upper-bound + prune (DAAT phase 0, no densify)",
    make_call=_contract_call,
    expect_dma=True,
    expect_scalar_prefetch=True,
    shape_grid=(
        ShapeCase("b1", dict(batch=1, lq=8, nb=100, m=16, n_bm=800)),
        ShapeCase("b4_wide", dict(batch=4, lq=32, nb=2048, m=64, n_bm=12000)),
        ShapeCase("b3_tiny", dict(batch=3, lq=5, nb=17, m=3, n_bm=40)),
        ShapeCase("b2_single_slot", dict(batch=2, lq=1, nb=64, m=8, n_bm=100)),
    ),
)
