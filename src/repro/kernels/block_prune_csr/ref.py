"""Pure-jnp oracle for the CSR-native block-prune kernel.

The masked-gather densification below is ``_gather_blockmax_lists`` +
``_dense_blockmax_rows`` from ``repro.core.daat``, inlined verbatim, followed
by the dense kernel's contraction — so the kernel is simultaneously checked
against the CSR semantics and against what ``block_prune_batched`` would have
produced from the densified rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_prune_csr_batched_ref(
    bm_block: jax.Array,  # i32[n_bm]
    bm_weight: jax.Array,  # f32[n_bm]
    base: jax.Array,  # i32[B, Lq]
    cnt: jax.Array,  # i32[B, Lq]
    q_weights: jax.Array,  # f32[B, Lq]
    theta: jax.Array,  # f32[B]
    *,
    n_blocks: int,
    max_bm_per_term: int,
) -> tuple[jax.Array, jax.Array]:
    """Returns ``(ub[B, n_blocks], survive[B, n_blocks])``."""
    B, lq = base.shape
    m = max_bm_per_term
    offs = jnp.arange(m, dtype=jnp.int32)
    idx = base[..., :, None] + offs
    valid = offs < jnp.minimum(cnt, m)[..., :, None]
    idx = jnp.where(valid, idx, 0)
    blocks = jnp.where(valid, bm_block[idx], 0)
    w = jnp.where(valid, bm_weight[idx], 0.0)
    rows = jnp.zeros((B, lq, n_blocks), jnp.float32)
    b_ix = jnp.arange(B, dtype=jnp.int32)[:, None, None]
    l_ix = jnp.arange(lq, dtype=jnp.int32)[None, :, None]
    rows = rows.at[b_ix, l_ix, blocks].add(w)
    ub = jnp.einsum(
        "ql,qlb->qb", q_weights.astype(jnp.float32), rows
    )
    survive = (ub > theta[:, None]) & (ub > 0)
    return ub, survive
