"""jit'd wrapper: two-stage top-k (Pallas per-tile select + finalist merge)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.kernel_contracts import KernelContract, ShapeCase
from repro.kernels.block_topk.kernel import block_topk_batched_kernel, block_topk_kernel
from repro.kernels.common import interpret_default, pad_axis


@partial(jax.jit, static_argnames=("k", "tile", "interpret"))
def block_topk(
    scores: jax.Array,
    k: int,
    *,
    tile: int = 8192,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Exact top-k over a 1-D score vector of any length. (scores, ids)."""
    if interpret is None:
        interpret = interpret_default()
    n = scores.shape[0]
    tile = min(tile, max(128, n))
    k_eff = min(k, n)
    s = pad_axis(scores.astype(jnp.float32), 0, tile, fill=-jnp.inf)
    k_tile = min(max(k_eff, 1), tile)
    ts, ti = block_topk_kernel(s, k=k_tile, tile=tile, interpret=interpret)
    fs, fi = jax.lax.top_k(ts.reshape(-1), k_eff)
    ids = ti.reshape(-1)[fi]
    if k_eff < k:  # pad to requested k for shape stability
        fs = jnp.concatenate([fs, jnp.full((k - k_eff,), -jnp.inf, fs.dtype)])
        ids = jnp.concatenate([ids, jnp.zeros((k - k_eff,), ids.dtype)])
    return fs, ids


@partial(jax.jit, static_argnames=("k", "tile", "interpret"))
def block_topk_batched(
    scores: jax.Array,
    k: int,
    *,
    tile: int = 8192,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Exact per-row top-k over ``scores [B, n]``. Returns ``([B, k], [B, k])``.

    One (query, tile)-gridded kernel launch for stage 1, then one batched
    finalist merge — no per-query vmapped programs.
    """
    if interpret is None:
        interpret = interpret_default()
    b, n = scores.shape
    tile = min(tile, max(128, n))
    k_eff = min(k, n)
    s = pad_axis(scores.astype(jnp.float32), 1, tile, fill=-jnp.inf)
    k_tile = min(max(k_eff, 1), tile)
    ts, ti = block_topk_batched_kernel(s, k=k_tile, tile=tile, interpret=interpret)
    fs, fi = jax.lax.top_k(ts.reshape(b, -1), k_eff)
    ids = jnp.take_along_axis(ti.reshape(b, -1), fi, axis=-1)
    if k_eff < k:  # pad to requested k for shape stability
        fs = jnp.concatenate([fs, jnp.full((b, k - k_eff), -jnp.inf, fs.dtype)], axis=-1)
        ids = jnp.concatenate([ids, jnp.zeros((b, k - k_eff), ids.dtype)], axis=-1)
    return fs, ids


def _contract_call(dims):
    """Trace target for the static checker: abstract inputs, sweep tiling."""
    sds = jax.ShapeDtypeStruct
    kw = dict(k=dims["k"], tile=dims["tile"], interpret=True)
    if "batch" in dims:
        return partial(block_topk_batched, **kw), (
            sds((dims["batch"], dims["n"]), jnp.float32),)
    return partial(block_topk, **kw), (sds((dims["n"],), jnp.float32),)


# Single source of truth for the sweep shapes in tests/test_kernels.py and
# the checker's trace grid: tile-ragged n, k == n, and k > tile degenerates.
CONTRACT = KernelContract(
    name="block_topk",
    description="two-stage exact top-k (per-tile select + finalist merge)",
    make_call=_contract_call,
    shape_grid=(
        ShapeCase("ragged", dict(n=1000, k=10, tile=256)),
        ShapeCase("aligned", dict(n=8192, k=100, tile=1024)),
        ShapeCase("k_is_n", dict(n=100, k=100, tile=128)),
        ShapeCase("wide_tile", dict(n=5000, k=7, tile=512)),
        ShapeCase("b1", dict(batch=1, n=1000, k=10, tile=256)),
        ShapeCase("b3_ragged", dict(batch=3, n=517, k=7, tile=128)),
        ShapeCase("b8_k_is_n", dict(batch=8, n=100, k=100, tile=128)),
    ),
)
