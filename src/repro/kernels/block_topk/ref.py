"""Pure-jnp oracle for the tiled top-k selection."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_topk_ref(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Exact top-k (descending scores, int32 indices)."""
    s, i = jax.lax.top_k(scores, k)
    return s, i.astype(jnp.int32)


def block_topk_batched_ref(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Row-wise exact top-k over ``[B, n]`` (descending, int32 indices)."""
    s, i = jax.lax.top_k(scores, k)
    return s, i.astype(jnp.int32)
