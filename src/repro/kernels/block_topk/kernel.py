"""Pallas TPU kernel: tiled top-k over a large score vector.

The JASS min-heap has no TPU analogue; the idiomatic replacement for top-k
over millions of accumulators (8.8M docs, 1M recsys candidates) is a
two-stage select: per-tile top-k entirely in VMEM, then a small host-side
(or XLA) merge over ``num_tiles * k`` finalists. This kernel is stage 1; the
``ops`` wrapper fuses stage 2 with ``lax.top_k`` over the finalists.

Per-tile selection uses ``jax.lax.top_k`` *inside* the kernel over the VMEM
tile — lowered by Mosaic to an on-chip sort network — so each grid step reads
its tile from HBM exactly once: the pass is strictly memory-bound at
``4 bytes/score``, the roofline floor for any selection algorithm.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(scores_ref, out_s_ref, out_i_ref, *, k: int):
    i = pl.program_id(0)
    tile = scores_ref[0, :]  # f32[T]
    t = tile.shape[0]
    s, idx = jax.lax.top_k(tile, k)
    out_s_ref[0, :] = s
    out_i_ref[0, :] = idx.astype(jnp.int32) + i * t


def _topk_kernel_batched(scores_ref, out_s_ref, out_i_ref, *, k: int):
    i = pl.program_id(1)
    tile = scores_ref[0, 0, :]  # f32[T] — one (query, tile) cell
    t = tile.shape[0]
    s, idx = jax.lax.top_k(tile, k)
    out_s_ref[0, 0, :] = s
    out_i_ref[0, 0, :] = idx.astype(jnp.int32) + i * t


def block_topk_batched_kernel(
    scores: jax.Array,  # f32[B, n], n % tile == 0
    *,
    k: int,
    tile: int = 8192,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Stage-1 select for a whole query batch: grid over (query, tile).

    Each grid cell reads one query's VMEM tile exactly once, so the batched
    pass keeps the single-query kernel's memory-bound roofline while amortizing
    one launch across the batch (DAAT chunk selection runs this every
    while_loop iteration).
    """
    b, n = scores.shape
    assert n % tile == 0 and k <= tile, (n, tile, k)
    n_tiles = n // tile
    s, i = pl.pallas_call(
        functools.partial(_topk_kernel_batched, k=k),
        grid=(b, n_tiles),
        in_specs=[pl.BlockSpec((1, 1, tile), lambda q, i: (q, i, 0))],
        out_specs=[
            pl.BlockSpec((1, 1, k), lambda q, i: (q, i, 0)),
            pl.BlockSpec((1, 1, k), lambda q, i: (q, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_tiles, k), jnp.float32),
            jax.ShapeDtypeStruct((b, n_tiles, k), jnp.int32),
        ],
        interpret=interpret,
    )(scores.reshape(b, n_tiles, tile))
    return s, i


def block_topk_kernel(
    scores: jax.Array,  # f32[n], n % tile == 0
    *,
    k: int,
    tile: int = 8192,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    n = scores.shape[0]
    assert n % tile == 0 and k <= tile, (n, tile, k)
    n_tiles = n // tile
    s, i = pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, k), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, k), jnp.int32),
        ],
        interpret=interpret,
    )(scores.reshape(n_tiles, tile))
    return s, i
