from repro.kernels.block_topk.ops import block_topk  # noqa: F401
