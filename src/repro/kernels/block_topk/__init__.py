from repro.kernels.block_topk.ops import block_topk, block_topk_batched  # noqa: F401
