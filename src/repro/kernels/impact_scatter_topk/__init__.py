from repro.kernels.impact_scatter_topk.ops import (  # noqa: F401
    impact_scatter_topk,
    impact_scatter_topk_batched,
)
