"""Pallas TPU kernel: fused SAAT scatter-add → per-block top-k selection.

The unfused SAAT pipeline writes the full ``[B, n_docs]`` accumulator to HBM
(``impact_scatter_batched``) and immediately reads it back for top-k — twice
the accumulator's worth of HBM traffic for a result that is only ``k`` entries
wide. This kernel fuses the selection into the scatter's output-revisiting
loop: the accumulator *block* lives in VMEM scratch, is revisited across the
posting-tile grid axis exactly as in ``impact_scatter``, and at the LAST tile
the kernel runs ``jax.lax.top_k`` over the finished block and emits only that
block's ``k`` best candidates (ids globalized to document space, scores f32).
What crosses the HBM boundary is the candidate pool ``[B, n_blocks * k]`` —
never the accumulator.

Rank safety of the two-stage select: a block of ``block_d`` documents can
contribute at most ``min(k, block_d)`` entries to the global top-k, so keeping
``min(k, block_d)`` candidates per block loses nothing; the caller's merge
pass over the pool (``repro.core.topk.tiled_topk``) recovers the exact global
top-k, bit-identical in ids to ``lax.top_k`` over the dense accumulator
(ties resolve block-major → ascending doc id, the same order).

Padded documents (``gid >= n_live``) are masked to ``-inf`` *inside* the
kernel, before selection, so the candidate pool replicates the unfused
engine's ``_mask_pad_docs`` + ``topk`` semantics. The index lifecycle's
tombstone bitmap rides the same gate: an optional ``[n_blocks, block_d]``
i32 live input (nonzero = live, i32 because Mosaic has no bool VMEM tiles)
is ANDed into the pad mask at selection time, so deleted documents score
``-inf`` without touching the accumulation — and therefore without
perturbing the surviving docs' bit-exact f32 sums. Masking only at select
(not during accumulate) is what keeps the candidate pool rank-safe AND
bit-identical to the unfused engine's masked accumulator.

The skip-range optimization carries over unchanged from ``impact_scatter``:
per-(query, tile) [min_doc, max_doc+1) bounds let non-overlapping (block,
tile) cells skip the one-hot matmul; the t==last selection step still runs so
every block emits its candidates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_topk_kernel(
    ranges_ref,
    docs_ref,
    contribs_ref,
    *rest,
    block_d: int,
    n_tiles: int,
    n_live: int,
    has_live: bool = False,
):
    # `rest` unpacks to (live_ref?, out_s_ref, out_i_ref, acc_ref): the live
    # bitmap is an optional trailing input, so the no-mask launch traces the
    # exact same kernel it always has.
    if has_live:
        live_ref, out_s_ref, out_i_ref, acc_ref = rest
    else:
        live_ref = None
        out_s_ref, out_i_ref, acc_ref = rest
    d = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    block_start = d * block_d
    tile_lo = ranges_ref[0, 0]
    tile_hi = ranges_ref[0, 1]
    overlaps = (tile_lo < block_start + block_d) & (tile_hi > block_start)

    @pl.when(overlaps)
    def _accumulate():
        docs = docs_ref[0, :]  # i32[TP]
        c = contribs_ref[0, :]  # f32[TP]
        local = docs - block_start
        bd = acc_ref.shape[1]
        tp = docs.shape[0]
        row_ids = jax.lax.broadcasted_iota(jnp.int32, (bd, tp), 0)
        onehot = (row_ids == local[None, :]).astype(jnp.float32)
        partial = jnp.dot(onehot, c[:, None], preferred_element_type=jnp.float32)
        acc_ref[0, :] += partial[:, 0]

    @pl.when(t == n_tiles - 1)
    def _select():
        k = out_s_ref.shape[1]
        # 2-D iota: Mosaic rejects 1-D iota on real TPUs (same convention as
        # the scatter kernels' broadcasted_iota row ids)
        gid = block_start + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 1)
        mask = gid < n_live
        if live_ref is not None:
            mask = mask & (live_ref[...] != 0)
        scores = jnp.where(mask, acc_ref[...], -jnp.inf)
        s, i = jax.lax.top_k(scores[0], k)
        out_s_ref[0, :] = s
        out_i_ref[0, :] = i.astype(jnp.int32) + block_start


def _scatter_topk_kernel_batched(
    ranges_ref,
    docs_ref,
    contribs_ref,
    *rest,
    block_d: int,
    n_tiles: int,
    n_live: int,
    has_live: bool = False,
):
    if has_live:
        live_ref, out_s_ref, out_i_ref, acc_ref = rest
    else:
        live_ref = None
        out_s_ref, out_i_ref, acc_ref = rest
    d = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    block_start = d * block_d
    tile_lo = ranges_ref[0, 0, 0]
    tile_hi = ranges_ref[0, 0, 1]
    overlaps = (tile_lo < block_start + block_d) & (tile_hi > block_start)

    @pl.when(overlaps)
    def _accumulate():
        docs = docs_ref[0, 0, :]  # i32[TP]
        c = contribs_ref[0, 0, :]  # f32[TP]
        local = docs - block_start
        bd = acc_ref.shape[1]
        tp = docs.shape[0]
        row_ids = jax.lax.broadcasted_iota(jnp.int32, (bd, tp), 0)
        onehot = (row_ids == local[None, :]).astype(jnp.float32)
        partial = jnp.dot(onehot, c[:, None], preferred_element_type=jnp.float32)
        acc_ref[0, :] += partial[:, 0]

    @pl.when(t == n_tiles - 1)
    def _select():
        k = out_s_ref.shape[2]
        # 2-D iota: Mosaic rejects 1-D iota on real TPUs
        gid = block_start + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 1)
        mask = gid < n_live
        if live_ref is not None:
            mask = mask & (live_ref[...] != 0)
        scores = jnp.where(mask, acc_ref[...], -jnp.inf)
        s, i = jax.lax.top_k(scores[0], k)
        out_s_ref[0, 0, :] = s
        out_i_ref[0, 0, :] = i.astype(jnp.int32) + block_start


def impact_scatter_topk_kernel(
    doc_ids: jax.Array,
    contribs: jax.Array,
    tile_ranges: jax.Array,
    *,
    n_docs: int,
    n_live: int,
    k: int,
    block_d: int = 512,
    tile_p: int = 512,
    live: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused scatter → per-block top-k for one query. See module docstring.

    Args:
      doc_ids: i32[P], P % tile_p == 0, values in [0, n_docs).
      contribs: f32[P].
      tile_ranges: i32[P // tile_p, 2] per-tile [min_doc, max_doc+1) bounds.
      n_docs: accumulator length; must be % block_d == 0.
      n_live: real document count; ids >= n_live are masked to -inf.
      k: candidates kept per accumulator block; must be <= block_d.
      live: optional i32[n_docs] tombstone bitmap (nonzero = live), ANDed
        into the pad mask at selection time.

    Returns:
      (cand_scores f32[n_blocks, k], cand_ids i32[n_blocks, k]) — the only
      arrays that leave VMEM; the accumulator never reaches HBM.
    """
    P = doc_ids.shape[0]
    assert P % tile_p == 0, (P, tile_p)
    assert n_docs % block_d == 0, (n_docs, block_d)
    assert 0 < k <= block_d, (k, block_d)
    n_tiles = P // tile_p
    n_blocks = n_docs // block_d

    grid = (n_blocks, n_tiles)
    docs2d = doc_ids.reshape(n_tiles, tile_p)
    c2d = contribs.astype(jnp.float32).reshape(n_tiles, tile_p)

    in_specs = [
        pl.BlockSpec((1, 2), lambda d, t: (t, 0)),
        pl.BlockSpec((1, tile_p), lambda d, t: (t, 0)),
        pl.BlockSpec((1, tile_p), lambda d, t: (t, 0)),
    ]
    inputs = [tile_ranges, docs2d, c2d]
    if live is not None:
        assert live.shape == (n_docs,), (live.shape, n_docs)
        in_specs.append(pl.BlockSpec((1, block_d), lambda d, t: (d, 0)))
        inputs.append(live.astype(jnp.int32).reshape(n_blocks, block_d))

    out_s, out_i = pl.pallas_call(
        functools.partial(
            _scatter_topk_kernel, block_d=block_d, n_tiles=n_tiles,
            n_live=n_live, has_live=live is not None,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, k), lambda d, t: (d, 0)),
            pl.BlockSpec((1, k), lambda d, t: (d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, k), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, k), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(*inputs)
    return out_s, out_i


def impact_scatter_topk_batched_kernel(
    doc_ids: jax.Array,
    contribs: jax.Array,
    tile_ranges: jax.Array,
    *,
    n_docs: int,
    n_live: int,
    k: int,
    block_d: int = 512,
    tile_p: int = 512,
    live: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Batched fused scatter → per-block top-k: grid over (query, block, tile).

    Args:
      doc_ids: i32[B, P], P % tile_p == 0, values in [0, n_docs).
      contribs: f32[B, P].
      tile_ranges: i32[B, P // tile_p, 2] per-(query, tile) doc-id bounds.
      n_docs: accumulator length; must be % block_d == 0.
      n_live: real document count; ids >= n_live are masked to -inf.
      k: candidates kept per accumulator block; must be <= block_d.
      live: optional i32[n_docs] tombstone bitmap shared by the whole batch.

    Returns:
      (cand_scores f32[B, n_blocks, k], cand_ids i32[B, n_blocks, k]).
    """
    B, P = doc_ids.shape
    assert P % tile_p == 0, (P, tile_p)
    assert n_docs % block_d == 0, (n_docs, block_d)
    assert 0 < k <= block_d, (k, block_d)
    n_tiles = P // tile_p
    n_blocks = n_docs // block_d

    grid = (B, n_blocks, n_tiles)
    docs3d = doc_ids.reshape(B, n_tiles, tile_p)
    c3d = contribs.astype(jnp.float32).reshape(B, n_tiles, tile_p)

    in_specs = [
        pl.BlockSpec((1, 1, 2), lambda b, d, t: (b, t, 0)),
        pl.BlockSpec((1, 1, tile_p), lambda b, d, t: (b, t, 0)),
        pl.BlockSpec((1, 1, tile_p), lambda b, d, t: (b, t, 0)),
    ]
    inputs = [tile_ranges, docs3d, c3d]
    if live is not None:
        assert live.shape == (n_docs,), (live.shape, n_docs)
        in_specs.append(pl.BlockSpec((1, block_d), lambda b, d, t: (d, 0)))
        inputs.append(live.astype(jnp.int32).reshape(n_blocks, block_d))

    out_s, out_i = pl.pallas_call(
        functools.partial(
            _scatter_topk_kernel_batched, block_d=block_d, n_tiles=n_tiles,
            n_live=n_live, has_live=live is not None,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, k), lambda b, d, t: (b, d, 0)),
            pl.BlockSpec((1, 1, k), lambda b, d, t: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_blocks, k), jnp.float32),
            jax.ShapeDtypeStruct((B, n_blocks, k), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(*inputs)
    return out_s, out_i
