"""jit'd wrappers around the fused scatter→top-k Pallas kernel.

The full fused SAAT selection is kernel + merge: the kernel emits per-block
candidate pools ``[B, n_blocks * k]`` (the only arrays that touch HBM), the
merge pass (``repro.core.topk.tiled_topk`` over the pool) recovers the exact
global top-k. Results are bit-identical in doc ids — including ``-inf`` tie
order — to ``top_k`` over the dense ``impact_scatter`` accumulator, and
bit-identical in scores to the unfused Pallas scatter (same accumulation
order per block).

Like ``impact_scatter``'s wrappers: padding, the optional doc-sort feeding the
kernel's (block x tile) skip ranges, and interpret-mode selection are handled
here so one call site serves CPU tests and TPU deployments.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.kernel_contracts import KernelContract, ShapeCase
from repro.core.topk import tiled_topk
from repro.kernels.common import (
    interpret_default,
    pad_axis,
    round_up,
    sorted_posting_tiles,
)
from repro.kernels.impact_scatter_topk.kernel import (
    impact_scatter_topk_batched_kernel,
    impact_scatter_topk_kernel,
)


def _merge_pool(
    cand_s: jax.Array, cand_i: jax.Array, k_out: int
) -> tuple[jax.Array, jax.Array]:
    """Exact global top-k over per-block candidate pools ``[..., nb, kb]``.

    ``tiled_topk`` with one tile per block is rank-safe here by construction
    (each tile IS a block's full candidate set), and its flat positional ids
    map back through ``cand_i`` to document ids.
    """
    nb, kb = cand_s.shape[-2:]
    flat_s = cand_s.reshape(cand_s.shape[:-2] + (nb * kb,))
    flat_i = cand_i.reshape(cand_i.shape[:-2] + (nb * kb,))
    ms, mpos = tiled_topk(flat_s, k_out, num_tiles=nb)
    return ms, jnp.take_along_axis(flat_i, mpos, axis=-1)


@partial(
    jax.jit,
    static_argnames=("n_docs", "k", "n_live", "block_d", "tile_p", "sort_by_doc", "interpret"),
)
def impact_scatter_topk(
    doc_ids: jax.Array,
    contribs: jax.Array,
    n_docs: int,
    k: int,
    *,
    n_live: int | None = None,
    live: jax.Array | None = None,
    block_d: int = 512,
    tile_p: int = 512,
    sort_by_doc: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused top-k of the scatter accumulator for one query.

    Equivalent to ``top_k(mask(impact_scatter(doc_ids, contribs, n_docs)), k)``
    with ids >= ``n_live`` masked to ``-inf`` — but the dense accumulator
    never leaves VMEM. Returns ``(scores, ids)`` of width ``min(k, n_docs)``
    (the same clamp as ``repro.core.topk.topk``).

    ``live`` is the index lifecycle's tombstone bitmap (i32/bool, length
    <= the padded accumulator; nonzero = live), ANDed into the pad mask at
    in-kernel selection time so deleted docs score ``-inf``.
    """
    if interpret is None:
        interpret = interpret_default()
    if n_live is None:
        n_live = n_docs
    n_docs_pad = round_up(max(n_docs, block_d), block_d)
    k_out = min(k, n_docs)
    k_blk = min(k_out, block_d)  # a block holds at most block_d of the top-k
    docs, c, ranges, _ = sorted_posting_tiles(doc_ids, contribs, n_docs_pad, tile_p, sort_by_doc)
    if live is not None:
        live = pad_axis(live.astype(jnp.int32), 0, n_docs_pad)[:n_docs_pad]
    cand_s, cand_i = impact_scatter_topk_kernel(
        docs,
        c,
        ranges,
        n_docs=n_docs_pad,
        n_live=min(n_live, n_docs),
        k=k_blk,
        block_d=block_d,
        tile_p=tile_p,
        live=live,
        interpret=interpret,
    )
    return _merge_pool(cand_s, cand_i, k_out)


@partial(
    jax.jit,
    static_argnames=("n_docs", "k", "n_live", "block_d", "tile_p", "sort_by_doc", "interpret"),
)
def impact_scatter_topk_batched(
    doc_ids: jax.Array,
    contribs: jax.Array,
    n_docs: int,
    k: int,
    *,
    n_live: int | None = None,
    live: jax.Array | None = None,
    block_d: int = 512,
    tile_p: int = 512,
    sort_by_doc: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched fused top-k: the batched SAAT engine's ``fused_topk`` hot path.

    One kernel launch grids over (query, block, tile); per-query accumulator
    blocks live in VMEM scratch and only the ``[B, n_blocks * k]`` candidate
    pool reaches HBM. Returns ``([B, min(k, n_docs)]`` score/id pairs.
    ``live`` is the optional tombstone bitmap, shared by the whole batch.
    """
    if interpret is None:
        interpret = interpret_default()
    if n_live is None:
        n_live = n_docs
    n_docs_pad = round_up(max(n_docs, block_d), block_d)
    k_out = min(k, n_docs)
    k_blk = min(k_out, block_d)
    docs, c, ranges, _ = sorted_posting_tiles(doc_ids, contribs, n_docs_pad, tile_p, sort_by_doc)
    if live is not None:
        live = pad_axis(live.astype(jnp.int32), 0, n_docs_pad)[:n_docs_pad]
    cand_s, cand_i = impact_scatter_topk_batched_kernel(
        docs,
        c,
        ranges,
        n_docs=n_docs_pad,
        n_live=min(n_live, n_docs),
        k=k_blk,
        block_d=block_d,
        tile_p=tile_p,
        live=live,
        interpret=interpret,
    )
    return _merge_pool(cand_s, cand_i, k_out)


def _contract_call(dims):
    """Trace target for the static checker: abstract inputs, sweep tiling."""
    sds = jax.ShapeDtypeStruct
    kw = dict(
        n_docs=dims["n_docs"], k=dims["k"], block_d=dims["block_d"],
        tile_p=dims["tile_p"], sort_by_doc=True, interpret=True,
    )
    live_sds = sds((dims["n_docs"],), jnp.int32) if dims.get("live") else None
    if "batch" in dims:
        shape = (dims["batch"], dims["n_postings"])
        qargs = (sds(shape, jnp.int32), sds(shape, jnp.float32))
        if live_sds is not None:
            fn = lambda d, c, l: impact_scatter_topk_batched(d, c, live=l, **kw)
            return fn, qargs + (live_sds,)
        return partial(impact_scatter_topk_batched, **kw), qargs
    shape = (dims["n_postings"],)
    qargs = (sds(shape, jnp.int32), sds(shape, jnp.float32))
    if live_sds is not None:
        fn = lambda d, c, l: impact_scatter_topk(d, c, live=l, **kw)
        return fn, qargs + (live_sds,)
    return partial(impact_scatter_topk, **kw), qargs


# Single source of truth for the sweep shapes in tests/test_kernels.py and
# the checker's trace grid: k from 1 to beyond block_d, ragged doc counts,
# and the tombstone-bitmap (live-masked) variants of both layouts.
CONTRACT = KernelContract(
    name="impact_scatter_topk",
    description="fused scatter -> per-block top-k candidate pool (SAAT fused_topk)",
    make_call=_contract_call,
    shape_grid=(
        ShapeCase("k1", dict(n_postings=128, n_docs=512, k=1, block_d=256, tile_p=128)),
        ShapeCase("k10_ragged", dict(n_postings=1000, n_docs=1000, k=10, block_d=256, tile_p=128)),
        ShapeCase("k300", dict(n_postings=4096, n_docs=512, k=300, block_d=256, tile_p=128)),
        ShapeCase("live_ragged", dict(n_postings=1000, n_docs=1000, k=10, block_d=256, tile_p=128, live=1)),
        ShapeCase("b1", dict(batch=1, n_postings=1000, n_docs=700, k=13, block_d=256, tile_p=128)),
        ShapeCase("b3_ragged", dict(batch=3, n_postings=1000, n_docs=700, k=13, block_d=256, tile_p=128)),
        ShapeCase("b8", dict(batch=8, n_postings=1000, n_docs=700, k=13, block_d=256, tile_p=128)),
        ShapeCase("b3_live", dict(batch=3, n_postings=1000, n_docs=700, k=13, block_d=256, tile_p=128, live=1)),
    ),
)
