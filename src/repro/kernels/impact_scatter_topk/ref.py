"""Pure-jnp oracles for the fused scatter → top-k pipeline.

Two levels: the *block-candidate* refs mirror what the kernel emits (per-block
candidate pools), the *fused* refs mirror the whole pipeline (scatter + pad
mask + global top-k over the dense accumulator) — i.e. exactly what the
unfused SAAT engine computes, which is the golden parity target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.impact_scatter.ref import (
    impact_scatter_batched_ref,
    impact_scatter_ref,
)


def _mask_live(acc: jax.Array, n_live: int) -> jax.Array:
    live = jnp.arange(acc.shape[-1], dtype=jnp.int32) < n_live
    return jnp.where(live, acc, -jnp.inf)


def impact_scatter_topk_block_ref(
    doc_ids: jax.Array,
    contribs: jax.Array,
    n_docs: int,
    n_live: int,
    k: int,
    block_d: int,
) -> tuple[jax.Array, jax.Array]:
    """Per-block candidates the kernel should emit. n_docs % block_d == 0."""
    acc = _mask_live(impact_scatter_ref(doc_ids, contribs, n_docs), n_live)
    blocks = acc.reshape(n_docs // block_d, block_d)
    s, i = jax.lax.top_k(blocks, k)
    base = (jnp.arange(n_docs // block_d, dtype=jnp.int32) * block_d)[:, None]
    return s, i.astype(jnp.int32) + base


def impact_scatter_topk_block_batched_ref(
    doc_ids: jax.Array,
    contribs: jax.Array,
    n_docs: int,
    n_live: int,
    k: int,
    block_d: int,
) -> tuple[jax.Array, jax.Array]:
    """Batched per-block candidate oracle: [B, n_blocks, k] pairs."""
    B = doc_ids.shape[0]
    acc = _mask_live(impact_scatter_batched_ref(doc_ids, contribs, n_docs), n_live)
    blocks = acc.reshape(B, n_docs // block_d, block_d)
    s, i = jax.lax.top_k(blocks, k)
    base = (jnp.arange(n_docs // block_d, dtype=jnp.int32) * block_d)[None, :, None]
    return s, i.astype(jnp.int32) + base


def impact_scatter_topk_ref(
    doc_ids: jax.Array,
    contribs: jax.Array,
    n_docs: int,
    n_live: int,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """End-to-end oracle: dense scatter, pad mask, global top-k."""
    acc = _mask_live(impact_scatter_ref(doc_ids, contribs, n_docs), n_live)
    s, i = jax.lax.top_k(acc, min(k, n_docs))
    return s, i.astype(jnp.int32)


def impact_scatter_topk_batched_ref(
    doc_ids: jax.Array,
    contribs: jax.Array,
    n_docs: int,
    n_live: int,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Batched end-to-end oracle: [B, min(k, n_docs)] pairs."""
    acc = _mask_live(impact_scatter_batched_ref(doc_ids, contribs, n_docs), n_live)
    s, i = jax.lax.top_k(acc, min(k, n_docs))
    return s, i.astype(jnp.int32)
