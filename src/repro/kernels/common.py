"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU in interpret mode — ``interpret_default()`` picks the right
mode so tests/benchmarks run anywhere while the lowered TPU path stays intact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def interpret_default() -> bool:
    """Interpret kernels when not running on a real TPU."""
    return jax.default_backend() != "tpu"


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def pad_axis(x: jax.Array, axis: int, multiple: int, fill=0) -> jax.Array:
    """Pad one axis up to a multiple (TPU tile alignment)."""
    n = x.shape[axis]
    target = round_up(n, multiple)
    if target == n:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads, constant_values=fill)
