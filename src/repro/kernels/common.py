"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU in interpret mode — ``interpret_default()`` picks the right
mode so tests/benchmarks run anywhere while the lowered TPU path stays intact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def interpret_default() -> bool:
    """Interpret kernels when not running on a real TPU."""
    return jax.default_backend() != "tpu"


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def pad_axis(x: jax.Array, axis: int, multiple: int, fill=0) -> jax.Array:
    """Pad one axis up to a multiple (TPU tile alignment)."""
    n = x.shape[axis]
    target = round_up(n, multiple)
    if target == n:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads, constant_values=fill)


def sorted_posting_tiles(
    doc_ids: jax.Array,
    contribs: jax.Array,
    n_docs_pad: int,
    tile_p: int,
    sort_by_doc: bool,
) -> tuple[jax.Array, jax.Array, jax.Array, int]:
    """Shared preprocessing for the scatter-family kernels.

    Optional doc-sort (ONE multi-operand ``lax.sort`` — the same primitive for
    every wrapper, so the fused and unfused kernels see postings in the
    identical order and their f32 accumulation is bit-identical by
    construction, not by copy-paste), padding to the posting-tile multiple,
    and the per-tile [min_doc, max_doc+1) skip ranges. Handles both the
    single-query ``[P]`` and batched ``[B, P]`` layouts.

    Returns ``(docs, contribs, tile_ranges, n_tiles)``.
    """
    docs = doc_ids.astype(jnp.int32)
    c = contribs.astype(jnp.float32)
    if sort_by_doc:
        # docs key, contribs payload: one fused pass, no argsort + gathers
        docs, c = jax.lax.sort((docs, c), dimension=-1, num_keys=1)
    axis = docs.ndim - 1
    docs = pad_axis(docs, axis, tile_p, fill=0)
    c = pad_axis(c, axis, tile_p, fill=0.0)
    n_tiles = docs.shape[axis] // tile_p
    tiles = docs.reshape(docs.shape[:-1] + (n_tiles, tile_p))
    if sort_by_doc:
        ranges = jnp.stack([tiles.min(axis=-1), tiles.max(axis=-1) + 1], axis=-1)
    else:
        lo = jnp.zeros(tiles.shape[:-1], jnp.int32)
        ranges = jnp.stack([lo, jnp.full_like(lo, n_docs_pad)], axis=-1)
    return docs, c, ranges.astype(jnp.int32), n_tiles
