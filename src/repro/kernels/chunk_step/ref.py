"""Pure-jnp oracle for the fused chunk-step kernel.

This is the batched DAAT engine's phase-2 while-body, verbatim: the exact
selection (``lax.top_k`` over the masked ub row), the exact ``score_blocks``
gather-reduce through a dense query vector, and the exact ``merge_topk``
pool+candidates concatenation. The fused kernel must be indistinguishable
from this function in doc ids, theta, and the processed bitmap (bitwise),
and in scores to f32 reassociation — which is exactly the engine-level
``fused_chunk`` parity contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunk_step_batched_ref(
    doc_terms: jax.Array,  # i32[n_docs_pad, Tmax] (pad slot term = n_terms)
    doc_weights: jax.Array,  # f32[n_docs_pad, Tmax]
    q_terms: jax.Array,  # i32[B, Lq]
    q_weights: jax.Array,  # f32[B, Lq] (slots with weight <= 0 are padding)
    ub: jax.Array,  # f32[B, n_blocks]
    processed: jax.Array,  # bool[B, n_blocks]
    pool_s: jax.Array,  # f32[B, k]
    pool_i: jax.Array,  # i32[B, k]
    theta: jax.Array,  # f32[B]
    *,
    block_budget: int,
    block_size: int,
    n_live: int,
    n_terms: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One jnp chunk step; returns ``(pool_s, pool_i, theta, processed)``."""
    B = q_terms.shape[0]
    k = pool_s.shape[-1]
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]

    # dense query vectors over V+1 slots — repro.core.daat.query_vectors
    safe = jnp.where(q_weights > 0, q_terms, n_terms)
    qvec = jnp.zeros((B, n_terms + 1), jnp.float32)
    qvec = qvec.at[rows, safe].add(q_weights.astype(jnp.float32))
    qvec = qvec.at[:, n_terms].set(0.0)

    rub = jnp.where(processed, -jnp.inf, ub)
    ub_c, b_c = jax.lax.top_k(rub, block_budget)  # [B, budget]
    live = ub_c > theta[:, None]

    # score_blocks: gather the doc-major rows, reduce against qvec
    docs = b_c[..., :, None] * block_size + jnp.arange(block_size, dtype=jnp.int32)
    terms = doc_terms[docs]  # [B, budget, bs, Tmax]
    w = doc_weights[docs]
    qv = qvec[rows[..., None, None], terms]
    s_c = jnp.sum(qv * w, axis=-1)
    s_c = jnp.where(docs < n_live, s_c, -jnp.inf)
    s_c = jnp.where(live[..., None], s_c, -jnp.inf)

    # merge_topk: pool first, candidates after — the tie order the kernel keeps
    all_s = jnp.concatenate([pool_s, s_c.reshape(B, -1)], axis=-1)
    all_i = jnp.concatenate([pool_i, docs.reshape(B, -1).astype(jnp.int32)], axis=-1)
    ms, mpos = jax.lax.top_k(all_s, k)
    new_i = jnp.take_along_axis(all_i, mpos, axis=-1)
    new_theta = ms[:, k - 1]
    new_processed = processed.at[rows, b_c].set(processed[rows, b_c] | live)
    return ms, new_i, new_theta, new_processed


def chunk_step_multi_batched_ref(
    doc_terms: jax.Array,
    doc_weights: jax.Array,
    q_terms: jax.Array,
    q_weights: jax.Array,
    ub: jax.Array,
    processed: jax.Array,
    pool_s: jax.Array,
    pool_i: jax.Array,
    theta: jax.Array,
    trips_left: jax.Array,  # i32[B] per-row trip budget
    *,
    trips_per_launch: int,
    block_budget: int,
    block_size: int,
    n_live: int,
    n_terms: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Multi-trip oracle: ``trips_per_launch`` sequential single-trip steps.

    Per-row gating mirrors the engine's while-loop semantics exactly — a row
    advances on trip ``t`` iff ``t < trips_left[row]`` AND it is not yet
    rank-safe; frozen rows keep their state bit-for-bit. Returns the final
    state plus ``trips_done[B]``, the per-row count of trips that advanced.
    """
    trips_done = jnp.zeros(trips_left.shape, jnp.int32)
    for t in range(trips_per_launch):
        rub = jnp.where(processed, -jnp.inf, ub)
        act = (t < trips_left) & (jnp.max(rub, axis=-1) > theta)
        ns, ni, nth, npr = chunk_step_batched_ref(
            doc_terms, doc_weights, q_terms, q_weights,
            ub, processed, pool_s, pool_i, theta,
            block_budget=block_budget, block_size=block_size,
            n_live=n_live, n_terms=n_terms,
        )
        pool_s = jnp.where(act[:, None], ns, pool_s)
        pool_i = jnp.where(act[:, None], ni, pool_i)
        theta = jnp.where(act, nth, theta)
        processed = jnp.where(act[:, None], npr, processed)
        trips_done = trips_done + act.astype(jnp.int32)
    return pool_s, pool_i, theta, processed, trips_done
