"""Fused DAAT phase-2 chunk step: select + score + merge in one VMEM pass."""
from repro.kernels.chunk_step.ops import chunk_step_batched  # noqa: F401
from repro.kernels.chunk_step.ref import chunk_step_batched_ref  # noqa: F401
