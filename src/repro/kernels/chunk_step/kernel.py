"""Pallas TPU kernel: fused DAAT phase-2 chunk step (select+score+merge).

One while_loop trip of the batched Block-Max engine used to be THREE kernel
launches (``block_topk_batched`` selection, ``sparse_score_batched`` scoring,
then the jnp ``merge_topk``), with the ``[B, budget, bs]`` score tensor and
the remaining-ub selection finalists round-tripping HBM between them — traffic
a skipping-hostile (wacky-weight) workload multiplies by its trip count. This
kernel fuses the whole chunk step into one batch-gridded pass:

  * remaining-ub top-``budget`` block selection (``lax.top_k`` over the
    per-query ub row, processed blocks masked to ``-inf``);
  * live gating (``ub_c > theta`` — only these can change the top-k);
  * sparse scoring of the selected doc blocks (the ``sparse_score``
    match-and-accumulate contraction, vocabulary-free);
  * candidate merge into the per-query top-k pool + the new threshold.

Chunk state — the pool scores/ids, theta, the candidate tile, and the
processed-bitmap row — lives in VMEM for the whole doc-block revisiting loop;
only the updated state (pool, theta, processed) is written back. The selected
blocks' doc-major rows are pulled from the HBM-resident store with
double-buffered ``make_async_copy`` DMAs: while block ``j`` is being scored,
block ``j+1``'s ``[bs, Tmax]`` term/weight rows are already in flight, so the
gather latency hides behind the one-hot contraction.

Parity contract (the engine's ``fused_chunk`` flag relies on it): the kernel
evaluates the numerically identical expressions, in the same order, as the
jnp while-body in ``repro.core.daat`` — selection tie order is ``lax.top_k``'s
(ties resolve to the lowest block id; the ``-inf`` pad lanes the ops wrapper
appends sit at the highest ids, so they never displace a real block while
``budget <= n_blocks``), the merge concatenates pool-then-candidates exactly
like ``merge_topk``, and non-live / padded-doc candidates mask to ``-inf``
before the merge. Doc ids, theta, and the processed bitmap are bit-identical
to the jnp body; scores agree to f32 reassociation.

Multi-trip launch (``_chunk_step_multi_kernel_batched``)
--------------------------------------------------------
The per-trip kernel above still exits to XLA on EVERY while_loop trip, so a
skipping-collapsed (wacky-weight) query pays one launch plus a pool/theta/
processed HBM round-trip per trip — multiplied by exactly the trip counts the
paper shows explode. The multi-trip variant runs up to ``trips`` trip bodies
inside ONE launch: the per-query state initializes the output blocks once,
revolves in VMEM across trips, and crosses HBM once per *launch*. A
scalar-prefetched per-row trip budget (``PrefetchScalarGridSpec``; the engine
passes ``min(max_chunks - chunks, trips_per_launch)``, 0 for inactive rows)
plus the in-kernel early exit — each trip body runs under
``pl.when(t < budget AND max remaining ub > theta)``, so a row that goes
rank-safe mid-launch skips the remaining trips' DMAs and compute entirely.
Because each row's trip sequence never depends on other rows, running T trip
bodies in-kernel is bit-identical to T per-trip launches; the extra
``trips_done`` output row lets the engine advance its per-query chunk counts
without re-deriving them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _trip_body(
    ub,  # f32[NBp] (value, not ref — constant across trips)
    proc,  # i32[NBp] current processed row (1 = processed / pad)
    theta,  # f32[] current threshold
    pool_s,  # f32[k] current pool scores
    pool_i,  # i32[k] current pool ids
    qt,  # i32[Lq]
    qw,  # f32[Lq]
    dt_hbm,
    dw_hbm,
    lv_hbm,  # i32[nb, bs] tombstone bitmap rows in HBM, or None
    out_s_ref,
    out_i_ref,
    out_theta_ref,
    out_proc_ref,
    dt_buf,
    dw_buf,
    lv_buf,  # VMEM (2, 1, bs) live-row double buffer, or None
    cand_ref,
    sems,
    *,
    budget: int,
    bs: int,
    n_live: int,
):
    """ONE select+score+merge trip; writes the new state into the out refs.

    Shared verbatim between the per-trip and multi-trip kernels so the parity
    contract (bit-identical ids/theta/processed vs the jnp while-body) is
    maintained in exactly one place.

    When ``lv_hbm`` is present, each selected block's ``[bs]`` tombstone row
    rides the same double-buffered DMA pipeline as its doc-major rows (third
    semaphore lane) and masks dead docs' scores to ``-inf`` before the merge
    — the in-kernel image of the jnp body's ``live_mask`` gather.
    """
    # ---- select: remaining-ub top-budget, entirely from the VMEM ub row ----
    rub = jnp.where(proc != 0, -jnp.inf, ub)
    ub_c, b_c = jax.lax.top_k(rub, budget)  # [budget], ties -> lowest block id
    live = ub_c > theta  # only these can change the top-k

    # ---- score: doc-block revisiting loop, double-buffered HBM prefetch ----
    def doc_dma(slot, j):
        row0 = b_c[j] * bs
        copies = (
            pltpu.make_async_copy(
                dt_hbm.at[pl.ds(row0, bs), :], dt_buf.at[slot], sems.at[slot, 0]
            ),
            pltpu.make_async_copy(
                dw_hbm.at[pl.ds(row0, bs), :], dw_buf.at[slot], sems.at[slot, 1]
            ),
        )
        if lv_hbm is not None:
            copies += (
                pltpu.make_async_copy(
                    lv_hbm.at[pl.ds(b_c[j], 1), :], lv_buf.at[slot], sems.at[slot, 2]
                ),
            )
        return copies

    for c in doc_dma(0, 0):  # warm up the pipeline
        c.start()
    for j in range(budget):
        slot = j % 2
        if j + 1 < budget:  # prefetch the next block while scoring this one
            for c in doc_dma((j + 1) % 2, j + 1):
                c.start()
        for c in doc_dma(slot, j):
            c.wait()
        terms = dt_buf[slot]  # i32[bs, Tmax]
        w = dw_buf[slot].astype(jnp.float32)
        tmax = terms.shape[-1]
        # the sparse_score contraction: term match -> one-hot -> MXU
        onehot = (terms.reshape(bs * tmax, 1) == qt[None, :]).astype(jnp.float32)
        qv = jnp.dot(onehot, qw[:, None], preferred_element_type=jnp.float32)
        s = jnp.sum(qv.reshape(bs, tmax) * w, axis=-1)  # f32[bs]
        docs = b_c[j] * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
        s = jnp.where(docs < n_live, s, -jnp.inf)  # padded docs never rank
        if lv_hbm is not None:
            s = jnp.where(lv_buf[slot][0] != 0, s, -jnp.inf)  # tombstoned docs
        s = jnp.where(live[j], s, -jnp.inf)  # dead blocks contribute nothing
        cand_ref[j, :] = s

    # ---- merge: pool + candidates -> new pool/theta (merge_topk order) ----
    k = pool_s.shape[0]
    d_flat = (
        b_c[:, None] * bs + jax.lax.broadcasted_iota(jnp.int32, (budget, bs), 1)
    ).reshape(-1)
    all_s = jnp.concatenate([pool_s, cand_ref[...].reshape(-1)])
    all_i = jnp.concatenate([pool_i, d_flat.astype(jnp.int32)])
    ms, mpos = jax.lax.top_k(all_s, k)
    out_s_ref[0, :] = ms
    out_i_ref[0, :] = jnp.take(all_i, mpos)
    out_theta_ref[0, 0] = ms[k - 1]

    # ---- processed |= live-selected blocks (top_k ids are distinct) ----
    nbp = proc.shape[0]
    hit = (jax.lax.broadcasted_iota(jnp.int32, (budget, nbp), 1) == b_c[:, None]) & live[
        :, None
    ]
    out_proc_ref[0, :] = jnp.maximum(proc, jnp.any(hit, axis=0).astype(proc.dtype))


def _chunk_step_kernel_batched(
    ub_ref,
    proc_ref,
    pool_s_ref,
    pool_i_ref,
    theta_ref,
    qt_ref,
    qw_ref,
    dt_hbm,
    dw_hbm,
    *rest,
    budget: int,
    bs: int,
    n_live: int,
    has_live: bool = False,
):
    if has_live:
        (lv_hbm, out_s_ref, out_i_ref, out_theta_ref, out_proc_ref,
         dt_buf, dw_buf, cand_ref, lv_buf, sems) = rest
    else:
        (out_s_ref, out_i_ref, out_theta_ref, out_proc_ref,
         dt_buf, dw_buf, cand_ref, sems) = rest
        lv_hbm = lv_buf = None
    _trip_body(
        ub_ref[0, :],
        proc_ref[0, :],
        theta_ref[0, 0],
        pool_s_ref[0, :],
        pool_i_ref[0, :],
        qt_ref[0, :],
        qw_ref[0, :].astype(jnp.float32),
        dt_hbm,
        dw_hbm,
        lv_hbm,
        out_s_ref,
        out_i_ref,
        out_theta_ref,
        out_proc_ref,
        dt_buf,
        dw_buf,
        lv_buf,
        cand_ref,
        sems,
        budget=budget,
        bs=bs,
        n_live=n_live,
    )


def _chunk_step_multi_kernel_batched(
    trips_ref,  # SMEM i32[B] — scalar-prefetched per-row trip budget
    ub_ref,
    proc_ref,
    pool_s_ref,
    pool_i_ref,
    theta_ref,
    qt_ref,
    qw_ref,
    dt_hbm,
    dw_hbm,
    *rest,
    trips: int,
    budget: int,
    bs: int,
    n_live: int,
    has_live: bool = False,
):
    """Up to ``trips`` trip bodies in ONE launch; state revolves in VMEM.

    The per-query state (pool, theta, processed) initializes the output
    blocks once and every trip reads/writes them in place — the output tile
    is VMEM-resident for the whole grid cell, so nothing crosses HBM between
    trips. Each trip runs under ``pl.when``: a row past its scalar-prefetched
    budget, or already rank-safe (``max remaining ub <= theta``), skips the
    trip's DMAs and compute entirely — the in-kernel early exit.
    """
    if has_live:
        (lv_hbm, out_s_ref, out_i_ref, out_theta_ref, out_proc_ref,
         out_trips_ref, dt_buf, dw_buf, cand_ref, lv_buf, sems) = rest
    else:
        (out_s_ref, out_i_ref, out_theta_ref, out_proc_ref,
         out_trips_ref, dt_buf, dw_buf, cand_ref, sems) = rest
        lv_hbm = lv_buf = None
    b = pl.program_id(0)
    out_s_ref[...] = pool_s_ref[...]
    out_i_ref[...] = pool_i_ref[...]
    out_theta_ref[...] = theta_ref[...]
    out_proc_ref[...] = proc_ref[...]
    out_trips_ref[0, 0] = 0

    ub = ub_ref[0, :]
    qt = qt_ref[0, :]
    qw = qw_ref[0, :].astype(jnp.float32)

    for t in range(trips):
        proc = out_proc_ref[0, :]
        theta = out_theta_ref[0, 0]
        more = jnp.max(jnp.where(proc != 0, -jnp.inf, ub)) > theta
        active = (t < trips_ref[b]) & more

        @pl.when(active)
        def _one_trip(proc=proc, theta=theta):
            _trip_body(
                ub,
                proc,
                theta,
                out_s_ref[0, :],
                out_i_ref[0, :],
                qt,
                qw,
                dt_hbm,
                dw_hbm,
                lv_hbm,
                out_s_ref,
                out_i_ref,
                out_theta_ref,
                out_proc_ref,
                dt_buf,
                dw_buf,
                lv_buf,
                cand_ref,
                sems,
                budget=budget,
                bs=bs,
                n_live=n_live,
            )
            out_trips_ref[0, 0] = out_trips_ref[0, 0] + 1


def chunk_step_batched_kernel(
    ub: jax.Array,  # f32[B, NBp] (pad lanes = -inf)
    processed: jax.Array,  # i32[B, NBp] (pad lanes = 1)
    pool_s: jax.Array,  # f32[B, k]
    pool_i: jax.Array,  # i32[B, k]
    theta: jax.Array,  # f32[B, 1]
    q_terms: jax.Array,  # i32[B, Lq]
    q_weights: jax.Array,  # f32[B, Lq] (pad slots already zeroed)
    doc_terms: jax.Array,  # i32[n_docs_pad, Tmax] — stays in HBM, DMA'd
    doc_weights: jax.Array,  # f32[n_docs_pad, Tmax] — stays in HBM, DMA'd
    *,
    budget: int,
    bs: int,
    n_live: int,
    live: jax.Array | None = None,  # i32[nb, bs] tombstone rows — HBM, DMA'd
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One fused phase-2 chunk step for a whole query batch: grid over B.

    Returns ``(pool_s, pool_i, theta, processed)`` — the only arrays that
    cross the HBM boundary per trip. The ``[B, budget, bs]`` candidate score
    tensor and the selection finalists never leave VMEM. ``live`` (optional)
    is the lifecycle tombstone bitmap reshaped to block rows; like the doc
    stores it stays in HBM and only the selected blocks' rows are DMA'd.
    """
    B, nbp = ub.shape
    k = pool_s.shape[1]
    lq = q_terms.shape[1]
    tmax = doc_terms.shape[1]

    row = lambda b: (b, 0)  # noqa: E731 — one query row per grid cell
    in_specs = [
        pl.BlockSpec((1, nbp), row),
        pl.BlockSpec((1, nbp), row),
        pl.BlockSpec((1, k), row),
        pl.BlockSpec((1, k), row),
        pl.BlockSpec((1, 1), row),
        pl.BlockSpec((1, lq), row),
        pl.BlockSpec((1, lq), row),
        pl.BlockSpec(memory_space=pltpu.ANY),  # doc-major store: DMA only
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    scratch = [
        pltpu.VMEM((2, bs, tmax), jnp.int32),  # double-buffered doc terms
        pltpu.VMEM((2, bs, tmax), jnp.float32),  # double-buffered doc weights
        pltpu.VMEM((budget, bs), jnp.float32),  # candidate score tile
        pltpu.SemaphoreType.DMA((2, 2)),  # (slot, terms/weights)
    ]
    args = [ub, processed, pool_s, pool_i, theta, q_terms, q_weights,
            doc_terms, doc_weights]
    if live is not None:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))  # live rows: DMA only
        args.append(live.astype(jnp.int32))
        scratch.insert(3, pltpu.VMEM((2, 1, bs), jnp.int32))  # live-row buffer
        scratch[-1] = pltpu.SemaphoreType.DMA((2, 3))  # (slot, terms/weights/live)
    out = pl.pallas_call(
        functools.partial(
            _chunk_step_kernel_batched, budget=budget, bs=bs, n_live=n_live,
            has_live=live is not None,
        ),
        grid=(B,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, k), row),
            pl.BlockSpec((1, k), row),
            pl.BlockSpec((1, 1), row),
            pl.BlockSpec((1, nbp), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, nbp), jnp.int32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(*args)
    return out[0], out[1], out[2], out[3]


def chunk_step_multi_batched_kernel(
    ub: jax.Array,  # f32[B, NBp] (pad lanes = -inf)
    processed: jax.Array,  # i32[B, NBp] (pad lanes = 1)
    pool_s: jax.Array,  # f32[B, k]
    pool_i: jax.Array,  # i32[B, k]
    theta: jax.Array,  # f32[B, 1]
    q_terms: jax.Array,  # i32[B, Lq]
    q_weights: jax.Array,  # f32[B, Lq] (pad slots already zeroed)
    doc_terms: jax.Array,  # i32[n_docs_pad, Tmax] — stays in HBM, DMA'd
    doc_weights: jax.Array,  # f32[n_docs_pad, Tmax] — stays in HBM, DMA'd
    trips_left: jax.Array,  # i32[B] — per-row trip budget (scalar-prefetched)
    *,
    trips: int,
    budget: int,
    bs: int,
    n_live: int,
    live: jax.Array | None = None,  # i32[nb, bs] tombstone rows — HBM, DMA'd
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Up to ``trips`` fused chunk steps per query in ONE launch: grid over B.

    Returns ``(pool_s, pool_i, theta, processed, trips_done)`` — the state
    crosses the HBM boundary once per launch instead of once per trip;
    ``trips_done[b]`` counts how many trip bodies actually ran for row ``b``
    (the in-kernel early exit stops short of the budget once rank-safe).
    """
    B, nbp = ub.shape
    k = pool_s.shape[1]
    lq = q_terms.shape[1]
    tmax = doc_terms.shape[1]

    row = lambda b, *_: (b, 0)  # noqa: E731 — scalar refs trail the index args
    in_specs = [
        pl.BlockSpec((1, nbp), row),
        pl.BlockSpec((1, nbp), row),
        pl.BlockSpec((1, k), row),
        pl.BlockSpec((1, k), row),
        pl.BlockSpec((1, 1), row),
        pl.BlockSpec((1, lq), row),
        pl.BlockSpec((1, lq), row),
        pl.BlockSpec(memory_space=pltpu.ANY),  # doc-major store: DMA only
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    scratch = [
        pltpu.VMEM((2, bs, tmax), jnp.int32),  # double-buffered doc terms
        pltpu.VMEM((2, bs, tmax), jnp.float32),  # double-buffered doc weights
        pltpu.VMEM((budget, bs), jnp.float32),  # candidate score tile
        pltpu.SemaphoreType.DMA((2, 2)),  # (slot, terms/weights)
    ]
    args = [trips_left, ub, processed, pool_s, pool_i, theta, q_terms,
            q_weights, doc_terms, doc_weights]
    if live is not None:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))  # live rows: DMA only
        args.append(live.astype(jnp.int32))
        scratch.insert(3, pltpu.VMEM((2, 1, bs), jnp.int32))  # live-row buffer
        scratch[-1] = pltpu.SemaphoreType.DMA((2, 3))  # (slot, terms/weights/live)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, k), row),
            pl.BlockSpec((1, k), row),
            pl.BlockSpec((1, 1), row),
            pl.BlockSpec((1, nbp), row),
            pl.BlockSpec((1, 1), row),
        ],
        scratch_shapes=scratch,
    )
    out = pl.pallas_call(
        functools.partial(
            _chunk_step_multi_kernel_batched,
            trips=trips, budget=budget, bs=bs, n_live=n_live,
            has_live=live is not None,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, nbp), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*args)
    return out[0], out[1], out[2], out[3], out[4]
