"""jit'd wrapper around the fused DAAT chunk-step Pallas kernel.

Handles the engine <-> kernel interface impedance: the processed set is a
bool bitmap on the engine side but an i32 row inside the kernel (Mosaic has
no bool VMEM tiles), the block axis is padded to the 128-lane multiple
(pad lanes carry ``ub = -inf`` / ``processed = 1`` so they can never be
selected ahead of a real block — ``lax.top_k`` breaks ``-inf`` ties toward
the lowest id, and every real block id sorts before every pad id), and
interpret-mode selection mirrors the other kernel packages so one call site
serves CPU tests and TPU deployments.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.chunk_step.kernel import chunk_step_batched_kernel
from repro.kernels.common import interpret_default, pad_axis


@partial(
    jax.jit,
    static_argnames=("block_budget", "block_size", "n_live", "interpret"),
)
def chunk_step_batched(
    doc_terms: jax.Array,
    doc_weights: jax.Array,
    q_terms: jax.Array,
    q_weights: jax.Array,
    ub: jax.Array,
    processed: jax.Array,
    pool_s: jax.Array,
    pool_i: jax.Array,
    theta: jax.Array,
    *,
    block_budget: int,
    block_size: int,
    n_live: int,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One fused phase-2 chunk step over the whole ``[B, ...]`` state.

    Args mirror the engine's while-loop state plus the phase-0 products:
      doc_terms/doc_weights: the HBM doc-major store ``[n_docs_pad, Tmax]``.
      q_terms/q_weights: ``[B, Lq]``; weight-``<=0`` slots must be zeroed.
      ub: ``f32[B, n_blocks]`` additive block upper bounds (phase 0).
      processed: ``bool[B, n_blocks]`` blocks already scored.
      pool_s/pool_i: the current top-k pool ``[B, k]``.
      theta: ``f32[B]`` current thresholds.

    Returns ``(pool_s, pool_i, theta, processed)`` with identical shapes and
    dtypes to the inputs — a drop-in replacement for the jnp while-body's
    select+score+merge (see :mod:`repro.kernels.chunk_step.ref`).
    """
    if interpret is None:
        interpret = interpret_default()
    B, nb = ub.shape
    if block_budget > nb:
        raise ValueError(
            f"block_budget={block_budget} exceeds n_blocks={nb}; the engine "
            "clamps budgets before the loop"
        )
    ubp = pad_axis(ub.astype(jnp.float32), 1, 128, fill=-jnp.inf)
    procp = pad_axis(processed.astype(jnp.int32), 1, 128, fill=1)
    ps, pi, th, pr = chunk_step_batched_kernel(
        ubp,
        procp,
        pool_s.astype(jnp.float32),
        pool_i.astype(jnp.int32),
        theta.astype(jnp.float32).reshape(B, 1),
        q_terms.astype(jnp.int32),
        q_weights.astype(jnp.float32),
        doc_terms,
        doc_weights,
        budget=block_budget,
        bs=block_size,
        n_live=n_live,
        interpret=interpret,
    )
    return ps, pi, th[:, 0], pr[:, :nb].astype(jnp.bool_)
