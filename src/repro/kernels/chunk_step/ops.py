"""jit'd wrapper around the fused DAAT chunk-step Pallas kernel.

Handles the engine <-> kernel interface impedance: the processed set is a
bool bitmap on the engine side but an i32 row inside the kernel (Mosaic has
no bool VMEM tiles), the block axis is padded to the 128-lane multiple
(pad lanes carry ``ub = -inf`` / ``processed = 1`` so they can never be
selected ahead of a real block — ``lax.top_k`` breaks ``-inf`` ties toward
the lowest id, and every real block id sorts before every pad id), and
interpret-mode selection mirrors the other kernel packages so one call site
serves CPU tests and TPU deployments.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.kernel_contracts import KernelContract, ShapeCase
from repro.kernels.chunk_step.kernel import (
    chunk_step_batched_kernel,
    chunk_step_multi_batched_kernel,
)
from repro.kernels.common import interpret_default, pad_axis


@partial(
    jax.jit,
    static_argnames=("block_budget", "block_size", "n_live", "interpret"),
)
def chunk_step_batched(
    doc_terms: jax.Array,
    doc_weights: jax.Array,
    q_terms: jax.Array,
    q_weights: jax.Array,
    ub: jax.Array,
    processed: jax.Array,
    pool_s: jax.Array,
    pool_i: jax.Array,
    theta: jax.Array,
    *,
    block_budget: int,
    block_size: int,
    n_live: int,
    live: jax.Array | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One fused phase-2 chunk step over the whole ``[B, ...]`` state.

    Args mirror the engine's while-loop state plus the phase-0 products:
      doc_terms/doc_weights: the HBM doc-major store ``[n_docs_pad, Tmax]``.
      q_terms/q_weights: ``[B, Lq]``; weight-``<=0`` slots must be zeroed.
      ub: ``f32[B, n_blocks]`` additive block upper bounds (phase 0).
      processed: ``bool[B, n_blocks]`` blocks already scored.
      pool_s/pool_i: the current top-k pool ``[B, k]``.
      theta: ``f32[B]`` current thresholds.
      live: optional i32/bool ``[n_docs_pad]`` lifecycle tombstone bitmap
        (nonzero = live), reshaped to block rows and DMA'd per selected block.

    Returns ``(pool_s, pool_i, theta, processed)`` with identical shapes and
    dtypes to the inputs — a drop-in replacement for the jnp while-body's
    select+score+merge (see :mod:`repro.kernels.chunk_step.ref`).
    """
    if interpret is None:
        interpret = interpret_default()
    B, nb = ub.shape
    if block_budget > nb:
        raise ValueError(
            f"block_budget={block_budget} exceeds n_blocks={nb}; the engine "
            "clamps budgets before the loop"
        )
    ubp = pad_axis(ub.astype(jnp.float32), 1, 128, fill=-jnp.inf)
    procp = pad_axis(processed.astype(jnp.int32), 1, 128, fill=1)
    if live is not None:
        live = live.astype(jnp.int32)[: nb * block_size].reshape(nb, block_size)
    ps, pi, th, pr = chunk_step_batched_kernel(
        ubp,
        procp,
        pool_s.astype(jnp.float32),
        pool_i.astype(jnp.int32),
        theta.astype(jnp.float32).reshape(B, 1),
        q_terms.astype(jnp.int32),
        q_weights.astype(jnp.float32),
        doc_terms,
        doc_weights,
        budget=block_budget,
        bs=block_size,
        n_live=n_live,
        live=live,
        interpret=interpret,
    )
    return ps, pi, th[:, 0], pr[:, :nb].astype(jnp.bool_)


@partial(
    jax.jit,
    static_argnames=(
        "trips_per_launch", "block_budget", "block_size", "n_live", "interpret",
    ),
)
def chunk_step_multi_batched(
    doc_terms: jax.Array,
    doc_weights: jax.Array,
    q_terms: jax.Array,
    q_weights: jax.Array,
    ub: jax.Array,
    processed: jax.Array,
    pool_s: jax.Array,
    pool_i: jax.Array,
    theta: jax.Array,
    trips_left: jax.Array,
    *,
    trips_per_launch: int,
    block_budget: int,
    block_size: int,
    n_live: int,
    live: jax.Array | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Up to ``trips_per_launch`` fused chunk steps in ONE kernel launch.

    Same engine-state interface as :func:`chunk_step_batched` plus
    ``trips_left: i32[B]`` — the per-row trip budget the kernel receives via
    scalar prefetch (the engine passes ``min(max_chunks - chunks,
    trips_per_launch)``; 0 freezes a row). Returns ``(pool_s, pool_i, theta,
    processed, trips_done)``: the state after up to ``trips_per_launch``
    sequential trips (the in-kernel early exit stops a row once rank-safe)
    and the per-row count of trips that actually advanced.
    """
    if interpret is None:
        interpret = interpret_default()
    if trips_per_launch < 1:
        raise ValueError(f"trips_per_launch={trips_per_launch} must be >= 1")
    B, nb = ub.shape
    if block_budget > nb:
        raise ValueError(
            f"block_budget={block_budget} exceeds n_blocks={nb}; the engine "
            "clamps budgets before the loop"
        )
    ubp = pad_axis(ub.astype(jnp.float32), 1, 128, fill=-jnp.inf)
    procp = pad_axis(processed.astype(jnp.int32), 1, 128, fill=1)
    if live is not None:
        live = live.astype(jnp.int32)[: nb * block_size].reshape(nb, block_size)
    ps, pi, th, pr, td = chunk_step_multi_batched_kernel(
        ubp,
        procp,
        pool_s.astype(jnp.float32),
        pool_i.astype(jnp.int32),
        theta.astype(jnp.float32).reshape(B, 1),
        q_terms.astype(jnp.int32),
        q_weights.astype(jnp.float32),
        doc_terms,
        doc_weights,
        trips_left.astype(jnp.int32),
        trips=trips_per_launch,
        budget=block_budget,
        bs=block_size,
        n_live=n_live,
        live=live,
        interpret=interpret,
    )
    return ps, pi, th[:, 0], pr[:, :nb].astype(jnp.bool_), td[:, 0]


def _contract_call(dims):
    """Trace target for the static checker: abstract engine-state inputs.

    Cases with a ``trips`` dim trace the multi-trip (scalar-prefetched)
    dispatch; the rest trace the per-trip kernel.
    """
    sds = jax.ShapeDtypeStruct
    B, k, lq = dims["B"], dims["k"], dims["lq"]
    bs, tmax = dims["block_size"], dims["tmax"]
    nb = -(-dims["n_docs"] // bs)
    ndp = nb * bs
    state = (
        sds((ndp, tmax), jnp.int32), sds((ndp, tmax), jnp.float32),  # doc store
        sds((B, lq), jnp.int32), sds((B, lq), jnp.float32),  # queries
        sds((B, nb), jnp.float32), sds((B, nb), jnp.bool_),  # ub / processed
        sds((B, k), jnp.float32), sds((B, k), jnp.int32),  # pool
        sds((B,), jnp.float32),  # theta
    )
    live_sds = sds((ndp,), jnp.int32) if dims.get("live") else None
    if "trips" in dims:
        kw = dict(
            trips_per_launch=dims["trips"], block_budget=dims["budget"],
            block_size=bs, n_live=dims["n_docs"], interpret=True,
        )
        state = state + (sds((B,), jnp.int32),)  # + trips_left
        if live_sds is not None:
            fn = lambda *a: chunk_step_multi_batched(*a[:-1], live=a[-1], **kw)
            return fn, state + (live_sds,)
        return partial(chunk_step_multi_batched, **kw), state
    kw = dict(
        block_budget=dims["budget"], block_size=bs, n_live=dims["n_docs"],
        interpret=True,
    )
    if live_sds is not None:
        fn = lambda *a: chunk_step_batched(*a[:-1], live=a[-1], **kw)
        return fn, state + (live_sds,)
    return partial(chunk_step_batched, **kw), state


# Single source of truth for the sweep shapes in tests/test_chunk_step.py and
# the checker's trace grid. expect_dma: the doc-major store MUST be pulled
# with double-buffered make_async_copy DMAs, and the checker's happens-before
# pass verifies every start is waited before its slot is read or reused —
# the race class this kernel's revolving buffers can hide.
CONTRACT = KernelContract(
    name="chunk_step",
    description="fused DAAT phase-2 chunk step (VMEM-resident select+score+merge)",
    make_call=_contract_call,
    expect_dma=True,
    # full B x budget x k cross on the 220-doc/bs=32 index (7 blocks: budget 3
    # is non-divisible, 7 == n_blocks), plus the ragged bs=24 degenerate and
    # the multi-trip (scalar-prefetched, in-kernel trip loop) cases — trips 1
    # degenerates to one gated trip, trips 4 spans the whole 7-block index at
    # budget 2, trips 3 exercises early exit headroom at the full budget
    shape_grid=tuple(
        ShapeCase(
            f"b{B}_budget{budget}_k{k}",
            dict(B=B, budget=budget, k=k, n_docs=220, block_size=32, lq=6, tmax=8),
        )
        for B in (1, 3)
        for budget in (1, 3, 7)
        for k in (1, 5)
    )
    + (
        ShapeCase(
            "ragged_bs24",  # bs not a lane multiple, 130/24 -> 6 blocks
            dict(B=2, budget=5, k=3, n_docs=130, block_size=24, lq=4, tmax=8),
        ),
    )
    + tuple(
        ShapeCase(
            f"multi_b{B}_trips{trips}_budget{budget}",
            dict(
                B=B, trips=trips, budget=budget, k=5,
                n_docs=220, block_size=32, lq=6, tmax=8,
            ),
            expect_scalar_prefetch=True,
        )
        for B, trips, budget in ((1, 1, 3), (3, 3, 7), (2, 4, 2))
    )
    + (
        ShapeCase(
            "multi_ragged_bs24",
            dict(B=2, trips=2, budget=5, k=3, n_docs=130, block_size=24, lq=4, tmax=8),
            expect_scalar_prefetch=True,
        ),
        # tombstone-bitmap (live-masked) variants: the live rows must ride the
        # same DMA discipline (third semaphore lane) the happens-before pass
        # checks for the doc store
        ShapeCase(
            "live_b2_budget3",
            dict(B=2, budget=3, k=5, n_docs=220, block_size=32, lq=6, tmax=8, live=1),
        ),
        ShapeCase(
            "multi_live_b2_trips3",
            dict(
                B=2, trips=3, budget=3, k=5,
                n_docs=220, block_size=32, lq=6, tmax=8, live=1,
            ),
            expect_scalar_prefetch=True,
        ),
    ),
)
