"""BM25 weighting (paper baseline rows 1a/2a/3a/4a).

Parameters k1=0.82, b=0.68 are the paper's (tuned for MS MARCO passage
ranking, via Pyserini). Weights are document-side only; query weights are 1
(the classic "sum of matched document weights" formulation of Eq. (1)).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class BM25Params:
    k1: float = 0.82
    b: float = 0.68


def bm25_weights(
    doc_idx: np.ndarray,
    term_idx: np.ndarray,
    tf: np.ndarray,
    n_docs: int,
    n_terms: int,
    params: BM25Params = BM25Params(),
) -> np.ndarray:
    """Per-posting BM25 weight w_{d,t} for COO postings."""
    doc_idx = np.asarray(doc_idx, dtype=np.int64)
    term_idx = np.asarray(term_idx, dtype=np.int64)
    tf = np.asarray(tf, dtype=np.float64)
    # document lengths (in tokens, tf-weighted) and df
    dl = np.zeros(n_docs, dtype=np.float64)
    np.add.at(dl, doc_idx, tf)
    avdl = dl.mean() if n_docs else 1.0
    df = np.zeros(n_terms, dtype=np.float64)
    np.add.at(df, term_idx, 1.0)
    idf = np.log(1.0 + (n_docs - df + 0.5) / (df + 0.5))
    k1, b = params.k1, params.b
    denom = tf + k1 * (1.0 - b + b * (dl[doc_idx] / max(avdl, 1e-9)))
    return (idf[term_idx] * tf * (k1 + 1.0) / denom).astype(np.float64)
