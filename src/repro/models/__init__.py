"""Retrieval models: the paper's six corpus treatments + neural sparse encoders.

``treatments`` produces, for each retrieval model, the (doc COO, weighted
queries) pair the core indexes consume — BM25, BM25 w/ doc2query-T5,
DeepImpact, uniCOIL-T5, uniCOIL-TILDE, SPLADEv2 — with weight distributions
calibrated against the paper's Table 2.

``sparse_encoder`` is the *trainable* path: a JAX transformer backbone with a
SPLADE-style (vocab-logit) or uniCOIL-style (scalar-per-token) head, trained
with pairwise + FLOPS-regularized losses (``repro.train``).
"""
from repro.models.bm25 import BM25Params, bm25_weights  # noqa: F401
from repro.models.treatments import (  # noqa: F401
    MODEL_NAMES,
    PROFILES,
    EncodedCollection,
    apply_treatment,
    encode_all,
)
