"""Trainable learned-sparse encoders: SPLADE-style and uniCOIL-style heads.

This is the *model-production* path of the paper's pipeline: a JAX
transformer encoder (any ``LMConfig`` backbone with ``window_pattern=(-1,)``
— bidirectional attention) plus a sparse head:

  * **splade**: MLM-head logits over the vocab, ``log1p(relu(.))``,
    max-pooled over positions -> [B, V]. Expansion is *learned*: any vocab
    dim can activate, which is exactly the mechanism behind the paper's
    "wacky" stopword/subword weights.
  * **unicoil**: scalar weight per input token, scattered (max) into the
    token's own vocab dim — no expansion beyond input terms (uniCOIL relies
    on doc2query/TILDE expansion upstream).

Training: contrastive pairwise softmax over (query, pos, neg) triples +
SPLADE's FLOPS regularizer (repro.train.losses) — the regularizer is the
published "efficiency in the training objective" answer to the paper's
conclusion, so its strength directly tunes index density (measured in the
``train_sparse_encoder`` example).

Encoded corpora feed ``repro.core.build_impact_index`` -> the full SAAT/DAAT
evaluation stack; i.e. this module closes the loop from gradient descent to
query latency.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.archs import layers
from repro.archs.transformer import LMConfig, init_lm_params, lm_hidden_states
from repro.train.losses import flops_regularizer, pairwise_softmax


@dataclasses.dataclass(frozen=True)
class SparseEncoderConfig:
    backbone: LMConfig  # window_pattern must be (-1,) (bidirectional)
    head: str = "splade"  # splade | unicoil
    flops_weight: float = 1e-3
    query_flops_weight: float = 3e-3  # SPLADEv2 regularizes queries harder

    def __post_init__(self):
        assert all(w == -1 for w in self.backbone.window_pattern), (
            "sparse encoders need bidirectional attention: window_pattern=(-1,)"
        )

    @property
    def vocab(self) -> int:
        return self.backbone.vocab


def encoder_backbone(d_model: int = 256, n_layers: int = 4, vocab: int = 4096, **kw) -> LMConfig:
    return LMConfig(
        name="sparse-encoder-backbone",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=max(4, d_model // 64),
        n_kv_heads=max(4, d_model // 64),
        d_head=min(64, d_model // 4),
        d_ff=4 * d_model,
        vocab=vocab,
        window_pattern=(-1,),
        tie_embeddings=True,
        dtype=jnp.float32,
        **kw,
    )


def init_encoder_params(key, cfg: SparseEncoderConfig):
    kb, kh = jax.random.split(key)
    p = {"backbone": init_lm_params(kb, cfg.backbone)}
    if cfg.head == "unicoil":
        p["head"] = {"w": layers.dense_init(kh, cfg.backbone.d_model, 1, cfg.backbone.dtype)}
    # splade ties the MLM head to the embedding matrix (params-free head)
    return p


def encode(params, tokens: jax.Array, mask: jax.Array, cfg: SparseEncoderConfig) -> jax.Array:
    """Token ids [B, L] (+ bool mask) -> sparse reps [B, V] (non-negative)."""
    h, _ = lm_hidden_states(params["backbone"], tokens, cfg.backbone)  # [B, L, D]
    m = mask[..., None].astype(h.dtype)
    if cfg.head == "splade":
        w_mlm = params["backbone"]["embed"].T  # [D, V] tied MLM head
        logits = (h @ w_mlm).astype(jnp.float32)  # [B, L, V]
        acts = jnp.log1p(jax.nn.relu(logits)) * m
        return acts.max(axis=1)  # max-pool over positions
    if cfg.head == "unicoil":
        w_tok = jax.nn.relu((h @ params["head"]["w"]).astype(jnp.float32))[..., 0]  # [B, L]
        w_tok = w_tok * mask.astype(jnp.float32)
        B, L = tokens.shape
        reps = jnp.zeros((B, cfg.vocab), jnp.float32)
        return reps.at[jnp.arange(B)[:, None], tokens].max(w_tok)
    raise ValueError(cfg.head)


def score(rep_q: jax.Array, rep_d: jax.Array) -> jax.Array:
    """Eq. (1): inner product in vocab space. [B,V]x[B,V] -> [B]."""
    return jnp.sum(rep_q * rep_d, axis=-1)


def encoder_loss(params, batch, cfg: SparseEncoderConfig):
    """Contrastive + FLOPS-regularized loss over (query, pos, neg) triples."""
    rq = encode(params, batch["query"], batch["query_mask"], cfg)
    rp = encode(params, batch["pos"], batch["pos_mask"], cfg)
    rn = encode(params, batch["neg"], batch["neg_mask"], cfg)
    s_pos = score(rq, rp)
    s_neg = score(rq, rn)
    rank = pairwise_softmax(s_pos, s_neg)
    reg = cfg.flops_weight * (flops_regularizer(rp) + flops_regularizer(rn))
    reg = reg + cfg.query_flops_weight * flops_regularizer(rq)
    loss = rank + reg
    acc = (s_pos > s_neg).mean()
    nnz_d = (rp > 1e-6).sum(axis=-1).mean()
    nnz_q = (rq > 1e-6).sum(axis=-1).mean()
    return loss, {"rank_loss": rank, "flops_reg": reg, "pair_acc": acc, "doc_nnz": nnz_d, "query_nnz": nnz_q}


def encode_corpus_to_coo(params, token_batches, mask_batches, cfg: SparseEncoderConfig, threshold: float = 1e-4):
    """Encode a corpus into COO postings for ``build_impact_index``."""
    import numpy as np

    doc_idx, term_idx, weights = [], [], []
    base = 0
    enc = jax.jit(lambda t, m: encode(params, t, m, cfg))
    for toks, mask in zip(token_batches, mask_batches):
        reps = np.asarray(jax.device_get(enc(toks, mask)))
        d, t = np.nonzero(reps > threshold)
        doc_idx.append(d + base)
        term_idx.append(t)
        weights.append(reps[d, t])
        base += reps.shape[0]
    return (
        np.concatenate(doc_idx),
        np.concatenate(term_idx),
        np.concatenate(weights).astype(np.float64),
        base,
    )
