"""The six retrieval-model corpus treatments (paper §3.1, Tables 1 & 2).

Each treatment turns the base concept-latent corpus into an encoded
collection: COO document postings with model-assigned weights, plus weighted
queries. The treatments reproduce the *mechanisms* of the original models:

  BM25            raw surface terms, BM25 weights, unweighted queries.
  BM25-T5         doc2query-T5 document expansion (docs gain the most
                  query-likely surface forms of their concepts), then BM25.
  DeepImpact      T5 expansion + learned impact weights (flat, "wacky"),
                  unweighted queries, surface vocabulary.
  uniCOIL-T5      T5 expansion + learned weights on a BERT-like subword
                  vocabulary, learned *query* weights.
  uniCOIL-TILDE   TILDE expansion (broader, cheaper) + learned weights +
                  learned query weights.
  SPLADEv2        MLM-based expansion on both documents and queries, the
                  heaviest expansion + flattest weights; stopword mass on
                  queries included (the paper's "srsly, wtf?" comma effect).

Mechanism, not fiat: learned weights read the corpus' latent *concept
centrality* (the same signal queries target), so they rank better than
BM25's tf/idf proxy — but they are *flat* ("wacky"), which kills the
block-max skipping DAAT relies on. ``PROFILES`` carries the paper's Table 2
targets for side-by-side reporting.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synthetic import Corpus
from repro.models.bm25 import bm25_weights

MODEL_NAMES = (
    "bm25",
    "bm25-t5",
    "deepimpact",
    "unicoil-t5",
    "unicoil-tilde",
    "spladev2",
)


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Treatment knobs + the paper's Table 2 targets (for reporting)."""

    name: str
    doc_expansion_forms: int  # forms added per doc concept (doc2query/TILDE/MLM)
    query_expansion_forms: int  # forms added per query concept (SPLADE only)
    learned_weights: bool  # transformer-assigned (flat) vs BM25 weights
    query_weights: bool  # learned query-side weights
    subword_frac: float  # 0 = surface vocab; else subword vocab fraction
    subwords_per_term: int  # 1 = plain hash, 2 = split effect (SPLADE)
    stopword_doc_weight: float  # learned weight mass on stopwords in docs
    stopword_query_terms: int  # stopword tokens injected into queries
    weight_flatness: float  # in (0, 1]; higher = flatter ("wackier")
    weight_scale: float  # scales total mass (Table 2 "total terms")
    table2_targets: dict


PROFILES: dict[str, ModelProfile] = {
    "bm25": ModelProfile(
        name="bm25",
        doc_expansion_forms=0,
        query_expansion_forms=0,
        learned_weights=False,
        query_weights=False,
        subword_frac=0.0,
        subwords_per_term=1,
        stopword_doc_weight=0.0,
        stopword_query_terms=0,
        weight_flatness=0.0,
        weight_scale=1.0,
        table2_targets={"doc_unique": 30.1, "q_unique": 5.8, "doc_total": 39.8, "rr10": 0.187},
    ),
    "bm25-t5": ModelProfile(
        name="bm25-t5",
        doc_expansion_forms=4,
        query_expansion_forms=0,
        learned_weights=False,
        query_weights=False,
        subword_frac=0.0,
        subwords_per_term=1,
        stopword_doc_weight=0.0,
        stopword_query_terms=0,
        weight_flatness=0.0,
        weight_scale=1.0,
        table2_targets={"doc_unique": 51.1, "q_unique": 5.8, "doc_total": 224.7, "rr10": 0.277},
    ),
    "deepimpact": ModelProfile(
        name="deepimpact",
        doc_expansion_forms=6,
        query_expansion_forms=0,
        learned_weights=True,
        query_weights=False,
        subword_frac=0.0,
        subwords_per_term=1,
        stopword_doc_weight=0.18,
        stopword_query_terms=0,
        weight_flatness=0.55,
        weight_scale=24.0,
        table2_targets={"doc_unique": 71.1, "q_unique": 4.2, "doc_total": 4010.0, "rr10": 0.325},
    ),
    "unicoil-t5": ModelProfile(
        name="unicoil-t5",
        doc_expansion_forms=6,
        query_expansion_forms=0,
        learned_weights=True,
        query_weights=True,
        subword_frac=1.0,
        subwords_per_term=1,
        stopword_doc_weight=0.22,
        stopword_query_terms=0,
        weight_flatness=0.62,
        weight_scale=30.0,
        table2_targets={"doc_unique": 66.4, "q_unique": 6.6, "doc_total": 5032.3, "rr10": 0.352},
    ),
    "unicoil-tilde": ModelProfile(
        name="unicoil-tilde",
        doc_expansion_forms=11,
        query_expansion_forms=0,
        learned_weights=True,
        query_weights=True,
        subword_frac=1.0,
        subwords_per_term=1,
        stopword_doc_weight=0.22,
        stopword_query_terms=0,
        weight_flatness=0.62,
        weight_scale=30.0,
        table2_targets={"doc_unique": 107.6, "q_unique": 6.5, "doc_total": 8260.8, "rr10": 0.350},
    ),
    "spladev2": ModelProfile(
        name="spladev2",
        doc_expansion_forms=16,
        query_expansion_forms=5,
        learned_weights=True,
        query_weights=True,
        # frac=1.0: SPLADE's BERT vocab is the SAME size as uniCOIL's (paper
        # Table 2: 28131 vs 27678); a shrunken vocab over-collides subwords
        # and was measured to cost ~3 RR@10 points
        subword_frac=1.0,
        subwords_per_term=2,
        stopword_doc_weight=0.35,
        stopword_query_terms=4,
        weight_flatness=0.78,
        weight_scale=36.0,
        table2_targets={"doc_unique": 229.4, "q_unique": 25.0, "doc_total": 10794.8, "rr10": 0.369},
    ),
}


@dataclasses.dataclass(frozen=True)
class EncodedCollection:
    """A (model x corpus) encoding, ready for ``build_impact_index``."""

    name: str
    doc_idx: np.ndarray  # i64[nnz]
    term_idx: np.ndarray  # i64[nnz]
    weights: np.ndarray  # f64[nnz]
    query_terms: list  # list of i32 arrays
    query_weights: list  # list of f32 arrays
    n_terms: int
    profile: ModelProfile

    @property
    def n_postings(self) -> int:
        return int(self.doc_idx.size)


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------


class _StrengthLookup:
    """O(log n) per-posting concept-centrality lookup over (doc, concept)."""

    def __init__(self, corpus: Corpus):
        cfg = corpus.config
        docs = np.repeat(
            np.arange(corpus.n_docs, dtype=np.int64),
            [c.size for c in corpus.doc_concepts],
        )
        cons = np.concatenate(corpus.doc_concepts).astype(np.int64)
        strs = np.concatenate(corpus.doc_concept_strengths).astype(np.float64)
        keys = docs * cfg.n_concepts + cons
        order = np.argsort(keys)
        self._keys = keys[order]
        self._strs = strs[order]
        self._cfg = cfg

    def concept_of(self, term_idx: np.ndarray) -> np.ndarray:
        cfg = self._cfg
        return np.where(
            term_idx >= cfg.n_stopwords,
            (term_idx - cfg.n_stopwords) // cfg.terms_per_concept,
            -1,
        )

    def __call__(self, doc_idx: np.ndarray, term_idx: np.ndarray) -> np.ndarray:
        """Per-posting strength in [0, 1]; stopwords/unknown get 0.1."""
        cfg = self._cfg
        con = self.concept_of(term_idx)
        keys = doc_idx.astype(np.int64) * cfg.n_concepts + con
        pos = np.searchsorted(self._keys, keys).clip(0, self._keys.size - 1)
        hit = (self._keys[pos] == keys) & (con >= 0)
        return np.where(hit, self._strs[pos], 0.1)


def _expand_docs(corpus: Corpus, forms_per_concept: int):
    """doc2query/TILDE/MLM-style document expansion.

    For every (doc, concept) pair, append the concept's ``forms_per_concept``
    most *query-popular* surface forms (what a seq2seq trained on queries
    predicts) with tf=1. Returns extra COO (doc, term, tf) postings.
    """
    cfg = corpus.config
    docs = np.repeat(
        np.arange(corpus.n_docs, dtype=np.int64),
        [c.size for c in corpus.doc_concepts],
    )
    cons = np.concatenate(corpus.doc_concepts).astype(np.int64)
    doc_rep = np.repeat(docs, forms_per_concept)
    con_rep = np.repeat(cons, forms_per_concept)
    form = np.tile(np.arange(forms_per_concept, dtype=np.int64), cons.size)
    terms = cfg.n_stopwords + con_rep * cfg.terms_per_concept + form
    tfs = np.ones(terms.size, dtype=np.float64)
    return doc_rep, terms, tfs


def _learned_weights(
    term_idx: np.ndarray,
    tf: np.ndarray,
    strength: np.ndarray,
    n_stopwords: int,
    profile: ModelProfile,
    rng,
) -> np.ndarray:
    """Transformer-style "wacky" impact weights.

    signal      concept centrality (the relevance signal tf/idf only proxies)
    flat floor  learned weights cluster in a narrow band -> loose block-max
                bounds -> DAAT skipping collapses (paper §4.2)
    stopwords   non-trivial learned mass ("and": 225 in the paper's example)
    """
    tf = np.asarray(tf, dtype=np.float64)
    signal = (0.3 + 0.7 * strength) * (0.75 + 0.25 * np.log1p(tf) / np.log1p(8.0))
    noise = rng.lognormal(0.0, 0.2, term_idx.size)
    flat = profile.weight_flatness
    w = ((1.0 - flat) * signal + flat * (0.55 + 0.2 * rng.random(term_idx.size))) * noise
    stop = term_idx < n_stopwords
    w = np.where(stop, profile.stopword_doc_weight * (0.5 + rng.random(term_idx.size)), w)
    return np.maximum(w, 1e-3) * profile.weight_scale


def _subword_vocab_size(profile: ModelProfile, n_surface: int) -> int:
    return max(2048, int(profile.subword_frac * n_surface))


def _subword_map(terms: np.ndarray, vocab: int, copies: int, n_stopwords: int) -> np.ndarray:
    """Hash surface terms onto a BERT-like subword vocabulary.

    Many-to-one collisions reproduce the paper's subword conflation ("and" vs
    "##rogen"); ``copies=2`` splits a term into two subwords (SPLADE docs).
    Stopwords map to a reserved low range so their identity (and wacky query
    mass) is preserved. Output shape: [copies * len(terms)].
    """
    terms = np.asarray(terms, dtype=np.int64)
    outs = []
    for c in range(copies):
        h = (terms * 2654435761 + 97 + 1013904223 * c) % (vocab - n_stopwords)
        mapped = np.where(terms < n_stopwords, terms, n_stopwords + h)
        outs.append(mapped)
    return np.concatenate(outs)


def _dedup_coo(doc_idx, term_idx, weights, n_terms: int, mode: str = "sum"):
    key = doc_idx.astype(np.int64) * n_terms + term_idx
    uk, inv = np.unique(key, return_inverse=True)
    w = np.zeros(uk.size, dtype=np.float64)
    if mode == "sum":
        np.add.at(w, inv, weights)
    else:  # max-pool (SPLADE)
        np.maximum.at(w, inv, weights)
    return (uk // n_terms).astype(np.int64), (uk % n_terms).astype(np.int64), w


# --------------------------------------------------------------------------
# the treatment itself
# --------------------------------------------------------------------------


def apply_treatment(corpus: Corpus, model: str, seed: int = 0) -> EncodedCollection:
    """Encode the base corpus under one of the six retrieval models."""
    if model not in PROFILES:
        raise ValueError(f"unknown model {model!r}; choose from {MODEL_NAMES}")
    profile = PROFILES[model]
    cfg = corpus.config
    rng = np.random.default_rng(seed * 1009 + list(PROFILES).index(model))
    lookup = _StrengthLookup(corpus)

    doc_idx, term_idx, tf = corpus.coo()
    if profile.doc_expansion_forms > 0:
        ed, et, etf = _expand_docs(corpus, profile.doc_expansion_forms)
        doc_idx = np.concatenate([doc_idx, ed])
        term_idx = np.concatenate([term_idx, et])
        tf = np.concatenate([tf, etf])
        doc_idx, term_idx, tf = _dedup_coo(doc_idx, term_idx, tf, cfg.n_surface_terms, "sum")

    # learned weights are computed on the *surface* postings (where concept
    # identity is known), then optionally mapped to subwords
    if profile.learned_weights:
        strength = lookup(doc_idx, term_idx)
        weights = _learned_weights(term_idx, tf, strength, cfg.n_stopwords, profile, rng)
    else:
        weights = None  # BM25 computed after (optional) vocab mapping

    n_terms = cfg.n_surface_terms
    if profile.subword_frac:
        n_terms = _subword_vocab_size(profile, cfg.n_surface_terms)
        copies = profile.subwords_per_term
        mapped = _subword_map(term_idx, n_terms, copies, cfg.n_stopwords)
        doc_idx = np.tile(doc_idx, copies)
        tf = np.tile(tf, copies)
        if weights is not None:
            weights = np.tile(weights / copies, copies)
        term_idx = mapped
        if weights is not None:
            doc_idx, term_idx, weights = _dedup_coo(doc_idx, term_idx, weights, n_terms, "sum")
        else:
            doc_idx, term_idx, tf = _dedup_coo(doc_idx, term_idx, tf, n_terms, "sum")

    if weights is None:
        weights = bm25_weights(doc_idx, term_idx, tf, corpus.n_docs, n_terms)

    # ---------------- queries ----------------
    q_terms_out, q_weights_out = [], []
    for qi in range(corpus.n_queries):
        terms = corpus.query_terms[qi].astype(np.int64)
        d_focus = int(corpus.qrels[qi])
        cs = corpus.query_concepts[qi].astype(np.int64)
        kind = np.zeros(terms.size, dtype=np.int64)  # 0=content, 1=expansion, 2=stop
        kind[terms < cfg.n_stopwords] = 2
        if profile.query_expansion_forms > 0:  # SPLADE-style query expansion
            reps = np.repeat(cs, profile.query_expansion_forms)
            form = np.tile(np.arange(profile.query_expansion_forms, dtype=np.int64), cs.size)
            exp = cfg.n_stopwords + reps * cfg.terms_per_concept + form
            terms = np.concatenate([terms, exp])
            kind = np.concatenate([kind, np.ones(exp.size, dtype=np.int64)])
        if profile.stopword_query_terms > 0:
            stops = rng.integers(0, cfg.n_stopwords, profile.stopword_query_terms)
            terms = np.concatenate([terms, stops])
            kind = np.concatenate([kind, np.full(stops.size, 2, dtype=np.int64)])
        if profile.query_weights:
            # learned query weights track term informativeness for this query
            strength = lookup(np.full(terms.size, d_focus, dtype=np.int64), terms)
            base = 0.25 + 0.75 * strength
            base = np.where(kind == 1, 0.6 * base, base)  # expansion discount
            base = np.where(kind == 2, 0.12, base)  # stopword down-weight
            qw = base * (0.85 + 0.3 * rng.random(terms.size)) * profile.weight_scale * 0.6
        else:
            qw = np.ones(terms.size, dtype=np.float64)
        if profile.subword_frac:
            terms = _subword_map(terms, n_terms, 1, cfg.n_stopwords)
        # dedup (max weight wins, SPLADE max-pool semantics)
        ut = np.unique(terms)
        w = np.zeros(ut.size, dtype=np.float64)
        pos = np.searchsorted(ut, terms)
        np.maximum.at(w, pos, qw)
        q_terms_out.append(ut.astype(np.int32))
        q_weights_out.append(w.astype(np.float32))

    return EncodedCollection(
        name=model,
        doc_idx=doc_idx,
        term_idx=term_idx,
        weights=weights,
        query_terms=q_terms_out,
        query_weights=q_weights_out,
        n_terms=int(n_terms),
        profile=profile,
    )


def encode_all(corpus: Corpus, seed: int = 0, models=MODEL_NAMES) -> dict[str, EncodedCollection]:
    return {m: apply_treatment(corpus, m, seed=seed) for m in models}
