"""Static analysis for the Pallas/serving stack.

Two passes, one CLI (``python -m repro.analysis.check``):

  * :mod:`repro.analysis.kernel_contracts` — traces every kernel package's
    declared ``KernelContract`` shape grid and verifies VMEM budgets,
    grid/BlockSpec divisibility, and DMA start/wait discipline;
  * :mod:`repro.analysis.hot_path` — traces the serving executables behind
    ``ServingConfig``/``make_bucketed_serve_step`` and flags host
    syncs/callbacks, dtype/weak-type drift, and executable-cache forks.

Both passes work on jaxprs only: no kernel executes, no device is needed,
and CPU CI covers the TPU contracts.
"""
from repro.analysis.hot_path import (  # noqa: F401
    check_dtype_discipline,
    check_host_sync,
    lint_server,
    lint_sharded_serve,
    lint_trace,
)
from repro.analysis.kernel_contracts import (  # noqa: F401
    KernelContract,
    ShapeCase,
    Violation,
    all_contracts,
    check_contract,
)
