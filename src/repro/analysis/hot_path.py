"""Hot-path purity lint: the serving executables must be pure and cache-stable.

The serving stack's latency story rests on three trace-time invariants that
nothing at runtime enforces:

  * **No host syncs.** A ``callback``/``infeed``/``outfeed`` primitive inside
    a served executable stalls the device on the host every dispatch; the
    paper's predictable-latency claim dies quietly. All host I/O belongs in
    the host-side wrappers (``AnytimeServer.search_batch``'s timing,
    ``serve_bucketed``'s numpy bucketization), never under the trace.
  * **No dtype drift.** jit caches key on dtypes *and* weak-type flags. A
    caller handing i64 terms or a weak-typed python float forks the compile
    cache per call site — the admission queue's warmup grid no longer covers
    serve time and "compiled once" becomes "recompiles at p99".
  * **One executable per key.** ``AnytimeServer.executable_key`` promises a
    1:1 map from (engine statics, Lq bucket, B) to compiled programs. The
    queue's service-time EMA and the warmup grid both break if equal keys
    can retrace or distinct keys alias.

This module checks all three *statically*: it traces the exact engine
dispatch (``AnytimeServer.engine_fn``) or sharded serve step
(``make_sharded_serve_step``'s tagged fns) to a jaxpr at every
(config, Lq bucket, B) point — ``jax.make_jaxpr`` over ShapeDtypeStructs,
no arrays, no execution — and lints the result. Run via
``python -m repro.analysis.check --serving``.
"""
from __future__ import annotations

import hashlib
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_walk import iter_eqns
from repro.analysis.kernel_contracts import Violation

# Primitive names (substring match) that force a host round-trip inside a
# traced computation. "callback" covers pure_callback / io_callback /
# debug_callback (jax.debug.print's carrier); infeed/outfeed are the raw XLA
# host-transfer ops.
FORBIDDEN_PRIMITIVE_SUBSTRINGS = ("callback", "infeed", "outfeed")


def check_host_sync(closed_jaxpr, label: str = "<traced>", case: str = "trace"):
    """Flag host-round-trip primitives anywhere in a traced hot path."""
    out = []
    for eqn in iter_eqns(closed_jaxpr.jaxpr):
        name = eqn.primitive.name
        if any(s in name for s in FORBIDDEN_PRIMITIVE_SUBSTRINGS):
            out.append(
                Violation(
                    label, case, "host_sync",
                    f"primitive '{name}' forces a host round-trip inside the "
                    "served executable; hot paths must stay pure — move the "
                    "I/O to the host-side wrapper (search_batch / "
                    "serve_bucketed), not under the trace",
                )
            )
    return out


def check_dtype_discipline(closed_jaxpr, label: str = "<traced>", case: str = "trace"):
    """Flag compile-cache-forking dtypes at the executable boundary.

    Interface avals (invars/outvars) must be strong-typed — a weak-typed
    input means some call site passed a python scalar and the next strong
    caller retraces. f64 anywhere in the body means an x64 leak: the same
    program traced from an x64 context compiles a second, slower executable.
    """
    out = []
    jaxpr = closed_jaxpr.jaxpr
    for role, atoms in (("input", jaxpr.invars), ("output", jaxpr.outvars)):
        for i, atom in enumerate(atoms):
            aval = getattr(atom, "aval", None)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            if getattr(aval, "weak_type", False):
                out.append(
                    Violation(
                        label, case, "weak_type",
                        f"{role} {i} is weak-typed {aval.dtype}: a python "
                        "scalar leaked into the executable boundary and every "
                        "strong-typed caller will silently retrace — "
                        "canonicalize with jnp.asarray(x, dtype) before "
                        "dispatch",
                    )
                )
    seen: set = set()
    for eqn in iter_eqns(jaxpr):
        for atom in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(atom, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is None or dt not in (jnp.float64, jnp.complex128):
                continue
            key = (eqn.primitive.name, str(dt))
            if key in seen:
                continue
            seen.add(key)
            out.append(
                Violation(
                    label, case, "f64_drift",
                    f"primitive '{eqn.primitive.name}' touches {dt}: an x64 "
                    "leak forks the compile cache (and doubles VMEM tiles) — "
                    "the hot path is an i32/f32 contract",
                )
            )
    return out


def check_no_densified_blockmax(
    closed_jaxpr,
    dense_shape: Sequence[int],
    label: str = "<traced>",
    case: str = "trace",
):
    """Flag the densified ``[B, Lq, n_blocks]`` block-max intermediate.

    Kernel-mode DAAT phase 0 walks the CSR block-max lists directly
    (``block_prune_csr``): the per-(query, slot) dense matrix must never be
    materialised — it is ``Lq`` x the footprint of the lists it expands from
    and every byte of it crosses HBM twice. Any aval of that exact shape in
    the traced search means the scatter-densify path crept back in.
    """
    out = []
    shape = tuple(int(d) for d in dense_shape)
    for eqn in iter_eqns(closed_jaxpr.jaxpr):
        for atom in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(atom, "aval", None)
            if getattr(aval, "shape", None) == shape:
                out.append(
                    Violation(
                        label, case, "dense_blockmax",
                        f"primitive '{eqn.primitive.name}' touches an aval of "
                        f"shape {shape} — the densified [B, Lq, n_blocks] "
                        "block-max intermediate is back in kernel-mode phase "
                        "0; the CSR prune kernel must consume base/cnt "
                        "windows off the index's bm lists, not scatter-dense "
                        "rows",
                    )
                )
                break
    return out


def fingerprint(closed_jaxpr) -> str:
    """Stable identity of a traced program (the executable-key invariant)."""
    return hashlib.sha1(str(closed_jaxpr).encode()).hexdigest()


def lint_trace(
    fn: Callable,
    args: Sequence,
    label: str,
    case: str,
) -> tuple[list, Optional[str]]:
    """Trace ``fn(*args)`` and run every purity check. -> (violations, fp).

    Traces TWICE and compares fingerprints: a nondeterministic trace (e.g. a
    dict-ordering or id()-dependent closure) means equal executable keys do
    not imply equal programs, which silently defeats the warmup grid.
    """
    try:
        jx = jax.make_jaxpr(fn)(*args)
    except Exception as e:  # noqa: BLE001 — any trace failure is the finding
        return (
            [Violation(label, case, "trace", f"hot path failed to trace: {e!r}")],
            None,
        )
    out = check_host_sync(jx, label, case) + check_dtype_discipline(jx, label, case)
    fp = fingerprint(jx)
    if fingerprint(jax.make_jaxpr(fn)(*args)) != fp:
        out.append(
            Violation(
                label, case, "retrace",
                "tracing the same hot path twice produced different jaxprs; "
                "the executable cache cannot be warmed for a nondeterministic "
                "trace",
            )
        )
    return out, fp


# --------------------------------------------------------------------------
# server lint: the AnytimeServer executable grid
# --------------------------------------------------------------------------


def _query_structs(B: int, lq: int):
    return (
        jax.ShapeDtypeStruct((B, lq), jnp.int32),
        jax.ShapeDtypeStruct((B, lq), jnp.float32),
    )


def lint_server(
    server,
    *,
    batch_sizes: Sequence[int] = (2, 4),
    rhos: Optional[Sequence[Optional[int]]] = None,
    label: Optional[str] = None,
    key_registry: Optional[dict] = None,
) -> list:
    """Lint every executable an :class:`AnytimeServer` can dispatch.

    Walks the full (rho-or-engine-config) x (Lq bucket) x (B) grid — the same
    grid ``warmup`` compiles and the admission queue flushes into — tracing
    ``server.engine_fn`` at each point. On top of the per-trace purity checks
    this asserts the executable-key invariant both ways: equal keys must
    fingerprint identically, distinct keys must fingerprint distinctly (a key
    that splits finer than the program means the cost model is learning two
    names for one executable).

    Pass one ``key_registry`` dict across several calls to extend the
    bijection over server *states* that never coexist in one dispatch grid —
    e.g. the same handle-backed server before and after a hot-swap
    compaction: each generation's (key, fingerprint) pairs land in the shared
    registry, so a key that fails to distinguish two generations' genuinely
    different programs (or splits one shared program in two) is a violation
    even though no single lint call sees both.
    """
    cfg = server.cfg
    if label is None:
        label = f"server:{cfg.engine}"
    if rhos is None:
        # EVERY ladder level: deadline degradation may flush any calibrated
        # rho, so each level is a dispatchable executable the key invariant
        # must cover (endpoints alone would miss a mid-ladder collision)
        rhos = [None] if cfg.engine == "daat" else list(server.rho_ladder)
    buckets = list(server.lq_buckets) if server.lq_buckets is not None else [8]
    out: list = []
    reg = key_registry if key_registry is not None else {}
    by_key: dict = reg.setdefault("by_key", {})
    by_fp: dict = reg.setdefault("by_fp", {})
    for bucket in buckets:
        for B in batch_sizes:
            for rho in dict.fromkeys(rhos):
                case = f"lq{bucket}_b{B}" + ("" if rho is None else f"_rho{rho}")
                vs, fp = lint_trace(
                    server.engine_fn(rho), _query_structs(B, bucket), label, case
                )
                out.extend(vs)
                if fp is None:
                    continue
                key = server.executable_key(bucket, B, rho)
                if key in by_key and by_key[key] != fp:
                    out.append(
                        Violation(
                            label, case, "executable_key",
                            f"executable_key {key} maps to two different "
                            "programs; equal keys must hit one compiled "
                            "executable",
                        )
                    )
                elif key not in by_key and fp in by_fp:
                    out.append(
                        Violation(
                            label, case, "executable_key",
                            f"executable_key {key} and {by_fp[fp]} name the "
                            "SAME program; the key distinguishes a config the "
                            "executable ignores, so the cost model learns two "
                            "names for one executable",
                        )
                    )
                by_key[key] = fp
                by_fp.setdefault(fp, key)
    return out


# --------------------------------------------------------------------------
# sharded serve lint: the pod-scale step behind make_bucketed_serve_step
# --------------------------------------------------------------------------


def lint_sharded_serve(
    serve,
    index_stack,
    *,
    batch_sizes: Sequence[int] = (2,),
    buckets: Optional[Sequence[int]] = None,
    label: str = "sharded",
    key_registry: Optional[dict] = None,
    live_stack=None,
) -> list:
    """Lint a (possibly bucketed) sharded/pod serve step at every bucket width.

    ``make_bucketed_serve_step``'s wrapper does host-side numpy bucketization
    and cannot be traced; its tagged ``.inner`` is the actual executable, so
    that is what gets traced — at each ``.buckets`` width, exactly the shapes
    the wrapper can dispatch.

    The step's tagged ``.statics`` dict names its compiled executable the
    same way ``AnytimeServer.executable_key`` does, so the one-executable-
    per-key bijection is asserted here too: (statics, bucket, B) keys must
    fingerprint 1:1. Pass one ``key_registry`` dict across several
    ``lint_sharded_serve`` calls and the bijection spans the whole serve
    surface — two steps whose statics differ (say, a pod mesh vs a
    single-host mesh at equal engine config) must never alias one program,
    and equal statics must never trace two. For a ``live_masked=True`` step
    pass the ``live_stack`` it will serve with; it rides as a traced operand.
    """
    inner = getattr(serve, "inner", serve)
    if buckets is None:
        tagged = getattr(serve, "buckets", None)
        if tagged is None:
            raise ValueError(
                "serve fn has no .buckets tag and no explicit buckets were "
                "given; pass buckets=(...) matching the widths it will serve"
            )
        buckets = tagged
    statics = getattr(serve, "statics", None)
    statics_key = (
        tuple(sorted(statics.items())) if isinstance(statics, dict) else None
    )
    reg = key_registry if key_registry is not None else {}
    by_key = reg.setdefault("by_key", {})
    by_fp = reg.setdefault("by_fp", {})
    out: list = []
    for bucket in buckets:
        for B in batch_sizes:
            case = f"lq{bucket}_b{B}"
            if live_stack is not None:
                fn = lambda qt, qw: inner(index_stack, qt, qw, live_stack=live_stack)  # noqa: E731
            else:
                fn = lambda qt, qw: inner(index_stack, qt, qw)  # noqa: E731
            vs, fp = lint_trace(fn, _query_structs(B, bucket), label, case)
            out.extend(vs)
            if fp is None or statics_key is None:
                continue
            key = statics_key + (int(bucket), int(B))
            if key in by_key and by_key[key] != fp:
                out.append(
                    Violation(
                        label, case, "executable_key",
                        "equal serve statics and shape traced two different "
                        "programs; the warmup grid cannot cover a "
                        "nondeterministic executable",
                    )
                )
            elif key not in by_key and fp in by_fp:
                out.append(
                    Violation(
                        label, case, "executable_key",
                        f"distinct serve statics/shape ({label}:{case} vs "
                        f"{by_fp[fp]}) name the SAME program; the key "
                        "distinguishes a config the executable ignores",
                    )
                )
            by_key[key] = fp
            by_fp.setdefault(fp, f"{label}:{case}")
    return out
