"""KernelContract: machine-checked invariants for the Pallas kernel packages.

Every ``kernels/*/ops.py`` exports a ``CONTRACT`` declaring the shapes its
interpret-mode sweeps exercise (the representative grid, ragged degenerates
included), the per-core VMEM budget the kernel must fit, and whether the
kernel is expected to issue async copies. :func:`check_contract` traces the
wrapped op to a jaxpr at every declared shape — no execution, no device —
and runs three passes over each ``pallas_call`` it finds:

  1. **VMEM footprint** — pipelined input/output tiles count twice (Pallas
     double-buffers blocked operands behind the grid), VMEM scratch once,
     ANY/semaphore operands not at all; failures carry the full per-operand
     breakdown so the offending tile is named, not inferred.
  2. **Grid/index-map divisibility** — every blocked dimension must divide
     its array dimension (the wrappers pre-pad; a ragged tile silently
     masks or miscompiles on device), and the block index map must stay in
     range over the whole grid, evaluated point by point.
  3. **DMA happens-before** — every ``make_async_copy`` start must be waited
     before its destination slot is read, its semaphore slot revolves, or a
     second copy starts into the same destination slot (the double-buffer /
     trip-loop revolving-buffer race classes in ``chunk_step``), and no copy
     may be left in flight at the end of the body.
  4. **Scalar prefetch** — a contract (or an individual case) that declares
     ``expect_scalar_prefetch`` must trace to a ``pallas_call`` with
     ``PrefetchScalarGridSpec`` operands; a silent fall-back to a static
     grid would drop the dynamic trip-budget / CSR-offset dispatch.

The shape grid is the single source of truth for the kernel test sweeps:
``tests/test_kernels.py`` parametrizes from ``CONTRACT.sweep(...)`` instead
of duplicating shape literals.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.analysis import jaxpr_walk

# Per-core VMEM on current TPU generations (see the pallas guide); contracts
# may declare tighter limits but never looser ones.
VMEM_BYTES_PER_CORE = 16 * 2**20

# Cap on exhaustive index-map evaluation; beyond it the grid is corner-sampled.
_MAX_GRID_POINTS = 4096


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    """One named point of a contract's shape grid.

    ``dims`` holds the op-level shape parameters (the same names the test
    sweeps use), so a case is both a trace target for the checker and a
    parametrize row for the interpret-mode tests.

    ``expect_scalar_prefetch`` overrides the contract-level default for this
    case (``None`` = inherit): a grid may mix plain cases with
    ``PrefetchScalarGridSpec`` cases (e.g. single-trip vs multi-trip
    ``chunk_step``), and the checker must know which dispatch each case is
    supposed to take.
    """

    name: str
    dims: Mapping[str, int]
    expect_scalar_prefetch: Optional[bool] = None

    def __post_init__(self):
        object.__setattr__(self, "dims", dict(self.dims))


@dataclasses.dataclass(frozen=True)
class KernelContract:
    """Declared invariants for one kernel package (exported as ``CONTRACT``).

    ``make_call(dims)`` returns ``(fn, args)`` such that ``fn(*args)`` traces
    the package's op at that shape (interpret mode, deterministic inputs).
    """

    name: str
    make_call: Callable[[Mapping[str, int]], Tuple[Callable, tuple]]
    shape_grid: Tuple[ShapeCase, ...]
    vmem_limit_bytes: int = VMEM_BYTES_PER_CORE
    expect_dma: bool = False
    expect_scalar_prefetch: bool = False
    description: str = ""

    def __post_init__(self):
        if self.vmem_limit_bytes > VMEM_BYTES_PER_CORE:
            raise ValueError(
                f"contract {self.name!r}: vmem_limit_bytes="
                f"{self.vmem_limit_bytes} exceeds the per-core budget "
                f"{VMEM_BYTES_PER_CORE}"
            )
        names = [c.name for c in self.shape_grid]
        if len(set(names)) != len(names):
            raise ValueError(f"contract {self.name!r}: duplicate case names {names}")

    def sweep(
        self, *dim_names: str, require: Sequence[str] = (), exclude: Sequence[str] = ()
    ) -> list[tuple]:
        """Shape tuples for test parametrization: one row per grid case that
        defines every requested dim (single dims flatten to scalars).

        ``require``/``exclude`` filter cases by the presence of OTHER dims —
        e.g. ``exclude=("batch",)`` selects the single-query cases.
        """
        rows = []
        for case in self.shape_grid:
            if any(n in case.dims for n in exclude):
                continue
            if not all(n in case.dims for n in require):
                continue
            if all(n in case.dims for n in dim_names):
                row = tuple(case.dims[n] for n in dim_names)
                rows.append(row[0] if len(dim_names) == 1 else row)
        return rows

    def sweep_values(
        self, dim_name: str, require: Sequence[str] = (), exclude: Sequence[str] = ()
    ) -> list[int]:
        """Deduplicated, order-preserving values of one dim across the grid."""
        return list(dict.fromkeys(self.sweep(dim_name, require=require, exclude=exclude)))


@dataclasses.dataclass(frozen=True)
class Violation:
    contract: str
    case: str
    check: str  # "vmem" | "divisibility" | "index_map" | "dma" | "scalar_prefetch" | "trace"
    message: str

    def __str__(self) -> str:
        return f"[{self.contract} / {self.case} / {self.check}] {self.message}"


# --------------------------------------------------------------------------
# the three passes
# --------------------------------------------------------------------------


def vmem_footprint(pallas_eqn) -> Tuple[int, list[tuple[str, int, str]]]:
    """(total bytes, [(operand label, counted bytes, note)]) for one launch."""
    rows: list[tuple[str, int, str]] = []
    total = 0
    for op in jaxpr_walk.kernel_operands(pallas_eqn):
        if op.space == "vmem" and op.role in ("in", "out"):
            counted = 2 * op.nbytes
            note = f"block {op.block_shape} {np.dtype(op.dtype).name} x2 (pipeline double-buffer)"
        elif op.space == "vmem":  # scratch
            counted = op.nbytes
            note = f"scratch {op.block_shape} {np.dtype(op.dtype).name}"
        else:
            counted = 0
            note = f"{op.space} (not VMEM-resident)"
        rows.append((op.label, counted, note))
        total += counted
    return total, rows


def _check_vmem(contract: KernelContract, case: ShapeCase, eqn) -> list[Violation]:
    total, rows = vmem_footprint(eqn)
    if total <= contract.vmem_limit_bytes:
        return []
    breakdown = "\n".join(
        f"    {label:<28} {counted:>12,} B  {note}" for label, counted, note in rows
    )
    return [
        Violation(
            contract.name,
            case.name,
            "vmem",
            f"per-core VMEM footprint {total:,} B exceeds the contract limit "
            f"{contract.vmem_limit_bytes:,} B; breakdown:\n{breakdown}",
        )
    ]


def _grid_points(grid: Sequence[int]) -> list[tuple[int, ...]]:
    import itertools

    dims = [int(g) for g in grid]
    n = 1
    for g in dims:
        n *= max(g, 1)
    if n <= _MAX_GRID_POINTS:
        return list(itertools.product(*[range(g) for g in dims]))
    # corner-sample: first / middle / last index per axis covers the bound
    # checks that actually fail in practice (off-by-one at either end)
    axes = [sorted({0, g // 2, g - 1}) for g in dims]
    return list(itertools.product(*axes))


def _check_blocks(contract: KernelContract, case: ShapeCase, eqn) -> list[Violation]:
    out: list[Violation] = []
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    points = _grid_points(grid) if grid else [()]
    for op in jaxpr_walk.kernel_operands(eqn):
        bm = op.block_mapping
        if bm is None or op.space != "vmem":
            continue
        block = tuple(d for d in bm.block_shape)
        array_shape = tuple(int(s) for s in bm.array_shape_dtype.shape)
        nblocks = []
        for d, (b, s) in enumerate(zip(block, array_shape)):
            b = 1 if b is None else int(b)
            if s % b != 0:
                out.append(
                    Violation(
                        contract.name,
                        case.name,
                        "divisibility",
                        f"{op.label}: array dim {d} ({s}) is not a multiple of "
                        f"its block dim ({b}) — the ops wrapper must pre-pad "
                        "(ragged tiles mask silently in interpret mode and "
                        "miscompile on device)",
                    )
                )
            nblocks.append(-(-s // b))
        imj = getattr(bm, "index_map_jaxpr", None)
        if imj is None:
            continue
        # scalar-prefetch operands trail the grid indices in the index-map
        # signature; the maps here never read them (`lambda b, *_: ...`), so
        # zero placeholders keep eval_jaxpr's arity happy
        n_extra = max(0, len(imj.jaxpr.invars) - len(points[0] if points else ()))
        extra = [np.int32(0)] * n_extra
        for pt in points:
            idx = jax.core.eval_jaxpr(imj.jaxpr, imj.consts, *map(np.int32, pt), *extra)
            vals = [int(v) for v in idx]
            if len(vals) != len(nblocks):
                out.append(
                    Violation(
                        contract.name,
                        case.name,
                        "index_map",
                        f"{op.label}: index map returns {len(vals)} coords for a "
                        f"rank-{len(nblocks)} block shape",
                    )
                )
                break
            bad = [
                (d, v, nb) for d, (v, nb) in enumerate(zip(vals, nblocks)) if not 0 <= v < nb
            ]
            if bad:
                d, v, nb = bad[0]
                out.append(
                    Violation(
                        contract.name,
                        case.name,
                        "index_map",
                        f"{op.label}: at grid point {pt} the index map returns "
                        f"block coord {v} on dim {d}, outside [0, {nb}) — the "
                        "tile would read/write past the padded array",
                    )
                )
                break
    return out


def _check_scalar_prefetch(
    contract: KernelContract, case: ShapeCase, eqns
) -> list[Violation]:
    expected = case.expect_scalar_prefetch
    if expected is None:
        expected = contract.expect_scalar_prefetch
    count = sum(jaxpr_walk.num_scalar_prefetch_operands(eqn) for eqn in eqns)
    if expected and count == 0:
        return [
            Violation(
                contract.name,
                case.name,
                "scalar_prefetch",
                "contract expects scalar-prefetch operands at this case but the "
                "traced pallas_call declares none (num_index_operands == 0) — "
                "the dynamic-offset/trip-budget dispatch is not being taken",
            )
        ]
    return []


def _check_dma(contract: KernelContract, case: ShapeCase, eqns) -> list[Violation]:
    out: list[Violation] = []
    starts = 0
    for eqn in eqns:
        report = jaxpr_walk.check_dma_discipline(eqn.params["jaxpr"])
        starts += report.starts
        out.extend(
            Violation(contract.name, case.name, "dma", msg) for msg in report.violations
        )
    if contract.expect_dma and starts == 0:
        out.append(
            Violation(
                contract.name,
                case.name,
                "dma",
                "contract declares expect_dma=True but the traced kernel issues "
                "no async copies — the HBM-resident operands are being copied "
                "by the pipeline instead of make_async_copy",
            )
        )
    return out


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------


def check_contract(
    contract: KernelContract, case_names: Optional[Sequence[str]] = None
) -> list[Violation]:
    """Trace + verify one contract over its shape grid. Returns violations."""
    out: list[Violation] = []
    for case in contract.shape_grid:
        if case_names is not None and case.name not in case_names:
            continue
        try:
            fn, args = contract.make_call(case.dims)
            closed = jax.make_jaxpr(fn)(*args)
        except Exception as e:  # noqa: BLE001 — a trace failure IS a finding
            out.append(
                Violation(
                    contract.name,
                    case.name,
                    "trace",
                    f"tracing failed at dims {dict(case.dims)}: {type(e).__name__}: {e}",
                )
            )
            continue
        eqns = jaxpr_walk.find_pallas_calls(closed.jaxpr)
        if not eqns:
            out.append(
                Violation(
                    contract.name,
                    case.name,
                    "trace",
                    "no pallas_call in the traced op — the kernel path is not "
                    "being exercised at these dims",
                )
            )
            continue
        for eqn in eqns:
            out.extend(_check_vmem(contract, case, eqn))
            out.extend(_check_blocks(contract, case, eqn))
        out.extend(_check_dma(contract, case, eqns))
        out.extend(_check_scalar_prefetch(contract, case, eqns))
    return out


def all_contracts() -> dict[str, KernelContract]:
    """Import every kernel package's CONTRACT (the checked-in registry)."""
    from repro.kernels.block_prune import ops as block_prune
    from repro.kernels.block_prune_csr import ops as block_prune_csr
    from repro.kernels.block_topk import ops as block_topk
    from repro.kernels.chunk_step import ops as chunk_step
    from repro.kernels.impact_scatter import ops as impact_scatter
    from repro.kernels.impact_scatter_topk import ops as impact_scatter_topk
    from repro.kernels.sparse_score import ops as sparse_score

    modules = (
        block_prune, block_prune_csr, block_topk, chunk_step, impact_scatter,
        impact_scatter_topk, sparse_score,
    )
    out: dict[str, KernelContract] = {}
    for mod in modules:
        contract = getattr(mod, "CONTRACT", None)
        if contract is None:
            raise AttributeError(
                f"{mod.__name__} exports no CONTRACT — every kernel package "
                "must declare one (see src/repro/analysis/README.md)"
            )
        out[contract.name] = contract
    return out
