"""Jaxpr traversal + Pallas introspection primitives for the static checkers.

Everything the analysis subsystem knows about JAX internals lives here:
recursive equation iteration (through pjit / scan / while / cond sub-jaxprs),
``pallas_call`` discovery, memory-space classification of kernel operands,
and the DMA happens-before abstract interpretation over an unrolled kernel
body. The contract/hot-path passes above this module only consume the small
dataclasses it returns, so a JAX upgrade that moves an attribute breaks ONE
file.

Layout facts this module relies on (verified against the pinned jax):

  * a ``pallas_call`` eqn's ``params["jaxpr"]`` is the kernel body whose
    invars are ``AbstractMemoryRef``s ordered (scalar-prefetch, inputs,
    outputs, scratch) — ``PrefetchScalarGridSpec`` operands arrive FIRST, as
    SMEM refs, counted by ``grid_mapping.num_index_operands``;
    ``params["grid_mapping"]`` carries ``grid``, ``block_mappings`` (inputs +
    outputs only, scalars excluded), and the ``num_*`` operand counts;
  * ``dma_start`` / ``dma_wait`` eqns share one invar layout — the flat
    ``(src_ref, *src_idx, dst_ref, *dst_idx, sem_ref, *sem_idx)`` copy
    descriptor — with constant indices appearing as ``Literal``s;
  * VMEM ref reads/writes are ``get`` / ``swap`` eqns whose first invar is
    the ref and whose remaining invars are index atoms (``swap`` interposes
    the stored value at position 1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, Optional, Sequence, Tuple

import numpy as np

from jax._src.core import ClosedJaxpr, Jaxpr, JaxprEqn, Literal, Var


# --------------------------------------------------------------------------
# generic traversal
# --------------------------------------------------------------------------


def _param_jaxprs(value: Any) -> Iterator[Jaxpr]:
    """Yield every (Closed)Jaxpr reachable from one eqn param value."""
    stack = [value]
    while stack:
        v = stack.pop()
        if isinstance(v, Jaxpr):
            yield v
        elif isinstance(v, ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, (list, tuple)):
            stack.extend(v)
        elif isinstance(v, dict):
            stack.extend(v.values())


def sub_jaxprs(eqn: JaxprEqn) -> list[Jaxpr]:
    """All sub-jaxprs of one equation (pjit body, scan/while/cond branches...)."""
    out: list[Jaxpr] = []
    for v in eqn.params.values():
        out.extend(_param_jaxprs(v))
    return out


def iter_eqns(jaxpr: Jaxpr) -> Iterator[JaxprEqn]:
    """Depth-first iteration over every equation, including nested jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def find_primitives(jaxpr: Jaxpr, match: Callable[[str], bool]) -> list[JaxprEqn]:
    """Equations (at any nesting depth) whose primitive name satisfies ``match``."""
    return [e for e in iter_eqns(jaxpr) if match(e.primitive.name)]


def find_pallas_calls(jaxpr: Jaxpr) -> list[JaxprEqn]:
    return find_primitives(jaxpr, lambda n: n == "pallas_call")


# --------------------------------------------------------------------------
# memory-space / size classification
# --------------------------------------------------------------------------


def is_ref(atom: Any) -> bool:
    """True for a jaxpr atom whose aval is a (memory) ref."""
    if isinstance(atom, Literal):
        return False
    return hasattr(atom.aval, "inner_aval")


def memory_space_of(aval: Any) -> str:
    """Normalized memory space of a kernel ref aval.

    Pallas leaves the default (pipelined VMEM block) space as ``None``; the
    explicit spaces stringify to ``any`` / ``vmem`` / ``smem`` /
    ``semaphore_mem`` across the jax versions we care about.
    """
    ms = getattr(aval, "memory_space", None)
    if ms is None:
        return "vmem"
    s = str(ms).lower()
    for known in ("semaphore", "smem", "vmem", "any", "hbm"):
        if known in s:
            return "hbm" if known == "any" else known
    return s


def aval_bytes(aval: Any) -> int:
    shape = getattr(aval, "shape", ())
    dtype = getattr(aval, "dtype", None)
    itemsize = np.dtype(dtype).itemsize if dtype is not None else 4
    return int(np.prod(shape, dtype=np.int64)) * itemsize if shape else itemsize


# --------------------------------------------------------------------------
# pallas_call operand bookkeeping
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelOperand:
    """One kernel-body invar, classified for the VMEM budget pass."""

    label: str  # e.g. "scalar[0]", "in[3] args[3]", "out[0]", "scratch[1]"
    role: str  # "scalar" | "in" | "out" | "scratch"
    space: str  # normalized memory space ("vmem", "hbm", "smem", "semaphore")
    block_shape: Tuple[int, ...]  # VMEM-resident tile shape (block or scratch)
    dtype: Any
    nbytes: int  # bytes of ONE buffer instance (no pipeline multiplier)
    array_shape: Tuple[int, ...]  # full HBM array shape ("" for scratch)
    block_mapping: Any = None  # the pallas BlockMapping (inputs/outputs only)


def _block_bytes(block_shape: Sequence[Any], dtype: Any) -> Tuple[Tuple[int, ...], int]:
    dims = tuple(int(d) for d in block_shape if d is not None)
    n = 1
    for d in dims:
        n *= d
    return dims, n * np.dtype(dtype).itemsize


def num_scalar_prefetch_operands(pallas_eqn: JaxprEqn) -> int:
    """Scalar-prefetch (``PrefetchScalarGridSpec``) operand count of one call."""
    return int(getattr(pallas_eqn.params["grid_mapping"], "num_index_operands", 0))


def kernel_operands(pallas_eqn: JaxprEqn) -> list[KernelOperand]:
    """Classify every kernel invar of one ``pallas_call`` equation."""
    gm = pallas_eqn.params["grid_mapping"]
    kernel_jaxpr: Jaxpr = pallas_eqn.params["jaxpr"]
    n_scalar = num_scalar_prefetch_operands(pallas_eqn)
    n_in = gm.num_inputs
    n_out = gm.num_outputs
    n_scratch = gm.num_scratch_operands
    invars = kernel_jaxpr.invars
    if len(invars) != n_scalar + n_in + n_out + n_scratch:
        raise ValueError(
            f"kernel jaxpr has {len(invars)} invars; grid_mapping claims "
            f"{n_scalar}+{n_in}+{n_out}+{n_scratch} "
            "(scalar-prefetch+inputs+outputs+scratch) — pallas internals "
            "changed, update jaxpr_walk.kernel_operands"
        )
    out: list[KernelOperand] = []
    mappings = list(gm.block_mappings)
    for i, var in enumerate(invars):
        aval = var.aval
        space = memory_space_of(aval)
        if i < n_scalar:
            # scalar-prefetch refs live in SMEM, carry no block mapping, and
            # cost no VMEM — but the budget/divisibility passes must still
            # see them so the invar count reconciles
            dtype = getattr(aval, "dtype", np.int32)
            shape = tuple(getattr(aval, "shape", ()))
            out.append(
                KernelOperand(
                    f"scalar[{i}]", "scalar", space, shape, dtype, aval_bytes(aval), ()
                )
            )
            continue
        i -= n_scalar
        if i < n_in + n_out:
            role = "in" if i < n_in else "out"
            idx = i if i < n_in else i - n_in
            bm = mappings[i] if i < len(mappings) else None
            origin = getattr(bm, "origin", "") if bm is not None else ""
            label = f"{role}[{idx}]" + (f" {origin}" if origin else "")
            if bm is not None:
                dtype = bm.array_shape_dtype.dtype
                block_shape, nbytes = _block_bytes(bm.block_shape, dtype)
                array_shape = tuple(bm.array_shape_dtype.shape)
            else:  # defensive: fall back to the aval itself
                dtype = getattr(aval, "dtype", np.float32)
                block_shape = tuple(getattr(aval, "shape", ()))
                nbytes = aval_bytes(aval)
                array_shape = block_shape
            out.append(
                KernelOperand(label, role, space, block_shape, dtype, nbytes, array_shape, bm)
            )
        else:
            j = i - n_in - n_out
            dtype = getattr(aval, "dtype", np.int32)
            shape = tuple(getattr(aval, "shape", ()))
            out.append(
                KernelOperand(
                    f"scratch[{j}]", "scratch", space, shape, dtype, aval_bytes(aval), ()
                )
            )
    return out


# --------------------------------------------------------------------------
# DMA happens-before abstract interpretation
# --------------------------------------------------------------------------


@dataclasses.dataclass
class PendingDma:
    """One in-flight async copy, keyed by its completion semaphore slot."""

    dst: Var
    dst_slot: Optional[int]  # None = statically unknown (matches any slot)
    sem: Var
    sem_idx: Optional[Tuple[int, ...]]  # None = statically unknown
    where: str  # human-readable start site


@dataclasses.dataclass
class DmaReport:
    """Result of the happens-before pass over one kernel jaxpr."""

    starts: int = 0
    waits: int = 0
    violations: list[str] = dataclasses.field(default_factory=list)


def _copy_descriptor(eqn: JaxprEqn) -> Tuple[Any, Optional[int], Any, Optional[Tuple[int, ...]]]:
    """Parse a dma_start/dma_wait invar list into (dst, slot, sem, sem_idx).

    The flat layout is ``(src_ref, *src_idx, dst_ref, *dst_idx, sem_ref,
    *sem_idx)``; groups are delimited by the ref-typed invars. Non-literal
    indices parse to ``None`` (= "unknown", matched conservatively).
    """
    groups: list[list[Any]] = []
    for v in eqn.invars:
        if is_ref(v):
            groups.append([v])
        elif groups:
            groups[-1].append(v)
    if len(groups) < 3:
        raise ValueError(
            f"{eqn.primitive.name} with {len(groups)} ref operands — expected "
            "(src, dst, sem); remote-copy layouts need a jaxpr_walk extension"
        )
    dst_ref, *dst_idx = groups[-2]
    sem_ref, *sem_idx = groups[-1]
    slot: Optional[int] = None
    for a in dst_idx:
        if isinstance(a, Literal):
            slot = int(a.val)
            break
    idx: Optional[Tuple[int, ...]]
    if all(isinstance(a, Literal) for a in sem_idx):
        idx = tuple(int(a.val) for a in sem_idx)
    else:
        idx = None
    return dst_ref, slot, sem_ref, idx


def _sem_matches(p: PendingDma, sem: Var, idx: Optional[Tuple[int, ...]]) -> bool:
    if p.sem is not sem:
        return False
    return p.sem_idx is None or idx is None or p.sem_idx == idx


def _slot_matches(pending_slot: Optional[int], access_slot: Optional[int]) -> bool:
    return pending_slot is None or access_slot is None or pending_slot == access_slot


def _access_slot(eqn: JaxprEqn) -> Optional[int]:
    """First literal index of a get/swap (the buffer-slot coordinate)."""
    start = 2 if eqn.primitive.name == "swap" else 1
    for a in eqn.invars[start:]:
        if isinstance(a, Literal):
            return int(a.val)
    return None


def check_dma_discipline(kernel_jaxpr: Jaxpr) -> DmaReport:
    """Happens-before over the unrolled kernel body.

    Flags, in program order:
      * a ``dma_start`` whose semaphore slot still has an un-waited copy in
        flight (the revolving-buffer reuse race);
      * a ``get``/``swap`` touching a destination buffer slot with a copy
        still in flight (read/write before wait);
      * a ``dma_wait`` with no matching start;
      * any copy still in flight when the body ends (start without wait).

    ``cond`` branches are analyzed independently and their in-flight sets
    merged by *intersection* (a copy waited on any path counts as waited):
    the lint gates CI, so a false "missing wait" on the epilogue-under-
    ``pl.when`` pipelining idiom would be worse than missing a race that
    only one branch closes. ``while``/``scan`` bodies are analyzed inline
    against the current in-flight set.
    """
    report = DmaReport()
    pending = _walk_dma(kernel_jaxpr, [], report)
    for p in pending:
        report.violations.append(
            f"dma_start at {p.where} is never waited on: destination "
            f"{_fmt_ref(p.dst)} slot {p.dst_slot} may still be in flight when "
            "the kernel body ends (missing make_async_copy(...).wait())"
        )
    return report


def _fmt_ref(var: Var) -> str:
    aval = var.aval
    return f"ref{getattr(aval, 'shape', '?')}@{memory_space_of(aval)}"


def _walk_dma(jaxpr: Jaxpr, pending: list[PendingDma], report: DmaReport) -> list[PendingDma]:
    pending = list(pending)
    for i, eqn in enumerate(jaxpr.eqns):
        name = eqn.primitive.name
        if name == "dma_start":
            dst, slot, sem, idx = _copy_descriptor(eqn)
            where = f"eqn {i} ({_fmt_ref(dst)} slot {slot}, sem idx {idx})"
            for p in pending:
                if _sem_matches(p, sem, idx):
                    report.violations.append(
                        f"dma_start at {where} reuses semaphore slot {idx} while "
                        f"the copy started at {p.where} is still in flight — "
                        "wait() must run before the slot revolves"
                    )
                elif p.dst is dst and _slot_matches(p.dst_slot, slot):
                    # different semaphore, same destination buffer slot: the
                    # trip-loop revolving-buffer race a per-trip sem rotation
                    # hides from the semaphore check above
                    report.violations.append(
                        f"dma_start at {where} overwrites destination "
                        f"{_fmt_ref(dst)} slot {slot} while the copy started at "
                        f"{p.where} is still in flight into the same slot — "
                        "the two copies race on the buffer even though their "
                        "semaphores differ; wait() the first before revolving"
                    )
            report.starts += 1
            pending.append(PendingDma(dst, slot, sem, idx, where))
        elif name == "dma_wait":
            dst, slot, sem, idx = _copy_descriptor(eqn)
            matched = [p for p in pending if _sem_matches(p, sem, idx)]
            if not matched:
                report.violations.append(
                    f"dma_wait at eqn {i} (sem idx {idx}) has no matching "
                    "dma_start on this path — wait on an idle semaphore "
                    "deadlocks on device"
                )
            else:
                pending.remove(matched[0])
            report.waits += 1
        elif name in ("get", "swap") and eqn.invars and is_ref(eqn.invars[0]):
            ref = eqn.invars[0]
            slot = _access_slot(eqn)
            for p in pending:
                if p.dst is ref and _slot_matches(p.dst_slot, slot):
                    verb = "read" if name == "get" else "overwritten"
                    report.violations.append(
                        f"{_fmt_ref(ref)} slot {slot} is {verb} at eqn {i} while "
                        f"the copy started at {p.where} is still in flight — "
                        "missing wait() before the access"
                    )
        elif name == "cond":
            branches = [b for b in sub_jaxprs(eqn)]
            if branches:
                results = [_walk_dma(b, pending, report) for b in branches]
                # intersection-by-identity: survive only if pending on EVERY path
                pending = [
                    p for p in results[0] if all(any(q is p for q in r) for r in results[1:])
                ]
        else:
            for sub in sub_jaxprs(eqn):
                pending = _walk_dma(sub, pending, report)
    return pending
