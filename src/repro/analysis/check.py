"""Static-analysis gate: ``python -m repro.analysis.check --all``.

Runs the two trace-time passes over everything checked in:

  * **kernel contracts** — every ``repro.kernels.*`` package's ``CONTRACT``
    (VMEM budget, DMA happens-before, grid/index-map divisibility) across
    its declared shape grid; see :mod:`repro.analysis.kernel_contracts`.
  * **serving hot paths** — the ``AnytimeServer`` executable grid for the
    full engine/flag matrix plus the sharded+bucketed serve step, on a tiny
    synthetic probe index; see :mod:`repro.analysis.hot_path`.

Everything is ``jax.make_jaxpr`` over ShapeDtypeStructs: no kernel executes,
no device memory is allocated beyond the probe index, and the whole gate runs
in CI's ``analysis`` lane in well under a minute. Exit status is the number
of violations (0 = clean), each printed as ``[contract / case / check]
message``.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

import numpy as np


def _probe_index(seed: int = 0, n_docs: int = 220, n_terms: int = 40,
                 n_postings: int = 1500, block_size: int = 32):
    """Tiny synthetic impact index: big enough to exercise every phase,
    small enough that building it dominates nothing."""
    from repro.core import build_impact_index

    rng = np.random.default_rng(seed)
    return build_impact_index(
        rng.integers(0, n_docs, n_postings),
        rng.integers(0, n_terms, n_postings),
        rng.uniform(0.1, 5.0, n_postings).astype(np.float32),
        n_docs,
        n_terms,
        block_size=block_size,
    )


def serving_config_matrix(lq_buckets: tuple = (4, 8), k: int = 5):
    """Every engine/flag combination the serving layer can dispatch.

    One ServingConfig per point of the paper's comparison: SAAT across its
    scatter implementations and the fused top-k, DAAT across the jnp oracle,
    kernel-backed phase 2, and the fused chunk step.
    """
    from repro.serving.scheduler import ServingConfig

    saat = dict(engine="saat", k=k, rho_ladder=(200, 1000), lq_buckets=lq_buckets)
    daat = dict(
        engine="daat", k=k, daat_est_blocks=4, daat_block_budget=4,
        lq_buckets=lq_buckets,
    )
    return (
        ServingConfig(scatter_impl="jnp", **saat),
        ServingConfig(scatter_impl="sort", **saat),
        ServingConfig(scatter_impl="pallas", **saat),
        ServingConfig(scatter_impl="sort", fused_topk=True, **saat),
        ServingConfig(**daat),
        ServingConfig(daat_use_kernels=True, **daat),
        ServingConfig(daat_use_kernels=True, daat_fused_chunk=True, **daat),
        ServingConfig(
            daat_use_kernels=True, daat_fused_chunk=True,
            daat_trips_per_launch=4, **daat,
        ),
    )


def run_daat_phase0_checks() -> list:
    """Assert kernel-mode phase 0 never densifies the block-max lists.

    Traces ``daat_search_batched(use_kernels=True)`` over ShapeDtypeStructs
    on the probe index and scans the jaxpr for any aval of the densified
    ``[B, Lq, n_blocks]`` shape — the intermediate the CSR prune kernel
    exists to eliminate.
    """
    import jax
    import jax.numpy as jnp

    from repro.analysis.hot_path import check_no_densified_blockmax
    from repro.core import daat_search_batched
    from repro.core.daat import max_blocks_per_term

    index = _probe_index()
    mb = max_blocks_per_term(index)
    out = []
    for B, lq in ((2, 6), (4, 8)):
        jaxpr = jax.make_jaxpr(
            lambda qt, qw: daat_search_batched(
                index, qt, qw, k=5, est_blocks=4, block_budget=4,
                max_bm_per_term=mb, exact=True, use_kernels=True,
            )
        )(
            jax.ShapeDtypeStruct((B, lq), jnp.int32),
            jax.ShapeDtypeStruct((B, lq), jnp.float32),
        )
        vs = check_no_densified_blockmax(
            jaxpr, (B, lq, index.n_blocks),
            label="daat:kernels:phase0", case=f"B{B}_lq{lq}",
        )
        print(f"  daat kernel-mode phase 0 B={B} Lq={lq} "
              f"(no densified block-max): {len(vs)} violations")
        out.extend(vs)
    return out


def run_kernel_checks(names: Optional[Sequence[str]] = None) -> list:
    from repro.analysis.kernel_contracts import all_contracts, check_contract

    contracts = all_contracts()
    if names:
        unknown = sorted(set(names) - set(contracts))
        if unknown:
            raise SystemExit(
                f"unknown contract(s) {unknown}; have {sorted(contracts)}"
            )
        contracts = {n: contracts[n] for n in names}
    out = []
    for name, contract in contracts.items():
        vs = check_contract(contract)
        print(f"  contract {name}: {len(contract.shape_grid)} cases, "
              f"{len(vs)} violations")
        out.extend(vs)
    return out


def run_serving_checks(batch_sizes: Sequence[int] = (2, 4)) -> list:
    import jax
    from jax.sharding import Mesh

    from repro.analysis.hot_path import lint_server, lint_sharded_serve
    from repro.core.saat import max_segments_per_term
    from repro.serving.scheduler import AnytimeServer
    from repro.serving.sharded import (
        make_bucketed_serve_step, shard_corpus, stack_indexes,
    )

    index = _probe_index()
    out = []
    for cfg in serving_config_matrix():
        label = f"server:{cfg.engine}:scatter={cfg.scatter_impl}" + (
            ":fused_topk" if cfg.fused_topk else ""
        ) + (":kernels" if cfg.daat_use_kernels else "") + (
            ":fused_chunk" if cfg.daat_fused_chunk else ""
        ) + (
            f":trips{cfg.daat_trips_per_launch}"
            if cfg.daat_trips_per_launch > 1 else ""
        )
        vs = lint_server(
            AnytimeServer(index, cfg), batch_sizes=batch_sizes, label=label
        )
        print(f"  {label}: {len(vs)} violations")
        out.extend(vs)

    # generation-extended matrix: one handle-backed server per engine, linted
    # in its churned generation-0 state (main + delta + tombstones) and again
    # after compact()+swap_index(), all into ONE shared key registry. The
    # executable-key bijection must hold ACROSS generations: the pre-swap
    # delta-merging program and the post-swap delta-free program are
    # genuinely different executables, so their keys must differ — while a
    # key that changed with the generation counter alone (same program both
    # sides) would be flagged as two names for one executable.
    from repro.core.index_handle import IndexHandle
    from repro.serving.scheduler import ServingConfig

    hrng = np.random.default_rng(3)
    h_docs, h_terms, h_post = 220, 40, 1500
    handle = IndexHandle.from_corpus(
        hrng.integers(0, h_docs, h_post), hrng.integers(0, h_terms, h_post),
        hrng.uniform(0.1, 5.0, h_post).astype(np.float32),
        h_docs, h_terms, block_size=32,
    )
    for gid in (3, 11, 19):
        handle.delete(gid)
    handle.add(np.array([1, 4, 7]), np.array([1.0, 2.0, 0.5]))
    handle.update(5, np.array([2, 6]), np.array([1.5, 2.5]))
    gen_reg: dict = {}
    gen_cfgs = (
        ServingConfig(engine="saat", k=5, rho_ladder=(200, 1000),
                      lq_buckets=(4, 8), scatter_impl="jnp"),
        ServingConfig(engine="daat", k=5, daat_est_blocks=4,
                      daat_block_budget=4, lq_buckets=(4, 8)),
    )
    gen_servers = [AnytimeServer(handle, cfg) for cfg in gen_cfgs]
    for phase in ("gen0", "gen1"):
        for cfg, server in zip(gen_cfgs, gen_servers):
            label = f"server:handle:{cfg.engine}:{phase}"
            vs = lint_server(
                server, batch_sizes=batch_sizes, label=label,
                key_registry=gen_reg,
            )
            print(f"  {label}: {len(vs)} violations")
            out.extend(vs)
        if phase == "gen0":
            handle.compact()
            for server in gen_servers:
                server.swap_index()

    # the pod-scale step: 1-device mesh is enough to trace the shard_map body
    rng = np.random.default_rng(1)
    n_docs, n_terms, n_post = 256, 32, 1200
    shards, docs_per_shard = shard_corpus(
        rng.integers(0, n_docs, n_post), rng.integers(0, n_terms, n_post),
        rng.uniform(0.1, 5.0, n_post).astype(np.float32),
        n_docs, n_terms, 1, block_size=32,
    )
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    serve, _, _ = make_bucketed_serve_step(
        mesh, lq_buckets=(4, 8), n_terms=n_terms, k=5, rho_per_shard=500,
        max_segs_per_term=max_segments_per_term(shards[0]),
        docs_per_shard=docs_per_shard,
    )
    key_reg: dict = {}
    vs = lint_sharded_serve(
        serve, stack_indexes(shards), batch_sizes=(2,), key_registry=key_reg,
    )
    print(f"  sharded+bucketed serve: {len(vs)} violations")
    out.extend(vs)

    # the pod step proper: a "pod" mesh axis routes make_bucketed_serve_step
    # to make_pod_serve_step (cross-host gather + canonical k-merge). A 2x2
    # mesh when the host platform simulates >=4 devices, else 1x1 — the
    # shard_map body traces identically, so the lint matrix stays covered on
    # single-device CI lanes too. Same key_registry as the sharded step: the
    # pod statics must name a distinct executable from the single-host one.
    if jax.device_count() >= 4:
        pod_devs, pod_shape = jax.devices()[:4], (2, 2)
    else:
        pod_devs, pod_shape = jax.devices()[:1], (1, 1)
    n_shards = pod_shape[0] * pod_shape[1]
    pod_shards, pod_dps = shard_corpus(
        rng.integers(0, n_docs, n_post), rng.integers(0, n_terms, n_post),
        rng.uniform(0.1, 5.0, n_post).astype(np.float32),
        n_docs, n_terms, n_shards, block_size=32,
    )
    pod_mesh = Mesh(np.array(pod_devs).reshape(pod_shape), ("pod", "model"))
    pod_serve, _, _ = make_bucketed_serve_step(
        pod_mesh, lq_buckets=(4, 8), n_terms=n_terms, k=5, rho_per_shard=500,
        max_segs_per_term=max_segments_per_term(pod_shards[0]),
        docs_per_shard=pod_dps, n_docs_total=n_docs,
    )
    vs = lint_sharded_serve(
        pod_serve, stack_indexes(pod_shards), batch_sizes=(2,),
        label=f"pod{pod_shape[0]}x{pod_shape[1]}", key_registry=key_reg,
    )
    print(f"  pod{pod_shape[0]}x{pod_shape[1]} serve: {len(vs)} violations")
    out.extend(vs)
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.check", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    p.add_argument("--all", action="store_true",
                   help="run kernel contracts AND serving hot-path lint")
    p.add_argument("--kernels", action="store_true",
                   help="run the kernel contract checker only")
    p.add_argument("--serving", action="store_true",
                   help="run the serving hot-path lint only")
    p.add_argument("--contract", action="append", metavar="NAME",
                   help="restrict --kernels to the named contract(s)")
    p.add_argument("--list", action="store_true",
                   help="list registered contracts and exit")
    args = p.parse_args(argv)

    if args.list:
        from repro.analysis.kernel_contracts import all_contracts

        for name, c in sorted(all_contracts().items()):
            cases = ", ".join(case.name for case in c.shape_grid)
            print(f"{name}: {c.description or '(no description)'}")
            print(f"  cases: {cases}")
            print(f"  vmem limit: {c.vmem_limit_bytes} B, expect_dma={c.expect_dma}")
        return 0

    do_kernels = args.kernels or args.all or args.contract
    do_serving = args.serving or args.all
    if not (do_kernels or do_serving):
        p.error("pick one of --all / --kernels / --serving / --list")

    violations = []
    if do_kernels:
        print("kernel contracts:")
        violations += run_kernel_checks(args.contract)
    if do_serving:
        print("serving hot paths:")
        violations += run_serving_checks()
        violations += run_daat_phase0_checks()

    if violations:
        print(f"\n{len(violations)} violation(s):", file=sys.stderr)
        for v in violations:
            print(f"  {v}", file=sys.stderr)
    else:
        print("\nall checks passed")
    return min(len(violations), 255)


if __name__ == "__main__":
    sys.exit(main())
