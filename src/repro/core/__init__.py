"""The paper's primary contribution: impact-quantized learned-sparse retrieval
with anytime SAAT and block-max DAAT query evaluation, TPU-native.

Public API:
    QuantConfig, quantize, dequantize     impact quantization
    ImpactIndex, build_impact_index       JASS-style impact-ordered index
    IndexHandle                           mutable lifecycle (delta/tombstones/compaction)
    saat_search, exact_rho                anytime SAAT (rho posting budget)
    daat_search_batched                   natively batched Block-Max DAAT
    blockmax_search / daat_search_vmap    vmapped Block-Max DAAT (parity oracle)
    exhaustive_search                     rank-safe exhaustive disjunction
    wacky.*                               weight-wackiness analyzers
    pareto.*                              effectiveness/efficiency frontier
"""
from repro.core.daat import (  # noqa: F401
    DaatPlan,
    DaatResult,
    WorkStats,
    blockmax_search,
    block_upper_bounds,
    daat_plan,
    daat_search_batched,
    daat_search_vmap,
    max_blocks_per_term,
    query_vectors,
    score_blocks,
)
from repro.core.exhaustive import ExhaustiveResult, exhaustive_search, score_all_docs  # noqa: F401
from repro.core.impact_index import (  # noqa: F401
    ImpactIndex,
    build_impact_index,
    extract_doc_coo,
    pad_queries,
    query_vector,
)
from repro.core.index_handle import (  # noqa: F401
    HandleResult,
    IndexHandle,
    search_delta_pool,
)
from repro.core.pareto import OperatingPoint, frontier_table, pareto_frontier  # noqa: F401
from repro.core.quantization import (  # noqa: F401
    QuantConfig,
    accumulator_analysis,
    dequantize,
    quantization_error,
    quantize,
)
from repro.core.saat import (  # noqa: F401
    SaatResult,
    exact_rho,
    max_segments_per_term,
    saat_plan,
    saat_search,
    saat_search_vmap,
)
from repro.core.topk import (  # noqa: F401
    merge_pools_by_id,
    merge_topk,
    sharded_topk_merge,
    tiled_topk,
    topk,
)
