"""Effectiveness/efficiency Pareto frontier (paper Figure 3).

A configuration (retrieval model x system x operating point) is on the
frontier iff no other configuration has both higher effectiveness and lower
mean latency. The paper's headline observation: *every* retrieval model is
Pareto-optimal under some system, and PISA(DAAT) / JASS-approx(SAAT) share the
frontier.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    name: str  # e.g. "splade/saat-rho=5m"
    model: str
    system: str
    effectiveness: float  # e.g. mean RR@10 (higher better)
    latency_ms: float  # mean query latency (lower better)
    extra: dict = dataclasses.field(default_factory=dict)


def pareto_frontier(points: Sequence[OperatingPoint]) -> list[OperatingPoint]:
    """Non-dominated subset, sorted by latency ascending."""
    pts = sorted(points, key=lambda p: (p.latency_ms, -p.effectiveness))
    frontier: list[OperatingPoint] = []
    best_eff = float("-inf")
    for p in pts:
        if p.effectiveness > best_eff:
            frontier.append(p)
            best_eff = p.effectiveness
    return frontier


def dominated_by(p: OperatingPoint, points: Sequence[OperatingPoint]) -> list[OperatingPoint]:
    """All points that dominate p (strictly better on one axis, >= on both)."""
    out = []
    for q in points:
        if q is p:
            continue
        if (
            q.effectiveness >= p.effectiveness
            and q.latency_ms <= p.latency_ms
            and (q.effectiveness > p.effectiveness or q.latency_ms < p.latency_ms)
        ):
            out.append(q)
    return out


def frontier_table(points: Sequence[OperatingPoint]) -> list[dict]:
    frontier = set(id(p) for p in pareto_frontier(points))
    rows = []
    for p in sorted(points, key=lambda p: p.latency_ms):
        rows.append(
            {
                "name": p.name,
                "model": p.model,
                "system": p.system,
                "effectiveness": round(p.effectiveness, 4),
                "latency_ms": round(p.latency_ms, 3),
                "pareto": id(p) in frontier,
                **p.extra,
            }
        )
    return rows
