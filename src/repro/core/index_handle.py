"""Generation-based mutable index lifecycle over immutable ``ImpactIndex``es.

Every engine in this repo consumes an immutable :class:`ImpactIndex` — the
right contract for jitted kernels, but a non-starter for a living corpus.
``IndexHandle`` closes the gap with the classic LSM-ish triple:

  * a **main** segment: the big immutable ``ImpactIndex`` (global doc id
    ``gid`` == main-local doc id);
  * a **delta** segment: a small ``ImpactIndex`` rebuilt host-side on every
    mutation from the raw added/updated documents, with local ids assigned in
    ascending-gid order and the SAME quantization grid / block constants as
    main (so every kernel CONTRACT and the cross-segment score units hold);
  * a **tombstone bitmap**: deleted (or updated-in-place) main docs flip a
    bit; the engines' ``live_mask`` paths score them ``-inf`` with zero
    rebuild work.

Search = engine over main (tombstones masked) + exact search over delta
(delta-local ids mapped back to gids) + :func:`repro.core.topk.merge_pools_by_id`,
whose stable id-ascending reorder reproduces the dense-accumulator tie order
— so a mutated handle answers bit-identically (ids and scores at finite
positions) to a from-scratch rebuild of the post-mutation corpus over the
same gid space with the same tombstone mask.

Compaction (:meth:`IndexHandle.compact`) folds main + delta − tombstones into
a fresh main segment off the serving path and bumps ``generation``; the
serving layers hot-swap on that counter between admission-queue flushes.
Tombstoned gids stay dead after compaction (the gid space never re-uses ids),
which is exactly what keeps the same-docspace parity oracle valid across
generations.

Quantization idempotence across compactions: the doc-major store holds
*dequantized* impacts ``q * scale``. The compactor recovers the integer
impacts (``q = round(w / scale)`` — exact, the f32 rounding error is ~1e-7
of a level) and feeds the builder mid-step weights ``(q - 0.5) * scale``
with the pinned grid, which re-quantize to exactly ``q`` (``ceil`` lands on
``q`` with half a level of slack on either side, instead of razor-edge on
the boundary like the raw dequantized values). Result: compaction is
bit-stable — impacts, segment weights, and block maxima never drift, no
matter how many generations pass.

Scope: uniform quantization scheme only (the repo default); the ``log``
scheme's dequantize is not an affine map so the mid-step trick above does
not apply.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import daat, saat
from repro.core.impact_index import ImpactIndex, build_impact_index, extract_doc_coo
from repro.core.quantization import QuantConfig
from repro.core.topk import merge_pools_by_id, topk


class HandleResult(NamedTuple):
    """Merged top-k over (main − tombstones) ∪ delta.

    ``main`` is the full engine result over the main segment (its
    ``WorkStats`` describe the anytime/budgeted part of the search); ``delta``
    is the delta-segment pool (``None`` when the delta is empty — the merge
    is skipped entirely and ``scores/doc_ids`` alias the main pool).
    """

    scores: jax.Array  # f32[B, <=k]
    doc_ids: jax.Array  # i32[B, <=k] global doc ids
    main: Any  # SaatResult | DaatResult over the main segment
    delta: Tuple[jax.Array, jax.Array] | None  # delta (scores, gids) pool

    @property
    def stats(self):
        """Main-segment ``WorkStats`` passthrough (DAAT only, else ``None``).

        The serving queue's survivor predictor reads ``res.stats`` — the
        budgeted main-segment search is the part whose work the predictor
        models; the delta's exhaustive pass is shape-fixed noise.
        """
        return getattr(self.main, "stats", None)


def search_delta_pool(
    delta: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    k: int,
    engine: str = "saat",
    scatter_impl: str = "jnp",
    fused_topk: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Exact top-k pool over a delta segment: ``(scores, local_ids)``.

    The delta is tiny, so both engines search it exhaustively: SAAT at its
    own ``exact_rho``; DAAT as a phase-1-only pass over every delta block
    (no pruning — selection order is ascending flat position, i.e. ascending
    local id, which is exactly the canonical merge's tie order). Shared by
    :class:`IndexHandle` and the pod front end's host-local delta merge.
    """
    if engine == "saat":
        res = saat.saat_search(
            delta, q_terms, q_weights, k=k, rho=saat.exact_rho(delta),
            max_segs_per_term=saat.max_segments_per_term(delta),
            scatter_impl=scatter_impl, fused_topk=fused_topk,
        )
        return res.scores, res.doc_ids
    B = q_terms.shape[0]
    qvec = daat.query_vectors(delta, q_terms, q_weights)
    block_ids = jnp.broadcast_to(
        jnp.arange(delta.n_blocks, dtype=jnp.int32)[None, :], (B, delta.n_blocks)
    )
    s, d = daat.score_blocks(delta, qvec, block_ids)
    ds, dpos = topk(s.reshape(B, -1), k)
    dlocal = jnp.take_along_axis(d.reshape(B, -1), dpos, axis=-1)
    return ds, dlocal


class IndexHandle:
    """Mutable corpus facade: main segment + delta segment + tombstones.

    Host-side mutable object (NOT a pytree): mutations rebuild the small
    delta index synchronously; searches launch the same jitted engines the
    immutable path uses. Global doc ids are stable forever — ``add`` assigns
    ``next_gid`` and ids are never re-used, so external id maps survive any
    number of mutations and compactions.
    """

    def __init__(
        self,
        main: ImpactIndex,
        *,
        quant_max_weight: float | None = None,
    ):
        if main.n_blocks * main.block_size != main.doc_terms.shape[0]:
            raise ValueError("main index doc-major store is not block-aligned")
        self.main = main
        self.generation = 0
        # pinned quantization grid: every delta build and every compaction
        # quantizes onto main's grid so impacts stay comparable across
        # segments and bit-stable across generations
        self.quant_max_weight = (
            float(quant_max_weight)
            if quant_max_weight is not None
            else float(main.scale) * QuantConfig(bits=main.bits).levels
        )
        self._next_gid = main.n_docs
        self._dead: set[int] = set()
        # raw (terms, weights) per delta gid — the delta index is derived
        self._delta: dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._delta_index: ImpactIndex | None = None
        self._delta_gids: jax.Array | None = None
        self._live_np = np.zeros(main.doc_terms.shape[0], np.int32)
        self._live_np[: main.n_docs] = 1
        self._live_dev = jnp.asarray(self._live_np)

    # ------------------------------------------------------------------ build
    @classmethod
    def from_corpus(
        cls,
        doc_idx: np.ndarray,
        term_idx: np.ndarray,
        weights: np.ndarray,
        n_docs: int,
        n_terms: int,
        *,
        quant: QuantConfig = QuantConfig(bits=8),
        block_size: int = 128,
        quant_max_weight: float | None = None,
        **build_kwargs,
    ) -> "IndexHandle":
        """Build the generation-0 handle from COO postings.

        For an empty corpus (``n_docs`` may still be > 0) pass
        ``quant_max_weight`` explicitly — otherwise the grid pins to the
        empty build's default max weight of 1.0 and later heavier documents
        quantize clipped.
        """
        if quant.scheme != "uniform":
            raise ValueError("IndexHandle requires the uniform quantization scheme")
        main = build_impact_index(
            doc_idx, term_idx, weights, n_docs, n_terms,
            quant=quant, block_size=block_size,
            quant_max_weight=quant_max_weight, **build_kwargs,
        )
        return cls(main, quant_max_weight=quant_max_weight)

    # ------------------------------------------------------------- properties
    @property
    def n_docs(self) -> int:
        """Size of the global doc-id space (monotone; includes dead gids)."""
        return self._next_gid

    @property
    def n_terms(self) -> int:
        return self.main.n_terms

    @property
    def live_mask(self) -> jax.Array:
        """i32[main n_docs_pad] tombstone bitmap the engines consume."""
        return self._live_dev

    @property
    def delta(self) -> ImpactIndex | None:
        """The delta segment index (``None`` when no docs are pending)."""
        return self._delta_index

    @property
    def delta_docs(self) -> int:
        return len(self._delta)

    @property
    def delta_gids(self) -> jax.Array | None:
        """local->gid map for the delta segment (``None`` with no delta).

        Padded to the delta's doc pad with gid 0 — safe because pad slots
        score ``-inf`` and the canonical merge lets every finite candidate
        beat them. Hand this (with :attr:`delta`) to a pod front end's
        ``set_lifecycle`` so remote hosts run the same gid mapping.
        """
        return self._delta_gids

    @property
    def tombstone_count(self) -> int:
        return len(self._dead)

    @property
    def dead_gids(self) -> frozenset[int]:
        return frozenset(self._dead)

    def live_mask_full(self, pad_to: int | None = None) -> np.ndarray:
        """i32 live bitmap over the FULL gid space — the parity oracle's mask.

        A from-scratch rebuild of the post-mutation corpus over
        ``n_docs = handle.n_docs`` must be searched with exactly this mask to
        reproduce the handle's answers: live gids 1, tombstoned gids 0, pad
        slots (``>= n_docs``) 0.
        """
        n = self._next_gid
        mask = np.ones(max(pad_to or n, n), np.int32)
        mask[n:] = 0
        for gid in self._dead:
            mask[gid] = 0
        return mask

    # -------------------------------------------------------------- mutations
    def add(self, terms: np.ndarray, weights: np.ndarray) -> int:
        """Add a new document; returns its (stable, never re-used) gid."""
        gid = self._next_gid
        self._next_gid += 1
        self._set_delta_doc(gid, terms, weights)
        return gid

    def update(self, gid: int, terms: np.ndarray, weights: np.ndarray) -> None:
        """Replace a document's sparse vector in place (same gid).

        A main-resident doc is tombstoned in main and reborn in the delta —
        the precondition :func:`repro.core.topk.merge_pools_by_id` relies on
        (a live doc appears in at most one pool).
        """
        if not 0 <= gid < self._next_gid:
            raise KeyError(f"gid {gid} was never allocated")
        self._dead.discard(gid)
        self._set_delta_doc(gid, terms, weights)

    def delete(self, gid: int) -> None:
        """Tombstone a document (idempotent; the gid is never re-used)."""
        if not 0 <= gid < self._next_gid:
            raise KeyError(f"gid {gid} was never allocated")
        self._dead.add(gid)
        dropped = self._delta.pop(gid, None)
        if gid < self.main.n_docs and self._live_np[gid]:
            self._live_np[gid] = 0
            self._live_dev = jnp.asarray(self._live_np)
        if dropped is not None:
            self._rebuild_delta()

    def _set_delta_doc(self, gid: int, terms: np.ndarray, weights: np.ndarray) -> None:
        terms = np.asarray(terms, dtype=np.int64).ravel()
        weights = np.asarray(weights, dtype=np.float64).ravel()
        if terms.shape != weights.shape:
            raise ValueError("terms/weights length mismatch")
        if terms.size and (terms.min() < 0 or terms.max() >= self.n_terms):
            raise ValueError("term id outside the handle's fixed vocabulary")
        keep = weights > 0
        self._delta[gid] = (terms[keep], weights[keep])
        if gid < self.main.n_docs and self._live_np[gid]:
            self._live_np[gid] = 0  # the delta copy supersedes the main copy
            self._live_dev = jnp.asarray(self._live_np)
        self._rebuild_delta()

    def _rebuild_delta(self) -> None:
        """Rebuild the delta segment from the raw pending docs.

        Local ids are assigned in ascending-gid order so the delta engines'
        tie order (ascending local id) maps to ascending gid — the invariant
        that makes the canonical merge reproduce single-index tie order.
        """
        if not self._delta:
            self._delta_index = None
            self._delta_gids = None
            return
        gids = sorted(self._delta)
        d, t, w = [], [], []
        for local, gid in enumerate(gids):
            terms, weights = self._delta[gid]
            d.append(np.full(terms.size, local, np.int64))
            t.append(terms)
            w.append(weights)
        self._delta_index = build_impact_index(
            np.concatenate(d) if d else np.zeros(0, np.int64),
            np.concatenate(t) if t else np.zeros(0, np.int64),
            np.concatenate(w) if w else np.zeros(0, np.float64),
            len(gids),
            self.n_terms,
            quant=QuantConfig(bits=self.main.bits),
            block_size=self.main.block_size,
            quant_max_weight=self.quant_max_weight,
        )
        pad = self._delta_index.doc_terms.shape[0]
        gid_arr = np.zeros(pad, np.int32)
        gid_arr[: len(gids)] = np.asarray(gids, np.int32)
        self._delta_gids = jnp.asarray(gid_arr)

    # ------------------------------------------------------------- compaction
    def _grid_coo(
        self, index: ImpactIndex, live: np.ndarray | None
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Extract COO from a segment with re-quantization-stable weights.

        Recovers the integer impacts from the dequantized store and returns
        mid-step weights ``(q - 0.5) * scale``: far from every ``ceil``
        boundary, so building with the pinned grid reproduces ``q`` exactly
        (see module docstring).
        """
        d, t, w = extract_doc_coo(index, live)
        scale = self.quant_max_weight / QuantConfig(bits=index.bits).levels
        q = np.round(w / scale)
        return d, t, (q - 0.5) * scale

    def export_coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Requantization-stable COO of the live MAIN segment.

        The re-shard read path: feed this to ``shard_corpus(...,
        quant_max_weight=handle.quant_max_weight)`` after a compaction and
        the rebuilt shards carry bit-identical impacts to :attr:`main`
        (mid-step weights, see :meth:`_grid_coo`). Raw
        :func:`~repro.core.impact_index.extract_doc_coo` output is NOT
        stable under a ``ceil`` rebuild — upper-step weights sit exactly on
        the boundary and float error bumps half the postings a level.
        Delta docs are excluded; compact first (or ship :attr:`delta` +
        :attr:`delta_gids` alongside, as the pod front end does).
        """
        return self._grid_coo(self.main, self._live_np)

    def compact(self) -> None:
        """Fold main + delta − tombstones into a fresh main; bump generation.

        Runs entirely off the serving path (host-side numpy + one index
        build); the caller hot-swaps the handle into the serving stack
        between admission-queue flushes. Tombstoned gids stay dead (ids are
        never re-used), the delta empties, and the quantization grid is
        unchanged — so post-compaction answers are bit-identical to
        pre-compaction answers for every query.
        """
        parts = [self._grid_coo(self.main, self._live_np)]
        if self._delta_index is not None:
            gids = np.asarray(sorted(self._delta), np.int64)
            d, t, w = self._grid_coo(self._delta_index, None)
            parts.append((gids[d], t, w))
        d = np.concatenate([p[0] for p in parts])
        t = np.concatenate([p[1] for p in parts])
        w = np.concatenate([p[2] for p in parts])
        self.main = build_impact_index(
            d, t, w, self._next_gid, self.n_terms,
            quant=QuantConfig(bits=self.main.bits),
            block_size=self.main.block_size,
            quant_max_weight=self.quant_max_weight,
        )
        self._delta = {}
        self._delta_index = None
        self._delta_gids = None
        self._live_np = np.zeros(self.main.doc_terms.shape[0], np.int32)
        self._live_np[: self._next_gid] = 1
        for gid in self._dead:
            self._live_np[gid] = 0
        self._live_dev = jnp.asarray(self._live_np)
        self.generation += 1

    # ---------------------------------------------------------------- search
    def _merge_delta(
        self,
        main_scores: jax.Array,
        main_ids: jax.Array,
        delta_scores: jax.Array,
        delta_local_ids: jax.Array,
        k: int,
    ) -> Tuple[jax.Array, jax.Array, Tuple[jax.Array, jax.Array]]:
        gids = self._delta_gids[delta_local_ids]
        scores, ids = merge_pools_by_id(main_scores, main_ids, delta_scores, gids, k)
        return scores, ids, (delta_scores, gids)

    def saat_search(
        self,
        q_terms: jax.Array,
        q_weights: jax.Array,
        *,
        k: int,
        rho: int | None = None,
        scatter_impl: str = "jnp",
        fused_topk: bool = False,
    ) -> HandleResult:
        """Anytime SAAT over the live corpus. ``rho`` budgets MAIN only.

        The delta segment is tiny and always searched exactly (its own
        ``exact_rho``) — degrading a handful of just-written docs would buy
        nothing and cost freshness. Tombstoned docs score ``-inf`` via the
        engine's ``live_mask`` path; results merge rank-safely by gid.
        """
        main = self.main
        res_m = saat.saat_search(
            main, q_terms, q_weights, k=k,
            rho=int(rho) if rho is not None else saat.exact_rho(main),
            max_segs_per_term=saat.max_segments_per_term(main),
            scatter_impl=scatter_impl, fused_topk=fused_topk,
            live_mask=self._live_dev,
        )
        if self._delta_index is None:
            return HandleResult(res_m.scores, res_m.doc_ids, res_m, None)
        ds, dlocal = search_delta_pool(
            self._delta_index, q_terms, q_weights, k=k, engine="saat",
            scatter_impl=scatter_impl, fused_topk=fused_topk,
        )
        scores, ids, pool = self._merge_delta(
            res_m.scores, res_m.doc_ids, ds, dlocal, k
        )
        return HandleResult(scores, ids, res_m, pool)

    def daat_search(
        self,
        q_terms: jax.Array,
        q_weights: jax.Array,
        *,
        k: int,
        est_blocks: int,
        block_budget: int,
        exact: bool = True,
        max_chunks: int | None = None,
        use_kernels: bool = False,
        fused_chunk: bool = False,
        trips_per_launch: int = 1,
    ) -> HandleResult:
        """Block-max DAAT over the live corpus; skipping applies to MAIN only.

        The delta segment is scored exhaustively (every delta block — i.e. a
        phase-1-only pass; its tie order, ascending flat position == ascending
        gid, is exactly the canonical merge order). Fully-dead main blocks
        drop out of selection via the engine's ``live_mask`` path.
        """
        main = self.main
        res_m = daat.daat_search_batched(
            main, q_terms, q_weights, k=k, est_blocks=est_blocks,
            block_budget=block_budget,
            max_bm_per_term=daat.max_blocks_per_term(main),
            exact=exact, max_chunks=max_chunks, use_kernels=use_kernels,
            fused_chunk=fused_chunk, trips_per_launch=trips_per_launch,
            live_mask=self._live_dev,
        )
        if self._delta_index is None:
            return HandleResult(res_m.scores, res_m.doc_ids, res_m, None)
        ds, dlocal = search_delta_pool(
            self._delta_index, q_terms, q_weights, k=k, engine="daat"
        )
        scores, ids, pool = self._merge_delta(
            res_m.scores, res_m.doc_ids, ds, dlocal, k
        )
        return HandleResult(scores, ids, res_m, pool)
