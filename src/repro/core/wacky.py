"""Wacky-weights characterization (paper §4.2, Table 2).

Quantifies *why* learned sparse models break DAAT skipping:

  * Table-2 term statistics (vocab size, total/unique terms per doc/query) —
    "total" counts the pseudo-document trick's repeats, i.e. the sum of
    quantized weights.
  * weight-distribution shape (CV, skewness, entropy, Gini) — learned models
    produce flatter, heavier-mass distributions than BM25.
  * block-max tightness: mean over postings of blockmax(t, b) / max(t).
    Tight-to-1 means a block's bound is no better than the term's global
    bound, so Block-Max structures cannot skip.
  * skip opportunity: with the true top-k threshold theta in hand, the
    fraction of (nonempty) blocks whose upper bound falls below theta — the
    headroom any DAAT algorithm has. This is the paper's central mechanism,
    measured directly.
  * accumulator overflow (16-bit JASS accumulators vs learned weights).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantization
from repro.core.daat import block_upper_bounds
from repro.core.exhaustive import exhaustive_search
from repro.core.impact_index import ImpactIndex


@dataclasses.dataclass(frozen=True)
class TermStats:
    """One row of the Table 2 analogue."""

    vocab_size: int
    doc_total_terms: float  # mean sum of (quantized) weights per doc
    doc_unique_terms: float  # mean nnz per doc
    query_total_terms: float
    query_unique_terms: float

    def row(self) -> dict:
        return dataclasses.asdict(self)


def term_statistics(
    doc_idx: np.ndarray,
    term_idx: np.ndarray,
    weights: np.ndarray,
    n_docs: int,
    query_terms: Sequence[np.ndarray],
    query_weights: Sequence[np.ndarray],
    quant_bits: int = 8,
) -> TermStats:
    """Compute the Table 2 statistics from COO postings + ragged queries."""
    q, _ = quantization.quantize(weights, quantization.QuantConfig(bits=quant_bits))
    uniq = np.zeros(n_docs, dtype=np.int64)
    np.add.at(uniq, doc_idx, 1)
    total = np.zeros(n_docs, dtype=np.float64)
    np.add.at(total, doc_idx, q.astype(np.float64))
    vocab = int(np.unique(term_idx).size)
    qu = np.array([len(np.asarray(t)) for t in query_terms], dtype=np.float64)
    qt = []
    for w in query_weights:
        w = np.asarray(w, dtype=np.float64)
        qq, _ = quantization.quantize(w, quantization.QuantConfig(bits=quant_bits))
        qt.append(float(qq.sum()))
    return TermStats(
        vocab_size=vocab,
        doc_total_terms=float(total.mean()),
        doc_unique_terms=float(uniq.mean()),
        query_total_terms=float(np.mean(qt)) if qt else 0.0,
        query_unique_terms=float(qu.mean()) if qu.size else 0.0,
    )


def weight_distribution_stats(weights: np.ndarray) -> dict:
    """Shape statistics of a weight population (per retrieval model)."""
    w = np.asarray(weights, dtype=np.float64)
    w = w[w > 0]
    if w.size == 0:
        return {k: 0.0 for k in ("mean", "std", "cv", "skewness", "kurtosis", "entropy", "gini")}
    mean, std = float(w.mean()), float(w.std())
    z = (w - mean) / (std + 1e-12)
    hist, _ = np.histogram(w, bins=64, density=False)
    p = hist / max(hist.sum(), 1)
    p = p[p > 0]
    ws = np.sort(w)
    n = ws.size
    gini = float((2 * np.arange(1, n + 1) - n - 1).dot(ws) / (n * ws.sum() + 1e-12))
    return {
        "mean": mean,
        "std": std,
        "cv": std / (mean + 1e-12),
        "skewness": float((z**3).mean()),
        "kurtosis": float((z**4).mean()) - 3.0,
        "entropy": float(-(p * np.log2(p)).sum()),
        "gini": gini,
    }


def blockmax_tightness(index: ImpactIndex) -> dict:
    """How informative block maxima are. ~1.0 tightness => skipping is dead.

    ``tightness`` averages blockmax/termmax over (term, block) cells weighted
    uniformly; ``posting_weighted`` weights terms by posting count (what a
    query actually touches).
    """
    bm_w = np.asarray(jax.device_get(index.bm_weight), dtype=np.float64)
    bm_start = np.asarray(jax.device_get(index.term_bm_start), dtype=np.int64)
    bm_count = np.asarray(jax.device_get(index.term_bm_count), dtype=np.int64)
    tmax = np.asarray(jax.device_get(index.term_max_weight), dtype=np.float64)
    post = np.asarray(jax.device_get(index.term_post_count), dtype=np.float64)
    V = index.n_terms
    ratios, weights_uniform, weights_post = [], [], []
    term_of_cell = np.repeat(np.arange(V + 1), bm_count)
    tm = tmax[term_of_cell]
    ok = tm > 0
    r = bm_w / np.maximum(tm, 1e-12)
    ratios = r[ok]
    per_term_cells = bm_count[term_of_cell]
    weights_post = (post[term_of_cell] / np.maximum(per_term_cells, 1))[ok]
    return {
        "tightness": float(ratios.mean()) if ratios.size else 0.0,
        "posting_weighted": float((ratios * weights_post).sum() / max(weights_post.sum(), 1e-12)),
        "cells": int(ratios.size),
        "cells_per_term_mean": float(bm_count[:V][post[:V] > 0].mean()) if V else 0.0,
    }


def skip_opportunity(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    k: int,
    max_bm_per_term: int,
) -> dict:
    """Fraction of candidate blocks a rank-safe DAAT could skip (per query).

    theta is the *true* k-th score (from the exhaustive oracle), i.e. the best
    threshold any DAAT run could ever reach; the skippable fraction is
    therefore an upper bound on real skipping. The paper's claim: this
    collapses for learned-sparse ("wacky") weight distributions.
    """
    res = exhaustive_search(index, q_terms, q_weights, k=k)
    theta = res.scores[:, k - 1]  # [B]

    def one(qt, qw, th):
        ub = block_upper_bounds(index, qt, qw, max_bm_per_term)
        nonempty = ub > 0
        skippable = nonempty & (ub <= th)
        return (
            jnp.sum(skippable).astype(jnp.float32) / jnp.maximum(jnp.sum(nonempty), 1),
            jnp.sum(nonempty).astype(jnp.int32),
        )

    frac, nonempty = jax.vmap(one)(q_terms, q_weights, theta)
    frac = np.asarray(jax.device_get(frac), dtype=np.float64)
    return {
        "skippable_fraction_mean": float(frac.mean()),
        "skippable_fraction_p10": float(np.percentile(frac, 10)),
        "skippable_fraction_p90": float(np.percentile(frac, 90)),
        "candidate_blocks_mean": float(np.asarray(jax.device_get(nonempty)).mean()),
    }


def accumulator_overflow(index: ImpactIndex, query_weight_max: float = 1.0) -> dict:
    """The 16-vs-32-bit JASS accumulator observation (paper §3.2)."""
    sums = np.asarray(jax.device_get(index.doc_weight_sum), dtype=np.float64)
    sums = sums[: index.n_docs]
    return quantization.accumulator_analysis(sums, query_weight_max=query_weight_max, bits=16)


def full_report(
    name: str,
    index: ImpactIndex,
    doc_weights_raw: np.ndarray,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    k: int = 10,
    max_bm_per_term: int | None = None,
) -> dict:
    """One consolidated wackiness report per retrieval model."""
    from repro.core.daat import max_blocks_per_term

    if max_bm_per_term is None:
        max_bm_per_term = max_blocks_per_term(index)
    return {
        "model": name,
        "weights": weight_distribution_stats(doc_weights_raw),
        "blockmax": blockmax_tightness(index),
        "skip": skip_opportunity(
            index, q_terms, q_weights, k=k, max_bm_per_term=max_bm_per_term
        ),
        "accumulator": accumulator_overflow(index),
    }
