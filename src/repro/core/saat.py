"""Anytime score-at-a-time (SAAT) query evaluation — the JASS analogue.

JASS processes impact-ordered posting segments in decreasing order of score
*contribution* (segment impact x query weight) and stops after ``rho``
postings, yielding an approximate top-k whose cost — and therefore latency —
is bounded by construction.

TPU adaptation (DESIGN.md §2): ``rho`` becomes a *static tensor shape*. The
plan step orders candidate segments by contribution; the execute step maps the
first ``rho`` posting slots onto (segment, offset) pairs with a vectorized
``searchsorted`` over the segment-length prefix sum, gathers doc ids, and
scatter-adds contributions into a dense accumulator. Every query therefore
executes the *identical* instruction stream — the strongest possible form of
the paper's "SAAT has predictable latency" claim, and simultaneously the
straggler-mitigation primitive for multi-pod serving.

The engine is *natively batched*: a ``[B, Lq]`` query batch runs one batched
argsort in the planner, one histogram-based batched ``searchsorted`` in the
posting gather, and one batch-aware scatter — a single executable per
(k, rho) configuration, not ``B`` vmapped single-query programs. ``saat_search_vmap`` keeps the original
``jax.vmap(one-query)`` formulation as a parity oracle and benchmark baseline
(``benchmarks/side_batched_vs_vmap.py``).

The scatter is the hot loop; ``scatter_impl='pallas'`` routes it to the
one-hot-matmul Pallas kernel (``repro.kernels.impact_scatter``), which for the
batched engine grids over (query, doc-block, posting-tile).

``fused_topk=True`` goes one step further and fuses the top-k selection INTO
the scatter kernel (``repro.kernels.impact_scatter_topk``): each accumulator
block's revisiting loop ends by emitting its per-block top-k candidates, so
only the ``[B, n_blocks * k]`` candidate pool — never the ``[B, n_docs]``
accumulator — crosses the HBM boundary; a final ``tiled_topk`` merge over the
pool recovers the exact global top-k. The fused path is rank-safe by
construction (a block contributes at most ``min(k, block_d)`` finalists) and
bit-identical in doc ids to the unfused engine; ``scatter_impl`` is ignored
when it is set (the fused kernel IS the scatter).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.impact_index import ImpactIndex
from repro.core.topk import topk


class SaatPlan(NamedTuple):
    """Per-query segment schedule, ordered by decreasing contribution.

    All fields carry the query batch dims in front (``[..., n_cand]``);
    single-query plans are simply the rank-1 case.
    """

    starts: jax.Array  # i32[..., n_cand] posting-store offsets
    contribs: jax.Array  # f32[..., n_cand] per-posting score contribution
    cum_len: jax.Array  # i32[..., n_cand] inclusive prefix sum of segment lengths
    total_postings: jax.Array  # i32[...] total candidate postings


class SaatResult(NamedTuple):
    scores: jax.Array  # f32[..., k]
    doc_ids: jax.Array  # i32[..., k]
    postings_processed: jax.Array  # i32[...]
    total_postings: jax.Array  # i32[...]


def max_segments_per_term(index: ImpactIndex) -> int:
    """Static bound for plan shapes (index-build-time constant).

    ``build_impact_index`` records this as ``index.max_segs`` so the serving
    hot path never blocks on a device sync; the reduction below only runs for
    indexes assembled by hand without the metadata. Clamped to >= 1: a
    corpus with zero postings (all docs tombstoned then compacted away) has
    no segments at all, and a 0-width plan axis cannot be indexed — the one
    padded slot carries segment count 0 and is masked everywhere.
    """
    if index.max_segs > 0:
        return int(index.max_segs)
    return max(1, int(jax.device_get(index.term_seg_count.max())))


def saat_plan(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    max_segs_per_term: int,
) -> SaatPlan:
    """Build the contribution-ordered segment schedule.

    Shape-polymorphic over leading batch dims: ``[Lq]`` inputs give a
    single-query plan, ``[B, Lq]`` a batched plan whose JASS ordering is ONE
    batched argsort over ``[B, n_cand]`` rather than B independent sorts.
    """
    n_terms = index.n_terms
    t = jnp.where(q_weights > 0, q_terms, n_terms)  # pad slot has no segments
    base = index.term_seg_start[t]  # [..., Lq]
    cnt = jnp.minimum(index.term_seg_count[t], max_segs_per_term)  # [..., Lq]
    offs = jnp.arange(max_segs_per_term, dtype=jnp.int32)
    j = base[..., :, None] + offs  # [..., Lq, M]
    valid = offs < cnt[..., :, None]
    j = jnp.where(valid, j, 0)
    contrib = index.seg_weight[j] * q_weights[..., :, None].astype(jnp.float32)
    contrib = jnp.where(valid, contrib, -jnp.inf)
    lens = jnp.where(valid, index.seg_len[j], 0)
    starts = jnp.where(valid, index.seg_start[j], 0)

    flat_shape = contrib.shape[:-2] + (contrib.shape[-2] * contrib.shape[-1],)
    flat_c = contrib.reshape(flat_shape)
    order = jnp.argsort(-flat_c, axis=-1)  # decreasing contribution (JASS order)
    starts = jnp.take_along_axis(starts.reshape(flat_shape), order, axis=-1)
    lens = jnp.take_along_axis(lens.reshape(flat_shape), order, axis=-1)
    sorted_c = jnp.take_along_axis(flat_c, order, axis=-1)
    contribs = jnp.where(jnp.isfinite(sorted_c), sorted_c, 0.0)
    cum = jnp.cumsum(lens, axis=-1, dtype=jnp.int32)
    return SaatPlan(
        starts=starts, contribs=contribs, cum_len=cum, total_postings=cum[..., -1]
    )


def _batched_searchsorted_slots(cum: jax.Array, rho: int) -> jax.Array:
    """Row-wise ``searchsorted(cum[b], arange(rho), side='right')`` without vmap.

    Because the queries are the *sorted* slot ids ``0..rho-1``, the binary
    search collapses to a counting argument: ``j[b, p] = #{i : cum[b, i] <= p}``
    is the prefix sum of a histogram of ``cum`` values. One batched
    ``[B, n_cand]`` scatter-add plus one batched ``[B, rho]`` cumsum —
    integer ops only, so bit-identical to ``jnp.searchsorted``.
    """
    B = cum.shape[0]
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    bins = jnp.clip(cum, 0, rho)  # bin rho collects entries past the budget
    hist = jnp.zeros((B, rho + 1), jnp.int32).at[rows, bins].add(1)
    return jnp.cumsum(hist[:, :rho], axis=-1)


def _gather_postings(
    index: ImpactIndex, plan: SaatPlan, rho: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Map posting slots [0, rho) -> (doc_id, contribution, n_processed)."""
    p = jnp.arange(rho, dtype=jnp.int32)
    j = jnp.searchsorted(plan.cum_len, p, side="right").astype(jnp.int32)
    j = jnp.minimum(j, plan.cum_len.shape[0] - 1)
    prev = jnp.where(j > 0, plan.cum_len[jnp.maximum(j - 1, 0)], 0)
    offset = p - prev
    pidx = plan.starts[j] + offset
    valid = p < plan.total_postings
    docs = index.doc_ids[jnp.where(valid, pidx, 0)]
    contribs = jnp.where(valid, plan.contribs[j], 0.0)
    docs = jnp.where(valid, docs, 0)
    n_processed = jnp.minimum(plan.total_postings, rho).astype(jnp.int32)
    return docs, contribs, n_processed


def _gather_postings_batched(
    index: ImpactIndex, plan: SaatPlan, rho: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched slot -> posting map: one histogram searchsorted over [B, rho]."""
    B, n_cand = plan.cum_len.shape
    p = jnp.broadcast_to(jnp.arange(rho, dtype=jnp.int32), (B, rho))
    j = _batched_searchsorted_slots(plan.cum_len, rho)
    j = jnp.minimum(j, n_cand - 1)
    prev_cum = jnp.take_along_axis(plan.cum_len, jnp.maximum(j - 1, 0), axis=-1)
    prev = jnp.where(j > 0, prev_cum, 0)
    offset = p - prev
    pidx = jnp.take_along_axis(plan.starts, j, axis=-1) + offset
    valid = p < plan.total_postings[:, None]
    docs = index.doc_ids[jnp.where(valid, pidx, 0)]
    contribs = jnp.where(valid, jnp.take_along_axis(plan.contribs, j, axis=-1), 0.0)
    docs = jnp.where(valid, docs, 0)
    n_processed = jnp.minimum(plan.total_postings, rho).astype(jnp.int32)
    return docs, contribs, n_processed


def _accumulate(index: ImpactIndex, docs, contribs, scatter_impl: str) -> jax.Array:
    n_docs_pad = index.doc_terms.shape[0]
    if scatter_impl == "jnp":
        acc = jnp.zeros((n_docs_pad,), jnp.float32).at[docs].add(contribs)
    elif scatter_impl == "sort":
        # Sort-by-doc then segment-sum: the layout the Pallas kernel assumes.
        order = jnp.argsort(docs)
        sd, sc = docs[order], contribs[order]
        acc = jax.ops.segment_sum(sc, sd, num_segments=n_docs_pad)
    elif scatter_impl == "pallas":
        from repro.kernels.impact_scatter import ops as scatter_ops

        acc = scatter_ops.impact_scatter(docs, contribs, n_docs_pad)
    else:
        raise ValueError(f"unknown scatter_impl {scatter_impl!r}")
    return acc


def _accumulate_batched(
    index: ImpactIndex, docs: jax.Array, contribs: jax.Array, scatter_impl: str
) -> jax.Array:
    """Batch-aware scatter: ``docs/contribs [B, rho]`` -> ``acc [B, n_docs_pad]``."""
    n_docs_pad = index.doc_terms.shape[0]
    B = docs.shape[0]
    if scatter_impl == "jnp":
        rows = jnp.arange(B, dtype=jnp.int32)[:, None]
        acc = jnp.zeros((B, n_docs_pad), jnp.float32).at[rows, docs].add(contribs)
    elif scatter_impl == "sort":
        if B * n_docs_pad < 2**31:  # row-offset keys must fit int32
            # One batched multi-operand sort-by-doc (docs key, contribs
            # payload — cheaper than argsort + two gathers), then a single
            # flat segment-sum with row-offset doc keys (row b owns keys
            # [b*D, (b+1)*D)).
            sd, sc = jax.lax.sort((docs, contribs), dimension=-1, num_keys=1)
            keys = sd + jnp.arange(B, dtype=jnp.int32)[:, None] * n_docs_pad
            acc = jax.ops.segment_sum(
                sc.reshape(-1),
                keys.reshape(-1),
                num_segments=B * n_docs_pad,
                indices_are_sorted=True,
            ).reshape(B, n_docs_pad)
        else:
            # Flat keys would overflow int32 and an unsorted scatter can't
            # exploit ordering anyway, so skip the sort entirely.
            rows = jnp.arange(B, dtype=jnp.int32)[:, None]
            acc = jnp.zeros((B, n_docs_pad), jnp.float32).at[rows, docs].add(contribs)
    elif scatter_impl == "pallas":
        from repro.kernels.impact_scatter import ops as scatter_ops

        acc = scatter_ops.impact_scatter_batched(docs, contribs, n_docs_pad)
    else:
        raise ValueError(f"unknown scatter_impl {scatter_impl!r}")
    return acc


def _mask_pad_docs(
    index: ImpactIndex, acc: jax.Array, live_mask: jax.Array | None = None
) -> jax.Array:
    n_docs_pad = acc.shape[-1]
    live = jnp.arange(n_docs_pad, dtype=jnp.int32) < index.n_docs
    if live_mask is not None:
        live = live & (live_mask != 0)
    return jnp.where(live, acc, -jnp.inf)


def _fused_scatter_topk_batched(
    index: ImpactIndex,
    docs: jax.Array,
    contribs: jax.Array,
    k: int,
    live_mask: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Scatter + pad-mask + top-k in ONE kernel: HBM sees only candidates."""
    from repro.kernels.impact_scatter_topk import ops as fused_ops

    n_docs_pad = index.doc_terms.shape[0]
    return fused_ops.impact_scatter_topk_batched(
        docs, contribs, n_docs_pad, k, n_live=index.n_docs, live=live_mask
    )


# The full static surface of the batched engine: everything here forks the
# compile cache. repro.analysis.hot_path keys executables on exactly this
# tuple, so keep it in sync with the jit decorator below (it IS the decorator
# argument).
SAAT_STATICS = ("k", "rho", "max_segs_per_term", "scatter_impl", "fused_topk")


@partial(jax.jit, static_argnames=SAAT_STATICS)
def saat_search(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    k: int,
    rho: int,
    max_segs_per_term: int,
    scatter_impl: str = "jnp",
    fused_topk: bool = False,
    live_mask: jax.Array | None = None,
) -> SaatResult:
    """Natively batched anytime SAAT top-k. ``q_terms/q_weights: [B, Lq]``.

    ``rho`` is the JASS posting budget. Exact (rank-safe) evaluation = any
    ``rho >= index.n_postings`` (the executor stops at the query's own total).

    The whole batch is one executable per (k, rho, scatter_impl): the planner
    runs one batched argsort, the gather one batched binary search, and the
    scatter one batch-aware kernel launch — no per-query vmapped programs.

    ``fused_topk=True`` replaces scatter-then-select with the fused
    ``impact_scatter_topk`` kernel: the accumulator never materializes in HBM
    and doc ids stay bit-identical to the unfused path. ``scatter_impl`` is
    ignored in that mode (the fused Pallas kernel IS the scatter).

    ``live_mask`` is the index lifecycle's tombstone gate: an i32/bool
    ``[n_docs_pad]`` bitmap (nonzero = live) ANDed into the same candidate
    mask that already demotes pad docs, so tombstoned docs score ``-inf``
    with zero index rebuild. The accumulation itself is untouched — dead
    docs' postings still scatter, they just can never surface — which keeps
    per-doc f32 sums bit-identical to a rebuilt index (posting order
    restricted to any surviving doc is unchanged by other docs' removal).
    """
    if q_terms.ndim != 2:
        raise ValueError(f"expected [B, Lq] query batch, got shape {q_terms.shape}")
    plan = saat_plan(index, q_terms, q_weights, max_segs_per_term)
    docs, contribs, n_proc = _gather_postings_batched(index, plan, rho)
    if fused_topk:
        scores, ids = _fused_scatter_topk_batched(index, docs, contribs, k, live_mask)
    else:
        acc = _accumulate_batched(index, docs, contribs, scatter_impl)
        scores, ids = topk(_mask_pad_docs(index, acc, live_mask), k)
    return SaatResult(scores, ids.astype(jnp.int32), n_proc, plan.total_postings)


@partial(jax.jit, static_argnames=("k", "rho", "max_segs_per_term", "scatter_impl"))
def saat_search_vmap(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    k: int,
    rho: int,
    max_segs_per_term: int,
    scatter_impl: str = "jnp",
    live_mask: jax.Array | None = None,
) -> SaatResult:
    """Legacy ``jax.vmap(one-query)`` SAAT — parity oracle / benchmark baseline.

    Semantically identical to :func:`saat_search` (including the tombstone
    ``live_mask``, shared across the batch); kept so the batched engine can be
    validated bit-for-bit on doc ids and raced in
    ``benchmarks/side_batched_vs_vmap.py``.
    """

    def one(qt, qw):
        plan = saat_plan(index, qt, qw, max_segs_per_term)
        docs, contribs, n_proc = _gather_postings(index, plan, rho)
        acc = _accumulate(index, docs, contribs, scatter_impl)
        scores, ids = topk(_mask_pad_docs(index, acc, live_mask), k)
        return SaatResult(scores, ids.astype(jnp.int32), n_proc, plan.total_postings)

    return jax.vmap(one)(q_terms, q_weights)


def exact_rho(index: ImpactIndex) -> int:
    """A rho that guarantees rank-safe evaluation for any query."""
    return index.n_postings
