"""Anytime score-at-a-time (SAAT) query evaluation — the JASS analogue.

JASS processes impact-ordered posting segments in decreasing order of score
*contribution* (segment impact x query weight) and stops after ``rho``
postings, yielding an approximate top-k whose cost — and therefore latency —
is bounded by construction.

TPU adaptation (DESIGN.md §2): ``rho`` becomes a *static tensor shape*. The
plan step orders candidate segments by contribution; the execute step maps the
first ``rho`` posting slots onto (segment, offset) pairs with a vectorized
``searchsorted`` over the segment-length prefix sum, gathers doc ids, and
scatter-adds contributions into a dense accumulator. Every query therefore
executes the *identical* instruction stream — the strongest possible form of
the paper's "SAAT has predictable latency" claim, and simultaneously the
straggler-mitigation primitive for multi-pod serving.

The scatter is the hot loop; ``scatter_impl='pallas'`` routes it to the
one-hot-matmul Pallas kernel (``repro.kernels.impact_scatter``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.impact_index import ImpactIndex
from repro.core.topk import topk


class SaatPlan(NamedTuple):
    """Per-query segment schedule, ordered by decreasing contribution."""

    starts: jax.Array  # i32[n_cand] posting-store offsets
    contribs: jax.Array  # f32[n_cand] per-posting score contribution
    cum_len: jax.Array  # i32[n_cand] inclusive prefix sum of segment lengths
    total_postings: jax.Array  # i32[] total candidate postings


class SaatResult(NamedTuple):
    scores: jax.Array  # f32[..., k]
    doc_ids: jax.Array  # i32[..., k]
    postings_processed: jax.Array  # i32[...]
    total_postings: jax.Array  # i32[...]


def max_segments_per_term(index: ImpactIndex) -> int:
    """Static bound for plan shapes (index-build-time constant)."""
    return int(jax.device_get(index.term_seg_count.max()))


def saat_plan(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    max_segs_per_term: int,
) -> SaatPlan:
    """Build the contribution-ordered segment schedule for one query."""
    n_terms = index.n_terms
    t = jnp.where(q_weights > 0, q_terms, n_terms)  # pad slot has no segments
    base = index.term_seg_start[t]  # [Lq]
    cnt = jnp.minimum(index.term_seg_count[t], max_segs_per_term)  # [Lq]
    offs = jnp.arange(max_segs_per_term, dtype=jnp.int32)
    j = base[:, None] + offs[None, :]  # [Lq, M]
    valid = offs[None, :] < cnt[:, None]
    j = jnp.where(valid, j, 0)
    contrib = index.seg_weight[j] * q_weights[:, None].astype(jnp.float32)
    contrib = jnp.where(valid, contrib, -jnp.inf)
    lens = jnp.where(valid, index.seg_len[j], 0)
    starts = jnp.where(valid, index.seg_start[j], 0)

    flat_c = contrib.reshape(-1)
    order = jnp.argsort(-flat_c)  # decreasing contribution (JASS order)
    starts = starts.reshape(-1)[order]
    lens = lens.reshape(-1)[order]
    contribs = jnp.where(jnp.isfinite(flat_c[order]), flat_c[order], 0.0)
    cum = jnp.cumsum(lens, dtype=jnp.int32)
    return SaatPlan(starts=starts, contribs=contribs, cum_len=cum, total_postings=cum[-1])


def _gather_postings(
    index: ImpactIndex, plan: SaatPlan, rho: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Map posting slots [0, rho) -> (doc_id, contribution, n_processed)."""
    p = jnp.arange(rho, dtype=jnp.int32)
    j = jnp.searchsorted(plan.cum_len, p, side="right").astype(jnp.int32)
    j = jnp.minimum(j, plan.cum_len.shape[0] - 1)
    prev = jnp.where(j > 0, plan.cum_len[jnp.maximum(j - 1, 0)], 0)
    offset = p - prev
    pidx = plan.starts[j] + offset
    valid = p < plan.total_postings
    docs = index.doc_ids[jnp.where(valid, pidx, 0)]
    contribs = jnp.where(valid, plan.contribs[j], 0.0)
    docs = jnp.where(valid, docs, 0)
    n_processed = jnp.minimum(plan.total_postings, rho).astype(jnp.int32)
    return docs, contribs, n_processed


def _accumulate(index: ImpactIndex, docs, contribs, scatter_impl: str) -> jax.Array:
    n_docs_pad = index.doc_terms.shape[0]
    if scatter_impl == "jnp":
        acc = jnp.zeros((n_docs_pad,), jnp.float32).at[docs].add(contribs)
    elif scatter_impl == "sort":
        # Sort-by-doc then segment-sum: the layout the Pallas kernel assumes.
        order = jnp.argsort(docs)
        sd, sc = docs[order], contribs[order]
        acc = jax.ops.segment_sum(sc, sd, num_segments=n_docs_pad)
    elif scatter_impl == "pallas":
        from repro.kernels.impact_scatter import ops as scatter_ops

        acc = scatter_ops.impact_scatter(docs, contribs, n_docs_pad)
    else:
        raise ValueError(f"unknown scatter_impl {scatter_impl!r}")
    return acc


def _mask_pad_docs(index: ImpactIndex, acc: jax.Array) -> jax.Array:
    n_docs_pad = acc.shape[0]
    live = jnp.arange(n_docs_pad, dtype=jnp.int32) < index.n_docs
    return jnp.where(live, acc, -jnp.inf)


@partial(jax.jit, static_argnames=("k", "rho", "max_segs_per_term", "scatter_impl"))
def saat_search(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    k: int,
    rho: int,
    max_segs_per_term: int,
    scatter_impl: str = "jnp",
) -> SaatResult:
    """Batched anytime SAAT top-k. ``q_terms/q_weights: [B, Lq]``.

    ``rho`` is the JASS posting budget. Exact (rank-safe) evaluation = any
    ``rho >= index.n_postings`` (the executor stops at the query's own total).
    """

    def one(qt, qw):
        plan = saat_plan(index, qt, qw, max_segs_per_term)
        docs, contribs, n_proc = _gather_postings(index, plan, rho)
        acc = _accumulate(index, docs, contribs, scatter_impl)
        scores, ids = topk(_mask_pad_docs(index, acc), k)
        return SaatResult(scores, ids.astype(jnp.int32), n_proc, plan.total_postings)

    return jax.vmap(one)(q_terms, q_weights)


def exact_rho(index: ImpactIndex) -> int:
    """A rho that guarantees rank-safe evaluation for any query."""
    return index.n_postings
