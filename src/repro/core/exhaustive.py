"""Exhaustive ranked disjunction — score every document, then top-k.

The paper's side experiment found that for SPLADEv2, WAND and BMW were
*slower* than exhaustive disjunction (619/681 vs 553 ms): when upper bounds
cannot prune, pruning machinery is pure overhead. On TPU the exhaustive path
is a regular, fully-dense contraction (the MXU's home game), so it doubles as
both the rank-safe oracle for tests and the performance baseline the pruned
DAAT path must beat — exactly the comparison the paper runs.

Implementation: the doc-major store gives ``score_d = sum_j qvec[term_dj] *
w_dj`` — one gather + one weighted row-sum over all documents, tiled by block.
With documents sharded over the ``model`` mesh axis this becomes an
embarrassingly parallel scan + a k-sized all-gather merge (see
``repro.distributed.sharding``).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.impact_index import ImpactIndex, query_vector
from repro.core.topk import topk


class ExhaustiveResult(NamedTuple):
    scores: jax.Array  # f32[..., k]
    doc_ids: jax.Array  # i32[..., k]


def score_all_docs(index: ImpactIndex, qvec: jax.Array) -> jax.Array:
    """Scores for every (padded) document; pad docs = -inf. f32[n_docs_pad]."""
    scores = jnp.sum(qvec[index.doc_terms] * index.doc_weights, axis=-1)
    live = jnp.arange(scores.shape[0], dtype=jnp.int32) < index.n_docs
    return jnp.where(live, scores, -jnp.inf)


@partial(jax.jit, static_argnames=("k",))
def exhaustive_search(
    index: ImpactIndex, q_terms: jax.Array, q_weights: jax.Array, *, k: int
) -> ExhaustiveResult:
    """Batched rank-safe top-k by scoring the full corpus. ``[B, Lq]`` inputs."""

    def one(qt, qw):
        qvec = query_vector(index, qt, qw)
        scores, ids = topk(score_all_docs(index, qvec), k)
        return ExhaustiveResult(scores, ids.astype(jnp.int32))

    return jax.vmap(one)(q_terms, q_weights)
