"""Impact-ordered inverted index — TPU-native JASS analogue.

The CPU JASS index stores, per term, postings grouped into equal-impact
segments ordered by descending impact, compressed with Group-Elias SIMD codes.
The TPU adaptation keeps the *logical* structure (term -> impact segments ->
doc ids) but lays everything out as flat, aligned ``int32``/``float32`` arrays
so query evaluation is pure gather / one-hot-matmul / top-k — no pointer
chasing, no bit unpacking (see DESIGN.md §2 for why compression is dropped).

Structures built here:
  * posting store     ``doc_ids[P]`` ordered by (term, impact desc, doc asc)
  * segment table     ``seg_{term,weight,start,len}[S]`` (term-impact runs)
  * per-term CSR      over segments and over raw postings
  * block-max table   per (term, doc-block) max weight, CSR by term — the
                      structure Block-Max WAND skips with
  * doc-major store   padded ``doc_terms/doc_weights[n_docs, Tmax]`` used by
                      the vectorized block scorer and the exhaustive evaluator

Everything is a registered-dataclass pytree: arrays are leaves, integer
metadata is static (so ``jax.jit`` treats block sizes etc. as compile-time
constants).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantization import QuantConfig, dequantize, quantize


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    if x.shape[0] >= n:
        return x[:n]
    pad = np.full((n - x.shape[0],) + x.shape[1:], fill, dtype=x.dtype)
    return np.concatenate([x, pad], axis=0)


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


# Static (non-array) fields of ImpactIndex. The single source of truth for
# the pytree registration AND for every consumer that splits an index into
# (data, meta) — e.g. repro.serving.sharded — so a new metadata field cannot
# silently be treated as an array leaf somewhere.
META_FIELDS = (
    "n_docs",
    "n_terms",
    "n_blocks",
    "block_size",
    "max_doc_terms",
    "scale",
    "bits",
    "max_segs",
    "max_bm",
)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "doc_ids",
        "seg_term",
        "seg_weight",
        "seg_start",
        "seg_len",
        "term_seg_start",
        "term_seg_count",
        "term_post_count",
        "term_max_weight",
        "bm_block",
        "bm_weight",
        "term_bm_start",
        "term_bm_count",
        "doc_terms",
        "doc_weights",
        "doc_n_terms",
        "doc_weight_sum",
    ],
    meta_fields=list(META_FIELDS),
)
@dataclasses.dataclass(frozen=True)
class ImpactIndex:
    """Impact-ordered index over a corpus of sparse vectors (see module doc)."""

    # --- posting store (impact order) ---
    doc_ids: jax.Array  # i32[P]
    # --- segment table ---
    seg_term: jax.Array  # i32[S]
    seg_weight: jax.Array  # f32[S] dequantized impact
    seg_start: jax.Array  # i32[S]
    seg_len: jax.Array  # i32[S]
    # --- per-term CSR ---
    term_seg_start: jax.Array  # i32[V+1]
    term_seg_count: jax.Array  # i32[V+1]
    term_post_count: jax.Array  # i32[V+1]
    term_max_weight: jax.Array  # f32[V+1]
    # --- block-max structure ---
    bm_block: jax.Array  # i32[NB]
    bm_weight: jax.Array  # f32[NB]
    term_bm_start: jax.Array  # i32[V+1]
    term_bm_count: jax.Array  # i32[V+1]
    # --- doc-major store ---
    doc_terms: jax.Array  # i32[n_docs_pad, Tmax] (pad slot = V)
    doc_weights: jax.Array  # f32[n_docs_pad, Tmax]
    doc_n_terms: jax.Array  # i32[n_docs_pad]
    doc_weight_sum: jax.Array  # f32[n_docs_pad] quantized-impact sum (overflow analysis)
    # --- static metadata ---
    n_docs: int
    n_terms: int
    n_blocks: int
    block_size: int
    max_doc_terms: int
    scale: float
    bits: int
    # Largest per-term segment count, computed at build time. Static plan
    # bound for SAAT; 0 = unknown (abstract/hand-rolled indexes), in which
    # case ``max_segments_per_term`` falls back to a device sync.
    max_segs: int = 0
    # Largest per-term block-max list length, computed at build time. Static
    # bound for the DAAT block-upper-bound gather; 0 = unknown, in which case
    # ``max_blocks_per_term`` falls back to a device sync.
    max_bm: int = 0

    @property
    def n_postings(self) -> int:
        return int(self.doc_ids.shape[0])

    @property
    def n_segments(self) -> int:
        return int(self.seg_term.shape[0])

    def nbytes(self) -> int:
        """Uncompressed index size (posting store + tables), bytes."""
        total = 0
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if hasattr(v, "nbytes"):
                total += int(v.nbytes)
        return total

    def posting_store_nbytes(self) -> int:
        """Size of the inverted-file part only (Table 1 'Index Size' analogue)."""
        parts = [
            self.doc_ids,
            self.seg_term,
            self.seg_weight,
            self.seg_start,
            self.seg_len,
            self.bm_block,
            self.bm_weight,
        ]
        return int(sum(p.nbytes for p in parts))


def build_impact_index(
    doc_idx: np.ndarray,
    term_idx: np.ndarray,
    weights: np.ndarray,
    n_docs: int,
    n_terms: int,
    *,
    quant: QuantConfig = QuantConfig(bits=8),
    block_size: int = 128,
    pad_postings_to: int = 128,
    max_doc_terms: int | None = None,
    quant_max_weight: float | None = None,
) -> ImpactIndex:
    """Build an :class:`ImpactIndex` from COO postings (host-side, numpy).

    Args:
      doc_idx/term_idx/weights: parallel COO arrays, one entry per posting
        (one (doc, term) pair with positive weight).
      n_docs, n_terms: corpus dimensions.
      quant: impact quantization config.
      block_size: document-block size for the block-max (BMW) structure.
      pad_postings_to: pad the posting store to this multiple (TPU alignment).
      max_doc_terms: doc-major padding width (defaults to the longest doc).
    """
    doc_idx = np.asarray(doc_idx, dtype=np.int64)
    term_idx = np.asarray(term_idx, dtype=np.int64)
    weights = np.asarray(weights, dtype=np.float64)
    keep = weights > 0
    doc_idx, term_idx, weights = doc_idx[keep], term_idx[keep], weights[keep]
    if doc_idx.size == 0:
        # Degenerate but legal: a shard whose doc range holds no postings
        # (short final shard of an uneven split, aggressively filtered
        # corpus). Every CSR count is zero, so the engines touch nothing —
        # but the posting/segment/block-max stores still get padded rows so
        # no zero-length array ever reaches a jitted gather.
        _, scale = quantize(weights, quant, max_weight=quant_max_weight)
        max_doc_terms = max(1, max_doc_terms or 1)
        n_docs_pad = _round_up(max(n_docs, 1), block_size)
        zc = np.zeros(n_terms + 1, dtype=np.int32)
        return ImpactIndex(
            doc_ids=jnp.zeros(max(pad_postings_to, 1), dtype=jnp.int32),
            seg_term=jnp.full(1, n_terms, dtype=jnp.int32),
            seg_weight=jnp.zeros(1, dtype=jnp.float32),
            seg_start=jnp.zeros(1, dtype=jnp.int32),
            seg_len=jnp.zeros(1, dtype=jnp.int32),
            term_seg_start=jnp.asarray(zc),
            term_seg_count=jnp.asarray(zc),
            term_post_count=jnp.asarray(zc),
            term_max_weight=jnp.zeros(n_terms + 1, dtype=jnp.float32),
            bm_block=jnp.zeros(1, dtype=jnp.int32),
            bm_weight=jnp.zeros(1, dtype=jnp.float32),
            term_bm_start=jnp.asarray(zc),
            term_bm_count=jnp.asarray(zc),
            doc_terms=jnp.full((n_docs_pad, max_doc_terms), n_terms, dtype=jnp.int32),
            doc_weights=jnp.zeros((n_docs_pad, max_doc_terms), dtype=jnp.float32),
            doc_n_terms=jnp.zeros(n_docs_pad, dtype=jnp.int32),
            doc_weight_sum=jnp.zeros(n_docs_pad, dtype=jnp.float32),
            n_docs=int(n_docs),
            n_terms=int(n_terms),
            n_blocks=int(n_docs_pad // block_size),
            block_size=int(block_size),
            max_doc_terms=int(max_doc_terms),
            scale=float(scale),
            bits=int(quant.bits),
            max_segs=0,
            max_bm=0,
        )

    # -- deduplicate (doc, term) pairs by summing weights (bag-of-words) --
    key = doc_idx * n_terms + term_idx
    order = np.argsort(key, kind="stable")
    key, doc_idx, term_idx, weights = key[order], doc_idx[order], term_idx[order], weights[order]
    uk, inv = np.unique(key, return_inverse=True)
    if uk.size != key.size:
        w = np.zeros(uk.size, dtype=np.float64)
        np.add.at(w, inv, weights)
        doc_idx = (uk // n_terms).astype(np.int64)
        term_idx = (uk % n_terms).astype(np.int64)
        weights = w

    # -- quantize to impacts (a caller-supplied max keeps SHARDED indexes on
    # one shared impact grid so cross-shard score merges are exact) --
    q, scale = quantize(weights, quant, max_weight=quant_max_weight)
    deq = dequantize(q, scale, quant).astype(np.float32)

    # -- posting order: (term asc, impact desc, doc asc) --
    order = np.lexsort((doc_idx, -q, term_idx))
    t_s, q_s, d_s, w_s = term_idx[order], q[order], doc_idx[order], deq[order]
    P = t_s.size

    # -- segment runs of equal (term, impact) --
    seg_break = np.empty(P, dtype=bool)
    seg_break[0] = True
    seg_break[1:] = (t_s[1:] != t_s[:-1]) | (q_s[1:] != q_s[:-1])
    seg_start = np.flatnonzero(seg_break)
    seg_end = np.append(seg_start[1:], P)
    seg_len = (seg_end - seg_start).astype(np.int32)
    seg_term = t_s[seg_start].astype(np.int32)
    seg_weight = w_s[seg_start].astype(np.float32)
    S = seg_start.size

    # -- per-term CSR over segments / postings (V+1 rows: last = pad slot) --
    term_seg_count = np.zeros(n_terms + 1, dtype=np.int32)
    np.add.at(term_seg_count, seg_term, 1)
    term_seg_start = np.zeros(n_terms + 1, dtype=np.int32)
    term_seg_start[1:] = np.cumsum(term_seg_count)[:-1]
    term_post_count = np.zeros(n_terms + 1, dtype=np.int32)
    np.add.at(term_post_count, t_s.astype(np.int64), 1)
    term_max_weight = np.zeros(n_terms + 1, dtype=np.float32)
    np.maximum.at(term_max_weight, t_s.astype(np.int64), w_s)

    # -- block-max: per (term, block) max dequantized weight --
    n_blocks = _round_up(n_docs, block_size) // block_size
    blk = (d_s // block_size).astype(np.int64)
    tb_key = t_s * n_blocks + blk
    ub_key, ub_inv = np.unique(tb_key, return_inverse=True)
    bm_weight = np.zeros(ub_key.size, dtype=np.float32)
    np.maximum.at(bm_weight, ub_inv, w_s)
    bm_term = (ub_key // n_blocks).astype(np.int64)
    bm_block = (ub_key % n_blocks).astype(np.int32)
    term_bm_count = np.zeros(n_terms + 1, dtype=np.int32)
    np.add.at(term_bm_count, bm_term, 1)
    term_bm_start = np.zeros(n_terms + 1, dtype=np.int32)
    term_bm_start[1:] = np.cumsum(term_bm_count)[:-1]

    # -- doc-major store --
    d_order = np.lexsort((t_s, d_s))
    dd, tt, ww, qq = d_s[d_order], t_s[d_order], w_s[d_order], q_s[d_order]
    doc_n = np.zeros(n_docs, dtype=np.int32)
    np.add.at(doc_n, dd, 1)
    if max_doc_terms is None:
        max_doc_terms = int(doc_n.max())
    max_doc_terms = max(1, max_doc_terms)
    n_docs_pad = _round_up(max(n_docs, 1), block_size)
    doc_terms = np.full((n_docs_pad, max_doc_terms), n_terms, dtype=np.int32)
    doc_weights = np.zeros((n_docs_pad, max_doc_terms), dtype=np.float32)
    # position of each posting within its doc
    doc_offsets = np.zeros(n_docs + 1, dtype=np.int64)
    doc_offsets[1:] = np.cumsum(doc_n)
    within = np.arange(dd.size, dtype=np.int64) - doc_offsets[dd]
    ok = within < max_doc_terms  # truncate over-long docs (counted, rare)
    doc_terms[dd[ok], within[ok]] = tt[ok]
    doc_weights[dd[ok], within[ok]] = ww[ok]
    doc_weight_sum = np.zeros(n_docs_pad, dtype=np.float32)
    np.add.at(doc_weight_sum, dd, qq.astype(np.float32))

    # -- pad posting store --
    P_pad = _round_up(P, pad_postings_to)
    doc_ids_arr = _pad_to(d_s.astype(np.int32), P_pad, 0)

    return ImpactIndex(
        doc_ids=jnp.asarray(doc_ids_arr),
        seg_term=jnp.asarray(seg_term),
        seg_weight=jnp.asarray(seg_weight),
        seg_start=jnp.asarray(seg_start.astype(np.int32)),
        seg_len=jnp.asarray(seg_len),
        term_seg_start=jnp.asarray(term_seg_start),
        term_seg_count=jnp.asarray(term_seg_count),
        term_post_count=jnp.asarray(term_post_count),
        term_max_weight=jnp.asarray(term_max_weight),
        bm_block=jnp.asarray(bm_block),
        bm_weight=jnp.asarray(bm_weight),
        term_bm_start=jnp.asarray(term_bm_start),
        term_bm_count=jnp.asarray(term_bm_count),
        doc_terms=jnp.asarray(doc_terms),
        doc_weights=jnp.asarray(doc_weights),
        doc_n_terms=jnp.asarray(_pad_to(doc_n, n_docs_pad, 0)),
        doc_weight_sum=jnp.asarray(doc_weight_sum),
        n_docs=int(n_docs),
        n_terms=int(n_terms),
        n_blocks=int(n_blocks),
        block_size=int(block_size),
        max_doc_terms=int(max_doc_terms),
        scale=float(scale),
        bits=int(quant.bits),
        max_segs=int(term_seg_count.max()),
        max_bm=int(term_bm_count.max()),
    )


def extract_doc_coo(
    index: ImpactIndex, live: np.ndarray | None = None
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Recover host-side COO postings from the doc-major store.

    The index-lifecycle compactor's read path: returns
    ``(doc_idx, term_idx, weights)`` over the real (non-pad) documents, with
    weights on the index's dequantized impact grid. ``live`` (optional bool/i32
    ``[>= n_docs]`` bitmap; nonzero = live) drops tombstoned documents
    entirely.

    Round-trip caveat: the doc-major store truncates documents longer than
    ``max_doc_terms`` at build time, so extraction only recovers what the
    store kept. Lifecycle rebuilds that must be lossless should build with
    the default ``max_doc_terms=None`` (no truncation).
    """
    dt = np.asarray(jax.device_get(index.doc_terms))[: index.n_docs]
    dw = np.asarray(jax.device_get(index.doc_weights))[: index.n_docs]
    keep = (dt != index.n_terms) & (dw > 0)
    if live is not None:
        keep &= np.asarray(live)[: index.n_docs].astype(bool)[:, None]
    d, slot = np.nonzero(keep)
    return d.astype(np.int64), dt[d, slot].astype(np.int64), dw[d, slot].astype(np.float64)


def query_vector(index: ImpactIndex, q_terms: jax.Array, q_weights: jax.Array) -> jax.Array:
    """Dense query vector over V+1 slots (pad slot stays 0)."""
    qvec = jnp.zeros(index.n_terms + 1, dtype=jnp.float32)
    safe = jnp.where(q_weights > 0, q_terms, index.n_terms)
    return qvec.at[safe].add(q_weights.astype(jnp.float32)).at[index.n_terms].set(0.0)


def pad_queries(
    term_lists: list[np.ndarray],
    weight_lists: list[np.ndarray],
    max_q_terms: int,
    n_terms: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Pad ragged host-side queries to ``[B, max_q_terms]`` arrays."""
    B = len(term_lists)
    qt = np.full((B, max_q_terms), n_terms, dtype=np.int32)
    qw = np.zeros((B, max_q_terms), dtype=np.float32)
    truncated = 0
    for i, (t, w) in enumerate(zip(term_lists, weight_lists)):
        t = np.asarray(t, dtype=np.int32)
        w = np.asarray(w, dtype=np.float32)
        if t.size > max_q_terms:  # keep the highest-weight terms
            top = np.argsort(-w)[:max_q_terms]
            t, w = t[top], w[top]
            truncated += 1
        qt[i, : t.size] = t
        qw[i, : w.size] = w
    return qt, qw
