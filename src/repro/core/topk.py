"""Top-k utilities shared by SAAT / DAAT / exhaustive evaluation and recsys.

On TPU there is no min-heap: full ``jax.lax.top_k`` over the accumulator (or a
tiled two-stage variant for very large candidate sets — see
``repro.kernels.block_topk`` for the Pallas version) replaces the heap +
accumulator-page machinery of JASS.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-jnp.inf)


def topk(scores: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Top-k scores and indices (descending). Static k."""
    k = min(k, scores.shape[-1])
    return jax.lax.top_k(scores, k)


def tiled_topk(scores: jax.Array, k: int, num_tiles: int) -> Tuple[jax.Array, jax.Array]:
    """Two-stage top-k: per-tile top-k then merge.

    For ``n`` candidates this reduces the sort working set from ``n`` to
    ``num_tiles * k`` — the pattern used for the recsys ``retrieval_cand``
    shape (1M candidates), for sharded document scoring, and for merging the
    fused scatter→top-k kernel's per-block candidate pools.

    Ragged inputs are handled rather than rejected: when ``n`` is not a
    multiple of ``num_tiles`` the tail tile is padded with ``NEG_INF`` (pad
    slots sort behind every real entry, including real ``-inf`` ties, because
    they sit at the highest flat positions), and ``k`` larger than the tile
    size is clamped per tile. Both cases stay rank-safe: a tile can contribute
    at most ``min(k, tile)`` entries to the global top-k, and a clamped ``k``
    keeps whole tiles. Like :func:`topk`, the output width is ``min(k, n)``.
    """
    n = scores.shape[-1]
    tile = -(-n // num_tiles)  # ceil: tail tile may be partial
    n_pad = tile * num_tiles
    if n_pad != n:
        pad = jnp.full(scores.shape[:-1] + (n_pad - n,), NEG_INF, scores.dtype)
        scores = jnp.concatenate([scores, pad], axis=-1)
    k_out = min(k, n)
    k_tile = min(k_out, tile)  # clamped k keeps whole tiles -> merge stays exact
    tiles = scores.reshape(scores.shape[:-1] + (num_tiles, tile))
    s, i = jax.lax.top_k(tiles, k_tile)  # [..., num_tiles, k_tile]
    base = (jnp.arange(num_tiles, dtype=jnp.int32) * tile)[:, None]
    gids = i.astype(jnp.int32) + base
    flat_s = s.reshape(scores.shape[:-1] + (num_tiles * k_tile,))
    flat_i = gids.reshape(scores.shape[:-1] + (num_tiles * k_tile,))
    ms, mi = jax.lax.top_k(flat_s, k_out)
    return ms, jnp.take_along_axis(flat_i, mi, axis=-1)


def merge_topk(
    scores_a: jax.Array,
    ids_a: jax.Array,
    scores_b: jax.Array,
    ids_b: jax.Array,
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Merge two top-k pools (e.g. incremental DAAT chunks) into one."""
    s = jnp.concatenate([scores_a, scores_b], axis=-1)
    i = jnp.concatenate([ids_a, ids_b], axis=-1)
    ms, mi = jax.lax.top_k(s, k)
    return ms, jnp.take_along_axis(i, mi, axis=-1)


def sharded_topk_merge(
    local_scores: jax.Array, local_ids: jax.Array, k: int, axis_name: str
) -> Tuple[jax.Array, jax.Array]:
    """Distributed top-k: all-gather per-shard top-k pools and re-select.

    Used inside ``shard_map`` when documents are sharded across the ``model``
    mesh axis: each chip computes top-k over its local shard (with globalized
    doc ids), then the k-sized pools — not the accumulators — cross the ICI.
    Communication = ``shards * k * 8`` bytes instead of ``n_docs * 4``.
    """
    gs = jax.lax.all_gather(local_scores, axis_name, axis=-1, tiled=True)
    gi = jax.lax.all_gather(local_ids, axis_name, axis=-1, tiled=True)
    ms, mi = jax.lax.top_k(gs, k)
    return ms, jnp.take_along_axis(gi, mi, axis=-1)
