"""Top-k utilities shared by SAAT / DAAT / exhaustive evaluation and recsys.

On TPU there is no min-heap: full ``jax.lax.top_k`` over the accumulator (or a
tiled two-stage variant for very large candidate sets — see
``repro.kernels.block_topk`` for the Pallas version) replaces the heap +
accumulator-page machinery of JASS.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-jnp.inf)


def topk(scores: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Top-k scores and indices (descending). Static k."""
    k = min(k, scores.shape[-1])
    return jax.lax.top_k(scores, k)


def tiled_topk(scores: jax.Array, k: int, num_tiles: int) -> Tuple[jax.Array, jax.Array]:
    """Two-stage top-k: per-tile top-k then merge.

    For ``n`` candidates this reduces the sort working set from ``n`` to
    ``num_tiles * k`` — the pattern used for the recsys ``retrieval_cand``
    shape (1M candidates), for sharded document scoring, and for merging the
    fused scatter→top-k kernel's per-block candidate pools.

    Ragged inputs are handled rather than rejected: when ``n`` is not a
    multiple of ``num_tiles`` the tail tile is padded with ``NEG_INF`` (pad
    slots sort behind every real entry, including real ``-inf`` ties, because
    they sit at the highest flat positions), and ``k`` larger than the tile
    size is clamped per tile. Both cases stay rank-safe: a tile can contribute
    at most ``min(k, tile)`` entries to the global top-k, and a clamped ``k``
    keeps whole tiles. Like :func:`topk`, the output width is ``min(k, n)``.
    """
    n = scores.shape[-1]
    tile = -(-n // num_tiles)  # ceil: tail tile may be partial
    n_pad = tile * num_tiles
    if n_pad != n:
        pad = jnp.full(scores.shape[:-1] + (n_pad - n,), NEG_INF, scores.dtype)
        scores = jnp.concatenate([scores, pad], axis=-1)
    k_out = min(k, n)
    k_tile = min(k_out, tile)  # clamped k keeps whole tiles -> merge stays exact
    tiles = scores.reshape(scores.shape[:-1] + (num_tiles, tile))
    s, i = jax.lax.top_k(tiles, k_tile)  # [..., num_tiles, k_tile]
    base = (jnp.arange(num_tiles, dtype=jnp.int32) * tile)[:, None]
    gids = i.astype(jnp.int32) + base
    flat_s = s.reshape(scores.shape[:-1] + (num_tiles * k_tile,))
    flat_i = gids.reshape(scores.shape[:-1] + (num_tiles * k_tile,))
    ms, mi = jax.lax.top_k(flat_s, k_out)
    return ms, jnp.take_along_axis(flat_i, mi, axis=-1)


def merge_topk(
    scores_a: jax.Array,
    ids_a: jax.Array,
    scores_b: jax.Array,
    ids_b: jax.Array,
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Merge two top-k pools (e.g. incremental DAAT chunks) into one."""
    s = jnp.concatenate([scores_a, scores_b], axis=-1)
    i = jnp.concatenate([ids_a, ids_b], axis=-1)
    ms, mi = jax.lax.top_k(s, k)
    return ms, jnp.take_along_axis(i, mi, axis=-1)


def merge_pools_by_id(
    scores_a: jax.Array,
    ids_a: jax.Array,
    scores_b: jax.Array,
    ids_b: jax.Array,
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Merge two candidate pools with ties canonicalized to doc-id order.

    The host-local analogue of :func:`canonical_topk_merge` — no collective,
    just two pools whose ids live in one global doc-id space. This is the
    ``IndexHandle`` merge boundary: the main index's top-k pool and the delta
    segment's top-k pool (delta-local ids already mapped to global ids) join
    here, and the result must be bit-identical to a top-k over a single
    accumulator covering both.

    Same tie argument as :func:`canonical_topk_merge`: after the stable
    id-ascending reorder, position order *is* id order, and ``lax.top_k``
    breaks equal-score ties toward the lower input position — so tied
    candidates surface in ascending-id order exactly as a dense-accumulator
    top-k would, regardless of which pool contributed them. Pad sentinels
    (``-inf`` score) lose to every finite candidate; positions holding
    ``-inf`` carry no id guarantee.

    Precondition: a live document appears in at most one pool (an updated doc
    is tombstoned in main, so its stale main entry scores ``-inf`` and loses).
    """
    s = jnp.concatenate([scores_a, scores_b], axis=-1)
    i = jnp.concatenate([ids_a, ids_b], axis=-1).astype(jnp.int32)
    order = jnp.argsort(i, axis=-1)  # jnp.argsort is stable
    s = jnp.take_along_axis(s, order, axis=-1)
    i = jnp.take_along_axis(i, order, axis=-1)
    ms, mi = topk(s, k)
    return ms, jnp.take_along_axis(i, mi, axis=-1)


def sharded_topk_merge(
    local_scores: jax.Array, local_ids: jax.Array, k: int, axis_name: str
) -> Tuple[jax.Array, jax.Array]:
    """Distributed top-k: all-gather per-shard top-k pools and re-select.

    Used inside ``shard_map`` when documents are sharded across the ``model``
    mesh axis: each chip computes top-k over its local shard (with globalized
    doc ids), then the k-sized pools — not the accumulators — cross the ICI.
    Communication = ``shards * k * 8`` bytes instead of ``n_docs * 4``.

    Ties break by *pool position* (rank-major), which is NOT the unsharded
    engines' tie order once pad sentinels enter the pool: a sentinel
    ``(NEG_INF, INT32_MAX)`` from an early rank outranks a real ``-inf``
    document from a later rank. Serve paths that promise bit-identity to the
    unsharded oracle must use :func:`canonical_topk_merge` instead.
    """
    gs = jax.lax.all_gather(local_scores, axis_name, axis=-1, tiled=True)
    gi = jax.lax.all_gather(local_ids, axis_name, axis=-1, tiled=True)
    ms, mi = jax.lax.top_k(gs, k)
    return ms, jnp.take_along_axis(gi, mi, axis=-1)


def canonical_topk_merge(
    local_scores: jax.Array,
    local_ids: jax.Array,
    k: int,
    axis_name,
) -> Tuple[jax.Array, jax.Array]:
    """Distributed top-k with ties canonicalized to global-doc-id order.

    The cross-shard/cross-host merge boundary: per-rank candidate pools are
    all-gathered over ``axis_name`` (a mesh axis name or a tuple of names —
    the pod case gathers over ``("pod", "model")`` at once), the pooled
    candidates are stably reordered by global doc id ascending, and the
    global top-k is re-selected with :func:`tiled_topk` (one tile per rank,
    so the sort working set stays ``ranks * k``).

    Why the reorder makes the result layout-invariant: ``lax.top_k`` breaks
    equal-score ties toward the lower input position, both per tile and in
    the tile-merge. After the id-ascending reorder, position order *is* id
    order — within a tile directly, and across tiles because each tile is a
    contiguous id range — so tied candidates surface in ascending-id order
    no matter how many ranks contributed them. That is exactly the unsharded
    engines' tie order (a top-k over the accumulator breaks ties toward the
    lower doc id), and it demotes pad sentinels (``INT32_MAX``) behind every
    real ``-inf`` document. 1 rank, 8 ranks, ragged or empty shards: one
    merged answer, bit-identical to the unsharded oracle.
    """
    gs = jax.lax.all_gather(local_scores, axis_name, axis=-1, tiled=True)
    gi = jax.lax.all_gather(local_ids, axis_name, axis=-1, tiled=True)
    order = jnp.argsort(gi, axis=-1)  # jnp.argsort is stable
    gs = jnp.take_along_axis(gs, order, axis=-1)
    gi = jnp.take_along_axis(gi, order, axis=-1)
    n_ranks = max(gs.shape[-1] // local_scores.shape[-1], 1)
    ms, mi = tiled_topk(gs, k, num_tiles=n_ranks)
    return ms, jnp.take_along_axis(gi, mi, axis=-1)
