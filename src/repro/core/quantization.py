"""Impact quantization (paper §3.2).

Score-at-a-time evaluation requires term weights quantized into small integer
*impact scores* organized into equal-impact segments.  The paper observes a
"wacky weights" consequence: learned sparse models generate weights whose
accumulated document scores overflow 16-bit accumulators (JASS had to move to
32-bit, a ~50% overhead on BM25).  This module provides the quantizers and the
overflow analysis used to reproduce that observation.

All quantizers map positive float weights to integers in ``[1, 2**bits - 1]``
(zero is reserved for "no posting").  ``dequantize`` maps back to the impact
midpoint so SAAT / DAAT / exhaustive evaluation all score in the same units.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import numpy as np

ArrayLike = Union[np.ndarray, "jax.Array"]  # noqa: F821 - jnp optional here


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration for impact quantization.

    Attributes:
      bits: width of the integer impact. The paper's systems use 8-bit impacts
        with 16/32-bit accumulators.
      scheme: ``uniform`` (linear in weight) or ``log`` (linear in log-weight,
        better for the heavy-tailed BM25-like distributions).
      per_term: if True, each term gets its own scale (max weight); otherwise a
        single global scale is used (JASS default, required so that impacts of
        different terms are comparable for segment ordering).
    """

    bits: int = 8
    scheme: str = "uniform"
    per_term: bool = False

    @property
    def levels(self) -> int:
        return (1 << self.bits) - 1


def _as_np(x) -> np.ndarray:
    return np.asarray(x)


def quantize(
    weights: ArrayLike,
    cfg: QuantConfig,
    max_weight: float | None = None,
) -> Tuple[np.ndarray, float]:
    """Quantize positive weights to integer impacts.

    Returns ``(impacts, scale)`` with ``impacts`` int32 in [0, levels] (0 only
    for non-positive input weights) and ``scale`` such that
    ``dequantize(impacts, scale) ~= weights``.
    """
    w = _as_np(weights).astype(np.float64)
    if max_weight is None:
        max_weight = float(w.max()) if w.size else 1.0
    max_weight = max(max_weight, 1e-12)
    levels = cfg.levels
    pos = w > 0
    if cfg.scheme == "uniform":
        q = np.ceil(np.clip(w / max_weight, 0.0, 1.0) * levels)
        scale = max_weight / levels
    elif cfg.scheme == "log":
        q = np.ceil(np.log1p(np.clip(w, 0.0, max_weight)) / np.log1p(max_weight) * levels)
        scale = max_weight / levels  # dequant for log scheme handled separately
    else:
        raise ValueError(f"unknown quantization scheme: {cfg.scheme!r}")
    q = np.where(pos, np.clip(q, 1, levels), 0).astype(np.int32)
    return q, float(scale)


def dequantize(impacts: ArrayLike, scale: float, cfg: QuantConfig | None = None) -> np.ndarray:
    """Map integer impacts back to float score contributions."""
    q = _as_np(impacts).astype(np.float64)
    if cfg is not None and cfg.scheme == "log":
        levels = cfg.levels
        max_weight = scale * levels
        return (np.expm1(q / levels * np.log1p(max_weight))).astype(np.float32)
    return (q * scale).astype(np.float32)


def quantization_error(weights: ArrayLike, cfg: QuantConfig) -> dict:
    """Round-trip error stats; uniform scheme error is bounded by one step."""
    w = _as_np(weights).astype(np.float64)
    q, scale = quantize(w, cfg)
    wd = dequantize(q, scale, cfg).astype(np.float64)
    err = np.abs(wd - w)[w > 0]
    step = scale
    return {
        "max_abs_err": float(err.max()) if err.size else 0.0,
        "mean_abs_err": float(err.mean()) if err.size else 0.0,
        "step": float(step),
        "bound_ok": bool(err.size == 0 or err.max() <= step + 1e-9),
    }


def accumulator_analysis(
    doc_impact_sums: ArrayLike,
    query_weight_max: float = 1.0,
    bits: int = 16,
) -> dict:
    """Reproduce the paper's 16-vs-32-bit accumulator overflow analysis.

    ``doc_impact_sums`` is the per-document sum of quantized impacts (the
    worst-case integer score when every document term matches the query with
    unit query weight).  With learned query weights the bound is multiplied by
    the max quantized query weight.  The paper: "32-bit accumulators were
    necessary ... as the learned sparse impacts and weights often result in
    scores exceeding 2^16 = 65,536".
    """
    sums = _as_np(doc_impact_sums).astype(np.float64) * float(query_weight_max)
    cap = float(1 << bits)
    frac = float((sums >= cap).mean()) if sums.size else 0.0
    return {
        "accumulator_bits": bits,
        "capacity": cap,
        "max_doc_score_bound": float(sums.max()) if sums.size else 0.0,
        "mean_doc_score_bound": float(sums.mean()) if sums.size else 0.0,
        "overflow_fraction": frac,
        "overflows": bool(sums.size and sums.max() >= cap),
    }
