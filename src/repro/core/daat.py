"""Vectorized Block-Max document-at-a-time (DAAT) evaluation.

CPU MaxScore / WAND / BMW walk doc-ordered postings and use per-term (and
per-block) score upper bounds to *skip* documents that cannot enter the top-k.
Per-document pivoting is meaningless on a 128-lane vector unit, so the TPU
adaptation works at document-*block* granularity — which is also exactly where
Block-Max WAND gets its skipping power:

  phase 0   upper bound for every block in one scatter-add over the per-term
            block-max lists (``ub[b] = sum_t qw_t * blockmax[t, b]``)
  phase 1   score the ``est_blocks`` highest-ub blocks exactly -> threshold
            theta = k-th best score
  phase 2   *skip* every block with ``ub <= theta``; score survivors in
            chunks of ``block_budget`` inside a ``lax.while_loop`` until
            rank-safe (``exact=True``) or for one chunk (approximate).

The while_loop trip count is data-dependent: with BM25-like skewed weights few
blocks survive and the loop exits immediately; with "wacky" learned weights
the bounds are loose, almost nothing is skippable, and the loop degenerates
toward exhaustive scoring — reproducing both the paper's DAAT slowdown *and*
its unpredictable tail latency, structurally, on TPU. ``WorkStats`` exposes
the survivor counts that quantify the collapse (benchmarks Table 1 / §4.2).

Batched while_loop semantics
----------------------------
Like the SAAT engine (PR 1), DAAT now has a *natively batched* formulation,
``daat_search_batched``: the whole ``[B, Lq]`` query batch is ONE executable —
one batched block-upper-bound scatter (``ub[b_q, blk]``), one batched phase-1
scoring pass, and a SINGLE ``lax.while_loop`` whose state carries every
query's (pool, processed-set, theta, chunk-count) side by side. Each query's
threshold dynamics stay *independent*:

  * the loop condition is ``any(active)`` where ``active[q]`` is exactly the
    per-query condition the single-query loop would evaluate
    (``max remaining ub > theta AND chunks < max_chunks``);
  * the body computes one batched chunk step, then per-query ``where`` masks
    keep every *inactive* query's state frozen — a query that became
    rank-safe idles (its rows ride along untouched) while stragglers keep
    scoring.

This replicates ``jax.vmap``-of-``while_loop`` semantics by construction, so
``daat_search_batched`` is bit-identical to the ``daat_search_vmap`` oracle —
but the batch executes as one program (one scatter, one top-k, one scorer per
iteration) instead of B interleaved vmapped programs. Tail latency remains
data-dependent *by design*: the batch runs until its SLOWEST query is done
(max over per-query trip counts), which is precisely the paper's DAAT
tail-latency mechanism, now measured per batch. ``WorkStats`` is still
per-query: survivor counts, scored-block counts, trip counts, and rank-safety
flags are carried through the masked loop unchanged.

``daat_search_vmap`` (the historical ``blockmax_search``, kept as an alias)
remains the parity oracle and benchmark baseline
(``benchmarks/side_daat_vs_saat_batched.py``).

Kernel-backed phase 2 (``use_kernels=True``)
--------------------------------------------
The batched engine can route its hot inner ops through the batch-gridded
Pallas kernels instead of jnp:

  * block upper bounds — ``block_prune_batched`` contracts per-query dense
    block-max rows with the query weights on the MXU (one launch, phase 0);
  * chunk selection — ``block_topk_batched`` replaces ``lax.top_k`` over the
    remaining-ub vector (phase 1 seeding and every phase-2 iteration);
  * chunk scoring — ``sparse_score_batched`` match-and-accumulate replaces
    the jnp gather-reduce ``score_blocks``.

The jnp path is kept verbatim as the parity oracle: doc ids and ``WorkStats``
must match exactly, scores to fp32 tolerance (the kernels reassociate the
same sums). All threshold/merge/masking logic is shared between the modes —
``use_kernels`` swaps only HOW the same numbers are produced.

Fused chunk step (``use_kernels=True, fused_chunk=True``)
---------------------------------------------------------
The split kernel mode still pays three launches per while_loop trip, with the
``[B, budget, bs]`` chunk-score tensor and the selection finalists
round-tripping HBM between them — exactly the per-trip traffic a
skipping-hostile (wacky-weight) workload multiplies by its trip count.
``fused_chunk=True`` routes the WHOLE phase-2 body through ONE batch-gridded
Pallas kernel (``repro.kernels.chunk_step``):

  * the chunk state — pool scores/ids, theta, the candidate score tile, and
    the per-query processed-bitmap row — stays in VMEM scratch across the
    doc-block revisiting loop;
  * the selected blocks' doc-major rows are pulled from the HBM store with
    double-buffered async-copy DMAs, so block ``j+1``'s ``[bs, Tmax]`` rows
    prefetch while block ``j`` is being scored;
  * only the updated per-query state (pool, theta, processed) crosses the
    HBM boundary per trip — the candidate output.

Phase 0/1 still run the split kernels (they execute once per query, not once
per trip). The jnp body remains the parity oracle: the fused kernel evaluates
the numerically identical expressions in the same order, so doc ids, theta,
and ``WorkStats`` are bit-identical across all three modes.

Multi-trip launches (``fused_chunk=True, trips_per_launch=N``)
--------------------------------------------------------------
The fused mode still exits to XLA on every while_loop trip — one launch plus
a pool/theta/processed HBM round-trip per trip, multiplied by exactly the
trip counts that explode under wacky weights. ``trips_per_launch=N`` runs up
to N trip bodies inside ONE ``chunk_step`` launch: the engine hands the
kernel a scalar-prefetched per-row trip budget
(``min(max_chunks - chunks, N)``; 0 for already-finished rows), the state
revolves in VMEM across the in-kernel trip loop with a per-trip early exit
(a rank-safe row skips the remaining trips' DMAs and compute), and the
while_loop advances ``chunks`` by the kernel's reported per-row
``trips_done``. Each row's trip sequence is independent of the others, so
the final pool/theta/processed AND the per-query trip counts are
bit-identical to ``trips_per_launch=1`` — a launch is just a window of T
consecutive trips, and a query's launch count drops to
``ceil(chunks / trips_per_launch)``. Approximate mode (``exact=False``)
clamps the budget to one trip so its single gated step stays flag-invariant.

CSR-native phase 0 (``use_kernels=True``)
-----------------------------------------
Kernel-mode phase 0 used to densify the per-(query, slot) block-max lists to
a ``[B, Lq, n_blocks]`` matrix — ``Lq`` x the footprint of the CSR lists it
expands — just to feed ``block_prune_batched``'s MXU contraction. The
``block_prune_csr`` kernel walks the CSR lists directly: the engine
scalar-prefetches the per-slot list offsets/counts
(:func:`csr_blockmax_offsets`), the kernel DMAs each slot's window out of
the HBM-resident ``bm_block``/``bm_weight`` arrays, densifies it into a
``[Lq, n_blocks]`` VMEM tile, and runs the SAME ``[1, Lq] x [Lq, NB]`` dot —
so ``ub`` (and therefore ids and ``WorkStats``) is bit-identical while the
dense intermediate never exists in the jaxpr.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.impact_index import ImpactIndex, query_vector
from repro.core.topk import merge_topk, topk


class WorkStats(NamedTuple):
    """Per-query DAAT work metrics — the paper's skipping-collapse evidence."""

    n_survivors: jax.Array  # i32[...] blocks with ub > theta after phase 1
    blocks_scored: jax.Array  # i32[...] total blocks actually scored
    chunks: jax.Array  # i32[...] while_loop trip count (tail-latency proxy)
    rank_safe: jax.Array  # bool[...] all survivors were scored


class DaatResult(NamedTuple):
    scores: jax.Array  # f32[..., k]
    doc_ids: jax.Array  # i32[..., k]
    n_survivors: jax.Array  # i32[...] blocks with ub > theta after phase 1
    blocks_scored: jax.Array  # i32[...] total blocks actually scored
    chunks: jax.Array  # i32[...] while_loop trip count (tail-latency proxy)
    rank_safe: jax.Array  # bool[...] all survivors were scored

    @property
    def stats(self) -> WorkStats:
        return WorkStats(self.n_survivors, self.blocks_scored, self.chunks, self.rank_safe)


class DaatPlan(NamedTuple):
    """Batched phase-0 output: per-query dense vectors the scorer consumes.

    Fields carry an optional leading query-batch dim (``[Lq]`` or ``[B, Lq]``
    inputs); single-query plans are the rank-1 case.
    """

    ub: jax.Array  # f32[..., n_blocks] additive block upper bounds
    qvec: jax.Array  # f32[..., n_terms + 1] dense query vector (pad slot 0)


def max_blocks_per_term(index: ImpactIndex) -> int:
    """Static bound on per-term block-max list length (safety: must not clip).

    ``build_impact_index`` records this as ``index.max_bm`` so DAAT serving
    setup never blocks on a device sync (mirroring ``max_segs`` for SAAT);
    the reduction below only runs for indexes assembled by hand without the
    metadata. Clamped to >= 1 so a zero-posting corpus (every doc
    tombstoned, then compacted) still yields an indexable bound — the padded
    slot has block count 0 and never survives pruning.
    """
    if index.max_bm > 0:
        return int(index.max_bm)
    return max(1, int(jax.device_get(index.term_bm_count.max())))


def query_vectors(index: ImpactIndex, q_terms: jax.Array, q_weights: jax.Array) -> jax.Array:
    """Dense query vectors over V+1 slots: ``[Lq]`` or ``[B, Lq]`` inputs.

    The batched (rank-2) case is ONE scatter over ``[B, V+1]`` (duplicate
    query terms sum, pad slot forced to 0), not B vmapped scatters.
    """
    if q_terms.ndim == 1:
        return query_vector(index, q_terms, q_weights)
    n_terms = index.n_terms
    safe = jnp.where(q_weights > 0, q_terms, n_terms)
    qvec = jnp.zeros(q_terms.shape[:-1] + (n_terms + 1,), jnp.float32)
    rows = jnp.arange(q_terms.shape[0], dtype=jnp.int32)[:, None]
    qvec = qvec.at[rows, safe].add(q_weights.astype(jnp.float32))
    return qvec.at[..., n_terms].set(0.0)


def _gather_blockmax_lists(
    index: ImpactIndex, q_terms: jax.Array, q_weights: jax.Array, max_bm_per_term: int
) -> Tuple[jax.Array, jax.Array]:
    """Clamp-and-gather the per-slot block-max lists (shared by the jnp and
    kernel phase-0 paths — ONE copy of the sentinel/clamp logic).

    Returns ``(blocks i32[..., Lq, M], w f32[..., Lq, M])`` with raw block
    maxima (query weight NOT applied) and invalid slots zeroed; pad /
    zero-weight query slots map to the sentinel term's empty list.
    """
    base, cnt = csr_blockmax_offsets(index, q_terms, q_weights, max_bm_per_term)
    offs = jnp.arange(max_bm_per_term, dtype=jnp.int32)
    idx = base[..., :, None] + offs
    valid = offs < cnt[..., :, None]
    idx = jnp.where(valid, idx, 0)
    blocks = jnp.where(valid, index.bm_block[idx], 0)
    w = jnp.where(valid, index.bm_weight[idx], 0.0)
    return blocks, w


def csr_blockmax_offsets(
    index: ImpactIndex, q_terms: jax.Array, q_weights: jax.Array, max_bm_per_term: int
) -> Tuple[jax.Array, jax.Array]:
    """Scalar-prefetch operands for the CSR-native prune kernel.

    The same sentinel/clamp logic as :func:`_gather_blockmax_lists` — pad /
    zero-weight query slots map to the sentinel term's empty list, counts
    clamp to the static per-term bound — but only the ``(base, cnt)``
    ``i32[..., Lq]`` window descriptors are materialized; the lists
    themselves stay in HBM for the kernel to DMA.
    """
    t = jnp.where(q_weights > 0, q_terms, index.n_terms)
    base = index.term_bm_start[t].astype(jnp.int32)
    cnt = jnp.minimum(index.term_bm_count[t], max_bm_per_term).astype(jnp.int32)
    return base, cnt


def block_upper_bounds(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    max_bm_per_term: int,
) -> jax.Array:
    """BMW-style additive upper bound for every document block.

    ``[Lq]`` inputs give ``f32[n_blocks]``; ``[B, Lq]`` inputs give
    ``f32[B, n_blocks]`` computed by ONE batched scatter-add over the
    per-term block-max lists (``ub[b_q, blk] = sum_t qw * blockmax``).
    Ranks above 2 are not supported (the row-index scatter is rank-2).
    """
    blocks, w = _gather_blockmax_lists(index, q_terms, q_weights, max_bm_per_term)
    w = w * q_weights[..., :, None].astype(jnp.float32)
    flat = blocks.shape[:-2] + (blocks.shape[-2] * blocks.shape[-1],)
    blocks, w = blocks.reshape(flat), w.reshape(flat)
    ub = jnp.zeros(blocks.shape[:-1] + (index.n_blocks,), jnp.float32)
    if blocks.ndim == 1:
        return ub.at[blocks].add(w)
    rows = jnp.arange(blocks.shape[0], dtype=jnp.int32)[:, None]
    return ub.at[rows, blocks].add(w)


def daat_plan(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    max_bm_per_term: int,
) -> DaatPlan:
    """Phase 0 for a whole batch: block upper bounds + dense query vectors."""
    return DaatPlan(
        ub=block_upper_bounds(index, q_terms, q_weights, max_bm_per_term),
        qvec=query_vectors(index, q_terms, q_weights),
    )


def score_blocks(
    index: ImpactIndex,
    qvec: jax.Array,
    block_ids: jax.Array,
    live_mask: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Exact scores for whole blocks of documents via the doc-major store.

    ``qvec[V+1], block_ids[nb]`` returns
    ``(scores[nb, block_size], doc_ids[nb, block_size])``; the batched case
    ``qvec[B, V+1], block_ids[B, nb]`` returns ``[B, nb, block_size]`` pairs.
    Padded documents are masked to -inf, as are documents whose slot in the
    optional ``live_mask`` (i32/bool ``[n_docs_pad]`` lifecycle tombstone
    bitmap; nonzero = live) is 0 — masking happens at selection, never inside
    the score sum, so surviving docs' f32 scores are bit-identical with or
    without the mask. The inner op is a gather of query weights by term id +
    a weighted row reduction — the ``block_score`` Pallas kernel implements
    the same contraction with VMEM-tiled blocks.
    """
    bs = index.block_size
    docs = block_ids[..., :, None] * bs + jnp.arange(bs, dtype=jnp.int32)
    terms = index.doc_terms[docs]  # [..., nb, bs, Tmax]
    w = index.doc_weights[docs]
    if qvec.ndim == 1:
        qv = qvec[terms]
    else:
        rows = jnp.arange(qvec.shape[0], dtype=jnp.int32)[:, None, None, None]
        qv = qvec[rows, terms]
    scores = jnp.sum(qv * w, axis=-1)
    scores = jnp.where(docs < index.n_docs, scores, -jnp.inf)
    if live_mask is not None:
        scores = jnp.where(live_mask[docs] != 0, scores, -jnp.inf)
    return scores, docs


def _dense_blockmax_rows(
    index: ImpactIndex, q_terms: jax.Array, q_weights: jax.Array, max_bm_per_term: int
) -> jax.Array:
    """Densify the per-(query, slot) block-max lists: ``f32[B, Lq, n_blocks]``.

    Raw block maxima (query weight NOT applied) — the ``[Lq, NB]`` layout the
    ``block_prune`` kernel contracts against ``q_weights`` on the MXU.
    Pad / zero-weight slots densify to empty rows, so they contribute exactly
    0 to the bound, mirroring :func:`block_upper_bounds`.

    Cost note: the dense layout is ``Lq`` x larger than the CSR lists it
    expands, which is why kernel-mode phase 0 no longer uses it — the
    CSR-native ``block_prune_csr`` kernel walks the lists directly and the
    analysis lane asserts this intermediate never appears in the traced
    search. Kept as the dense ``block_prune`` kernel's input builder for its
    oracle tests.
    """
    blocks, w = _gather_blockmax_lists(index, q_terms, q_weights, max_bm_per_term)
    B, Lq = q_terms.shape
    rows = jnp.zeros((B, Lq, index.n_blocks), jnp.float32)
    b_ix = jnp.arange(B, dtype=jnp.int32)[:, None, None]
    l_ix = jnp.arange(Lq, dtype=jnp.int32)[None, :, None]
    return rows.at[b_ix, l_ix, blocks].add(w)


def _score_blocks_kernel_batched(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    block_ids: jax.Array,
    live_mask: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Kernel-backed :func:`score_blocks`: one ``sparse_score_batched`` launch.

    Gathers the selected blocks' doc-major rows (exactly as the jnp scorer
    does) and hands the ``[B, nb * block_size, Tmax]`` tile to the
    match-and-accumulate kernel; padded and tombstoned documents mask to
    ``-inf`` outside the kernel, matching the jnp path (selection-time
    masking, never inside the score sum).
    """
    from repro.kernels.sparse_score import ops as score_ops

    bs = index.block_size
    docs = block_ids[..., :, None] * bs + jnp.arange(bs, dtype=jnp.int32)  # [B, nb, bs]
    B = docs.shape[0]
    flat = docs.reshape(B, -1)
    dt = index.doc_terms[flat]  # [B, nb*bs, Tmax]
    dw = index.doc_weights[flat]
    # the engine defines qw <= 0 slots as padding; the kernel sums raw weights
    qw = jnp.where(q_weights > 0, q_weights.astype(jnp.float32), 0.0)
    scores = score_ops.sparse_score_batched(dt, dw, q_terms, qw)
    scores = jnp.where(flat < index.n_docs, scores, -jnp.inf)
    if live_mask is not None:
        scores = jnp.where(live_mask[flat] != 0, scores, -jnp.inf)
    return scores.reshape(docs.shape), docs


def _mask_dead_blocks(
    index: ImpactIndex, ub: jax.Array, live_mask: jax.Array
) -> jax.Array:
    """``ub -> -inf`` for blocks whose every document is tombstoned.

    Applied identically in every mode right after phase 0 (the
    ``block_prune_csr`` kernel itself is untouched — a stale-high bound over
    a partially-dead block is still a valid upper bound, and uniform
    post-phase-0 masking keeps ``WorkStats`` mode-identical): a fully-dead
    block can never contribute a candidate, so dropping it from selection
    keeps survivor counts meaningful and lets ``rank_safe`` converge without
    scoring blocks that only contain ``-inf``.
    """
    bs = index.block_size
    blk_live = live_mask.reshape(index.n_blocks, bs).max(axis=-1)
    return jnp.where(blk_live != 0, ub, -jnp.inf)


def _resolve_daat_shapes(
    index: ImpactIndex, k: int, est_blocks: int, block_budget: int, max_chunks: int | None
) -> Tuple[int, int, int]:
    n_blocks = index.n_blocks
    est_blocks = min(est_blocks, n_blocks)
    block_budget = min(block_budget, n_blocks)
    if max_chunks is None:
        max_chunks = -(-n_blocks // block_budget)  # ceil: worst case scores all
    if k > est_blocks * index.block_size:
        raise ValueError(
            f"k={k} exceeds the phase-1 pool (est_blocks={est_blocks} * "
            f"block_size={index.block_size}); raise est_blocks"
        )
    return est_blocks, block_budget, max_chunks


@partial(
    jax.jit,
    static_argnames=("k", "est_blocks", "block_budget", "max_bm_per_term", "exact", "max_chunks"),
)
def daat_search_vmap(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    k: int,
    est_blocks: int,
    block_budget: int,
    max_bm_per_term: int,
    exact: bool = True,
    max_chunks: int | None = None,
    live_mask: jax.Array | None = None,
) -> DaatResult:
    """Legacy ``jax.vmap(one-query)`` block-max DAAT — the parity oracle.

    ``q_terms/q_weights: [B, Lq]``. Semantically identical to
    :func:`daat_search_batched`; kept so the batched engine can be validated
    bit-for-bit on doc ids and raced in the side benchmarks. ``live_mask``
    (optional ``[n_docs_pad]`` tombstone bitmap, shared by the batch) masks
    deleted docs to ``-inf`` and drops fully-dead blocks from selection.
    """
    n_blocks = index.n_blocks
    est_blocks, block_budget, max_chunks = _resolve_daat_shapes(
        index, k, est_blocks, block_budget, max_chunks
    )

    def one(qt, qw):
        qvec = query_vector(index, qt, qw)
        ub = block_upper_bounds(index, qt, qw, max_bm_per_term)
        if live_mask is not None:
            ub = _mask_dead_blocks(index, ub, live_mask)

        # ---- phase 1: seed the top-k pool from the most promising blocks ----
        _, b1 = topk(ub, est_blocks)
        s1, d1 = score_blocks(index, qvec, b1, live_mask)
        pool_s, pool_i = topk(s1.reshape(-1), k)
        pool_i = d1.reshape(-1)[pool_i].astype(jnp.int32)
        theta = pool_s[k - 1]
        processed = jnp.zeros((n_blocks,), jnp.bool_).at[b1].set(True)
        survivors0 = jnp.sum((ub > theta) & ~processed).astype(jnp.int32)

        # ---- phase 2: chunked scoring of surviving blocks ----
        def remaining_ub(processed, theta):
            return jnp.where(processed, -jnp.inf, ub)

        def cond(state):
            pool_s, pool_i, processed, theta, chunks = state
            more = jnp.max(remaining_ub(processed, theta)) > theta
            return more & (chunks < max_chunks)

        def body(state):
            pool_s, pool_i, processed, theta, chunks = state
            rub = remaining_ub(processed, theta)
            ub_c, b_c = topk(rub, block_budget)
            live = ub_c > theta  # only these can change the top-k
            s_c, d_c = score_blocks(index, qvec, b_c, live_mask)
            s_c = jnp.where(live[:, None], s_c, -jnp.inf)
            pool_s, pool_i = merge_topk(
                pool_s, pool_i, s_c.reshape(-1), d_c.reshape(-1).astype(jnp.int32), k
            )
            theta = pool_s[k - 1]
            processed = processed.at[b_c].set(processed[b_c] | live)
            return pool_s, pool_i, processed, theta, chunks + 1

        state = (pool_s, pool_i, processed, theta, jnp.int32(0))
        if exact:
            pool_s, pool_i, processed, theta, chunks = jax.lax.while_loop(cond, body, state)
        else:
            pool_s, pool_i, processed, theta, chunks = jax.lax.cond(
                cond(state), body, lambda s: s, state
            )
        blocks_scored = jnp.sum(processed).astype(jnp.int32)
        rank_safe = jnp.max(remaining_ub(processed, theta)) <= theta
        return DaatResult(pool_s, pool_i, survivors0, blocks_scored, chunks, rank_safe)

    return jax.vmap(one)(q_terms, q_weights)


# Historical name, kept for existing callers (benchmarks, wacky reports).
blockmax_search = daat_search_vmap


# The full static surface of the batched engine: everything here forks the
# compile cache. repro.analysis.hot_path keys executables on exactly this
# tuple, so keep it in sync with the jit decorator below (it IS the decorator
# argument).
DAAT_STATICS = (
    "k", "est_blocks", "block_budget", "max_bm_per_term", "exact", "max_chunks",
    "use_kernels", "fused_chunk", "trips_per_launch",
)


@partial(jax.jit, static_argnames=DAAT_STATICS)
def daat_search_batched(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    k: int,
    est_blocks: int,
    block_budget: int,
    max_bm_per_term: int,
    exact: bool = True,
    max_chunks: int | None = None,
    use_kernels: bool = False,
    fused_chunk: bool = False,
    trips_per_launch: int = 1,
    live_mask: jax.Array | None = None,
) -> DaatResult:
    """Natively batched block-max DAAT top-k. ``q_terms/q_weights: [B, Lq]``.

    One executable per (k, est_blocks, block_budget, exact) configuration for
    the whole batch: a single phase-0 scatter, a single phase-1 scoring pass,
    and a single ``lax.while_loop`` with per-query masked state (see module
    docstring for the batched-loop semantics). Bit-identical doc ids and
    :class:`WorkStats` to :func:`daat_search_vmap`.

    ``use_kernels=True`` routes phase 0's upper bounds through the CSR-native
    ``block_prune_csr`` kernel, chunk selection through
    ``block_topk_batched``, and chunk scoring through
    ``sparse_score_batched``; ``fused_chunk=True`` (kernel mode only)
    additionally collapses every phase-2 trip's select+score+merge into the
    single VMEM-resident ``chunk_step`` kernel, and ``trips_per_launch=N``
    (fused mode only) runs up to N trips per launch inside that kernel (see
    module docstring); the jnp formulation stays the parity oracle for every
    combination.

    ``live_mask`` (optional i32/bool ``[n_docs_pad]`` lifecycle tombstone
    bitmap; nonzero = live, shared by the batch) threads through every mode:
    fully-dead blocks drop out of selection right after phase 0
    (:func:`_mask_dead_blocks`), and dead docs mask to ``-inf`` at
    selection time — via the jnp/kernel scorers' gather or the fused
    ``chunk_step`` kernel's DMA'd live rows — so ids, theta, and
    ``WorkStats`` stay bit-identical across all kernel modes for any mask.
    """
    if q_terms.ndim != 2:
        raise ValueError(f"expected [B, Lq] query batch, got shape {q_terms.shape}")
    if fused_chunk and not use_kernels:
        raise ValueError(
            "fused_chunk fuses the kernel-mode chunk step; pass use_kernels=True"
        )
    if trips_per_launch < 1:
        raise ValueError(f"trips_per_launch={trips_per_launch} must be >= 1")
    if trips_per_launch > 1 and not fused_chunk:
        raise ValueError(
            "trips_per_launch > 1 batches trips inside the fused chunk_step "
            "kernel; pass use_kernels=True, fused_chunk=True"
        )
    n_blocks = index.n_blocks
    est_blocks, block_budget, max_chunks = _resolve_daat_shapes(
        index, k, est_blocks, block_budget, max_chunks
    )
    B = q_terms.shape[0]
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]

    if use_kernels:
        from repro.kernels.block_prune_csr import ops as prune_ops
        from repro.kernels.block_topk import ops as topk_ops

        # CSR-native phase 0: only the [B, Lq] window descriptors cross to
        # the kernel; the dense [B, Lq, n_blocks] block-max intermediate the
        # old block_prune_batched path densified never exists (the analysis
        # lane asserts its absence from this jaxpr). ub stays bit-identical.
        base, cnt = csr_blockmax_offsets(index, q_terms, q_weights, max_bm_per_term)
        ub, _ = prune_ops.block_prune_csr_batched(
            index.bm_block, index.bm_weight, base, cnt,
            q_weights.astype(jnp.float32),
            jnp.full((B,), -jnp.inf, jnp.float32),  # no threshold yet: pure ub pass
            n_blocks=n_blocks, max_bm_per_term=max_bm_per_term,
        )
        qvec = None  # the kernel scorer consumes (q_terms, q_weights) directly

        def _select(scores_vec, n):  # noqa: ANN001 — chunk/phase-1 block select
            return topk_ops.block_topk_batched(scores_vec, n)

        def _score(block_ids):
            return _score_blocks_kernel_batched(
                index, q_terms, q_weights, block_ids, live_mask
            )

    else:
        plan = daat_plan(index, q_terms, q_weights, max_bm_per_term)
        ub, qvec = plan.ub, plan.qvec  # [B, n_blocks], [B, V+1]

        def _select(scores_vec, n):
            return topk(scores_vec, n)

        def _score(block_ids):
            return score_blocks(index, qvec, block_ids, live_mask)

    if live_mask is not None:
        ub = _mask_dead_blocks(index, ub, live_mask)

    # ---- phase 1: seed every query's top-k pool in one batched pass ----
    _, b1 = _select(ub, est_blocks)  # [B, est_blocks]
    s1, d1 = _score(b1)  # [B, est_blocks, bs]
    pool_s, pool_i = topk(s1.reshape(B, -1), k)
    pool_i = jnp.take_along_axis(d1.reshape(B, -1), pool_i, axis=-1).astype(jnp.int32)
    theta = pool_s[:, k - 1]  # [B]
    processed = jnp.zeros((B, n_blocks), jnp.bool_).at[rows, b1].set(True)
    survivors0 = jnp.sum((ub > theta[:, None]) & ~processed, axis=-1).astype(jnp.int32)

    # ---- phase 2: one while_loop, per-query state advances independently ----
    def remaining_ub(processed):
        return jnp.where(processed, -jnp.inf, ub)

    def active_rows(state):
        pool_s, pool_i, processed, theta, chunks = state
        more = jnp.max(remaining_ub(processed), axis=-1) > theta
        return more & (chunks < max_chunks)  # bool[B]

    def cond(state):
        return jnp.any(active_rows(state))

    # approximate mode applies the body ONCE outside the while_loop, so its
    # launch must stay a single gated trip for flag-invariant results
    trip_cap = trips_per_launch if exact else 1
    multi_body = None

    if fused_chunk:
        from repro.kernels.chunk_step import ops as chunk_ops

        # the engine defines qw <= 0 slots as padding; the kernel sums raw
        # weights (same contract as _score_blocks_kernel_batched)
        qw_raw = jnp.where(q_weights > 0, q_weights.astype(jnp.float32), 0.0)

        def _chunk_step(pool_s, pool_i, processed, theta):
            """ONE kernel launch: select+score+merge, state VMEM-resident."""
            return chunk_ops.chunk_step_batched(
                index.doc_terms, index.doc_weights, q_terms, qw_raw,
                ub, processed, pool_s, pool_i, theta,
                block_budget=block_budget,
                block_size=index.block_size,
                n_live=index.n_docs,
                live=live_mask,
            )

        if trip_cap > 1:

            def multi_body(state):
                """Up to ``trip_cap`` trips in ONE launch; state stays in VMEM.

                The per-row scalar-prefetched budget folds the engine's
                ``chunks < max_chunks`` bound into the kernel (a row never
                overruns it) and zeroes out inactive rows, so the kernel's
                in-kernel gating reproduces the per-trip loop's active
                condition trip by trip — final state AND per-query trip
                counts are bit-identical to ``trips_per_launch=1``.
                """
                pool_s, pool_i, processed, theta, chunks = state
                act = active_rows(state)
                trips_left = jnp.where(
                    act, jnp.minimum(max_chunks - chunks, trip_cap), 0
                ).astype(jnp.int32)
                new_s, new_i, new_theta, new_processed, trips_done = (
                    chunk_ops.chunk_step_multi_batched(
                        index.doc_terms, index.doc_weights, q_terms, qw_raw,
                        ub, processed, pool_s, pool_i, theta, trips_left,
                        trips_per_launch=trip_cap,
                        block_budget=block_budget,
                        block_size=index.block_size,
                        n_live=index.n_docs,
                        live=live_mask,
                    )
                )
                # the kernel freezes trips_left == 0 rows itself; the masks
                # keep the inactive-row guarantee structural regardless
                pool_s = jnp.where(act[:, None], new_s, pool_s)
                pool_i = jnp.where(act[:, None], new_i, pool_i)
                processed = jnp.where(act[:, None], new_processed, processed)
                theta = jnp.where(act, new_theta, theta)
                chunks = chunks + jnp.where(act, trips_done, 0)
                return pool_s, pool_i, processed, theta, chunks

    else:

        def _chunk_step(pool_s, pool_i, processed, theta):
            """Split chunk step: selection, scoring, and merge round-trip HBM."""
            rub = remaining_ub(processed)
            ub_c, b_c = _select(rub, block_budget)  # [B, budget]
            live = ub_c > theta[:, None]  # only these can change the top-k
            s_c, d_c = _score(b_c)  # [B, budget, bs]
            s_c = jnp.where(live[..., None], s_c, -jnp.inf)
            new_s, new_i = merge_topk(
                pool_s, pool_i, s_c.reshape(B, -1), d_c.reshape(B, -1).astype(jnp.int32), k
            )
            new_theta = new_s[:, k - 1]
            new_processed = processed.at[rows, b_c].set(
                processed[rows, b_c] | live
            )
            return new_s, new_i, new_theta, new_processed

    def body(state):
        pool_s, pool_i, processed, theta, chunks = state
        act = active_rows(state)  # finished queries idle below
        new_s, new_i, new_theta, new_processed = _chunk_step(
            pool_s, pool_i, processed, theta
        )
        # per-query masking: inactive rows keep their state bit-for-bit
        pool_s = jnp.where(act[:, None], new_s, pool_s)
        pool_i = jnp.where(act[:, None], new_i, pool_i)
        processed = jnp.where(act[:, None], new_processed, processed)
        theta = jnp.where(act, new_theta, theta)
        chunks = chunks + act.astype(jnp.int32)
        return pool_s, pool_i, processed, theta, chunks

    if multi_body is not None:
        body = multi_body

    state = (pool_s, pool_i, processed, theta, jnp.zeros((B,), jnp.int32))
    if exact:
        pool_s, pool_i, processed, theta, chunks = jax.lax.while_loop(cond, body, state)
    else:
        # approximate mode: at most one chunk step, per-query gated
        new_state = body(state)
        pool_s, pool_i, processed, theta, chunks = new_state
    blocks_scored = jnp.sum(processed, axis=-1).astype(jnp.int32)
    rank_safe = jnp.max(remaining_ub(processed), axis=-1) <= theta
    return DaatResult(pool_s, pool_i, survivors0, blocks_scored, chunks, rank_safe)
