"""Vectorized Block-Max document-at-a-time (DAAT) evaluation.

CPU MaxScore / WAND / BMW walk doc-ordered postings and use per-term (and
per-block) score upper bounds to *skip* documents that cannot enter the top-k.
Per-document pivoting is meaningless on a 128-lane vector unit, so the TPU
adaptation works at document-*block* granularity — which is also exactly where
Block-Max WAND gets its skipping power:

  phase 0   upper bound for every block in one scatter-add over the per-term
            block-max lists (``ub[b] = sum_t qw_t * blockmax[t, b]``)
  phase 1   score the ``est_blocks`` highest-ub blocks exactly -> threshold
            theta = k-th best score
  phase 2   *skip* every block with ``ub <= theta``; score survivors in
            chunks of ``block_budget`` inside a ``lax.while_loop`` until
            rank-safe (``exact=True``) or for one chunk (approximate).

The while_loop trip count is data-dependent: with BM25-like skewed weights few
blocks survive and the loop exits immediately; with "wacky" learned weights
the bounds are loose, almost nothing is skippable, and the loop degenerates
toward exhaustive scoring — reproducing both the paper's DAAT slowdown *and*
its unpredictable tail latency, structurally, on TPU. ``WorkStats`` exposes
the survivor counts that quantify the collapse (benchmarks Table 1 / §4.2).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.impact_index import ImpactIndex, query_vector
from repro.core.topk import merge_topk, topk


class DaatResult(NamedTuple):
    scores: jax.Array  # f32[..., k]
    doc_ids: jax.Array  # i32[..., k]
    n_survivors: jax.Array  # i32[...] blocks with ub > theta after phase 1
    blocks_scored: jax.Array  # i32[...] total blocks actually scored
    chunks: jax.Array  # i32[...] while_loop trip count (tail-latency proxy)
    rank_safe: jax.Array  # bool[...] all survivors were scored


def max_blocks_per_term(index: ImpactIndex) -> int:
    """Static bound on per-term block-max list length (safety: must not clip)."""
    return int(jax.device_get(index.term_bm_count.max()))


def block_upper_bounds(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    max_bm_per_term: int,
) -> jax.Array:
    """BMW-style additive upper bound for every document block. f32[n_blocks]."""
    n_terms = index.n_terms
    t = jnp.where(q_weights > 0, q_terms, n_terms)
    base = index.term_bm_start[t]
    cnt = jnp.minimum(index.term_bm_count[t], max_bm_per_term)
    offs = jnp.arange(max_bm_per_term, dtype=jnp.int32)
    idx = base[:, None] + offs[None, :]
    valid = offs[None, :] < cnt[:, None]
    idx = jnp.where(valid, idx, 0)
    blocks = jnp.where(valid, index.bm_block[idx], 0)
    w = jnp.where(valid, index.bm_weight[idx] * q_weights[:, None].astype(jnp.float32), 0.0)
    ub = jnp.zeros((index.n_blocks,), jnp.float32)
    return ub.at[blocks.reshape(-1)].add(w.reshape(-1))


def score_blocks(
    index: ImpactIndex, qvec: jax.Array, block_ids: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Exact scores for whole blocks of documents via the doc-major store.

    Returns ``(scores[nb, block_size], doc_ids[nb, block_size])`` with padded
    documents masked to -inf. The inner op is a gather of query weights by
    term id + a weighted row reduction — the ``block_score`` Pallas kernel
    implements the same contraction with VMEM-tiled blocks.
    """
    bs = index.block_size
    docs = block_ids[:, None] * bs + jnp.arange(bs, dtype=jnp.int32)[None, :]
    terms = index.doc_terms[docs]  # [nb, bs, Tmax]
    w = index.doc_weights[docs]
    scores = jnp.sum(qvec[terms] * w, axis=-1)
    scores = jnp.where(docs < index.n_docs, scores, -jnp.inf)
    return scores, docs


@partial(
    jax.jit,
    static_argnames=("k", "est_blocks", "block_budget", "max_bm_per_term", "exact", "max_chunks"),
)
def blockmax_search(
    index: ImpactIndex,
    q_terms: jax.Array,
    q_weights: jax.Array,
    *,
    k: int,
    est_blocks: int,
    block_budget: int,
    max_bm_per_term: int,
    exact: bool = True,
    max_chunks: int | None = None,
) -> DaatResult:
    """Batched block-max DAAT top-k. ``q_terms/q_weights: [B, Lq]``."""
    n_blocks = index.n_blocks
    est_blocks = min(est_blocks, n_blocks)
    block_budget = min(block_budget, n_blocks)
    if max_chunks is None:
        max_chunks = -(-n_blocks // block_budget)  # ceil: worst case scores all

    def one(qt, qw):
        qvec = query_vector(index, qt, qw)
        ub = block_upper_bounds(index, qt, qw, max_bm_per_term)

        # ---- phase 1: seed the top-k pool from the most promising blocks ----
        _, b1 = topk(ub, est_blocks)
        s1, d1 = score_blocks(index, qvec, b1)
        pool_s, pool_i = topk(s1.reshape(-1), k)
        pool_i = d1.reshape(-1)[pool_i].astype(jnp.int32)
        theta = pool_s[k - 1]
        processed = jnp.zeros((n_blocks,), jnp.bool_).at[b1].set(True)
        survivors0 = jnp.sum((ub > theta) & ~processed).astype(jnp.int32)

        # ---- phase 2: chunked scoring of surviving blocks ----
        def remaining_ub(processed, theta):
            return jnp.where(processed, -jnp.inf, ub)

        def cond(state):
            pool_s, pool_i, processed, theta, chunks = state
            more = jnp.max(remaining_ub(processed, theta)) > theta
            return more & (chunks < max_chunks)

        def body(state):
            pool_s, pool_i, processed, theta, chunks = state
            rub = remaining_ub(processed, theta)
            ub_c, b_c = topk(rub, block_budget)
            live = ub_c > theta  # only these can change the top-k
            s_c, d_c = score_blocks(index, qvec, b_c)
            s_c = jnp.where(live[:, None], s_c, -jnp.inf)
            pool_s, pool_i = merge_topk(
                pool_s, pool_i, s_c.reshape(-1), d_c.reshape(-1).astype(jnp.int32), k
            )
            theta = pool_s[k - 1]
            processed = processed.at[b_c].set(processed[b_c] | live)
            return pool_s, pool_i, processed, theta, chunks + 1

        state = (pool_s, pool_i, processed, theta, jnp.int32(0))
        if exact:
            pool_s, pool_i, processed, theta, chunks = jax.lax.while_loop(cond, body, state)
        else:
            pool_s, pool_i, processed, theta, chunks = jax.lax.cond(
                cond(state), body, lambda s: s, state
            )
        blocks_scored = jnp.sum(processed).astype(jnp.int32)
        rank_safe = jnp.max(remaining_ub(processed, theta)) <= theta
        return DaatResult(pool_s, pool_i, survivors0, blocks_scored, chunks, rank_safe)

    return jax.vmap(one)(q_terms, q_weights)
