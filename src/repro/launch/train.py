"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps (smoke-scale on CPU by default, production configs when
``--full`` is given on hardware that can hold them). Wires together the
whole substrate: arch registry -> data pipeline -> sharded train step ->
checkpoint manager (periodic + SIGTERM) -> metrics log. This is deliverable
(b)'s end-to-end driver for the assigned architectures; the sparse-encoder
training example lives in ``examples/train_sparse_encoder.py``.
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import pipeline
from repro.distributed.sharding import param_shardings, train_state_shardings
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.train.trainer import abstract_train_state


def _make_loss(spec, cfg):
    if spec.family == "lm":
        from repro.archs.transformer import lm_loss

        return lambda p, b: lm_loss(p, b["tokens"], b["labels"], cfg)
    if spec.family == "gnn":
        from repro.archs.gnn import gnn_loss

        return lambda p, b: gnn_loss(p, b, cfg)
    from repro.archs.recsys import loss as recsys_loss

    return lambda p, b: recsys_loss(p, b, cfg)


def _make_batches(spec, cfg, batch: int, seq: int):
    if spec.family == "lm":
        return pipeline.lm_token_batches(cfg.vocab, batch, seq)
    if spec.family == "gnn":
        readout = getattr(cfg, "graph_readout", False)
        return pipeline.gnn_batches(cfg, n_nodes=max(batch * 4, 64), n_edges=max(batch * 16, 256),
                                    graph_readout_graphs=8 if readout else 0)
    return pipeline.recsys_batches(cfg, batch)


def _init_params(spec, cfg, key):
    if spec.family == "lm":
        from repro.archs.transformer import init_lm_params

        return init_lm_params(key, cfg)
    if spec.family == "gnn":
        from repro.archs.gnn import init_gnn_params

        return init_gnn_params(key, cfg)
    from repro.archs.recsys import init_params

    return init_params(key, cfg)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--full", action="store_true", help="use the full (not smoke) config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.config_for("train_4k" if "train_4k" in spec.cells else "train_batch") if args.full else spec.smoke_config()
    loss_fn = _make_loss(spec, cfg)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10), total_steps=args.steps)
    step_fn = make_train_step(loss_fn, opt, grad_accum=args.grad_accum)

    params = _init_params(spec, cfg, jax.random.PRNGKey(0))
    state = init_train_state(params)

    cm = None
    if args.ckpt_dir:
        cm = CheckpointManager(args.ckpt_dir, keep=2)
        if args.resume and cm.latest_step() is not None:
            abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state, meta = cm.restore(abstract)
            print(f"resumed from step {int(state.step)} ({meta})")

        def on_sigterm(signum, frame):  # checkpoint-on-preemption
            cm.save(int(state.step), state, {"reason": "sigterm"})
            cm.wait()
            sys.exit(0)

        signal.signal(signal.SIGTERM, on_sigterm)

    batches = _make_batches(spec, cfg, args.batch, args.seq)
    fn = jax.jit(step_fn)
    t0 = time.time()
    for i, batch in enumerate(itertools.islice(batches, args.steps)):
        state, metrics = fn(state, batch)
        if cm and (i + 1) % args.ckpt_every == 0:
            cm.save(int(state.step), state, {"metrics": {k: float(v) for k, v in metrics.items()}})
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            m = {k: round(float(v), 4) for k, v in metrics.items() if jnp.ndim(v) == 0}
            print(f"step {i}: {json.dumps(m)}", flush=True)
    if cm:
        cm.save(int(state.step), state, {"final": True})
        cm.wait()
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s ({dt / args.steps * 1e3:.1f} ms/step)")


if __name__ == "__main__":
    main()
