"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — required because the
dry-run must set ``XLA_FLAGS`` before any jax initialization.

Topology: TPU v5e pods of 16x16 = 256 chips; the multi-pod mesh stacks two
pods on a leading ``pod`` axis (512 chips). The ``pod`` axis joins the
data-parallel group (gradient sync crosses DCI; model parallelism stays
inside a pod where ICI bandwidth lives).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_for(name: str):
    if name in ("single", "single_pod", "16x16"):
        return make_production_mesh(multi_pod=False)
    if name in ("multi", "multi_pod", "2x16x16"):
        return make_production_mesh(multi_pod=True)
    raise ValueError(f"unknown mesh {name!r} (use 'single' or 'multi')")


def n_chips(mesh) -> int:
    return mesh.devices.size
