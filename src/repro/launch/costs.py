"""Roofline cost accounting.

XLA's HloCostAnalysis counts every while-loop body ONCE (verified: an 8-step
``lax.scan`` of a 512^3 matmul reports 1/8 of the true FLOPs), and our layer
stacks, loss chunking, attention chunking and grad accumulation are all
scans. Three consequences, three fixes:

  * **FLOPs**: computed analytically from the model config + cell shape —
    an exact matmul inventory (attention, FFN/MoE-with-capacity, vocab
    projections, interaction layers) times the fwd/bwd/remat multiplier.
    XLA's raw (loop-undercounting) counter is recorded alongside.
  * **HBM bytes**: analytic lower-bound traffic model (documented per
    family): parameter reads/writes (incl. optimizer state), activation
    read/write per layer, embedding gathers, KV-cache traffic. This is the
    roofline *denominator* convention: best-achievable traffic, so the
    memory term is a true lower bound on step time.
  * **Collective bytes**: parsed from post-SPMD HLO with **while-loop trip
    multiplication** — each computation's collective bytes are scaled by the
    product of trip counts of the while loops enclosing it (trip counts are
    recovered from each loop condition's ROOT compare against a constant).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# ---------------------------------------------------------------------------
# analytic FLOPs
# ---------------------------------------------------------------------------


def _lm_layer_matmul_flops_per_token(cfg) -> float:
    """Projection + FFN matmul FLOPs for ONE token through ONE layer (fwd)."""
    d, hd = cfg.d_model, cfg.d_head
    attn = 2.0 * d * cfg.n_heads * hd  # wq
    attn += 2.0 * 2.0 * d * cfg.n_kv_heads * hd  # wk, wv
    attn += 2.0 * cfg.n_heads * hd * d  # wo
    if cfg.moe is not None:
        m = cfg.moe
        # HLO computes the full capacity buffer: E * C tokens of expert work,
        # C = T*K/E * capacity_factor  =>  per source token: K * cf experts
        ffn = 3.0 * 2.0 * d * m.d_expert_ff * m.top_k * m.capacity_factor
        ffn += 2.0 * d * m.n_experts  # router
        if m.n_shared:
            ffn += 3.0 * 2.0 * d * m.d_expert_ff * m.n_shared
    else:
        ffn = 3.0 * 2.0 * d * cfg.d_ff
    return attn + ffn


def _lm_attention_flops_per_token(cfg, seq: int, context: Optional[int] = None) -> float:
    """Score + AV einsum FLOPs per *query* token (fwd), summed over layers."""
    total = 0.0
    for l in range(cfg.n_layers):
        w = cfg.layer_window(l)
        if context is not None:  # decode: attend over the cache
            s_eff = min(w, context) if w > 0 else context
        else:  # full causal self-attention averages S/2 visible keys
            s_eff = min(w, seq) if w > 0 else seq / 2.0
        total += 2.0 * 2.0 * s_eff * cfg.n_heads * cfg.d_head
    return total


def _remat_mult(cfg) -> float:
    # fwd(1) + bwd(2) (+ recompute fwd(1) under full remat)
    return {"none": 3.0, "dots": 3.5, "full": 4.0}.get(getattr(cfg, "remat", "none"), 3.0)


def lm_train_flops(cfg, batch: int, seq: int) -> float:
    tokens = batch * seq
    per_tok = cfg.n_layers * _lm_layer_matmul_flops_per_token(cfg)
    attn = _lm_attention_flops_per_token(cfg, seq) * tokens
    body = (per_tok * tokens + attn) * _remat_mult(cfg)
    logits = 2.0 * cfg.d_model * cfg.vocab * tokens * 3.0  # loss is outside remat
    embed_bwd = 2.0 * cfg.d_model * tokens  # scatter-add grads (cheap)
    return body + logits + embed_bwd


def lm_prefill_flops(cfg, batch: int, seq: int) -> float:
    tokens = batch * seq
    per_tok = cfg.n_layers * _lm_layer_matmul_flops_per_token(cfg)
    attn = _lm_attention_flops_per_token(cfg, seq) * tokens
    logits = 2.0 * cfg.d_model * cfg.vocab * batch  # last position only
    return per_tok * tokens + attn + logits


def lm_decode_flops(cfg, batch: int, context: int) -> float:
    per_tok = cfg.n_layers * _lm_layer_matmul_flops_per_token(cfg)
    attn = _lm_attention_flops_per_token(cfg, 1, context=context)
    logits = 2.0 * cfg.d_model * cfg.vocab
    return (per_tok + attn + logits) * batch


def gnn_train_flops(cfg, n_nodes: int, n_edges: int) -> float:
    h = cfg.d_hidden
    enc = n_nodes * (cfg.d_feat + h) * h + n_edges * (cfg.d_edge_feat + h) * h
    per_layer = n_edges * (3 * h + h) * h + n_nodes * (2 * h + h) * h
    dec = n_nodes * (h * h + h * cfg.n_vars)
    fwd = 2.0 * (enc + cfg.n_layers * per_layer + dec)
    mult = 4.0 if cfg.remat != "none" else 3.0
    return fwd * mult


def recsys_dense_params(cfg) -> int:
    """Interaction/MLP params (excludes the embedding table + wide vector)."""
    import numpy as np
    import jax

    from repro.archs.recsys import abstract_params

    p = abstract_params(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(p):
        k = jax.tree_util.keystr(path)
        if "table" in k or "wide" in k or "pos_embed" in k:
            continue
        total += int(np.prod(leaf.shape))
    return total


def recsys_forward_flops(cfg, batch: int) -> float:
    dense = recsys_dense_params(cfg)
    if cfg.kind == "din":
        # attention MLP runs per history position; split params by module
        per_hist = 0
        import numpy as np
        import jax

        from repro.archs.recsys import abstract_params

        p = abstract_params(cfg)
        attn_p = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p["attn"]))
        rest = dense - attn_p
        return 2.0 * batch * (attn_p * cfg.seq_len + rest)
    if cfg.kind == "sasrec":
        per_pos = dense  # blocks run per sequence position
        attn_quad = 2.0 * 2.0 * cfg.seq_len * cfg.embed_dim * cfg.n_blocks
        return 2.0 * batch * cfg.seq_len * (per_pos + attn_quad) / 1.0
    return 2.0 * batch * dense


def recsys_train_flops(cfg, batch: int) -> float:
    return 3.0 * recsys_forward_flops(cfg, batch)


# ---------------------------------------------------------------------------
# analytic HBM bytes (lower-bound traffic)
# ---------------------------------------------------------------------------


def _dtype_bytes(cfg) -> int:
    import jax.numpy as jnp

    return jnp.dtype(getattr(cfg, "dtype", jnp.float32)).itemsize


def lm_train_bytes(cfg, batch: int, seq: int) -> float:
    b = _dtype_bytes(cfg)
    tokens = batch * seq
    p = cfg.n_params()
    # params: read fwd + read bwd-recompute + grad write + AdamW (rd p,m,v / wr p,m,v in f32)
    param_traffic = p * b * 3 + p * 4 * 6
    # activations: ~6 major [tokens, d] tensors read+written per layer
    act = cfg.n_layers * tokens * cfg.d_model * b * 12
    logits = 2.0 * tokens * cfg.vocab * 4 / max(1, (tokens // cfg.vocab_chunk) if cfg.vocab_chunk else 1)
    return param_traffic + act + logits


def lm_decode_bytes(cfg, batch: int, context: int) -> float:
    b = _dtype_bytes(cfg)
    params = cfg.n_active_params() * b  # every weight read once
    cache = 0.0
    for l in range(cfg.n_layers):
        w = cfg.layer_window(l)
        s_eff = min(w, context) if w > 0 else context
        cache += 2.0 * s_eff * cfg.n_kv_heads * cfg.d_head * b * batch  # k+v read
    return params + cache


def lm_prefill_bytes(cfg, batch: int, seq: int) -> float:
    b = _dtype_bytes(cfg)
    tokens = batch * seq
    return cfg.n_params() * b + cfg.n_layers * tokens * cfg.d_model * b * 8


def gnn_train_bytes(cfg, n_nodes: int, n_edges: int) -> float:
    h, b = cfg.d_hidden, _dtype_bytes(cfg)
    per_layer = (2 * n_edges + 2 * n_nodes) * h * b * 3  # msgs+nodes, fwd/bwd
    return cfg.n_params() * (4 * 9) + cfg.n_layers * per_layer


def recsys_train_bytes(cfg, batch: int) -> float:
    lookups = batch * cfg.table.n_slots * cfg.table.dim * 4 * 3  # gather + grad scatter
    if cfg.kind in ("din", "sasrec"):
        lookups *= cfg.seq_len / max(cfg.table.n_slots, 1)
    dense = recsys_dense_params(cfg) * 4 * 9
    acts = batch * 4 * 4096  # order-of-magnitude MLP activations
    return lookups + dense + acts


def recsys_serve_bytes(cfg, batch: int) -> float:
    lookups = batch * cfg.table.n_slots * cfg.table.dim * 4
    if cfg.kind in ("din", "sasrec"):
        lookups *= cfg.seq_len / max(cfg.table.n_slots, 1)
    return lookups + recsys_dense_params(cfg) * 4


# ---------------------------------------------------------------------------
# loop-aware collective parsing
# ---------------------------------------------------------------------------

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*condition=%?([\w\.\-]+).*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"%?([\w\.\-]+)\s*=\s*s(?:32|64)\[\]\s+constant\((\d+)\)")
_ROOT_CMP = re.compile(r"ROOT\s+%?[\w\.\-]+\s*=\s*pred\[\]\s+compare\(([^)]*)\)")
_COLL_LINE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Computation:
    name: str
    coll: dict  # kind -> {count, bytes}
    whiles: list  # [(cond_name, body_name)]
    constants: dict  # const name -> int
    root_cmp_args: Optional[str] = None


def _parse_computations(hlo: str) -> dict:
    comps: dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for line in hlo.splitlines():
        s = line.strip()
        hdr = _COMP_HDR.match(line) if (line and not line.startswith(" ")) else None
        if hdr is None and s.endswith("{") and ("->" in s) and ("%" in s):
            hdr = _COMP_HDR.match(s)
        if hdr:
            cur = _Computation(hdr.group(1), {}, [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if s == "}":
            cur = None
            continue
        mc = _CONST_RE.search(s)
        if mc:
            cur.constants[mc.group(1)] = int(mc.group(2))
        mw = _WHILE_RE.search(s)
        if mw:
            cur.whiles.append((mw.group(1), mw.group(2)))
        mr = _ROOT_CMP.search(s)
        if mr:
            cur.root_cmp_args = mr.group(1)
        ml = _COLL_LINE.search(s)
        if ml and "-done" not in s:
            ty, kind = ml.group(1), ml.group(2)
            b = _shape_bytes(ty) * (2 if kind == "all-reduce" else 1)
            rec = cur.coll.setdefault(kind, {"count": 0, "bytes": 0})
            rec["count"] += 1
            rec["bytes"] += b
    return comps


def _trip_count(cond: _Computation) -> int:
    """Trip count from the loop condition's ROOT compare vs constant."""
    if cond.root_cmp_args:
        for name, val in cond.constants.items():
            if name in cond.root_cmp_args:
                return max(1, val)
    # fallback: the largest constant in the condition
    return max([1] + list(cond.constants.values()))


def parse_collectives_loop_aware(hlo: str) -> dict:
    """Per-device collective bytes with while-loop trip multiplication."""
    comps = _parse_computations(hlo)
    # multiplier per computation: product of enclosing loop trip counts
    mult: dict[str, int] = {name: 1 for name in comps}

    # iterate to fixpoint (nested whiles): body multiplier = caller's * trips
    for _ in range(8):
        changed = False
        for c in comps.values():
            for cond_name, body_name in c.whiles:
                cond = comps.get(cond_name)
                trips = _trip_count(cond) if cond else 1
                want = mult.get(c.name, 1) * trips
                for target in (body_name, cond_name):
                    if target in mult and mult[target] != want:
                        mult[target] = want
                        changed = True
        if not changed:
            break

    out: dict = {}
    for c in comps.values():
        m = mult.get(c.name, 1)
        for kind, rec in c.coll.items():
            agg = out.setdefault(kind, {"count": 0, "bytes": 0})
            agg["count"] += rec["count"] * m
            agg["bytes"] += rec["bytes"] * m
    out["total_bytes"] = sum(v["bytes"] for v in out.values() if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# dispatch per (family, kind)
# ---------------------------------------------------------------------------


def analytic_costs(family: str, kind: str, cfg, dims: dict) -> dict:
    """(flops, bytes) for the whole step, hardware-independent."""
    if family == "lm":
        B, S = dims["global_batch"], dims["seq_len"]
        if kind == "train":
            return {"flops": lm_train_flops(cfg, B, S), "bytes": lm_train_bytes(cfg, B, S)}
        if kind == "prefill":
            return {"flops": lm_prefill_flops(cfg, B, S), "bytes": lm_prefill_bytes(cfg, B, S)}
        return {"flops": lm_decode_flops(cfg, B, S), "bytes": lm_decode_bytes(cfg, B, S)}
    if family == "gnn":
        n, e = dims["_n_nodes"], dims["_n_edges"]
        return {"flops": gnn_train_flops(cfg, n, e), "bytes": gnn_train_bytes(cfg, n, e)}
    if family == "recsys":
        B = dims.get("n_candidates", dims["batch"]) if kind == "retrieval" else dims["batch"]
        if kind == "train":
            return {"flops": recsys_train_flops(cfg, B), "bytes": recsys_train_bytes(cfg, B)}
        return {"flops": recsys_forward_flops(cfg, B), "bytes": recsys_serve_bytes(cfg, B)}
    raise ValueError(family)
