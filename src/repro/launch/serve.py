"""Serving driver: ``python -m repro.launch.serve [...]``.

End-to-end anytime retrieval: synthetic corpus -> retrieval-model treatment
-> impact index -> batched SAAT serving with the deadline->rho controller.
Prints effectiveness (RR@10) + the full latency distribution (tail latency is
the paper's headline serving metric).
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from repro.core import build_impact_index, pad_queries
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.metrics.ir_metrics import mrr_at_k
from repro.models.treatments import MODEL_NAMES, apply_treatment
from repro.serving import AnytimeServer, ServingConfig, run_query_stream


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="spladev2", choices=list(MODEL_NAMES))
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=500)
    ap.add_argument("--k", type=int, default=100)
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--rho", type=int, default=None, help="fixed posting budget (overrides deadline)")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument(
        "--engine", default="saat", choices=("saat", "daat"),
        help="saat = anytime rho-budgeted; daat = batched Block-Max pruning",
    )
    ap.add_argument("--daat-est-blocks", type=int, default=8)
    ap.add_argument("--daat-block-budget", type=int, default=16)
    ap.add_argument(
        "--fused-topk", action="store_true",
        help="SAAT: fuse top-k into the scatter kernel (accumulator never hits HBM)",
    )
    ap.add_argument(
        "--daat-use-kernels", action="store_true",
        help="DAAT: route phase 2 through the batched Pallas kernels",
    )
    args = ap.parse_args()
    if args.fused_topk and args.engine != "saat":
        ap.error("--fused-topk is a SAAT scatter fusion; use --engine saat")
    if args.daat_use_kernels and args.engine != "daat":
        ap.error("--daat-use-kernels selects DAAT kernels; use --engine daat")
    if args.engine == "daat" and (args.deadline_ms is not None or args.rho is not None):
        ap.error("--deadline-ms/--rho are SAAT budgets; the daat engine cannot honor them")

    corpus = generate_corpus(CorpusConfig(n_docs=args.docs, n_queries=args.queries))
    enc = apply_treatment(corpus, args.model)
    index = build_impact_index(
        enc.doc_idx, enc.term_idx, enc.weights, corpus.n_docs, enc.n_terms
    )
    max_q = max(len(t) for t in enc.query_terms)
    qt, qw = pad_queries(enc.query_terms, enc.query_weights, max_q, enc.n_terms)

    ladder = (args.rho,) if args.rho else (100_000, 500_000, 1_000_000, 5_000_000)
    server = AnytimeServer(
        index,
        ServingConfig(
            k=args.k, rho_ladder=ladder, batch_size=args.batch,
            deadline_ms=args.deadline_ms, engine=args.engine,
            fused_topk=args.fused_topk,
            daat_est_blocks=args.daat_est_blocks, daat_block_budget=args.daat_block_budget,
            daat_use_kernels=args.daat_use_kernels,
        ),
    )
    server.warmup(jnp.asarray(qt[: args.batch]), jnp.asarray(qw[: args.batch]))
    server.reset_stats()
    scores, ids = run_query_stream(server, qt, qw)
    stats = server.stats()
    print(
        json.dumps(
            {
                "model": args.model,
                "n_docs": corpus.n_docs,
                "n_postings": index.n_postings,
                "rr@10": round(mrr_at_k(ids, corpus.qrels, 10), 4),
                "latency": {k: round(v, 3) for k, v in stats.row().items()},
                "tail_ratio_p99_p50": round(stats.tail_ratio, 2),
            },
            indent=1,
        )
    )


if __name__ == "__main__":
    main()
