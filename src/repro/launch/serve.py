"""Serving driver: ``python -m repro.launch.serve [...]``.

End-to-end anytime retrieval: synthetic corpus -> retrieval-model treatment
-> impact index -> batched SAAT serving with the deadline->rho controller.
Prints effectiveness (RR@10) + the full latency distribution (tail latency is
the paper's headline serving metric).

``--queue`` switches from pre-formed batches to arrival-driven serving: a
seeded Poisson request stream (``--arrival-qps``) flows through the
continuous-batching ``AdmissionQueue`` on a ``HybridClock`` (scripted
arrivals + real measured service times), and the report adds queue-wait
percentiles, per-bucket flush counts, and the deadline-policy violation
count — which is falsifiable here, since service time genuinely consumes
deadline budget. ``--lq-buckets`` turns on Lq-bucketed executables in
either mode. (The fully deterministic SimulatedClock variant of this loop
lives in tests/test_queue.py.)

``--mutate-qps`` layers a seeded Poisson *mutation* stream (adds / updates /
deletes over an ``IndexHandle``) onto the arrival stream: the replay runs on
a ``SimulatedClock`` through :func:`repro.serving.lifecycle.replay_with_churn`
with threshold compaction hot-swapping new generations between flushes. The
report then adds the churn ledger: per-op counts, compactions, the final
generation, and the generation span observed across flushes.

``--counters-port`` starts a Prometheus-style scrape endpoint
(``GET /metrics``) on localhost for the duration of the run: each scrape
derives the counter families fresh from the live server/queue objects —
including the index lifecycle gauges (``repro_index_generation``,
``repro_index_tombstones``, ``repro_index_delta_docs``) when the corpus is
mutable. Port 0 picks an ephemeral port (printed to stderr).
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from repro.core import build_impact_index, pad_queries
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.metrics.ir_metrics import mrr_at_k
from repro.metrics.latency import HybridClock, summarize_latencies
from repro.models.treatments import MODEL_NAMES, apply_treatment
from repro.serving import AnytimeServer, ServingConfig, run_query_stream
from repro.serving.queue import AdmissionQueue, replay_arrivals


def _csv_ints(text: str) -> tuple[int, ...]:
    try:
        return tuple(int(x) for x in text.split(",") if x.strip())
    except ValueError as e:
        raise argparse.ArgumentTypeError(f"expected comma-separated ints, got {text!r}") from e


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="spladev2", choices=list(MODEL_NAMES))
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=500)
    ap.add_argument("--k", type=int, default=100)
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--rho", type=int, default=None, help="fixed posting budget (overrides deadline)")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument(
        "--engine", default="saat", choices=("saat", "daat"),
        help="saat = anytime rho-budgeted; daat = batched Block-Max pruning",
    )
    ap.add_argument("--daat-est-blocks", type=int, default=8)
    ap.add_argument("--daat-block-budget", type=int, default=16)
    ap.add_argument(
        "--fused-topk", action="store_true",
        help="SAAT: fuse top-k into the scatter kernel (accumulator never hits HBM)",
    )
    ap.add_argument(
        "--daat-use-kernels", action="store_true",
        help="DAAT: route phase 2 through the batched Pallas kernels",
    )
    ap.add_argument(
        "--daat-fused-chunk", action="store_true",
        help="DAAT: fuse each phase-2 trip's select+score+merge into the "
        "single VMEM-resident chunk_step kernel (needs --daat-use-kernels)",
    )
    ap.add_argument(
        "--daat-trips-per-launch", type=int, default=1, metavar="N",
        help="DAAT: batch up to N phase-2 trips inside one fused chunk_step "
        "launch (pool/theta cross HBM once per launch; needs "
        "--daat-fused-chunk)",
    )
    ap.add_argument(
        "--lq-buckets", type=_csv_ints, default=None, metavar="W1,W2,...",
        help="Lq bucket widths: pad each batch to the smallest covering "
        "bucket (one executable per (config, bucket); bit-identical results)",
    )
    ap.add_argument(
        "--queue", action="store_true",
        help="serve a Poisson arrival stream through the continuous-batching "
        "AdmissionQueue (scripted arrivals, real measured service times)",
    )
    ap.add_argument("--arrival-qps", type=float, default=2000.0, help="Poisson arrival rate")
    ap.add_argument(
        "--request-deadline-ms", type=float, default=25.0,
        help="per-request completion deadline for the admission queue",
    )
    ap.add_argument(
        "--queue-shapes", type=_csv_ints, default=(8, 32), metavar="B1,B2,...",
        help="allowed flush batch shapes for the admission queue",
    )
    ap.add_argument(
        "--queue-safety-ms", type=float, default=2.0,
        help="flush headroom before each due instant (absorbs host dispatch cost)",
    )
    ap.add_argument(
        "--degrade-rho", action="store_true",
        help="SAAT + --queue: a flush that can no longer meet the oldest "
        "deadline at the full budget degrades to the largest calibrated rho "
        "that still fits (degradation replaces violation; served levels are "
        "reported per flush)",
    )
    ap.add_argument(
        "--eval-qrels", action="store_true",
        help="report the effectiveness ledger against the synthetic corpus "
        "qrels: Recall/MRR/NDCG per rho level vs the exact budget (direct "
        "mode) or per rho actually served (--queue mode), plus the smallest "
        "rho within 3%% MRR loss",
    )
    ap.add_argument(
        "--queue-max-wait-s", type=float, default=None,
        help="age-based flush bound: a bucket flushes no later than "
        "oldest-arrival + this many seconds (keeps deadline-less traffic "
        "from starving in a never-full bucket)",
    )
    ap.add_argument(
        "--mutate-qps", type=float, default=None, metavar="QPS",
        help="with --queue: interleave a seeded Poisson mutation stream "
        "(adds/updates/deletes on an IndexHandle) with the arrival stream; "
        "threshold compaction hot-swaps generations between flushes. Runs "
        "the deterministic SimulatedClock replay (service wall time is not "
        "measured in this mode)",
    )
    ap.add_argument(
        "--compact-delta-docs", type=int, default=64, metavar="N",
        help="churn replay: compact once the delta segment holds N docs "
        "(the tombstone-fraction trigger uses the policy defaults)",
    )
    ap.add_argument(
        "--counters-port", type=int, default=None, metavar="PORT",
        help="serve the counter families at http://127.0.0.1:PORT/metrics "
        "for the duration of the run (0 = ephemeral port, printed to stderr)",
    )
    ap.add_argument(
        "--counters-linger-s", type=float, default=0.0, metavar="S",
        help="keep the --counters-port endpoint up S seconds after the "
        "report prints (for external scrapers)",
    )
    ap.add_argument(
        "--counters", action="store_true",
        help="export the serving counter families (Prometheus text exposition "
        "to stderr, structured copy under report['counters']); with --queue "
        "this includes the admission-queue flush/violation/served-rho "
        "families, otherwise the server-side families only",
    )
    ap.add_argument("--seed", type=int, default=0, help="arrival-schedule RNG seed")
    args = ap.parse_args()
    if args.queue and args.lq_buckets is None:
        ap.error("--queue needs --lq-buckets (the queue coalesces onto the bucket grid)")
    if args.fused_topk and args.engine != "saat":
        ap.error("--fused-topk is a SAAT scatter fusion; use --engine saat")
    if args.daat_use_kernels and args.engine != "daat":
        ap.error("--daat-use-kernels selects DAAT kernels; use --engine daat")
    if args.daat_fused_chunk and not args.daat_use_kernels:
        ap.error("--daat-fused-chunk fuses the kernel chunk step; add --daat-use-kernels")
    if args.daat_trips_per_launch < 1:
        ap.error("--daat-trips-per-launch must be >= 1")
    if args.daat_trips_per_launch > 1 and not args.daat_fused_chunk:
        ap.error(
            "--daat-trips-per-launch > 1 batches trips inside the fused "
            "chunk_step kernel; add --daat-fused-chunk"
        )
    if args.engine == "daat" and (args.deadline_ms is not None or args.rho is not None):
        ap.error("--deadline-ms/--rho are SAAT budgets; the daat engine cannot honor them")
    if args.degrade_rho and not args.queue:
        ap.error("--degrade-rho is a flush-time policy of the admission queue; add --queue")
    if args.degrade_rho and args.engine != "saat":
        ap.error("--degrade-rho trades the SAAT posting budget; use --engine saat")
    if args.mutate_qps is not None and not args.queue:
        ap.error("--mutate-qps interleaves mutations with queue flushes; add --queue")
    if args.mutate_qps is not None and args.mutate_qps <= 0:
        ap.error("--mutate-qps must be positive")
    if args.counters_port is not None and not args.counters:
        ap.error("--counters-port scrapes the counter families; add --counters")

    corpus = generate_corpus(CorpusConfig(n_docs=args.docs, n_queries=args.queries))
    enc = apply_treatment(corpus, args.model)
    max_q = max(len(t) for t in enc.query_terms)
    qt, qw = pad_queries(enc.query_terms, enc.query_weights, max_q, enc.n_terms)

    ladder = (args.rho,) if args.rho else (100_000, 500_000, 1_000_000, 5_000_000)
    cfg = ServingConfig(
        k=args.k, rho_ladder=ladder, batch_size=args.batch,
        deadline_ms=args.deadline_ms, engine=args.engine,
        fused_topk=args.fused_topk,
        daat_est_blocks=args.daat_est_blocks, daat_block_budget=args.daat_block_budget,
        daat_use_kernels=args.daat_use_kernels,
        daat_fused_chunk=args.daat_fused_chunk,
        daat_trips_per_launch=args.daat_trips_per_launch,
        lq_buckets=args.lq_buckets,
    )
    if args.queue and args.mutate_qps is not None:
        _serve_churn(args, corpus, enc, cfg, qt, qw)
        return
    index = build_impact_index(
        enc.doc_idx, enc.term_idx, enc.weights, corpus.n_docs, enc.n_terms
    )
    if args.queue:
        _serve_queue(args, corpus, index, enc, cfg, qt, qw)
        return
    server = AnytimeServer(index, cfg)
    endpoint = _maybe_counters_endpoint(args, server)
    server.warmup(jnp.asarray(qt[: args.batch]), jnp.asarray(qw[: args.batch]))
    server.reset_stats()
    scores, ids = run_query_stream(server, qt, qw)
    stats = server.stats()
    report = {
        "model": args.model,
        "n_docs": corpus.n_docs,
        "n_postings": index.n_postings,
        "rr@10": round(mrr_at_k(ids, corpus.qrels, 10), 4),
        "latency": {k: round(v, 3) for k, v in stats.row().items()},
        "tail_ratio_p99_p50": round(stats.tail_ratio, 2),
    }
    if args.eval_qrels:
        if args.engine != "saat":
            raise SystemExit("--eval-qrels sweeps the SAAT rho ladder; use --engine saat")
        from repro.metrics.ir_metrics import cheapest_rho_within_loss, rho_effectiveness_sweep

        sweep = rho_effectiveness_sweep(
            server, qt, qw, np.asarray(corpus.qrels),
            recall_k=min(args.k, 100), batch_size=args.batch,
        )
        report["effectiveness_by_rho"] = [
            {k: (round(v, 4) if isinstance(v, float) else v) for k, v in row.items()}
            for row in sweep
        ]
        report["rho_within_3pct_mrr_loss"] = cheapest_rho_within_loss(sweep, max_loss=0.03)
    if args.counters:
        report["counters"] = _export_counters(server)
    print(json.dumps(report, indent=1))
    _close_counters_endpoint(args, endpoint)


def _serve_queue(args, corpus, index, enc, cfg: ServingConfig, qt, qw) -> None:
    """Arrival-driven serving: scripted Poisson arrivals, real service times.

    The HybridClock accrues measured wall time between events, so the cost
    model calibrates on real service cost and the reported
    deadline_policy_violations count is falsifiable (a slow flush really
    shows up); arrivals follow the seeded schedule, so the *load shape* is
    reproducible even though wall times are not.
    """
    clock = HybridClock()
    server = AnytimeServer(index, cfg, clock=clock)
    queue = AdmissionQueue(
        server,
        batch_shapes=args.queue_shapes,
        clock=clock,
        safety_ms=args.queue_safety_ms,
        max_wait_s=args.queue_max_wait_s,
        degrade_rho=args.degrade_rho,
    )
    # endpoint up before the (slow) warmup so scrapers see the whole run
    endpoint = _maybe_counters_endpoint(args, server, queue)
    server.warmup(
        jnp.asarray(qt[: min(8, qt.shape[0])]),
        jnp.asarray(qw[: min(8, qw.shape[0])]),
        batch_sizes=args.queue_shapes,
    )
    server.reset_stats()
    rng = np.random.default_rng(args.seed)
    n = args.queries
    gaps = rng.exponential(1.0 / args.arrival_qps, size=n)
    arrivals = np.cumsum(gaps)
    order = rng.integers(0, qt.shape[0], size=n)
    completions = replay_arrivals(
        queue,
        arrivals.tolist(),
        [qt[i] for i in order],
        [qw[i] for i in order],
        [args.request_deadline_ms] * n,
    )
    waits = summarize_latencies([c.wait_ms for c in completions])
    by_rid = sorted(completions, key=lambda c: c.rid)
    ids = np.stack([c.doc_ids for c in by_rid])
    qrels = np.asarray(corpus.qrels)[order]
    flush_counts: dict = {}
    for f in queue.flush_log:
        key = f"b{f.bucket}xB{f.batch_shape}"
        flush_counts[key] = flush_counts.get(key, 0) + 1
    report = {
        "model": args.model,
        "mode": "admission-queue",
        "requests": n,
        "completed": queue.n_completed,
        "deadline_policy_violations": queue.n_violations,
        "infeasible_on_arrival": queue.n_infeasible,
        "degraded_flushes": queue.n_degraded,
        "rr@10": round(mrr_at_k(ids, qrels, 10), 4),
        "queue_wait_ms": {k: round(v, 3) for k, v in waits.row().items()},
        "flushes": dict(sorted(flush_counts.items())),
        "flush_reasons": {
            r: sum(1 for f in queue.flush_log if f.reason == r)
            for r in ("full", "deadline", "drain")
        },
    }
    if args.eval_qrels:
        # effectiveness of what was ACTUALLY served, grouped by flush rho —
        # the live-traffic ledger of the degradation trade
        from repro.metrics.ir_metrics import effectiveness_report

        groups: dict = {}
        for c in by_rid:
            groups.setdefault(c.rho, []).append(c)
        report["effectiveness_by_served_rho"] = [
            {
                "rho": rho,
                "n_queries": len(cs),
                **{
                    k: (round(v, 4) if isinstance(v, float) else v)
                    for k, v in effectiveness_report(
                        np.stack([c.doc_ids for c in cs]),
                        qrels[[c.rid for c in cs]],
                        recall_k=min(args.k, 100),
                    ).items()
                },
            }
            for rho, cs in sorted(groups.items(), key=lambda kv: (kv[0] is None, kv[0] or 0))
        ]
    if args.counters:
        report["counters"] = _export_counters(server, queue)
    print(json.dumps(report, indent=1))
    _close_counters_endpoint(args, endpoint)


def _mutation_schedule(rng, n_docs: int, n_terms: int, horizon_s: float, qps: float):
    """Seeded Poisson mutation stream over an evolving live-gid set.

    The gid bookkeeping here mirrors the handle's (adds take sequential gids;
    updates/deletes target currently-live gids only), so the schedule is
    always applicable and the replay never hits a dead-gid mutation.
    """
    from repro.serving.lifecycle import MutationEvent

    alive = list(range(n_docs))
    next_gid = n_docs
    events = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / qps))
        if t >= horizon_s:
            break
        op = str(rng.choice(["add", "update", "delete"], p=[0.5, 0.25, 0.25]))
        if not alive and op != "add":
            op = "add"
        if op == "delete":
            gid = alive.pop(int(rng.integers(len(alive))))
            events.append(MutationEvent(t_s=t, op="delete", gid=gid))
            continue
        n_term = int(rng.integers(2, 8))
        terms = rng.choice(n_terms, size=n_term, replace=False).astype(np.int64)
        weights = rng.uniform(0.2, 4.0, n_term)
        if op == "add":
            events.append(MutationEvent(t_s=t, op="add", terms=terms, weights=weights))
            alive.append(next_gid)
            next_gid += 1
        else:
            gid = int(alive[int(rng.integers(len(alive)))])
            events.append(
                MutationEvent(t_s=t, op="update", gid=gid, terms=terms, weights=weights)
            )
    return events


def _serve_churn(args, corpus, enc, cfg: ServingConfig, qt, qw) -> None:
    """Arrival + mutation replay over a generation-handled index.

    Runs the deterministic :func:`replay_with_churn` loop on a
    ``SimulatedClock``: queries and mutations interleave at their scheduled
    instants, threshold compaction folds main+delta−tombstones and hot-swaps
    the new generation between flushes, and the report carries the churn
    ledger next to the usual queue metrics.
    """
    from repro.core.index_handle import IndexHandle
    from repro.metrics.latency import SimulatedClock
    from repro.serving.lifecycle import CompactionPolicy, Compactor, replay_with_churn

    clock = SimulatedClock()
    handle = IndexHandle.from_corpus(
        enc.doc_idx, enc.term_idx, enc.weights, corpus.n_docs, enc.n_terms
    )
    server = AnytimeServer(handle, cfg, clock=clock)
    queue = AdmissionQueue(
        server,
        batch_shapes=args.queue_shapes,
        clock=clock,
        safety_ms=args.queue_safety_ms,
        max_wait_s=args.queue_max_wait_s,
        degrade_rho=args.degrade_rho,
    )
    # endpoint up before the (slow) warmup so scrapers see the whole run
    endpoint = _maybe_counters_endpoint(args, server, queue)
    server.warmup(
        jnp.asarray(qt[: min(8, qt.shape[0])]),
        jnp.asarray(qw[: min(8, qw.shape[0])]),
        batch_sizes=args.queue_shapes,
    )
    server.reset_stats()
    rng = np.random.default_rng(args.seed)
    n = args.queries
    arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_qps, size=n))
    order = rng.integers(0, qt.shape[0], size=n)
    mutations = _mutation_schedule(
        np.random.default_rng(args.seed + 1), corpus.n_docs, enc.n_terms,
        float(arrivals[-1]), args.mutate_qps,
    )
    compactor = Compactor(
        queue, handle, CompactionPolicy(max_delta_docs=args.compact_delta_docs)
    )
    completions, mutation_log = replay_with_churn(
        queue,
        handle,
        arrivals.tolist(),
        [qt[i] for i in order],
        [qw[i] for i in order],
        [args.request_deadline_ms] * n,
        mutations,
        compactor=compactor,
    )
    waits = summarize_latencies([c.wait_ms for c in completions])
    by_rid = sorted(completions, key=lambda c: c.rid)
    ids = np.stack([c.doc_ids for c in by_rid])
    qrels = np.asarray(corpus.qrels)[order]
    gens = [f.generation for f in queue.flush_log] or [handle.generation]
    op_counts: dict = {}
    for m in mutation_log:
        op_counts[m["op"]] = op_counts.get(m["op"], 0) + 1
    report = {
        "model": args.model,
        "mode": "admission-queue+churn",
        "requests": n,
        "completed": queue.n_completed,
        "deadline_policy_violations": queue.n_violations,
        "rr@10": round(mrr_at_k(ids, qrels, 10), 4),
        "queue_wait_ms": {k: round(v, 3) for k, v in waits.row().items()},
        "mutations": {
            "total": len(mutation_log),
            **dict(sorted(op_counts.items())),
            "compactions": compactor.n_compactions,
            "final_generation": handle.generation,
            "flush_generation_span": [min(gens), max(gens)],
            "pending_delta_docs": handle.delta_docs,
            "tombstones": handle.tombstone_count,
        },
    }
    if args.counters:
        report["counters"] = _export_counters(server, queue)
    print(json.dumps(report, indent=1))
    _close_counters_endpoint(args, endpoint)


def _maybe_counters_endpoint(args, server, queue=None):
    """Start the localhost scrape endpoint when ``--counters-port`` is set.

    Each ``GET /metrics`` derives the counter families fresh from the live
    server/queue — the same scrape-time derivation ``--counters`` uses for
    the final report, so the endpoint adds nothing to the hot path.
    """
    if args.counters_port is None:
        return None
    import sys
    import threading
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    def render() -> str:
        return _scrape_registry(server, queue).render()

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path not in ("/", "/metrics"):
                self.send_error(404)
                return
            body = render().encode()
            self.send_response(200)
            self.send_header("Content-Type", "text/plain; version=0.0.4")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *_args):  # keep stdout JSON-clean
            pass

    httpd = ThreadingHTTPServer(("127.0.0.1", args.counters_port), Handler)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    sys.stderr.write(
        f"counters endpoint: http://127.0.0.1:{httpd.server_address[1]}/metrics\n"
    )
    return httpd


def _close_counters_endpoint(args, httpd) -> None:
    if httpd is None:
        return
    if args.counters_linger_s > 0:
        import time

        time.sleep(args.counters_linger_s)
    httpd.shutdown()
    httpd.server_close()


def _scrape_registry(server, queue=None):
    from repro.serving.counters import CounterRegistry

    registry = CounterRegistry()
    if queue is not None:
        queue.export_counters(registry)
    server.export_counters(registry)
    return registry


def _export_counters(server, queue=None) -> dict:
    """Scrape the serving counter families once, post-run.

    Counters are *derived* at scrape time from the flush log and server
    tallies — the hot path carries no instrumentation (the purity lint in
    ``repro.analysis.hot_path`` would flag it). The Prometheus text
    exposition goes to stderr so the stdout JSON report stays parseable;
    a structured copy lands in the report for jq-style assertions.
    """
    import sys

    registry = _scrape_registry(server, queue)
    sys.stderr.write(registry.render())
    return registry.as_dict()


if __name__ == "__main__":
    main()
