"""Serving driver: ``python -m repro.launch.serve [...]``.

End-to-end anytime retrieval: synthetic corpus -> retrieval-model treatment
-> impact index -> batched SAAT serving with the deadline->rho controller.
Prints effectiveness (RR@10) + the full latency distribution (tail latency is
the paper's headline serving metric).

``--queue`` switches from pre-formed batches to arrival-driven serving: a
seeded Poisson request stream (``--arrival-qps``) flows through the
continuous-batching ``AdmissionQueue`` on a ``HybridClock`` (scripted
arrivals + real measured service times), and the report adds queue-wait
percentiles, per-bucket flush counts, and the deadline-policy violation
count — which is falsifiable here, since service time genuinely consumes
deadline budget. ``--lq-buckets`` turns on Lq-bucketed executables in
either mode. (The fully deterministic SimulatedClock variant of this loop
lives in tests/test_queue.py.)
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from repro.core import build_impact_index, pad_queries
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.metrics.ir_metrics import mrr_at_k
from repro.metrics.latency import HybridClock, summarize_latencies
from repro.models.treatments import MODEL_NAMES, apply_treatment
from repro.serving import AnytimeServer, ServingConfig, run_query_stream
from repro.serving.queue import AdmissionQueue, replay_arrivals


def _csv_ints(text: str) -> tuple[int, ...]:
    try:
        return tuple(int(x) for x in text.split(",") if x.strip())
    except ValueError as e:
        raise argparse.ArgumentTypeError(f"expected comma-separated ints, got {text!r}") from e


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="spladev2", choices=list(MODEL_NAMES))
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=500)
    ap.add_argument("--k", type=int, default=100)
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--rho", type=int, default=None, help="fixed posting budget (overrides deadline)")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument(
        "--engine", default="saat", choices=("saat", "daat"),
        help="saat = anytime rho-budgeted; daat = batched Block-Max pruning",
    )
    ap.add_argument("--daat-est-blocks", type=int, default=8)
    ap.add_argument("--daat-block-budget", type=int, default=16)
    ap.add_argument(
        "--fused-topk", action="store_true",
        help="SAAT: fuse top-k into the scatter kernel (accumulator never hits HBM)",
    )
    ap.add_argument(
        "--daat-use-kernels", action="store_true",
        help="DAAT: route phase 2 through the batched Pallas kernels",
    )
    ap.add_argument(
        "--daat-fused-chunk", action="store_true",
        help="DAAT: fuse each phase-2 trip's select+score+merge into the "
        "single VMEM-resident chunk_step kernel (needs --daat-use-kernels)",
    )
    ap.add_argument(
        "--daat-trips-per-launch", type=int, default=1, metavar="N",
        help="DAAT: batch up to N phase-2 trips inside one fused chunk_step "
        "launch (pool/theta cross HBM once per launch; needs "
        "--daat-fused-chunk)",
    )
    ap.add_argument(
        "--lq-buckets", type=_csv_ints, default=None, metavar="W1,W2,...",
        help="Lq bucket widths: pad each batch to the smallest covering "
        "bucket (one executable per (config, bucket); bit-identical results)",
    )
    ap.add_argument(
        "--queue", action="store_true",
        help="serve a Poisson arrival stream through the continuous-batching "
        "AdmissionQueue (scripted arrivals, real measured service times)",
    )
    ap.add_argument("--arrival-qps", type=float, default=2000.0, help="Poisson arrival rate")
    ap.add_argument(
        "--request-deadline-ms", type=float, default=25.0,
        help="per-request completion deadline for the admission queue",
    )
    ap.add_argument(
        "--queue-shapes", type=_csv_ints, default=(8, 32), metavar="B1,B2,...",
        help="allowed flush batch shapes for the admission queue",
    )
    ap.add_argument(
        "--queue-safety-ms", type=float, default=2.0,
        help="flush headroom before each due instant (absorbs host dispatch cost)",
    )
    ap.add_argument(
        "--degrade-rho", action="store_true",
        help="SAAT + --queue: a flush that can no longer meet the oldest "
        "deadline at the full budget degrades to the largest calibrated rho "
        "that still fits (degradation replaces violation; served levels are "
        "reported per flush)",
    )
    ap.add_argument(
        "--eval-qrels", action="store_true",
        help="report the effectiveness ledger against the synthetic corpus "
        "qrels: Recall/MRR/NDCG per rho level vs the exact budget (direct "
        "mode) or per rho actually served (--queue mode), plus the smallest "
        "rho within 3%% MRR loss",
    )
    ap.add_argument(
        "--queue-max-wait-s", type=float, default=None,
        help="age-based flush bound: a bucket flushes no later than "
        "oldest-arrival + this many seconds (keeps deadline-less traffic "
        "from starving in a never-full bucket)",
    )
    ap.add_argument(
        "--counters", action="store_true",
        help="export the serving counter families (Prometheus text exposition "
        "to stderr, structured copy under report['counters']); with --queue "
        "this includes the admission-queue flush/violation/served-rho "
        "families, otherwise the server-side families only",
    )
    ap.add_argument("--seed", type=int, default=0, help="arrival-schedule RNG seed")
    args = ap.parse_args()
    if args.queue and args.lq_buckets is None:
        ap.error("--queue needs --lq-buckets (the queue coalesces onto the bucket grid)")
    if args.fused_topk and args.engine != "saat":
        ap.error("--fused-topk is a SAAT scatter fusion; use --engine saat")
    if args.daat_use_kernels and args.engine != "daat":
        ap.error("--daat-use-kernels selects DAAT kernels; use --engine daat")
    if args.daat_fused_chunk and not args.daat_use_kernels:
        ap.error("--daat-fused-chunk fuses the kernel chunk step; add --daat-use-kernels")
    if args.daat_trips_per_launch < 1:
        ap.error("--daat-trips-per-launch must be >= 1")
    if args.daat_trips_per_launch > 1 and not args.daat_fused_chunk:
        ap.error(
            "--daat-trips-per-launch > 1 batches trips inside the fused "
            "chunk_step kernel; add --daat-fused-chunk"
        )
    if args.engine == "daat" and (args.deadline_ms is not None or args.rho is not None):
        ap.error("--deadline-ms/--rho are SAAT budgets; the daat engine cannot honor them")
    if args.degrade_rho and not args.queue:
        ap.error("--degrade-rho is a flush-time policy of the admission queue; add --queue")
    if args.degrade_rho and args.engine != "saat":
        ap.error("--degrade-rho trades the SAAT posting budget; use --engine saat")

    corpus = generate_corpus(CorpusConfig(n_docs=args.docs, n_queries=args.queries))
    enc = apply_treatment(corpus, args.model)
    index = build_impact_index(
        enc.doc_idx, enc.term_idx, enc.weights, corpus.n_docs, enc.n_terms
    )
    max_q = max(len(t) for t in enc.query_terms)
    qt, qw = pad_queries(enc.query_terms, enc.query_weights, max_q, enc.n_terms)

    ladder = (args.rho,) if args.rho else (100_000, 500_000, 1_000_000, 5_000_000)
    cfg = ServingConfig(
        k=args.k, rho_ladder=ladder, batch_size=args.batch,
        deadline_ms=args.deadline_ms, engine=args.engine,
        fused_topk=args.fused_topk,
        daat_est_blocks=args.daat_est_blocks, daat_block_budget=args.daat_block_budget,
        daat_use_kernels=args.daat_use_kernels,
        daat_fused_chunk=args.daat_fused_chunk,
        daat_trips_per_launch=args.daat_trips_per_launch,
        lq_buckets=args.lq_buckets,
    )
    if args.queue:
        _serve_queue(args, corpus, index, enc, cfg, qt, qw)
        return
    server = AnytimeServer(index, cfg)
    server.warmup(jnp.asarray(qt[: args.batch]), jnp.asarray(qw[: args.batch]))
    server.reset_stats()
    scores, ids = run_query_stream(server, qt, qw)
    stats = server.stats()
    report = {
        "model": args.model,
        "n_docs": corpus.n_docs,
        "n_postings": index.n_postings,
        "rr@10": round(mrr_at_k(ids, corpus.qrels, 10), 4),
        "latency": {k: round(v, 3) for k, v in stats.row().items()},
        "tail_ratio_p99_p50": round(stats.tail_ratio, 2),
    }
    if args.eval_qrels:
        if args.engine != "saat":
            raise SystemExit("--eval-qrels sweeps the SAAT rho ladder; use --engine saat")
        from repro.metrics.ir_metrics import cheapest_rho_within_loss, rho_effectiveness_sweep

        sweep = rho_effectiveness_sweep(
            server, qt, qw, np.asarray(corpus.qrels),
            recall_k=min(args.k, 100), batch_size=args.batch,
        )
        report["effectiveness_by_rho"] = [
            {k: (round(v, 4) if isinstance(v, float) else v) for k, v in row.items()}
            for row in sweep
        ]
        report["rho_within_3pct_mrr_loss"] = cheapest_rho_within_loss(sweep, max_loss=0.03)
    if args.counters:
        report["counters"] = _export_counters(server)
    print(json.dumps(report, indent=1))


def _serve_queue(args, corpus, index, enc, cfg: ServingConfig, qt, qw) -> None:
    """Arrival-driven serving: scripted Poisson arrivals, real service times.

    The HybridClock accrues measured wall time between events, so the cost
    model calibrates on real service cost and the reported
    deadline_policy_violations count is falsifiable (a slow flush really
    shows up); arrivals follow the seeded schedule, so the *load shape* is
    reproducible even though wall times are not.
    """
    clock = HybridClock()
    server = AnytimeServer(index, cfg, clock=clock)
    server.warmup(
        jnp.asarray(qt[: min(8, qt.shape[0])]),
        jnp.asarray(qw[: min(8, qw.shape[0])]),
        batch_sizes=args.queue_shapes,
    )
    server.reset_stats()
    queue = AdmissionQueue(
        server,
        batch_shapes=args.queue_shapes,
        clock=clock,
        safety_ms=args.queue_safety_ms,
        max_wait_s=args.queue_max_wait_s,
        degrade_rho=args.degrade_rho,
    )
    rng = np.random.default_rng(args.seed)
    n = args.queries
    gaps = rng.exponential(1.0 / args.arrival_qps, size=n)
    arrivals = np.cumsum(gaps)
    order = rng.integers(0, qt.shape[0], size=n)
    completions = replay_arrivals(
        queue,
        arrivals.tolist(),
        [qt[i] for i in order],
        [qw[i] for i in order],
        [args.request_deadline_ms] * n,
    )
    waits = summarize_latencies([c.wait_ms for c in completions])
    by_rid = sorted(completions, key=lambda c: c.rid)
    ids = np.stack([c.doc_ids for c in by_rid])
    qrels = np.asarray(corpus.qrels)[order]
    flush_counts: dict = {}
    for f in queue.flush_log:
        key = f"b{f.bucket}xB{f.batch_shape}"
        flush_counts[key] = flush_counts.get(key, 0) + 1
    report = {
        "model": args.model,
        "mode": "admission-queue",
        "requests": n,
        "completed": queue.n_completed,
        "deadline_policy_violations": queue.n_violations,
        "infeasible_on_arrival": queue.n_infeasible,
        "degraded_flushes": queue.n_degraded,
        "rr@10": round(mrr_at_k(ids, qrels, 10), 4),
        "queue_wait_ms": {k: round(v, 3) for k, v in waits.row().items()},
        "flushes": dict(sorted(flush_counts.items())),
        "flush_reasons": {
            r: sum(1 for f in queue.flush_log if f.reason == r)
            for r in ("full", "deadline", "drain")
        },
    }
    if args.eval_qrels:
        # effectiveness of what was ACTUALLY served, grouped by flush rho —
        # the live-traffic ledger of the degradation trade
        from repro.metrics.ir_metrics import effectiveness_report

        groups: dict = {}
        for c in by_rid:
            groups.setdefault(c.rho, []).append(c)
        report["effectiveness_by_served_rho"] = [
            {
                "rho": rho,
                "n_queries": len(cs),
                **{
                    k: (round(v, 4) if isinstance(v, float) else v)
                    for k, v in effectiveness_report(
                        np.stack([c.doc_ids for c in cs]),
                        qrels[[c.rid for c in cs]],
                        recall_k=min(args.k, 100),
                    ).items()
                },
            }
            for rho, cs in sorted(groups.items(), key=lambda kv: (kv[0] is None, kv[0] or 0))
        ]
    if args.counters:
        report["counters"] = _export_counters(server, queue)
    print(json.dumps(report, indent=1))


def _export_counters(server, queue=None) -> dict:
    """Scrape the serving counter families once, post-run.

    Counters are *derived* at scrape time from the flush log and server
    tallies — the hot path carries no instrumentation (the purity lint in
    ``repro.analysis.hot_path`` would flag it). The Prometheus text
    exposition goes to stderr so the stdout JSON report stays parseable;
    a structured copy lands in the report for jq-style assertions.
    """
    import sys

    from repro.serving.counters import CounterRegistry

    registry = CounterRegistry()
    if queue is not None:
        queue.export_counters(registry)
    server.export_counters(registry)
    sys.stderr.write(registry.render())
    return registry.as_dict()


if __name__ == "__main__":
    main()
