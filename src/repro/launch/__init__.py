"""Launchers: production mesh, multi-pod dry-run, train/serve drivers.

NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS at import — never import it
from code that needs the real device count.
"""
from repro.launch.mesh import make_production_mesh, mesh_for, n_chips  # noqa: F401
