import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init); do not move them. 512 placeholder host devices back
both the 16x16 single-pod and 2x16x16 multi-pod meshes.

For every runnable cell this driver:
  1. builds the abstract step (ShapeDtypeStructs only — zero allocation),
  2. ``jax.jit(fn, in_shardings=...).lower(*args).compile()``,
  3. records ``compiled.memory_analysis()`` (fits-in-HBM proof),
     ``compiled.cost_analysis()`` (FLOPs / bytes for §Roofline), and the
     collective-op byte census parsed from the post-SPMD optimized HLO,
  4. writes one JSON per cell under ``--out`` (benchmarks/roofline.py and
     EXPERIMENTS.md §Dry-run/§Roofline read these).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, get_arch
from repro.launch import costs
from repro.launch.mesh import mesh_for, n_chips
from repro.launch.steps import build_cell_plan

# v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (1 active link assumed — conservative)


def _mesh_scope(mesh):
    """Version-tolerant ambient-mesh scope.

    ``jax.set_mesh`` only exists in newer JAX releases; on older ones the
    ``Mesh`` object itself is the context manager that sets the ambient mesh
    (which ``repro.distributed.sharding._get_abstract_mesh`` reads back).
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def run_cell(arch_id: str, shape_name: str, mesh_name: str) -> dict:
    spec = get_arch(arch_id)
    cell = spec.cells[shape_name]
    rec: dict = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": cell.kind,
    }
    if cell.skip is not None:
        rec["status"] = "skipped"
        rec["skip_reason"] = cell.skip
        return rec
    mesh = mesh_for(mesh_name)
    chips = n_chips(mesh)
    t0 = time.time()
    try:
        plan = build_cell_plan(spec, shape_name, mesh)
        with _mesh_scope(mesh):
            lowered = jax.jit(plan.fn, in_shardings=plan.in_shardings).lower(*plan.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):  # older JAX: one dict per device
                ca = ca[0] if ca else {}
            hlo = compiled.as_text()
        coll = costs.parse_collectives_loop_aware(hlo)
        # analytic step totals (XLA HloCostAnalysis counts loop bodies once —
        # see launch/costs.py — so the roofline numerators are analytic)
        cfg = spec.config_for(shape_name)
        dims = dict(cell.dims)
        if spec.family == "gnn":
            dims["_n_nodes"] = plan.static_meta["n_nodes"]
            dims["_n_edges"] = plan.static_meta["n_edges"]
        an = costs.analytic_costs(spec.family, cell.kind, cfg, dims)
        flops_dev_raw = float(ca.get("flops", 0.0))
        bytes_dev_raw = float(ca.get("bytes accessed", 0.0))
        coll_dev = float(coll.get("total_bytes", 0))
        compute_s = an["flops"] / chips / PEAK_FLOPS_BF16
        memory_s = an["bytes"] / chips / HBM_BW
        collective_s = coll_dev / ICI_BW
        terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
        bottleneck = max(terms, key=terms.get)
        rec.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=dict(
                argument_bytes=ma.argument_size_in_bytes,
                output_bytes=ma.output_size_in_bytes,
                temp_bytes=ma.temp_size_in_bytes,
                total_bytes=ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes,
            ),
            cost=dict(
                flops_total_analytic=an["flops"],
                bytes_total_analytic=an["bytes"],
                flops_per_device_xla_raw=flops_dev_raw,  # loop bodies counted once
                bytes_per_device_xla_raw=bytes_dev_raw,
            ),
            collectives=coll,
            model_flops=plan.model_flops,
            useful_flops_ratio=(plan.model_flops / an["flops"] if an["flops"] else None),
            roofline=dict(
                **terms,
                bottleneck=bottleneck,
                step_time_lower_bound_s=max(terms.values()),
                roofline_fraction=(
                    min(1.0, compute_s / max(max(terms.values()), 1e-30))
                ),
            ),
            static_meta=plan.static_meta,
            hlo_lines=hlo.count("\n"),
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a recorded bug
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = sorted(ARCHS) if (args.all or args.arch is None) else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch_id in archs:
        spec = get_arch(arch_id)
        shapes = sorted(spec.cells) if (args.all or args.shape is None) else [args.shape]
        for shape in shapes:
            for mesh_name in meshes:
                rec = run_cell(arch_id, shape, mesh_name)
                fname = f"{arch_id}__{shape}__{mesh_name}.json"
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                if status == "ok":
                    r = rec["roofline"]
                    print(
                        f"[{status}] {arch_id}/{shape}/{mesh_name}: "
                        f"compile={rec['compile_s']}s "
                        f"mem/chip={rec['memory']['total_bytes']/2**30:.2f}GiB "
                        f"terms(c/m/x)={r['compute_s']:.2e}/{r['memory_s']:.2e}/{r['collective_s']:.2e}s "
                        f"bottleneck={r['bottleneck']}",
                        flush=True,
                    )
                elif status == "skipped":
                    print(f"[skip] {arch_id}/{shape}/{mesh_name}: {rec['skip_reason'][:80]}", flush=True)
                else:
                    failures += 1
                    print(f"[FAIL] {arch_id}/{shape}/{mesh_name}: {rec['error']}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
