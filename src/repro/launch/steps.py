"""Step builders: (arch x shape x mesh) -> (fn, abstract args, shardings).

This is the single place that knows how to turn an ArchSpec cell into the
jittable step the production job runs — shared by the dry-run (lower+compile
only), the trainers, and the smoke tests (which call the same builders with
reduced configs and real arrays).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, Cell, batch_specs
from repro.distributed.sharding import (
    batch_dim_sharding,
    cache_shardings,
    fully_sharded_dim,
    mesh_axes,
    param_shardings,
    train_state_shardings,
)
from repro.train.optim import AdamWConfig
from repro.train.trainer import abstract_train_state, make_train_step


@dataclasses.dataclass
class CellPlan:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable
    args: tuple  # abstract (ShapeDtypeStruct) args, or real arrays in tests
    in_shardings: tuple
    model_flops: float
    static_meta: dict


def _dp_size(mesh: Mesh) -> int:
    ax = mesh_axes(mesh)
    n = 1
    for a in ax.data:
        n *= mesh.shape[a]
    return n


def _maybe_batch_sharding(mesh: Mesh, leaf, *, fully: bool = False):
    """Shard the leading dim if divisible by the axis group; degrade
    all-axes -> data-axes -> replicated."""
    ax = mesh_axes(mesh)

    def group_size(group):
        n = 1
        for a in group:
            n *= mesh.shape[a]
        return n

    extra = max(len(leaf.shape) - 1, 0)
    if fully and leaf.shape and leaf.shape[0] % group_size(ax.all) == 0:
        return fully_sharded_dim(mesh, extra)
    if leaf.shape and leaf.shape[0] % group_size(ax.data) == 0:
        return batch_dim_sharding(mesh, extra)
    return NamedSharding(mesh, P())


def _batch_shardings(batch, mesh: Mesh, *, fully: bool = False):
    return jax.tree.map(lambda l: _maybe_batch_sharding(mesh, l, fully=fully), batch)


# --------------------------------------------------------------------------
# per-family builders
# --------------------------------------------------------------------------


def _lm_plan(spec: ArchSpec, cell: Cell, mesh: Mesh, opt_cfg: AdamWConfig) -> CellPlan:
    from repro.archs import transformer as T

    cfg = spec.config_for(cell.name)
    aparams = T.abstract_lm_params(cfg)
    p_sh = param_shardings(aparams, "lm", mesh)
    B, S = cell.dims["global_batch"], cell.dims["seq_len"]
    batch = batch_specs(spec, cell.name)

    if cell.kind == "train":
        state = abstract_train_state(aparams)
        st_sh = train_state_shardings(state, "lm", mesh)
        loss_fn = lambda p, b: T.lm_loss(p, b["tokens"], b["labels"], cfg)
        step = make_train_step(loss_fn, opt_cfg)
        args = (state, batch)
        in_sh = (st_sh, _batch_shardings(batch, mesh, fully=cfg.dp_layout))
        flops = T.train_step_model_flops(cfg, B, S)
    elif cell.kind == "prefill":
        step = lambda p, b: T.lm_prefill(p, b["tokens"], cfg)
        args = (aparams, batch)
        in_sh = (p_sh, _batch_shardings(batch, mesh))
        flops = T.train_step_model_flops(cfg, B, S) / 3.0  # fwd only
    elif cell.kind == "decode":
        cache = batch["cache"]
        step = lambda p, c, t, pos: T.lm_decode_step(p, c, t, pos, cfg)
        args = (aparams, cache, batch["tokens"], batch["pos"])
        in_sh = (
            p_sh,
            cache_shardings(cache, mesh),
            _maybe_batch_sharding(mesh, batch["tokens"]),
            _maybe_batch_sharding(mesh, batch["pos"]),
        )
        flops = T.decode_step_model_flops(cfg, B, S)
    else:
        raise ValueError(cell.kind)
    return CellPlan(
        arch_id=spec.arch_id,
        shape_name=cell.name,
        kind=cell.kind,
        fn=step,
        args=args,
        in_shardings=in_sh,
        model_flops=flops,
        static_meta={
            "n_params": cfg.n_params(),
            "n_active_params": cfg.n_active_params(),
            "global_batch": B,
            "seq_len": S,
        },
    )


def _gnn_plan(spec: ArchSpec, cell: Cell, mesh: Mesh, opt_cfg: AdamWConfig) -> CellPlan:
    from repro.archs import gnn as G

    cfg = spec.config_for(cell.name)
    aparams = G.abstract_gnn_params(cfg)
    batch = batch_specs(spec, cell.name)
    state = abstract_train_state(aparams)
    st_sh = train_state_shardings(state, "gnn", mesh)
    loss_fn = lambda p, b: G.gnn_loss(p, b, cfg)
    step = make_train_step(loss_fn, opt_cfg)
    n_nodes = batch["node_feats"].shape[0]
    n_edges = batch["edge_src"].shape[0]
    return CellPlan(
        arch_id=spec.arch_id,
        shape_name=cell.name,
        kind="train",
        fn=step,
        args=(state, batch),
        in_shardings=(st_sh, _batch_shardings(batch, mesh, fully=True)),
        model_flops=G.train_step_model_flops(cfg, n_nodes, n_edges),
        static_meta={"n_params": cfg.n_params(), "n_nodes": n_nodes, "n_edges": n_edges},
    )


def _recsys_plan(spec: ArchSpec, cell: Cell, mesh: Mesh, opt_cfg: AdamWConfig) -> CellPlan:
    from repro.archs import recsys as R

    cfg = spec.config_for(cell.name)
    aparams = R.abstract_params(cfg)
    p_sh = param_shardings(aparams, "recsys", mesh)
    batch = batch_specs(spec, cell.name)
    B = cell.dims["batch"]

    if cell.kind == "train":
        state = abstract_train_state(aparams)
        st_sh = train_state_shardings(state, "recsys", mesh)
        loss_fn = lambda p, b: R.loss(p, b, cfg)
        step = make_train_step(loss_fn, opt_cfg)
        args = (state, batch)
        in_sh = (st_sh, _batch_shardings(batch, mesh))
        flops = R.train_step_model_flops(cfg, B)
    elif cell.kind == "serve":
        step = lambda p, b: R.forward(p, b, cfg)
        args = (aparams, batch)
        in_sh = (p_sh, _batch_shardings(batch, mesh))
        flops = R.train_step_model_flops(cfg, B) / 3.0
    elif cell.kind == "retrieval":
        n_cand = cell.dims["n_candidates"]
        step = lambda p, b: R.retrieve_topk(p, b, cfg, k=100, num_tiles=64)
        args = (aparams, batch)
        # user-side features replicated (batch=1); candidates over all axes
        cand_sh = {
            k: (_maybe_batch_sharding(mesh, v, fully=True) if k == "candidates" else NamedSharding(mesh, P()))
            for k, v in batch.items()
        }
        in_sh = (p_sh, cand_sh)
        flops = R.train_step_model_flops(cfg, n_cand) / 3.0
    else:
        raise ValueError(cell.kind)
    return CellPlan(
        arch_id=spec.arch_id,
        shape_name=cell.name,
        kind=cell.kind,
        fn=step,
        args=args,
        in_shardings=in_sh,
        model_flops=flops,
        static_meta={"n_params": cfg.n_params(), "batch": B},
    )


def build_cell_plan(
    spec: ArchSpec, shape_name: str, mesh: Mesh, opt_cfg: Optional[AdamWConfig] = None
) -> CellPlan:
    cell = spec.cells[shape_name]
    if cell.skip is not None:
        raise ValueError(f"cell {spec.arch_id}/{shape_name} is skipped: {cell.skip}")
    opt_cfg = opt_cfg or AdamWConfig()
    builder = {"lm": _lm_plan, "gnn": _gnn_plan, "recsys": _recsys_plan}[spec.family]
    return builder(spec, cell, mesh, opt_cfg)
