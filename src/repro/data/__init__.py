"""Data substrate: synthetic vocabulary-mismatch corpus + batch pipelines."""
from repro.data.synthetic import Corpus, CorpusConfig, generate_corpus  # noqa: F401
