"""Graph data substrate: synthetic graphs + a real fanout neighbor sampler.

``minibatch_lg`` requires genuine GraphSAGE-style neighbor sampling: seed
nodes -> sample ``fanout[0]`` in-neighbors -> ``fanout[1]`` of theirs, build
the induced bipartite subgraph with *local* node ids, pad to static shapes.
The sampler is host-side numpy over a CSR adjacency (the standard
input-pipeline placement: sampling is data prep, message passing is device
work).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """In-neighbor CSR: predecessors of node v are col[ptr[v]:ptr[v+1]]."""

    ptr: np.ndarray  # i64[N+1]
    col: np.ndarray  # i32[E]
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return int(self.col.shape[0])


def edges_to_csr(edge_src: np.ndarray, edge_dst: np.ndarray, n_nodes: int) -> CSRGraph:
    order = np.argsort(edge_dst, kind="stable")
    src, dst = edge_src[order].astype(np.int32), edge_dst[order]
    ptr = np.zeros(n_nodes + 1, dtype=np.int64)
    counts = np.bincount(dst, minlength=n_nodes)
    ptr[1:] = np.cumsum(counts)
    return CSRGraph(ptr=ptr, col=src, n_nodes=n_nodes)


def random_power_law_graph(n_nodes: int, n_edges: int, seed: int = 0, alpha: float = 1.3):
    """Synthetic scale-free-ish graph (host side)."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.power(np.arange(1, n_nodes + 1, dtype=np.float64), alpha)
    p /= p.sum()
    src = rng.choice(n_nodes, size=n_edges, p=p).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    return src, dst


@dataclasses.dataclass(frozen=True)
class SampledSubgraph:
    """Static-shape padded subgraph (device-ready)."""

    node_ids: np.ndarray  # i32[N_pad] global ids (padding = 0)
    node_mask: np.ndarray  # bool[N_pad]
    edge_src: np.ndarray  # i32[E_pad] local ids
    edge_dst: np.ndarray  # i32[E_pad] local ids
    edge_mask: np.ndarray  # bool[E_pad]
    n_seeds: int  # seeds occupy local ids [0, n_seeds)


def sample_neighbors(
    g: CSRGraph,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    *,
    rng: np.random.Generator,
    pad_nodes: int,
    pad_edges: int,
) -> SampledSubgraph:
    """Multi-hop fanout sampling with replacement-free per-node draws."""
    frontier = np.asarray(seeds, dtype=np.int32)
    # local id assignment: seeds first (stable order for the loss)
    local: dict[int, int] = {int(v): i for i, v in enumerate(frontier)}
    nodes: list[int] = list(map(int, frontier))
    e_src: list[int] = []
    e_dst: list[int] = []
    for fanout in fanouts:
        next_frontier: list[int] = []
        for v in frontier:
            lo, hi = g.ptr[v], g.ptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(fanout, int(deg))
            picks = rng.choice(deg, size=take, replace=False) + lo
            for u in g.col[picks]:
                u = int(u)
                if u not in local:
                    local[u] = len(nodes)
                    nodes.append(u)
                    next_frontier.append(u)
                e_src.append(local[u])
                e_dst.append(local[int(v)])
        frontier = np.asarray(next_frontier, dtype=np.int32)
        if frontier.size == 0:
            break
    n, e = len(nodes), len(e_src)
    if n > pad_nodes or e > pad_edges:
        raise ValueError(f"sample exceeds padding: nodes {n}>{pad_nodes} or edges {e}>{pad_edges}")
    node_ids = np.zeros(pad_nodes, dtype=np.int32)
    node_ids[:n] = nodes
    node_mask = np.zeros(pad_nodes, dtype=bool)
    node_mask[:n] = True
    es = np.zeros(pad_edges, dtype=np.int32)
    ed = np.zeros(pad_edges, dtype=np.int32)
    es[:e] = e_src
    ed[:e] = e_dst
    em = np.zeros(pad_edges, dtype=bool)
    em[:e] = True
    return SampledSubgraph(node_ids, node_mask, es, ed, em, n_seeds=len(seeds))


def sampling_budget(batch_nodes: int, fanouts: Sequence[int]) -> tuple[int, int]:
    """Static (pad_nodes, pad_edges) bounds for a fanout schedule."""
    nodes = batch_nodes
    frontier = batch_nodes
    edges = 0
    for f in fanouts:
        new = frontier * f
        edges += new
        nodes += new
        frontier = new
    return nodes, edges


def block_diagonal_batch(
    n_graphs: int, nodes_per_graph: int, edges_per_graph: int, d_feat: int, seed: int = 0
):
    """Batch many small graphs as one block-diagonal graph (molecule shape)."""
    rng = np.random.default_rng(seed)
    N = n_graphs * nodes_per_graph
    E = n_graphs * edges_per_graph
    offs = np.repeat(np.arange(n_graphs) * nodes_per_graph, edges_per_graph)
    src = rng.integers(0, nodes_per_graph, E).astype(np.int32) + offs
    dst = rng.integers(0, nodes_per_graph, E).astype(np.int32) + offs
    graph_ids = np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per_graph)
    feats = rng.normal(size=(N, d_feat)).astype(np.float32)
    return feats, src.astype(np.int32), dst.astype(np.int32), graph_ids
