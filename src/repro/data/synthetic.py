"""Concept-latent synthetic corpus with built-in vocabulary mismatch.

The paper evaluates on MS MARCO passages with five pre-encoded model
treatments. We have no network access, so (DESIGN.md §7.3) we *generate* a
corpus whose retrieval difficulty has the same mechanism that makes learned
sparse models win on MS MARCO: **vocabulary mismatch**.

Generative story:
  * ``n_concepts`` latent concepts; concept popularity ~ Zipf.
  * each concept owns ``terms_per_concept`` surface terms (synonyms / related
    phrasings), with an internal Zipf distribution over which surface term a
    writer picks.
  * a shared stopword vocabulary is mixed into every document and query.
  * a document samples a few concepts, then surface terms *per concept*; a
    query is authored about a focus document's concepts but re-samples the
    surface terms independently — so query and relevant document frequently
    use *different* surface forms of the same concept. Plain BM25 cannot
    bridge that gap; expansion models (doc2query/TILDE/SPLADE treatments in
    ``repro.models``) bridge it by construction, which is precisely how they
    earn their Table-1 effectiveness edge here, mechanistically rather than
    by fiat.

Qrels are MS MARCO style: one relevant (focus) document per query, evaluated
with RR@10.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    n_docs: int = 20000
    n_queries: int = 200
    n_concepts: int = 2000
    terms_per_concept: int = 24
    n_stopwords: int = 64
    concepts_per_doc: float = 6.0  # Poisson mean (>=1 enforced)
    terms_per_doc_concept: float = 4.0  # surface terms drawn per (doc, concept)
    stopwords_per_doc: float = 6.0
    concepts_per_query: float = 2.0
    terms_per_query_concept: float = 1.3
    stopwords_per_query: float = 0.8
    concept_zipf: float = 1.1  # popularity skew across concepts
    term_zipf: float = 1.2  # skew across surface forms within a concept
    max_tf: int = 8
    seed: int = 0

    @property
    def n_surface_terms(self) -> int:
        return self.n_stopwords + self.n_concepts * self.terms_per_concept


@dataclasses.dataclass(frozen=True)
class Corpus:
    """Base (pre-treatment) corpus: docs/queries over the surface vocabulary."""

    config: CorpusConfig
    # documents, CSR over a ragged (term, tf) representation
    doc_offsets: np.ndarray  # i64[n_docs + 1]
    doc_terms: np.ndarray  # i32[nnz] surface term ids
    doc_tfs: np.ndarray  # i32[nnz]
    doc_concepts: list  # list of i32 arrays (latent, used by expansion models)
    doc_concept_strengths: list  # list of f32 arrays: how central each concept is
    # queries (ragged)
    query_terms: list  # list of i32 arrays
    query_concepts: list  # list of i32 arrays (latent)
    qrels: np.ndarray  # i32[n_queries] focus (relevant) doc per query

    @property
    def n_docs(self) -> int:
        return len(self.doc_offsets) - 1

    @property
    def n_queries(self) -> int:
        return len(self.query_terms)

    def doc(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        lo, hi = self.doc_offsets[i], self.doc_offsets[i + 1]
        return self.doc_terms[lo:hi], self.doc_tfs[lo:hi]

    def coo(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(doc_idx, term_idx, tf) postings."""
        doc_idx = np.repeat(
            np.arange(self.n_docs, dtype=np.int64), np.diff(self.doc_offsets)
        )
        return doc_idx, self.doc_terms.astype(np.int64), self.doc_tfs.astype(np.float64)


def _zipf_probs(n: int, alpha: float) -> np.ndarray:
    p = 1.0 / np.power(np.arange(1, n + 1, dtype=np.float64), alpha)
    return p / p.sum()


def _sample_counts(rng, mean: float, n: int, minimum: int = 0) -> np.ndarray:
    return np.maximum(rng.poisson(mean, n), minimum)


def generate_corpus(cfg: CorpusConfig) -> Corpus:
    """Generate the base corpus (host-side numpy; offline data prep)."""
    rng = np.random.default_rng(cfg.seed)
    concept_p = _zipf_probs(cfg.n_concepts, cfg.concept_zipf)
    term_p = _zipf_probs(cfg.terms_per_concept, cfg.term_zipf)

    def concept_term(concepts: np.ndarray, forms: np.ndarray) -> np.ndarray:
        return cfg.n_stopwords + concepts * cfg.terms_per_concept + forms

    # ---------------- documents ----------------
    n_con = _sample_counts(rng, cfg.concepts_per_doc, cfg.n_docs, minimum=1)
    doc_concepts: list[np.ndarray] = []
    doc_strengths: list[np.ndarray] = []
    all_terms: list[np.ndarray] = []
    all_tfs: list[np.ndarray] = []
    lengths = np.zeros(cfg.n_docs, dtype=np.int64)
    # vectorized-ish: loop over docs but with array ops inside (host data prep)
    for i in range(cfg.n_docs):
        cs = rng.choice(cfg.n_concepts, size=n_con[i], replace=False, p=concept_p)
        doc_concepts.append(cs.astype(np.int32))
        # concept centrality: a doc is "about" its first concepts (geometric
        # decay); central concepts get more surface terms and higher tfs, and
        # queries about this doc target its central concepts — the relevance
        # signal learned weights can exploit but BM25 only sees through tf.
        strength = 0.6 ** np.arange(n_con[i], dtype=np.float64)
        strength = strength / strength.max()
        doc_strengths.append(strength.astype(np.float32))
        k = np.maximum(rng.poisson(cfg.terms_per_doc_concept * strength), 1)
        reps = np.repeat(cs, k)
        forms = rng.choice(cfg.terms_per_concept, size=reps.size, p=term_p)
        terms = concept_term(reps, forms)
        rep_strength = np.repeat(strength, k)
        n_stop = max(int(rng.poisson(cfg.stopwords_per_doc)), 0)
        stops = rng.integers(0, cfg.n_stopwords, n_stop)
        terms = np.concatenate([terms, stops])
        # heavy-tailed tf (centrality-boosted): BM25's within-term weight
        # variance (and hence block-max skipping headroom) comes from here
        str_all = np.concatenate([rep_strength, np.full(n_stop, 1.0)])
        tfs = 1 + np.floor(rng.exponential(0.9 + 2.0 * str_all)).astype(np.int64)
        tfs = tfs.clip(1, cfg.max_tf)
        # merge duplicate surface terms
        ut, inv = np.unique(terms, return_inverse=True)
        tf = np.zeros(ut.size, dtype=np.int64)
        np.add.at(tf, inv, tfs)
        all_terms.append(ut.astype(np.int32))
        all_tfs.append(tf.clip(1, cfg.max_tf * 4).astype(np.int32))
        lengths[i] = ut.size
    doc_offsets = np.zeros(cfg.n_docs + 1, dtype=np.int64)
    doc_offsets[1:] = np.cumsum(lengths)
    doc_terms = np.concatenate(all_terms)
    doc_tfs = np.concatenate(all_tfs)

    # ---------------- queries ----------------
    query_terms: list[np.ndarray] = []
    query_concepts: list[np.ndarray] = []
    qrels = np.zeros(cfg.n_queries, dtype=np.int32)
    for qi in range(cfg.n_queries):
        d = int(rng.integers(0, cfg.n_docs))
        qrels[qi] = d
        m = min(max(int(rng.poisson(cfg.concepts_per_query)), 1), doc_concepts[d].size)
        # queries target the doc's central concepts
        p = doc_strengths[d].astype(np.float64) ** 2
        p = p / p.sum()
        cs = rng.choice(doc_concepts[d], size=m, replace=False, p=p)
        query_concepts.append(cs.astype(np.int32))
        k = _sample_counts(rng, cfg.terms_per_query_concept, m, minimum=1)
        reps = np.repeat(cs, k)
        # independent surface-form resampling => vocabulary mismatch
        forms = rng.choice(cfg.terms_per_concept, size=reps.size, p=term_p)
        terms = concept_term(reps, forms)
        n_stop = max(int(rng.poisson(cfg.stopwords_per_query)), 0)
        stops = rng.integers(0, cfg.n_stopwords, n_stop)
        terms = np.unique(np.concatenate([terms, stops]))
        query_terms.append(terms.astype(np.int32))

    return Corpus(
        config=cfg,
        doc_offsets=doc_offsets,
        doc_terms=doc_terms,
        doc_tfs=doc_tfs,
        doc_concepts=doc_concepts,
        doc_concept_strengths=doc_strengths,
        query_terms=query_terms,
        query_concepts=query_concepts,
        qrels=qrels,
    )


def mismatch_rate(corpus: Corpus) -> float:
    """Fraction of queries with no raw surface-term overlap with their
    relevant document — the quantity expansion models exist to fix."""
    cfg = corpus.config
    miss = 0
    for qi in range(corpus.n_queries):
        d = corpus.qrels[qi]
        dt, _ = corpus.doc(d)
        q = corpus.query_terms[qi]
        content = q[q >= cfg.n_stopwords]
        if content.size and not np.intersect1d(content, dt).size:
            miss += 1
    return miss / max(corpus.n_queries, 1)
