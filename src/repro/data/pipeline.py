"""Sharded batch pipeline for training (LM / sparse encoder / recsys / GNN).

Host-side numpy generators -> device_put with the mesh's batch shardings.
Synthetic but *mechanistic* data (see repro.data.synthetic): the sparse
encoder's triples come from the concept-latent corpus so ranking quality is
learned, not scripted. All batch shapes are static; iterators are infinite.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import Corpus


def lm_token_batches(
    vocab: int, batch: int, seq: int, seed: int = 0
) -> Iterator[dict]:
    """Zipf-distributed synthetic token stream with next-token labels."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, vocab + 1, dtype=np.float64) ** 1.1
    p /= p.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq + 1), p=p).astype(np.int32)
        yield {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}


@dataclasses.dataclass
class TripleSampler:
    """(query, positive doc, negative doc) triples from the synthetic corpus.

    Tokens are surface term ids (the corpus vocabulary IS the token space —
    no subword stage for the trainable-encoder path). Padded/masked to
    static lengths.
    """

    corpus: Corpus
    q_len: int = 16
    d_len: int = 64
    seed: int = 0

    def _pad(self, terms: np.ndarray, n: int) -> tuple[np.ndarray, np.ndarray]:
        out = np.zeros(n, dtype=np.int32)
        mask = np.zeros(n, dtype=bool)
        t = terms[:n]
        out[: t.size] = t
        mask[: t.size] = True
        return out, mask

    def batches(self, batch: int) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        nq = self.corpus.n_queries
        while True:
            rows = {k: [] for k in ("query", "query_mask", "pos", "pos_mask", "neg", "neg_mask")}
            for _ in range(batch):
                qi = int(rng.integers(0, nq))
                d_pos = int(self.corpus.qrels[qi])
                d_neg = int(rng.integers(0, self.corpus.n_docs))
                while d_neg == d_pos:
                    d_neg = int(rng.integers(0, self.corpus.n_docs))
                q, qm = self._pad(self.corpus.query_terms[qi], self.q_len)
                dp, dpm = self._pad(self.corpus.doc(d_pos)[0], self.d_len)
                dn, dnm = self._pad(self.corpus.doc(d_neg)[0], self.d_len)
                for k, v in zip(rows, (q, qm, dp, dpm, dn, dnm)):
                    rows[k].append(v)
            yield {k: jnp.asarray(np.stack(v)) for k, v in rows.items()}

    def doc_token_batches(self, batch: int) -> Iterator[tuple]:
        """All corpus docs in order (for corpus encoding), padded batches."""
        n = self.corpus.n_docs
        for lo in range(0, n, batch):
            hi = min(lo + batch, n)
            toks = np.zeros((batch, self.d_len), dtype=np.int32)
            mask = np.zeros((batch, self.d_len), dtype=bool)
            for i, d in enumerate(range(lo, hi)):
                t, m = self._pad(self.corpus.doc(d)[0], self.d_len)
                toks[i], mask[i] = t, m
            yield jnp.asarray(toks), jnp.asarray(mask), hi - lo


def recsys_batches(cfg, batch: int, seed: int = 0) -> Iterator[dict]:
    """Synthetic recsys batches with a learnable preference signal."""
    rng = np.random.default_rng(seed)
    total = cfg.table.total_rows
    while True:
        if cfg.kind == "dcn-v2":
            dense = rng.normal(size=(batch, cfg.n_dense)).astype(np.float32)
            sparse = rng.integers(0, 1 << 30, (batch, cfg.table.n_slots)).astype(np.int32)
            y = (dense[:, 0] + (sparse[:, 0] % 7 == 0) > 0.5).astype(np.float32)
            b = {"dense": dense, "sparse": sparse, "label": y}
        elif cfg.kind == "din":
            hist = rng.integers(0, 1 << 30, (batch, cfg.seq_len)).astype(np.int32)
            mask = rng.random((batch, cfg.seq_len)) > 0.2
            tgt = np.where(
                rng.random(batch) < 0.5, hist[:, 0], rng.integers(0, 1 << 30, batch)
            ).astype(np.int32)
            y = (tgt == hist[:, 0]).astype(np.float32)
            b = {"hist": hist, "hist_mask": mask, "target": tgt, "label": y}
        elif cfg.kind == "sasrec":
            seq = rng.integers(0, 1 << 30, (batch, cfg.seq_len)).astype(np.int32)
            pos = np.roll(seq, -1, axis=1)
            neg = rng.integers(0, 1 << 30, (batch, cfg.seq_len)).astype(np.int32)
            b = {
                "seq": seq,
                "pos": pos,
                "neg": neg,
                "mask": np.ones((batch, cfg.seq_len), dtype=bool),
            }
        elif cfg.kind == "wide-deep":
            sparse = rng.integers(0, 1 << 30, (batch, cfg.table.n_slots)).astype(np.int32)
            y = ((sparse[:, 0] % 5 == 0) | (sparse[:, 1] % 3 == 0)).astype(np.float32)
            b = {"sparse": sparse, "label": y}
        else:
            raise ValueError(cfg.kind)
        yield {k: jnp.asarray(v) for k, v in b.items()}


def gnn_batches(cfg, n_nodes: int, n_edges: int, seed: int = 0, graph_readout_graphs: int = 0):
    """Synthetic graph batches (fixed topology, fresh features per step)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    w_true = rng.normal(size=(cfg.d_feat, cfg.n_vars)).astype(np.float32) * 0.3
    while True:
        feats = rng.normal(size=(n_nodes, cfg.d_feat)).astype(np.float32)
        node_targets = feats @ w_true + 0.05 * rng.normal(size=(n_nodes, cfg.n_vars)).astype(np.float32)
        b = {
            "node_feats": jnp.asarray(feats),
            "edge_src": jnp.asarray(src),
            "edge_dst": jnp.asarray(dst),
            "edge_feats": jnp.asarray(rng.normal(size=(n_edges, cfg.d_edge_feat)).astype(np.float32)),
        }
        if graph_readout_graphs:
            gid = np.sort(rng.integers(0, graph_readout_graphs, n_nodes)).astype(np.int32)
            b["graph_ids"] = jnp.asarray(gid)
            b["targets"] = jnp.asarray(
                rng.normal(size=(graph_readout_graphs, cfg.n_vars)).astype(np.float32)
            )
        else:
            b["targets"] = jnp.asarray(node_targets)
        yield b


def shard_batch(batch, mesh, shardings=None):
    """device_put a host batch with the mesh's batch shardings."""
    if shardings is None:
        from repro.distributed.sharding import batch_shardings

        shardings = batch_shardings(batch, mesh)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), batch, shardings)
