"""Latency distribution statistics (paper Figure 2: tail latency).

The paper's key systems observation is not about means alone: DAAT means can
beat SAAT while DAAT's p99/max explode on ill-behaved queries. We therefore
always report the full Tukey summary.

This module also owns the serving layer's *time source*: every component that
measures or schedules against wall time (``AnytimeServer``'s cost model, the
``AdmissionQueue``'s deadline-driven flush policy) reads an injectable
:class:`Clock` instead of calling ``time.perf_counter`` directly. Production
uses :class:`SystemClock`; tests drive a :class:`SimulatedClock` so
time-dependent policy (EMA calibration, flush-before-deadline) is exercised
deterministically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Protocol, runtime_checkable

import numpy as np


# --------------------------------------------------------------------------
# clocks
# --------------------------------------------------------------------------


@runtime_checkable
class Clock(Protocol):
    """Monotonic time source, in seconds. The serving layer's only clock."""

    def now(self) -> float:  # pragma: no cover - protocol
        ...


class SystemClock:
    """Wall clock: ``time.perf_counter`` (monotonic, high resolution)."""

    def now(self) -> float:
        return time.perf_counter()


class SimulatedClock:
    """Deterministic clock for tests: time moves only via ``advance``.

    ``advance_to`` never moves time backwards, so a driver can safely jump to
    ``max(next_arrival, queue.next_due())`` event times in any order.
    """

    def __init__(self, start_s: float = 0.0):
        self._t = float(start_s)

    def now(self) -> float:
        return self._t

    def advance(self, dt_s: float) -> float:
        if dt_s < 0:
            raise ValueError(f"cannot advance by negative dt {dt_s}")
        self._t += dt_s
        return self._t

    def advance_to(self, t_s: float) -> float:
        self._t = max(self._t, float(t_s))
        return self._t


class HybridClock(SimulatedClock):
    """Simulated schedule + real measured work: every ``now()`` also accrues
    the wall time elapsed since the previous call.

    Replay drivers jump between arrival/due events with ``advance_to`` (never
    backwards) exactly like :class:`SimulatedClock`, but any real computation
    between calls — a search, host-side padding — advances time by its
    measured duration. Cost-model calibration therefore sees real service
    times and deadline-policy accounting becomes falsifiable, while the
    arrival schedule stays scripted. Under overload, time outruns the
    schedule and arrivals are admitted late (closed-loop load semantics) —
    use a pure :class:`SimulatedClock` when determinism matters more than
    realism.
    """

    def __init__(self, start_s: float = 0.0):
        super().__init__(start_s)
        self._last_real = time.perf_counter()

    def _accrue(self):
        r = time.perf_counter()
        self._t += r - self._last_real
        self._last_real = r

    def now(self) -> float:
        self._accrue()
        return self._t

    def advance(self, dt_s: float) -> float:
        self._accrue()
        return super().advance(dt_s)

    def advance_to(self, t_s: float) -> float:
        self._accrue()
        return super().advance_to(t_s)


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    mean_ms: float
    p50_ms: float
    p75_ms: float
    p95_ms: float
    p99_ms: float
    max_ms: float
    std_ms: float
    n: int

    def row(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def tail_ratio(self) -> float:
        """p99 / p50 — the predictability figure of merit."""
        return self.p99_ms / max(self.p50_ms, 1e-9)


def summarize_latencies(latencies_ms) -> LatencyStats:
    x = np.asarray(list(latencies_ms), dtype=np.float64)
    if x.size == 0:
        return LatencyStats(0, 0, 0, 0, 0, 0, 0, 0)
    return LatencyStats(
        mean_ms=float(x.mean()),
        p50_ms=float(np.percentile(x, 50)),
        p75_ms=float(np.percentile(x, 75)),
        p95_ms=float(np.percentile(x, 95)),
        p99_ms=float(np.percentile(x, 99)),
        max_ms=float(x.max()),
        std_ms=float(x.std()),
        n=int(x.size),
    )
