"""Latency distribution statistics (paper Figure 2: tail latency).

The paper's key systems observation is not about means alone: DAAT means can
beat SAAT while DAAT's p99/max explode on ill-behaved queries. We therefore
always report the full Tukey summary.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    mean_ms: float
    p50_ms: float
    p75_ms: float
    p95_ms: float
    p99_ms: float
    max_ms: float
    std_ms: float
    n: int

    def row(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def tail_ratio(self) -> float:
        """p99 / p50 — the predictability figure of merit."""
        return self.p99_ms / max(self.p50_ms, 1e-9)


def summarize_latencies(latencies_ms) -> LatencyStats:
    x = np.asarray(list(latencies_ms), dtype=np.float64)
    if x.size == 0:
        return LatencyStats(0, 0, 0, 0, 0, 0, 0, 0)
    return LatencyStats(
        mean_ms=float(x.mean()),
        p50_ms=float(np.percentile(x, 50)),
        p75_ms=float(np.percentile(x, 75)),
        p95_ms=float(np.percentile(x, 95)),
        p99_ms=float(np.percentile(x, 99)),
        max_ms=float(x.max()),
        std_ms=float(x.std()),
        n=int(x.size),
    )
