from repro.metrics.ir_metrics import mrr_at_k, recall_at_k  # noqa: F401
from repro.metrics.latency import LatencyStats, summarize_latencies  # noqa: F401
