"""IR effectiveness metrics: RR@10 (the paper's official metric) + recall."""
from __future__ import annotations

import numpy as np


def mrr_at_k(ranked_doc_ids: np.ndarray, qrels: np.ndarray, k: int = 10) -> float:
    """Mean reciprocal rank at cutoff k.

    Args:
      ranked_doc_ids: [n_queries, >=k] doc ids in decreasing score order.
      qrels: [n_queries] the single relevant doc per query (MS MARCO style).
    """
    ranked = np.asarray(ranked_doc_ids)[:, :k]
    rel = np.asarray(qrels).reshape(-1, 1)
    hits = ranked == rel
    ranks = np.argmax(hits, axis=1) + 1
    rr = np.where(hits.any(axis=1), 1.0 / ranks, 0.0)
    return float(rr.mean())


def recall_at_k(ranked_doc_ids: np.ndarray, qrels: np.ndarray, k: int = 1000) -> float:
    ranked = np.asarray(ranked_doc_ids)[:, :k]
    rel = np.asarray(qrels).reshape(-1, 1)
    return float((ranked == rel).any(axis=1).mean())


def rank_overlap(ids_a: np.ndarray, ids_b: np.ndarray, k: int) -> float:
    """Mean top-k set overlap between two systems (rank-safety diagnostics)."""
    a = np.asarray(ids_a)[:, :k]
    b = np.asarray(ids_b)[:, :k]
    out = []
    for i in range(a.shape[0]):
        out.append(len(np.intersect1d(a[i], b[i])) / k)
    return float(np.mean(out))
