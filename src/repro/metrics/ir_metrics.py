"""IR effectiveness metrics and the rho-degradation effectiveness harness.

Point metrics: RR@10 (the paper's official metric), Recall@k, NDCG@k, and
top-k overlap. On top of them this module quantifies the paper's serving
trade — *what does each rho level cost in effectiveness?* — two ways:

  * :func:`rho_effectiveness_sweep` serves a labeled query set directly at
    every ladder level and reports per-rho Recall@k/MRR/NDCG plus relative
    loss against the exhaustive (max-rho) level;
  * :func:`replay_effectiveness` / :func:`effectiveness_surface` push the
    same labeled set through a continuous-batching
    :class:`~repro.serving.queue.AdmissionQueue` *under load*, so the rho
    each query was actually served at is decided by the deadline-driven
    flush policy (``degrade_rho``), and effectiveness is accounted per
    served level — the effectiveness-vs-rho-vs-deadline surface behind the
    paper's "≤3% loss buys large mean/tail gains" claim.

Qrels replay format
-------------------
A labeled replay is four parallel sequences, one entry per request ``i``
(request ``i`` gets rid ``i``, so completions re-align by rid):

  * ``arrivals_s[i]``   — arrival instant (seconds, clock domain), ascending;
  * ``q_terms_list[i]`` / ``q_weights_list[i]`` — the ragged query (int term
    ids / float weights, trailing padding allowed);
  * ``qrels[i]``        — the single relevant doc id (MS MARCO style). The
    point metrics also accept ``[n_queries, R]`` graded qrels with ``-1``
    padding (see :func:`ndcg_at_k`), but the replay harness keys its
    per-rho grouping on the 1-D form.

Queries are replayed on the queue's injectable clock: a
:class:`~repro.metrics.latency.SimulatedClock` makes the whole surface a
deterministic function of the schedule (CI), a
:class:`~repro.metrics.latency.HybridClock` keeps the scripted arrivals but
accrues real measured service time (load rehearsal).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np


def mrr_at_k(ranked_doc_ids: np.ndarray, qrels: np.ndarray, k: int = 10) -> float:
    """Mean reciprocal rank at cutoff k.

    Args:
      ranked_doc_ids: [n_queries, >=k] doc ids in decreasing score order.
      qrels: [n_queries] the single relevant doc per query (MS MARCO style).
    """
    ranked = np.asarray(ranked_doc_ids)[:, :k]
    if ranked.shape[0] == 0:
        return 0.0  # empty query set: defined as 0, not a nan mean
    rel = np.asarray(qrels).reshape(-1, 1)
    hits = ranked == rel
    ranks = np.argmax(hits, axis=1) + 1
    rr = np.where(hits.any(axis=1), 1.0 / ranks, 0.0)
    return float(rr.mean())


def recall_at_k(ranked_doc_ids: np.ndarray, qrels: np.ndarray, k: int = 1000) -> float:
    ranked = np.asarray(ranked_doc_ids)[:, :k]
    if ranked.shape[0] == 0:
        return 0.0
    rel = np.asarray(qrels).reshape(-1, 1)
    return float((ranked == rel).any(axis=1).mean())


def ndcg_at_k(
    ranked_doc_ids: np.ndarray,
    qrel_ids: np.ndarray,
    k: int = 10,
    qrel_gains: np.ndarray | None = None,
) -> float:
    """Mean NDCG at cutoff k with graded relevance.

    Args:
      ranked_doc_ids: ``[n_queries, >=k]`` doc ids in decreasing score order.
        A cutoff larger than the ranking just uses the whole ranking.
      qrel_ids: ``[n_queries, R]`` relevant doc ids per query, ``-1`` padding
        for queries with fewer than R judged docs. A 1-D array is treated as
        one relevant doc per query (MS MARCO style).
      qrel_gains: optional ``[n_queries, R]`` graded gains aligned with
        ``qrel_ids``; omitted = binary relevance (gain 1 per judged doc).
        Pad slots are ignored regardless of their gain value.

    Uses the standard ``gain / log2(rank + 1)`` discount; the ideal DCG sorts
    each query's (unpadded) gains descending and truncates at k. Queries with
    no judged docs contribute 0 (the sklearn/trec_eval convention), so adding
    unjudged queries can only lower the mean — never inflate it.
    """
    ranked = np.asarray(ranked_doc_ids)[:, :k]
    if ranked.shape[0] == 0:
        return 0.0
    rels = np.asarray(qrel_ids)
    if rels.ndim == 1:
        rels = rels.reshape(-1, 1)
    if qrel_gains is None:
        gains = np.ones(rels.shape, np.float64)
    else:
        gains = np.asarray(qrel_gains, np.float64)
        if gains.shape != rels.shape:
            raise ValueError(
                f"qrel_gains shape {gains.shape} != qrel_ids shape {rels.shape}"
            )
    live = rels >= 0
    gains = np.where(live, gains, 0.0)
    # gain of each ranked slot: matched judged doc's gain, else 0. Judged ids
    # are unique per query, so the sum over R picks at most one gain per slot.
    slot_gain = np.einsum(
        "qkr,qr->qk", (ranked[:, :, None] == rels[:, None, :]) & live[:, None, :], gains
    )
    discount = 1.0 / np.log2(np.arange(ranked.shape[1]) + 2.0)
    dcg = slot_gain @ discount
    ideal = -np.sort(-gains, axis=1)[:, : ranked.shape[1]]
    idcg = ideal @ discount[: ideal.shape[1]]
    return float(np.where(idcg > 0, dcg / np.maximum(idcg, 1e-12), 0.0).mean())


def rank_overlap(ids_a: np.ndarray, ids_b: np.ndarray, k: int) -> float:
    """Mean top-k set overlap between two systems (rank-safety diagnostics)."""
    a = np.asarray(ids_a)[:, :k]
    b = np.asarray(ids_b)[:, :k]
    out = []
    for i in range(a.shape[0]):
        out.append(len(np.intersect1d(a[i], b[i])) / k)
    return float(np.mean(out))


# --------------------------------------------------------------------------
# the rho-degradation effectiveness harness
# --------------------------------------------------------------------------


def effectiveness_report(
    ranked_doc_ids: np.ndarray,
    qrels: np.ndarray,
    *,
    recall_k: int = 100,
    mrr_k: int = 10,
    ndcg_k: int = 10,
) -> dict:
    """The harness's standard metric triple for one ranking set."""
    return {
        "mrr": mrr_at_k(ranked_doc_ids, qrels, mrr_k),
        "recall": recall_at_k(ranked_doc_ids, qrels, recall_k),
        "ndcg": ndcg_at_k(ranked_doc_ids, qrels, ndcg_k),
        "mrr_k": mrr_k,
        "recall_k": recall_k,
        "ndcg_k": ndcg_k,
    }


def _relative_loss(value: float, exact: float) -> float:
    """Fractional effectiveness lost vs the exhaustive level (floored at 0:
    a budget that happens to beat exhaustive on a small label set is not a
    negative loss the 3%-tolerance selector should reward)."""
    if exact <= 0.0:
        return 0.0
    return max(0.0, (exact - value) / exact)


def _serve_ids_at_rho(server, q_terms, q_weights, rho, batch_size):
    import jax.numpy as jnp  # lazy: keep the metrics module numpy-cheap

    N = q_terms.shape[0]
    out = []
    for lo in range(0, N, batch_size):
        hi = min(lo + batch_size, N)
        bt, bw = q_terms[lo:hi], q_weights[lo:hi]
        if hi - lo < batch_size:  # pad final batch (served, then dropped)
            pad = batch_size - (hi - lo)
            bt = np.concatenate([bt, np.repeat(bt[-1:], pad, 0)])
            bw = np.concatenate([bw, np.repeat(bw[-1:], pad, 0)])
        res = server.search_batch(jnp.asarray(bt), jnp.asarray(bw), rho=rho)
        out.append(np.asarray(res.doc_ids)[: hi - lo])
    return np.concatenate(out)


def rho_effectiveness_sweep(
    server,
    q_terms: np.ndarray,  # [N, Lq]
    q_weights: np.ndarray,
    qrels: np.ndarray,  # [N] single relevant doc per query
    *,
    recall_k: int = 100,
    mrr_k: int = 10,
    ndcg_k: int = 10,
    batch_size: Optional[int] = None,
) -> list:
    """Serve a labeled set at EVERY ladder level; one row per rho.

    Each row carries the metric triple plus ``loss_mrr/loss_recall/loss_ndcg``
    — relative loss against the exhaustive level (the ladder top, which the
    server caps at the index's own posting count). This is the direct
    (no-queue) arm of the harness: what each budget costs in effectiveness,
    independent of load.
    """
    qt = np.asarray(q_terms)
    qw = np.asarray(q_weights)
    rels = np.asarray(qrels)
    bs = int(batch_size) if batch_size is not None else int(server.cfg.batch_size)
    rows = []
    by_rho = {}
    for rho in server.rho_ladder:
        ids = _serve_ids_at_rho(server, qt, qw, rho, bs)
        by_rho[rho] = effectiveness_report(
            ids, rels, recall_k=recall_k, mrr_k=mrr_k, ndcg_k=ndcg_k
        )
    exact = by_rho[server.rho_ladder[-1]]
    for rho in server.rho_ladder:
        rep = by_rho[rho]
        rows.append(
            {
                "rho": int(rho),
                "exact": rho == server.rho_ladder[-1],
                **rep,
                "loss_mrr": _relative_loss(rep["mrr"], exact["mrr"]),
                "loss_recall": _relative_loss(rep["recall"], exact["recall"]),
                "loss_ndcg": _relative_loss(rep["ndcg"], exact["ndcg"]),
            }
        )
    return rows


def cheapest_rho_within_loss(
    sweep_rows: Sequence[dict], *, max_loss: float = 0.03, metric: str = "mrr"
) -> int:
    """Smallest ladder level within ``max_loss`` relative loss of exhaustive.

    This is "the largest tolerable degradation": the most aggressive posting
    budget the paper's ≤3%-effectiveness-loss tolerance admits (every level
    at or above it also qualifies — the sweep's losses are what make the
    claim auditable). When NO level is within tolerance (a ``max_loss``
    below the exhaustive level's own 0.0, or a partial sweep that lost its
    exact row) the answer is the exact budget itself — the level that
    *defines* zero loss — never ``None``: callers feed the result straight
    into a rho ladder, and "no tolerable degradation" means "don't degrade",
    not "crash the serving config".
    """
    rows = list(sweep_rows)
    if not rows:
        raise ValueError("cheapest_rho_within_loss needs a non-empty sweep")
    key = f"loss_{metric}"
    fits = [r for r in rows if r[key] <= max_loss]
    if fits:
        return int(min(fits, key=lambda r: r["rho"])["rho"])
    exact_rows = [r for r in rows if r.get("exact")] or rows
    return int(max(exact_rows, key=lambda r: r["rho"])["rho"])


def replay_effectiveness(
    queue,
    arrivals_s: Sequence[float],
    q_terms_list: Sequence[np.ndarray],
    q_weights_list: Sequence[np.ndarray],
    deadlines_ms: Sequence[float],
    qrels: np.ndarray,
    *,
    recall_k: int = 100,
    mrr_k: int = 10,
    ndcg_k: int = 10,
) -> dict:
    """Push a labeled arrival schedule through an AdmissionQueue and account
    effectiveness per rho level *actually served* (see the module docstring
    for the replay format).

    The flush policy — not the caller — decides each request's budget, so
    under overload with ``degrade_rho=True`` the report shows exactly what
    the SLO cost: which fraction of traffic was degraded, to which levels,
    and what each level scored on the labels. Returns one surface row::

        {"n_requests", "violations", "infeasible", "degraded_flushes",
         "wait_ms": {...percentiles...}, "overall": {metric triple},
         "by_rho": [{"rho", "n_queries", ...metric triple...}, ...]}
    """
    from repro.metrics.latency import summarize_latencies  # lazy: no cycle
    from repro.serving.queue import replay_arrivals

    rels = np.asarray(qrels)
    if rels.ndim != 1:
        raise ValueError(
            f"replay harness needs 1-D single-relevant qrels, got {rels.shape}"
        )
    if len(arrivals_s) != rels.shape[0]:
        raise ValueError(
            f"{len(arrivals_s)} arrivals vs {rels.shape[0]} qrels entries"
        )
    comps = replay_arrivals(queue, arrivals_s, q_terms_list, q_weights_list, deadlines_ms)
    comps = sorted(comps, key=lambda c: c.rid)
    if not comps:
        # an empty schedule served nothing at any rho: a well-formed all-zero
        # report, not an np.stack([]) crash deep in the accounting
        return {
            "n_requests": 0,
            "violations": queue.n_violations,
            "infeasible": queue.n_infeasible,
            "degraded_flushes": queue.n_degraded,
            "wait_ms": {k: round(v, 4) for k, v in summarize_latencies([]).row().items()},
            "overall": effectiveness_report(
                np.zeros((0, 1), np.int32), rels[:0],
                recall_k=recall_k, mrr_k=mrr_k, ndcg_k=ndcg_k,
            ),
            "by_rho": [],
        }
    ids = np.stack([c.doc_ids for c in comps])
    served_rho = [c.rho for c in comps]
    waits = summarize_latencies([c.wait_ms for c in comps])
    by_rho = []
    for rho in sorted({r for r in served_rho if r is not None}):
        pick = np.asarray([r == rho for r in served_rho])
        if not pick.any():
            continue  # a level nothing completed at contributes no row
        by_rho.append(
            {
                "rho": int(rho),
                "n_queries": int(pick.sum()),
                **effectiveness_report(
                    ids[pick], rels[pick], recall_k=recall_k, mrr_k=mrr_k, ndcg_k=ndcg_k
                ),
            }
        )
    return {
        "n_requests": len(comps),
        "violations": queue.n_violations,
        "infeasible": queue.n_infeasible,
        "degraded_flushes": queue.n_degraded,
        "wait_ms": {k: round(v, 4) for k, v in waits.row().items()},
        "overall": effectiveness_report(
            ids, rels, recall_k=recall_k, mrr_k=mrr_k, ndcg_k=ndcg_k
        ),
        "by_rho": by_rho,
    }


def effectiveness_surface(
    queue_factory: Callable[[float], object],
    deadlines_ms: Sequence[float],
    arrivals_s: Sequence[float],
    q_terms_list: Sequence[np.ndarray],
    q_weights_list: Sequence[np.ndarray],
    qrels: np.ndarray,
    **report_kw,
) -> list:
    """Effectiveness-vs-rho-vs-deadline surface: one replay per deadline.

    ``queue_factory(deadline_ms)`` must build a FRESH queue (and state) for
    each replay — reusing one queue would leak calibration and flush logs
    across deadline points. Each row is :func:`replay_effectiveness`'s dict
    plus the ``deadline_ms`` that produced it: tightening the deadline
    shifts traffic down the rho ladder, and the surface shows what that
    costs on the labels.
    """
    rows = []
    for d in deadlines_ms:
        queue = queue_factory(float(d))
        row = replay_effectiveness(
            queue,
            arrivals_s,
            q_terms_list,
            q_weights_list,
            [float(d)] * len(arrivals_s),
            qrels,
            **report_kw,
        )
        row["deadline_ms"] = float(d)
        rows.append(row)
    return rows
