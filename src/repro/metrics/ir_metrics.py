"""IR effectiveness metrics: RR@10 (the paper's official metric), recall, NDCG."""
from __future__ import annotations

import numpy as np


def mrr_at_k(ranked_doc_ids: np.ndarray, qrels: np.ndarray, k: int = 10) -> float:
    """Mean reciprocal rank at cutoff k.

    Args:
      ranked_doc_ids: [n_queries, >=k] doc ids in decreasing score order.
      qrels: [n_queries] the single relevant doc per query (MS MARCO style).
    """
    ranked = np.asarray(ranked_doc_ids)[:, :k]
    rel = np.asarray(qrels).reshape(-1, 1)
    hits = ranked == rel
    ranks = np.argmax(hits, axis=1) + 1
    rr = np.where(hits.any(axis=1), 1.0 / ranks, 0.0)
    return float(rr.mean())


def recall_at_k(ranked_doc_ids: np.ndarray, qrels: np.ndarray, k: int = 1000) -> float:
    ranked = np.asarray(ranked_doc_ids)[:, :k]
    rel = np.asarray(qrels).reshape(-1, 1)
    return float((ranked == rel).any(axis=1).mean())


def ndcg_at_k(
    ranked_doc_ids: np.ndarray,
    qrel_ids: np.ndarray,
    k: int = 10,
    qrel_gains: np.ndarray | None = None,
) -> float:
    """Mean NDCG at cutoff k with graded relevance.

    Args:
      ranked_doc_ids: ``[n_queries, >=k]`` doc ids in decreasing score order.
        A cutoff larger than the ranking just uses the whole ranking.
      qrel_ids: ``[n_queries, R]`` relevant doc ids per query, ``-1`` padding
        for queries with fewer than R judged docs. A 1-D array is treated as
        one relevant doc per query (MS MARCO style).
      qrel_gains: optional ``[n_queries, R]`` graded gains aligned with
        ``qrel_ids``; omitted = binary relevance (gain 1 per judged doc).
        Pad slots are ignored regardless of their gain value.

    Uses the standard ``gain / log2(rank + 1)`` discount; the ideal DCG sorts
    each query's (unpadded) gains descending and truncates at k. Queries with
    no judged docs contribute 0 (the sklearn/trec_eval convention), so adding
    unjudged queries can only lower the mean — never inflate it.
    """
    ranked = np.asarray(ranked_doc_ids)[:, :k]
    rels = np.asarray(qrel_ids)
    if rels.ndim == 1:
        rels = rels.reshape(-1, 1)
    if qrel_gains is None:
        gains = np.ones(rels.shape, np.float64)
    else:
        gains = np.asarray(qrel_gains, np.float64)
        if gains.shape != rels.shape:
            raise ValueError(
                f"qrel_gains shape {gains.shape} != qrel_ids shape {rels.shape}"
            )
    live = rels >= 0
    gains = np.where(live, gains, 0.0)
    # gain of each ranked slot: matched judged doc's gain, else 0. Judged ids
    # are unique per query, so the sum over R picks at most one gain per slot.
    slot_gain = np.einsum(
        "qkr,qr->qk", (ranked[:, :, None] == rels[:, None, :]) & live[:, None, :], gains
    )
    discount = 1.0 / np.log2(np.arange(ranked.shape[1]) + 2.0)
    dcg = slot_gain @ discount
    ideal = -np.sort(-gains, axis=1)[:, : ranked.shape[1]]
    idcg = ideal @ discount[: ideal.shape[1]]
    return float(np.where(idcg > 0, dcg / np.maximum(idcg, 1e-12), 0.0).mean())


def rank_overlap(ids_a: np.ndarray, ids_b: np.ndarray, k: int) -> float:
    """Mean top-k set overlap between two systems (rank-safety diagnostics)."""
    a = np.asarray(ids_a)[:, :k]
    b = np.asarray(ids_b)[:, :k]
    out = []
    for i in range(a.shape[0]):
        out.append(len(np.intersect1d(a[i], b[i])) / k)
    return float(np.mean(out))
