"""repro: learned-sparse retrieval framework (Wacky Weights / SAAT-vs-DAAT).

A production-oriented JAX reimplementation + TPU adaptation of

    Mackenzie, Trotman, Lin. "Wacky Weights in Learned Sparse Representations
    and the Revenge of Score-at-a-Time Query Evaluation" (2021).

Layers:
    repro.core         impact-quantized indexes, SAAT/DAAT/exhaustive top-k
    repro.kernels      Pallas TPU kernels for the scoring hot loops
    repro.models       BM25 / expansion / learned sparse encoders
    repro.archs        assigned architectures (LM / GNN / RecSys)
    repro.data         synthetic vocabulary-mismatch corpus + pipelines
    repro.train        optimizers, losses, trainer
    repro.distributed  sharding rules, collectives, elastic utilities
    repro.checkpoint   sharded fault-tolerant checkpointing
    repro.serving      batched anytime serving with deadline -> rho control
    repro.launch       production mesh, multi-pod dry-run, drivers
"""

__version__ = "1.0.0"
