"""Prometheus-style serving counters: scrape-time export, zero hot-path cost.

The serving stack has been accumulating its own observability for free —
``FlushRecord`` / ``Completion`` already carry flush occupancy, violation and
infeasibility judgements, the served-rho distribution, and per-bucket queue
state; the pod serve step's statics carry the merge fan-in. This module is
the thin export layer: a tiny metric registry whose families are *derived at
scrape time* from those records (``AdmissionQueue.export_counters``,
``PodServer.export_counters``), rendered either as the Prometheus text
exposition format (``render()``, for a scrape endpoint or the
``launch/serve.py --counters`` stderr dump) or as a JSON-able dict
(``as_dict()``, what the CI lane jq-checks).

Deliberately NOT a client library: no background threads, no process
collectors, no default registry — and nothing here is ever called from
under a trace. The hot path stays pure (the analysis lint enforces it); a
counter increment is always a host-side bookkeeping read of state the
serving layer already kept.

Counter families (see also ``serving/README.md``):

  ``repro_queue_submitted_total`` / ``repro_queue_completed_total``
      admission volume per queue.
  ``repro_queue_flush_total{bucket, reason}``
      flushes by Lq bucket and trigger (``full`` | ``deadline`` | ``drain``).
  ``repro_queue_flush_occupancy{bucket}``
      histogram of real-rows / batch-shape per flush — how much of each
      compiled executable the traffic actually filled.
  ``repro_queue_violations_total`` / ``repro_queue_infeasible_total``
      SLO accounting: late-flush policy violations vs dead-on-arrival
      deadlines (disjoint by construction — see ``FlushRecord``).
  ``repro_queue_served_rho_total{rho}``
      distribution of SAAT posting budgets actually served (the degrade
      knob's audit trail); DAAT flushes count under ``rho="none"``.
  ``repro_queue_degraded_total``
      flushes served below the full budget.
  ``repro_queue_depth{bucket}``
      gauge: requests pending per bucket lane at scrape time.
  ``repro_pod_dispatch_total{host, engine, rho}`` /
  ``repro_pod_merge_fanin{host, rho}``
      pod serve-step dispatches and the candidates-per-cross-host-merge
      (``ranks * k``) each dispatch feeds through ``canonical_topk_merge``.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

_LabelKey = Tuple[Tuple[str, str], ...]


def _labelkey(labels: Dict[str, str]) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: _LabelKey, extra: Optional[Tuple[Tuple[str, str], ...]] = None) -> str:
    items = list(key) + list(extra or ())
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


class _Child:
    """One labeled sample of a counter/gauge family."""

    def __init__(self, family: "Family", key: _LabelKey):
        self._family = family
        self._key = key

    def inc(self, v: float = 1.0):
        if self._family.kind == "gauge":
            self._family._samples[self._key] = self._family._samples.get(self._key, 0.0) + v
            return
        if v < 0:
            raise ValueError(f"counter increments must be >= 0, got {v}")
        self._family._samples[self._key] = self._family._samples.get(self._key, 0.0) + v

    def set(self, v: float):
        if self._family.kind != "gauge":
            raise TypeError(f"set() is gauge-only; {self._family.name} is a {self._family.kind}")
        self._family._samples[self._key] = float(v)

    def observe(self, v: float):
        if self._family.kind != "histogram":
            raise TypeError(
                f"observe() is histogram-only; {self._family.name} is a {self._family.kind}"
            )
        counts, agg = self._family._hist.setdefault(
            self._key, ([0] * len(self._family.buckets), [0.0, 0])
        )
        for i, le in enumerate(self._family.buckets):
            if v <= le:
                counts[i] += 1
        agg[0] += float(v)
        agg[1] += 1


class Family:
    """One named metric family (counter | gauge | histogram)."""

    def __init__(self, name: str, help: str, kind: str, buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.help = help
        self.kind = kind
        self._samples: Dict[_LabelKey, float] = {}
        if kind == "histogram":
            bs = tuple(float(b) for b in (buckets or (0.25, 0.5, 0.75, 1.0)))
            if sorted(bs) != list(bs):
                raise ValueError(f"histogram buckets must be ascending, got {buckets!r}")
            self.buckets = bs + ((float("inf"),) if bs[-1] != float("inf") else ())
        else:
            if buckets is not None:
                raise ValueError(f"{kind} takes no buckets")
            self.buckets = ()
        self._hist: Dict[_LabelKey, tuple[list, list]] = {}

    def labels(self, **labels: str) -> _Child:
        return _Child(self, _labelkey(labels))

    # conveniences for label-less families
    def inc(self, v: float = 1.0):
        self.labels().inc(v)

    def set(self, v: float):
        self.labels().set(v)

    def observe(self, v: float):
        self.labels().observe(v)

    def render(self) -> str:
        lines = [f"# HELP {self.name} {self.help}", f"# TYPE {self.name} {self.kind}"]
        if self.kind == "histogram":
            for key in sorted(self._hist):
                counts, (total, n) = self._hist[key]
                for le, c in zip(self.buckets, counts):
                    le_s = "+Inf" if le == float("inf") else _fmt_value(le)
                    lines.append(
                        f"{self.name}_bucket{_fmt_labels(key, (('le', le_s),))} {c}"
                    )
                lines.append(f"{self.name}_sum{_fmt_labels(key)} {_fmt_value(total)}")
                lines.append(f"{self.name}_count{_fmt_labels(key)} {n}")
            return "\n".join(lines)
        for key in sorted(self._samples):
            lines.append(f"{self.name}{_fmt_labels(key)} {_fmt_value(self._samples[key])}")
        return "\n".join(lines)

    def as_dict(self) -> dict:
        out = {"type": self.kind, "help": self.help}
        if self.kind == "histogram":
            out["samples"] = [
                {
                    "labels": dict(key),
                    "buckets": {
                        ("+Inf" if le == float("inf") else _fmt_value(le)): c
                        for le, c in zip(self.buckets, counts)
                    },
                    "sum": total,
                    "count": n,
                }
                for key, (counts, (total, n)) in sorted(self._hist.items())
            ]
        else:
            out["samples"] = [
                {"labels": dict(key), "value": v}
                for key, v in sorted(self._samples.items())
            ]
        return out


class CounterRegistry:
    """A bag of metric families with one text and one JSON rendering.

    ``counter``/``gauge``/``histogram`` are get-or-create (re-registering
    the same name with the same kind returns the existing family, so several
    queues/servers can export into one registry), and registering a name as
    two different kinds is an error.
    """

    def __init__(self):
        self._families: Dict[str, Family] = {}

    def _get(self, name: str, help: str, kind: str, buckets=None) -> Family:
        fam = self._families.get(name)
        if fam is None:
            fam = Family(name, help, kind, buckets)
            self._families[name] = fam
        elif fam.kind != kind:
            raise ValueError(f"{name} already registered as {fam.kind}, not {kind}")
        return fam

    def counter(self, name: str, help: str) -> Family:
        return self._get(name, help, "counter")

    def gauge(self, name: str, help: str) -> Family:
        return self._get(name, help, "gauge")

    def histogram(self, name: str, help: str, buckets: Optional[Sequence[float]] = None) -> Family:
        return self._get(name, help, "histogram", buckets)

    def families(self) -> dict[str, Family]:
        return dict(self._families)

    def render(self) -> str:
        """Prometheus text exposition format (one scrape page)."""
        return "\n".join(self._families[n].render() for n in sorted(self._families)) + "\n"

    def as_dict(self) -> dict:
        """JSON-able view, family name -> {type, help, samples} (jq-friendly)."""
        return {n: f.as_dict() for n, f in sorted(self._families.items())}
