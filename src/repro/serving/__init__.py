"""Anytime serving: deadline->rho control, batched streams, doc sharding,
Lq-bucketed executables, the continuous-batching admission queue, and the
mutable-index lifecycle (tombstone-masked serve steps, hot-swap compaction)."""
from repro.serving.bucketing import (  # noqa: F401
    bucket_for,
    bucketize_batch,
    effective_lq,
    normalize_buckets,
    pad_to_width,
    sentinel_rows,
)
from repro.serving.counters import CounterRegistry  # noqa: F401
from repro.serving.pod import (  # noqa: F401
    PodFrontEnd,
    PodResult,
    PodServer,
    pod_hosts,
    warmup_pod,
)
from repro.serving.queue import (  # noqa: F401
    AdmissionQueue,
    Completion,
    FlushRecord,
    SurvivorPredictor,
)
from repro.serving.lifecycle import (  # noqa: F401
    CompactionPolicy,
    Compactor,
    MutationEvent,
    replay_with_churn,
)
from repro.serving.scheduler import (  # noqa: F401
    AnytimeServer,
    ServingConfig,
    index_static_signature,
    run_query_stream,
)
from repro.serving.sharded import (  # noqa: F401
    abstract_stacked_index,
    make_bucketed_serve_step,
    make_pod_serve_step,
    make_sharded_serve_step,
    shard_corpus,
    shard_live_stack,
    stack_indexes,
)
