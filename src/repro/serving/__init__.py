"""Anytime serving: deadline->rho control, batched streams, doc sharding,
Lq-bucketed executables, and the continuous-batching admission queue."""
from repro.serving.bucketing import (  # noqa: F401
    bucket_for,
    bucketize_batch,
    effective_lq,
    normalize_buckets,
    pad_to_width,
    sentinel_rows,
)
from repro.serving.counters import CounterRegistry  # noqa: F401
from repro.serving.pod import (  # noqa: F401
    PodFrontEnd,
    PodResult,
    PodServer,
    pod_hosts,
    warmup_pod,
)
from repro.serving.queue import (  # noqa: F401
    AdmissionQueue,
    Completion,
    FlushRecord,
    SurvivorPredictor,
)
from repro.serving.scheduler import AnytimeServer, ServingConfig, run_query_stream  # noqa: F401
from repro.serving.sharded import (  # noqa: F401
    abstract_stacked_index,
    make_bucketed_serve_step,
    make_pod_serve_step,
    make_sharded_serve_step,
    shard_corpus,
    stack_indexes,
)
