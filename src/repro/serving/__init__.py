"""Anytime serving: deadline->rho control, batched streams, doc sharding."""
from repro.serving.scheduler import AnytimeServer, ServingConfig, run_query_stream  # noqa: F401
from repro.serving.sharded import (  # noqa: F401
    abstract_stacked_index,
    make_sharded_serve_step,
    shard_corpus,
    stack_indexes,
)
