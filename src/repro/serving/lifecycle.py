"""Index lifecycle management over the serving stack: when to compact, and
how a hot swap interleaves with live traffic.

The mutable-corpus machinery lives in :class:`repro.core.index_handle.
IndexHandle` (delta segment, tombstones, :meth:`~repro.core.index_handle.
IndexHandle.compact`); the serving layers know how to *adopt* a new
generation (:meth:`AnytimeServer.swap_index` / :meth:`AdmissionQueue.
swap_index` — calibration decayed, never discarded). This module supplies
the policy between them:

  * :class:`CompactionPolicy` / :class:`Compactor` — the threshold rule for
    when accumulated churn justifies folding main + delta − tombstones into
    a fresh main segment, and the driver that runs the fold off the serving
    path and hot-swaps the result in. Two pressures trigger it: a fat delta
    (every dispatch pays the delta scan + merge) and a tombstone-heavy main
    (budgeted work wasted scoring docs that are masked to ``-inf`` at
    select time).
  * :func:`replay_with_churn` — the deterministic mutation-replay harness:
    one simulated-clock event loop that interleaves query arrivals, index
    mutations, due-time flushes, and threshold compactions. Mutations and
    compactions only ever run *between* flushes (the event loop applies them
    at their timestamps, and flushes are synchronous), which is precisely
    the hot-swap contract: no request observes a half-swapped index, and a
    swap loses / duplicates / reorders zero requests. The mutation log the
    replay returns records the generation at every event, so tests can pin
    ``FlushRecord.generation`` monotonicity against it.

Compaction here is "background" in the scheduling sense, not the threading
sense: on the simulated clock it is a synchronous step whose wall time the
caller can model by advancing the clock. That keeps the replay a pure
function of its event schedule — the property every serving test in this
repo is built on.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.index_handle import IndexHandle
from repro.metrics.latency import SimulatedClock
from repro.serving.queue import AdmissionQueue, Completion


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """Threshold rule: fold the LSM triple once churn makes serving pay.

    ``max_delta_docs``: delta segment size at which the per-dispatch delta
    scan + merge overhead justifies a rebuild. ``max_tombstone_frac``:
    fraction of the MAIN segment's docs that are tombstoned — dead docs
    still occupy blocks, so the budgeted scan wastes rho/block budget on
    rows the live mask immediately demotes to ``-inf``. ``min_tombstones``
    keeps a tiny corpus from compacting on its first delete.
    """

    max_delta_docs: int = 128
    max_tombstone_frac: float = 0.25
    min_tombstones: int = 8

    def due(self, handle: IndexHandle) -> bool:
        if handle.delta_docs >= self.max_delta_docs:
            return True
        # only tombstones that still OCCUPY postings in main create scan
        # waste; a gid dead since before the last compaction already has an
        # empty row (ids are never re-used), so counting it would latch the
        # trigger permanently after the first tombstone-driven fold
        doc_n_terms = np.asarray(handle.main.doc_n_terms)
        dead_in_main = sum(
            1
            for g in handle.dead_gids
            if g < handle.main.n_docs and doc_n_terms[g] > 0
        )
        if dead_in_main < self.min_tombstones:
            return False
        return dead_in_main >= self.max_tombstone_frac * max(handle.main.n_docs, 1)


class Compactor:
    """Threshold-driven compaction driver over one queue (or bare server).

    ``maybe_compact()`` checks the policy, and when due: folds the handle
    (:meth:`IndexHandle.compact`) and hot-swaps the serving stack
    (:meth:`AdmissionQueue.swap_index` — or the server's, when no queue is
    involved). Call it between flushes — e.g. from the event loop of
    :func:`replay_with_churn`, or after ``poll()`` in a driver.
    """

    def __init__(
        self,
        target,  # AdmissionQueue | AnytimeServer
        handle: IndexHandle,
        policy: CompactionPolicy = CompactionPolicy(),
        *,
        decay: float = 0.5,
    ):
        self.target = target
        self.handle = handle
        self.policy = policy
        self.decay = decay
        self.n_compactions = 0
        self.log: list[dict] = []

    def maybe_compact(self, now_s: Optional[float] = None) -> bool:
        if not self.policy.due(self.handle):
            return False
        before = dict(
            delta_docs=self.handle.delta_docs,
            tombstones=self.handle.tombstone_count,
            n_docs_main=self.handle.main.n_docs,
        )
        self.handle.compact()
        self.target.swap_index(decay=self.decay)
        self.n_compactions += 1
        self.log.append(
            dict(
                t_s=now_s,
                generation=self.handle.generation,
                **before,
            )
        )
        return True


@dataclasses.dataclass(frozen=True)
class MutationEvent:
    """One corpus mutation at an instant of the replay's simulated clock.

    ``op`` is ``"add"`` | ``"update"`` | ``"delete"``; ``gid`` identifies the
    target for update/delete (``None`` for add — the handle assigns the next
    gid); ``terms``/``weights`` carry the sparse vector for add/update.
    """

    t_s: float
    op: str
    gid: Optional[int] = None
    terms: Optional[np.ndarray] = None
    weights: Optional[np.ndarray] = None


def _apply_mutation(handle: IndexHandle, ev: MutationEvent) -> Optional[int]:
    if ev.op == "add":
        return handle.add(ev.terms, ev.weights)
    if ev.op == "update":
        handle.update(ev.gid, ev.terms, ev.weights)
        return ev.gid
    if ev.op == "delete":
        handle.delete(ev.gid)
        return ev.gid
    raise ValueError(f"unknown mutation op {ev.op!r}")


def replay_with_churn(
    queue: AdmissionQueue,
    handle: IndexHandle,
    arrivals_s: Sequence[float],
    q_terms_list: Sequence[np.ndarray],
    q_weights_list: Sequence[np.ndarray],
    deadlines_ms: Sequence[float],
    mutations: Sequence[MutationEvent],
    *,
    compactor: Optional[Compactor] = None,
) -> tuple[list[Completion], list[dict]]:
    """Deterministically replay queries AND corpus churn on one clock.

    Extends :func:`repro.serving.queue.replay_arrivals` with a third event
    stream: at each step the loop advances the queue's
    :class:`~repro.metrics.latency.SimulatedClock` to the earliest of (next
    arrival, next mutation, ``next_due()``) and handles exactly that event.
    Mutations apply to the handle at their timestamps; after each one the
    optional ``compactor`` gets a chance to fold and hot-swap. Because every
    flush is synchronous inside ``poll()``/``submit()``, mutations and swaps
    can only ever land *between* flushes — the replay is the executable
    statement of the hot-swap contract.

    Returns ``(completions, mutation_log)``; each mutation-log entry records
    the op, the gid it touched, the clock instant, the handle's generation
    AFTER the op (and any compaction it triggered), and the live
    delta/tombstone tallies — enough for a test to reconstruct the exact
    corpus any completed request was served against.
    """
    clock = queue.clock
    if not isinstance(clock, SimulatedClock):
        raise TypeError(
            "replay_with_churn drives time itself; queue needs a SimulatedClock"
        )
    if not (
        len(arrivals_s) == len(q_terms_list) == len(q_weights_list) == len(deadlines_ms)
    ):
        raise ValueError("arrival schedule fields must have equal length")
    muts = sorted(mutations, key=lambda ev: ev.t_s)
    inf = float("inf")
    completions: list[Completion] = []
    mutation_log: list[dict] = []
    i, n = 0, len(arrivals_s)
    j, m = 0, len(muts)
    while i < n or j < m or queue.pending():
        t_arr = arrivals_s[i] if i < n else inf
        t_mut = muts[j].t_s if j < m else inf
        due = queue.next_due()
        t_due = due if due is not None else inf
        t_next = min(t_arr, t_mut, t_due)
        if t_next is inf:
            break
        clock.advance_to(t_next)
        # mutations first at a tie: a query arriving at the same instant as a
        # write observes the write (read-your-writes at equal timestamps)
        if t_mut <= min(t_arr, t_due):
            ev = muts[j]
            j += 1
            gid = _apply_mutation(handle, ev)
            compacted = bool(compactor and compactor.maybe_compact(now_s=t_next))
            mutation_log.append(
                dict(
                    t_s=t_next, op=ev.op, gid=gid,
                    generation=handle.generation,
                    delta_docs=handle.delta_docs,
                    tombstones=handle.tombstone_count,
                    compacted=compacted,
                )
            )
        elif t_arr <= t_due:
            queue.submit(q_terms_list[i], q_weights_list[i], deadlines_ms[i])
            i += 1
        completions.extend(queue.poll())
    completions.extend(queue.drain())
    return completions, mutation_log
