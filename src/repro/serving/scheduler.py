"""Anytime serving: batched queries, deadline -> rho control, doc sharding.

The paper's core serving claim is that SAAT's posting budget rho makes query
cost — and therefore latency — *predictable*. This module turns that into a
deadline controller: given a target latency, pick the largest rho whose
predicted cost fits. Because rho is a static tensor shape, the controller
quantizes to a ladder of pre-compiled rho levels — and because ``saat_search``
is natively batched, each level is ONE batched executable over the whole
``[B, Lq]`` query batch (single batched plan sort, gather, and scatter), not
``B`` vmapped single-query programs. Switching levels never recompiles at
serve time.

At pod scale, documents shard over the ``model`` axis: each chip runs the
identical rho-budgeted scan over its shard and ships only its k finalists
(``sharded_topk_merge``). Uniform per-chip work = no stragglers from corpus
skew — the paper's tail-latency argument, promoted to a cluster property.

The server can also run the natively batched Block-Max DAAT engine
(``engine="daat"``) so both sides of the paper's SAAT-vs-DAAT comparison are
served by one batched executable each. DAAT has no rho knob: its cost is
data-dependent (the while_loop runs until the slowest query in the batch is
rank-safe), which is exactly the tail-latency contrast the benchmarks
measure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.daat import daat_search_batched, max_blocks_per_term
from repro.core.impact_index import ImpactIndex
from repro.core.saat import max_segments_per_term, saat_search
from repro.metrics.latency import LatencyStats, summarize_latencies


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    k: int = 1000
    rho_ladder: tuple[int, ...] = (100_000, 500_000, 1_000_000, 5_000_000, 10_000_000)
    batch_size: int = 32
    deadline_ms: Optional[float] = None  # None = always use max rho
    scatter_impl: str = "sort"
    # fuse SAAT's top-k into the scatter kernel (impact_scatter_topk): the
    # [B, n_docs] accumulator never reaches HBM; scatter_impl is then ignored
    fused_topk: bool = False
    ema_alpha: float = 0.2  # cost-model smoothing
    # engine selection: "saat" (anytime, rho ladder) or "daat" (block-max
    # pruning; data-dependent cost, no rho control)
    engine: str = "saat"
    daat_est_blocks: int = 8
    daat_block_budget: int = 16
    daat_exact: bool = True
    # route DAAT phase 2 through the batched Pallas kernels (block_prune /
    # block_topk / sparse_score); False keeps the jnp oracle formulation
    daat_use_kernels: bool = False


@dataclasses.dataclass
class _CostModel:
    """us per million postings, learned online per rho level."""

    us_per_mpost: dict
    alpha: float

    def update(self, rho: int, elapsed_us: float):
        per = elapsed_us / max(rho / 1e6, 1e-9)
        old = self.us_per_mpost.get(rho)
        self.us_per_mpost[rho] = per if old is None else (1 - self.alpha) * old + self.alpha * per

    def predict_us(self, rho: int) -> float:
        if not self.us_per_mpost:
            return 0.0
        # nearest calibrated level
        lvl = min(self.us_per_mpost, key=lambda r: abs(r - rho))
        return self.us_per_mpost[lvl] * rho / 1e6


class AnytimeServer:
    """Batched SAAT serving over one impact index.

    Every ``search_batch`` call dispatches the natively batched engine; the
    per-rho executables are compiled once (``warmup``) and reused. The plan
    bound ``max_segs`` comes from index build-time metadata, so constructing
    a server never blocks on a device sync.
    """

    def __init__(self, index: ImpactIndex, cfg: ServingConfig):
        if cfg.engine not in ("saat", "daat"):
            raise ValueError(f"unknown engine {cfg.engine!r}")
        self.index = index
        self.cfg = cfg
        # both bounds come from index build-time metadata — no device sync
        self.max_segs = max_segments_per_term(index)
        self.max_bm = max_blocks_per_term(index)
        self._latencies_ms: list[float] = []
        self._rhos: list[int] = []
        self._cost = _CostModel({}, cfg.ema_alpha)
        # cap the ladder at the index's own posting count (exact level)
        exact = index.n_postings
        ladder = sorted({min(r, exact) for r in cfg.rho_ladder} | {exact})
        self.rho_ladder = tuple(ladder)

    # -------------------------- rho selection -----------------------------

    def pick_rho(self) -> int:
        if self.cfg.deadline_ms is None:
            return self.rho_ladder[-1]
        budget_us = self.cfg.deadline_ms * 1e3
        best = self.rho_ladder[0]
        for rho in self.rho_ladder:
            pred = self._cost.predict_us(rho)
            if pred == 0.0 or pred <= budget_us:
                best = rho
        return best

    # ----------------------------- serving --------------------------------

    def _daat_search(self, q_terms: jax.Array, q_weights: jax.Array):
        return daat_search_batched(
            self.index,
            q_terms,
            q_weights,
            k=self.cfg.k,
            est_blocks=self.cfg.daat_est_blocks,
            block_budget=self.cfg.daat_block_budget,
            max_bm_per_term=self.max_bm,
            exact=self.cfg.daat_exact,
            use_kernels=self.cfg.daat_use_kernels,
        )

    def search_batch(self, q_terms: jax.Array, q_weights: jax.Array, rho: Optional[int] = None):
        if self.cfg.engine == "daat":
            if rho is not None:
                raise ValueError(
                    "rho is a SAAT posting budget; the daat engine's cost is "
                    "data-dependent and cannot honor it"
                )
            t0 = time.perf_counter()
            res = self._daat_search(q_terms, q_weights)
            jax.block_until_ready(res.scores)
            per_query = (time.perf_counter() - t0) * 1e3 / q_terms.shape[0]
            self._latencies_ms.extend([per_query] * q_terms.shape[0])
            self._rhos.extend([0] * q_terms.shape[0])
            return res
        rho = rho or self.pick_rho()
        t0 = time.perf_counter()
        res = saat_search(
            self.index,
            q_terms,
            q_weights,
            k=self.cfg.k,
            rho=rho,
            max_segs_per_term=self.max_segs,
            scatter_impl=self.cfg.scatter_impl,
            fused_topk=self.cfg.fused_topk,
        )
        jax.block_until_ready(res.scores)
        elapsed = (time.perf_counter() - t0) * 1e3
        per_query = elapsed / q_terms.shape[0]
        for _ in range(q_terms.shape[0]):
            self._latencies_ms.append(per_query)
            self._rhos.append(rho)
        self._cost.update(rho, per_query * 1e3)
        return res

    def warmup(self, q_terms: jax.Array, q_weights: jax.Array, repeats: int = 2):
        """Compile + calibrate every rho level (excluded from stats)."""
        if self.cfg.engine == "daat":
            for _ in range(repeats):
                jax.block_until_ready(self._daat_search(q_terms, q_weights).scores)
            return
        for rho in self.rho_ladder:
            for _ in range(repeats):
                t0 = time.perf_counter()
                res = saat_search(
                    self.index,
                    q_terms,
                    q_weights,
                    k=self.cfg.k,
                    rho=rho,
                    max_segs_per_term=self.max_segs,
                    scatter_impl=self.cfg.scatter_impl,
                    fused_topk=self.cfg.fused_topk,
                )
                jax.block_until_ready(res.scores)
                per_query_us = (time.perf_counter() - t0) * 1e6 / q_terms.shape[0]
            self._cost.update(rho, per_query_us)

    def stats(self) -> LatencyStats:
        return summarize_latencies(self._latencies_ms)

    def reset_stats(self):
        self._latencies_ms.clear()
        self._rhos.clear()


def run_query_stream(
    server: AnytimeServer,
    q_terms: np.ndarray,  # [N, Lq]
    q_weights: np.ndarray,
    *,
    batch_size: Optional[int] = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Drive a query stream through the server in fixed batches.

    Returns (scores [N, k], doc_ids [N, k]). The final ragged batch is padded
    with repeats (served, then dropped) so every executable sees one shape.
    """
    bs = batch_size or server.cfg.batch_size
    N = q_terms.shape[0]
    out_s, out_i = [], []
    for lo in range(0, N, bs):
        hi = min(lo + bs, N)
        qt = q_terms[lo:hi]
        qw = q_weights[lo:hi]
        if hi - lo < bs:  # pad final batch
            pad = bs - (hi - lo)
            qt = np.concatenate([qt, np.repeat(qt[-1:], pad, 0)])
            qw = np.concatenate([qw, np.repeat(qw[-1:], pad, 0)])
        res = server.search_batch(jnp.asarray(qt), jnp.asarray(qw))
        out_s.append(np.asarray(res.scores)[: hi - lo])
        out_i.append(np.asarray(res.doc_ids)[: hi - lo])
    return np.concatenate(out_s), np.concatenate(out_i)
