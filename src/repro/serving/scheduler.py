"""Anytime serving: batched queries, deadline -> rho control, doc sharding.

The paper's core serving claim is that SAAT's posting budget rho makes query
cost — and therefore latency — *predictable*. This module turns that into a
deadline controller: given a target latency, pick the largest rho whose
predicted cost fits. Because rho is a static tensor shape, the controller
quantizes to a ladder of pre-compiled rho levels — and because ``saat_search``
is natively batched, each level is ONE batched executable over the whole
``[B, Lq]`` query batch (single batched plan sort, gather, and scatter), not
``B`` vmapped single-query programs. Switching levels never recompiles at
serve time.

At pod scale, documents shard over the ``model`` axis: each chip runs the
identical rho-budgeted scan over its shard and ships only its k finalists
(``sharded_topk_merge``). Uniform per-chip work = no stragglers from corpus
skew — the paper's tail-latency argument, promoted to a cluster property.

The server can also run the natively batched Block-Max DAAT engine
(``engine="daat"``) so both sides of the paper's SAAT-vs-DAAT comparison are
served by one batched executable each. DAAT has no rho knob: its cost is
data-dependent (the while_loop runs until the slowest query in the batch is
rank-safe), which is exactly the tail-latency contrast the benchmarks
measure.

Two serving-layer properties make the continuous-batching admission queue
(``repro.serving.queue``) possible:

  * **Lq bucketing** (``ServingConfig.lq_buckets``): each batch is padded to
    the smallest bucket width covering its live terms instead of the stream's
    max Lq, so the executable grid is (rho-or-engine-config) x (Lq bucket)
    and short-query traffic stops paying long-query gather cost. Results are
    bit-identical to the max-Lq pad (see ``repro.serving.bucketing``).
  * **Injectable time** (``clock=``): every latency measurement and the cost
    model's calibration read a :class:`repro.metrics.latency.Clock`, so the
    queue's deadline-driven flush policy can be tested on a simulated clock.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.daat import daat_search_batched, max_blocks_per_term
from repro.core.impact_index import META_FIELDS, ImpactIndex
from repro.core.index_handle import IndexHandle
from repro.core.saat import max_segments_per_term, saat_search
from repro.metrics.latency import Clock, LatencyStats, SystemClock, summarize_latencies
from repro.serving.bucketing import bucketize_batch, normalize_buckets, pad_to_width

_UNSET = object()  # pick_rho sentinel: "use cfg.deadline_ms"


def index_static_signature(ix: ImpactIndex) -> tuple:
    """Hashable shape-level signature of one ``ImpactIndex`` segment.

    Meta fields plus every array field's shape — exactly the jit-visible
    surface of the index pytree (array *values* are runtime operands and do
    not fork compiled programs). Used by ``AnytimeServer.executable_key``
    and the pod front end to fold segment identity into executable keys.
    """
    meta = tuple(getattr(ix, f) for f in META_FIELDS)
    shapes = tuple(
        tuple(np.shape(getattr(ix, f.name)))
        for f in dataclasses.fields(ix)
        if f.name not in META_FIELDS
    )
    return meta + shapes


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    k: int = 1000
    rho_ladder: tuple[int, ...] = (100_000, 500_000, 1_000_000, 5_000_000, 10_000_000)
    batch_size: int = 32
    deadline_ms: Optional[float] = None  # None = always use max rho
    scatter_impl: str = "sort"
    # fuse SAAT's top-k into the scatter kernel (impact_scatter_topk): the
    # [B, n_docs] accumulator never reaches HBM; scatter_impl is then ignored
    fused_topk: bool = False
    ema_alpha: float = 0.2  # cost-model smoothing
    # engine selection: "saat" (anytime, rho ladder) or "daat" (block-max
    # pruning; data-dependent cost, no rho control)
    engine: str = "saat"
    daat_est_blocks: int = 8
    daat_block_budget: int = 16
    daat_exact: bool = True
    # route DAAT phase 2 through the batched Pallas kernels (block_prune /
    # block_topk / sparse_score); False keeps the jnp oracle formulation
    daat_use_kernels: bool = False
    # fuse every phase-2 trip's select+score+merge into the single
    # VMEM-resident chunk_step kernel (requires daat_use_kernels=True);
    # per-trip HBM traffic drops to the candidate/state output only
    daat_fused_chunk: bool = False
    # batch up to this many phase-2 trips inside ONE fused chunk_step launch
    # (requires daat_fused_chunk=True); pool/theta/processed cross HBM once
    # per launch instead of once per trip. 1 = the per-trip launch cadence.
    # Ignored (clamped to 1) when daat_exact=False: the anytime budget is
    # enforced at trip granularity.
    daat_trips_per_launch: int = 1
    # Lq bucket widths: each batch is padded to the smallest bucket covering
    # its live terms (one executable per (config, bucket) pair, bit-identical
    # results); None pads to whatever width the caller sends
    lq_buckets: Optional[tuple[int, ...]] = None


@dataclasses.dataclass
class _CostModel:
    """us per million postings, learned online per rho level.

    ``clock`` stamps each level's last calibration time so staleness is
    observable (and so calibration itself is testable on a simulated clock).
    A level is *calibrated* once it has been directly measured. Predictions
    for unmeasured levels interpolate piecewise-linearly in *total cost*
    between the two bracketing calibrated levels. Above the calibrated range
    the boundary level's per-Mpost rate extrapolates linearly; BELOW it the
    prediction floors at the boundary level's measured total — fixed
    per-call overhead does not shrink with rho, so scaling through the
    origin under-predicts small budgets (the old nearest-level-times-
    ``rho/level`` rule had the same disease across the whole ladder).
    ``predict_us`` returns ``None`` only when nothing has been measured at
    all — callers must treat that as "unknown", never as "free".
    """

    us_per_mpost: dict
    alpha: float
    clock: Clock = dataclasses.field(default_factory=SystemClock)
    last_update_s: dict = dataclasses.field(default_factory=dict)
    # per-level confidence in [0, 1]: 1.0 = the EMA is fully trusted (the
    # steady state; update() then smooths at exactly `alpha`). A hot swap
    # decays confidence instead of discarding the value — the old measurement
    # is still the best available prior for the new generation's executable,
    # but the next observations blend in faster (effective alpha rises toward
    # 1 as confidence falls) until confidence recovers.
    confidence: dict = dataclasses.field(default_factory=dict)

    def update(self, rho: int, elapsed_us: float):
        per = elapsed_us / max(rho / 1e6, 1e-9)
        conf = self.confidence.get(rho, 1.0)
        a = self.alpha + (1.0 - self.alpha) * (1.0 - conf)
        old = self.us_per_mpost.get(rho)
        self.us_per_mpost[rho] = per if old is None else (1 - a) * old + a * per
        self.confidence[rho] = 1.0 - (1.0 - conf) * (1.0 - self.alpha)
        self.last_update_s[rho] = self.clock.now()

    def decay(self, factor: float):
        """Generation bump: keep every calibrated value, shrink its trust."""
        for rho in self.us_per_mpost:
            self.confidence[rho] = self.confidence.get(rho, 1.0) * factor

    def is_calibrated(self, rho: int) -> bool:
        return rho in self.us_per_mpost

    def predict_us(self, rho: int) -> Optional[float]:
        if not self.us_per_mpost:
            return None
        levels = sorted(self.us_per_mpost)
        # below the calibrated range: floor at the boundary level's measured
        # TOTAL cost. Scaling linearly through the origin pretends the fixed
        # per-call overhead (dispatch, plan sort, top-k) shrinks with rho —
        # it doesn't, and the resulting under-prediction made pick_rho admit
        # small-rho work that blew its deadline. Over-predicting a smaller
        # rho by at most the boundary total is the safe direction.
        if rho <= levels[0]:
            return self.us_per_mpost[levels[0]] * levels[0] / 1e6
        # above it: the boundary RATE extrapolates linearly (dominated by the
        # per-posting scan, so the rate is the right asymptote)
        if rho >= levels[-1]:
            return self.us_per_mpost[levels[-1]] * rho / 1e6
        hi_ix = bisect.bisect_left(levels, rho)
        lo, hi = levels[hi_ix - 1], levels[hi_ix]
        total_lo = self.us_per_mpost[lo] * lo / 1e6
        total_hi = self.us_per_mpost[hi] * hi / 1e6
        frac = (rho - lo) / (hi - lo)
        return total_lo + frac * (total_hi - total_lo)


class AnytimeServer:
    """Batched SAAT serving over one impact index — or a mutable handle.

    Every ``search_batch`` call dispatches the natively batched engine; the
    per-rho executables are compiled once (``warmup``) and reused. The plan
    bound ``max_segs`` comes from index build-time metadata, so constructing
    a server never blocks on a device sync.

    Passing an :class:`repro.core.index_handle.IndexHandle` makes the server
    lifecycle-aware: dispatches serve (main − tombstones) ∪ delta through the
    handle's merged search (rho budgets the MAIN segment only; the delta is
    tiny and always exact), and :meth:`swap_index` hot-swaps to a freshly
    compacted main between admission-queue flushes — bumping ``generation``
    and *decaying* (never discarding) the service-time calibration.
    """

    def __init__(
        self,
        index: ImpactIndex | IndexHandle,
        cfg: ServingConfig,
        clock: Optional[Clock] = None,
    ):
        if cfg.engine not in ("saat", "daat"):
            raise ValueError(f"unknown engine {cfg.engine!r}")
        if cfg.daat_fused_chunk and not cfg.daat_use_kernels:
            raise ValueError(
                "daat_fused_chunk fuses the kernel-mode chunk step; set "
                "daat_use_kernels=True"
            )
        if cfg.daat_trips_per_launch < 1:
            raise ValueError(
                f"daat_trips_per_launch={cfg.daat_trips_per_launch} must be >= 1"
            )
        if cfg.daat_trips_per_launch > 1 and not cfg.daat_fused_chunk:
            raise ValueError(
                "daat_trips_per_launch > 1 batches trips inside the fused "
                "chunk_step kernel; set daat_fused_chunk=True (and "
                "daat_use_kernels=True)"
            )
        self.handle: Optional[IndexHandle] = None
        if isinstance(index, IndexHandle):
            self.handle = index
        else:
            self.index = index
        self.cfg = cfg
        self.clock: Clock = clock if clock is not None else SystemClock()
        self.generation = self.handle.generation if self.handle is not None else 0
        self._latencies_ms: list[float] = []
        self._rhos: list[int] = []
        self._cost = _CostModel({}, cfg.ema_alpha, clock=self.clock)
        # whole-batch wall-ms EMA keyed by (engine, Lq bucket, batch shape,
        # rho): a batch runs as ONE executable whose wall time is far from
        # linear in B (plan/gather amortize, the DAAT while_loop runs to the
        # slowest row), so the admission queue's service-time estimate is
        # learned per compiled shape — never extrapolated linearly in B.
        # rho is part of the key because each SAAT ladder level is its own
        # executable with its own wall time (that difference IS the knob the
        # degrade-instead-of-violate flush policy trades on); DAAT has no rho
        # and keys with rho=None. SAAT falls back to the per-query rho model
        # only when no shape in the (engine, bucket, rho) lane is calibrated.
        self._bucket_ms: dict[tuple[str, int, int, Optional[int]], float] = {}
        # per-key calibration confidence (1.0 = steady state; see _CostModel)
        self._bucket_conf: dict[tuple[str, int, int, Optional[int]], float] = {}
        self.lq_buckets = (
            normalize_buckets(cfg.lq_buckets) if cfg.lq_buckets is not None else None
        )
        self._bind_main_segment()

    def _bind_main_segment(self):
        """(Re)derive everything that depends on the current main segment:
        the plan bounds (build-time metadata — no device sync) and the rho
        ladder cap (the exact level IS the main segment's posting count).
        Called at construction and on every :meth:`swap_index`.
        """
        index = self.handle.main if self.handle is not None else self.index
        self.index = index
        self.max_segs = max_segments_per_term(index)
        self.max_bm = max_blocks_per_term(index)
        # cap the ladder at the index's own posting count (exact level)
        exact = index.n_postings
        ladder = sorted({min(r, exact) for r in self.cfg.rho_ladder} | {exact})
        self.rho_ladder = tuple(ladder)

    # -------------------------- index lifecycle ----------------------------

    def swap_index(self, handle: Optional[IndexHandle] = None, *, decay: float = 0.5):
        """Hot-swap the serving index to the handle's current main segment.

        Called between admission-queue flushes after a background
        :meth:`~repro.core.index_handle.IndexHandle.compact` (or to adopt a
        replacement handle). Rebinds the main-segment statics (plan bounds,
        rho-ladder cap) and takes the handle's ``generation``.

        Calibration survives the swap **decayed, not discarded**: every
        service-time EMA keyed by shape — and every rho cost-model level —
        keeps its value but has its confidence multiplied by ``decay``, so the
        next observation of each executable blends in faster (effective alpha
        rises toward 1 as confidence falls) while the queue's flush policy
        still has a usable prediction from the first post-swap request.
        Resetting instead would re-open the cold-start window on every
        compaction — ``predict_service_ms`` returning 0.0 makes the queue
        flush exactly at the deadline, which a warm system has no reason to
        regress to.
        """
        if handle is not None:
            self.handle = handle
        if self.handle is None:
            raise ValueError(
                "swap_index needs a handle-backed server; construct the "
                "AnytimeServer with an IndexHandle"
            )
        if not 0.0 <= decay <= 1.0:
            raise ValueError(f"decay must be in [0, 1], got {decay}")
        self._bind_main_segment()
        self.generation = self.handle.generation
        self._decay_calibration(decay)

    def _decay_calibration(self, decay: float):
        """Shrink trust in every calibrated value without discarding it
        (service-time EMAs by shape, and the per-rho cost model)."""
        if not 0.0 <= decay <= 1.0:
            raise ValueError(f"decay must be in [0, 1], got {decay}")
        for key in self._bucket_ms:
            self._bucket_conf[key] = self._bucket_conf.get(key, 1.0) * decay
        self._cost.decay(decay)

    # -------------------------- rho selection -----------------------------

    def pick_rho(self, deadline_ms=_UNSET) -> int:
        """Largest *calibrated* ladder level whose predicted cost fits.

        ``deadline_ms`` overrides ``cfg.deadline_ms`` (the admission queue
        passes each batch's remaining time budget); ``None`` means no
        deadline -> max rho. An uncalibrated level is never treated as free:
        when no calibrated level fits we fall back to the *smallest*
        uncalibrated one (measure it cheaply, let the EMA learn), and only
        then to the smallest level outright.
        """
        deadline = self.cfg.deadline_ms if deadline_ms is _UNSET else deadline_ms
        if deadline is None:
            return self.rho_ladder[-1]
        budget_us = deadline * 1e3
        calibrated_fit = [
            rho
            for rho in self.rho_ladder
            if self._cost.is_calibrated(rho) and self._cost.predict_us(rho) <= budget_us
        ]
        if calibrated_fit:
            return calibrated_fit[-1]  # ladder is sorted ascending
        uncalibrated = [r for r in self.rho_ladder if not self._cost.is_calibrated(r)]
        if uncalibrated:
            return uncalibrated[0]
        return self.rho_ladder[0]

    # ------------------------ queue-facing predictions ---------------------

    def _rho_key(self, rho: Optional[int]) -> Optional[int]:
        """Canonical rho component of the service-time key (None for DAAT)."""
        if self.cfg.engine == "daat":
            return None
        return int(rho) if rho is not None else self.pick_rho()

    def predict_service_ms(self, n_queries: int, lq_bucket: int, rho: Optional[int] = None) -> float:
        """Predicted wall time to serve an ``[n_queries, lq_bucket]`` batch.

        Prefers the per-(engine, bucket, batch-shape, rho) EMA of observed
        whole-batch wall times: a batch is ONE executable, so its cost is far
        from linear in B and the old per-query-EMA-times-``n_queries`` rule
        systematically over-predicted large-shape flushes. ``rho`` selects
        the SAAT ladder level being considered (default: whatever
        ``pick_rho()`` would serve) — each level is a distinct executable
        with its own wall time, so predictions never mix levels. When the
        exact shape is uncalibrated, the nearest calibrated shape in the same
        (engine, bucket, rho) lane stands in: unscaled when predicting a
        smaller shape (a smaller batch can only be cheaper — over-predicting
        is safe), ratio-scaled upward when predicting a LARGER shape
        (flushing early is safe; under-predicting an unmeasured big
        executable would turn the cold start into deadline violations). Once
        a shape is observed its exact key takes over. SAAT falls back to the
        rho cost model only when no shape in the lane is calibrated at all,
        and the result is 0.0 when nothing is known — the admission queue
        then flushes exactly at the deadline, which is the conservative
        policy for an unknown service time.
        """
        eng, bucket, shape = self.cfg.engine, int(lq_bucket), int(n_queries)
        rk = self._rho_key(rho)
        batch_ms = self._bucket_ms.get((eng, bucket, shape, rk))
        if batch_ms is not None:
            return batch_ms
        shapes = [
            b for (e, bk, b, r) in self._bucket_ms if e == eng and bk == bucket and r == rk
        ]
        if shapes:
            nearest = min(shapes, key=lambda b: (abs(b - shape), b))
            batch_ms = self._bucket_ms[(eng, bucket, nearest, rk)]
            if shape > nearest:  # conservative upper bound, never a late flush
                return batch_ms * shape / nearest
            return batch_ms
        if eng == "saat":
            pred_us = self._cost.predict_us(rk)
            if pred_us is not None:
                return pred_us / 1e3 * n_queries
        return 0.0

    def service_calibrated(self, lq_bucket: int, rho: Optional[int] = None) -> bool:
        """True when some batch shape in the (engine, bucket, rho) lane has
        been directly measured — i.e. ``predict_service_ms`` for that lane
        rests on an observation of THAT executable, not on a cross-level
        guess. The degraded-rho picker only trusts calibrated lanes: an
        unmeasured small-rho level must never be "picked to fit" on faith.
        """
        eng, bucket, rk = self.cfg.engine, int(lq_bucket), self._rho_key(rho)
        return any(
            e == eng and bk == bucket and r == rk for (e, bk, _b, r) in self._bucket_ms
        )

    def pick_degraded_rho(self, n_queries: int, lq_bucket: int, remaining_ms: float) -> int:
        """Largest *calibrated* ladder level whose predicted service for this
        ``[n_queries, lq_bucket]`` flush still fits in ``remaining_ms``.

        This is the queue's degrade-instead-of-violate policy: when the full
        budget would blow the oldest deadline, trade effectiveness (a smaller
        posting budget) for the SLO rather than miss it. When no calibrated
        level fits, the SMALLEST calibrated level is the least-late choice;
        with nothing calibrated at all this defers to :meth:`pick_rho`'s
        deadline logic (which probes the smallest uncalibrated level so the
        EMA can learn it).
        """
        fit = [
            rho
            for rho in self.rho_ladder
            if self.service_calibrated(lq_bucket, rho)
            and self.predict_service_ms(n_queries, lq_bucket, rho) <= remaining_ms
        ]
        if fit:
            return fit[-1]  # ladder is sorted ascending
        calibrated = [r for r in self.rho_ladder if self.service_calibrated(lq_bucket, r)]
        if calibrated:
            return calibrated[0]
        return self.pick_rho(deadline_ms=remaining_ms)

    def _observe_bucket_ms(
        self, lq_bucket: int, batch_shape: int, batch_ms: float, rho: Optional[int] = None
    ):
        key = (self.cfg.engine, int(lq_bucket), int(batch_shape), self._rho_key(rho))
        old = self._bucket_ms.get(key)
        conf = self._bucket_conf.get(key, 1.0)
        # confidence-weighted smoothing: at full confidence (no swap since the
        # last observation settled) this is exactly cfg.ema_alpha; after a
        # generation bump the decayed confidence raises the effective alpha so
        # the stale-but-kept value re-converges quickly
        a = self.cfg.ema_alpha + (1.0 - self.cfg.ema_alpha) * (1.0 - conf)
        self._bucket_ms[key] = batch_ms if old is None else (1 - a) * old + a * batch_ms
        self._bucket_conf[key] = 1.0 - (1.0 - conf) * (1.0 - self.cfg.ema_alpha)

    # ----------------------------- serving --------------------------------

    def _daat_search(self, q_terms: jax.Array, q_weights: jax.Array):
        return daat_search_batched(
            self.index,
            q_terms,
            q_weights,
            k=self.cfg.k,
            est_blocks=self.cfg.daat_est_blocks,
            block_budget=self.cfg.daat_block_budget,
            max_bm_per_term=self.max_bm,
            exact=self.cfg.daat_exact,
            use_kernels=self.cfg.daat_use_kernels,
            fused_chunk=self.cfg.daat_fused_chunk,
            trips_per_launch=self.cfg.daat_trips_per_launch,
        )

    def engine_fn(self, rho: Optional[int] = None):
        """The pure engine dispatch for one executable: ``(qt, qw) -> result``.

        This is exactly what ``search_batch`` runs after host-side
        bucketization — the traced hot path, with every static baked in. The
        analysis lint (``repro.analysis.hot_path``) traces the returned
        callable at each (Lq bucket, B) shape, so serving MUST route through
        it: anything dispatched some other way is invisible to the purity
        gate.

        Handle-backed servers dispatch the handle's merged search (main with
        tombstone mask + exact delta + canonical merge); the handle's current
        segment arrays are closed over at call time, so every dispatch sees
        the latest mutations with no server-side bookkeeping.
        """
        if self.handle is not None:
            return self._handle_engine(rho)
        if self.cfg.engine == "daat":
            return self._daat_search
        if rho is None:
            rho = self.rho_ladder[-1]
        return functools.partial(
            saat_search,
            self.index,
            k=self.cfg.k,
            rho=rho,
            max_segs_per_term=self.max_segs,
            scatter_impl=self.cfg.scatter_impl,
            fused_topk=self.cfg.fused_topk,
        )

    def _handle_engine(self, rho: Optional[int] = None):
        """Merged lifecycle dispatch: ``(qt, qw) -> HandleResult``.

        rho budgets the MAIN segment only — the delta segment is tiny and
        always searched exactly, so the anytime knob trades effectiveness
        on the bulk corpus without ever degrading freshly written docs.
        """
        cfg = self.cfg
        if cfg.engine == "daat":
            return functools.partial(
                self.handle.daat_search,
                k=cfg.k,
                est_blocks=cfg.daat_est_blocks,
                block_budget=cfg.daat_block_budget,
                exact=cfg.daat_exact,
                use_kernels=cfg.daat_use_kernels,
                fused_chunk=cfg.daat_fused_chunk,
                trips_per_launch=cfg.daat_trips_per_launch,
            )
        return functools.partial(
            self.handle.saat_search,
            k=cfg.k,
            rho=self.rho_ladder[-1] if rho is None else rho,
            scatter_impl=cfg.scatter_impl,
            fused_topk=cfg.fused_topk,
        )

    def executable_key(
        self, lq_bucket: int, batch_size: int, rho: Optional[int] = None
    ) -> tuple:
        """Hashable id of the compiled executable serving this dispatch.

        The admission queue's service-time EMA and warmup grid both assume
        **one executable per key**: equal keys must hit the same compiled
        program (never a silent retrace), distinct keys must be distinct
        programs. The tuple mirrors the engines' ``SAAT_STATICS`` /
        ``DAAT_STATICS`` jit surface plus the batch shape — plus the **index
        static signature**: the segments' meta fields and array shapes are
        part of the jit cache key (the index rides the trace as pytree
        leaves whose treedef/avals are shape-derived), so a delta growing a
        block or a compaction changing the main pad width forks the compiled
        program and must fork the key. The lifecycle ``generation`` counter
        is deliberately NOT in the key: two generations with identical
        signatures trace to the identical program (array *values* are
        runtime inputs), so folding them into one key is what keeps the
        lint's key <-> fingerprint bijection true across hot swaps. The
        analysis lint verifies the invariant by tracing every key twice.
        """
        cfg = self.cfg
        if cfg.engine == "daat":
            statics: tuple = (
                "daat", cfg.k, cfg.daat_est_blocks, cfg.daat_block_budget,
                self.max_bm, cfg.daat_exact, cfg.daat_use_kernels,
                cfg.daat_fused_chunk, cfg.daat_trips_per_launch,
            )
        else:
            statics = (
                "saat", cfg.k, self.rho_ladder[-1] if rho is None else rho,
                self.max_segs, cfg.scatter_impl, cfg.fused_topk,
            )
        return statics + self._index_signature() + (int(lq_bucket), int(batch_size))

    def _index_signature(self) -> tuple:
        """Static (shape-level) signature of the index the dispatch closes over.

        One entry per segment: the ``ImpactIndex`` meta fields plus every
        array field's shape — exactly the jit-visible surface of the index
        pytree. Handle-backed servers contribute the main segment, a marker
        for the always-present tombstone mask, and the delta segment (or
        ``None`` when empty: the merge is skipped, a genuinely different
        program).
        """
        if self.handle is None:
            return (index_static_signature(self.index),)
        d = self.handle.delta
        return (
            index_static_signature(self.handle.main),
            "live",
            None if d is None else index_static_signature(d),
        )

    def _bucketize(self, q_terms, q_weights) -> tuple[jax.Array, jax.Array, int]:
        """Pad the batch to its Lq bucket and canonicalize dtypes.

        Dtype canonicalization is a compile-cache invariant, not a nicety: a
        caller handing i64 terms or weak-typed python-float weights would
        silently fork the jit cache per dtype and break the
        one-executable-per-key contract ``executable_key`` promises. The
        casts are host-side (pre-dispatch), so the traced hot path always
        sees ``i32/f32`` strong types — which is what the analysis lint
        asserts.
        """
        if self.lq_buckets is None:
            qt = jnp.asarray(q_terms, jnp.int32)
            qw = jnp.asarray(q_weights, jnp.float32)
            return qt, qw, int(qt.shape[-1])
        qt, qw, bucket = bucketize_batch(
            np.asarray(q_terms), np.asarray(q_weights), self.lq_buckets, self.index.n_terms
        )
        return jnp.asarray(qt, jnp.int32), jnp.asarray(qw, jnp.float32), bucket

    def search_batch(self, q_terms: jax.Array, q_weights: jax.Array, rho: Optional[int] = None):
        if self.cfg.engine == "daat":
            if rho is not None:
                raise ValueError(
                    "rho is a SAAT posting budget; the daat engine's cost is "
                    "data-dependent and cannot honor it"
                )
            t0 = self.clock.now()  # bucketize is service cost: keep it timed
            q_terms, q_weights, bucket = self._bucketize(q_terms, q_weights)
            res = self.engine_fn()(q_terms, q_weights)
            jax.block_until_ready(res.scores)
            elapsed = (self.clock.now() - t0) * 1e3
            per_query = elapsed / q_terms.shape[0]
            self._latencies_ms.extend([per_query] * q_terms.shape[0])
            self._rhos.extend([0] * q_terms.shape[0])
            self._observe_bucket_ms(bucket, q_terms.shape[0], elapsed)
            return res
        # an explicit rho must be a real ladder level: `rho or pick_rho()`
        # silently routed rho=0 (any falsy budget) to the controller
        if rho is None:
            rho = self.pick_rho()
        elif rho not in self.rho_ladder:
            raise ValueError(
                f"rho={rho!r} is not a ladder level {self.rho_ladder}; explicit "
                "budgets must hit a pre-compiled executable"
            )
        t0 = self.clock.now()  # bucketize is service cost: keep it timed
        q_terms, q_weights, bucket = self._bucketize(q_terms, q_weights)
        res = self.engine_fn(rho)(q_terms, q_weights)
        jax.block_until_ready(res.scores)
        elapsed = (self.clock.now() - t0) * 1e3
        per_query = elapsed / q_terms.shape[0]
        for _ in range(q_terms.shape[0]):
            self._latencies_ms.append(per_query)
            self._rhos.append(rho)
        self._cost.update(rho, per_query * 1e3)
        self._observe_bucket_ms(bucket, q_terms.shape[0], elapsed, rho=rho)
        return res

    def warmup(
        self,
        q_terms: jax.Array,
        q_weights: jax.Array,
        repeats: int = 2,
        batch_sizes: Optional[Sequence[int]] = None,
    ):
        """Compile + calibrate the executable grid (excluded from stats).

        The grid is (rho-or-engine-config) x (Lq bucket) x (batch size):
        every shape the admission queue can flush is compiled here, so
        serve-time never recompiles. ``batch_sizes`` defaults to the sample's
        own B; the queue passes its flushable shapes.
        """
        sizes = [int(q_terms.shape[0])] if batch_sizes is None else sorted(set(batch_sizes))
        buckets = [int(q_terms.shape[-1])] if self.lq_buckets is None else list(self.lq_buckets)
        qt_np, qw_np = np.asarray(q_terms), np.asarray(q_weights)
        for bucket in buckets:
            if bucket >= qt_np.shape[-1]:
                bt, bw = pad_to_width(qt_np, qw_np, bucket, self.index.n_terms)
            else:
                # slice regardless of live terms: warmup only needs the SHAPE
                # compiled and timed; which terms survive is irrelevant
                bt, bw = qt_np[:, :bucket], qw_np[:, :bucket]
            for B in sizes:
                reps = np.resize(np.arange(qt_np.shape[0]), B)
                qt, qw = jnp.asarray(bt[reps]), jnp.asarray(bw[reps])
                if self.cfg.engine == "daat":
                    for _ in range(repeats):
                        t0 = self.clock.now()
                        jax.block_until_ready(self.engine_fn()(qt, qw).scores)
                        batch_ms = (self.clock.now() - t0) * 1e3
                    self._observe_bucket_ms(bucket, B, batch_ms)
                    continue
                for rho in self.rho_ladder:
                    for _ in range(repeats):
                        t0 = self.clock.now()
                        res = self.engine_fn(rho)(qt, qw)
                        jax.block_until_ready(res.scores)
                        batch_ms = (self.clock.now() - t0) * 1e3
                    self._cost.update(rho, batch_ms * 1e3 / B)
                    # per-rho key: each ladder level is its own executable,
                    # so its wall time must never EMA-mix with another level's
                    self._observe_bucket_ms(bucket, B, batch_ms, rho=rho)

    def stats(self) -> LatencyStats:
        return summarize_latencies(self._latencies_ms)

    def reset_stats(self):
        self._latencies_ms.clear()
        self._rhos.clear()

    def export_counters(self, registry=None):
        """Scrape-time serving counters for this server's dispatch surface.

        Derived from state the server already keeps (query tallies, the
        shape-keyed service-time EMA, the rho cost model) — never touched on
        the hot path. Shares the registry conventions of
        ``AdmissionQueue.export_counters`` / ``repro.serving.counters``.
        """
        from repro.serving.counters import CounterRegistry

        reg = registry if registry is not None else CounterRegistry()
        reg.counter(
            "repro_server_queries_total", "Queries served (per-request rows)"
        ).labels(engine=self.cfg.engine).inc(len(self._latencies_ms))
        cal = reg.gauge(
            "repro_server_calibrated_shapes",
            "Directly measured (bucket, batch-shape, rho) executables",
        )
        cal.labels(engine=self.cfg.engine).set(len(self._bucket_ms))
        ema = reg.gauge(
            "repro_server_service_ms",
            "EMA whole-batch wall ms per (bucket, batch shape, rho) executable",
        )
        for (eng, bucket, shape, rho), ms in sorted(
            self._bucket_ms.items(), key=lambda kv: (kv[0][0], kv[0][1], kv[0][2], str(kv[0][3]))
        ):
            ema.labels(
                engine=eng, bucket=str(bucket), shape=str(shape),
                rho="none" if rho is None else str(rho),
            ).set(ms)
        # index lifecycle: generation is meaningful (0) even for an immutable
        # server; tombstone/delta families only exist on a handle-backed one
        reg.gauge(
            "repro_index_generation",
            "Index lifecycle generation (bumped by each hot-swapped compaction)",
        ).labels(engine=self.cfg.engine).set(self.generation)
        if self.handle is not None:
            reg.gauge(
                "repro_index_tombstones",
                "Deleted/updated docs masked -inf in the main segment",
            ).labels(engine=self.cfg.engine).set(self.handle.tombstone_count)
            reg.gauge(
                "repro_index_delta_docs",
                "Docs pending in the append-only delta segment",
            ).labels(engine=self.cfg.engine).set(self.handle.delta_docs)
        return reg


def run_query_stream(
    server: AnytimeServer,
    q_terms: np.ndarray,  # [N, Lq]
    q_weights: np.ndarray,
    *,
    batch_size: Optional[int] = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Drive a query stream through the server in fixed batches.

    Returns (scores [N, k], doc_ids [N, k]). The final ragged batch is padded
    with repeats (served, then dropped) so every executable sees one shape.
    """
    bs = batch_size or server.cfg.batch_size
    N = q_terms.shape[0]
    out_s, out_i = [], []
    for lo in range(0, N, bs):
        hi = min(lo + bs, N)
        qt = q_terms[lo:hi]
        qw = q_weights[lo:hi]
        if hi - lo < bs:  # pad final batch
            pad = bs - (hi - lo)
            qt = np.concatenate([qt, np.repeat(qt[-1:], pad, 0)])
            qw = np.concatenate([qw, np.repeat(qw[-1:], pad, 0)])
        res = server.search_batch(jnp.asarray(qt), jnp.asarray(qw))
        out_s.append(np.asarray(res.scores)[: hi - lo])
        out_i.append(np.asarray(res.doc_ids)[: hi - lo])
    return np.concatenate(out_s), np.concatenate(out_i)
