"""Pod-scale serving front end: per-host admission over one shared mesh.

``make_pod_serve_step`` (repro.serving.sharded) is the SPMD program: every
rank scores the pod-global query batch against its local doc shard(s) and
joins the id-canonical cross-host k-merge. This module is the *host side* of
that program:

  * :class:`PodServer` — one ingestion host's :class:`AnytimeServer`: the
    same rho ladder / cost model / service-time EMA surface the admission
    queue consumes, but every dispatch embeds the host's local ``[B]`` block
    into the pod-global ``[hosts * B]`` batch (absent hosts' rows are inert
    sentinels — see ``repro.serving.bucketing.sentinel_rows``) and runs the
    pod serve step. A single process therefore simulates any one host of a
    pod faithfully, which is exactly what the
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` CI lane exercises.
  * :class:`PodFrontEnd` — the whole pod in one object: one
    :class:`~repro.serving.queue.AdmissionQueue` per ingestion host, all
    feeding the same mesh, with merged counter export.

Serving counters (``repro.serving.counters``) are derived at scrape time
from the queues' flush logs and the servers' dispatch tallies — the traced
hot path stays pure; nothing under the shard_map ever increments a counter.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.daat import max_blocks_per_term
from repro.core.impact_index import ImpactIndex
from repro.core.index_handle import search_delta_pool
from repro.core.saat import max_segments_per_term
from repro.core.topk import merge_pools_by_id
from repro.metrics.latency import Clock
from repro.serving.bucketing import sentinel_rows
from repro.serving.counters import CounterRegistry
from repro.serving.queue import AdmissionQueue, Completion
from repro.serving.scheduler import AnytimeServer, ServingConfig, index_static_signature
from repro.serving.sharded import make_pod_serve_step


@dataclasses.dataclass(frozen=True)
class PodResult:
    """One host's block of the pod-merged answer (no per-rank WorkStats:
    the merge consumes only the k-pools, so survivor counts never leave
    their rank)."""

    scores: jax.Array  # f32[B, k]
    doc_ids: jax.Array  # i32[B, k]


def pod_hosts(mesh: Mesh) -> int:
    """Number of ingestion hosts = product of the data-group axis sizes."""
    n = 1
    for name in mesh.axis_names:
        if name != "model":
            n *= int(mesh.shape[name])
    return n


class PodServer(AnytimeServer):
    """One ingestion host's anytime server over a pod mesh.

    Inherits the whole queue-facing surface of :class:`AnytimeServer`
    (``pick_rho`` / ``predict_service_ms`` / ``pick_degraded_rho`` /
    ``search_batch`` / ``warmup`` — all keyed on the host's LOCAL batch
    shape), and reroutes the engine dispatch through the pod serve step:

      * ``rho_ladder`` caps at the *per-shard* posting count (the stacked
        index's trailing postings dim), not ``ImpactIndex.n_postings`` —
        which on a stacked index is the shard count. The top level is the
        exact budget: every shard scans all of its postings.
      * ``engine_fn(rho)`` returns a host-side wrapper, not a traceable
        engine: it pads the local block to the pod-global batch, dispatches
        the (jitted) pod step, and slices the host's rows back out. The
        traced hot path is the step's ``serve`` itself — lint it with
        ``repro.analysis.hot_path.lint_sharded_serve`` over
        ``serve_step(rho)``, never ``lint_server``.
    """

    def __init__(
        self,
        mesh: Mesh,
        index_stack: ImpactIndex,
        cfg: ServingConfig,
        *,
        docs_per_shard: int,
        n_docs_total: Optional[int] = None,
        host: int = 0,
        clock: Optional[Clock] = None,
    ):
        super().__init__(index_stack, cfg, clock)
        self.mesh = mesh
        self.n_hosts = pod_hosts(mesh)
        if not (0 <= host < self.n_hosts):
            raise ValueError(f"host={host} outside the pod's {self.n_hosts} hosts")
        self.host = int(host)
        self.docs_per_shard = int(docs_per_shard)
        self.n_docs_total = n_docs_total
        # stacked index: doc_ids is [S, postings_per_shard]; n_postings
        # (= leading dim) is the SHARD count, so rebuild the ladder against
        # the true per-shard exact budget
        exact = int(index_stack.doc_ids.shape[1])
        self.rho_ladder = tuple(sorted({min(r, exact) for r in cfg.rho_ladder} | {exact}))
        self._steps: dict[Optional[int], object] = {}
        self._jitted: dict[Optional[int], object] = {}
        self.n_pod_dispatches: dict[tuple[str, Optional[int]], int] = {}
        # index lifecycle at pod scale: a per-shard tombstone stack rides the
        # live-masked serve step; the (corpus-global) delta pool is searched
        # host-side and merged by gid AFTER the pod k-merge hands back this
        # host's rows — the delta never crosses the ICI
        self._live_stack: Optional[jax.Array] = None
        self._delta_index: Optional[ImpactIndex] = None
        self._delta_gids: Optional[jax.Array] = None

    # --------------------------- index lifecycle ---------------------------

    def set_lifecycle(
        self,
        *,
        live_stack=None,
        delta: Optional[ImpactIndex] = None,
        delta_gids=None,
        generation: Optional[int] = None,
        decay: float = 0.5,
    ):
        """Install (or clear) this host's view of the mutable corpus.

        ``live_stack`` is the per-shard tombstone bitmap
        (:func:`repro.serving.sharded.shard_live_stack`); ``delta`` +
        ``delta_gids`` the pending-docs segment with its local->gid map.
        Toggling the live mask on or off switches between the masked and
        unmasked serve-step programs, so the step cache is dropped on that
        edge (same-program updates — new mask values, a changed delta — keep
        every compiled step). A ``generation`` bump decays — never discards —
        the calibration, exactly like :meth:`AnytimeServer.swap_index`.
        """
        if (delta is None) != (delta_gids is None):
            raise ValueError("delta and delta_gids must be set (or cleared) together")
        was_masked = self._live_stack is not None
        self._live_stack = None if live_stack is None else jnp.asarray(live_stack, jnp.int32)
        if (self._live_stack is not None) != was_masked:
            self._steps.clear()
            self._jitted.clear()
        self._delta_index = delta
        self._delta_gids = None if delta_gids is None else jnp.asarray(delta_gids, jnp.int32)
        if generation is not None and generation != self.generation:
            self.generation = int(generation)
            self._decay_calibration(decay)

    def swap_stack(
        self,
        index_stack: ImpactIndex,
        *,
        live_stack=None,
        delta: Optional[ImpactIndex] = None,
        delta_gids=None,
        generation: Optional[int] = None,
        decay: float = 0.5,
        docs_per_shard: Optional[int] = None,
        n_docs_total: Optional[int] = None,
    ):
        """Hot-swap a recompacted shard stack between admission-queue flushes.

        Rebinds the stacked index and its build-time bounds, rebuilds the
        per-shard rho ladder, drops the compiled step cache (the stack's
        shapes/bounds are baked into every step), and installs the new
        lifecycle state. A compaction usually changes the shard geometry
        (docs fold out, the gid space grows), so pass the new
        ``docs_per_shard`` / ``n_docs_total`` from the re-shard alongside the
        stack. Calibration survives decayed, not discarded.
        """
        if docs_per_shard is not None:
            self.docs_per_shard = int(docs_per_shard)
        if n_docs_total is not None:
            self.n_docs_total = int(n_docs_total)
        self.index = index_stack
        self.max_segs = max_segments_per_term(index_stack)
        self.max_bm = max_blocks_per_term(index_stack)
        exact = int(index_stack.doc_ids.shape[1])
        self.rho_ladder = tuple(
            sorted({min(r, exact) for r in self.cfg.rho_ladder} | {exact})
        )
        self._steps.clear()
        self._jitted.clear()
        gen = generation if generation is not None else self.generation + 1
        self.set_lifecycle(
            live_stack=live_stack, delta=delta, delta_gids=delta_gids,
            generation=gen, decay=decay,
        )

    # ------------------------- pod step plumbing ---------------------------

    def serve_step(self, rho: Optional[int] = None):
        """The raw pod serve step for one SAAT ladder level (or DAAT).

        This is the traced hot path behind ``engine_fn`` — what the analysis
        lint matrix traces, and what carries ``.statics`` (including
        ``merge_fanin``, the pod's candidates-per-merge).
        """
        key = self._rho_key(rho)
        if key not in self._steps:
            cfg = self.cfg
            serve, _, _ = make_pod_serve_step(
                self.mesh,
                k=cfg.k,
                rho_per_shard=self.rho_ladder[-1] if key is None else key,
                max_segs_per_term=self.max_segs,
                docs_per_shard=self.docs_per_shard,
                scatter_impl=cfg.scatter_impl,
                fused_topk=cfg.fused_topk,
                engine=cfg.engine,
                daat_est_blocks=cfg.daat_est_blocks,
                daat_block_budget=cfg.daat_block_budget,
                max_bm_per_term=self.max_bm if cfg.engine == "daat" else 0,
                daat_exact=cfg.daat_exact,
                daat_use_kernels=cfg.daat_use_kernels,
                daat_fused_chunk=cfg.daat_fused_chunk,
                daat_trips_per_launch=cfg.daat_trips_per_launch,
                n_docs_total=self.n_docs_total,
                live_masked=self._live_stack is not None,
            )
            self._steps[key] = serve
            # ImpactIndex is a registered-dataclass pytree: the stack rides
            # along as an operand, so one compiled program per (B, Lq) shape
            self._jitted[key] = jax.jit(serve)
        return self._steps[key]

    def _pod_dispatch(self, qt, qw, rho: Optional[int]) -> PodResult:
        key = self._rho_key(rho)
        self.serve_step(rho)  # ensure built
        qt = np.asarray(qt, dtype=np.int32)
        qw = np.asarray(qw, dtype=np.float32)
        B, width = qt.shape
        gqt, gqw = sentinel_rows(self.n_hosts * B, width, self.index.n_terms)
        gqt[self.host * B : (self.host + 1) * B] = qt
        gqw[self.host * B : (self.host + 1) * B] = qw
        if self._live_stack is not None:
            scores, ids = self._jitted[key](
                self.index, jnp.asarray(gqt, jnp.int32), jnp.asarray(gqw, jnp.float32),
                live_stack=self._live_stack,
            )
        else:
            scores, ids = self._jitted[key](
                self.index, jnp.asarray(gqt, jnp.int32), jnp.asarray(gqw, jnp.float32)
            )
        self.n_pod_dispatches[(self.cfg.engine, key)] = (
            self.n_pod_dispatches.get((self.cfg.engine, key), 0) + 1
        )
        lo, hi = self.host * B, (self.host + 1) * B
        scores, ids = scores[lo:hi], ids[lo:hi]
        if self._delta_index is not None:
            # host-local freshness merge: the pending-docs pool is searched
            # exactly on this host (it never crosses the ICI) and merged by
            # gid with the pod answer — same canonical merge the single-host
            # IndexHandle uses, so ties still resolve ascending-gid
            ds, dlocal = search_delta_pool(
                self._delta_index, jnp.asarray(qt, jnp.int32),
                jnp.asarray(qw, jnp.float32), k=self.cfg.k,
                engine=self.cfg.engine, scatter_impl=self.cfg.scatter_impl,
                fused_topk=self.cfg.fused_topk,
            )
            dgids = self._delta_gids[dlocal]
            scores, ids = merge_pools_by_id(scores, ids, ds, dgids, self.cfg.k)
        return PodResult(scores=scores, doc_ids=ids)

    # ------------------------ AnytimeServer overrides ----------------------

    def engine_fn(self, rho: Optional[int] = None):
        if self.cfg.engine == "daat":
            return self._daat_search
        if rho is None:
            rho = self.rho_ladder[-1]

        def fn(qt, qw, _rho=rho):
            return self._pod_dispatch(qt, qw, _rho)

        return fn

    def _daat_search(self, q_terms, q_weights):
        return self._pod_dispatch(q_terms, q_weights, None)

    def executable_key(
        self, lq_bucket: int, batch_size: int, rho: Optional[int] = None
    ) -> tuple:
        # the pod program differs from the single-host engine at equal
        # engine statics (collectives, shard layout), and its batch is
        # hosts * B wide — fold the pod identity AND the lifecycle state's
        # static surface (mask presence, delta shapes) into the key; the
        # generation counter itself stays out for the same reason as in
        # AnytimeServer.executable_key
        base = super().executable_key(lq_bucket, batch_size, rho)
        lifecycle = (
            "live" if self._live_stack is not None else None,
            None if self._delta_index is None
            else index_static_signature(self._delta_index),
        )
        return ("pod", self.n_hosts, int(self.mesh.shape["model"]),
                self.docs_per_shard, self.n_docs_total) + lifecycle + base

    # ----------------------------- counters --------------------------------

    def export_counters(self, registry: Optional[CounterRegistry] = None) -> CounterRegistry:
        """Scrape-time serving counters for this host's dispatch path."""
        reg = registry if registry is not None else CounterRegistry()
        host = str(self.host)
        disp = reg.counter(
            "repro_pod_dispatch_total",
            "Pod serve-step dispatches by host, engine and served rho",
        )
        for (engine, rho), n in sorted(
            self.n_pod_dispatches.items(), key=lambda kv: (kv[0][0], str(kv[0][1]))
        ):
            disp.labels(host=host, engine=engine, rho="none" if rho is None else str(rho)).inc(n)
        fanin = reg.gauge(
            "repro_pod_merge_fanin",
            "Candidates entering the cross-host k-merge (ranks * k)",
        )
        for key, serve in self._steps.items():
            fanin.labels(
                host=host, rho="none" if key is None else str(key)
            ).set(serve.statics["merge_fanin"])
        return reg


class PodFrontEnd:
    """The whole pod on one process: per-host admission queues, one mesh.

    Each ingestion host gets its own :class:`PodServer` (host ``h`` embeds
    its flushes at block ``h`` of the pod batch) and its own
    :class:`AdmissionQueue` over that server — per-host admission is the
    deployment shape the paper's traffic claim needs, and simulating every
    host in one process is what lets the CI pod lane drive it end to end.
    """

    def __init__(
        self,
        mesh: Mesh,
        index_stack: ImpactIndex,
        cfg: ServingConfig,
        *,
        docs_per_shard: int,
        n_docs_total: Optional[int] = None,
        clock: Optional[Clock] = None,
        queue_kwargs: Optional[dict] = None,
    ):
        self.mesh = mesh
        self.n_hosts = pod_hosts(mesh)
        self.servers = [
            PodServer(
                mesh, index_stack, cfg,
                docs_per_shard=docs_per_shard, n_docs_total=n_docs_total,
                host=h, clock=clock,
            )
            for h in range(self.n_hosts)
        ]
        qkw = dict(queue_kwargs or {})
        self.queues = [AdmissionQueue(srv, **qkw) for srv in self.servers]

    def submit(self, host: int, q_terms, q_weights, deadline_ms: Optional[float] = None) -> int:
        return self.queues[host].submit(q_terms, q_weights, deadline_ms)

    def poll(self) -> list[tuple[int, Completion]]:
        out: list[tuple[int, Completion]] = []
        for h, q in enumerate(self.queues):
            out.extend((h, c) for c in q.poll())
        return out

    def drain(self) -> list[tuple[int, Completion]]:
        out: list[tuple[int, Completion]] = []
        for h, q in enumerate(self.queues):
            out.extend((h, c) for c in q.drain())
        return out

    def pending(self) -> int:
        return sum(q.pending() for q in self.queues)

    def set_lifecycle(self, **kwargs):
        """Install lifecycle state (tombstone stack / delta pool) on every
        host's server; see :meth:`PodServer.set_lifecycle`."""
        for srv in self.servers:
            srv.set_lifecycle(**kwargs)
        if kwargs.get("generation") is not None:
            for q in self.queues:
                q.survivors.decay(kwargs.get("decay", 0.5))

    def swap_stack(self, index_stack: ImpactIndex, **kwargs):
        """Hot-swap a recompacted shard stack on every host between flushes;
        pending requests ride (see :meth:`AdmissionQueue.swap_index` for the
        zero-loss argument — the same one applies per host queue)."""
        for srv in self.servers:
            srv.swap_stack(index_stack, **kwargs)
        for q in self.queues:
            q.survivors.decay(kwargs.get("decay", 0.5))

    def export_counters(self, registry: Optional[CounterRegistry] = None) -> CounterRegistry:
        reg = registry if registry is not None else CounterRegistry()
        for h, (srv, q) in enumerate(zip(self.servers, self.queues)):
            q.export_counters(reg, labels={"host": str(h)})
            srv.export_counters(reg)
        return reg


def warmup_pod(
    front: PodFrontEnd,
    q_terms,
    q_weights,
    *,
    batch_sizes: Optional[Sequence[int]] = None,
    repeats: int = 1,
):
    """Warm every host's executable grid (hosts share compiled programs
    only per-(host-block) — each host's embedding is a distinct operand
    layout of the SAME jitted step, so warming host 0 compiles for all)."""
    for srv in front.servers:
        srv.warmup(q_terms, q_weights, repeats=repeats, batch_sizes=batch_sizes)
