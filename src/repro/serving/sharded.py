"""Doc-sharded SAAT retrieval: the paper's serve step at pod scale.

Documents are partitioned into ``n_shards`` equal ranges over the ``model``
mesh axis; each chip owns the full impact index *of its shard* and runs the
identical rho-budgeted SAAT scan. Only the k finalists cross the ICI
(``k * 8`` bytes per shard vs ``n_docs * 4`` for accumulator exchange).
Queries batch over the data axes.

Why this is the right scale-out for the paper's technique:
  * per-chip work is rho_per_shard postings — *identical by construction*
    across chips, so corpus skew cannot create stragglers (the paper's
    predictable-latency claim, promoted to a cluster property);
  * a lost pod/chip shrinks the corpus coverage but never blocks the merge
    (elastic serving; repro.distributed.elastic).

``stack_indexes`` packs per-shard indexes into one pytree with a leading
shard axis (sharded over ``model``); ``abstract_stacked_index`` builds the
same as ShapeDtypeStructs for the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.daat import daat_search_batched
from repro.core.impact_index import ImpactIndex, META_FIELDS as _META_FIELDS, build_impact_index
from repro.core.quantization import QuantConfig
from repro.core.saat import saat_search
from repro.core.topk import NEG_INF, canonical_topk_merge, merge_topk
from repro.distributed.sharding import mesh_axes


# --------------------------------------------------------------------------
# shard construction (host side)
# --------------------------------------------------------------------------


def shard_corpus(
    doc_idx: np.ndarray,
    term_idx: np.ndarray,
    weights: np.ndarray,
    n_docs: int,
    n_terms: int,
    n_shards: int,
    **build_kwargs,
) -> tuple[list[ImpactIndex], int]:
    """Split a COO corpus into per-shard impact indexes (equal doc ranges).

    All shards quantize against the GLOBAL max weight so their impact grids
    (and therefore merged scores) are identical to a global index's. Pass an
    explicit ``quant_max_weight`` to pin a different grid — re-sharding a
    compacted :class:`~repro.core.index_handle.IndexHandle` must reuse the
    handle's pinned grid, not re-derive one from the folded (mid-step)
    weights' max.
    """
    docs_per_shard = -(-n_docs // n_shards)
    global_max = build_kwargs.pop(
        "quant_max_weight", float(np.max(weights)) if len(weights) else 1.0
    )
    shards = []
    for s in range(n_shards):
        lo, hi = s * docs_per_shard, min((s + 1) * docs_per_shard, n_docs)
        m = (doc_idx >= lo) & (doc_idx < hi)
        shards.append(
            build_impact_index(
                doc_idx[m] - lo, term_idx[m], weights[m], docs_per_shard, n_terms,
                quant_max_weight=global_max, **build_kwargs
            )
        )
    return shards, docs_per_shard


def _pad_cat(arrs: Sequence[np.ndarray], fill) -> np.ndarray:
    n = max(a.shape[0] for a in arrs)
    out = np.full((len(arrs), n) + arrs[0].shape[1:], fill, dtype=arrs[0].dtype)
    for i, a in enumerate(arrs):
        out[i, : a.shape[0]] = a
    return out


def shard_live_stack(
    live_full: np.ndarray,
    *,
    n_shards: int,
    docs_per_shard: int,
    n_docs_pad: int,
) -> np.ndarray:
    """Slice a global live bitmap into the per-shard tombstone stack.

    ``live_full`` is the corpus-wide i32/bool bitmap over global doc ids
    (e.g. ``IndexHandle.live_mask_full()``); the result is
    ``i32[n_shards, n_docs_pad]`` — shard ``s`` holds gids
    ``[s * docs_per_shard, (s+1) * docs_per_shard)``, trailing pad slots
    (block padding, and the short final shard's tail) forced dead so a pad
    doc can never out-compete a real one inside the engines' masked scans.
    ``n_docs_pad`` is the per-shard DOC pad — the engines' accumulator
    length, i.e. ``index_stack.doc_n_terms.shape[1]`` of the stacked index
    (NOT the posting-store width).
    Partition it over the same axes as the index stack and hand it to a
    ``live_masked=True`` serve step.
    """
    if n_docs_pad < docs_per_shard:
        raise ValueError(
            f"n_docs_pad={n_docs_pad} smaller than docs_per_shard={docs_per_shard}"
        )
    live_full = np.asarray(live_full).astype(np.int32).ravel()
    out = np.zeros((n_shards, n_docs_pad), np.int32)
    for s in range(n_shards):
        lo = s * docs_per_shard
        hi = min(lo + docs_per_shard, live_full.shape[0])
        if hi > lo:
            out[s, : hi - lo] = live_full[lo:hi]
    return out


def stack_indexes(shards: list[ImpactIndex]) -> ImpactIndex:
    """Stack per-shard indexes on a new leading axis (ragged -> padded).

    Static metadata comes from shard 0 (shards are built with identical
    corpus-level constants); per-term CSR tables are padded per shard.
    """
    fields = [f.name for f in dataclasses.fields(ImpactIndex)]
    data_fields = [f for f in fields if f not in _META_FIELDS]
    stacked = {}
    for f in data_fields:
        if f in ("doc_terms", "doc_weights"):
            continue  # ragged in BOTH dims; re-padded below
        arrs = [np.asarray(jax.device_get(getattr(s, f))) for s in shards]
        fill = 0
        stacked[f] = jnp.asarray(_pad_cat(arrs, fill))
    # shard-invariant meta comes from shard 0; size-like bounds take the max
    _RAGGED_META = ("max_doc_terms", "max_segs", "max_bm")
    meta = {k: getattr(shards[0], k) for k in _META_FIELDS if k not in _RAGGED_META}
    for k in _RAGGED_META:
        meta[k] = max(getattr(s, k) for s in shards)
    # re-pad doc-major stores to a common Tmax
    tmax = meta["max_doc_terms"]
    dts = [np.asarray(jax.device_get(s.doc_terms)) for s in shards]
    dws = [np.asarray(jax.device_get(s.doc_weights)) for s in shards]
    nd = max(a.shape[0] for a in dts)
    dt = np.full((len(shards), nd, tmax), shards[0].n_terms, dtype=np.int32)
    dw = np.zeros((len(shards), nd, tmax), dtype=np.float32)
    for i, (a, b) in enumerate(zip(dts, dws)):
        dt[i, : a.shape[0], : a.shape[1]] = a
        dw[i, : b.shape[0], : b.shape[1]] = b
    stacked["doc_terms"] = jnp.asarray(dt)
    stacked["doc_weights"] = jnp.asarray(dw)
    return ImpactIndex(**stacked, **meta)


def abstract_stacked_index(
    *,
    n_shards: int,
    docs_per_shard: int,
    n_terms: int,
    postings_per_shard: int,
    segments_per_shard: int,
    bm_cells_per_shard: int,
    max_doc_terms: int,
    block_size: int = 128,
) -> ImpactIndex:
    """ShapeDtypeStruct stacked index for the dry-run (no allocation)."""
    S = n_shards
    f32 = jnp.float32
    i32 = jnp.int32

    def sds(shape, dt):
        return jax.ShapeDtypeStruct(shape, dt)

    n_docs_pad = -(-docs_per_shard // block_size) * block_size
    n_blocks = n_docs_pad // block_size
    return ImpactIndex(
        doc_ids=sds((S, postings_per_shard), i32),
        seg_term=sds((S, segments_per_shard), i32),
        seg_weight=sds((S, segments_per_shard), f32),
        seg_start=sds((S, segments_per_shard), i32),
        seg_len=sds((S, segments_per_shard), i32),
        term_seg_start=sds((S, n_terms + 1), i32),
        term_seg_count=sds((S, n_terms + 1), i32),
        term_post_count=sds((S, n_terms + 1), i32),
        term_max_weight=sds((S, n_terms + 1), f32),
        bm_block=sds((S, bm_cells_per_shard), i32),
        bm_weight=sds((S, bm_cells_per_shard), f32),
        term_bm_start=sds((S, n_terms + 1), i32),
        term_bm_count=sds((S, n_terms + 1), i32),
        doc_terms=sds((S, n_docs_pad, max_doc_terms), i32),
        doc_weights=sds((S, n_docs_pad, max_doc_terms), f32),
        doc_n_terms=sds((S, n_docs_pad), i32),
        doc_weight_sum=sds((S, n_docs_pad), f32),
        n_docs=docs_per_shard,
        n_terms=n_terms,
        n_blocks=n_blocks,
        block_size=block_size,
        max_doc_terms=max_doc_terms,
        scale=1.0,
        bits=8,
    )


# --------------------------------------------------------------------------
# the sharded serve step
# --------------------------------------------------------------------------


def _validate_engine_cfg(
    engine: str,
    max_bm_per_term: int,
    daat_use_kernels: bool,
    daat_fused_chunk: bool,
    daat_trips_per_launch: int,
):
    if engine not in ("saat", "daat"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine == "daat" and max_bm_per_term <= 0:
        raise ValueError("engine='daat' needs the static max_bm_per_term bound")
    if daat_fused_chunk and not daat_use_kernels:
        raise ValueError(
            "daat_fused_chunk fuses the kernel-mode chunk step; pass "
            "daat_use_kernels=True"
        )
    if daat_trips_per_launch < 1:
        raise ValueError(
            f"daat_trips_per_launch={daat_trips_per_launch} must be >= 1"
        )
    if daat_trips_per_launch > 1 and not daat_fused_chunk:
        raise ValueError(
            "daat_trips_per_launch > 1 batches trips inside the fused "
            "chunk_step kernel; pass daat_fused_chunk=True (and "
            "daat_use_kernels=True)"
        )


def _scan_local_shards(
    idx_data: dict, qt, qw, *, shard_ord0, st: dict, meta_cell: dict, live=None
):
    """Search every doc shard resident on this rank; merge their k-pools.

    Runs inside ``shard_map``. ``shard_ord0`` is this rank's flat position in
    the shard partition order (the leading shard axis is laid out
    major-to-minor along the partition spec, so consecutive flat ranks own
    consecutive shard ranges); each local shard ``j`` is global shard
    ``shard_ord0 * n_local + j``. Pad documents (block-padding slots, and —
    on a short final shard — ids past the corpus end) are demoted to
    ``(NEG_INF, INT32_MAX)`` *before* globalization so they can never alias
    a real doc id in a later shard's range. ``live`` is the optional
    per-shard tombstone stack ``i32[n_local, n_docs_pad]`` (same leading
    order as ``idx_data``'s shard rows): shard ``j``'s row
    rides the engines' ``live_mask`` paths, so deleted docs score ``-inf``
    inside the budgeted scan itself (never reaching the pool) rather than
    being filtered after the fact. Returns the rank's merged ``(scores,
    gids)`` candidate pool, ``[B, k]``.
    """
    n_local = jax.tree.leaves(idx_data)[0].shape[0]
    docs_per_shard = st["docs_per_shard"]
    pool_s = pool_i = None
    for j in range(n_local):
        local = jax.tree.map(lambda x, _j=j: x[_j], idx_data)
        index = ImpactIndex(
            **local, **_static_meta_from(local, docs_per_shard, meta_cell)
        )
        lv = live[j] if live is not None else None
        if st["engine"] == "daat":
            res = daat_search_batched(
                index,
                qt,
                qw,
                k=st["k"],
                est_blocks=st["daat_est_blocks"],
                block_budget=st["daat_block_budget"],
                max_bm_per_term=st["max_bm_per_term"],
                exact=st["daat_exact"],
                use_kernels=st["daat_use_kernels"],
                fused_chunk=st["daat_fused_chunk"],
                trips_per_launch=st["daat_trips_per_launch"],
                live_mask=lv,
            )
        else:
            res = saat_search(
                index,
                qt,
                qw,
                k=st["k"],
                rho=st["rho_per_shard"],
                max_segs_per_term=st["max_segs_per_term"],
                scatter_impl=st["scatter_impl"],
                fused_topk=st["fused_topk"],
                live_mask=lv,
            )
        shard_ord = shard_ord0 * n_local + j
        if st["n_docs_total"] is None:
            n_live = jnp.int32(docs_per_shard)
        else:
            n_live = jnp.clip(
                st["n_docs_total"] - shard_ord * docs_per_shard, 0, docs_per_shard
            ).astype(jnp.int32)
        pad = res.doc_ids >= n_live
        scores = jnp.where(pad, NEG_INF, res.scores)
        gids = jnp.where(
            pad,
            jnp.iinfo(jnp.int32).max,
            res.doc_ids + shard_ord * docs_per_shard,
        )
        if pool_s is None:
            pool_s, pool_i = scores, gids
        else:
            pool_s, pool_i = merge_topk(pool_s, pool_i, scores, gids, st["k"])
    return pool_s, pool_i


def make_sharded_serve_step(
    mesh: Mesh,
    *,
    k: int,
    rho_per_shard: int,
    max_segs_per_term: int,
    docs_per_shard: int,
    scatter_impl: str = "sort",
    fused_topk: bool = False,
    engine: str = "saat",
    daat_est_blocks: int = 8,
    daat_block_budget: int = 16,
    max_bm_per_term: int = 0,
    daat_exact: bool = True,
    daat_use_kernels: bool = False,
    daat_fused_chunk: bool = False,
    daat_trips_per_launch: int = 1,
    n_docs_total: Optional[int] = None,
    live_masked: bool = False,
):
    """Builds ``serve(index_stack, q_terms, q_weights) -> (scores, ids)``.

    Inside ``shard_map``: every model-rank runs the identical rho-budgeted
    SAAT over its local doc shard, globalizes ids by its shard offset, then
    merges finalists with a k-sized all-gather over ``model``. Data axes
    carry the query batch; each rank's local batch executes the natively
    batched engine (one plan sort / gather / scatter for the whole block),
    so the per-chip instruction stream stays identical across ranks AND
    independent of batch composition.

    ``engine="daat"`` swaps in the natively batched Block-Max engine per
    shard (``rho_per_shard`` is then unused; pass the STATIC
    ``max_bm_per_term`` bound from the stacked index's build-time metadata).
    Per-chip work becomes data-dependent — each rank loops until its own
    local batch is rank-safe — so corpus skew CAN create stragglers, which
    is exactly the contrast with SAAT the paper draws.

    ``fused_topk=True`` makes every rank's SAAT scan emit only its
    ``[B, blocks * k]`` candidate pool from VMEM (the per-shard accumulator
    never reaches HBM) before the cross-shard k-merge; ``daat_use_kernels``
    routes each rank's DAAT phase 2 through the batched Pallas kernels, and
    ``daat_fused_chunk`` collapses each rank's per-trip select+score+merge
    into the single VMEM-resident ``chunk_step`` kernel (per-trip HBM traffic
    on every rank drops to the candidate/state output only).

    ``n_docs_total`` (the UNSHARDED corpus size) bounds the live doc range of
    every shard: block-padding slots and — on a short final shard — doc ids
    past the corpus end are masked to ``(NEG_INF, INT32_MAX)`` before their
    local ids are globalized, so a pad doc can never alias a real document in
    a later shard's id range. Omitting it still masks the per-shard block
    padding (ids ``>= docs_per_shard``) but assumes every shard is full.

    ``live_masked=True`` builds the *lifecycle* variant of the step: ``serve``
    then requires a ``live_stack`` — the per-shard tombstone bitmap
    ``i32[n_shards, n_docs_pad]`` (see :func:`shard_live_stack`), laid out in
    the SAME leading shard order as the index stack and placed on the mesh
    the same way — and every rank threads its shard's row through the
    engines' ``live_mask`` paths. The flag is a
    constructor static (mirrored in ``serve.statics``) because masked and
    unmasked dispatches are genuinely different traced programs: one serve
    step is always exactly one program per batch shape, which is the
    invariant the hot-path lint keys on.
    """
    _validate_engine_cfg(
        engine, max_bm_per_term, daat_use_kernels, daat_fused_chunk,
        daat_trips_per_launch,
    )
    axes = mesh_axes(mesh)
    dp = axes.data if len(axes.data) > 1 else axes.data[0]
    idx_specs = jax.tree.map(lambda _: P("model"), _index_data_template())
    if live_masked:
        # The live stack rides with the index stack: the idx specs replicate
        # the stacked arrays onto every rank (each rank scans all local rows
        # and globalizes by its own shard_ord), so the live rows must be
        # replicated too — a partitioned spec would desynchronize live[j]
        # from idx_data[...][j].
        in_specs = (idx_specs, P(), P(dp, None), P(dp, None))
    else:
        in_specs = (idx_specs, P(dp, None), P(dp, None))
    out_specs = (P(dp, None), P(dp, None))

    # Real static metadata of the caller's index_stack (block_size, quant
    # scale/bits, seg/bm bounds). `serve()` fills it before tracing so every
    # per-shard reconstruction inside the shard_map carries the true build
    # constants instead of hardcoded defaults; a direct `sm(...)` call on a
    # bare data dict falls back to the historical defaults.
    meta_cell: dict = {}

    # Static surface of this serve step, exposed for repro.analysis.hot_path:
    # the lint traces `serve` at each (bucket, B) shape and keys executables
    # on exactly this dict plus the shape. Keep it the full closure config —
    # a knob missing here is a knob the one-executable-per-key check can't
    # see. The same dict feeds `_scan_local_shards` under the trace.
    statics = dict(
        engine=engine, k=k, rho_per_shard=rho_per_shard,
        max_segs_per_term=max_segs_per_term, docs_per_shard=docs_per_shard,
        scatter_impl=scatter_impl, fused_topk=fused_topk,
        daat_est_blocks=daat_est_blocks, daat_block_budget=daat_block_budget,
        max_bm_per_term=max_bm_per_term, daat_exact=daat_exact,
        daat_use_kernels=daat_use_kernels, daat_fused_chunk=daat_fused_chunk,
        daat_trips_per_launch=daat_trips_per_launch, n_docs_total=n_docs_total,
        live_masked=live_masked,
    )

    def body(idx_data: dict, qt, qw):
        # the block may hold SEVERAL shards when n_shards > model-axis size
        # (multiple doc ranges per chip): search each, merge locally, then
        # k-merge across chips
        rank = jax.lax.axis_index("model").astype(jnp.int32)
        pool_s, pool_i = _scan_local_shards(
            idx_data, qt, qw, shard_ord0=rank, st=statics, meta_cell=meta_cell
        )
        return canonical_topk_merge(pool_s, pool_i, k, "model")

    def body_live(idx_data: dict, live, qt, qw):
        rank = jax.lax.axis_index("model").astype(jnp.int32)
        pool_s, pool_i = _scan_local_shards(
            idx_data, qt, qw, shard_ord0=rank, st=statics, meta_cell=meta_cell,
            live=live,
        )
        return canonical_topk_merge(pool_s, pool_i, k, "model")

    sm = shard_map(
        body_live if live_masked else body,
        mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False,
    )

    def serve(index_stack: ImpactIndex, q_terms, q_weights, live_stack=None):
        meta_cell.clear()
        meta_cell.update(
            block_size=index_stack.block_size,
            scale=index_stack.scale,
            bits=index_stack.bits,
            max_segs=index_stack.max_segs,
            max_bm=index_stack.max_bm,
        )
        data = _index_data_dict(index_stack)
        if live_masked:
            if live_stack is None:
                raise ValueError(
                    "this serve step was built live_masked=True; pass the "
                    "per-shard live_stack (see shard_live_stack)"
                )
            return sm(data, jnp.asarray(live_stack, jnp.int32), q_terms, q_weights)
        if live_stack is not None:
            raise ValueError(
                "live_stack passed to a serve step built without "
                "live_masked=True; rebuild the step with live_masked=True"
            )
        return sm(data, q_terms, q_weights)

    serve.statics = statics
    return serve, in_specs, out_specs


def make_pod_serve_step(
    mesh: Mesh,
    *,
    k: int,
    rho_per_shard: int,
    max_segs_per_term: int,
    docs_per_shard: int,
    scatter_impl: str = "sort",
    fused_topk: bool = False,
    engine: str = "saat",
    daat_est_blocks: int = 8,
    daat_block_budget: int = 16,
    max_bm_per_term: int = 0,
    daat_exact: bool = True,
    daat_use_kernels: bool = False,
    daat_fused_chunk: bool = False,
    daat_trips_per_launch: int = 1,
    n_docs_total: Optional[int] = None,
    live_masked: bool = False,
):
    """Multi-host pod serve: every host's query block, every rank's shard.

    The mesh carries a ``"pod"`` axis (one position per ingestion host) in
    the data group alongside the ``"model"`` axis; the stacked index's
    leading shard axis is partitioned over *all* mesh axes pod-major, so the
    whole pod is one document-sharded replica set. Each host contributes its
    own ``B_local`` admission block (query in_spec shards the batch over the
    data group); inside the step every rank

      1. all-gathers the query blocks over the data group — the global
         ``[hosts * B_local, Lq]`` batch, identical on every rank, so every
         query is answered by every shard;
      2. runs the engine over its local shard(s) via the shared
         ``_scan_local_shards`` (identical rho-budgeted work per rank for
         SAAT — the paper's no-straggler property, now pod-wide);
      3. joins the rank-safe cross-host k-merge: per-rank ``[B_glob, k]``
         candidate pools are gathered over ``("pod", ..., "model")`` at once
         and re-selected with the id-canonical :func:`canonical_topk_merge`
         (``tiled_topk`` over ``ranks * k`` candidates — ties and pad
         sentinels resolve identically to the unsharded oracle no matter the
         host/shard layout);
      4. hands back its own host's ``B_local`` rows, so results land on the
         host that admitted the queries.

    Returns ``(serve, in_specs, out_specs)`` like
    :func:`make_sharded_serve_step`; the caller's query batch is the
    concatenation of all hosts' blocks (``hosts * B_local`` rows, pod-major)
    — :class:`repro.serving.pod.PodServer` assembles it from one host's
    admission queue plus inert sentinel rows for the absent hosts.
    """
    _validate_engine_cfg(
        engine, max_bm_per_term, daat_use_kernels, daat_fused_chunk,
        daat_trips_per_launch,
    )
    if "pod" not in mesh.axis_names:
        raise ValueError(
            f"pod serve step needs a 'pod' mesh axis, got {mesh.axis_names}"
        )
    if "model" not in mesh.axis_names:
        raise ValueError(
            f"pod serve step needs a 'model' mesh axis, got {mesh.axis_names}"
        )
    axes = mesh_axes(mesh)
    data_axes = tuple(axes.data)  # every non-"model" axis, "pod" included
    dp = data_axes if len(data_axes) > 1 else data_axes[0]
    shard_axes = data_axes + ("model",)
    idx_specs = jax.tree.map(lambda _: P(shard_axes), _index_data_template())
    if live_masked:
        # the tombstone stack rides replicated exactly like the index stack
        # (idx specs replicate the stacked rows onto every rank), so
        # rank-local shard j always meets its own mask row live[j]
        in_specs = (idx_specs, P(), P(dp, None), P(dp, None))
    else:
        in_specs = (idx_specs, P(dp, None), P(dp, None))
    out_specs = (P(dp, None), P(dp, None))
    data_sizes = tuple(int(mesh.shape[name]) for name in data_axes)
    n_hosts = 1
    for s in data_sizes:
        n_hosts *= s
    n_model = int(mesh.shape["model"])
    meta_cell: dict = {}

    statics = dict(
        engine=engine, k=k, rho_per_shard=rho_per_shard,
        max_segs_per_term=max_segs_per_term, docs_per_shard=docs_per_shard,
        scatter_impl=scatter_impl, fused_topk=fused_topk,
        daat_est_blocks=daat_est_blocks, daat_block_budget=daat_block_budget,
        max_bm_per_term=max_bm_per_term, daat_exact=daat_exact,
        daat_use_kernels=daat_use_kernels, daat_fused_chunk=daat_fused_chunk,
        daat_trips_per_launch=daat_trips_per_launch, n_docs_total=n_docs_total,
        # pod identity: same engine statics on a different mesh is a
        # DIFFERENT executable (different collectives), and the merge fan-in
        # is the serving counter the host side reports per dispatch
        pod_axes=shard_axes, pod_hosts=n_hosts, pod_model_ranks=n_model,
        merge_fanin=n_hosts * n_model * k,
        live_masked=live_masked,
    )

    def body(idx_data: dict, qt, qw, live=None):
        # flat position of this rank's host in the data group — the same
        # major-to-minor order P(shard_axes) partitions the shard axis in,
        # so host blocks, shard ranges, and gather order all agree
        drank = jnp.int32(0)
        for name, size in zip(data_axes, data_sizes):
            drank = drank * size + jax.lax.axis_index(name).astype(jnp.int32)
        mrank = jax.lax.axis_index("model").astype(jnp.int32)
        b_local = qt.shape[0]
        qt_g = jax.lax.all_gather(qt, data_axes, axis=0, tiled=True)
        qw_g = jax.lax.all_gather(qw, data_axes, axis=0, tiled=True)
        pool_s, pool_i = _scan_local_shards(
            idx_data, qt_g, qw_g,
            shard_ord0=drank * n_model + mrank, st=statics, meta_cell=meta_cell,
            live=live,
        )
        ms, mi = canonical_topk_merge(pool_s, pool_i, k, shard_axes)
        # every rank now holds the pod-global answer; hand back the rows of
        # the host that admitted them
        ms = jax.lax.dynamic_slice_in_dim(ms, drank * b_local, b_local, axis=0)
        mi = jax.lax.dynamic_slice_in_dim(mi, drank * b_local, b_local, axis=0)
        return ms, mi

    def body_live(idx_data: dict, live, qt, qw):
        return body(idx_data, qt, qw, live=live)

    sm = shard_map(
        body_live if live_masked else body,
        mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False,
    )

    def serve(index_stack: ImpactIndex, q_terms, q_weights, live_stack=None):
        meta_cell.clear()
        meta_cell.update(
            block_size=index_stack.block_size,
            scale=index_stack.scale,
            bits=index_stack.bits,
            max_segs=index_stack.max_segs,
            max_bm=index_stack.max_bm,
        )
        data = _index_data_dict(index_stack)
        if live_masked:
            if live_stack is None:
                raise ValueError(
                    "this pod serve step was built live_masked=True; pass "
                    "the per-shard live_stack (see shard_live_stack)"
                )
            return sm(data, jnp.asarray(live_stack, jnp.int32), q_terms, q_weights)
        if live_stack is not None:
            raise ValueError(
                "live_stack passed to a pod serve step built without "
                "live_masked=True; rebuild the step with live_masked=True"
            )
        return sm(data, q_terms, q_weights)

    serve.statics = statics
    return serve, in_specs, out_specs


def make_bucketed_serve_step(
    mesh: Mesh,
    *,
    lq_buckets: Sequence[int],
    n_terms: int,
    **kwargs,
):
    """Lq-bucketed wrapper over the sharded (or pod) serve step.

    The underlying serve step is shape-polymorphic — one executable per
    query-batch shape — so bucketing at pod scale is purely a host-side
    dispatch: pad each incoming batch to the smallest bucket covering its
    live terms and the ``(B, bucket)`` executable grid materializes lazily
    under the same shard_map. Short-query traffic stops paying long-query
    gather cost on *every rank at once*, and per-rank work stays identical
    across ranks because all ranks see the same padded batch shape. Results
    are bit-identical to padding at max Lq (trailing pad slots are inert in
    both engines).

    A mesh with a ``"pod"`` axis routes to :func:`make_pod_serve_step`
    (multi-host: query batch = concatenation of all hosts' blocks);
    otherwise the single-host :func:`make_sharded_serve_step` applies.
    """
    from repro.serving.bucketing import bucketize_batch, normalize_buckets

    buckets = normalize_buckets(lq_buckets)
    step = make_pod_serve_step if "pod" in mesh.axis_names else make_sharded_serve_step
    serve, in_specs, out_specs = step(mesh, **kwargs)

    def serve_bucketed(index_stack: ImpactIndex, q_terms, q_weights, live_stack=None):
        qt, qw, _ = bucketize_batch(
            np.asarray(q_terms), np.asarray(q_weights), buckets, n_terms
        )
        # strong i32/f32, pre-dispatch: same compile-cache invariant as
        # AnytimeServer._bucketize (see its docstring)
        return serve(
            index_stack, jnp.asarray(qt, jnp.int32), jnp.asarray(qw, jnp.float32),
            live_stack=live_stack,
        )

    # serve_bucketed itself does host-side numpy bucketization and CANNOT be
    # traced; the lint must trace `.inner` at each `.buckets` width instead.
    serve_bucketed.inner = serve
    serve_bucketed.buckets = buckets
    serve_bucketed.statics = serve.statics
    return serve_bucketed, in_specs, out_specs


def _index_data_dict(index: ImpactIndex) -> dict:
    return {
        f.name: getattr(index, f.name)
        for f in dataclasses.fields(ImpactIndex)
        if f.name not in _META_FIELDS
    }


def _index_data_template() -> dict:
    return {
        f.name: None
        for f in dataclasses.fields(ImpactIndex)
        if f.name not in _META_FIELDS
    }


def _static_meta_from(local: dict, docs_per_shard: int, meta: dict | None = None) -> dict:
    """Static metadata for a per-shard index rebuilt inside the shard_map.

    Shape-derived fields come from the local arrays; build-time constants
    (block size, quant scale/bits, seg/bm bounds) come from the real
    ``index_stack`` via ``meta`` when :func:`make_sharded_serve_step`'s
    ``serve()`` is the entry point. The historical defaults (128/1.0/8) only
    apply to bare ``sm(data_dict, ...)`` calls that never saw a real index.
    """
    n_docs_pad, tmax = local["doc_terms"].shape
    n_terms = local["term_seg_start"].shape[0] - 1
    m = meta or {}
    block_size = int(m.get("block_size", 128))
    return dict(
        n_docs=docs_per_shard,
        n_terms=n_terms,
        n_blocks=n_docs_pad // block_size,
        block_size=block_size,
        max_doc_terms=tmax,
        scale=float(m.get("scale", 1.0)),
        bits=int(m.get("bits", 8)),
        max_segs=int(m.get("max_segs", 0)),
        max_bm=int(m.get("max_bm", 0)),
    )
