"""Continuous-batching admission queue over the anytime server.

The paper's latency story is about *arrival-driven* traffic: SAAT's rho
budget makes per-query cost predictable while DAAT's tail is data-dependent.
``run_query_stream`` serves fixed pre-formed batches, which never exercises
that story. This module adds the missing serving front end: an
:class:`AdmissionQueue` accepts ``(q_terms, q_weights, deadline)`` requests
one at a time, coalesces them into the pre-compiled ``(B, Lq-bucket)``
executable grid of an :class:`~repro.serving.scheduler.AnytimeServer`, and
flushes a batch when it fills — or when waiting any longer would make the
oldest request miss its deadline given the cost model's predicted service
time.

Coalescing policy
-----------------
  * Requests are partitioned by **Lq bucket** (``repro.serving.bucketing``):
    a short query never pays a long query's gather cost, and every flush
    lands on a pre-compiled ``(B, bucket)`` shape (pad-to-shape is free by
    construction — trailing pad slots are bit-identity-preserving).
  * Within a bucket, admission order is FIFO. For the SAAT engine, flush
    order equals admission order. For the **DAAT engine**, the batch drawn
    from the FIFO prefix is re-ordered by *predicted survivor count*
    (:class:`SurvivorPredictor`, an EMA over observed ``WorkStats`` history):
    the batched ``while_loop`` runs until the slowest query is rank-safe, so
    co-scheduling requests with similar predicted work trims the batch tail.
    Completions may therefore permute *within one flush* — never across
    flushes. This is the "reordered-beyond-policy" boundary the tests pin.
  * A flush uses the smallest allowed batch shape that covers the pending
    prefix; missing rows are *inert sentinel rows* (all pad term ids, zero
    weights). A sentinel row has no survivors and idles after the first
    trip, so a short DAAT flush never burns while_loop work re-scoring a
    duplicated request; real-row results are independent of pad rows in
    both engines, and only the ``n_real`` real rows ever reach the
    ``SurvivorPredictor`` or the shape-keyed service-time EMA's per-request
    accounting.

Flush-time policy
-----------------
A bucket is *due* at ``oldest.deadline - predicted_service(B, bucket) -
safety`` — or at ``oldest.arrival + max_wait_s`` if that comes first: the
age bound is what keeps best-effort traffic (``deadline_ms=None``) from
starving in a bucket that never fills. ``poll()`` flushes every due bucket;
``next_due()`` exposes the
earliest such instant so a driver (or a simulated-clock test harness) can
sleep exactly until the next decision point instead of busy-polling. A
flush that happens later than its due instant is recorded as a policy
violation in ``flush_log`` — the serving suite asserts there are none.

``degrade_rho=True`` (SAAT only) arms the anytime knob the paper's serving
argument turns on: when a lane's due instant arrives before it fills, the
flush serves at the **largest calibrated rho whose predicted service still
meets the oldest deadline** (``AnytimeServer.pick_degraded_rho``) instead of
blowing the deadline at the full budget. The rho actually served is recorded
on every ``FlushRecord`` and ``Completion``, and the violation judgement
uses the served level's predicted service — degradation *replaces*
violation, and the effectiveness cost of each degraded flush is auditable
against the rho ladder (see ``repro.metrics.ir_metrics``).

The ``Clock`` injection point
-----------------------------
All time in this subsystem flows through one injectable
:class:`repro.metrics.latency.Clock`: the queue's arrival stamps, deadline
arithmetic, and due-time computation, *and* the server's latency/cost-model
measurements (the server shares the same clock instance by default). Pass a
:class:`repro.metrics.latency.SimulatedClock` and the whole admission →
coalesce → flush → complete pipeline becomes a deterministic function of
the arrival schedule: tests advance time explicitly (``clock.advance_to``)
between ``submit``/``poll`` calls and can replay hundreds of Poisson
arrivals with zero flakiness. Production constructs the queue with the
default :class:`~repro.metrics.latency.SystemClock`.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence

import jax
import numpy as np

from repro.metrics.latency import Clock, SimulatedClock, SystemClock  # noqa: F401  (re-export)
from repro.serving.bucketing import (
    bucket_for,
    effective_lq,
    normalize_buckets,
    pad_to_width,
    sentinel_rows,
)
from repro.serving.counters import CounterRegistry
from repro.serving.scheduler import AnytimeServer

_EPS_S = 1e-9  # float tolerance when judging "flushed after its due instant"


class SurvivorPredictor:
    """EMA of observed DAAT survivor counts, keyed by effective query length.

    ``WorkStats.n_survivors`` is the paper's per-query work metric: the
    number of blocks that outlive phase-1 pruning, which is what the batched
    while_loop's trip count — and therefore the batch tail — tracks. Queries
    with the same effective Lq tend to have similar survivor counts, so the
    EMA is keyed by ``lq_eff``; an unseen key falls back to the *nearest
    observed* Lq key first (survivor counts are roughly monotone in Lq, so a
    neighbor is informative where a global mean over a bimodal stream is
    not), and to the global EMA only before any observation at all.
    """

    def __init__(self, alpha: float = 0.2):
        self.alpha = alpha
        self._by_lq: dict[int, float] = {}
        self._global: Optional[float] = None
        # per-key trust in [0, 1]: 1.0 is the steady state (observe smooths
        # at exactly alpha). A hot swap decays trust instead of discarding
        # the EMA — survivor counts over the compacted corpus are close to
        # the pre-swap ones (the live docs are the same), so the old value
        # is the right prior, it just re-converges faster.
        self._conf: dict[int, float] = {}
        self._gconf: float = 1.0

    def observe(self, lq_eff: int, survivors: float):
        s = float(survivors)
        a = self.alpha
        conf = self._conf.get(lq_eff, 1.0)
        a_eff = a + (1 - a) * (1 - conf)
        old = self._by_lq.get(lq_eff)
        self._by_lq[lq_eff] = s if old is None else (1 - a_eff) * old + a_eff * s
        self._conf[lq_eff] = 1 - (1 - conf) * (1 - a)
        g_eff = a + (1 - a) * (1 - self._gconf)
        self._global = s if self._global is None else (1 - g_eff) * self._global + g_eff * s
        self._gconf = 1 - (1 - self._gconf) * (1 - a)

    def decay(self, factor: float = 0.5):
        """Generation bump: keep every EMA value, shrink its trust."""
        for key in self._by_lq:
            self._conf[key] = self._conf.get(key, 1.0) * factor
        self._gconf *= factor

    def predict(self, lq_eff: int) -> float:
        v = self._by_lq.get(lq_eff)
        if v is not None:
            return v
        # unseen Lq: the nearest observed key beats the global EMA. Under a
        # bimodal stream (say Lq 3 and 30) the global mean describes NO
        # query, so predicting with it interleaved short and long queries in
        # one batch — exactly the tail the survivor sort exists to avoid.
        # Ties break toward the smaller key (stable, deterministic).
        if self._by_lq:
            nearest = min(self._by_lq, key=lambda key: (abs(key - lq_eff), key))
            return self._by_lq[nearest]
        return self._global if self._global is not None else 0.0


@dataclasses.dataclass
class _Request:
    rid: int
    q_terms: np.ndarray  # [lq_eff] trimmed to live width
    q_weights: np.ndarray
    arrival_s: float
    deadline_s: float  # absolute, clock domain
    lq_eff: int
    bucket: int


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    scores: np.ndarray  # f32[k]
    doc_ids: np.ndarray  # i32[k]
    arrival_s: float
    flush_s: float
    deadline_s: float
    bucket: int
    batch_shape: int
    rho: Optional[int]  # ladder level actually served; None for the daat engine

    @property
    def wait_ms(self) -> float:
        return (self.flush_s - self.arrival_s) * 1e3


@dataclasses.dataclass(frozen=True)
class FlushRecord:
    flush_s: float
    bucket: int
    batch_shape: int
    n_real: int
    rids: tuple[int, ...]
    rho: Optional[int]
    predicted_ms: float
    oldest_deadline_s: float
    reason: str  # "full" | "deadline" | "drain"
    # flushed too late for the predicted service to finish by the oldest
    # deadline (safety_ms is headroom BEFORE this boundary, not part of it:
    # a flush inside its safety margin is early, not violating)
    violation: bool
    # the oldest request's deadline was unmeetable the moment it ARRIVED
    # (deadline - predicted service < arrival): the queue flushes best-effort
    # immediately, and the miss is admission infeasibility, not a scheduling
    # failure — counted separately from `violation`
    infeasible: bool
    # index lifecycle generation the flush was served at (0 for an immutable
    # server). Monotone non-decreasing across flush_log: swaps happen only
    # between flushes, never under one — the hot-swap tests pin this.
    generation: int = 0


class AdmissionQueue:
    """Deadline-aware request coalescing onto the (B, Lq-bucket) grid.

    Parameters
    ----------
    server: the engine + executable grid; its ``lq_buckets`` (or ``max_lq``)
        define the width grid, ``batch_shapes`` the allowed B values.
    batch_shapes: allowed flush batch sizes, ascending. A bucket flushes as
        "full" at the largest shape; a deadline flush uses the smallest
        shape covering the pending prefix.
    clock: defaults to the *server's* clock so queue wait and service cost
        share one time domain.
    safety_ms: subtracted from every due instant (headroom for dispatch
        overhead the cost model cannot see).
    max_wait_s: age-based flush trigger — a bucket is due no later than
        ``oldest.arrival + max_wait_s`` even when no deadline says so.
        Without it, a non-full bucket whose pending requests all carry no
        (or an infinite) deadline is never due: ``next_due()`` has nothing
        to report and the requests starve until ``drain()``. ``None``
        (default) keeps the pure deadline-driven policy.
    dynamic_rho: when True (SAAT only), each flush re-picks rho against the
        oldest request's *remaining* budget instead of the server default.
    degrade_rho: when True (SAAT only), a flush that can no longer meet the
        oldest deadline at the default budget degrades to the largest
        *calibrated* ladder level whose predicted service for this exact
        ``(batch shape, bucket)`` still fits the remaining time
        (``AnytimeServer.pick_degraded_rho``); the served level is recorded
        in ``flush_log``/completions and the violation judgement uses it.
        Differs from ``dynamic_rho`` in consulting the shape-keyed
        service-time EMA (whole-flush wall time) rather than the per-query
        rho cost model; the two policies are mutually exclusive.
    """

    def __init__(
        self,
        server: AnytimeServer,
        *,
        batch_shapes: Sequence[int] = (8, 32),
        clock: Optional[Clock] = None,
        safety_ms: float = 0.0,
        max_wait_s: Optional[float] = None,
        dynamic_rho: bool = False,
        degrade_rho: bool = False,
        max_lq: Optional[int] = None,
        survivor_alpha: float = 0.2,
    ):
        self.server = server
        self.clock: Clock = clock if clock is not None else server.clock
        self.batch_shapes = tuple(sorted(set(int(b) for b in batch_shapes)))
        if not self.batch_shapes or self.batch_shapes[0] <= 0:
            raise ValueError(f"batch_shapes must be positive, got {batch_shapes!r}")
        if server.lq_buckets is not None:
            self.buckets = server.lq_buckets
        elif max_lq is not None:
            self.buckets = normalize_buckets((max_lq,))
        else:
            raise ValueError(
                "server has no lq_buckets; pass max_lq= so the queue has a width grid"
            )
        self.safety_s = safety_ms / 1e3
        if max_wait_s is not None and max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self.max_wait_s = max_wait_s
        if (dynamic_rho or degrade_rho) and server.cfg.engine != "saat":
            raise ValueError(
                "dynamic_rho/degrade_rho trade the SAAT posting budget; the "
                "daat engine has no rho knob"
            )
        if dynamic_rho and degrade_rho:
            raise ValueError(
                "dynamic_rho and degrade_rho are alternative flush-time rho "
                "policies; enable at most one"
            )
        self.dynamic_rho = dynamic_rho
        self.degrade_rho = degrade_rho
        self.survivors = SurvivorPredictor(alpha=survivor_alpha)
        self._pending: dict[int, deque[_Request]] = {b: deque() for b in self.buckets}
        self._completions: list[Completion] = []
        self._next_rid = 0
        self.flush_log: list[FlushRecord] = []
        self.n_submitted = 0
        self.n_completed = 0

    # ------------------------------ admission ------------------------------

    def submit(self, q_terms, q_weights, deadline_ms: Optional[float] = None) -> int:
        """Admit one request; returns its rid. May flush a now-full bucket.

        ``deadline_ms=None`` (or ``inf``) admits a best-effort request with
        no latency contract: it never makes its bucket due on its own, so it
        flushes when the bucket fills, when a deadlined neighbor is due, or
        at the ``max_wait_s`` age bound.
        """
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be positive, got {deadline_ms}")
        qt = np.asarray(q_terms, dtype=np.int32).reshape(-1)
        qw = np.asarray(q_weights, dtype=np.float32).reshape(-1)
        if qt.shape != qw.shape:
            raise ValueError(f"terms/weights shape mismatch: {qt.shape} vs {qw.shape}")
        n_terms = self.server.index.n_terms
        eff = effective_lq(qt[None, :], qw[None, :], n_terms)
        bucket = bucket_for(eff, self.buckets)
        if bucket not in self._pending:  # overflow width: own lane, compiled on demand
            self._pending[bucket] = deque()
        now = self.clock.now()
        rid = self._next_rid
        self._next_rid += 1
        self.n_submitted += 1
        self._pending[bucket].append(
            _Request(
                rid=rid,
                q_terms=qt[:eff].copy(),
                q_weights=qw[:eff].copy(),
                arrival_s=now,
                deadline_s=(
                    float("inf") if deadline_ms is None else now + deadline_ms / 1e3
                ),
                lq_eff=eff,
                bucket=bucket,
            )
        )
        while len(self._pending[bucket]) >= self.batch_shapes[-1]:
            self._flush(bucket, reason="full")
        return rid

    def pending(self) -> int:
        return sum(len(q) for q in self._pending.values())

    # --------------------------- index lifecycle ---------------------------

    def swap_index(self, handle=None, *, decay: float = 0.5):
        """Hot-swap the serving index between flushes; pending requests ride.

        Delegates to :meth:`AnytimeServer.swap_index` (rebind main-segment
        statics, bump generation, decay — never discard — the service-time
        calibration) and applies the same decay to the survivor predictor.
        Pending requests are host-side rows keyed by Lq bucket, a grid the
        swap cannot change (the vocabulary is fixed for the handle's
        lifetime), so a swap loses, duplicates, and reorders **zero**
        requests: everything admitted before the swap flushes after it,
        against the new generation — the invariant the hot-swap replay tests
        pin via ``FlushRecord.generation`` monotonicity + rid accounting.
        """
        self.server.swap_index(handle, decay=decay)
        self.survivors.decay(decay)

    # ----------------------------- flush policy ----------------------------

    def _shape_for(self, n: int) -> int:
        for b in self.batch_shapes:
            if b >= n:
                return b
        return self.batch_shapes[-1]

    def _due_instant(self, bucket: int) -> Optional[float]:
        q = self._pending[bucket]
        if not q:
            return None
        shape = self._shape_for(len(q))
        # an overfull lane (> largest shape) drains as ceil(n/shape) chunked
        # launches, and the lane's deadlines are only safe once the LAST
        # launch lands — predicting one launch made the due instant
        # optimistic exactly when the lane was overloaded
        launches = -(-len(q) // shape)
        predicted_ms = self.server.predict_service_ms(shape, bucket) * launches
        oldest = min(r.deadline_s for r in q)
        due = oldest - predicted_ms / 1e3 - self.safety_s
        # age bound: deadline-less (inf) requests would otherwise push `due`
        # to +inf and starve in a bucket that never fills
        if self.max_wait_s is not None:
            due = min(due, min(r.arrival_s for r in q) + self.max_wait_s)
        return due if due < float("inf") else None

    def next_due(self) -> Optional[float]:
        """Earliest instant at which some bucket must flush (None if empty)."""
        dues = [d for b in self._pending for d in [self._due_instant(b)] if d is not None]
        return min(dues) if dues else None

    def poll(self) -> list[Completion]:
        """Flush every due bucket, then hand back (and clear) completions."""
        for bucket in sorted(self._pending):
            while True:
                # Re-read the clock every iteration: under a real (or hybrid)
                # clock an earlier bucket's flush accrues service time, which
                # can make THIS bucket due *during* the same poll — judging
                # every bucket against the poll's entry time flushed it one
                # driver wakeup late.
                now = self.clock.now()
                due = self._due_instant(bucket)
                if due is None or now < due - _EPS_S:
                    break
                self._flush(bucket, reason="deadline")
        return self.take_completions()

    def drain(self) -> list[Completion]:
        """Flush everything pending regardless of deadlines (end of stream)."""
        for bucket in sorted(self._pending):
            while self._pending[bucket]:
                self._flush(bucket, reason="drain")
        return self.take_completions()

    def take_completions(self) -> list[Completion]:
        out = self._completions
        self._completions = []
        return out

    # ------------------------------- flushing ------------------------------

    def _flush(self, bucket: int, reason: str):
        """Serve the pending lane: one launch, or — when the lane holds more
        than the largest batch shape — every ceil(n/top) chunked launch it
        takes to drain it. One ``FlushRecord`` per launch; each launch reads
        the clock itself, so on a real clock a later chunk's violation
        judgement sees the service time the earlier chunks actually spent.
        """
        top = self.batch_shapes[-1]
        n_chunks = max(-(-len(self._pending[bucket]) // top), 1)
        for _ in range(n_chunks):
            self._flush_chunk(bucket, reason)

    def _flush_chunk(self, bucket: int, reason: str):
        q = self._pending[bucket]
        if not q:
            return
        now = self.clock.now()
        n = min(len(q), self.batch_shapes[-1])
        shape = self._shape_for(n)
        batch = [q.popleft() for _ in range(n)]
        daat = self.server.cfg.engine == "daat"
        if daat:
            # straggler-aware composition: similar predicted survivor counts
            # sit in one batch so the while_loop tail tracks the batch, not
            # the stream (stable sort: FIFO among equal predictions)
            batch.sort(key=lambda r: self.survivors.predict(r.lq_eff))
        # rows [n:] stay inert sentinels (all pad ids, zero weights): cheaper
        # than repeating the last request, which burned DAAT while_loop work
        # on a duplicate's survivors
        qt, qw = sentinel_rows(shape, bucket, self.server.index.n_terms)
        for i, r in enumerate(batch):
            t, w = pad_to_width(r.q_terms, r.q_weights, bucket, self.server.index.n_terms)
            qt[i], qw[i] = t, w
        r_oldest = min(batch, key=lambda r: r.deadline_s)
        oldest = r_oldest.deadline_s
        rho: Optional[int] = None
        if not daat:
            # pick the level here (identically to what search_batch would do)
            # so completions/flush_log record the budget actually served
            if self.degrade_rho:
                # budget = time to the oldest deadline, less the same safety
                # headroom the due instant reserves; the epsilon keeps an
                # exactly-on-time flush from degrading over float round-off
                remaining_ms = max((oldest - now - self.safety_s + _EPS_S) * 1e3, 0.0)
                rho = self.server.pick_degraded_rho(shape, bucket, remaining_ms)
            elif self.dynamic_rho:
                remaining_ms = max((oldest - now) * 1e3, 0.0)
                rho = self.server.pick_rho(deadline_ms=remaining_ms)
            else:
                rho = self.server.pick_rho()
        # predicted service of the level ACTUALLY served: the violation /
        # infeasibility judgement below must account degradation as meeting
        # the deadline it was chosen to meet, not as missing full-rho's
        predicted_ms = self.server.predict_service_ms(shape, bucket, rho=rho)
        res = self.server.search_batch(qt, qw, rho=rho)
        scores = np.asarray(jax.device_get(res.scores))
        ids = np.asarray(jax.device_get(res.doc_ids))
        # the pod serve step returns only the merged (scores, ids) — per-rank
        # WorkStats never cross the merge — so survivor feedback is best-effort
        stats = getattr(res, "stats", None) if daat else None
        if stats is not None:
            survivors = np.asarray(jax.device_get(stats.n_survivors))
            for i, r in enumerate(batch):
                self.survivors.observe(r.lq_eff, float(survivors[i]))
        for i, r in enumerate(batch):
            self._completions.append(
                Completion(
                    rid=r.rid,
                    scores=scores[i],
                    doc_ids=ids[i],
                    arrival_s=r.arrival_s,
                    flush_s=now,
                    deadline_s=r.deadline_s,
                    bucket=bucket,
                    batch_shape=shape,
                    rho=rho,
                )
            )
        self.n_completed += n
        due = oldest - predicted_ms / 1e3  # violation boundary excludes safety headroom
        infeasible = due <= r_oldest.arrival_s + _EPS_S  # unmeetable at admission
        self.flush_log.append(
            FlushRecord(
                flush_s=now,
                bucket=bucket,
                batch_shape=shape,
                n_real=n,
                rids=tuple(r.rid for r in batch),
                rho=rho,
                predicted_ms=predicted_ms,
                oldest_deadline_s=oldest,
                reason=reason,
                violation=bool(now > due + _EPS_S) and not infeasible and reason != "drain",
                infeasible=infeasible,
                generation=getattr(self.server, "generation", 0),
            )
        )

    # ------------------------------ reporting ------------------------------

    @property
    def n_violations(self) -> int:
        return sum(1 for f in self.flush_log if f.violation)

    @property
    def n_infeasible(self) -> int:
        return sum(1 for f in self.flush_log if f.infeasible)

    @property
    def n_degraded(self) -> int:
        """Flushes served below the full posting budget (SAAT only)."""
        if self.server.cfg.engine != "saat":
            return 0
        top = self.server.rho_ladder[-1]
        return sum(1 for f in self.flush_log if f.rho is not None and f.rho < top)

    def export_counters(
        self,
        registry: Optional[CounterRegistry] = None,
        labels: Optional[dict] = None,
    ) -> CounterRegistry:
        """Scrape-time counter export, derived wholly from records this queue
        already keeps (``flush_log``, admission tallies, pending lanes) — no
        hot-path instrumentation anywhere. ``labels`` (e.g. ``{"host": "2"}``)
        are attached to every sample so several queues can share a registry.
        """
        reg = registry if registry is not None else CounterRegistry()
        base = {str(k): str(v) for k, v in (labels or {}).items()}
        reg.counter("repro_queue_submitted_total", "Requests admitted").labels(**base).inc(
            self.n_submitted
        )
        reg.counter("repro_queue_completed_total", "Requests served").labels(**base).inc(
            self.n_completed
        )
        flushes = reg.counter(
            "repro_queue_flush_total", "Flushes by Lq bucket and trigger reason"
        )
        occupancy = reg.histogram(
            "repro_queue_flush_occupancy",
            "Real rows / batch shape per flush (executable fill factor)",
            buckets=(0.25, 0.5, 0.75, 1.0),
        )
        served_rho = reg.counter(
            "repro_queue_served_rho_total",
            "Flushes by served SAAT posting budget (daat flushes under rho=\"none\")",
        )
        for f in self.flush_log:
            flushes.labels(**base, bucket=str(f.bucket), reason=f.reason).inc()
            occupancy.labels(**base, bucket=str(f.bucket)).observe(f.n_real / f.batch_shape)
            served_rho.labels(**base, rho="none" if f.rho is None else str(f.rho)).inc()
        reg.counter(
            "repro_queue_violations_total",
            "Flushes later than the predicted-service deadline boundary",
        ).labels(**base).inc(self.n_violations)
        reg.counter(
            "repro_queue_infeasible_total",
            "Flushes whose oldest deadline was unmeetable at admission",
        ).labels(**base).inc(self.n_infeasible)
        reg.counter(
            "repro_queue_degraded_total",
            "Flushes served below the full posting budget",
        ).labels(**base).inc(self.n_degraded)
        depth = reg.gauge("repro_queue_depth", "Pending requests per Lq bucket lane")
        for bucket, lane in sorted(self._pending.items()):
            depth.labels(**base, bucket=str(bucket)).set(len(lane))
        return reg


def replay_arrivals(
    queue: AdmissionQueue,
    arrivals_s: Sequence[float],
    q_terms_list: Sequence[np.ndarray],
    q_weights_list: Sequence[np.ndarray],
    deadlines_ms: Sequence[float],
) -> list[Completion]:
    """Deterministically replay an arrival schedule on a simulated clock.

    The event loop advances the queue's :class:`SimulatedClock` to the next
    event — an arrival or ``next_due()`` — and polls at exactly that
    instant, so no flush can ever be observed late for lack of a wakeup.
    rids are assigned in arrival order (rid ``i`` is request ``i``).
    """
    clock = queue.clock
    if not isinstance(clock, SimulatedClock):
        raise TypeError("replay_arrivals drives time itself; queue needs a SimulatedClock")
    if not (len(arrivals_s) == len(q_terms_list) == len(q_weights_list) == len(deadlines_ms)):
        raise ValueError("arrival schedule fields must have equal length")
    inf = float("inf")
    completions: list[Completion] = []
    i, n = 0, len(arrivals_s)
    while i < n or queue.pending():
        t_arr = arrivals_s[i] if i < n else inf
        due = queue.next_due()
        t_due = due if due is not None else inf
        if t_arr <= t_due:
            clock.advance_to(t_arr)
            queue.submit(q_terms_list[i], q_weights_list[i], deadlines_ms[i])
            i += 1
        else:
            clock.advance_to(t_due)
        completions.extend(queue.poll())
    return completions
