"""Lq bucketing: pad query batches to a small grid of widths, not to max Lq.

Both engines run a ``[B, Lq]`` batch as one executable, and both are
*invariant to trailing pad columns*: a pad slot (term id ``n_terms`` or
weight 0) contributes no segments to the SAAT plan and scatters nothing into
the DAAT dense query vector, and the posting gather masks invalid slots
before they touch the accumulator. Serving every batch at the width of the
longest query in the *stream* therefore wastes gather/sort work linear in
``Lq`` for short-query traffic — but serving each batch at its own exact
width would compile a fresh executable per distinct width.

The compromise is a small ladder of bucket widths: a batch whose widest
query has ``eff`` live terms is padded to the smallest bucket ``>= eff``,
so the executable grid stays ``O(|buckets|)`` per engine config while doc
ids and scores stay **bit-identical** to the max-Lq pad (asserted by the
hypothesis property suite in ``tests/test_queue.py``).

Pad-slot convention (matches ``pad_queries`` / ``saat_plan``): a slot is
live iff ``term_id != n_terms`` *and* ``weight > 0``. ``effective_lq`` is
the last live column + 1, so interior pads are never sliced away.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def normalize_buckets(buckets: Sequence[int]) -> Tuple[int, ...]:
    """Sorted, deduplicated, validated bucket widths."""
    out = tuple(sorted({int(b) for b in buckets}))
    if not out or out[0] <= 0:
        raise ValueError(f"bucket widths must be positive, got {buckets!r}")
    return out


def effective_lq(q_terms: np.ndarray, q_weights: np.ndarray, n_terms: int) -> int:
    """Width of the narrowest left-slice covering every live slot (>= 1)."""
    qt = np.asarray(q_terms)
    qw = np.asarray(q_weights)
    live = (qt != n_terms) & (qw > 0)
    cols = np.nonzero(live.any(axis=tuple(range(live.ndim - 1))))[0]
    return int(cols[-1]) + 1 if cols.size else 1


def bucket_for(eff_lq: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= eff_lq (buckets ascending).

    A width that overflows the ladder rounds up to the next multiple of the
    largest bucket, so pathologically wide queries cost at most one extra
    executable per ``buckets[-1]`` step instead of one per distinct width.
    """
    for b in buckets:
        if b >= eff_lq:
            return int(b)
    top = int(buckets[-1])
    return -(-int(eff_lq) // top) * top


def pad_to_width(
    q_terms: np.ndarray, q_weights: np.ndarray, width: int, n_terms: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Pad (or slice, when every dropped column is dead) a batch to ``width``.

    Slicing below ``effective_lq`` would drop live terms, so callers must
    pass ``width >= effective_lq(...)``; this is asserted cheaply here.
    """
    qt = np.asarray(q_terms, dtype=np.int32)
    qw = np.asarray(q_weights, dtype=np.float32)
    L = qt.shape[-1]
    if width == L:
        return qt, qw
    if width < L:
        dropped_live = (qt[..., width:] != n_terms) & (qw[..., width:] > 0)
        if dropped_live.any():
            raise ValueError(
                f"slicing [.., {L}) -> [.., {width}) would drop live query terms"
            )
        return np.ascontiguousarray(qt[..., :width]), np.ascontiguousarray(qw[..., :width])
    pad_shape = qt.shape[:-1] + (width,)
    out_t = np.full(pad_shape, n_terms, dtype=np.int32)
    out_w = np.zeros(pad_shape, dtype=np.float32)
    out_t[..., :L] = qt
    out_w[..., :L] = qw
    return out_t, out_w


def sentinel_rows(n_rows: int, width: int, n_terms: int) -> Tuple[np.ndarray, np.ndarray]:
    """Inert query rows: every slot is a pad (term id ``n_terms``, weight 0).

    A sentinel row contributes no SAAT segments and no DAAT survivors, so it
    is the free way to fill a batch to a compiled shape — the admission
    queue's short flushes and the pod front end's absent-host blocks both
    stamp real rows over this canvas. Returns ``(q_terms, q_weights)`` of
    shape ``[n_rows, width]``.
    """
    return (
        np.full((n_rows, width), n_terms, dtype=np.int32),
        np.zeros((n_rows, width), dtype=np.float32),
    )


def bucketize_batch(
    q_terms: np.ndarray,
    q_weights: np.ndarray,
    buckets: Sequence[int],
    n_terms: int,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Pad a ``[B, Lq]`` batch to its bucket width; returns (qt, qw, bucket)."""
    eff = effective_lq(q_terms, q_weights, n_terms)
    b = bucket_for(eff, buckets)
    qt, qw = pad_to_width(q_terms, q_weights, b, n_terms)
    return qt, qw, b
