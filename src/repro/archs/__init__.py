"""Assigned architectures: LM transformers (dense/MoE/GQA/local-global),
GraphCast-style GNN, and four recsys models — all as selectable configs.

The arch registry lives in ``repro.configs`` (one file per assigned arch);
this package holds the model code itself.
"""
