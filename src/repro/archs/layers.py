"""Shared neural building blocks (pure functions over param pytrees).

No flax/haiku on purpose: params are nested dicts of jnp arrays, every layer
is a pure function, and sharding is applied by the caller via GSPMD
annotations (repro.distributed.sharding). Initializers take explicit PRNG
keys so model construction is deterministic and mesh-independent.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rmsnorm_params(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_params(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [..., seq, n_heads, d_head]; positions: broadcastable to [..., seq]."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)  # [d/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, d/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., seq, 1, d/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA / MQA / sliding-window, prefill & decode)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    d_head: int

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv_heads


def attn_params(key, d_model: int, dims: AttnDims, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, dims.n_heads * dims.d_head, dtype),
        "wk": dense_init(kk, d_model, dims.n_kv_heads * dims.d_head, dtype),
        "wv": dense_init(kv, d_model, dims.n_kv_heads * dims.d_head, dtype),
        "wo": dense_init(ko, dims.n_heads * dims.d_head, d_model, dtype),
    }


def _causal_window_mask(
    q_pos: jax.Array, k_pos: jax.Array, window: jax.Array | int
) -> jax.Array:
    """bool[..., q, k]: causality/window mask.

    ``window`` semantics: 0 = global causal; W>0 = causal sliding window W;
    -1 = **bidirectional** (encoder stacks, e.g. the SPLADE/uniCOIL sparse
    encoders). May be a traced scalar (per-layer selection inside a scanned
    stack). Key positions < 0 denote empty ring-buffer cache slots and are
    always masked.
    """
    nonneg = k_pos[None, :] >= 0
    causal = k_pos[None, :] <= q_pos[:, None]
    w = jnp.asarray(window, jnp.int32)
    in_window = (q_pos[:, None] - k_pos[None, :]) < jnp.where(w > 0, w, jnp.int32(2**30))
    return nonneg & jnp.where(w < 0, nonneg, causal & in_window)


def multihead_attention(
    params,
    x: jax.Array,  # [B, S, D]
    dims: AttnDims,
    *,
    positions: jax.Array,  # [B, S] or [S]
    window: jax.Array | int = 0,
    rope_theta: float = 10000.0,
    kv_override: Optional[tuple[jax.Array, jax.Array, jax.Array]] = None,
    chunk_size: int = 0,
) -> jax.Array:
    """GQA attention. ``kv_override=(k, v, k_positions)`` enables decode
    against a cache; ``chunk_size>0`` switches to the blockwise (flash-style)
    online-softmax path for long sequences."""
    B, S, D = x.shape
    q = (x @ params["wq"]).reshape(B, S, dims.n_heads, dims.d_head)
    k = (x @ params["wk"]).reshape(B, S, dims.n_kv_heads, dims.d_head)
    v = (x @ params["wv"]).reshape(B, S, dims.n_kv_heads, dims.d_head)
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None, :], (B, S))
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    k_pos = positions
    if kv_override is not None:
        k, v, k_pos = kv_override  # already rope'd cache + positions
    if chunk_size and k.shape[1] > chunk_size:
        out = _attention_chunked(q, k, v, positions, k_pos, dims, window, chunk_size)
    else:
        out = _attention_dense(q, k, v, positions, k_pos, dims, window)
    return out.reshape(B, S, dims.n_heads * dims.d_head) @ params["wo"]


def _attention_dense(q, k, v, q_pos, k_pos, dims: AttnDims, window) -> jax.Array:
    B, S, H, hd = q.shape
    T = k.shape[1]
    g = dims.group
    qg = q.reshape(B, S, dims.n_kv_heads, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    mask = jax.vmap(lambda qp, kp: _causal_window_mask(qp, kp, window))(q_pos, k_pos)
    scores = jnp.where(mask[:, None, None, :, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows with no visible keys (ring-buffer cache padding) produce NaN; zero them
    probs = jnp.where(jnp.isnan(probs), 0.0, probs).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, H, hd)


def _attention_chunked(q, k, v, q_pos, k_pos, dims: AttnDims, window, chunk: int) -> jax.Array:
    """Blockwise online-softmax attention (flash-style), O(S*chunk) memory.

    KV is scanned in chunks with running (max, denominator, numerator) — the
    standard memory-safe formulation for 32k+ contexts on TPU where the full
    [S, T] score matrix cannot live in HBM.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    assert T % chunk == 0, (T, chunk)
    g = dims.group
    qg = q.reshape(B, S, dims.n_kv_heads, g, hd)
    n_chunks = T // chunk

    def body(carry, inputs):
        m, denom, num = carry
        kc, vc, kpc = inputs  # [B, chunk, K, hd], [B, chunk]
        s = jnp.einsum("bskgh,btkh->bkgst", qg, kc).astype(jnp.float32) / jnp.sqrt(
            jnp.float32(hd)
        )
        mask = jax.vmap(lambda qp, kp: _causal_window_mask(qp, kp, window))(q_pos, kpc)
        s = jnp.where(mask[:, None, None, :, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        denom = denom * alpha + p.sum(axis=-1)
        num = num * alpha[..., None] + jnp.einsum("bkgst,btkh->bkgsh", p.astype(vc.dtype), vc)
        return (m_new, denom, num), None

    m0 = jnp.full((B, dims.n_kv_heads, g, S), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((B, dims.n_kv_heads, g, S), jnp.float32)
    n0 = jnp.zeros((B, dims.n_kv_heads, g, S, hd), jnp.float32)
    ks = k.reshape(B, n_chunks, chunk, dims.n_kv_heads, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n_chunks, chunk, dims.n_kv_heads, hd).transpose(1, 0, 2, 3, 4)
    kps = k_pos.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    # checkpoint the chunk body: otherwise scan saves every chunk's [S, chunk]
    # probs + mask for backward — the flash-attention memory win would be lost
    (m, denom, num), _ = jax.lax.scan(jax.checkpoint(body), (m0, d0, n0), (ks, vs, kps))
    out = num / jnp.maximum(denom[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# FFN: SwiGLU + GShard-style top-k MoE (sort/scatter dispatch, EP-shardable)
# --------------------------------------------------------------------------


def mlp_params(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    n_shared: int = 0  # shared (always-on) experts, DeepSeek style
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    # dispatch groups (GShard): tokens are split into G groups, each group
    # sorts/scatters LOCALLY (group axis shards over the mesh, so no global
    # token-permutation collective ever exists). 0 = one group per chip
    # (inferred from the ambient mesh; 1 group without a mesh).
    n_groups: int = 0


def moe_params(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32):
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.d_expert_ff
    p = {
        "router": dense_init(kr, d_model, E, jnp.float32),
        "w_gate": (jax.random.normal(k1, (E, d_model, F), jnp.float32) / jnp.sqrt(d_model)).astype(dtype),
        "w_up": (jax.random.normal(k2, (E, d_model, F), jnp.float32) / jnp.sqrt(d_model)).astype(dtype),
        "w_down": (jax.random.normal(k3, (E, F, d_model), jnp.float32) / jnp.sqrt(F)).astype(dtype),
    }
    if cfg.n_shared:
        p["shared"] = mlp_params(ks, d_model, cfg.d_expert_ff * cfg.n_shared, dtype)
    return p


def _round_up(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def _dispatch_one_group(xg, logits_g, cfg: MoEConfig, C: int, dtype):
    """Local (per-group) top-k sort/scatter dispatch. xg: [Tg, D]."""
    Tg, D = xg.shape
    E, K = cfg.n_experts, cfg.top_k
    probs = jax.nn.softmax(logits_g, axis=-1)
    gate, choice = jax.lax.top_k(probs, K)  # [Tg, K]
    gate = (gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)).astype(dtype)
    flat_e = choice.reshape(Tg * K)
    order = jnp.argsort(flat_e, stable=True)
    ranks = jnp.empty_like(order).at[order].set(jnp.arange(Tg * K, dtype=order.dtype))
    counts = jnp.bincount(flat_e, length=E).astype(jnp.int32)
    expert_base = jnp.cumsum(counts) - counts
    pos_in_expert = ranks.astype(jnp.int32) - expert_base[flat_e]
    keep = pos_in_expert < C
    tok = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), K)
    updates = xg[tok] * keep[:, None].astype(dtype)
    slot = jnp.where(keep, flat_e * C + pos_in_expert, 0)
    buf = jnp.zeros((E * C, D), dtype).at[slot].add(updates)
    return buf.reshape(E, C, D), (gate, keep, slot, tok, flat_e)


def _combine_one_group(out_e, route, Tg: int, D: int, dtype):
    gate, keep, slot, tok, _ = route
    y = out_e.reshape(-1, D)[slot]  # slot 0 aliases drops; keep-mask zeroes them
    y = y * (gate.reshape(-1, 1) * keep[:, None].astype(dtype))
    return jnp.zeros((Tg, D), dtype).at[tok].add(y)


def moe(
    params, x: jax.Array, cfg: MoEConfig, token_axis: str = "all"
) -> tuple[jax.Array, jax.Array]:
    """Grouped top-k MoE (GShard dispatch), EP-shardable.

    Tokens are split into ``G`` groups (one per chip by default); each group
    runs a LOCAL sort/scatter into its ``[E, C_g, D]`` capacity slice, so the
    only cross-chip movement is the ``[G, E, C_g, D]`` buffer itself —
    G-sharded over the token axes and, when ``E`` divides the model axis,
    E-sharded over ``model`` (the canonical all-to-all EP exchange). Global-
    permutation dispatch (argsort over all T*K assignments) was measured at
    +300 s/step of collectives on granite's 40-expert config (§Perf).
    Tokens beyond an expert's per-group capacity are dropped (GShard
    semantics, capacity_factor-controlled).

    Returns (output, aux_loss).
    """
    from repro.distributed.sharding import act, ambient_axis_size

    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, D)
    logits = (xt.astype(jnp.float32)) @ params["router"]  # [T, E]

    G = cfg.n_groups or max(ambient_axis_size(token_axis), 1)
    if T % G != 0:
        G = 1
    Tg = T // G
    C = _round_up(max(int(Tg * K / E * cfg.capacity_factor), 1), 8)

    xg = xt.reshape(G, Tg, D)
    lg = logits.reshape(G, Tg, E)
    # EP on the expert axis only when the model axis isn't already carrying
    # the token groups (dp_layout) and E divides it
    expert_tok = (
        "model"
        if token_axis != "all" and E % max(ambient_axis_size("model"), 1) == 0
        else None
    )
    buf, route = jax.vmap(
        lambda xgi, lgi: _dispatch_one_group(xgi, lgi, cfg, C, x.dtype)
    )(xg, lg)
    buf = act(buf, token_axis, expert_tok, None, None)  # [G, E, C, D]
    a = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["w_gate"]))
    a = a * jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    out_e = jnp.einsum("gecf,efd->gecd", a, params["w_down"])
    out_e = act(out_e, token_axis, expert_tok, None, None)
    y = jax.vmap(lambda oe, r: _combine_one_group(oe, r, Tg, D, x.dtype))(out_e, route)
    y = act(y.reshape(T, D), token_axis, None)

    if cfg.n_shared:
        y = y + mlp(params["shared"], xt)

    # load-balance aux loss (Switch) + router z-loss, computed globally
    probs = jax.nn.softmax(logits, axis=-1)
    me = probs.mean(axis=0)  # [E]
    ce = jnp.zeros((E,), jnp.float32).at[route[4].reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce) + cfg.router_z_loss * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1))
    )
    return y.reshape(B, S, D), aux
