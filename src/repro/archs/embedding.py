"""EmbeddingBag and sparse-feature tables — built from scratch on JAX.

JAX has no native ``EmbeddingBag`` and no CSR/CSC sparse (only BCOO), so the
recsys substrate implements the classic lookup stack directly:

  * one **concatenated table** ``[total_rows, dim]`` per model with per-slot
    row offsets — a single array row-shards cleanly over the ``model`` mesh
    axis (the classic vocab/row-sharded embedding layout; the lookup becomes
    a sharded gather = one all-to-all under GSPMD);
  * ``embedding_lookup``: fixed-slot features (one id per slot) via
    ``jnp.take``;
  * ``embedding_bag``: ragged multi-hot features via ``jnp.take`` +
    ``jax.ops.segment_sum`` (sum/mean combiners), the pattern shared with the
    GNN message-passing substrate;
  * hashed OOV folding so synthetic id streams can exceed table sizes safely.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.archs import layers


@dataclasses.dataclass(frozen=True)
class TableSpec:
    """Static layout of a model's concatenated embedding table."""

    slot_rows: tuple[int, ...]  # rows per feature slot
    dim: int

    @property
    def n_slots(self) -> int:
        return len(self.slot_rows)

    @property
    def total_rows(self) -> int:
        return int(sum(self.slot_rows))

    @property
    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.slot_rows)[:-1]]).astype(np.int64)

    def nbytes(self, dtype_bytes: int = 4) -> int:
        return self.total_rows * self.dim * dtype_bytes


def init_table(key, spec: TableSpec, dtype=jnp.float32) -> jax.Array:
    return layers.embed_init(key, spec.total_rows, spec.dim, dtype)


def fold_ids(ids: jax.Array, spec: TableSpec) -> jax.Array:
    """Per-slot modulo fold + offset into the concatenated table.

    ``ids: i32[..., n_slots]`` raw per-slot ids (any magnitude) ->
    global row indices into the ``[total_rows, dim]`` table.
    """
    rows = jnp.asarray(spec.slot_rows, dtype=jnp.int32)
    offs = jnp.asarray(spec.offsets, dtype=jnp.int32)
    return (ids.astype(jnp.int32) % rows) + offs


def embedding_lookup(table: jax.Array, ids: jax.Array, spec: TableSpec) -> jax.Array:
    """Fixed-slot lookup: ``ids [..., n_slots] -> [..., n_slots, dim]``."""
    return jnp.take(table, fold_ids(ids, spec), axis=0)


def embedding_bag(
    table: jax.Array,
    flat_ids: jax.Array,  # i32[nnz] global row indices (already folded)
    segment_ids: jax.Array,  # i32[nnz] output bag per id
    num_segments: int,
    *,
    weights: jax.Array | None = None,  # f32[nnz]
    combiner: str = "sum",
) -> jax.Array:
    """EmbeddingBag: ``out[b] = combine_{i: seg[i]==b} w_i * table[id_i]``."""
    vecs = jnp.take(table, flat_ids, axis=0)
    if weights is not None:
        vecs = vecs * weights[:, None].astype(vecs.dtype)
    s = jax.ops.segment_sum(vecs, segment_ids, num_segments=num_segments)
    if combiner == "sum":
        return s
    if combiner == "mean":
        ones = jnp.ones((flat_ids.shape[0], 1), vecs.dtype)
        if weights is not None:
            ones = weights[:, None].astype(vecs.dtype)
        cnt = jax.ops.segment_sum(ones, segment_ids, num_segments=num_segments)
        return s / jnp.maximum(cnt, 1e-9)
    raise ValueError(combiner)


def masked_mean_bag(vecs: jax.Array, mask: jax.Array) -> jax.Array:
    """Dense-layout bag: ``vecs [B, L, D]`` + ``mask [B, L]`` -> mean [B, D]."""
    m = mask.astype(vecs.dtype)[..., None]
    return (vecs * m).sum(axis=-2) / jnp.maximum(m.sum(axis=-2), 1e-9)


def criteo_like_rows(n_slots: int, *, big: int, medium: int, small: int, seed: int = 0) -> tuple[int, ...]:
    """A realistic skewed slot-size mix (a few huge id spaces, many small).

    Sizes round to multiples of 1024 so the concatenated table's row axis
    shards evenly over every production mesh (256- and 512-chip).
    """
    rng = np.random.default_rng(seed)
    sizes = []
    for i in range(n_slots):
        if i < max(1, n_slots // 8):
            sizes.append(big)
        elif i < n_slots // 2:
            sizes.append(medium)
        else:
            sizes.append(small)
    return tuple(max(1024, int(s * (0.5 + rng.random())) // 1024 * 1024) for s in sizes)
