"""GraphCast-style encode-process-decode GNN (assigned arch ``graphcast``).

JAX sparse is BCOO-only, so message passing is built directly on an
edge-index representation: per-edge gathers (``jnp.take``) + per-node
scatters (``jax.ops.segment_sum`` / ``segment_max``). This IS the system's
GNN substrate (kernel_taxonomy §GNN, SpMM regime) — the same segment machinery
backs the recsys EmbeddingBag.

Model: encoder (node/edge feature MLPs into d_hidden), ``n_layers``
InteractionNetwork processor blocks (edge update from [edge, src, dst] ->
aggregate to nodes -> node update, both residual), decoder (node MLP to
``n_vars`` outputs). Processor params are stacked and scanned — 16 layers
lower to one HLO loop body, which keeps the 512-device dry-run tractable.

Graphs are static-shape: ``(node_feats[N, F], edge_src[E], edge_dst[E],
node_mask[N], edge_mask[E])`` with padding. Four assigned shapes:
  full_graph_sm   full-batch small graph (2.7k nodes)
  minibatch_lg    fanout-sampled subgraphs from a 233k-node graph — the real
                  neighbor sampler lives in ``repro.data.graphs``
  ogb_products    full-batch 2.4M-node / 62M-edge graph (edge-sharded)
  molecule        128 small graphs batched block-diagonally + graph readout
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.archs import layers


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 16
    d_hidden: int = 512
    aggregator: str = "sum"  # sum | mean | max
    n_vars: int = 227  # output dim per node (GraphCast: weather variables)
    d_feat: int = 227  # input node feature dim (per shape)
    d_edge_feat: int = 4  # input edge feature dim (e.g. displacement vectors)
    mesh_refinement: int = 6  # used by the weather example's mesh builder
    graph_readout: bool = False  # molecule shape: per-graph output
    remat: str = "full"
    dtype: object = jnp.float32

    def n_params(self) -> int:
        h = self.d_hidden
        enc = self.d_feat * h + h + self.d_edge_feat * h + h
        proc = self.n_layers * ((3 * h) * h + h + h * h + h + (2 * h) * h + h + h * h + h)
        dec = h * self.n_vars + self.n_vars
        return enc + proc + dec


def _mlp2_params(key, d_in: int, d_hidden: int, d_out: int, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w1": layers.dense_init(k1, d_in, d_hidden, dtype),
        "b1": jnp.zeros((d_hidden,), dtype),
        "w2": layers.dense_init(k2, d_hidden, d_out, dtype),
        "b2": jnp.zeros((d_out,), dtype),
    }


def _mlp2(p, x):
    return jax.nn.silu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def init_gnn_params(key, cfg: GNNConfig):
    ke, kee, kp, kd = jax.random.split(key, 4)
    h = cfg.d_hidden

    def block_params(k):
        k1, k2 = jax.random.split(k)
        return {
            "edge": _mlp2_params(k1, 3 * h, h, h, cfg.dtype),
            "node": _mlp2_params(k2, 2 * h, h, h, cfg.dtype),
        }

    proc_keys = jax.random.split(kp, cfg.n_layers)
    return {
        "enc_node": _mlp2_params(ke, cfg.d_feat, h, h, cfg.dtype),
        "enc_edge": _mlp2_params(kee, cfg.d_edge_feat, h, h, cfg.dtype),
        "proc": jax.vmap(block_params)(proc_keys),  # leaves [L, ...]
        "dec": _mlp2_params(kd, h, h, cfg.n_vars, cfg.dtype),
    }


def abstract_gnn_params(cfg: GNNConfig):
    return jax.eval_shape(lambda: init_gnn_params(jax.random.PRNGKey(0), cfg))


def _aggregate(cfg: GNNConfig, msgs: jax.Array, dst: jax.Array, n_nodes: int) -> jax.Array:
    if cfg.aggregator == "sum":
        return jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    if cfg.aggregator == "mean":
        s = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
        c = jax.ops.segment_sum(jnp.ones((msgs.shape[0], 1), msgs.dtype), dst, num_segments=n_nodes)
        return s / jnp.maximum(c, 1.0)
    if cfg.aggregator == "max":
        return jax.ops.segment_max(msgs, dst, num_segments=n_nodes)
    raise ValueError(cfg.aggregator)


def gnn_forward(
    params,
    node_feats: jax.Array,  # f32[N, F]
    edge_src: jax.Array,  # i32[E]
    edge_dst: jax.Array,  # i32[E]
    cfg: GNNConfig,
    *,
    edge_feats: Optional[jax.Array] = None,  # f32[E, Fe]
    edge_mask: Optional[jax.Array] = None,  # bool[E] (padding)
    graph_ids: Optional[jax.Array] = None,  # i32[N] for graph readout
    n_graphs: int = 0,
) -> jax.Array:
    """Node outputs ``[N, n_vars]`` (or graph outputs ``[n_graphs, n_vars]``)."""
    from repro.distributed.sharding import act

    N = node_feats.shape[0]
    E = edge_src.shape[0]
    h = act(_mlp2(params["enc_node"], node_feats.astype(cfg.dtype)), "all", None)
    if edge_feats is None:
        edge_feats = jnp.zeros((E, cfg.d_edge_feat), cfg.dtype)
    e = act(_mlp2(params["enc_edge"], edge_feats.astype(cfg.dtype)), "all", None)
    if edge_mask is not None:
        e = jnp.where(edge_mask[:, None], e, 0.0)
        # padded edges point at node 0; zero messages keep them inert
        edge_src = jnp.where(edge_mask, edge_src, 0)
        edge_dst = jnp.where(edge_mask, edge_dst, 0)

    def block(carry, block_p):
        h, e = carry

        def inner(h, e, block_p):
            he_src = act(jnp.take(h, edge_src, axis=0), "all", None)
            he_dst = act(jnp.take(h, edge_dst, axis=0), "all", None)
            e_new = e + _mlp2(block_p["edge"], jnp.concatenate([e, he_src, he_dst], axis=-1))
            if edge_mask is not None:
                e_new = jnp.where(edge_mask[:, None], e_new, 0.0)
            e_new = act(e_new, "all", None)
            # constrain the scattered node aggregate: unconstrained, SPMD
            # materializes it replicated (2.4M x 512 f32 per layer on
            # ogb_products) and all-reduces it
            agg = act(_aggregate(cfg, e_new, edge_dst, N), "all", None)
            h_new = h + _mlp2(block_p["node"], jnp.concatenate([h, agg], axis=-1))
            return act(h_new, "all", None), e_new

        fn = inner if cfg.remat == "none" else jax.checkpoint(inner)
        h, e = fn(h, e, block_p)
        return (h, e), None

    (h, e), _ = jax.lax.scan(block, (h, e), params["proc"])
    if cfg.graph_readout:
        assert graph_ids is not None and n_graphs > 0
        pooled = jax.ops.segment_sum(h, graph_ids, num_segments=n_graphs)
        return _mlp2(params["dec"], pooled)
    return _mlp2(params["dec"], h)


def gnn_loss(params, batch, cfg: GNNConfig):
    """MSE regression loss (GraphCast trains on per-variable weather MSE)."""
    out = gnn_forward(
        params,
        batch["node_feats"],
        batch["edge_src"],
        batch["edge_dst"],
        cfg,
        edge_feats=batch.get("edge_feats"),
        edge_mask=batch.get("edge_mask"),
        graph_ids=batch.get("graph_ids"),
        n_graphs=int(batch["targets"].shape[0]) if cfg.graph_readout else 0,
    )
    tgt = batch["targets"].astype(jnp.float32)
    err = (out.astype(jnp.float32) - tgt) ** 2
    mask = batch.get("node_mask")
    if mask is not None and not cfg.graph_readout:
        err = err * mask[:, None]
        denom = jnp.maximum(mask.sum() * cfg.n_vars, 1.0)
    else:
        denom = float(err.size)
    loss = err.sum() / denom
    return loss, {"mse": loss}


def train_step_model_flops(cfg: GNNConfig, n_nodes: int, n_edges: int) -> float:
    """Useful FLOPs for one fwd+bwd step: 6 * (per-entity matmul work)."""
    h = cfg.d_hidden
    enc = n_nodes * cfg.d_feat * h + n_nodes * h * h + n_edges * cfg.d_edge_feat * h + n_edges * h * h
    per_layer = n_edges * (3 * h) * h + n_edges * h * h + n_nodes * (2 * h) * h + n_nodes * h * h
    dec = n_nodes * h * h + n_nodes * h * cfg.n_vars
    return 6.0 * (enc + cfg.n_layers * per_layer + dec)


# --------------------------------------------------------------------------
# weather-mesh builder (mesh_refinement) — used by the weather example
# --------------------------------------------------------------------------


def build_refined_mesh(refinement: int) -> tuple:
    """Icosahedral-style refined mesh (numpy, host side).

    Returns ``(n_nodes, edge_src, edge_dst)`` of the multilevel mesh graph.
    Node count follows 10 * 4^r + 2; edges connect each node to its ~6
    neighbors at the finest level plus coarse long-range edges — matching the
    connectivity *statistics* GraphCast's processor sees (the exact spherical
    geometry is irrelevant to the systems behaviour).
    """
    import numpy as np

    n = 10 * (4**refinement) + 2
    rng = np.random.default_rng(refinement)
    # 6-regular ring lattice + random long-range (coarse-level) shortcuts
    base = np.arange(n, dtype=np.int64)
    src, dst = [], []
    for d in (1, 2, 3):
        src.append(base)
        dst.append((base + d) % n)
    n_long = n // 2
    src.append(rng.integers(0, n, n_long))
    dst.append(rng.integers(0, n, n_long))
    s = np.concatenate(src)
    t = np.concatenate(dst)
    # symmetrize
    es = np.concatenate([s, t]).astype(np.int32)
    ed = np.concatenate([t, s]).astype(np.int32)
    return n, es, ed
