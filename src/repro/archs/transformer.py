"""Decoder-only transformer LM: the five assigned LM-family architectures.

Design targets (DESIGN.md §3, §5):
  * **scan-over-layers** with stacked parameter pytrees — keeps the lowered
    HLO size O(1) in depth so the 512-device dry-run of a 60-layer model
    compiles in tractable time, and gives remat a single natural boundary.
  * **heterogeneous attention** (gemma3's 5 local : 1 global interleave) via a
    *period/repeat* layout: layers are grouped into ``R`` repeats of a
    ``period``-long block; each position-in-period ``j`` has its own stacked
    params ``[R, ...]`` and its own KV-cache length (sliding-window layers
    keep a ring buffer of ``window`` slots, global layers keep the full
    sequence) — this is the sub-quadratic structure that makes ``long_500k``
    decode feasible.
  * **GQA/MQA** (all five archs), RoPE, SwiGLU dense FFN or top-k MoE FFN
    (granite 40e top-8, moonshot 64e top-6) with EP-shardable expert dispatch.
  * **chunked-vocab cross entropy**: the loss scans over token chunks so the
    [tokens, vocab] logit matrix is never materialized — required for
    minitron's 256k vocab at 1M tokens/step, and a §Perf lever everywhere.

Params are nested dicts of jnp arrays (no flax); sharding is annotated by the
caller through ``repro.distributed.sharding`` PartitionSpec trees that mirror
the param pytree structure.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.archs import layers
from repro.archs.layers import AttnDims, MoEConfig


# --------------------------------------------------------------------------
# config
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 10000.0
    # attention pattern: ``window_pattern`` is cycled over layers; entry 0
    # means global (full causal) attention, entry W>0 means sliding window W.
    window_pattern: tuple[int, ...] = (0,)
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    # remat policy for the scanned layer body: none | full | dots
    remat: str = "full"
    # attention KV-chunk size for the online-softmax path (0 = dense scores)
    attn_chunk: int = 0
    # vocab chunk for the scanned cross-entropy (0 = materialize logits)
    vocab_chunk: int = 0
    # sequence (context) parallelism: shard S over the model axis instead of
    # heads (long-context prefill where B is small and H*hd < n_model_chips)
    seq_shard: bool = False
    # data-parallel-dominant layout: batch shards over EVERY mesh axis and
    # activations stay unsharded in the feature dims. The right layout for
    # small models (<~8B): TP=16 activation all-reduces on a 1B model cost
    # ~30x its compute (measured on gemma3, EXPERIMENTS.md §Perf). Param/
    # optimizer-state leaves stay model-sharded (ZeRO) via the rule table.
    dp_layout: bool = False

    @property
    def dims(self) -> AttnDims:
        return AttnDims(self.n_heads, self.n_kv_heads, self.d_head)

    @property
    def period(self) -> int:
        return len(self.window_pattern)

    @property
    def repeats(self) -> int:
        return self.n_layers // self.period

    @property
    def remainder(self) -> int:
        return self.n_layers % self.period

    def layer_window(self, layer: int) -> int:
        return self.window_pattern[layer % self.period]

    def cache_len(self, j: int, seq_len: int) -> int:
        """KV-cache length for position-in-period j at a given context size."""
        w = self.window_pattern[j]
        return min(w, seq_len) if w > 0 else seq_len

    def n_params(self) -> int:
        """Total parameter count (exact, from the init shapes)."""
        d, hd = self.d_model, self.d_head
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.moe is not None:
            m = self.moe
            ffn = d * m.n_experts * (2 * m.d_expert_ff) + m.n_experts * m.d_expert_ff * d
            ffn += d * m.n_experts  # router
            if m.n_shared:
                ffn += 3 * d * m.d_expert_ff * m.n_shared
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d  # 2 rmsnorm scales
        embed = self.vocab * d
        head = 0 if self.tie_embeddings else self.vocab * d
        return self.n_layers * per_layer + embed + head + d  # final norm

    def n_active_params(self) -> int:
        """Active-per-token params (MoE: only routed top_k + shared experts)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        m = self.moe
        hd = self.d_head
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        ffn = 3 * d * m.d_expert_ff * (m.top_k + m.n_shared) + d * m.n_experts
        per_layer = attn + ffn + 2 * d
        embed = self.vocab * d
        head = 0 if self.tie_embeddings else self.vocab * d
        return self.n_layers * per_layer + embed + head + d


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _layer_params(key, cfg: LMConfig):
    """One transformer block's params."""
    ka, kf = jax.random.split(key)
    p = {
        "ln_attn": layers.rmsnorm_params(cfg.d_model, cfg.dtype),
        "ln_ffn": layers.rmsnorm_params(cfg.d_model, cfg.dtype),
        "attn": layers.attn_params(ka, cfg.d_model, cfg.dims, cfg.dtype),
    }
    if cfg.moe is not None:
        p["moe"] = layers.moe_params(kf, cfg.d_model, cfg.moe, cfg.dtype)
    else:
        p["mlp"] = layers.mlp_params(kf, cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def init_lm_params(key, cfg: LMConfig):
    """Stacked param pytree.

    ``params["blocks"]`` is a list of ``period`` pytrees whose leaves carry a
    leading ``[repeats]`` axis (scanned); ``params["tail"]`` is a list of
    ``remainder`` plain layer pytrees (unrolled).
    """
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    blocks = []
    for j in range(cfg.period):
        keys_j = layer_keys[j :: cfg.period][: cfg.repeats]
        stacked = jax.vmap(lambda k: _layer_params(k, cfg))(jnp.stack(keys_j)) if cfg.repeats else None
        blocks.append(stacked)
    tail = [
        _layer_params(layer_keys[cfg.repeats * cfg.period + t], cfg)
        for t in range(cfg.remainder)
    ]
    params = {
        "embed": layers.embed_init(k_embed, cfg.vocab, cfg.d_model, cfg.dtype),
        "blocks": blocks,
        "tail": tail,
        "ln_out": layers.rmsnorm_params(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = layers.dense_init(k_head, cfg.d_model, cfg.vocab, cfg.dtype)
    return params


def abstract_lm_params(cfg: LMConfig):
    """ShapeDtypeStruct pytree of the params (no allocation) — dry-run input."""
    return jax.eval_shape(lambda: init_lm_params(jax.random.PRNGKey(0), cfg))


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _block_body(p, x, cfg: LMConfig, window, positions, kv_override=None):
    """One transformer block. Returns (y, aux_loss, (k, v))."""
    h = layers.rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    attn_out, kv = _attn_with_kv(p["attn"], h, cfg, positions, window, kv_override)
    x = x + attn_out
    h = layers.rmsnorm(p["ln_ffn"], x, cfg.norm_eps)
    if cfg.moe is not None:
        ffn_out, aux = layers.moe(
            p["moe"], h, cfg.moe, token_axis="all" if cfg.dp_layout else "data"
        )
    else:
        ffn_out, aux = layers.mlp(p["mlp"], h), jnp.float32(0.0)
    return x + ffn_out, aux, kv


def _attn_with_kv(p, x, cfg: LMConfig, positions, window, kv_override):
    """Like layers.multihead_attention but also returns this step's (k, v)."""
    from repro.distributed.sharding import act

    dims = cfg.dims
    B, S, D = x.shape
    batch_tok = "all" if cfg.dp_layout else "data"
    seq_tok = "model" if cfg.seq_shard else None
    head_tok = None if (cfg.seq_shard or cfg.dp_layout) else "model"
    # constrain the MERGED projection dim (H*hd), not the 4D head axis: head
    # counts like 56 or 24 don't divide a 16-way model axis, but H*hd does —
    # uneven 4D constraints trigger SPMD involuntary-full-remat
    q = act(x @ p["wq"], batch_tok, seq_tok, head_tok).reshape(B, S, dims.n_heads, dims.d_head)
    k = act(x @ p["wk"], batch_tok, seq_tok, head_tok).reshape(B, S, dims.n_kv_heads, dims.d_head)
    v = act(x @ p["wv"], batch_tok, seq_tok, head_tok).reshape(B, S, dims.n_kv_heads, dims.d_head)
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None, :], (B, S))
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    k_att, v_att, kp_att = (k, v, positions) if kv_override is None else kv_override
    if cfg.attn_chunk and k_att.shape[1] > cfg.attn_chunk:
        out = layers._attention_chunked(
            q, k_att, v_att, positions, kp_att, dims, window, cfg.attn_chunk
        )
    else:
        out = layers._attention_dense(q, k_att, v_att, positions, kp_att, dims, window)
    return out.reshape(B, S, dims.n_heads * dims.d_head) @ p["wo"], (k, v)


def _remat_wrap(fn, cfg: LMConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # full


def lm_hidden_states(params, tokens: jax.Array, cfg: LMConfig) -> tuple[jax.Array, jax.Array]:
    """Token ids [B, S] -> final hidden states [B, S, D] (+ MoE aux loss).

    Full-sequence causal forward (training / prefill). Layers run as
    ``repeats`` scan steps of a ``period``-long unrolled block, then the
    remainder layers unrolled.
    """
    from repro.distributed.sharding import act

    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = params["embed"][tokens].astype(cfg.dtype)
    x = act(x, "all" if cfg.dp_layout else "data", "model" if cfg.seq_shard else None, None)

    def scan_step(carry, block_p):
        # remat at LAYER granularity: checkpointing the whole period block
        # keeps every layer's attention internals alive simultaneously during
        # the block backward (measured 80 GiB/chip on gemma3; §Perf)
        x, aux = carry
        for j in range(cfg.period):
            pj = jax.tree.map(lambda l: l[j], block_p) if cfg.period > 1 else block_p
            layer = lambda x, p, _j=j: _block_body(
                p, x, cfg, cfg.window_pattern[_j], positions
            )[:2]
            x, a = _remat_wrap(layer, cfg)(x, pj)
            aux = aux + a
        return (x, aux), None

    if cfg.repeats:
        if cfg.period > 1:
            # re-stack: list of per-j [R, ...] pytrees -> one pytree [R, period, ...]
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls, axis=1), *params["blocks"])
        else:
            stacked = params["blocks"][0]
        (x, aux), _ = jax.lax.scan(scan_step, (x, jnp.float32(0.0)), stacked)
    else:
        aux = jnp.float32(0.0)
    for t, p in enumerate(params["tail"]):
        j = t  # tail layers continue the pattern from position 0
        x, a = _remat_wrap(
            lambda x, p, _j=j: _block_body(p, x, cfg, cfg.window_pattern[_j], positions)[:2],
            cfg,
        )(x, p)
        aux = aux + a
    return layers.rmsnorm(params["ln_out"], x, cfg.norm_eps), aux


def _unembed(params, cfg: LMConfig):
    if cfg.tie_embeddings:
        return params["embed"].T  # [D, V]
    return params["unembed"]


def lm_logits(params, tokens: jax.Array, cfg: LMConfig) -> jax.Array:
    h, _ = lm_hidden_states(params, tokens, cfg)
    return (h @ _unembed(params, cfg)).astype(jnp.float32)


def lm_loss(params, tokens: jax.Array, labels: jax.Array, cfg: LMConfig):
    """Mean next-token cross entropy (+ MoE aux). Labels < 0 are masked.

    With ``cfg.vocab_chunk > 0`` the unembed projection + log-softmax run in a
    ``lax.scan`` over **sequence** chunks, so peak memory is
    ``B * chunk * vocab`` instead of ``B * S * vocab`` — the enabling trick
    for 256k-vocab training. Chunking the sequence axis (not flat tokens)
    keeps the batch axis dp-sharded through the scan: slicing a sharded axis
    would force SPMD to all-gather the whole [tokens, d] hidden tensor every
    step (measured 2 x 4.8 GB/step on gemma3 before this layout).
    """
    h, aux = lm_hidden_states(params, tokens, cfg)
    B, S, D = h.shape
    w = _unembed(params, cfg)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)

    def chunk_loss(hc, lc, vc):
        logits = (hc @ w).astype(jnp.float32)  # [B, chunk, V]
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via one-hot mask-sum, NOT take_along_axis: indexing a
        # vocab-sharded logits tensor makes SPMD all-gather the full [B,
        # chunk, V] f32 block per loss chunk (2.7 GB/chunk on moonshot);
        # the mask-sum reduces over the sharded axis locally + tiny psum
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.sum(jnp.where(vocab_iota == lc[..., None], logits, 0.0), axis=-1)
        return jnp.where(vc, logz - gold, 0.0)

    chunk = min(cfg.vocab_chunk, S) if cfg.vocab_chunk else 0
    if chunk and S > chunk and S % chunk == 0:
        n_chunks = S // chunk

        def to_chunks(x):  # [B, S, ...] -> [n_chunks, B, chunk, ...]
            xs = x.reshape((B, n_chunks, chunk) + x.shape[2:])
            return jnp.moveaxis(xs, 1, 0)

        def body(tot, xs):
            hc, lc, vc = xs
            return tot + chunk_loss(hc, lc, vc).sum(), None

        total, _ = jax.lax.scan(
            body, jnp.float32(0.0), (to_chunks(h), to_chunks(safe), to_chunks(valid))
        )
    else:
        total = chunk_loss(h, safe, valid).sum()
    n = jnp.maximum(valid.sum(), 1)
    return total / n + 0.01 * aux, {"xent": total / n, "aux": aux, "tokens": n}


# --------------------------------------------------------------------------
# KV cache: prefill & decode
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Static description of the KV cache for (cfg, max context)."""

    cfg: LMConfig
    batch: int
    seq_len: int  # max context the cache covers

    def lengths(self) -> list[int]:
        return [self.cfg.cache_len(j, self.seq_len) for j in range(self.cfg.period)]


def init_cache(spec: CacheSpec, dtype=None):
    """Zero cache pytree.

    Layout mirrors the param blocks: ``cache["blocks"][j]`` holds
    ``k/v: [R, B, Tj, K, hd]`` and ``pos: [R, B, Tj]`` (key positions; -1 =
    empty slot, masked out by causality). ``cache["tail"][t]`` the same
    without the leading R. Sliding-window layers get ``Tj = window`` ring
    buffers — the sub-quadratic memory structure for ``long_500k``.
    """
    cfg = spec.cfg
    dtype = dtype or cfg.dtype
    K, hd = cfg.n_kv_heads, cfg.d_head

    def one(r_axis: tuple, T: int):
        return {
            "k": jnp.zeros(r_axis + (spec.batch, T, K, hd), dtype),
            "v": jnp.zeros(r_axis + (spec.batch, T, K, hd), dtype),
            "pos": jnp.full(r_axis + (spec.batch, T), -1, jnp.int32),
        }

    blocks = [one((cfg.repeats,), spec.lengths()[j]) for j in range(cfg.period)]
    tail = [one((), spec.lengths()[t % cfg.period]) for t in range(cfg.remainder)]
    return {"blocks": blocks, "tail": tail}


def abstract_cache(spec: CacheSpec, dtype=None):
    return jax.eval_shape(lambda: init_cache(spec, dtype))


def _cache_update(entry, k_new, v_new, positions):
    """Write [B, S_new] keys/values into a ring-buffer cache entry.

    The refreshed entries are sharding-constrained (batch over data, cache
    positions over model) — without this the prefill scan materializes its
    per-layer cache outputs REPLICATED (measured 260 GiB/chip on yi-34b's
    60-layer 32k prefill; §Perf).
    """
    from repro.distributed.sharding import act

    T = entry["k"].shape[-3]
    slots = positions % T  # [B, S_new]
    b_idx = jnp.arange(k_new.shape[0], dtype=jnp.int32)[:, None]
    k = entry["k"].at[b_idx, slots].set(k_new.astype(entry["k"].dtype))
    v = entry["v"].at[b_idx, slots].set(v_new.astype(entry["v"].dtype))
    pos = entry["pos"].at[b_idx, slots].set(positions)
    return {
        "k": act(k, "data", "model", None, None),
        "v": act(v, "data", "model", None, None),
        "pos": act(pos, "data", "model"),
    }


def lm_decode_step(params, cache, tokens: jax.Array, pos: jax.Array, cfg: LMConfig):
    """One decode step: ``tokens [B, 1]`` at position ``pos [B]``.

    Returns (logits [B, vocab], new_cache). Attention reads the per-layer
    ring/full cache (ragged lengths across the period pattern); every layer
    writes its new KV in place. This is the ``decode_32k`` / ``long_500k``
    ``serve_step``.
    """
    B = tokens.shape[0]
    positions = pos[:, None].astype(jnp.int32)  # [B, 1]
    x = params["embed"][tokens].astype(cfg.dtype)

    def layer_with_cache(p, x, entry, j):
        h = layers.rmsnorm(p["ln_attn"], x, cfg.norm_eps)
        dims = cfg.dims
        q = (h @ p["attn"]["wq"]).reshape(B, 1, dims.n_heads, dims.d_head)
        k = (h @ p["attn"]["wk"]).reshape(B, 1, dims.n_kv_heads, dims.d_head)
        v = (h @ p["attn"]["wv"]).reshape(B, 1, dims.n_kv_heads, dims.d_head)
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
        new_entry = _cache_update(entry, k, v, positions)
        window = cfg.window_pattern[j]
        out = layers._attention_dense(
            q, new_entry["k"], new_entry["v"], positions, new_entry["pos"], dims, window
        )
        x = x + out.reshape(B, 1, dims.n_heads * dims.d_head) @ p["attn"]["wo"]
        h = layers.rmsnorm(p["ln_ffn"], x, cfg.norm_eps)
        if cfg.moe is not None:
            ffn_out, _ = layers.moe(p["moe"], h, cfg.moe)
        else:
            ffn_out = layers.mlp(p["mlp"], h)
        return x + ffn_out, new_entry

    new_blocks = []
    if cfg.repeats:
        # scan over repeats; unrolled over the period inside
        def step(x, xs):
            block_p, entries = xs
            new_entries = []
            for j in range(cfg.period):
                pj = jax.tree.map(lambda l: l[j], block_p) if cfg.period > 1 else block_p
                x, ne = layer_with_cache(pj, x, entries[j], j)
                new_entries.append(ne)
            return x, new_entries

        if cfg.period > 1:
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls, axis=1), *params["blocks"])
        else:
            stacked = params["blocks"][0]
        x, new_blocks = jax.lax.scan(step, x, (stacked, cache["blocks"]))
    new_tail = []
    for t, p in enumerate(params["tail"]):
        x, ne = layer_with_cache(p, x, cache["tail"][t], t % cfg.period)
        new_tail.append(ne)
    h = layers.rmsnorm(params["ln_out"], x, cfg.norm_eps)
    logits = (h[:, 0, :] @ _unembed(params, cfg)).astype(jnp.float32)
    return logits, {"blocks": new_blocks, "tail": new_tail}


def lm_prefill(params, tokens: jax.Array, cfg: LMConfig, cache_seq_len: int | None = None):
    """Full-sequence prefill producing (last-token logits, populated cache).

    The forward is the standard scanned causal pass; each layer's fresh KV is
    written into a cache sized for ``cache_seq_len`` (default: the prompt
    length) so decode can continue from it.
    """
    B, S = tokens.shape
    cache_seq_len = cache_seq_len or S
    spec = CacheSpec(cfg, B, cache_seq_len)
    positions = jnp.arange(S, dtype=jnp.int32)
    pos_b = jnp.broadcast_to(positions[None, :], (B, S))
    x = params["embed"][tokens].astype(cfg.dtype)
    cache = init_cache(spec)

    def scan_step(x, xs):
        block_p, entries = xs
        new_entries = []

        def inner(x, block_p, entries):
            out_entries = []
            for j in range(cfg.period):
                pj = jax.tree.map(lambda l: l[j], block_p) if cfg.period > 1 else block_p
                xj, _, (k, v) = _block_body(pj, x, cfg, cfg.window_pattern[j], positions)
                out_entries.append(_cache_update(entries[j], k, v, pos_b))
                x = xj
            return x, out_entries

        x, new_entries = _remat_wrap(inner, cfg)(x, block_p, entries)
        return x, new_entries

    if cfg.repeats:
        if cfg.period > 1:
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls, axis=1), *params["blocks"])
        else:
            stacked = params["blocks"][0]
        x, new_blocks = jax.lax.scan(scan_step, x, (stacked, cache["blocks"]))
    else:
        new_blocks = []
    new_tail = []
    for t, p in enumerate(params["tail"]):
        x, _, (k, v) = _block_body(p, x, cfg, cfg.window_pattern[t % cfg.period], positions)
        new_tail.append(_cache_update(cache["tail"][t], k, v, pos_b))
    h = layers.rmsnorm(params["ln_out"], x, cfg.norm_eps)
    logits = (h[:, -1, :] @ _unembed(params, cfg)).astype(jnp.float32)
    return logits, {"blocks": new_blocks, "tail": new_tail}


# --------------------------------------------------------------------------
# FLOPs accounting (roofline MODEL_FLOPS)
# --------------------------------------------------------------------------


def train_step_model_flops(cfg: LMConfig, batch: int, seq: int) -> float:
    """6 * N_active * D + attention quadratic term, for one train step."""
    n = cfg.n_active_params()
    d_tokens = batch * seq
    base = 6.0 * n * d_tokens
    # attention scores+AV: 2 * 2 * B * S * S_eff * H * hd * 3 (fwd+bwd)
    attn = 0.0
    for l in range(cfg.n_layers):
        w = cfg.layer_window(l)
        s_eff = min(w, seq) if w > 0 else seq
        attn += 2.0 * 2.0 * batch * seq * (s_eff / (1 if w else 2)) * cfg.n_heads * cfg.d_head
    return base + 3.0 * attn  # fwd + 2x bwd


def decode_step_model_flops(cfg: LMConfig, batch: int, context: int) -> float:
    """One-token decode: 2 * N_active + attention over the cache."""
    base = 2.0 * cfg.n_active_params() * batch
    attn = 0.0
    for l in range(cfg.n_layers):
        w = cfg.layer_window(l)
        s_eff = min(w, context) if w > 0 else context
        attn += 2.0 * 2.0 * batch * s_eff * cfg.n_heads * cfg.d_head
    return base + attn
