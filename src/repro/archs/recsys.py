"""The four assigned recsys architectures: DCN-v2, DIN, SASRec, Wide&Deep.

Each model is (huge row-sharded embedding table) -> (feature interaction) ->
(small MLP), per the recsys kernel regime. The embedding lookup is the hot
path and runs on the from-scratch EmbeddingBag substrate
(``repro.archs.embedding``). All four share:

  * ``init_params(key, cfg)`` / ``abstract_params(cfg)``
  * ``forward(params, batch, cfg) -> logits [B]``
  * ``loss(params, batch, cfg) -> (bce, metrics)``
  * ``score_candidates(params, batch, cfg) -> scores [n_cand]`` — the
    ``retrieval_cand`` path: ONE query scored against 10^6 candidates as a
    single batched contraction (never a loop), feeding the shared
    ``tiled_topk`` / ``block_topk`` kernel. For the additive sparse-linear
    models (Wide&Deep's wide part) this is exactly Eq. (1) of the paper, and
    the budgeted SAAT evaluator applies (DESIGN.md §4).

Batch layouts (all dense/static; see ``repro.configs``):
  dcn-v2     dense [B,13] f32, sparse [B,26] i32, label [B]
  din        hist [B,100] i32, hist_mask [B,100] bool, target [B] i32, label
  sasrec     seq [B,50] i32, pos [B,50] i32, neg [B,50] i32, mask [B,50]
  wide-deep  sparse [B,40] i32, label [B]
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.archs import layers
from repro.archs.embedding import TableSpec, embedding_lookup, fold_ids, init_table


# --------------------------------------------------------------------------
# configs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str  # dcn-v2 | din | sasrec | wide-deep
    table: TableSpec
    n_dense: int = 0
    mlp_dims: tuple[int, ...] = ()
    # dcn-v2
    n_cross_layers: int = 0
    # din
    attn_mlp_dims: tuple[int, ...] = ()
    seq_len: int = 0
    # sasrec
    n_blocks: int = 0
    n_heads: int = 1
    dtype: object = jnp.float32

    @property
    def embed_dim(self) -> int:
        return self.table.dim

    def n_params(self) -> int:
        import numpy as np

        p = abstract_params(self)
        return int(sum(np.prod(l.shape) for l in jax.tree.leaves(p)))


def _mlp_params(key, dims: Sequence[int], dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {"w": layers.dense_init(ks[i], dims[i], dims[i + 1], dtype), "b": jnp.zeros((dims[i + 1],), dtype)}
        for i in range(len(dims) - 1)
    ]


def _mlp_apply(ps, x, final_act: bool = False):
    for i, p in enumerate(ps):
        x = x @ p["w"] + p["b"]
        if i < len(ps) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# --------------------------------------------------------------------------
# init / forward per kind
# --------------------------------------------------------------------------


def init_params(key, cfg: RecsysConfig):
    kt, km, kx, ka, kw = jax.random.split(key, 5)
    p = {"table": init_table(kt, cfg.table, cfg.dtype)}
    d_embed_all = cfg.table.n_slots * cfg.embed_dim

    if cfg.kind == "dcn-v2":
        d0 = cfg.n_dense + d_embed_all
        cross_keys = jax.random.split(kx, cfg.n_cross_layers)
        p["cross"] = [
            {"w": layers.dense_init(cross_keys[i], d0, d0, cfg.dtype, scale=0.01), "b": jnp.zeros((d0,), cfg.dtype)}
            for i in range(cfg.n_cross_layers)
        ]
        p["deep"] = _mlp_params(km, (d0,) + cfg.mlp_dims, cfg.dtype)
        p["out"] = _mlp_params(kw, (d0 + cfg.mlp_dims[-1], 1), cfg.dtype)
    elif cfg.kind == "din":
        d = cfg.embed_dim
        p["attn"] = _mlp_params(ka, (4 * d,) + cfg.attn_mlp_dims + (1,), cfg.dtype)
        p["mlp"] = _mlp_params(km, (3 * d,) + cfg.mlp_dims + (1,), cfg.dtype)
    elif cfg.kind == "sasrec":
        d = cfg.embed_dim
        p["pos_embed"] = layers.embed_init(kx, cfg.seq_len, d, cfg.dtype)
        blk_keys = jax.random.split(km, cfg.n_blocks)
        dims = layers.AttnDims(cfg.n_heads, cfg.n_heads, d // cfg.n_heads)
        p["blocks"] = [
            {
                "ln1": layers.layernorm_params(d, cfg.dtype),
                "attn": layers.attn_params(blk_keys[i], d, dims, cfg.dtype),
                "ln2": layers.layernorm_params(d, cfg.dtype),
                "ffn": _mlp_params(jax.random.fold_in(blk_keys[i], 7), (d, d, d), cfg.dtype),
            }
            for i in range(cfg.n_blocks)
        ]
        p["ln_out"] = layers.layernorm_params(d, cfg.dtype)
    elif cfg.kind == "wide-deep":
        p["wide"] = (jax.random.normal(kw, (cfg.table.total_rows,), jnp.float32) * 1e-3).astype(cfg.dtype)
        p["deep"] = _mlp_params(km, (d_embed_all,) + cfg.mlp_dims + (1,), cfg.dtype)
    else:
        raise ValueError(cfg.kind)
    return p


def abstract_params(cfg: RecsysConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---- dcn-v2 ----------------------------------------------------------------


def _dcn_forward(p, dense, sparse, cfg: RecsysConfig):
    emb = embedding_lookup(p["table"], sparse, cfg.table)  # [B, S, D]
    x0 = jnp.concatenate([dense.astype(cfg.dtype), emb.reshape(emb.shape[0], -1)], axis=-1)
    x = x0
    for cp in p["cross"]:  # DCN-v2 full-matrix cross: x_{l+1} = x0 * (W x_l + b) + x_l
        x = x0 * (x @ cp["w"] + cp["b"]) + x
    deep = _mlp_apply(p["deep"], x0, final_act=True)
    return _mlp_apply(p["out"], jnp.concatenate([x, deep], axis=-1))[:, 0]


# ---- din -------------------------------------------------------------------


def _din_attention(p, hist_e, target_e, mask, cfg: RecsysConfig):
    """Target attention: score each history item against the target."""
    B, L, D = hist_e.shape
    t = jnp.broadcast_to(target_e[:, None, :], (B, L, D))
    feats = jnp.concatenate([hist_e, t, hist_e - t, hist_e * t], axis=-1)
    logits = _mlp_apply(p["attn"], feats)[..., 0]  # [B, L]
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)  # all-masked rows
    return jnp.einsum("bl,bld->bd", w.astype(hist_e.dtype), hist_e)


def _din_forward(p, hist, hist_mask, target, cfg: RecsysConfig):
    hist_rows = fold_ids(hist[..., None], cfg.table)[..., 0]
    tgt_rows = fold_ids(target[..., None], cfg.table)[..., 0]
    hist_e = jnp.take(p["table"], hist_rows, axis=0)  # [B, L, D]
    tgt_e = jnp.take(p["table"], tgt_rows, axis=0)  # [B, D]
    user = _din_attention(p, hist_e, tgt_e, hist_mask, cfg)
    x = jnp.concatenate([user, tgt_e, user * tgt_e], axis=-1)
    return _mlp_apply(p["mlp"], x)[:, 0]


# ---- sasrec ----------------------------------------------------------------


def _sasrec_hidden(p, seq, mask, cfg: RecsysConfig):
    B, L = seq.shape
    rows = fold_ids(seq[..., None], cfg.table)[..., 0]
    x = jnp.take(p["table"], rows, axis=0) + p["pos_embed"][None, :L, :]
    x = jnp.where(mask[..., None], x, 0.0)
    positions = jnp.arange(L, dtype=jnp.int32)
    dims = layers.AttnDims(cfg.n_heads, cfg.n_heads, cfg.embed_dim // cfg.n_heads)
    for blk in p["blocks"]:
        h = layers.layernorm(blk["ln1"], x)
        # SASRec uses causal self-attention without RoPE (learned positions)
        q = (h @ blk["attn"]["wq"]).reshape(B, L, dims.n_heads, dims.d_head)
        k = (h @ blk["attn"]["wk"]).reshape(B, L, dims.n_kv_heads, dims.d_head)
        v = (h @ blk["attn"]["wv"]).reshape(B, L, dims.n_kv_heads, dims.d_head)
        pos_b = jnp.broadcast_to(positions[None, :], (B, L))
        out = layers._attention_dense(q, k, v, pos_b, pos_b, dims, 0)
        x = x + out.reshape(B, L, -1) @ blk["attn"]["wo"]
        h = layers.layernorm(blk["ln2"], x)
        x = x + _mlp_apply(blk["ffn"], h, final_act=False)
        x = jnp.where(mask[..., None], x, 0.0)
    return layers.layernorm(p["ln_out"], x)  # [B, L, D]


def _sasrec_pair_logits(p, seq, mask, pos, neg, cfg: RecsysConfig):
    h = _sasrec_hidden(p, seq, mask, cfg)
    pe = jnp.take(p["table"], fold_ids(pos[..., None], cfg.table)[..., 0], axis=0)
    ne = jnp.take(p["table"], fold_ids(neg[..., None], cfg.table)[..., 0], axis=0)
    return jnp.sum(h * pe, -1), jnp.sum(h * ne, -1)  # [B, L] each


# ---- wide & deep -----------------------------------------------------------


def _wide_deep_forward(p, sparse, cfg: RecsysConfig):
    rows = fold_ids(sparse, cfg.table)  # [B, S]
    wide = jnp.take(p["wide"], rows, axis=0).sum(axis=-1)  # additive sparse linear
    emb = jnp.take(p["table"], rows, axis=0)  # [B, S, D]
    deep = _mlp_apply(p["deep"], emb.reshape(emb.shape[0], -1))[:, 0]
    return wide.astype(jnp.float32) + deep.astype(jnp.float32)


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------


def forward(params, batch, cfg: RecsysConfig) -> jax.Array:
    """Per-example logits [B] (sasrec: [B, L] positive logits)."""
    if cfg.kind == "dcn-v2":
        return _dcn_forward(params, batch["dense"], batch["sparse"], cfg)
    if cfg.kind == "din":
        return _din_forward(params, batch["hist"], batch["hist_mask"], batch["target"], cfg)
    if cfg.kind == "sasrec":
        pos_l, _ = _sasrec_pair_logits(
            params, batch["seq"], batch["mask"], batch["pos"], batch["neg"], cfg
        )
        return pos_l
    if cfg.kind == "wide-deep":
        return _wide_deep_forward(params, batch["sparse"], cfg)
    raise ValueError(cfg.kind)


def loss(params, batch, cfg: RecsysConfig):
    """BCE training loss (sasrec: pairwise BCE over pos/neg next items)."""
    if cfg.kind == "sasrec":
        pos_l, neg_l = _sasrec_pair_logits(
            params, batch["seq"], batch["mask"], batch["pos"], batch["neg"], cfg
        )
        m = batch["mask"].astype(jnp.float32)
        l = -jax.nn.log_sigmoid(pos_l) - jax.nn.log_sigmoid(-neg_l)
        total = (l * m).sum() / jnp.maximum(m.sum(), 1.0)
        return total, {"bce": total}
    logits = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    l = jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    total = l.mean()
    return total, {"bce": total, "mean_logit": logits.mean()}


def score_candidates(params, batch, cfg: RecsysConfig) -> jax.Array:
    """``retrieval_cand``: one query vs ``n_cand`` candidates, f32[n_cand].

    Candidates enter as raw slot-0/item ids; user-side features broadcast.
    Every model reduces to one batched contraction over the candidate axis.
    """
    cand = batch["candidates"]  # i32[n_cand]
    n = cand.shape[0]
    if cfg.kind == "sasrec":
        h = _sasrec_hidden(params, batch["seq"], batch["mask"], cfg)[:, -1, :]  # [1, D]
        ce = jnp.take(params["table"], fold_ids(cand[:, None], cfg.table)[..., 0], axis=0)
        return (ce @ h[0]).astype(jnp.float32)  # matvec over 1M candidates
    if cfg.kind == "din":
        from repro.distributed.sharding import act

        # score all candidates against one history: vectorize target axis;
        # every [n_cand, ...] broadcast must be constrained over the whole
        # mesh or SPMD replicates the [1M, 100, 4D] attention features
        hist_rows = fold_ids(batch["hist"][..., None], cfg.table)[..., 0]
        hist_e = jnp.take(params["table"], hist_rows, axis=0)  # [1, L, D]
        tgt_e = act(
            jnp.take(params["table"], fold_ids(cand[:, None], cfg.table)[..., 0], axis=0),
            "all", None,
        )
        hist_b = act(jnp.broadcast_to(hist_e, (n,) + hist_e.shape[1:]), "all", None, None)
        mask_b = jnp.broadcast_to(batch["hist_mask"], (n,) + batch["hist_mask"].shape[1:])
        user = _din_attention(params, hist_b, tgt_e, mask_b, cfg)
        x = act(jnp.concatenate([user, tgt_e, user * tgt_e], axis=-1), "all", None)
        return _mlp_apply(params["mlp"], x)[:, 0].astype(jnp.float32)
    if cfg.kind == "dcn-v2":
        dense = jnp.broadcast_to(batch["dense"], (n, batch["dense"].shape[-1]))
        sparse = jnp.broadcast_to(batch["sparse"], (n, batch["sparse"].shape[-1]))
        sparse = sparse.at[:, 0].set(cand)  # slot 0 = item id
        return _dcn_forward(params, dense, sparse, cfg).astype(jnp.float32)
    if cfg.kind == "wide-deep":
        sparse = jnp.broadcast_to(batch["sparse"], (n, batch["sparse"].shape[-1]))
        sparse = sparse.at[:, 0].set(cand)
        return _wide_deep_forward(params, sparse, cfg).astype(jnp.float32)
    raise ValueError(cfg.kind)


def retrieve_topk(params, batch, cfg: RecsysConfig, k: int = 100, num_tiles: int = 64):
    """score_candidates + the shared two-stage top-k (paper's top-k problem)."""
    from repro.core.topk import tiled_topk

    scores = score_candidates(params, batch, cfg)
    return tiled_topk(scores, k, num_tiles)


def train_step_model_flops(cfg: RecsysConfig, batch: int) -> float:
    """6 * active-params-excluding-table + lookup bytes don't count as FLOPs."""
    import numpy as np

    p = abstract_params(cfg)
    dense_params = sum(
        int(np.prod(l.shape))
        for path, l in jax.tree_util.tree_leaves_with_path(p)
        if "table" not in jax.tree_util.keystr(path) and "wide" not in jax.tree_util.keystr(path)
    )
    seq_mult = cfg.seq_len if cfg.kind in ("din", "sasrec") and cfg.seq_len else 1
    # MLP/cross work is per-example; DIN attention MLP runs per history item
    per_ex = dense_params * (seq_mult if cfg.kind == "din" else 1)
    if cfg.kind == "sasrec":
        per_ex = dense_params * cfg.seq_len
    return 6.0 * per_ex * batch
