"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step)::

    <root>/step_000123.tmp-<nonce>/   # written here first
        manifest.json                  # pytree paths, shapes, dtypes, meta
        shard_000.npz ... shard_NNN.npz
    <root>/step_000123/               # atomic os.replace on completion

Properties:
  * **atomic**: readers only ever see complete checkpoints (rename barrier);
    a crash mid-write leaves a ``.tmp-*`` turd that is skipped and GC'd.
  * **sharded**: leaves are packed into ~``shard_mb`` NPZ shards so very
    large states stream instead of one giant file; each leaf records its
    shard + key in the manifest.
  * **async**: ``save`` returns immediately; a writer thread drains a queue
    (training never blocks on I/O); ``wait()`` joins outstanding writes.
  * **elastic restore**: leaves are restored host-side, then ``device_put``
    onto the *target* mesh's shardings — the restoring job's mesh does not
    need to match the writer's (repro.distributed.elastic.reshard_state).
  * **self-describing**: the manifest stores the flattened key paths, so a
    restore can verify structural compatibility and report precise diffs.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import re
import shutil
import threading
import uuid
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _flatten_with_paths(tree) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), l) for p, l in leaves], treedef


@dataclasses.dataclass
class CheckpointManager:
    root: str
    keep: int = 3
    shard_mb: int = 128
    async_writes: bool = True

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._errors: list = []
        self._thread: Optional[threading.Thread] = None
        if self.async_writes:
            self._thread = threading.Thread(target=self._writer_loop, daemon=True)
            self._thread.start()

    # ----------------------------- write path -----------------------------

    def save(self, step: int, state: Any, meta: Optional[dict] = None) -> None:
        """Snapshot to host memory now; write (possibly async) afterwards."""
        paths, _ = _flatten_with_paths(state)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in paths]
        if self.async_writes:
            self._q.put((step, host, meta or {}))
        else:
            self._write(step, host, meta or {})

    def wait(self) -> None:
        if self.async_writes:
            self._q.join()
        if self._errors:
            raise RuntimeError(f"checkpoint writer failed: {self._errors[0]}")

    def _writer_loop(self):
        while True:
            item = self._q.get()
            try:
                self._write(*item)
            except Exception as e:  # surfaced by wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _write(self, step: int, host_leaves, meta: dict) -> None:
        final = os.path.join(self.root, f"step_{step:09d}")
        tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        limit = self.shard_mb * (1 << 20)
        shards: list[dict] = []
        cur: dict = {}
        cur_bytes = 0
        manifest_leaves = []
        for i, (key, arr) in enumerate(host_leaves):
            name = f"leaf_{i:05d}"
            if cur_bytes + arr.nbytes > limit and cur:
                shards.append(cur)
                cur, cur_bytes = {}, 0
            cur[name] = arr
            cur_bytes += arr.nbytes
            manifest_leaves.append(
                {
                    "path": key,
                    "shard": len(shards),
                    "name": name,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                }
            )
        if cur:
            shards.append(cur)
        for si, shard in enumerate(shards):
            np.savez(os.path.join(tmp, f"shard_{si:03d}.npz"), **shard)
        manifest = {"step": step, "leaves": manifest_leaves, "meta": meta}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.available_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"), ignore_errors=True)
        # remove stale tmp dirs from crashed writers
        for d in os.listdir(self.root):
            if ".tmp-" in d:
                full = os.path.join(self.root, d)
                try:
                    if os.path.getmtime(full) < __import__("time").time() - 3600:
                        shutil.rmtree(full, ignore_errors=True)
                except OSError:
                    pass

    # ----------------------------- read path ------------------------------

    def available_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.root, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        abstract_state: Any,
        step: Optional[int] = None,
        *,
        shardings: Any = None,
    ) -> tuple[Any, dict]:
        """Restore into the structure of ``abstract_state``.

        ``shardings`` (optional pytree of NamedSharding) places each leaf on
        the current mesh — pass a *different* mesh's shardings for an elastic
        restart. Returns (state, manifest_meta).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, treedef = _flatten_with_paths(abstract_state)
        want = [k for k, _ in paths]
        have = {l["path"]: l for l in manifest["leaves"]}
        missing = [k for k in want if k not in have]
        extra = [k for k in have if k not in want]
        if missing or extra:
            raise ValueError(
                f"checkpoint structure mismatch: missing={missing[:5]} extra={extra[:5]}"
            )
        cache: dict[int, Any] = {}

        def shard_file(si: int):
            if si not in cache:
                cache[si] = np.load(os.path.join(d, f"shard_{si:03d}.npz"))
            return cache[si]

        restored = []
        for k, ref in paths:
            entry = have[k]
            arr = shard_file(entry["shard"])[entry["name"]]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"shape mismatch at {k}: {arr.shape} vs {ref.shape}")
            restored.append(arr.astype(ref.dtype))
        state = jax.tree_util.tree_unflatten(treedef, restored)
        if shardings is not None:
            state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
        return state, manifest["meta"]

    # --------------------------- trainer hook ------------------------------

    def every_n_steps_hook(self, n: int, meta: Optional[dict] = None):
        def hook(step: int, state, metrics):
            if (step + 1) % n == 0:
                self.save(step + 1, state, {**(meta or {}), "metrics": metrics})

        return hook
