"""Arch registry: 10 assigned architectures + the paper's retrieval models.

``--arch <id>`` anywhere in the launchers resolves through ``ARCHS``.
"""
from repro.configs.base import (  # noqa: F401
    ArchSpec,
    Cell,
    GNN_SHAPES,
    LM_SHAPES,
    RECSYS_SHAPES,
    batch_specs,
)
from repro.configs import gnn_archs, lm_archs, recsys_archs

ARCHS: dict = {}
ARCHS.update(lm_archs.SPECS)
ARCHS.update(gnn_archs.SPECS)
ARCHS.update(recsys_archs.SPECS)


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells():
    """Every (arch, shape) pair, including documented skips."""
    out = []
    for aid, spec in ARCHS.items():
        for cell in spec.cells.values():
            out.append((aid, cell))
    return out
