"""GraphCast arch config (encode-process-decode mesh GNN).

The paper's technique (impact-quantized vocab-space retrieval) is NOT
applicable to a weather GNN — no bag-of-words scoring exists anywhere in
encode-process-decode; documented in DESIGN.md §4. The arch is implemented
in full *without* the technique and shares the generic substrate (trainer,
checkpointing, sharding, and the segment_sum machinery that also backs the
recsys EmbeddingBag).

``d_feat`` varies by assigned shape (input feature width of each dataset);
the processor (16 x 512, sum aggregator, 227 output vars) is the published
GraphCast configuration and never changes.
"""
from __future__ import annotations

import dataclasses

from repro.archs.gnn import GNNConfig
from repro.configs.base import ArchSpec, GNN_SHAPES, gnn_cells

GRAPHCAST = GNNConfig(
    name="graphcast",
    n_layers=16,
    d_hidden=512,
    aggregator="sum",
    n_vars=227,
    mesh_refinement=6,
)


def _config_for(shape: str) -> GNNConfig:
    import jax.numpy as jnp

    dims = GNN_SHAPES[shape]
    # bf16 compute: the dominant cost is moving the [N, 512] node array
    # through gathers/scatters every layer (unpartitioned message passing is
    # all-to-all by nature) — bf16 halves those bytes (§Perf #6)
    return dataclasses.replace(
        GRAPHCAST,
        d_feat=dims["d_feat"],
        graph_readout=(shape == "molecule"),
        dtype=jnp.bfloat16,
    )


def _smoke() -> GNNConfig:
    return dataclasses.replace(
        GRAPHCAST, n_layers=2, d_hidden=32, n_vars=5, d_feat=16, mesh_refinement=1
    )


SPECS = {
    "graphcast": ArchSpec(
        arch_id="graphcast",
        family="gnn",
        source="arXiv:2212.12794; unverified",
        config_for=_config_for,
        smoke_config=_smoke,
        cells=gnn_cells(),
    )
}
