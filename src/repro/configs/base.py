"""Arch/shape registry scaffolding.

Every assigned architecture ships one module defining ``SPEC: ArchSpec``:
  * ``config_for(shape)`` — the exact published config, tuned per shape only
    in *execution* knobs (attn chunking, vocab-chunked loss, seq sharding),
    never in model math;
  * ``smoke_config()`` — a reduced same-family config for CPU smoke tests;
  * ``cells`` — the assigned input shapes, each mapping to a step kind:
        train      train_step(state, batch)          (LM / GNN / recsys)
        prefill    prefill(params, tokens)           (LM)
        decode     decode_step(params, cache, t, pos)(LM)
        serve      forward(params, batch)            (recsys online/bulk)
        retrieval  retrieve_topk(params, batch)      (recsys 1 x 1M)
    Cells may be marked ``skip`` with a documented reason (DESIGN.md
    §Arch-applicability) — they count as cells but are not lowered.

``batch_specs(spec, shape)`` returns ShapeDtypeStruct stand-ins for every
model input — the dry-run lowers against these (no allocation ever).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass(frozen=True)
class Cell:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval
    dims: dict
    skip: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys
    source: str
    config_for: Callable[[str], Any]
    smoke_config: Callable[[], Any]
    cells: dict

    def runnable_cells(self) -> list:
        return [c for c in self.cells.values() if c.skip is None]


# --------------------------------------------------------------------------
# shared shape tables (the assignment's per-family shape sets)
# --------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256),
    "prefill_32k": dict(seq_len=32768, global_batch=32),
    "decode_32k": dict(seq_len=32768, global_batch=128),
    "long_500k": dict(seq_len=524288, global_batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433),
    "minibatch_lg": dict(
        n_nodes=232965, n_edges=114615892, batch_nodes=1024, fanout=(15, 10), d_feat=602
    ),
    "ogb_products": dict(n_nodes=2449029, n_edges=61859140, d_feat=100),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, d_feat=32),
}

RECSYS_SHAPES = {
    "train_batch": dict(batch=65536),
    "serve_p99": dict(batch=512),
    "serve_bulk": dict(batch=262144),
    "retrieval_cand": dict(batch=1, n_candidates=1_000_000),
}


def lm_cells(*, long_ok: bool, long_skip_reason: str = "") -> dict:
    kinds = {"train_4k": "train", "prefill_32k": "prefill", "decode_32k": "decode", "long_500k": "decode"}
    cells = {}
    for name, dims in LM_SHAPES.items():
        skip = None
        if name == "long_500k" and not long_ok:
            skip = long_skip_reason
        cells[name] = Cell(name=name, kind=kinds[name], dims=dims, skip=skip)
    return cells


def gnn_cells() -> dict:
    return {n: Cell(name=n, kind="train", dims=d) for n, d in GNN_SHAPES.items()}


def recsys_cells() -> dict:
    kinds = {
        "train_batch": "train",
        "serve_p99": "serve",
        "serve_bulk": "serve",
        "retrieval_cand": "retrieval",
    }
    return {n: Cell(name=n, kind=kinds[n], dims=d) for n, d in RECSYS_SHAPES.items()}


# --------------------------------------------------------------------------
# batch ShapeDtypeStructs per family/kind
# --------------------------------------------------------------------------


def lm_batch_specs(cell: Cell, cfg) -> dict:
    B, S = cell.dims["global_batch"], cell.dims["seq_len"]
    if cell.kind == "train":
        return {"tokens": sds((B, S), jnp.int32), "labels": sds((B, S), jnp.int32)}
    if cell.kind == "prefill":
        return {"tokens": sds((B, S), jnp.int32)}
    if cell.kind == "decode":
        from repro.archs.transformer import CacheSpec, abstract_cache

        cache = abstract_cache(CacheSpec(cfg, B, S))
        return {
            "tokens": sds((B, 1), jnp.int32),
            "pos": sds((B,), jnp.int32),
            "cache": cache,
        }
    raise ValueError(cell.kind)


def _pad512(n: int) -> int:
    """Graph arrays pad to 512-aligned sizes (masked) so node/edge axes can
    shard evenly on the 256/512-chip meshes — the assigned raw sizes (e.g.
    ogb_products' 2,449,029 nodes) divide nothing."""
    return (n + 511) // 512 * 512


def gnn_batch_specs(cell: Cell, cfg) -> dict:
    d = cell.dims
    if cell.name == "minibatch_lg":
        from repro.data.graphs import sampling_budget

        n_pad, e_pad = sampling_budget(d["batch_nodes"], d["fanout"])
        out = {
            "node_feats": sds((n_pad, d["d_feat"]), jnp.float32),
            "edge_src": sds((e_pad,), jnp.int32),
            "edge_dst": sds((e_pad,), jnp.int32),
            "edge_feats": sds((e_pad, cfg.d_edge_feat), jnp.float32),
            "edge_mask": sds((e_pad,), jnp.bool_),
            "node_mask": sds((n_pad,), jnp.float32),
            "targets": sds((n_pad, cfg.n_vars), jnp.float32),
        }
        return out
    if cell.name == "molecule":
        N = _pad512(d["batch"] * d["n_nodes"])
        E = _pad512(d["batch"] * d["n_edges"])
        return {
            "node_feats": sds((N, d["d_feat"]), jnp.float32),
            "edge_src": sds((E,), jnp.int32),
            "edge_dst": sds((E,), jnp.int32),
            "edge_feats": sds((E, cfg.d_edge_feat), jnp.float32),
            "edge_mask": sds((E,), jnp.bool_),
            "graph_ids": sds((N,), jnp.int32),
            "targets": sds((d["batch"], cfg.n_vars), jnp.float32),
        }
    N, E = _pad512(d["n_nodes"]), _pad512(d["n_edges"])
    return {
        "node_feats": sds((N, d["d_feat"]), jnp.float32),
        "edge_src": sds((E,), jnp.int32),
        "edge_dst": sds((E,), jnp.int32),
        "edge_feats": sds((E, cfg.d_edge_feat), jnp.float32),
        "edge_mask": sds((E,), jnp.bool_),
        "node_mask": sds((N,), jnp.float32),
        "targets": sds((N, cfg.n_vars), jnp.float32),
    }


def recsys_batch_specs(cell: Cell, cfg) -> dict:
    B = cell.dims["batch"]
    kind = cfg.kind
    if kind == "dcn-v2":
        base = {
            "dense": sds((B, cfg.n_dense), jnp.float32),
            "sparse": sds((B, cfg.table.n_slots), jnp.int32),
        }
    elif kind == "din":
        base = {
            "hist": sds((B, cfg.seq_len), jnp.int32),
            "hist_mask": sds((B, cfg.seq_len), jnp.bool_),
            "target": sds((B,), jnp.int32),
        }
    elif kind == "sasrec":
        base = {
            "seq": sds((B, cfg.seq_len), jnp.int32),
            "mask": sds((B, cfg.seq_len), jnp.bool_),
            "pos": sds((B, cfg.seq_len), jnp.int32),
            "neg": sds((B, cfg.seq_len), jnp.int32),
        }
    elif kind == "wide-deep":
        base = {"sparse": sds((B, cfg.table.n_slots), jnp.int32)}
    else:
        raise ValueError(kind)
    if cell.kind == "train":
        base["label"] = sds((B,), jnp.float32)
    if cell.kind == "retrieval":
        # pad 1,000,000 -> 512-aligned (1,000,448): the candidate axis then
        # shards over all 256/512 chips instead of the 16 data ranks
        # (1M % 256 != 0); padded slots repeat candidate 0, dropped post-topk
        n_cand = -(-cell.dims["n_candidates"] // 512) * 512
        base["candidates"] = sds((n_cand,), jnp.int32)
        base.pop("label", None)
        # retrieval uses user-side features only; sasrec/din drop pos/neg/target
        if kind == "sasrec":
            base.pop("pos"), base.pop("neg")
        if kind == "din":
            base.pop("target")
    return base


def batch_specs(spec: ArchSpec, shape_name: str) -> dict:
    cell = spec.cells[shape_name]
    cfg = spec.config_for(shape_name)
    return {"lm": lm_batch_specs, "gnn": gnn_batch_specs, "recsys": recsys_batch_specs}[
        spec.family
    ](cell, cfg)
