"""The five assigned LM-family architectures (exact published configs).

Execution knobs per shape (math-preserving):
  * ``train_4k``    attn_chunk=1024, vocab-chunked loss, full remat
  * ``prefill_32k`` attn_chunk=2048, sequence(context)-parallel over model
  * ``decode_32k``  dense one-token attention over the model-sharded cache
  * ``long_500k``   (gemma3 only) ring-buffer local layers + seq-sharded
                    global caches
``long_500k`` is SKIPPED for the four pure full-attention archs: a 512k KV
cache at every layer has no sub-quadratic structure to exploit (documented,
DESIGN.md §4). gemma3's 5:1 local:global interleave caps 5/6 of the layers at
the 1024-token window — that is its sub-quadratic structure.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.archs.layers import MoEConfig
from repro.archs.transformer import LMConfig
from repro.configs.base import ArchSpec, lm_cells

_LONG_SKIP = (
    "pure full-attention GQA arch: 512k KV at every layer has no sub-quadratic "
    "structure (no local:global interleave / SSM / linear attention) — skipped per "
    "assignment rules; see DESIGN.md §4"
)


# models under this size use the DP-dominant (ZeRO-3) layout for training.
# Measured §Perf: TP=16 activation all-reduces cost ~30x compute for a ~1B
# model and ~20x for yi-34b at 1M tokens/step — with a per-chip batch this
# large, FSDP weight-gathers + grad reduce beat TP for EVERY assigned LM, so
# the threshold covers all five (TP remains the decode/serving layout).
DP_LAYOUT_MAX_PARAMS = 1e11


def _shape_knobs(cfg: LMConfig, shape: str) -> LMConfig:
    dp = cfg.n_params() < DP_LAYOUT_MAX_PARAMS
    if shape == "train_4k":
        return dataclasses.replace(cfg, attn_chunk=1024, remat="full", dp_layout=dp)
    if shape == "prefill_32k":
        return dataclasses.replace(cfg, attn_chunk=2048, remat="none", seq_shard=True)
    if shape in ("decode_32k", "long_500k"):
        return dataclasses.replace(cfg, attn_chunk=0, remat="none")
    return cfg


def _smoke(cfg: LMConfig) -> LMConfig:
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, n_experts=4, top_k=2, d_expert_ff=32)
    return dataclasses.replace(
        cfg,
        n_layers=max(2, len(cfg.window_pattern)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_head=16,
        d_ff=128,
        vocab=512,
        moe=moe,
        dtype=jnp.float32,
        vocab_chunk=0,
        attn_chunk=0,
        remat="none",
    )


def _spec(cfg: LMConfig, source: str, long_ok: bool = False) -> ArchSpec:
    return ArchSpec(
        arch_id=cfg.name,
        family="lm",
        source=source,
        config_for=lambda shape, _c=cfg: _shape_knobs(_c, shape),
        smoke_config=lambda _c=cfg: _smoke(_c),
        cells=lm_cells(long_ok=long_ok, long_skip_reason=_LONG_SKIP),
    )


MINITRON_4B = LMConfig(
    name="minitron-4b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_head=128,
    d_ff=9216,
    vocab=256000,
    tie_embeddings=False,
    vocab_chunk=256,
)

YI_34B = LMConfig(
    name="yi-34b",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    vocab=64000,
    tie_embeddings=False,
    rope_theta=5_000_000.0,
    vocab_chunk=256,
)

GEMMA3_1B = LMConfig(
    name="gemma3-1b",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab=262144,
    tie_embeddings=True,
    # 5 local (sliding-window 1024) : 1 global, cycled; 26 = 4*6 + 2
    window_pattern=(1024, 1024, 1024, 1024, 1024, 0),
    rope_theta=1_000_000.0,
    vocab_chunk=256,
)

GRANITE_MOE = LMConfig(
    name="granite-moe-3b-a800m",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab=49155,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, d_expert_ff=512),
    vocab_chunk=256,
)

MOONSHOT_16B = LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab=163840,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert_ff=1408),
    vocab_chunk=256,
)

SPECS = {
    "minitron-4b": _spec(MINITRON_4B, "arXiv:2407.14679; hf"),
    "yi-34b": _spec(YI_34B, "arXiv:2403.04652; hf"),
    "gemma3-1b": _spec(GEMMA3_1B, "hf:google/gemma-3-1b-pt; unverified", long_ok=True),
    "granite-moe-3b-a800m": _spec(GRANITE_MOE, "hf:ibm-granite/granite-3.0-1b-a400m-base; hf"),
    "moonshot-v1-16b-a3b": _spec(MOONSHOT_16B, "hf:moonshotai/Moonlight-16B-A3B; hf"),
}
