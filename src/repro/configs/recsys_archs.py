"""The four assigned recsys architectures (exact published interaction configs).

Embedding-table row counts follow the 10^6-10^9 guidance with a realistic
skew (a few huge id spaces, many small) — the tables are the memory object
the row-sharding design exists for. The paper's technique applies to the
*scoring role*: ``retrieval_cand`` is exactly the top-k-under-budget problem
(Eq. 1 for the additive wide part), sharing the top-k kernels (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

from repro.archs.embedding import TableSpec, criteo_like_rows
from repro.archs.recsys import RecsysConfig
from repro.configs.base import ArchSpec, recsys_cells

DCN_V2 = RecsysConfig(
    name="dcn-v2",
    kind="dcn-v2",
    table=TableSpec(criteo_like_rows(26, big=10_000_000, medium=1_000_000, small=100_000), 16),
    n_dense=13,
    n_cross_layers=3,
    mlp_dims=(1024, 1024, 512),
)

DIN = RecsysConfig(
    name="din",
    kind="din",
    table=TableSpec((10_485_760,), 18),  # item/goods id space (10 * 2^20 rows)
    attn_mlp_dims=(80, 40),
    mlp_dims=(200, 80),
    seq_len=100,
)

SASREC = RecsysConfig(
    name="sasrec",
    kind="sasrec",
    table=TableSpec((3_145_728,), 50),  # 3 * 2^20 item rows
    n_blocks=2,
    n_heads=1,
    seq_len=50,
)

WIDE_DEEP = RecsysConfig(
    name="wide-deep",
    kind="wide-deep",
    table=TableSpec(criteo_like_rows(40, big=10_000_000, medium=1_000_000, small=50_000, seed=1), 32),
    mlp_dims=(1024, 512, 256),
)


def _smoke_table(cfg: RecsysConfig) -> RecsysConfig:
    small = TableSpec(tuple(min(r, 200) for r in cfg.table.slot_rows), cfg.table.dim)
    reduced = dataclasses.replace(cfg, table=small)
    if cfg.kind in ("din", "sasrec"):
        reduced = dataclasses.replace(reduced, seq_len=min(cfg.seq_len, 12))
    if cfg.mlp_dims:
        reduced = dataclasses.replace(reduced, mlp_dims=tuple(min(d, 64) for d in cfg.mlp_dims))
    return reduced


def _spec(cfg: RecsysConfig, source: str) -> ArchSpec:
    return ArchSpec(
        arch_id=cfg.name,
        family="recsys",
        source=source,
        config_for=lambda shape, _c=cfg: _c,
        smoke_config=lambda _c=cfg: _smoke_table(_c),
        cells=recsys_cells(),
    )


SPECS = {
    "dcn-v2": _spec(DCN_V2, "arXiv:2008.13535; paper"),
    "din": _spec(DIN, "arXiv:1706.06978; paper"),
    "sasrec": _spec(SASREC, "arXiv:1808.09781; paper"),
    "wide-deep": _spec(WIDE_DEEP, "arXiv:1606.07792; paper"),
}
