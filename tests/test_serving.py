"""Serving layer: anytime server, deadline->rho control, doc-sharded search."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exhaustive_search
from repro.core.saat import max_segments_per_term
from repro.metrics.latency import summarize_latencies
from repro.serving import (
    AnytimeServer,
    ServingConfig,
    make_sharded_serve_step,
    run_query_stream,
    shard_corpus,
    stack_indexes,
)


def test_server_exact_matches_exhaustive(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    srv = AnytimeServer(bm25_index, ServingConfig(k=10, rho_ladder=(10**9,), batch_size=8))
    scores, ids = run_query_stream(srv, qt, qw)
    ex = exhaustive_search(bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10)
    np.testing.assert_allclose(scores, np.asarray(ex.scores), rtol=1e-4, atol=1e-4)


def test_server_ladder_capped_at_exact(bm25_index):
    srv = AnytimeServer(bm25_index, ServingConfig(rho_ladder=(100, 10**9)))
    assert srv.rho_ladder[-1] == bm25_index.n_postings
    assert srv.rho_ladder[0] == 100


def test_deadline_controller_picks_rho(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    srv = AnytimeServer(
        bm25_index,
        ServingConfig(k=10, rho_ladder=(100, 1000, 10000), batch_size=8, deadline_ms=10.0),
    )
    srv.warmup(jnp.asarray(qt[:8]), jnp.asarray(qw[:8]))
    # an impossible deadline must select the smallest rho
    srv.cfg = ServingConfig(k=10, rho_ladder=(100, 1000, 10000), batch_size=8, deadline_ms=1e-9)
    assert srv.pick_rho() == srv.rho_ladder[0]
    # an infinite deadline must select the largest
    srv.cfg = ServingConfig(k=10, rho_ladder=(100, 1000, 10000), batch_size=8, deadline_ms=1e9)
    assert srv.pick_rho() == srv.rho_ladder[-1]


def test_latency_stats():
    s = summarize_latencies([1.0] * 98 + [10.0, 100.0])
    assert s.p50_ms == 1.0
    assert s.max_ms == 100.0
    assert s.tail_ratio > 5


@pytest.mark.serving
def test_search_batch_rejects_off_ladder_rho(bm25_index, bm25_queries):
    """rho=0 (or any off-ladder budget) must raise, not silently fall
    through to the deadline controller (the old `rho or pick_rho()` bug)."""
    qt, qw = bm25_queries
    srv = AnytimeServer(bm25_index, ServingConfig(k=5, rho_ladder=(100, 1000)))
    with pytest.raises(ValueError, match="ladder"):
        srv.search_batch(jnp.asarray(qt[:2]), jnp.asarray(qw[:2]), rho=0)
    with pytest.raises(ValueError, match="ladder"):
        srv.search_batch(jnp.asarray(qt[:2]), jnp.asarray(qw[:2]), rho=777)
    # a real ladder level is honored verbatim
    srv.search_batch(jnp.asarray(qt[:2]), jnp.asarray(qw[:2]), rho=100)
    assert srv._rhos[-2:] == [100, 100]


@pytest.mark.serving
def test_pick_rho_never_treats_uncalibrated_as_free(bm25_index):
    """An unmeasured level must not look free under a tight deadline."""
    srv = AnytimeServer(
        bm25_index, ServingConfig(rho_ladder=(100, 1000, 10**9), deadline_ms=1.0)
    )
    # nothing calibrated: fall back to the SMALLEST uncalibrated level, never
    # the 10M-posting one the old `pred == 0.0 -> fits` logic selected
    assert srv.pick_rho() == srv.rho_ladder[0]
    # calibrate only the smallest level, cheap enough to fit 1 ms
    srv._cost.us_per_mpost[srv.rho_ladder[0]] = 1.0
    srv._cost.last_update_s[srv.rho_ladder[0]] = 0.0
    # largest CALIBRATED fitting level wins over larger uncalibrated ones
    # (the never-measured exact level stays ineligible however cheap the
    # nearest-level extrapolation makes it look)
    assert srv.pick_rho() == srv.rho_ladder[0]
    # once the big level is measured as cheap, it becomes eligible
    srv._cost.us_per_mpost[srv.rho_ladder[-1]] = 1e-6
    assert srv.pick_rho() == srv.rho_ladder[-1]


@pytest.mark.serving
def test_pick_rho_deadline_override(bm25_index, bm25_queries):
    """The admission queue passes per-batch remaining budgets."""
    qt, qw = bm25_queries
    srv = AnytimeServer(bm25_index, ServingConfig(rho_ladder=(100, 1000, 10000)))
    srv.warmup(jnp.asarray(qt[:4]), jnp.asarray(qw[:4]))
    assert srv.pick_rho() == srv.rho_ladder[-1]  # cfg deadline None -> max
    assert srv.pick_rho(deadline_ms=1e-12) == srv.rho_ladder[0]
    assert srv.pick_rho(deadline_ms=1e9) == srv.rho_ladder[-1]
    assert srv.pick_rho(deadline_ms=None) == srv.rho_ladder[-1]


@pytest.mark.serving
def test_run_query_stream_ragged_final_batch(bm25_index, bm25_queries):
    """N % batch_size != 0: the padded-with-repeats tail must be dropped and
    the kept rows must equal serving everything in one batch."""
    qt, qw = bm25_queries
    N, bs = 10, 4  # final batch holds 2 real + 2 repeated rows
    srv = AnytimeServer(bm25_index, ServingConfig(k=10, rho_ladder=(10**9,), batch_size=bs))
    scores, ids = run_query_stream(srv, qt[:N], qw[:N])
    assert scores.shape == (N, 10) and ids.shape == (N, 10)
    one = srv.search_batch(jnp.asarray(qt[:N]), jnp.asarray(qw[:N]))
    np.testing.assert_array_equal(ids, np.asarray(one.doc_ids))
    np.testing.assert_array_equal(scores, np.asarray(one.scores))
    # the repeated pad rows were served but never reported
    assert len(srv._latencies_ms) == 12 + N  # 3 batches of 4, then the direct call


@pytest.mark.serving
def test_cost_model_ema_convergence_and_interpolation():
    from repro.metrics.latency import SimulatedClock
    from repro.serving.scheduler import _CostModel

    clock = SimulatedClock()
    m = _CostModel({}, alpha=0.5, clock=clock)
    assert m.predict_us(1_000_000) is None and not m.is_calibrated(1_000_000)
    # EMA converges to a shifted steady state
    m.update(1_000_000, 100.0)  # 100 us / Mpost
    assert m.predict_us(1_000_000) == pytest.approx(100.0)
    for _ in range(40):
        clock.advance(1.0)
        m.update(1_000_000, 300.0)
    assert m.predict_us(1_000_000) == pytest.approx(300.0, rel=1e-3)
    assert m.last_update_s[1_000_000] == pytest.approx(40.0)
    # one calibrated level: above it, clamp to that level's RATE; below it,
    # floor at the level's measured TOTAL — small batches still pay the full
    # launch/dispatch overhead, so rate-scaling 300 us down to 150 us was a
    # systematic under-prediction that admitted infeasible work
    assert m.predict_us(2_000_000) == pytest.approx(600.0, rel=1e-3)
    assert m.predict_us(500_000) == pytest.approx(300.0, rel=1e-3)
    # two calibrated levels: in-between rho interpolates TOTAL cost between
    # the bracketing levels instead of scaling the nearest level's rate —
    # the old rule predicted 8 * 500 = 4000 us for 8M, jumping wildly at the
    # nearest-level boundary; the interpolant is continuous across the ladder
    m.update(10_000_000, 5000.0)  # total 5000 us at 10M
    lo, hi = 300.0, 5000.0  # calibrated totals at 1M and 10M
    assert m.predict_us(8_000_000) == pytest.approx(lo + (hi - lo) * 7 / 9, rel=1e-3)
    assert m.predict_us(1_200_000) == pytest.approx(lo + (hi - lo) * 0.2 / 9, rel=1e-3)
    # calibrated levels predict exactly themselves (interpolant hits knots)
    assert m.predict_us(10_000_000) == pytest.approx(5000.0, rel=1e-3)
    # beyond the top level: clamp to the top level's rate
    assert m.predict_us(20_000_000) == pytest.approx(10_000.0, rel=1e-3)


@pytest.mark.serving
def test_cost_model_low_end_floors_at_boundary_total(bm25_index):
    """Seeding ONLY a high-rho level must not make small-rho work look
    fractionally cheap: a 100k-posting batch pays the same launch/dispatch
    overhead as the measured 5M-posting one, so its prediction floors at the
    boundary level's measured total instead of rate-scaling through the
    origin (the old rule predicted 5000 * 0.1/5 = 100 us and over-admitted)."""
    from repro.serving.scheduler import _CostModel

    m = _CostModel({}, alpha=0.5)
    m.update(5_000_000, 5000.0)  # measured 5000 us total at 5M postings
    # every rho at or below the only calibrated level predicts its total
    assert m.predict_us(5_000_000) == pytest.approx(5000.0)
    assert m.predict_us(1_000_000) == pytest.approx(5000.0)
    assert m.predict_us(100_000) == pytest.approx(5000.0)
    # above it still extrapolates by rate
    assert m.predict_us(10_000_000) == pytest.approx(10_000.0)

    # end to end: with only the big level measured as slow, a deadline that
    # the old origin-scaled estimate called feasible for the small level now
    # correctly falls back to the smallest rung instead of "fitting" rho=100
    srv = AnytimeServer(
        bm25_index, ServingConfig(rho_ladder=(100, 1000, 10**9), deadline_ms=1.0)
    )
    srv._cost.us_per_mpost[srv.rho_ladder[-1]] = 1e9  # seconds total: nothing fits
    srv._cost.last_update_s[srv.rho_ladder[-1]] = 0.0
    assert srv._cost.predict_us(100) == pytest.approx(
        srv._cost.predict_us(srv.rho_ladder[-1])
    )
    assert srv.pick_rho() == srv.rho_ladder[0]


def test_server_rejects_multi_trip_without_fused_chunk(bm25_index):
    """daat_trips_per_launch > 1 batches trips inside the fused kernel."""
    with pytest.raises(ValueError, match="daat_fused_chunk"):
        AnytimeServer(
            bm25_index,
            ServingConfig(engine="daat", daat_use_kernels=True, daat_trips_per_launch=4),
        )
    with pytest.raises(ValueError, match="daat_trips_per_launch"):
        AnytimeServer(
            bm25_index, ServingConfig(engine="daat", daat_trips_per_launch=0)
        )


def test_sharded_daat_rejects_multi_trip_without_fused_chunk(bm25_index):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="daat_fused_chunk"):
        make_sharded_serve_step(
            mesh, k=5, rho_per_shard=0, max_segs_per_term=0, docs_per_shard=100,
            engine="daat", max_bm_per_term=4, daat_use_kernels=True,
            daat_trips_per_launch=2,
        )
    with pytest.raises(ValueError, match="daat_trips_per_launch"):
        make_sharded_serve_step(
            mesh, k=5, rho_per_shard=0, max_segs_per_term=0, docs_per_shard=100,
            engine="daat", max_bm_per_term=4, daat_trips_per_launch=0,
        )


class _ScriptedClock:
    """Clock whose now() returns a scripted sequence (pads with the last)."""

    def __init__(self, times):
        self.times = list(times)
        self.i = 0

    def now(self) -> float:
        t = self.times[min(self.i, len(self.times) - 1)]
        self.i += 1
        return t


@pytest.mark.serving
def test_predict_service_ms_is_shape_keyed_not_linear_in_b(bm25_index, bm25_queries):
    """B=8 and B=32 flushes observing different wall times must produce
    different, NON-linear-in-B predictions (a batch is one executable; the
    old per-query EMA x n_queries over-predicted every large-shape flush)."""
    qt, qw = bm25_queries
    L = qt.shape[1]
    # scripted service times: the B=8 batch takes 10 ms, the B=32 batch 16
    # ms. A SAAT search_batch reads the clock exactly three times (start,
    # stop, cost-model calibration stamp) — the script covers two calls.
    clock = _ScriptedClock([0.0, 0.010, 0.010, 0.010, 0.026, 0.026])
    srv = AnytimeServer(
        bm25_index,
        ServingConfig(k=5, rho_ladder=(10**9,), lq_buckets=(L,)),
        clock=clock,
    )
    reps8 = np.resize(np.arange(qt.shape[0]), 8)
    reps32 = np.resize(np.arange(qt.shape[0]), 32)
    srv.search_batch(jnp.asarray(qt[reps8]), jnp.asarray(qw[reps8]))
    srv.search_batch(jnp.asarray(qt[reps32]), jnp.asarray(qw[reps32]))
    p8 = srv.predict_service_ms(8, L)
    p32 = srv.predict_service_ms(32, L)
    assert p8 == pytest.approx(10.0)
    assert p32 == pytest.approx(16.0)  # observed, NOT 4 * p8 = 40 ms
    assert p32 != pytest.approx(4 * p8)
    # nearest-shape fallback: a smaller unseen shape borrows the closest
    # executable's time unscaled (over-predicts, safe) ...
    assert srv.predict_service_ms(6, L) == pytest.approx(p8)
    # ... a LARGER unseen shape ratio-scales up (a conservative upper bound:
    # under-predicting an unmeasured big executable means late flushes)
    assert srv.predict_service_ms(40, L) == pytest.approx(p32 * 40 / 32)
    # an unseen bucket has no shapes: SAAT falls back to the rho model
    assert srv.predict_service_ms(8, L + 7) >= 0.0


@pytest.mark.serving
def test_observe_bucket_ms_ema_is_per_shape_and_per_rho():
    """EMAs for different shapes — and different rho levels — never mix:
    every SAAT ladder level is its own executable with its own wall time."""

    class _Srv(AnytimeServer):  # bypass engine setup; only the EMA matters
        def __init__(self):
            self.cfg = ServingConfig()
            self.rho_ladder = (100, 1000)
            self._bucket_ms = {}
            self._bucket_conf = {}

    srv = _Srv()
    srv._observe_bucket_ms(4, 8, 10.0, rho=1000)
    srv._observe_bucket_ms(4, 32, 16.0, rho=1000)
    srv._observe_bucket_ms(4, 8, 10.0, rho=1000)
    srv._observe_bucket_ms(4, 8, 2.0, rho=100)  # small budget, small time
    assert srv._bucket_ms[("saat", 4, 8, 1000)] == pytest.approx(10.0)
    assert srv._bucket_ms[("saat", 4, 32, 1000)] == pytest.approx(16.0)
    assert srv._bucket_ms[("saat", 4, 8, 100)] == pytest.approx(2.0)
    # default rho resolves to pick_rho() (= full ladder without a deadline)
    srv._observe_bucket_ms(4, 8, 10.0)
    assert srv._bucket_ms[("saat", 4, 8, 1000)] == pytest.approx(10.0)
    # predictions read the lane they were asked about, never a neighbor level
    assert srv.predict_service_ms(8, 4, rho=100) == pytest.approx(2.0)
    assert srv.predict_service_ms(8, 4, rho=1000) == pytest.approx(10.0)


def test_server_daat_engine_matches_exhaustive(bm25_index, bm25_queries):
    """engine='daat' serves the batched Block-Max engine, rank-safe."""
    qt, qw = bm25_queries
    srv = AnytimeServer(
        bm25_index,
        ServingConfig(k=10, batch_size=8, engine="daat", daat_est_blocks=2, daat_block_budget=2),
    )
    srv.warmup(jnp.asarray(qt[:8]), jnp.asarray(qw[:8]))
    scores, ids = run_query_stream(srv, qt, qw)
    ex = exhaustive_search(bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10)
    np.testing.assert_allclose(scores, np.asarray(ex.scores), rtol=1e-4, atol=1e-4)
    assert srv.stats().p50_ms > 0


def test_server_rejects_unknown_engine(bm25_index):
    with pytest.raises(ValueError, match="engine"):
        AnytimeServer(bm25_index, ServingConfig(engine="bmw"))


def test_server_rejects_fused_chunk_without_kernels(bm25_index):
    """daat_fused_chunk fuses the KERNEL chunk step; jnp mode has no fusion."""
    with pytest.raises(ValueError, match="daat_use_kernels"):
        AnytimeServer(
            bm25_index, ServingConfig(engine="daat", daat_fused_chunk=True)
        )


def test_daat_engine_rejects_explicit_rho(bm25_index, bm25_queries):
    """A SAAT budget passed to the daat engine is a caller bug, not a no-op."""
    qt, qw = bm25_queries
    srv = AnytimeServer(
        bm25_index,
        ServingConfig(k=10, engine="daat", daat_est_blocks=2, daat_block_budget=2),
    )
    with pytest.raises(ValueError, match="rho"):
        srv.search_batch(jnp.asarray(qt[:4]), jnp.asarray(qw[:4]), rho=100)


@pytest.mark.parametrize("n_shards", [1, 4])
def test_sharded_serve_matches_exhaustive(tiny_corpus, bm25_collection, bm25_index, bm25_queries, n_shards):
    """Doc-sharded SAAT with k-merge == global exhaustive oracle (1-dev mesh)."""
    enc = bm25_collection
    qt, qw = bm25_queries
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards, dps = shard_corpus(
        enc.doc_idx, enc.term_idx, enc.weights, tiny_corpus.n_docs, enc.n_terms, n_shards
    )
    stacked = stack_indexes(shards)
    # rho is a STATIC shape: it must cover the shard's postings for rank
    # safety but stay small (a huge literal materializes [rho]-sized arrays
    # per vmapped query)
    rho_exact = max(s.n_postings for s in shards)
    serve, _, _ = make_sharded_serve_step(
        mesh,
        k=10,
        rho_per_shard=rho_exact,
        max_segs_per_term=max(max_segments_per_term(s) for s in shards),
        docs_per_shard=dps,
    )
    with mesh:
        ss, si = serve(stacked, jnp.asarray(qt), jnp.asarray(qw))
    ex = exhaustive_search(bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ex.scores), rtol=1e-4, atol=1e-4)
    assert (np.asarray(si) == np.asarray(ex.doc_ids)).mean() > 0.95  # ties may permute


@pytest.mark.parametrize("n_shards", [1, 2])
def test_sharded_daat_serve_matches_exhaustive(
    tiny_corpus, bm25_collection, bm25_index, bm25_queries, n_shards
):
    """Doc-sharded batched DAAT with k-merge == global exhaustive oracle."""
    enc = bm25_collection
    qt, qw = bm25_queries
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards, dps = shard_corpus(
        enc.doc_idx, enc.term_idx, enc.weights, tiny_corpus.n_docs, enc.n_terms, n_shards
    )
    stacked = stack_indexes(shards)
    assert stacked.max_bm == max(s.max_bm for s in shards)  # build-time bound survives stacking
    serve, _, _ = make_sharded_serve_step(
        mesh,
        k=10,
        rho_per_shard=0,  # unused by the daat engine
        max_segs_per_term=0,
        docs_per_shard=dps,
        engine="daat",
        daat_est_blocks=2,
        daat_block_budget=2,
        max_bm_per_term=stacked.max_bm,
    )
    with mesh:
        ss, si = serve(stacked, jnp.asarray(qt), jnp.asarray(qw))
    ex = exhaustive_search(bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ex.scores), rtol=1e-4, atol=1e-4)
    # DAAT's incremental merge permutes ties more than a single top-k pass,
    # so demand only majority id agreement on top of the exact score parity
    assert (np.asarray(si) == np.asarray(ex.doc_ids)).mean() > 0.8


def test_sharded_daat_requires_static_bm_bound(bm25_index):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="max_bm_per_term"):
        make_sharded_serve_step(
            mesh, k=5, rho_per_shard=0, max_segs_per_term=0, docs_per_shard=100,
            engine="daat",
        )


def test_sharded_rho_budget_is_per_shard(tiny_corpus, bm25_collection):
    """A small per-shard budget bounds work identically on every shard."""
    enc = bm25_collection
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards, dps = shard_corpus(
        enc.doc_idx, enc.term_idx, enc.weights, tiny_corpus.n_docs, enc.n_terms, 2
    )
    stacked = stack_indexes(shards)
    serve, _, _ = make_sharded_serve_step(
        mesh, k=5, rho_per_shard=50,
        max_segs_per_term=max(max_segments_per_term(s) for s in shards),
        docs_per_shard=dps,
    )
    qt = jnp.asarray(np.array([[1, 2, 3]], dtype=np.int32))
    qw = jnp.asarray(np.ones((1, 3), np.float32))
    with mesh:
        ss, si = serve(stacked, qt, qw)
    assert ss.shape == (1, 5) and si.shape == (1, 5)


# ------------------------------------------------------------------------
# sharded-path correctness regressions: pad-doc leak, metadata threading,
# degenerate shard layouts
# ------------------------------------------------------------------------

_I32_MAX = np.iinfo(np.int32).max


def _hand_coo(postings):
    """postings: [(doc, term, weight), ...] -> parallel COO arrays."""
    d = np.array([p[0] for p in postings], dtype=np.int64)
    t = np.array([p[1] for p in postings], dtype=np.int64)
    w = np.array([p[2] for p in postings], dtype=np.float64)
    return d, t, w


def test_sharded_pad_docs_never_alias_real_ids():
    """k > live docs per shard: pad docs (score 0.0) used to survive the
    local top-k and globalize into the NEXT shard's real-id range. They must
    come out as explicit (-inf, INT32_MAX) sentinels instead."""
    from repro.core import build_impact_index

    # 5 docs, one distinct term each, descending weights; 3 shards of 2 =>
    # the final shard is short (1 live doc) AND every shard has fewer live
    # docs than k
    d, t, w = _hand_coo([(i, i, 5.0 - i) for i in range(5)])
    n_docs, n_terms, k = 5, 6, 8
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards, dps = shard_corpus(d, t, w, n_docs, n_terms, 3)
    stacked = stack_indexes(shards)
    serve, _, _ = make_sharded_serve_step(
        mesh,
        k=k,
        rho_per_shard=max(s.n_postings for s in shards),
        max_segs_per_term=max(max_segments_per_term(s) for s in shards),
        docs_per_shard=dps,
        n_docs_total=n_docs,
    )
    qt = jnp.asarray(np.arange(5, dtype=np.int32)[None, :])
    qw = jnp.ones((1, 5), jnp.float32)
    with mesh:
        ss, si = serve(stacked, qt, qw)
    ss, si = np.asarray(ss)[0], np.asarray(si)[0]
    oracle = build_impact_index(d, t, w, n_docs, n_terms)
    ex = exhaustive_search(oracle, qt, qw, k=n_docs)
    # the live prefix matches the unsharded oracle doc-for-doc ...
    np.testing.assert_allclose(ss[:n_docs], np.asarray(ex.scores)[0], rtol=1e-4, atol=1e-4)
    assert si[:n_docs].tolist() == np.asarray(ex.doc_ids)[0].tolist()
    # ... and the k - n_docs overflow slots are sentinels, NOT aliased docs
    assert np.all(si[n_docs:] == _I32_MAX)
    assert np.all(np.isneginf(ss[n_docs:]))
    assert len(set(si[:n_docs].tolist())) == n_docs  # no duplicate real ids


def test_sharded_meta_threads_real_build_constants(
    tiny_corpus, bm25_collection, bm25_index, bm25_queries
):
    """block_size=64 + non-unit quant scale: the per-shard indexes rebuilt
    inside the shard_map must carry the REAL build constants (the old
    hardcoded 128/1.0/8 mis-mapped block ids to doc ranges and broke the
    sharded DAAT engine on non-default corpora)."""
    enc = bm25_collection
    qt, qw = bm25_queries
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards, dps = shard_corpus(
        enc.doc_idx, enc.term_idx, enc.weights, tiny_corpus.n_docs, enc.n_terms, 2,
        block_size=64,
    )
    stacked = stack_indexes(shards)
    assert stacked.block_size == 64  # precondition: non-default build
    assert stacked.scale != 1.0  # precondition: non-unit quant scale
    serve, _, _ = make_sharded_serve_step(
        mesh,
        k=10,
        rho_per_shard=0,
        max_segs_per_term=0,
        docs_per_shard=dps,
        engine="daat",
        daat_est_blocks=2,
        daat_block_budget=2,
        max_bm_per_term=stacked.max_bm,
        n_docs_total=tiny_corpus.n_docs,
    )
    with mesh:
        ss, _ = serve(stacked, jnp.asarray(qt), jnp.asarray(qw))
    ex = exhaustive_search(bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ex.scores), rtol=1e-4, atol=1e-4)


def test_sharded_short_final_shard_matches_exhaustive(
    tiny_corpus, bm25_collection, bm25_index, bm25_queries
):
    """n_shards not dividing n_docs: the short final shard's out-of-corpus
    tail is masked via n_docs_total and results match the unsharded oracle."""
    enc = bm25_collection
    qt, qw = bm25_queries
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards, dps = shard_corpus(
        enc.doc_idx, enc.term_idx, enc.weights, tiny_corpus.n_docs, enc.n_terms, 3
    )
    assert 3 * dps > tiny_corpus.n_docs  # precondition: final shard is short
    stacked = stack_indexes(shards)
    serve, _, _ = make_sharded_serve_step(
        mesh,
        k=10,
        rho_per_shard=max(s.n_postings for s in shards),
        max_segs_per_term=max(max_segments_per_term(s) for s in shards),
        docs_per_shard=dps,
        n_docs_total=tiny_corpus.n_docs,
    )
    with mesh:
        ss, si = serve(stacked, jnp.asarray(qt), jnp.asarray(qw))
    ex = exhaustive_search(bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ex.scores), rtol=1e-4, atol=1e-4)
    assert np.asarray(si).max() < tiny_corpus.n_docs  # no out-of-corpus ids
    assert (np.asarray(si) == np.asarray(ex.doc_ids)).mean() > 0.95


def test_sharded_empty_shard_serves(tiny_corpus):
    """A shard whose COO mask is empty must build, stack, and serve — and the
    merge must match the unsharded oracle."""
    from repro.core import build_impact_index

    # postings only in docs 0..1; 2 shards of 2 => shard 1 is empty
    d, t, w = _hand_coo([(0, 0, 2.0), (0, 1, 1.0), (1, 2, 3.0)])
    n_docs, n_terms = 4, 5
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards, dps = shard_corpus(d, t, w, n_docs, n_terms, 2)
    assert shards[1].max_segs == 0  # precondition: second shard IS empty
    stacked = stack_indexes(shards)
    serve, _, _ = make_sharded_serve_step(
        mesh,
        k=n_docs,
        rho_per_shard=max(s.n_postings for s in shards),
        max_segs_per_term=max(1, max(max_segments_per_term(s) for s in shards)),
        docs_per_shard=dps,
        n_docs_total=n_docs,
    )
    qt = jnp.asarray(np.array([[0, 2]], dtype=np.int32))
    qw = jnp.ones((1, 2), jnp.float32)
    with mesh:
        ss, si = serve(stacked, qt, qw)
    ss, si = np.asarray(ss)[0], np.asarray(si)[0]
    oracle = build_impact_index(d, t, w, n_docs, n_terms)
    ex = exhaustive_search(oracle, qt, qw, k=n_docs)
    np.testing.assert_allclose(ss, np.asarray(ex.scores)[0], rtol=1e-4, atol=1e-4)
    assert si[0] == 1 and si[1] == 0  # scored docs lead; zero-score docs trail
    assert set(si.tolist()) == set(range(n_docs))
