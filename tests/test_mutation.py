"""Mutable index lifecycle suite: generation-handled indexes, delta
segments, tombstones, and hot-swap compaction.

The tentpole contract under test: for ANY mutation sequence, search results
over the live :class:`~repro.core.index_handle.IndexHandle` are bit-identical
(doc ids; scores to engine accumulation order) to a from-scratch rebuild of
the post-mutation corpus searched with the handle's full live mask — across
both engines and all kernel modes, including the sharded and pod serve
paths. The oracle here is the honest one: a host-side mirror of the raw
corpus (gid -> sparse vector) evolves alongside the handle, and the rebuild
quantizes the mirror from scratch on the handle's pinned grid.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import daat, saat
from repro.core.impact_index import build_impact_index
from repro.core.index_handle import IndexHandle
from repro.metrics.latency import SimulatedClock
from repro.serving import (
    AnytimeServer,
    CompactionPolicy,
    Compactor,
    MutationEvent,
    ServingConfig,
    replay_with_churn,
    shard_live_stack,
)
from repro.serving.pod import PodServer
from repro.serving.queue import AdmissionQueue, SurvivorPredictor
from repro.serving.scheduler import index_static_signature
from repro.serving.sharded import (
    make_sharded_serve_step,
    shard_corpus,
    stack_indexes,
)

pytestmark = pytest.mark.mutation


# ---------------------------------------------------------------------------
# mirror + oracle: the from-scratch rebuild the handle must reproduce
# ---------------------------------------------------------------------------


def _coo(seed=0, n_docs=80, n_terms=24, nnz=420):
    rng = np.random.default_rng(seed)
    d = rng.integers(0, n_docs, nnz).astype(np.int64)
    t = rng.integers(0, n_terms, nnz).astype(np.int64)
    w = rng.uniform(0.1, 5.0, nnz)
    _, ix = np.unique(d * n_terms + t, return_index=True)
    return d[ix], t[ix], w[ix]


class _Mirror:
    """Raw host-side corpus the handle's logical state must always equal."""

    def __init__(self, d, t, w, n_docs):
        self.docs: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        for gid in range(n_docs):
            sel = d == gid
            self.docs[gid] = (t[sel].copy(), w[sel].copy())
        self.next_gid = n_docs
        self.dead: set[int] = set()

    def add(self, terms, weights) -> int:
        gid = self.next_gid
        self.next_gid += 1
        self.docs[gid] = (np.asarray(terms), np.asarray(weights))
        return gid

    def update(self, gid, terms, weights):
        self.docs[gid] = (np.asarray(terms), np.asarray(weights))

    def delete(self, gid):
        self.dead.add(gid)

    def rebuild(self, handle: IndexHandle):
        """Build the post-mutation corpus from scratch on the pinned grid."""
        d, t, w = [], [], []
        for gid, (terms, weights) in self.docs.items():
            if gid in self.dead:
                continue
            d.append(np.full(terms.size, gid, np.int64))
            t.append(terms.astype(np.int64))
            w.append(np.asarray(weights, np.float64))
        index = build_impact_index(
            np.concatenate(d) if d else np.zeros(0, np.int64),
            np.concatenate(t) if t else np.zeros(0, np.int64),
            np.concatenate(w) if w else np.zeros(0, np.float64),
            self.next_gid,
            handle.n_terms,
            quant_max_weight=handle.quant_max_weight,
            block_size=handle.main.block_size,
        )
        live = handle.live_mask_full(int(index.doc_n_terms.shape[0]))
        return index, jnp.asarray(live)


def _mk(seed=0, n_docs=80, n_terms=24, block_size=16):
    d, t, w = _coo(seed, n_docs, n_terms)
    handle = IndexHandle.from_corpus(d, t, w, n_docs, n_terms, block_size=block_size)
    return handle, _Mirror(d, t, w, n_docs)


def _churn(handle, mirror, rng, n_ops=12, n_terms=24):
    """A deterministic add/update/delete sequence applied to both sides."""
    for _ in range(n_ops):
        op = rng.choice(["add", "update", "delete"], p=[0.4, 0.3, 0.3])
        alive = [g for g in mirror.docs if g not in mirror.dead]
        if not alive and op != "add":
            op = "add"
        if op == "add":
            n = int(rng.integers(2, 6))
            terms = rng.choice(n_terms, n, replace=False).astype(np.int64)
            weights = rng.uniform(0.2, 4.0, n)
            assert handle.add(terms, weights) == mirror.add(terms, weights)
        elif op == "update":
            gid = int(alive[int(rng.integers(len(alive)))])
            n = int(rng.integers(2, 6))
            terms = rng.choice(n_terms, n, replace=False).astype(np.int64)
            weights = rng.uniform(0.2, 4.0, n)
            handle.update(gid, terms, weights)
            mirror.update(gid, terms, weights)
        else:
            gid = int(alive[int(rng.integers(len(alive)))])
            handle.delete(gid)
            mirror.delete(gid)


def _queries(rng, n_terms, B=4, lq=5):
    qt = rng.integers(0, n_terms, (B, lq)).astype(np.int32)
    qw = rng.uniform(0.1, 2.0, (B, lq)).astype(np.float32)
    return jnp.asarray(qt), jnp.asarray(qw)


def _assert_parity(res, oracle_scores, oracle_ids, dead):
    s, i = np.asarray(res.scores), np.asarray(res.doc_ids)
    os_, oi = np.asarray(oracle_scores), np.asarray(oracle_ids)
    fin, fino = np.isfinite(s), np.isfinite(os_)
    np.testing.assert_array_equal(fin.sum(1), fino.sum(1))
    for b in range(s.shape[0]):
        m = fino[b]
        np.testing.assert_array_equal(i[b][m], oi[b][m])
        np.testing.assert_allclose(s[b][m], os_[b][m], rtol=1e-6, atol=1e-6)
        assert not np.isin(i[b][m], sorted(dead)).any()


# ---------------------------------------------------------------------------
# tentpole: handle search == from-scratch rebuild, every engine mode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "scatter_impl,fused_topk",
    [("jnp", False), ("sort", False), ("sort", True), ("jnp", True)],
)
def test_saat_parity_after_churn(scatter_impl, fused_topk):
    handle, mirror = _mk(seed=1)
    rng = np.random.default_rng(11)
    _churn(handle, mirror, rng)
    oracle, live = mirror.rebuild(handle)
    qt, qw = _queries(rng, handle.n_terms)
    k = 8
    res = handle.saat_search(
        qt, qw, k=k, scatter_impl=scatter_impl, fused_topk=fused_topk
    )
    ex = saat.saat_search(
        oracle, qt, qw, k=k, rho=saat.exact_rho(oracle),
        max_segs_per_term=saat.max_segments_per_term(oracle),
        scatter_impl=scatter_impl, fused_topk=fused_topk, live_mask=live,
    )
    _assert_parity(res, ex.scores, ex.doc_ids, mirror.dead)


@pytest.mark.parametrize(
    "use_kernels,fused_chunk,trips",
    [(False, False, 1), (True, False, 1), (True, True, 1), (True, True, 2)],
)
def test_daat_parity_after_churn(use_kernels, fused_chunk, trips):
    handle, mirror = _mk(seed=2)
    rng = np.random.default_rng(22)
    _churn(handle, mirror, rng)
    oracle, live = mirror.rebuild(handle)
    qt, qw = _queries(rng, handle.n_terms)
    k = 8
    res = handle.daat_search(
        qt, qw, k=k, est_blocks=4, block_budget=4, exact=True,
        use_kernels=use_kernels, fused_chunk=fused_chunk,
        trips_per_launch=trips,
    )
    ex = daat.daat_search_batched(
        oracle, qt, qw, k=k, est_blocks=4, block_budget=4,
        max_bm_per_term=daat.max_blocks_per_term(oracle), exact=True,
        use_kernels=use_kernels, fused_chunk=fused_chunk,
        trips_per_launch=trips, live_mask=live,
    )
    _assert_parity(res, ex.scores, ex.doc_ids, mirror.dead)


def test_parity_survives_compaction():
    """Compaction changes NO answer: same ids before and after the fold."""
    handle, mirror = _mk(seed=3)
    rng = np.random.default_rng(33)
    _churn(handle, mirror, rng)
    qt, qw = _queries(rng, handle.n_terms)
    before = handle.saat_search(qt, qw, k=8)
    gen = handle.generation
    handle.compact()
    assert handle.generation == gen + 1
    assert handle.delta_docs == 0 and handle.delta is None
    after = handle.saat_search(qt, qw, k=8)
    bs, bi = np.asarray(before.scores), np.asarray(before.doc_ids)
    as_, ai = np.asarray(after.scores), np.asarray(after.doc_ids)
    fin = np.isfinite(bs)
    np.testing.assert_array_equal(fin, np.isfinite(as_))
    np.testing.assert_array_equal(bi[fin], ai[fin])
    np.testing.assert_allclose(bs[fin], as_[fin], rtol=1e-6, atol=1e-6)
    # and the compacted corpus still equals the from-scratch rebuild
    oracle, live = mirror.rebuild(handle)
    ex = saat.saat_search(
        oracle, qt, qw, k=8, rho=saat.exact_rho(oracle),
        max_segs_per_term=saat.max_segments_per_term(oracle),
        live_mask=live,
    )
    _assert_parity(after, ex.scores, ex.doc_ids, mirror.dead)


# ---------------------------------------------------------------------------
# degenerate mutation states
# ---------------------------------------------------------------------------


def test_delete_all_then_compact_equals_empty_index():
    handle, mirror = _mk(seed=4, n_docs=10, n_terms=12)
    for gid in range(10):
        handle.delete(gid)
        mirror.delete(gid)
    handle.compact()
    assert handle.tombstone_count == 10 and handle.delta_docs == 0
    assert not np.asarray(handle.main.doc_n_terms).any()  # every row folded out
    rng = np.random.default_rng(44)
    qt, qw = _queries(rng, 12)
    res = handle.saat_search(qt, qw, k=4)
    assert not np.isfinite(np.asarray(res.scores)).any()
    # the compacted main IS the builder's empty-corpus branch: building the
    # same (empty) corpus from scratch yields a static-identical segment
    empty = build_impact_index(
        np.zeros(0, np.int64), np.zeros(0, np.int64), np.zeros(0, np.float64),
        10, 12, quant_max_weight=handle.quant_max_weight,
        block_size=handle.main.block_size,
    )
    assert index_static_signature(handle.main) == index_static_signature(empty)


def test_delta_only_corpus_empty_main():
    """A handle born over an empty corpus serves entirely from the delta."""
    handle = IndexHandle.from_corpus(
        np.zeros(0, np.int64), np.zeros(0, np.int64), np.zeros(0, np.float64),
        0, 12, block_size=16, quant_max_weight=5.0,
    )
    mirror = _Mirror(np.zeros(0, np.int64), np.zeros(0, np.int64),
                     np.zeros(0, np.float64), 0)
    rng = np.random.default_rng(55)
    for _ in range(5):
        n = int(rng.integers(2, 5))
        terms = rng.choice(12, n, replace=False).astype(np.int64)
        weights = rng.uniform(0.2, 4.0, n)
        assert handle.add(terms, weights) == mirror.add(terms, weights)
    oracle, live = mirror.rebuild(handle)
    qt, qw = _queries(rng, 12)
    res = handle.saat_search(qt, qw, k=4)
    ex = saat.saat_search(
        oracle, qt, qw, k=4, rho=saat.exact_rho(oracle),
        max_segs_per_term=saat.max_segments_per_term(oracle), live_mask=live,
    )
    _assert_parity(res, ex.scores, ex.doc_ids, mirror.dead)


def test_update_of_doc_already_in_delta():
    handle, mirror = _mk(seed=5, n_docs=20, n_terms=12)
    rng = np.random.default_rng(66)
    gid = handle.add(np.array([1, 3, 5]), np.array([1.0, 2.0, 3.0]))
    mirror.add(np.array([1, 3, 5]), np.array([1.0, 2.0, 3.0]))
    assert handle.delta_docs == 1
    up_w = np.array([5.0, 5.0])  # near the pinned grid max: lands in top-k
    handle.update(gid, np.array([2, 4]), up_w)
    mirror.update(gid, np.array([2, 4]), up_w)
    assert handle.delta_docs == 1  # replaced in place, not duplicated
    oracle, live = mirror.rebuild(handle)
    qt = jnp.asarray(np.array([[2, 4, 1]], np.int32))
    qw = jnp.asarray(np.array([[1.0, 1.0, 1.0]], np.float32))
    res = handle.saat_search(qt, qw, k=5)
    ex = saat.saat_search(
        oracle, qt, qw, k=5, rho=saat.exact_rho(oracle),
        max_segs_per_term=saat.max_segments_per_term(oracle), live_mask=live,
    )
    _assert_parity(res, ex.scores, ex.doc_ids, mirror.dead)
    assert int(gid) in np.asarray(res.doc_ids)


def test_tombstone_of_doc_only_in_delta():
    handle, mirror = _mk(seed=6, n_docs=20, n_terms=12)
    gid = handle.add(np.array([1, 2]), np.array([5.0, 5.0]))
    mirror.add(np.array([1, 2]), np.array([5.0, 5.0]))
    handle.delete(gid)
    mirror.delete(gid)
    assert handle.delta_docs == 0  # removed from the pending set entirely
    qt = jnp.asarray(np.array([[1, 2]], np.int32))
    qw = jnp.asarray(np.array([[1.0, 1.0]], np.float32))
    res = handle.saat_search(qt, qw, k=5)
    assert int(gid) not in np.asarray(res.doc_ids)
    oracle, live = mirror.rebuild(handle)
    ex = saat.saat_search(
        oracle, qt, qw, k=5, rho=saat.exact_rho(oracle),
        max_segs_per_term=saat.max_segments_per_term(oracle), live_mask=live,
    )
    _assert_parity(res, ex.scores, ex.doc_ids, mirror.dead)


# ---------------------------------------------------------------------------
# satellite: calibration decays — never resets — across a hot swap
# ---------------------------------------------------------------------------


def test_service_ema_decays_not_resets_on_swap():
    handle, _ = _mk(seed=7, n_docs=40, n_terms=12)
    cfg = ServingConfig(k=4, rho_ladder=(10**9,), lq_buckets=(4,), ema_alpha=0.3)
    srv = AnytimeServer(handle, cfg)
    srv._observe_bucket_ms(4, 2, 10.0)
    srv._observe_bucket_ms(4, 2, 20.0)
    key = next(iter(srv._bucket_ms))
    # steady state == the classic EMA, exactly (immutable-path regression)
    assert srv._bucket_ms[key] == pytest.approx(0.7 * 10.0 + 0.3 * 20.0)
    assert srv._bucket_conf[key] == pytest.approx(1.0)
    before = srv._bucket_ms[key]
    srv.swap_index(decay=0.5)
    # the VALUE survives the swap; only its trust is halved
    assert srv._bucket_ms[key] == before
    assert srv._bucket_conf[key] == pytest.approx(0.5)
    srv._observe_bucket_ms(4, 2, 30.0)
    a_eff = 0.3 + 0.7 * 0.5  # decayed confidence raises the effective alpha
    assert srv._bucket_ms[key] == pytest.approx((1 - a_eff) * before + a_eff * 30.0)
    # trust recovers toward 1 with every new observation
    assert srv._bucket_conf[key] == pytest.approx(1 - 0.5 * 0.7)
    # the rho cost model decayed alongside
    assert all(c == pytest.approx(1.0) or c == pytest.approx(0.5)
               for c in srv._cost.confidence.values())


def test_survivor_predictor_decays_not_resets():
    p = SurvivorPredictor(alpha=0.2)
    p.observe(4, 10.0)
    p.observe(4, 20.0)
    classic = 0.8 * 10.0 + 0.2 * 20.0
    assert p.predict(4) == pytest.approx(classic)
    p.decay(0.5)
    assert p.predict(4) == pytest.approx(classic)  # value kept
    p.observe(4, 40.0)
    a_eff = 0.2 + 0.8 * (1 - 0.5)  # decayed trust raises the effective alpha
    assert p.predict(4) == pytest.approx((1 - a_eff) * classic + a_eff * 40.0)


# ---------------------------------------------------------------------------
# hot swap under a running admission queue: zero lost / dup / reordered
# ---------------------------------------------------------------------------


def test_hot_swap_replay_loses_nothing():
    handle, mirror = _mk(seed=8, n_docs=60, n_terms=16)
    rng = np.random.default_rng(88)
    clock = SimulatedClock()
    cfg = ServingConfig(
        k=5, rho_ladder=(10**9,), lq_buckets=(5,), batch_size=4,
    )
    srv = AnytimeServer(handle, cfg, clock=clock)
    queue = AdmissionQueue(srv, batch_shapes=(2, 4), clock=clock, max_wait_s=0.02)
    compactor = Compactor(
        queue, handle, CompactionPolicy(max_delta_docs=3, min_tombstones=2,
                                        max_tombstone_frac=0.05),
    )
    n = 17  # deliberately not a multiple of any batch shape
    arrivals = np.cumsum(rng.uniform(0.004, 0.012, n))
    qts = [rng.integers(0, 16, 5).astype(np.int32) for _ in range(n)]
    qws = [rng.uniform(0.1, 2.0, 5).astype(np.float32) for _ in range(n)]
    muts = []
    mrng = np.random.default_rng(99)
    for i in range(8):
        t_s = float(arrivals[0] + (arrivals[-1] - arrivals[0]) * (i + 0.5) / 8)
        nterm = int(mrng.integers(2, 5))
        terms = mrng.choice(16, nterm, replace=False).astype(np.int64)
        weights = mrng.uniform(0.2, 4.0, nterm)
        muts.append(MutationEvent(t_s=t_s, op="add", terms=terms, weights=weights))
        mirror.add(terms, weights)
    completions, mlog = replay_with_churn(
        queue, handle, arrivals.tolist(), qts, qws, [50.0] * n, muts,
        compactor=compactor,
    )
    # zero lost, zero duplicated, zero reordered
    assert sorted(c.rid for c in completions) == list(range(n))
    assert len(completions) == n
    assert len(mlog) == len(muts)
    assert compactor.n_compactions >= 1
    assert handle.generation == compactor.n_compactions
    # generation is monotone non-decreasing across the flush log: swaps only
    # ever land BETWEEN flushes
    gens = [f.generation for f in queue.flush_log]
    assert gens == sorted(gens)
    assert gens[-1] == handle.generation
    # post-replay: the served corpus equals the from-scratch rebuild
    oracle, live = mirror.rebuild(handle)
    qt, qw = _queries(rng, 16)
    res = srv.search_batch(qt, qw)
    ex = saat.saat_search(
        oracle, qt, qw, k=5, rho=saat.exact_rho(oracle),
        max_segs_per_term=saat.max_segments_per_term(oracle), live_mask=live,
    )
    _assert_parity(res, ex.scores, ex.doc_ids, mirror.dead)


def test_executable_key_tracks_lifecycle_not_generation():
    handle, mirror = _mk(seed=9, n_docs=40, n_terms=12)
    cfg = ServingConfig(k=4, rho_ladder=(10**9,), lq_buckets=(4,))
    srv = AnytimeServer(handle, cfg)
    k0 = srv.executable_key(4, 2, srv.rho_ladder[-1])
    handle.add(np.array([1, 2]), np.array([1.0, 2.0]))
    k_delta = srv.executable_key(4, 2, srv.rho_ladder[-1])
    assert k_delta != k0  # delta present = genuinely different program
    handle.compact()
    srv.swap_index()
    k1 = srv.executable_key(4, 2, srv.rho_ladder[-1])
    assert srv.generation == 1
    assert k1 != k_delta  # delta folded away again
    # counters carry the lifecycle gauges
    reg = srv.export_counters()
    text = reg.render()
    for fam in ("repro_index_generation", "repro_index_tombstones",
                "repro_index_delta_docs"):
        assert fam in text


# ---------------------------------------------------------------------------
# sharded + pod serve paths
# ---------------------------------------------------------------------------


def test_sharded_live_masked_parity():
    """Tombstone-masked sharded serve == masked unsharded oracle (1-dev mesh)."""
    from jax.sharding import Mesh

    rng = np.random.default_rng(10)
    n_docs, n_terms = 80, 24
    d, t, w = _coo(seed=10, n_docs=n_docs, n_terms=n_terms)
    dead = sorted(rng.choice(n_docs, 17, replace=False).tolist())
    live_full = np.ones(n_docs, np.int32)
    live_full[dead] = 0
    shards, dps = shard_corpus(d, t, w, n_docs, n_terms, 2)
    stack = stack_indexes(shards)
    ls = shard_live_stack(
        live_full, n_shards=2, docs_per_shard=dps,
        n_docs_pad=int(stack.doc_n_terms.shape[1]),
    )
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    serve, _, _ = make_sharded_serve_step(
        mesh, k=8, rho_per_shard=max(s.n_postings for s in shards),
        max_segs_per_term=max(saat.max_segments_per_term(s) for s in shards),
        docs_per_shard=dps, n_docs_total=n_docs, live_masked=True,
    )
    qt, qw = _queries(rng, n_terms)
    with mesh:
        ss, si = serve(stack, qt, qw, live_stack=ls)
    oracle = build_impact_index(d, t, w, n_docs, n_terms)
    lm = np.zeros(int(oracle.doc_n_terms.shape[0]), np.int32)
    lm[:n_docs] = live_full
    ex = saat.saat_search(
        oracle, qt, qw, k=8, rho=saat.exact_rho(oracle),
        max_segs_per_term=saat.max_segments_per_term(oracle),
        live_mask=jnp.asarray(lm),
    )
    s1, i1 = np.asarray(ss), np.asarray(si)
    os_, oi = np.asarray(ex.scores), np.asarray(ex.doc_ids)
    fin, fino = np.isfinite(s1), np.isfinite(os_)
    np.testing.assert_array_equal(fin.sum(1), fino.sum(1))
    for b in range(s1.shape[0]):
        m = fino[b]
        np.testing.assert_array_equal(i1[b][m], oi[b][m])
        np.testing.assert_allclose(s1[b][m], os_[b][m], rtol=1e-6, atol=1e-6)
        assert not np.isin(i1[b][m], dead).any()


def test_pod_server_lifecycle_parity():
    """A 1x1 pod host with live mask + delta merge equals the handle."""
    from jax.sharding import Mesh

    handle, mirror = _mk(seed=12, n_docs=40, n_terms=16)
    rng = np.random.default_rng(12)
    d, t, w = _coo(seed=12, n_docs=40, n_terms=16)
    for gid in (2, 9):
        handle.delete(gid)
        mirror.delete(gid)
    for _ in range(2):
        n = int(rng.integers(2, 5))
        terms = rng.choice(16, n, replace=False).astype(np.int64)
        weights = rng.uniform(0.2, 4.0, n)
        assert handle.add(terms, weights) == mirror.add(terms, weights)
    qt, qw = _queries(rng, 16)
    k = 6
    oracle_res = handle.saat_search(qt, qw, k=k)

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("pod", "model"))
    shards, dps = shard_corpus(d, t, w, 40, 16, 1)
    stack = stack_indexes(shards)
    cfg = ServingConfig(k=k, rho_ladder=(10**9,), lq_buckets=(5,), batch_size=4)
    srv = PodServer(mesh, stack, cfg, docs_per_shard=dps, n_docs_total=40)
    ls = shard_live_stack(
        np.asarray(handle.live_mask)[:40], n_shards=1, docs_per_shard=dps,
        n_docs_pad=int(stack.doc_n_terms.shape[1]),
    )
    srv.set_lifecycle(
        live_stack=ls, delta=handle.delta, delta_gids=handle.delta_gids,
        generation=handle.generation,
    )
    res = srv.search_batch(qt, qw)
    _assert_parity(res, oracle_res.scores, oracle_res.doc_ids, mirror.dead)

    # compact + swap_stack: the pod host adopts the folded generation.
    # export_coo + the pinned grid keep the re-sharded impacts bit-identical
    # to the handle's main segment
    handle.compact()
    d2, t2, w2 = handle.export_coo()
    shards2, dps2 = shard_corpus(
        d2, t2, w2, handle.n_docs, 16, 1,
        quant_max_weight=handle.quant_max_weight,
    )
    stack2 = stack_indexes(shards2)
    ls2 = shard_live_stack(
        np.asarray(handle.live_mask)[: handle.n_docs], n_shards=1,
        docs_per_shard=dps2, n_docs_pad=int(stack2.doc_n_terms.shape[1]),
    )
    srv.swap_stack(
        stack2, live_stack=ls2, generation=handle.generation,
        docs_per_shard=dps2, n_docs_total=handle.n_docs,
    )
    assert srv.generation == handle.generation
    res2 = srv.search_batch(qt, qw)
    oracle2 = handle.saat_search(qt, qw, k=k)
    _assert_parity(res2, oracle2.scores, oracle2.doc_ids, mirror.dead)
