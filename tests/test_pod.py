"""Pod-scale serving suite: cross-host k-merge vs the unsharded oracle.

The acceptance bar is BIT-IDENTITY, not score parity: the pod step's
id-canonical merge must return exactly the doc ids the unsharded SAAT
oracle returns, ragged shard layouts and score ties included.

Run the full mesh grid under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI ``pod``
lane); on a plain 1-device CPU only the ``(1, 1)`` mesh cases run, so the
pod code path still executes in tier-1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import build_impact_index
from repro.core.saat import max_segments_per_term, saat_search
from repro.metrics.latency import SimulatedClock
from repro.serving import (
    PodFrontEnd,
    PodServer,
    ServingConfig,
    make_bucketed_serve_step,
    make_pod_serve_step,
    pod_hosts,
    shard_corpus,
    stack_indexes,
)

pytestmark = pytest.mark.pod


def _mesh(n_pod: int, n_model: int) -> Mesh:
    need = n_pod * n_model
    if jax.device_count() < need:
        pytest.skip(
            f"needs {need} devices (run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
    devs = np.array(jax.devices()[:need]).reshape(n_pod, n_model)
    return Mesh(devs, ("pod", "model"))


def _coo(seed=0, n_docs=37, n_terms=24, nnz=300):
    """Random deduplicated COO postings (ragged against most shard counts)."""
    rng = np.random.default_rng(seed)
    d = rng.integers(0, n_docs, nnz).astype(np.int32)
    t = rng.integers(0, n_terms, nnz).astype(np.int32)
    w = rng.uniform(0.1, 5.0, nnz).astype(np.float32)
    _, ix = np.unique(d.astype(np.int64) * n_terms + t, return_index=True)
    return d[ix], t[ix], w[ix], n_docs, n_terms


def _oracle(d, t, w, n_docs, n_terms, qt, qw, k):
    """Unsharded exact SAAT: one accumulator, one top-k (ties -> lower id)."""
    idx = build_impact_index(d, t, w, n_docs, n_terms)
    res = saat_search(
        idx, jnp.asarray(qt), jnp.asarray(qw), k=k,
        rho=idx.n_postings, max_segs_per_term=max_segments_per_term(idx),
    )
    return np.asarray(res.scores), np.asarray(res.doc_ids)


def _pod_step(mesh, shards, dps, n_docs, k, **kw):
    kw.setdefault("rho_per_shard", int(stack_indexes(shards).doc_ids.shape[1]))
    kw.setdefault(
        "max_segs_per_term", max(max_segments_per_term(s) for s in shards)
    )
    return make_pod_serve_step(
        mesh, k=k, docs_per_shard=dps, n_docs_total=n_docs, **kw
    )


# ---------------------------------------------------------------------------
# tentpole: pod merge == unsharded oracle, bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", [(1, 1), (1, 2), (2, 4), (4, 2), (8, 1)])
def test_pod_saat_bit_identical_to_oracle(layout):
    """Every (pod, model) mesh layout over a ragged corpus returns exactly
    the unsharded oracle's doc ids — scores and ids both."""
    n_pod, n_model = layout
    mesh = _mesh(n_pod, n_model)
    d, t, w, n_docs, n_terms = _coo()
    rng = np.random.default_rng(7)
    B, Lq, k = 8, 6, 10
    qt = rng.integers(0, n_terms, (B, Lq)).astype(np.int32)
    qw = rng.uniform(0.1, 2.0, (B, Lq)).astype(np.float32)
    os_, oi = _oracle(d, t, w, n_docs, n_terms, qt, qw, k)

    shards, dps = shard_corpus(d, t, w, n_docs, n_terms, n_pod * n_model)
    serve, _, _ = _pod_step(mesh, shards, dps, n_docs, k)
    ss, si = serve(stack_indexes(shards), jnp.asarray(qt), jnp.asarray(qw))
    np.testing.assert_allclose(np.asarray(ss), os_, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(si), oi)


@pytest.mark.parametrize("layout", [(1, 1), (2, 2)])
def test_pod_daat_bit_identical_distinct_scores(layout):
    """The DAAT engine under the pod merge: on a corpus whose per-doc scores
    are all distinct quant levels, exact Block-Max must return the oracle's
    ids bit-identically (no tie freedom to hide behind)."""
    n_pod, n_model = layout
    mesh = _mesh(n_pod, n_model)
    n_docs, n_terms = 23, 8
    d = np.arange(n_docs, dtype=np.int32)
    t = np.zeros(n_docs, dtype=np.int32)
    w = (d + 1).astype(np.float32) * 0.5  # doc-unique, quant-distinct
    B, k = 4, 6
    qt = np.full((B, 2), n_terms, np.int32)
    qt[:, 0] = 0
    qw = np.zeros((B, 2), np.float32)
    qw[:, 0] = np.linspace(0.5, 2.0, B, dtype=np.float32)
    os_, oi = _oracle(d, t, w, n_docs, n_terms, qt, qw, k)
    assert all(len(np.unique(row)) == k for row in os_)  # genuinely tie-free

    shards, dps = shard_corpus(d, t, w, n_docs, n_terms, n_pod * n_model)
    stacked = stack_indexes(shards)
    serve, _, _ = _pod_step(
        mesh, shards, dps, n_docs, k,
        rho_per_shard=0, max_segs_per_term=0, engine="daat",
        daat_est_blocks=2, daat_block_budget=2, max_bm_per_term=stacked.max_bm,
    )
    ss, si = serve(stacked, jnp.asarray(qt), jnp.asarray(qw))
    np.testing.assert_allclose(np.asarray(ss), os_, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(si), oi)


@pytest.mark.parametrize("layout", [(1, 1), (2, 1), (3, 1), (2, 2)])
def test_pod_merge_tie_order_all_equal_scores(layout):
    """Satellite: the merge canonicalizes ties to global-doc-id order.

    Every doc scores exactly 1.0, so the ENTIRE top-k is tie-broken. The
    unsharded oracle's top-k prefers lower accumulator position = lower doc
    id; the pod merge must agree bit-identically at 1, 2 and 3 hosts — the
    case the rank-concatenation merge order gets wrong (a sentinel or a
    higher-id doc on an earlier rank would outrank a lower-id doc)."""
    n_pod, n_model = layout
    mesh = _mesh(n_pod, n_model)
    n_docs, n_terms, k = 17, 4, 10
    d = np.arange(n_docs, dtype=np.int32)
    t = np.zeros(n_docs, dtype=np.int32)
    w = np.ones(n_docs, dtype=np.float32)
    B = 6  # divisible by 1, 2, 3 hosts
    qt = np.full((B, 2), n_terms, np.int32)
    qt[:, 0] = 0
    qw = np.zeros((B, 2), np.float32)
    qw[:, 0] = 1.0
    os_, oi = _oracle(d, t, w, n_docs, n_terms, qt, qw, k)
    np.testing.assert_array_equal(oi, np.tile(np.arange(k, dtype=np.int32), (B, 1)))

    shards, dps = shard_corpus(d, t, w, n_docs, n_terms, n_pod * n_model)
    serve, _, _ = _pod_step(mesh, shards, dps, n_docs, k)
    ss, si = serve(stack_indexes(shards), jnp.asarray(qt), jnp.asarray(qw))
    np.testing.assert_allclose(np.asarray(ss), os_, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(si), oi)


def test_pod_bucketed_routing_and_statics():
    """A mesh with a "pod" axis routes make_bucketed_serve_step to the pod
    step; the tagged statics carry the pod identity the lint bijection and
    counters consume, and results still match the oracle."""
    mesh = _mesh(1, 1)
    d, t, w, n_docs, n_terms = _coo(seed=2)
    shards, dps = shard_corpus(d, t, w, n_docs, n_terms, 1)
    stacked = stack_indexes(shards)
    k = 5
    serve, _, _ = make_bucketed_serve_step(
        mesh, lq_buckets=(4, 8), n_terms=n_terms, k=k,
        rho_per_shard=int(stacked.doc_ids.shape[1]),
        max_segs_per_term=max_segments_per_term(shards[0]),
        docs_per_shard=dps, n_docs_total=n_docs,
    )
    st = serve.statics
    assert st["pod_axes"] == ("pod", "model")  # merge spans the whole mesh
    assert st["pod_hosts"] == 1 and st["pod_model_ranks"] == 1
    assert st["merge_fanin"] == 1 * 1 * k

    rng = np.random.default_rng(3)
    qt = rng.integers(0, n_terms, (4, 3)).astype(np.int32)
    qw = rng.uniform(0.1, 2.0, (4, 3)).astype(np.float32)
    os_, oi = _oracle(d, t, w, n_docs, n_terms, qt, qw, k)
    ss, si = serve(stacked, qt, qw)
    np.testing.assert_allclose(np.asarray(ss), os_, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(si), oi)


# ---------------------------------------------------------------------------
# host side: PodServer / PodFrontEnd / counters
# ---------------------------------------------------------------------------


def _front(layout, n_shards=None, **queue_kwargs):
    mesh = _mesh(*layout)
    d, t, w, n_docs, n_terms = _coo(seed=5, n_docs=30, n_terms=16, nnz=200)
    shards, dps = shard_corpus(
        d, t, w, n_docs, n_terms, n_shards or layout[0] * layout[1]
    )
    cfg = ServingConfig(k=5, rho_ladder=(10**9,), lq_buckets=(4, 8), batch_size=4)
    queue_kwargs.setdefault("batch_shapes", (2, 4))
    queue_kwargs.setdefault("max_wait_s", 0.05)
    front = PodFrontEnd(
        mesh, stack_indexes(shards), cfg, docs_per_shard=dps,
        n_docs_total=n_docs, clock=SimulatedClock(),
        queue_kwargs=queue_kwargs,
    )
    return front, (d, t, w, n_docs, n_terms)


@pytest.mark.parametrize("layout", [(1, 1), (2, 2)])
def test_pod_front_end_end_to_end(layout):
    """Per-host admission queues over one mesh: every completion is
    bit-identical to the unsharded oracle, whichever host admitted it."""
    front, (d, t, w, n_docs, n_terms) = _front(layout)
    rng = np.random.default_rng(11)
    Q = 6
    queries, owners = [], {h: [] for h in range(front.n_hosts)}
    for i in range(Q):
        lq = int(rng.integers(2, 5))
        qt = rng.choice(n_terms, lq, replace=False).astype(np.int32)
        qw = rng.uniform(0.2, 2.0, lq).astype(np.float32)
        queries.append((qt, qw))
        host = i % front.n_hosts
        owners[host].append(i)
        front.submit(host, qt, qw, deadline_ms=50.0)

    comps = front.drain()
    assert len(comps) == Q and front.pending() == 0
    for host, c in comps:
        qt, qw = queries[owners[host][c.rid]]
        _, oi = _oracle(d, t, w, n_docs, n_terms, qt[None], qw[None], 5)
        np.testing.assert_array_equal(c.doc_ids, oi[0])


def test_pod_front_end_counters():
    """The merged scrape exposes queue families per host plus the pod
    dispatch/fan-in families, in Prometheus text exposition format."""
    front, _ = _front((1, 1))
    rng = np.random.default_rng(13)
    for i in range(4):
        qt = rng.choice(16, 3, replace=False).astype(np.int32)
        front.submit(0, qt, rng.uniform(0.2, 2.0, 3).astype(np.float32), 50.0)
    front.drain()
    reg = front.export_counters()
    text = reg.render()
    d = reg.as_dict()
    for fam in (
        "repro_queue_submitted_total",
        "repro_queue_completed_total",
        "repro_queue_flush_total",
        "repro_queue_violations_total",
        "repro_queue_served_rho_total",
        "repro_queue_flush_occupancy",
        "repro_queue_depth",
        "repro_pod_dispatch_total",
        "repro_pod_merge_fanin",
    ):
        assert fam in d, sorted(d)
    # queue families carry the host label
    sub = d["repro_queue_submitted_total"]["samples"]
    assert any(s["labels"].get("host") == "0" and s["value"] == 4 for s in sub)
    assert "# TYPE repro_pod_dispatch_total counter" in text
    assert 'repro_queue_submitted_total{host="0"} 4' in text
    assert text.endswith("\n")
    # fan-in gauge reports ranks * k
    fanin = [s["value"] for s in d["repro_pod_merge_fanin"]["samples"]]
    assert fanin and all(v == pod_hosts(front.mesh) * 1 * 5 for v in fanin)


def test_pod_server_rho_ladder_is_per_shard():
    """On a stacked index, n_postings is the SHARD count — the ladder must
    cap at the per-shard posting budget instead, topped by the exact level."""
    mesh = _mesh(1, 1)
    d, t, w, n_docs, n_terms = _coo(seed=4)
    shards, dps = shard_corpus(d, t, w, n_docs, n_terms, 1)
    stacked = stack_indexes(shards)
    cfg = ServingConfig(k=5, rho_ladder=(10, 10**9), lq_buckets=(4,))
    srv = PodServer(mesh, stacked, cfg, docs_per_shard=dps, n_docs_total=n_docs)
    exact = int(stacked.doc_ids.shape[1])
    assert srv.rho_ladder == (10, exact)
    assert srv.rho_ladder[-1] > stacked.n_postings  # would be shard count


def test_pod_server_executable_key_embeds_pod_identity():
    mesh = _mesh(1, 1)
    d, t, w, n_docs, n_terms = _coo(seed=6)
    shards, dps = shard_corpus(d, t, w, n_docs, n_terms, 1)
    cfg = ServingConfig(k=5, rho_ladder=(10**9,), lq_buckets=(4,))
    srv = PodServer(
        mesh, stack_indexes(shards), cfg, docs_per_shard=dps, n_docs_total=n_docs
    )
    key = srv.executable_key(4, 2, srv.rho_ladder[-1])
    assert key[0] == "pod" and key[1] == 1 and key[3] == dps
    other = PodServer(
        mesh, stack_indexes(shards), cfg, docs_per_shard=dps + 1,
        n_docs_total=n_docs,
    )
    assert other.executable_key(4, 2, other.rho_ladder[-1]) != key
