"""Unit coverage for ``repro.metrics.ir_metrics``: hand-computed goldens,
tie/degenerate behavior, and the k-larger-than-ranking edge every caller hits
when an index is smaller than the cutoff.

Rides in the ``analysis`` CI lane: pure numpy, no JAX, milliseconds.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.metrics.ir_metrics import (
    cheapest_rho_within_loss,
    effectiveness_report,
    mrr_at_k,
    ndcg_at_k,
    rank_overlap,
    recall_at_k,
)

pytestmark = pytest.mark.analysis


# --------------------------------- MRR ------------------------------------


def test_mrr_hand_computed():
    ranked = np.array([[3, 1, 2], [9, 8, 7], [5, 6, 4]])
    qrels = np.array([3, 7, 0])  # rank 1, rank 3, miss
    assert mrr_at_k(ranked, qrels, k=3) == pytest.approx((1.0 + 1 / 3 + 0.0) / 3)


def test_mrr_cutoff_drops_late_hits():
    ranked = np.array([[1, 2, 3, 4]])
    assert mrr_at_k(ranked, np.array([4]), k=3) == 0.0
    assert mrr_at_k(ranked, np.array([4]), k=4) == pytest.approx(0.25)


def test_mrr_duplicate_hit_counts_first_occurrence():
    # ties/duplicates in a ranking: the FIRST matching slot sets the rank
    ranked = np.array([[7, 7, 2]])
    assert mrr_at_k(ranked, np.array([7]), k=3) == 1.0


def test_mrr_k_exceeds_ranking_width():
    ranked = np.array([[5, 1]])
    assert mrr_at_k(ranked, np.array([1]), k=100) == pytest.approx(0.5)


# -------------------------------- recall ----------------------------------


def test_recall_hand_computed():
    ranked = np.array([[3, 1], [9, 8], [5, 6]])
    assert recall_at_k(ranked, np.array([1, 2, 5]), k=2) == pytest.approx(2 / 3)


def test_recall_cutoff():
    ranked = np.array([[3, 1, 4]])
    assert recall_at_k(ranked, np.array([4]), k=2) == 0.0
    assert recall_at_k(ranked, np.array([4]), k=3) == 1.0


def test_recall_k_exceeds_ranking_width():
    ranked = np.array([[3, 1]])
    assert recall_at_k(ranked, np.array([1]), k=1000) == 1.0


# --------------------------------- NDCG ------------------------------------


def test_ndcg_perfect_ranking_is_one():
    ranked = np.array([[4, 2, 9]])
    rels = np.array([[4, 2, 9]])
    gains = np.array([[3.0, 2.0, 1.0]])  # already descending = ideal order
    assert ndcg_at_k(ranked, rels, k=3, qrel_gains=gains) == pytest.approx(1.0)


def test_ndcg_hand_computed_binary():
    # one query, judged {5, 7}, ranking hits them at ranks 1 and 3
    ranked = np.array([[5, 2, 7]])
    rels = np.array([[5, 7]])
    dcg = 1.0 / np.log2(2) + 1.0 / np.log2(4)
    idcg = 1.0 / np.log2(2) + 1.0 / np.log2(3)
    assert ndcg_at_k(ranked, rels, k=3) == pytest.approx(dcg / idcg)


def test_ndcg_graded_order_matters():
    # swapping a high-gain doc behind a low-gain one must strictly lower NDCG
    rels = np.array([[1, 2]])
    gains = np.array([[3.0, 1.0]])
    good = ndcg_at_k(np.array([[1, 2]]), rels, k=2, qrel_gains=gains)
    bad = ndcg_at_k(np.array([[2, 1]]), rels, k=2, qrel_gains=gains)
    assert good == pytest.approx(1.0)
    assert bad < good


def test_ndcg_single_qrel_1d_matches_mrr_shape_convention():
    # 1-D qrels (MS MARCO style): same call shape as mrr_at_k/recall_at_k
    ranked = np.array([[3, 1, 2], [9, 8, 7]])
    got = ndcg_at_k(ranked, np.array([1, 7]), k=3)
    want = (1.0 / np.log2(3) + 1.0 / np.log2(4)) / 2  # ranks 2 and 3, idcg=1
    assert got == pytest.approx(want)


def test_ndcg_padded_qrels_ignored():
    # -1 pads must contribute nothing, even with nonzero gain in the pad slot
    ranked = np.array([[5, 2]])
    with_pad = ndcg_at_k(
        ranked, np.array([[5, -1]]), k=2, qrel_gains=np.array([[2.0, 9.0]])
    )
    without = ndcg_at_k(ranked, np.array([[5]]), k=2, qrel_gains=np.array([[2.0]]))
    assert with_pad == pytest.approx(without) == pytest.approx(1.0)


def test_ndcg_no_judged_docs_scores_zero():
    # all-pad query contributes 0, not NaN — adding it halves the mean
    ranked = np.array([[1, 2], [3, 4]])
    rels = np.array([[1, -1], [-1, -1]])
    assert ndcg_at_k(ranked, rels, k=2) == pytest.approx(0.5)


def test_ndcg_k_exceeds_ranking_and_judgments():
    ranked = np.array([[5, 9]])
    assert ndcg_at_k(ranked, np.array([[9, 5]]), k=50) == pytest.approx(1.0)


def test_ndcg_gain_shape_mismatch_raises():
    with pytest.raises(ValueError, match="qrel_gains"):
        ndcg_at_k(np.array([[1]]), np.array([[1, 2]]), qrel_gains=np.array([[1.0]]))


# ------------------------------ rank overlap --------------------------------


def test_rank_overlap_permutation_invariant():
    a = np.array([[1, 2, 3], [4, 5, 6]])
    b = np.array([[3, 1, 2], [4, 5, 9]])
    assert rank_overlap(a, b, k=3) == pytest.approx((1.0 + 2 / 3) / 2)


def test_rank_overlap_disjoint_is_zero():
    assert rank_overlap(np.array([[1, 2]]), np.array([[3, 4]]), k=2) == 0.0


# ------------------- effectiveness harness (numpy parts) --------------------


def test_effectiveness_report_triple_and_cutoffs():
    ranked = np.array([[3, 1, 2], [9, 8, 7]])
    qrels = np.array([1, 9])  # ranks 2 and 1
    rep = effectiveness_report(ranked, qrels, recall_k=2, mrr_k=2, ndcg_k=2)
    assert rep["mrr"] == pytest.approx((0.5 + 1.0) / 2)
    assert rep["recall"] == pytest.approx(1.0)
    assert 0.0 < rep["ndcg"] <= 1.0
    assert (rep["mrr_k"], rep["recall_k"], rep["ndcg_k"]) == (2, 2, 2)


def test_cheapest_rho_within_loss_selector():
    rows = [
        {"rho": 100, "loss_mrr": 0.10, "loss_recall": 0.01},
        {"rho": 500, "loss_mrr": 0.02, "loss_recall": 0.00},
        {"rho": 1000, "loss_mrr": 0.00, "loss_recall": 0.00},
    ]
    # the smallest level inside the tolerance = the largest tolerable degradation
    assert cheapest_rho_within_loss(rows, max_loss=0.03) == 500
    assert cheapest_rho_within_loss(rows, max_loss=0.5) == 100
    assert cheapest_rho_within_loss(rows, max_loss=0.001) == 1000
    assert cheapest_rho_within_loss(rows, max_loss=0.03, metric="recall") == 100


def test_cheapest_rho_nothing_within_tolerance_returns_exact_budget():
    """Regression: a tolerance no level meets (even the exhaustive level's
    own 0.0 loss) must answer with the exact budget — "don't degrade" —
    never None or a crash: callers feed the result straight into a rho
    ladder."""
    rows = [
        {"rho": 100, "loss_mrr": 0.10},
        {"rho": 500, "loss_mrr": 0.02},
        {"rho": 1000, "loss_mrr": 0.00, "exact": True},
    ]
    assert cheapest_rho_within_loss(rows, max_loss=-1.0) == 1000
    # no row flagged exact: the largest swept budget stands in
    del rows[2]["exact"]
    assert cheapest_rho_within_loss(rows, max_loss=-1.0) == 1000
    with pytest.raises(ValueError, match="non-empty"):
        cheapest_rho_within_loss([], max_loss=0.03)
