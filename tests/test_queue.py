"""Continuous-batching admission queue + Lq-bucketed serving suite.

Everything here is deterministic: time is a ``SimulatedClock`` the tests
advance explicitly, arrival schedules come from seeded numpy RNGs, and the
hypothesis properties run under the derandomized ``serving-ci`` profile in
CI. The two core claims pinned by this file:

  * **Bucketing is invisible**: serving through the (B, Lq-bucket) grid is
    bit-identical in doc ids AND scores to padding at max Lq, both engines.
  * **The queue is lossless and on time**: every submitted request completes
    exactly once, order is FIFO within a bucket (modulo DAAT's declared
    within-flush survivor sort), and no batch flushes after its oldest
    request's deadline minus the predicted service time.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exhaustive_search
from repro.metrics.latency import Clock, HybridClock, SimulatedClock, SystemClock
from repro.serving import (
    AdmissionQueue,
    AnytimeServer,
    ServingConfig,
    SurvivorPredictor,
    bucket_for,
    effective_lq,
    make_bucketed_serve_step,
    normalize_buckets,
    pad_to_width,
    shard_corpus,
    stack_indexes,
)
from repro.serving.queue import replay_arrivals

pytestmark = pytest.mark.serving

EXACT = (10**9,)  # rho ladder that caps to the index's exact level


# --------------------------------------------------------------------------
# clocks + bucketing helpers
# --------------------------------------------------------------------------


def test_simulated_clock_semantics():
    c = SimulatedClock(1.5)
    assert c.now() == 1.5
    assert c.advance(0.25) == 1.75
    assert c.advance_to(1.0) == 1.75  # never backwards
    assert c.advance_to(2.0) == 2.0
    with pytest.raises(ValueError):
        c.advance(-0.1)
    assert isinstance(c, Clock) and isinstance(SystemClock(), Clock)


def test_system_clock_monotonic():
    c = SystemClock()
    a = c.now()
    assert c.now() >= a


def test_hybrid_clock_accrues_real_work():
    import time

    c = HybridClock(5.0)
    assert c.now() >= 5.0
    t0 = c.now()
    time.sleep(0.01)  # real work between calls must advance simulated time
    assert c.now() - t0 >= 0.009
    t1 = c.advance_to(100.0)
    assert t1 >= 100.0 and c.advance_to(0.0) >= 100.0  # never backwards
    assert isinstance(c, SimulatedClock)  # accepted by replay_arrivals


def test_bucket_helpers():
    assert normalize_buckets([8, 4, 8]) == (4, 8)
    with pytest.raises(ValueError):
        normalize_buckets([0, 4])
    assert bucket_for(3, (4, 8)) == 4
    assert bucket_for(4, (4, 8)) == 4
    assert bucket_for(5, (4, 8)) == 8
    # overflow rounds up to a multiple of the top bucket (bounded grid)
    assert bucket_for(9, (4, 8)) == 16
    assert bucket_for(17, (4, 8)) == 24


def test_effective_lq_and_pad(bm25_index):
    n_terms = bm25_index.n_terms
    qt = np.array([[1, n_terms, 3, n_terms], [2, 4, n_terms, n_terms]], np.int32)
    qw = np.array([[1.0, 0.0, 2.0, 0.0], [1.0, 0.5, 0.0, 0.0]], np.float32)
    assert effective_lq(qt, qw, n_terms) == 3  # interior pad never sliced
    t, w = pad_to_width(qt, qw, 6, n_terms)
    assert t.shape == (2, 6) and np.all(t[:, 4:] == n_terms) and np.all(w[:, 4:] == 0)
    t2, w2 = pad_to_width(t, w, 3, n_terms)  # dead columns may be sliced
    assert t2.shape == (2, 3)
    with pytest.raises(ValueError, match="live"):
        pad_to_width(qt, qw, 2, n_terms)  # would drop column 2's live term


# --------------------------------------------------------------------------
# bucketed serving == max-Lq pad, bit-identical (deterministic versions)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["saat", "daat"])
def test_bucketed_serving_bit_identical(bm25_index, bm25_queries, engine):
    qt, qw = bm25_queries
    L = qt.shape[1]
    kw = dict(k=10, rho_ladder=EXACT, daat_est_blocks=2, daat_block_budget=2, engine=engine)
    ref = AnytimeServer(bm25_index, ServingConfig(**kw))
    buk = AnytimeServer(bm25_index, ServingConfig(**kw, lq_buckets=(2, 4, L)))
    for lo, w in [(0, L), (4, 3), (8, 2), (12, 1)]:  # mixed widths incl. truncated
        bt, bw = qt[lo : lo + 8, :w], qw[lo : lo + 8, :w]
        r1 = ref.search_batch(jnp.asarray(bt), jnp.asarray(bw))
        r2 = buk.search_batch(jnp.asarray(bt), jnp.asarray(bw))
        assert np.array_equal(np.asarray(r1.doc_ids), np.asarray(r2.doc_ids))
        assert np.array_equal(np.asarray(r1.scores), np.asarray(r2.scores))


def test_bucketed_server_serves_smaller_executables(bm25_index, bm25_queries):
    """Short-query traffic really lands on a narrow bucket, not max Lq."""
    qt, qw = bm25_queries
    srv = AnytimeServer(
        bm25_index, ServingConfig(k=5, rho_ladder=EXACT, lq_buckets=(2, qt.shape[1]))
    )
    srv.search_batch(jnp.asarray(qt[:4, :2]), jnp.asarray(qw[:4, :2]))
    top = srv.rho_ladder[-1]
    assert ("saat", 2, 4, top) in srv._bucket_ms  # narrow bucket was exercised
    srv.search_batch(jnp.asarray(qt[:4]), jnp.asarray(qw[:4]))
    assert ("saat", qt.shape[1], 4, top) in srv._bucket_ms


def test_warmup_calibrates_every_bucket_from_a_wide_sample(bm25_index, bm25_queries):
    """A full-width calibration sample must still warm the NARROW buckets
    (slice to shape; which live terms survive is irrelevant to compilation)."""
    qt, qw = bm25_queries
    L = qt.shape[1]
    srv = AnytimeServer(
        bm25_index, ServingConfig(k=5, rho_ladder=EXACT, lq_buckets=(2, 4, L))
    )
    srv.warmup(jnp.asarray(qt[:4]), jnp.asarray(qw[:4]), batch_sizes=(4,))
    assert {b for (_, b, _, _) in srv._bucket_ms} == {2, 4, L}


def test_bucketed_sharded_serve_matches_exhaustive(tiny_corpus, bm25_collection, bm25_index, bm25_queries):
    import jax

    from repro.core.saat import max_segments_per_term

    enc = bm25_collection
    qt, qw = bm25_queries
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards, dps = shard_corpus(
        enc.doc_idx, enc.term_idx, enc.weights, tiny_corpus.n_docs, enc.n_terms, 2
    )
    stacked = stack_indexes(shards)
    serve, _, _ = make_bucketed_serve_step(
        mesh,
        lq_buckets=(2, qt.shape[1]),
        n_terms=enc.n_terms,
        k=10,
        rho_per_shard=max(s.n_postings for s in shards),
        max_segs_per_term=max(max_segments_per_term(s) for s in shards),
        docs_per_shard=dps,
    )
    with mesh:
        ss, si = serve(stacked, jnp.asarray(qt), jnp.asarray(qw))
    ex = exhaustive_search(bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ex.scores), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# admission queue mechanics
# --------------------------------------------------------------------------


def _queue_server(index, L, *, engine="saat", clock=None, buckets=None, **cfg_kw):
    cfg = ServingConfig(
        k=10,
        rho_ladder=EXACT,
        engine=engine,
        daat_est_blocks=2,
        daat_block_budget=2,
        lq_buckets=buckets if buckets is not None else (2, 4, L),
        **cfg_kw,
    )
    return AnytimeServer(index, cfg, clock=clock or SimulatedClock())


def test_queue_requires_width_grid(bm25_index):
    srv = AnytimeServer(bm25_index, ServingConfig(rho_ladder=EXACT), clock=SimulatedClock())
    with pytest.raises(ValueError, match="lq_buckets"):
        AdmissionQueue(srv, batch_shapes=(4,))
    AdmissionQueue(srv, batch_shapes=(4,), max_lq=8)  # explicit width grid is enough


def test_queue_rejects_bad_submissions(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    srv = _queue_server(bm25_index, qt.shape[1])
    q = AdmissionQueue(srv, batch_shapes=(4,))
    with pytest.raises(ValueError, match="deadline"):
        q.submit(qt[0], qw[0], deadline_ms=0.0)
    with pytest.raises(ValueError, match="shape"):
        q.submit(qt[0], qw[0][:2], deadline_ms=5.0)
    with pytest.raises(ValueError, match="batch_shapes"):
        AdmissionQueue(srv, batch_shapes=())


def test_queue_flushes_when_full(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, qt.shape[1], clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(2, 4), clock=clock)
    # same effective width -> same bucket lane for all four
    t3, w3 = np.array([1, 2, 3], np.int32), np.ones(3, np.float32)
    rids = [q.submit(t3, w3, deadline_ms=100.0) for _ in range(4)]
    # the 4th admission fills the largest shape -> immediate flush, no time passed
    comps = q.take_completions()
    assert sorted(c.rid for c in comps) == rids and q.pending() == 0
    assert q.flush_log[-1].reason == "full" and q.flush_log[-1].batch_shape == 4
    assert not q.flush_log[-1].violation


def test_queue_deadline_flush_uses_smallest_covering_shape(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, qt.shape[1], clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(2, 8), clock=clock)
    q.submit(np.array([1, 2], np.int32), np.ones(2, np.float32), deadline_ms=10.0)
    assert q.poll() == []  # not due yet
    due = q.next_due()
    assert due == pytest.approx(0.010)  # uncalibrated predicted service = 0
    clock.advance_to(due)
    comps = q.poll()
    assert len(comps) == 1 and comps[0].batch_shape == 2  # padded to smallest shape
    assert q.flush_log[-1].reason == "deadline" and not q.flush_log[-1].violation
    assert comps[0].wait_ms == pytest.approx(10.0)


def test_queue_partitions_by_bucket(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, qt.shape[1], clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(2,), clock=clock)
    q.submit(np.array([1], np.int32), np.ones(1, np.float32), deadline_ms=50.0)  # bucket 2
    q.submit(np.array([1, 2, 3], np.int32), np.ones(3, np.float32), deadline_ms=50.0)  # bucket 4
    assert q.pending() == 2  # different lanes: no cross-bucket coalescing
    comps = q.drain()
    assert {c.bucket for c in comps} == {2, 4}
    assert all(f.reason == "drain" for f in q.flush_log)


def test_queue_completions_match_direct_serving(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, qt.shape[1], clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(2, 4), clock=clock)
    for i in range(6):
        clock.advance(0.001)
        q.submit(qt[i], qw[i], deadline_ms=20.0)
    comps = {c.rid: c for c in q.drain()}
    ref = AnytimeServer(bm25_index, ServingConfig(k=10, rho_ladder=EXACT))
    direct = ref.search_batch(jnp.asarray(qt[:6]), jnp.asarray(qw[:6]))
    for i in range(6):
        assert np.array_equal(comps[i].doc_ids, np.asarray(direct.doc_ids)[i])
        assert np.array_equal(comps[i].scores, np.asarray(direct.scores)[i])
        # SAAT completions record the ladder level actually served
        assert comps[i].rho == srv.rho_ladder[-1]


# --------------------------------------------------------------------------
# the simulated-clock serving harness (acceptance test)
# --------------------------------------------------------------------------


def _mixed_lq_requests(qt, qw, n, rng):
    """Sample n requests with mixed widths from the padded query matrix."""
    L = qt.shape[1]
    widths = rng.choice([1, 2, 3, L], size=n, p=[0.2, 0.3, 0.2, 0.3])
    picks = rng.integers(0, qt.shape[0], size=n)
    return [np.asarray(qt[q, :w]) for q, w in zip(picks, widths)], [
        np.asarray(qw[q, :w]) for q, w in zip(picks, widths)
    ]


def test_queue_poisson_stream_500_requests(bm25_index, bm25_queries):
    """>=500 Poisson arrivals, mixed Lq, simulated clock: the tentpole claim.

    Asserts zero deadline-policy violations, zero dropped/duplicated/
    reordered-beyond-policy requests, and doc ids bit-identical to serving
    the same requests directly via ``search_batch`` at max rho with max-Lq
    padding (no bucketing).
    """
    qt, qw = bm25_queries
    L = qt.shape[1]
    N = 500
    rng = np.random.default_rng(7)
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, L, clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(4, 16), clock=clock)

    terms, weights = _mixed_lq_requests(qt, qw, N, rng)
    arrivals = np.cumsum(rng.exponential(0.002, size=N))  # ~500 qps
    deadlines = rng.uniform(20.0, 60.0, size=N)
    comps = replay_arrivals(q, arrivals.tolist(), terms, weights, deadlines.tolist())

    # lossless: every rid exactly once
    assert sorted(c.rid for c in comps) == list(range(N))
    assert q.n_submitted == q.n_completed == N
    # on time: no flush after (oldest deadline - predicted service - safety)
    assert q.n_violations == 0
    assert all(f.reason in ("full", "deadline") for f in q.flush_log)
    # ordered within policy: SAAT keeps FIFO per bucket
    per_bucket: dict = {}
    for c in comps:
        per_bucket.setdefault(c.bucket, []).append(c.rid)
    for bucket, rids in per_bucket.items():
        assert rids == sorted(rids), f"bucket {bucket} completions reordered"
    # every completion waited no longer than its own deadline
    for c in comps:
        assert c.flush_s <= c.deadline_s + 1e-9

    # bit-identical to direct max-rho serving with max-Lq padding
    ref = AnytimeServer(bm25_index, ServingConfig(k=10, rho_ladder=EXACT))
    rt = np.full((N, L), bm25_index.n_terms, np.int32)
    rw = np.zeros((N, L), np.float32)
    for i, (t, w) in enumerate(zip(terms, weights)):
        rt[i, : len(t)], rw[i, : len(w)] = t, w
    by_rid = sorted(comps, key=lambda c: c.rid)
    for lo in range(0, N, 100):
        direct = ref.search_batch(jnp.asarray(rt[lo : lo + 100]), jnp.asarray(rw[lo : lo + 100]))
        ids = np.asarray(direct.doc_ids)
        for i in range(100):
            assert np.array_equal(by_rid[lo + i].doc_ids, ids[i])


def test_queue_daat_straggler_coscheduling(bm25_index, bm25_queries):
    """DAAT queue: survivor predictor learns, batches stay FIFO-prefix sets."""
    qt, qw = bm25_queries
    L = qt.shape[1]
    N = 80
    rng = np.random.default_rng(11)
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, L, engine="daat", clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(4, 8), clock=clock)
    terms, weights = _mixed_lq_requests(qt, qw, N, rng)
    arrivals = np.cumsum(rng.exponential(0.001, size=N))
    comps = replay_arrivals(q, arrivals.tolist(), terms, weights, [30.0] * N)

    assert sorted(c.rid for c in comps) == list(range(N))
    assert q.n_violations == 0
    # WorkStats history reached the predictor
    assert q.survivors._by_lq and q.survivors.predict(2) >= 0.0
    # policy boundary: a flush may permute rids internally (survivor sort)
    # but always consumes a contiguous FIFO prefix of its bucket lane
    seen: dict = {}
    for f in q.flush_log:
        lane = seen.setdefault(f.bucket, [])
        assert min(f.rids) > (max(lane) if lane else -1)
        lane.extend(f.rids)
    # and ids still match direct unbucketed daat serving
    ref = AnytimeServer(
        bm25_index,
        ServingConfig(k=10, engine="daat", daat_est_blocks=2, daat_block_budget=2),
    )
    rt = np.full((N, L), bm25_index.n_terms, np.int32)
    rw = np.zeros((N, L), np.float32)
    for i, (t, w) in enumerate(zip(terms, weights)):
        rt[i, : len(t)], rw[i, : len(w)] = t, w
    direct = ref.search_batch(jnp.asarray(rt), jnp.asarray(rw))
    ids = np.asarray(direct.doc_ids)
    for c in comps:
        assert np.array_equal(c.doc_ids, ids[c.rid])


def test_queue_separates_infeasible_from_violation(bm25_index, bm25_queries):
    """A deadline unmeetable at ADMISSION is infeasibility, not a policy
    violation; a missed-but-meetable due instant is a violation."""
    qt, qw = bm25_queries
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, qt.shape[1], clock=clock)
    # make service expensive in the model's eyes: 50 ms predicted per flush
    srv._bucket_ms[("saat", 4, 2, srv.rho_ladder[-1])] = 50.0  # whole-batch wall ms at shape 2
    q = AdmissionQueue(srv, batch_shapes=(2,), clock=clock)
    t3, w3 = np.array([1, 2, 3], np.int32), np.ones(3, np.float32)
    # infeasible: 10 ms budget < 50 ms predicted -> due is before arrival
    q.submit(t3, w3, deadline_ms=10.0)
    q.poll()
    assert q.flush_log[-1].infeasible and not q.flush_log[-1].violation
    # violation: 100 ms budget is meetable (due = +50 ms) but we poll late
    q.submit(t3, w3, deadline_ms=100.0)
    clock.advance(0.080)  # overslept past the 50 ms due instant
    q.poll()
    assert q.flush_log[-1].violation and not q.flush_log[-1].infeasible
    assert q.n_violations == 1 and q.n_infeasible == 1


# --------------------------------------------------------------------------
# degrade-instead-of-violate: the anytime SLO autopilot
# --------------------------------------------------------------------------


def _overload_server(index, *, clock):
    """SAAT server with a scripted per-(shape, rho) service model.

    Ladder has three levels; only the smallest and the full budget are
    *calibrated* (directly measured) — the middle level exists but was never
    timed, so the degrade policy must never pick it on faith.
    """
    cfg = ServingConfig(k=10, rho_ladder=(200, 1000) + EXACT, lq_buckets=(4,))
    srv = AnytimeServer(index, cfg, clock=clock)
    small, full = srv.rho_ladder[0], srv.rho_ladder[-1]
    srv._bucket_ms.update(
        {
            ("saat", 4, 2, full): 20.0,  # whole-flush wall ms
            ("saat", 4, 4, full): 60.0,
            ("saat", 4, 2, small): 5.0,
            ("saat", 4, 4, small): 15.0,
        }
    )
    return srv, small, full


def _overload_schedule():
    """Three requests, 100 ms deadlines, arrival rate sized so full-rho
    service cannot meet them: the third arrival (t=75ms) jumps the covering
    shape from 2 to 4, moving the due instant (oldest deadline - predicted
    service) from t=80ms back to t=40ms — already in the past, but after the
    oldest ARRIVAL (t=0), so missing it is a scheduling violation rather
    than admission infeasibility. 25 ms remain; full rho needs 60."""
    t3, w3 = np.array([1, 2, 3], np.int32), np.ones(3, np.float32)
    return [0.0, 0.070, 0.075], [t3] * 3, [w3] * 3, [100.0] * 3


def test_overload_replay_violates_without_degradation(bm25_index):
    clock = SimulatedClock()
    srv, small, full = _overload_server(bm25_index, clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(2, 4), clock=clock)
    arrivals, ts, ws, dl = _overload_schedule()
    comps = replay_arrivals(q, arrivals, ts, ws, dl)
    assert q.n_violations >= 1 and q.n_degraded == 0
    # every flush records the budget actually served (the full ladder level)
    assert [f.rho for f in q.flush_log] == [full] * len(q.flush_log)
    # at max rho, queue-served ids stay bit-identical to direct serving
    ref = AnytimeServer(
        bm25_index, ServingConfig(k=10, rho_ladder=(200, 1000) + EXACT, lq_buckets=(4,))
    )
    direct = ref.search_batch(jnp.asarray(ts[0][None, :]), jnp.asarray(ws[0][None, :]))
    direct_ids = np.asarray(direct.doc_ids)[0]
    for c in comps:
        assert c.rho == full
        assert np.array_equal(c.doc_ids, direct_ids)


def test_overload_replay_degrades_instead_of_violating(bm25_index):
    clock = SimulatedClock()
    srv, small, full = _overload_server(bm25_index, clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(2, 4), clock=clock, degrade_rho=True)
    arrivals, ts, ws, dl = _overload_schedule()
    comps = replay_arrivals(q, arrivals, ts, ws, dl)
    # the identical overload produces ZERO violations: the overloaded flush
    # served the largest calibrated budget that still fit (the small level)
    assert q.n_violations == 0
    assert q.n_degraded >= 1
    assert all(f.rho == small for f in q.flush_log if f.rho != full)
    assert any(f.rho == small for f in q.flush_log)
    # every completion met its deadline and audits the budget it was served
    for c in comps:
        assert c.flush_s <= c.deadline_s + 1e-9
        assert c.rho in (small, full)
    # degraded ids match direct serving at the SAME degraded budget
    ref = AnytimeServer(
        bm25_index, ServingConfig(k=10, rho_ladder=(200, 1000) + EXACT, lq_buckets=(4,))
    )
    direct = ref.search_batch(
        jnp.asarray(ts[0][None, :]), jnp.asarray(ws[0][None, :]), rho=small
    )
    direct_ids = np.asarray(direct.doc_ids)[0]
    for c in comps:
        if c.rho == small:
            assert np.array_equal(c.doc_ids, direct_ids)


def test_pick_degraded_rho_prefers_largest_calibrated_fit(bm25_index):
    clock = SimulatedClock()
    srv, small, full = _overload_server(bm25_index, clock=clock)
    mid = srv.rho_ladder[1]
    assert srv.pick_degraded_rho(4, 4, 100.0) == full  # everything fits
    assert srv.pick_degraded_rho(4, 4, 25.0) == small  # only small fits
    # the uncalibrated middle level is never picked on faith, even though
    # its (interpolated) cost-model guess might fit
    assert mid not in (srv.pick_degraded_rho(4, 4, b) for b in (1.0, 25.0, 100.0))
    # nothing fits -> the smallest calibrated level is the least-late choice
    assert srv.pick_degraded_rho(4, 4, 1.0) == small
    # nothing calibrated at all -> defer to pick_rho's deadline logic
    cold = AnytimeServer(
        bm25_index,
        ServingConfig(k=10, rho_ladder=(200, 1000) + EXACT, lq_buckets=(4,)),
        clock=SimulatedClock(),
    )
    assert cold.pick_degraded_rho(4, 4, 25.0) == cold.pick_rho(deadline_ms=25.0)


def test_degrade_rho_policy_validation(bm25_index, bm25_queries):
    qt, _ = bm25_queries
    clock = SimulatedClock()
    saat = _queue_server(bm25_index, qt.shape[1], clock=clock)
    with pytest.raises(ValueError, match="at most one"):
        AdmissionQueue(saat, clock=clock, dynamic_rho=True, degrade_rho=True)
    daat = _queue_server(bm25_index, qt.shape[1], engine="daat", clock=clock)
    with pytest.raises(ValueError, match="rho"):
        AdmissionQueue(daat, clock=clock, degrade_rho=True)


# --------------------------------------------------------------------------
# the effectiveness harness, wired to real serving
# --------------------------------------------------------------------------


def test_rho_effectiveness_sweep_reports_per_level_loss(
    tiny_corpus, bm25_index, bm25_queries
):
    from repro.metrics.ir_metrics import (
        cheapest_rho_within_loss,
        mrr_at_k,
        rho_effectiveness_sweep,
    )

    qt, qw = bm25_queries
    qrels = np.asarray(tiny_corpus.qrels)
    srv = AnytimeServer(
        bm25_index,
        ServingConfig(k=20, rho_ladder=(200, 1000) + EXACT, batch_size=8),
        clock=SimulatedClock(),
    )
    rows = rho_effectiveness_sweep(srv, qt, qw, qrels, recall_k=20)
    assert [r["rho"] for r in rows] == list(srv.rho_ladder)
    # the exhaustive level anchors the loss scale at exactly zero
    assert rows[-1]["exact"] and rows[-1]["loss_mrr"] == 0.0
    assert all(r["loss_mrr"] >= 0.0 and r["loss_recall"] >= 0.0 for r in rows)
    # exact-level metrics equal the rank-safe exhaustive oracle's
    ex = exhaustive_search(bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=20)
    assert rows[-1]["mrr"] == pytest.approx(mrr_at_k(np.asarray(ex.doc_ids), qrels, 10))
    # the 3%-tolerance selector always finds a level (exhaustive qualifies)
    best = cheapest_rho_within_loss(rows, max_loss=0.03)
    assert best in srv.rho_ladder


def _replay_server(index, L, *, clock):
    """Single-bucket SAAT server with a scripted per-(shape, rho) model."""
    cfg = ServingConfig(k=10, rho_ladder=(200, 1000) + EXACT, lq_buckets=(L,))
    srv = AnytimeServer(index, cfg, clock=clock)
    small, full = srv.rho_ladder[0], srv.rho_ladder[-1]
    srv._bucket_ms.update(
        {
            ("saat", L, 2, full): 20.0,
            ("saat", L, 4, full): 60.0,
            ("saat", L, 2, small): 5.0,
            ("saat", L, 4, small): 15.0,
        }
    )
    return srv, small, full


def test_replay_effectiveness_accounts_per_served_rho(
    tiny_corpus, bm25_index, bm25_queries
):
    """Two bursts through a degrading queue: the loose-deadline burst serves
    the full budget, the tight one degrades — and the report groups
    effectiveness by the rho each request was ACTUALLY served at."""
    from repro.metrics.ir_metrics import replay_effectiveness

    qt, qw = bm25_queries
    L = qt.shape[1]
    qrels = np.asarray(tiny_corpus.qrels)[:8]
    clock = SimulatedClock()
    srv, small, full = _replay_server(bm25_index, L, clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(2, 4), clock=clock, degrade_rho=True)
    # burst A (t=0..3ms, 200 ms deadlines): fills to shape 4 and fits the
    # full budget. burst B (t=50..53ms, 30 ms deadlines): the third arrival
    # jumps the covering shape to 4, whose predicted full-rho service no
    # longer fits the remaining ~28 ms -> that flush degrades to the small
    # level; the straggler then flushes alone, on time, at full rho.
    arrivals = [0.0, 0.001, 0.002, 0.003, 0.050, 0.051, 0.052, 0.053]
    deadlines = [200.0] * 4 + [30.0] * 4
    rep = replay_effectiveness(
        q,
        arrivals,
        [qt[i] for i in range(8)],
        [qw[i] for i in range(8)],
        deadlines,
        qrels,
        recall_k=10,
    )
    assert rep["n_requests"] == 8
    assert rep["violations"] == 0
    assert rep["degraded_flushes"] == 1
    assert {(g["rho"], g["n_queries"]) for g in rep["by_rho"]} == {(small, 3), (full, 5)}
    for g in rep["by_rho"] + [rep["overall"]]:
        assert 0.0 <= g["mrr"] <= 1.0 and 0.0 <= g["recall"] <= 1.0
    assert "p99_ms" in rep["wait_ms"]


def test_effectiveness_surface_shifts_traffic_down_the_ladder(
    tiny_corpus, bm25_index, bm25_queries
):
    """Tightening the deadline moves served traffic down the rho ladder;
    every deadline point gets a FRESH queue so rows are independent."""
    from repro.metrics.ir_metrics import effectiveness_surface

    qt, qw = bm25_queries
    L = qt.shape[1]
    qrels = np.asarray(tiny_corpus.qrels)[:4]
    _, small, full = _replay_server(bm25_index, L, clock=SimulatedClock())

    def factory(deadline_ms):
        clock = SimulatedClock()
        srv, _, _ = _replay_server(bm25_index, L, clock=clock)
        return AdmissionQueue(srv, batch_shapes=(2, 4), clock=clock, degrade_rho=True)

    arrivals = [0.0, 0.001, 0.002, 0.003]
    rows = effectiveness_surface(
        factory,
        [200.0, 30.0],
        arrivals,
        [qt[i] for i in range(4)],
        [qw[i] for i in range(4)],
        qrels,
        recall_k=10,
    )
    assert [r["deadline_ms"] for r in rows] == [200.0, 30.0]
    loose, tight = rows
    assert loose["degraded_flushes"] == 0 and loose["violations"] == 0
    assert tight["degraded_flushes"] >= 1 and tight["violations"] == 0
    # the loose deadline serves everything at the full budget; tightening it
    # pushes part of the traffic down the ladder
    assert {g["rho"] for g in loose["by_rho"]} == {full}
    assert small in {g["rho"] for g in tight["by_rho"]}


def test_flush_pads_with_inert_sentinel_rows(bm25_index, bm25_queries):
    """A short flush pads with all-sentinel rows (pad term ids, zero weights)
    — never by repeating the last real request, which burned DAAT while_loop
    work on a duplicate's survivors — and only the n_real rows ever reach the
    SurvivorPredictor or the per-request accounting."""
    qt, qw = bm25_queries
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, qt.shape[1], engine="daat", clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(4,), clock=clock)
    captured = {}
    real_search = srv.search_batch

    def spy(qt_, qw_, rho=None):
        captured["qt"], captured["qw"] = np.asarray(qt_), np.asarray(qw_)
        return real_search(qt_, qw_, rho=rho)

    srv.search_batch = spy
    observed: list = []
    real_observe = q.survivors.observe
    q.survivors.observe = lambda lq, s: (observed.append((lq, s)), real_observe(lq, s))[1]
    t3, w3 = np.array([1, 2, 3], np.int32), np.ones(3, np.float32)
    q.submit(t3, w3, deadline_ms=10.0)
    comps = q.drain()
    assert len(comps) == 1 and captured["qt"].shape[0] == 4
    n_terms = bm25_index.n_terms
    # rows past n_real are inert sentinels, not copies of the last request
    assert np.all(captured["qt"][1:] == n_terms) and np.all(captured["qw"][1:] == 0.0)
    # only the single real request reached the survivor predictor
    assert len(observed) == 1 and q.flush_log[-1].n_real == 1
    # the service-time EMA is keyed by the flushed executable shape
    assert ("daat", 4, 4, None) in srv._bucket_ms
    # and the real row's results are untouched by the sentinel pads
    ref = AnytimeServer(
        bm25_index,
        ServingConfig(k=10, engine="daat", daat_est_blocks=2, daat_block_budget=2),
    )
    rt, rw = pad_to_width(t3[None, :], w3[None, :], 4, n_terms)
    direct = ref.search_batch(jnp.asarray(rt), jnp.asarray(rw))
    assert np.array_equal(comps[0].doc_ids, np.asarray(direct.doc_ids)[0])
    assert np.array_equal(comps[0].scores, np.asarray(direct.scores)[0])


def test_survivor_predictor_ema():
    p = SurvivorPredictor(alpha=0.5)
    assert p.predict(3) == 0.0  # cold start
    p.observe(3, 10.0)
    assert p.predict(3) == 10.0
    p.observe(3, 20.0)
    assert p.predict(3) == pytest.approx(15.0)
    assert p.predict(7) == pytest.approx(15.0)  # nearest observed key (3)
    p.observe(7, 100.0)
    assert p.predict(7) == 100.0


def test_survivor_predictor_nearest_key_beats_global():
    """Unseen Lq under a bimodal stream: the nearest observed key predicts,
    not the global EMA (which describes NO query in a bimodal mix)."""
    p = SurvivorPredictor(alpha=0.2)
    p.observe(2, 5.0)
    p.observe(30, 400.0)
    # global EMA is 0.8*5 + 0.2*400 = 84 — wrong for BOTH modes
    assert p._global == pytest.approx(84.0)
    assert p.predict(3) == pytest.approx(5.0)  # nearest is 2
    assert p.predict(28) == pytest.approx(400.0)  # nearest is 30
    assert p.predict(16) == pytest.approx(5.0)  # tie |2-16|==|30-16| -> smaller
    assert p.predict(2) == pytest.approx(5.0)  # exact keys still exact


def test_queue_bimodal_lq_coschedules_with_neighbor(bm25_index, bm25_queries):
    """DAAT survivor sort under a bimodal stream: an UNSEEN Lq rides with its
    neighboring mode instead of the global EMA. With history at Lq 4 (cheap)
    and Lq 30 (expensive), a first-ever Lq-3 request must tie with the Lq-4
    mode — stable FIFO keeps it first — where the old global fallback
    predicted 84 survivors and bumped it behind the cheap Lq-4 request."""
    qt, qw = bm25_queries
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, qt.shape[1], engine="daat", clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(2,), clock=clock)
    q.survivors.observe(4, 5.0)
    q.survivors.observe(30, 400.0)
    assert q.survivors._global == pytest.approx(84.0)  # describes no mode
    captured = {}
    real_search = srv.search_batch

    def spy(qt_, qw_, rho=None):
        captured["qt"] = np.asarray(qt_)
        return real_search(qt_, qw_, rho=rho)

    srv.search_batch = spy
    n_terms = bm25_index.n_terms
    # both requests land in bucket 4 (same lane): Lq 3 first, then Lq 4
    q.submit(np.array([1, 2, 3], np.int32), np.ones(3, np.float32), deadline_ms=50.0)
    q.submit(np.array([4, 5, 6, 7], np.int32), np.ones(4, np.float32), deadline_ms=50.0)
    q.drain()
    # nearest-key predicts Lq 3 ~ Lq 4: tie -> FIFO keeps the Lq-3 row first
    assert captured["qt"].shape[0] == 2
    assert int((captured["qt"][0] != n_terms).sum()) == 3
    assert int((captured["qt"][1] != n_terms).sum()) == 4


def test_queue_max_wait_flushes_deadline_less_traffic(bm25_index, bm25_queries):
    """The starvation bug: a non-full bucket of deadline-less requests was
    never due (next_due() = None) and sat until drain(). max_wait_s bounds
    the wait at oldest-arrival + max_wait, pinned on a simulated clock."""
    qt, qw = bm25_queries
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, qt.shape[1], clock=clock)
    t3, w3 = np.array([1, 2, 3], np.int32), np.ones(3, np.float32)

    # without the age bound the request starves: nothing is ever due
    starved = AdmissionQueue(srv, batch_shapes=(4,), clock=clock)
    starved.submit(t3, w3, deadline_ms=None)
    assert starved.next_due() is None
    clock.advance(3600.0)
    assert starved.poll() == [] and starved.pending() == 1

    bounded = AdmissionQueue(srv, batch_shapes=(4,), clock=clock, max_wait_s=0.05)
    t0 = clock.now()
    bounded.submit(t3, w3, deadline_ms=None)
    assert bounded.next_due() == pytest.approx(t0 + 0.05)
    clock.advance(0.049)
    assert bounded.poll() == []  # age bound not reached yet
    clock.advance_to(t0 + 0.05)
    comps = bounded.poll()
    assert len(comps) == 1 and comps[0].wait_ms == pytest.approx(50.0)
    assert bounded.flush_log[-1].reason == "deadline"
    assert not bounded.flush_log[-1].violation  # inf deadline is never late


def test_queue_max_wait_coexists_with_deadlines(bm25_index, bm25_queries):
    """An earlier hard deadline still wins over the age bound, and the age
    bound still wins over a distant deadline."""
    qt, qw = bm25_queries
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, qt.shape[1], clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(4,), clock=clock, max_wait_s=1.0)
    t3, w3 = np.array([1, 2, 3], np.int32), np.ones(3, np.float32)
    t0 = clock.now()
    q.submit(t3, w3, deadline_ms=10.0)  # deadline due at +10 ms beats +1 s age
    assert q.next_due() == pytest.approx(t0 + 0.010)
    clock.advance_to(q.next_due())
    assert len(q.poll()) == 1
    t1 = clock.now()
    q.submit(t3, w3, deadline_ms=60_000.0)  # distant deadline: age bound wins
    assert q.next_due() == pytest.approx(t1 + 1.0)
    with pytest.raises(ValueError, match="max_wait_s"):
        AdmissionQueue(srv, batch_shapes=(4,), clock=clock, max_wait_s=-0.1)


def test_queue_drain_final_partial_flush_accounting(bm25_index, bm25_queries):
    """drain()'s ragged last batch: the full flush happens on admission, the
    remainder pads with sentinels, and ONLY real rows reach the survivor
    predictor / per-request accounting."""
    qt, qw = bm25_queries
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, qt.shape[1], engine="daat", clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(2, 4), clock=clock)
    observed: list = []
    real_observe = q.survivors.observe
    q.survivors.observe = lambda lq, s: (observed.append((lq, s)), real_observe(lq, s))[1]
    t3, w3 = np.array([1, 2, 3], np.int32), np.ones(3, np.float32)
    rids = [q.submit(t3, w3, deadline_ms=None) for _ in range(7)]
    comps = q.take_completions()  # the 4-wide full flush fired on admission
    assert len(comps) == 4 and q.pending() == 3
    comps += q.drain()  # ragged remainder: 3 real rows in the 4-wide shape
    assert sorted(c.rid for c in comps) == rids
    last = q.flush_log[-1]
    assert last.reason == "drain" and last.n_real == 3 and last.batch_shape == 4
    # 4 real rows from the full flush + 3 from the drain, never the sentinel
    assert len(observed) == 7
    assert q.n_submitted == q.n_completed == 7


def test_replay_arrivals_requires_simulated_clock(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    srv = _queue_server(bm25_index, qt.shape[1], clock=SystemClock())
    q = AdmissionQueue(srv, batch_shapes=(2,))
    with pytest.raises(TypeError, match="SimulatedClock"):
        replay_arrivals(q, [0.0], [qt[0]], [qw[0]], [5.0])


# --------------------------------------------------------------------------
# hypothesis properties (skipped — not the whole module — without hypothesis)
# --------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    _settings = settings(
        max_examples=15,
        deadline=None,
        suppress_health_check=[HealthCheck.function_scoped_fixture, HealthCheck.too_slow],
    )
    _HYPOTHESIS = True
except ImportError:  # deterministic suite above still runs
    _HYPOTHESIS = False

    def given(**kw):  # noqa: D103 - placeholder so decorators below parse
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def _settings(f):
        return f

    class st:  # noqa: D101
        integers = sampled_from = staticmethod(lambda *a, **k: None)


@_settings
@given(
    seed=st.integers(0, 2**31 - 1),
    engine=st.sampled_from(["saat", "daat"]),
    width=st.sampled_from([1, 2, 3, 4]),
)
def test_prop_bucketed_bit_identical(bm25_index, bm25_queries, seed, engine, width):
    """(a) bucketed serving == unbucketed max-Lq pad, both engines."""
    qt, qw = bm25_queries
    L = qt.shape[1]
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, qt.shape[0], size=4)
    bt, bw = np.asarray(qt[rows, :width]), np.asarray(qw[rows, :width])
    # reference at max-Lq padding
    rt, rw = pad_to_width(bt, bw, L, bm25_index.n_terms)
    kw = dict(k=10, rho_ladder=EXACT, daat_est_blocks=2, daat_block_budget=2, engine=engine)
    ref = AnytimeServer(bm25_index, ServingConfig(**kw))
    buk = AnytimeServer(bm25_index, ServingConfig(**kw, lq_buckets=(2, 4, L)))
    r1 = ref.search_batch(jnp.asarray(rt), jnp.asarray(rw))
    r2 = buk.search_batch(jnp.asarray(bt), jnp.asarray(bw))
    assert np.array_equal(np.asarray(r1.doc_ids), np.asarray(r2.doc_ids))
    assert np.array_equal(np.asarray(r1.scores), np.asarray(r2.scores))


@_settings
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 40),
    qps=st.sampled_from([200.0, 1000.0, 5000.0]),
)
def test_prop_queue_lossless_and_on_time(bm25_index, bm25_queries, seed, n, qps):
    """(b) no drops, no duplicates, no flush past the oldest deadline."""
    qt, qw = bm25_queries
    rng = np.random.default_rng(seed)
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, qt.shape[1], clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(4, 8), clock=clock)
    terms, weights = _mixed_lq_requests(qt, qw, n, rng)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, size=n))
    deadlines = rng.uniform(5.0, 50.0, size=n)
    comps = replay_arrivals(q, arrivals.tolist(), terms, weights, deadlines.tolist())
    assert sorted(c.rid for c in comps) == list(range(n))
    assert q.n_violations == 0
    for f in q.flush_log:
        assert f.flush_s <= f.oldest_deadline_s + 1e-9


# --------------------------------------------------------------------------
# regression: flush-time clock semantics
# --------------------------------------------------------------------------


def test_queue_poll_rereads_clock_between_buckets(bm25_index):
    """Regression: ``poll()`` captured ``now`` once, so a bucket whose
    deadline expired DURING an earlier bucket's flush (real service time on a
    hybrid clock) waited for the next driver wakeup instead of flushing in
    the same poll. The clock must be re-read per bucket iteration."""
    clock = HybridClock(0.0)
    srv = _queue_server(bm25_index, 16, clock=clock, buckets=(4, 16))
    q = AdmissionQueue(srv, batch_shapes=(2,), clock=clock)

    orig = srv.search_batch

    def search_and_accrue(qt, qw, rho=None):
        res = orig(qt, qw, rho=rho)
        clock.advance(10.0)  # this flush's service time, in simulated seconds
        return res

    srv.search_batch = search_and_accrue

    # bucket 4: due almost immediately; bucket 16: due only after the first
    # flush's 10 s of service time has accrued
    q.submit(np.array([1, 2], np.int32), np.ones(2, np.float32), deadline_ms=5.0)
    q.submit(np.arange(1, 8, dtype=np.int32), np.ones(7, np.float32), deadline_ms=5000.0)
    clock.advance(0.006)
    assert clock.now() < q._due_instant(16)  # not yet due at poll entry

    comps = q.poll()  # ONE poll must serve both
    assert sorted(c.rid for c in comps) == [0, 1]
    assert [f.bucket for f in q.flush_log] == [4, 16]
    assert all(f.reason == "deadline" for f in q.flush_log)


def test_queue_overfull_lane_predicts_chunked_launches(bm25_index):
    """Regression: a lane holding more than the largest batch shape drains as
    ceil(n/shape) launches, but ``_due_instant`` predicted ONE launch — the
    lane flushed too late and every chunk after the first mis-accounted as a
    violation. Seed the lane directly (``submit`` auto-flushes full lanes,
    so an overfull lane only arises between poll wakeups)."""
    from repro.serving.queue import _Request

    clock = SimulatedClock()
    srv = _queue_server(bm25_index, 4, clock=clock, buckets=(4,))
    q = AdmissionQueue(srv, batch_shapes=(2, 4), clock=clock)
    rho = srv.pick_rho()
    pred_ms = 500.0
    srv._observe_bucket_ms(4, 4, pred_ms, rho=rho)
    assert srv.predict_service_ms(4, 4) == pytest.approx(pred_ms)

    now = clock.now()
    deadline = now + 2 * pred_ms / 1e3 + 0.010  # meetable only as 2 launches
    for _ in range(7):  # ceil(7/4) = 2 launches
        q._pending[4].append(
            _Request(
                rid=q._next_rid,
                q_terms=np.array([1, 2, 3], np.int32),
                q_weights=np.ones(3, np.float32),
                arrival_s=now,
                deadline_s=deadline,
                lq_eff=3,
                bucket=4,
            )
        )
        q._next_rid += 1
        q.n_submitted += 1

    # the due instant must reserve BOTH launches' predicted service
    assert q.next_due() == pytest.approx(deadline - 2 * pred_ms / 1e3)
    clock.advance_to(q.next_due())
    comps = q.poll()
    assert len(comps) == 7 and q.pending() == 0
    recs = q.flush_log[-2:]
    assert [r.n_real for r in recs] == [4, 3]
    assert all(r.reason == "deadline" for r in recs)
    assert not any(r.violation or r.infeasible for r in recs)


def test_replay_effectiveness_empty_schedule(bm25_index, bm25_queries):
    """Regression: a replay that completes nothing (empty schedule) must
    return a well-formed all-zero report, not crash in np.stack([])."""
    from repro.metrics.ir_metrics import replay_effectiveness

    qt, _ = bm25_queries
    clock = SimulatedClock()
    srv = _queue_server(bm25_index, qt.shape[1], clock=clock)
    q = AdmissionQueue(srv, batch_shapes=(2,), clock=clock)
    rep = replay_effectiveness(q, [], [], [], [], np.zeros(0, np.int64), recall_k=10)
    assert rep["n_requests"] == 0 and rep["by_rho"] == []
    assert rep["violations"] == 0 and rep["infeasible"] == 0
    assert rep["overall"]["mrr"] == 0.0 and rep["overall"]["recall"] == 0.0
    assert rep["wait_ms"]["p99_ms"] == 0.0
