"""Trainer, optimizer, compression, and checkpoint behaviour tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.distributed.collectives import (
    CompressionConfig,
    make_error_feedback_transform,
)
from repro.train import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    init_train_state,
    make_train_step,
    schedule_lr,
    train_loop,
)


def _quadratic_loss(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    l = jnp.mean((pred - batch["y"]) ** 2)
    return l, {"mse": l}


def _batches(n, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(4, 3)).astype(np.float32)
    for _ in range(n):
        x = rng.normal(size=(16, 4)).astype(np.float32)
        yield {"x": jnp.asarray(x), "y": jnp.asarray(x @ w_true)}


def test_train_loss_decreases():
    params = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((3,))}
    step = make_train_step(_quadratic_loss, AdamWConfig(lr=0.05, warmup_steps=1, weight_decay=0.0))
    state, hist = train_loop(step, init_train_state(params), list(_batches(60)))
    assert hist[-1]["loss"] < 0.1 * hist[0]["loss"]


def test_grad_accum_equivalence():
    """accum=4 over one batch == accum=1 over the same batch (mean loss)."""
    params = {"w": jnp.ones((4, 3)), "b": jnp.zeros((3,))}
    batch = next(_batches(1))
    s1 = make_train_step(_quadratic_loss, AdamWConfig(lr=1e-2, warmup_steps=1))
    s4 = make_train_step(_quadratic_loss, AdamWConfig(lr=1e-2, warmup_steps=1), grad_accum=4)
    st1, _ = jax.jit(s1)(init_train_state(params), batch)
    st4, _ = jax.jit(s4)(init_train_state(params), batch)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_adamw_matches_reference_step():
    """One AdamW step against a hand-computed reference."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip_norm=0.0, schedule="constant", warmup_steps=0)
    p = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([0.5])}
    st = adamw_init(p)
    new_p, st2, _ = adamw_update(g, st, p, cfg)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    step = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.99)) + 1e-8)
    np.testing.assert_allclose(float(new_p["w"][0]), 2.0 - 0.1 * step, rtol=1e-5)


def test_lr_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[2] > lrs[3] > lrs[4] >= 0.1 * (1 - 1e-6)


def test_grad_clip_caps_norm():
    from repro.train import clip_by_global_norm, global_norm

    g = {"a": jnp.full((100,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_error_feedback_compensates():
    """With error feedback, the SUM of sent grads converges to the true sum."""
    compress, init_res = make_error_feedback_transform(CompressionConfig(block=64))
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=256).astype(np.float32))}
    res = init_res(g)
    sent_total = np.zeros(256, np.float32)
    for _ in range(20):
        sent, res = compress(g, res)
        sent_total += np.asarray(sent["w"])
    np.testing.assert_allclose(sent_total / 20, np.asarray(g["w"]), atol=0.02)


def test_compressed_grads_still_converge():
    compress, init_res = make_error_feedback_transform(CompressionConfig(block=32))
    residual = {"holder": None}
    params = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((3,))}
    residual["holder"] = init_res(params)

    def transform(grads):
        sent, residual["holder"] = compress(grads, residual["holder"])
        return sent

    step = make_train_step(
        _quadratic_loss, AdamWConfig(lr=0.05, warmup_steps=1, weight_decay=0.0),
        grad_transform=transform,
    )
    state, hist = train_loop(step, init_train_state(params), list(_batches(60)), jit=False)
    assert hist[-1]["loss"] < 0.2 * hist[0]["loss"]


# ------------------------------------------------------------------ checkpoint


def test_checkpoint_roundtrip_and_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_writes=False)
    params = {"w": jnp.arange(12.0).reshape(3, 4), "nested": {"b": jnp.ones((2,))}}
    state = init_train_state(params)
    for s in (1, 2, 3):
        cm.save(s, state)
    assert cm.available_steps() == [2, 3]  # keep=2 GC'd step 1
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, _ = cm.restore(abstract)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_structure_mismatch_rejected(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_writes=False)
    state = init_train_state({"w": jnp.ones((2, 2))})
    cm.save(1, state)
    bad = init_train_state({"w": jnp.ones((2, 2)), "extra": jnp.ones((1,))})
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), bad)
    with pytest.raises(ValueError, match="mismatch"):
        cm.restore(abstract)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_writes=False)
    state = init_train_state({"w": jnp.ones((2, 2))})
    cm.save(1, state)
    bad = init_train_state({"w": jnp.ones((3, 2))})
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), bad)
    with pytest.raises(ValueError, match="shape"):
        cm.restore(abstract)


def test_checkpoint_atomicity_tmp_dirs_invisible(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_writes=False)
    # a crashed writer leaves a tmp dir: must not be listed as a checkpoint
    os.makedirs(tmp_path / "step_000000007.tmp-dead")
    state = init_train_state({"w": jnp.ones((2,))})
    cm.save(9, state)
    assert cm.available_steps() == [9]


def test_checkpoint_async_writer(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_writes=True)
    state = init_train_state({"w": jnp.ones((64, 64))})
    cm.save(5, state)
    cm.wait()
    assert cm.latest_step() == 5


def test_checkpoint_resume_training(tmp_path):
    """Save mid-run, restore, continue — matches an uninterrupted run."""
    params = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((3,))}
    step = make_train_step(_quadratic_loss, AdamWConfig(lr=0.05, warmup_steps=1))
    batches = list(_batches(10))
    # uninterrupted
    state_a, _ = train_loop(step, init_train_state(params), batches)
    # interrupted at 5
    state_b, _ = train_loop(step, init_train_state(params), batches[:5])
    cm = CheckpointManager(str(tmp_path), async_writes=False)
    cm.save(5, state_b)
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state_b)
    restored, _ = cm.restore(abstract)
    state_c, _ = train_loop(step, restored, batches[5:])
    for a, c in zip(jax.tree.leaves(state_a.params), jax.tree.leaves(state_c.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5, atol=1e-6)
