"""Property-based tests (hypothesis) for system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.archs.embedding import TableSpec, embedding_bag, embedding_lookup, fold_ids
from repro.core.quantization import QuantConfig, dequantize, quantize
from repro.core.topk import topk
from repro.distributed.collectives import compress_decompress, quantize_int8, dequantize_int8

_settings = settings(max_examples=30, deadline=None)


@_settings
@given(
    st.lists(st.floats(0.001, 1e4), min_size=1, max_size=200),
    st.sampled_from([4, 6, 8, 10]),
)
def test_quantization_error_bounded_by_step(weights, bits):
    w = np.asarray(weights)
    q, scale = quantize(w, QuantConfig(bits=bits))
    deq = dequantize(q, scale)
    assert np.all(np.abs(deq - w) <= scale + 1e-9 * np.abs(w).max())
    assert q.max() <= (1 << bits) - 1 and q[w > 0].min() >= 1


@_settings
@given(st.integers(0, 2**31 - 1), st.integers(2, 64))
def test_topk_permutation_invariance(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=n).astype(np.float32)
    k = min(8, n)
    s1, _ = topk(jnp.asarray(x), k)
    perm = rng.permutation(n)
    s2, i2 = topk(jnp.asarray(x[perm]), k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2))
    np.testing.assert_allclose(x[perm][np.asarray(i2)], np.asarray(s1))


@_settings
@given(st.integers(0, 2**31 - 1), st.integers(1, 300), st.integers(2, 50))
def test_segment_sum_equals_onehot_matmul(seed, n, segs):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, segs, n)
    vals = rng.normal(size=n).astype(np.float32)
    got = jax.ops.segment_sum(jnp.asarray(vals), jnp.asarray(ids), num_segments=segs)
    onehot = np.zeros((segs, n), np.float32)
    onehot[ids, np.arange(n)] = 1.0
    np.testing.assert_allclose(np.asarray(got), onehot @ vals, rtol=1e-4, atol=1e-4)


@_settings
@given(st.integers(0, 2**31 - 1))
def test_embedding_bag_equals_dense(seed):
    rng = np.random.default_rng(seed)
    spec = TableSpec((7, 13, 29), 4)
    table = jnp.asarray(rng.normal(size=(spec.total_rows, 4)).astype(np.float32))
    nnz, bags = 40, 6
    flat = rng.integers(0, spec.total_rows, nnz)
    seg = np.sort(rng.integers(0, bags, nnz))
    got = embedding_bag(table, jnp.asarray(flat), jnp.asarray(seg), bags)
    want = np.zeros((bags, 4), np.float32)
    for i, b in zip(flat, seg):
        want[b] += np.asarray(table)[i]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


@_settings
@given(st.integers(0, 2**31 - 1))
def test_fold_ids_in_range(seed):
    rng = np.random.default_rng(seed)
    spec = TableSpec((5, 11, 1000), 2)
    ids = jnp.asarray(rng.integers(-(2**30), 2**31 - 1, (8, 3)), jnp.int32)
    rows = np.asarray(fold_ids(jnp.abs(ids), spec))
    offs = spec.offsets
    for s in range(3):
        lo, hi = offs[s], offs[s] + spec.slot_rows[s]
        assert ((rows[:, s] >= lo) & (rows[:, s] < hi)).all()


@_settings
@given(st.integers(0, 2**31 - 1), st.integers(1, 3000))
def test_int8_compression_bounded_error(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n).astype(np.float32) * 10)
    xc = compress_decompress(x, block=256)
    # error bounded by half a quantization step per block
    blocks = np.asarray(x)
    err = np.abs(np.asarray(xc) - blocks)
    step = np.abs(blocks).max() / 127
    assert err.max() <= step + 1e-6


@_settings
@given(st.integers(0, 2**31 - 1))
def test_int8_roundtrip_shape_dtype(seed):
    rng = np.random.default_rng(seed)
    shape = (rng.integers(1, 20), rng.integers(1, 20))
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    q, s = quantize_int8(x, block=64)
    y = dequantize_int8(q, s, x.shape, x.dtype)
    assert y.shape == x.shape and y.dtype == x.dtype


@_settings
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
def test_saat_plan_contribution_order(seed, scale):
    """Plans always process segments in non-increasing contribution order."""
    from repro.core import build_impact_index
    from repro.core.saat import saat_plan

    rng = np.random.default_rng(seed)
    n_docs, n_terms, n_post = 50, 20, 300
    d = rng.integers(0, n_docs, n_post)
    t = rng.integers(0, n_terms, n_post)
    w = rng.gamma(2.0, scale, n_post)
    idx = build_impact_index(d, t, w, n_docs, n_terms)
    qt = jnp.asarray(rng.choice(n_terms, 5, replace=False).astype(np.int32))
    qw = jnp.asarray(rng.gamma(1.0, 1.0, 5).astype(np.float32))
    plan = saat_plan(idx, qt, qw, max_segs_per_term=int(jnp.max(idx.term_seg_count)))
    c = np.asarray(plan.contribs)
    assert (np.diff(c) <= 1e-6).all()
