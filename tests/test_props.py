"""Property-based tests (hypothesis) for system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.archs.embedding import TableSpec, embedding_bag, embedding_lookup, fold_ids
from repro.core.quantization import QuantConfig, dequantize, quantize
from repro.core.topk import topk
from repro.distributed.collectives import compress_decompress, quantize_int8, dequantize_int8

_settings = settings(max_examples=30, deadline=None)


@_settings
@given(
    st.lists(st.floats(0.001, 1e4), min_size=1, max_size=200),
    st.sampled_from([4, 6, 8, 10]),
)
def test_quantization_error_bounded_by_step(weights, bits):
    w = np.asarray(weights)
    q, scale = quantize(w, QuantConfig(bits=bits))
    deq = dequantize(q, scale)
    assert np.all(np.abs(deq - w) <= scale + 1e-9 * np.abs(w).max())
    assert q.max() <= (1 << bits) - 1 and q[w > 0].min() >= 1


@_settings
@given(st.integers(0, 2**31 - 1), st.integers(2, 64))
def test_topk_permutation_invariance(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=n).astype(np.float32)
    k = min(8, n)
    s1, _ = topk(jnp.asarray(x), k)
    perm = rng.permutation(n)
    s2, i2 = topk(jnp.asarray(x[perm]), k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2))
    np.testing.assert_allclose(x[perm][np.asarray(i2)], np.asarray(s1))


@_settings
@given(st.integers(0, 2**31 - 1), st.integers(1, 300), st.integers(2, 50))
def test_segment_sum_equals_onehot_matmul(seed, n, segs):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, segs, n)
    vals = rng.normal(size=n).astype(np.float32)
    got = jax.ops.segment_sum(jnp.asarray(vals), jnp.asarray(ids), num_segments=segs)
    onehot = np.zeros((segs, n), np.float32)
    onehot[ids, np.arange(n)] = 1.0
    np.testing.assert_allclose(np.asarray(got), onehot @ vals, rtol=1e-4, atol=1e-4)


@_settings
@given(st.integers(0, 2**31 - 1))
def test_embedding_bag_equals_dense(seed):
    rng = np.random.default_rng(seed)
    spec = TableSpec((7, 13, 29), 4)
    table = jnp.asarray(rng.normal(size=(spec.total_rows, 4)).astype(np.float32))
    nnz, bags = 40, 6
    flat = rng.integers(0, spec.total_rows, nnz)
    seg = np.sort(rng.integers(0, bags, nnz))
    got = embedding_bag(table, jnp.asarray(flat), jnp.asarray(seg), bags)
    want = np.zeros((bags, 4), np.float32)
    for i, b in zip(flat, seg):
        want[b] += np.asarray(table)[i]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


@_settings
@given(st.integers(0, 2**31 - 1))
def test_fold_ids_in_range(seed):
    rng = np.random.default_rng(seed)
    spec = TableSpec((5, 11, 1000), 2)
    ids = jnp.asarray(rng.integers(-(2**30), 2**31 - 1, (8, 3)), jnp.int32)
    rows = np.asarray(fold_ids(jnp.abs(ids), spec))
    offs = spec.offsets
    for s in range(3):
        lo, hi = offs[s], offs[s] + spec.slot_rows[s]
        assert ((rows[:, s] >= lo) & (rows[:, s] < hi)).all()


@_settings
@given(st.integers(0, 2**31 - 1), st.integers(1, 3000))
def test_int8_compression_bounded_error(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n).astype(np.float32) * 10)
    xc = compress_decompress(x, block=256)
    # error bounded by half a quantization step per block
    blocks = np.asarray(x)
    err = np.abs(np.asarray(xc) - blocks)
    step = np.abs(blocks).max() / 127
    assert err.max() <= step + 1e-6


@_settings
@given(st.integers(0, 2**31 - 1))
def test_int8_roundtrip_shape_dtype(seed):
    rng = np.random.default_rng(seed)
    shape = (rng.integers(1, 20), rng.integers(1, 20))
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    q, s = quantize_int8(x, block=64)
    y = dequantize_int8(q, s, x.shape, x.dtype)
    assert y.shape == x.shape and y.dtype == x.dtype


def _random_wacky_index(seed: int, scale: float, *, n_docs=50, n_terms=20, n_post=300):
    """Small impact-quantized index with gamma-distributed ("wacky") weights."""
    from repro.core import build_impact_index

    rng = np.random.default_rng(seed)
    d = rng.integers(0, n_docs, n_post)
    t = rng.integers(0, n_terms, n_post)
    w = rng.gamma(2.0, scale, n_post)
    return build_impact_index(d, t, w, n_docs, n_terms), rng


@_settings
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
def test_block_upper_bounds_dominate_block_scores(seed, scale):
    """ub[b] is a true upper bound on every block's exact document score."""
    from repro.core.daat import block_upper_bounds, max_blocks_per_term
    from repro.core.exhaustive import score_all_docs
    from repro.core.impact_index import query_vector

    idx, rng = _random_wacky_index(seed, scale)
    n_q = min(5, idx.n_terms)
    qt = jnp.asarray(rng.choice(idx.n_terms, n_q, replace=False).astype(np.int32))
    qw = jnp.asarray(rng.gamma(1.0, 1.0, n_q).astype(np.float32))
    ub = np.asarray(block_upper_bounds(idx, qt, qw, max_blocks_per_term(idx)))
    scores = np.asarray(score_all_docs(idx, query_vector(idx, qt, qw)))
    scores = np.where(np.isfinite(scores), scores, 0.0)  # pad docs score 0
    block_best = scores.reshape(idx.n_blocks, idx.block_size).max(axis=-1)
    # fp32 scatter order may differ from the row reduction: allow an ulp-scale slack
    slack = 1e-5 * max(1.0, float(np.abs(ub).max()))
    assert (ub + slack >= block_best).all(), (ub, block_best)


@pytest.mark.slow
@_settings
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
def test_daat_exact_equals_exhaustive_topk(seed, scale):
    """exact=True batched DAAT == exhaustive top-k on random wacky indexes."""
    from repro.core import daat_search_batched, exhaustive_search
    from repro.core.daat import max_blocks_per_term

    idx, rng = _random_wacky_index(seed, scale)
    B, n_q = 3, min(4, idx.n_terms)
    qt = jnp.asarray(rng.integers(0, idx.n_terms, (B, n_q)).astype(np.int32))
    qw = jnp.asarray(rng.gamma(1.0, 1.0, (B, n_q)).astype(np.float32))
    k = 5
    da = daat_search_batched(
        idx, qt, qw, k=k, est_blocks=1, block_budget=1,
        max_bm_per_term=max_blocks_per_term(idx), exact=True,
    )
    ex = exhaustive_search(idx, qt, qw, k=k)
    assert bool(np.asarray(da.rank_safe).all())
    np.testing.assert_allclose(
        np.asarray(da.scores), np.asarray(ex.scores), rtol=1e-5, atol=1e-5
    )


@_settings
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
def test_daat_rank_safe_monotone_in_est_blocks(seed, scale):
    """Raising est_blocks never decreases rank_safe (chunk ladder is nested).

    Safety at prefix m of the ub order is monotone in m — once the k-th score
    of the scored prefix dominates the next block's bound, any longer prefix
    dominates too — so seeding more phase-1 blocks (with the chunk count
    capped) can only move queries TOWARD rank safety.
    """
    from repro.core import daat_search_batched
    from repro.core.daat import max_blocks_per_term

    idx, rng = _random_wacky_index(seed, scale)
    n_q = min(4, idx.n_terms)
    qt = jnp.asarray(rng.integers(0, idx.n_terms, (2, n_q)).astype(np.int32))
    qw = jnp.asarray(rng.gamma(1.0, 1.0, (2, n_q)).astype(np.float32))
    mb = max_blocks_per_term(idx)
    prev = None
    for est in (1, 2, idx.n_blocks):
        da = daat_search_batched(
            idx, qt, qw, k=3, est_blocks=est, block_budget=1,
            max_bm_per_term=mb, exact=True, max_chunks=1,
        )
        safe = np.asarray(da.rank_safe).astype(np.int32)
        if prev is not None:
            assert (safe >= prev).all(), (est, safe, prev)
        prev = safe


@_settings
@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 400),
    st.integers(1, 64),
    st.integers(1, 16),
)
def test_block_candidate_topk_equals_global_topk(seed, n, k, num_tiles):
    """Rank-safety of the fused selection: per-block (tile) candidate pools
    merged with ``tiled_topk`` equal global ``lax.top_k`` — scores AND tie
    order — whenever k <= the per-block candidate count (which ``tiled_topk``
    guarantees by clamping k to the tile size: clamped tiles survive whole).
    Covers ragged n (auto-padded with NEG_INF) and k > n (clamped like topk).
    """
    from repro.core.topk import tiled_topk

    rng = np.random.default_rng(seed)
    x = rng.normal(size=n).astype(np.float32)
    x[rng.random(n) < 0.2] = -np.inf  # masked docs: exercise -inf tie order
    ts, ti = tiled_topk(jnp.asarray(x), k, num_tiles)
    gs, gi = jax.lax.top_k(jnp.asarray(x), min(k, n))
    np.testing.assert_array_equal(np.asarray(ts), np.asarray(gs))
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(gi))
    assert (np.asarray(ti) < n).all()  # pad slots never surface


@_settings
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
def test_fused_saat_rank_safe_rho_equals_exhaustive(seed, scale):
    """Fused scatter→top-k SAAT at a rank-safe rho == exhaustive scoring."""
    from repro.core import exact_rho, exhaustive_search, saat_search
    from repro.core.saat import max_segments_per_term

    idx, rng = _random_wacky_index(seed, scale)
    B, n_q = 2, min(4, idx.n_terms)
    qt = jnp.asarray(rng.integers(0, idx.n_terms, (B, n_q)).astype(np.int32))
    qw = jnp.asarray(rng.gamma(1.0, 1.0, (B, n_q)).astype(np.float32))
    k = 5
    f = saat_search(
        idx, qt, qw, k=k, rho=exact_rho(idx),
        max_segs_per_term=max_segments_per_term(idx), fused_topk=True,
    )
    ex = exhaustive_search(idx, qt, qw, k=k)
    np.testing.assert_allclose(
        np.asarray(f.scores), np.asarray(ex.scores), rtol=1e-4, atol=1e-4
    )


@_settings
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
def test_saat_plan_contribution_order(seed, scale):
    """Plans always process segments in non-increasing contribution order."""
    from repro.core import build_impact_index
    from repro.core.saat import saat_plan

    rng = np.random.default_rng(seed)
    n_docs, n_terms, n_post = 50, 20, 300
    d = rng.integers(0, n_docs, n_post)
    t = rng.integers(0, n_terms, n_post)
    w = rng.gamma(2.0, scale, n_post)
    idx = build_impact_index(d, t, w, n_docs, n_terms)
    qt = jnp.asarray(rng.choice(n_terms, 5, replace=False).astype(np.int32))
    qw = jnp.asarray(rng.gamma(1.0, 1.0, 5).astype(np.float32))
    plan = saat_plan(idx, qt, qw, max_segs_per_term=int(jnp.max(idx.term_seg_count)))
    c = np.asarray(plan.contribs)
    assert (np.diff(c) <= 1e-6).all()
