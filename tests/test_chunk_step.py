"""Fused DAAT chunk-step kernel: interpret-mode sweeps + properties.

The ``chunk_step`` kernel replaces the batched engine's phase-2 while-body
(select + score + merge) with ONE VMEM-resident pass, so the bar is the
strictest in the repo: doc ids, theta, the processed bitmap, AND the pool
scores must be **bitwise** identical to the jnp body (``chunk_step_batched_ref``
— the engine formulation, verbatim), per trip and end-to-end. The module
carries the ``kernels`` marker so a regression fails in the standalone CI
kernels entry by name.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_impact_index
from repro.core.daat import (
    daat_plan,
    daat_search_batched,
    max_blocks_per_term,
    score_blocks,
)
from repro.core.topk import topk
from repro.kernels.chunk_step.ops import (
    CONTRACT,
    chunk_step_batched,
    chunk_step_multi_batched,
)
from repro.kernels.chunk_step.ref import (
    chunk_step_batched_ref,
    chunk_step_multi_batched_ref,
)

pytestmark = pytest.mark.kernels


# --------------------------------------------------------------------------
# state construction helpers
# --------------------------------------------------------------------------

_INDEX_CACHE: dict = {}


def _tiny_index(seed=0, n_docs=220, n_terms=40, n_postings=1500, block_size=32):
    """Session-cached tiny index (220 docs / bs=32 -> 7 blocks, non-divisible
    by any power-of-two budget — the shapes the sweeps need)."""
    key = (seed, n_docs, n_terms, n_postings, block_size)
    if key not in _INDEX_CACHE:
        rng = np.random.default_rng(seed)
        d = rng.integers(0, n_docs, n_postings)
        t = rng.integers(0, n_terms, n_postings)
        w = rng.gamma(2.0, 1.0, n_postings)
        _INDEX_CACHE[key] = build_impact_index(
            d, t, w, n_docs, n_terms, block_size=block_size
        )
    return _INDEX_CACHE[key]


def _phase1_state(idx, qt, qw, *, k, est_blocks=2):
    """Reproduce the engine's phase-1 seeding: the state a chunk step takes."""
    mb = max_blocks_per_term(idx)
    plan = daat_plan(idx, qt, qw, mb)
    ub = plan.ub
    B = qt.shape[0]
    _, b1 = topk(ub, est_blocks)
    s1, d1 = score_blocks(idx, plan.qvec, b1)
    pool_s, pool_i = topk(s1.reshape(B, -1), k)
    pool_i = jnp.take_along_axis(d1.reshape(B, -1), pool_i, axis=-1).astype(jnp.int32)
    theta = pool_s[:, k - 1]
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    processed = jnp.zeros((B, idx.n_blocks), jnp.bool_).at[rows, b1].set(True)
    return ub, processed, pool_s, pool_i, theta


def _random_queries(idx, rng, B, Lq):
    qt = rng.integers(0, idx.n_terms, (B, Lq)).astype(np.int32)
    qw = rng.gamma(1.0, 1.0, (B, Lq)).astype(np.float32)
    return jnp.asarray(qt), jnp.asarray(qw)


def _assert_step_bitwise(idx, qt, qw, state, *, budget):
    """Kernel vs the jnp body: EVERYTHING bitwise, scores included."""
    ub, processed, pool_s, pool_i, theta = state
    qw_raw = jnp.where(qw > 0, qw, 0.0)
    got = chunk_step_batched(
        idx.doc_terms, idx.doc_weights, qt, qw_raw,
        ub, processed, pool_s, pool_i, theta,
        block_budget=budget, block_size=idx.block_size, n_live=idx.n_docs,
    )
    want = chunk_step_batched_ref(
        idx.doc_terms, idx.doc_weights, qt, qw,
        ub, processed, pool_s, pool_i, theta,
        block_budget=budget, block_size=idx.block_size, n_live=idx.n_docs,
        n_terms=idx.n_terms,
    )
    for name, g, r in zip(("pool_s", "pool_i", "theta", "processed"), got, want):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(r), err_msg=f"chunk step {name} diverged (bitwise)"
        )
    return got


def _assert_multi_step_bitwise(idx, qt, qw, state, trips_left, *, budget, trips):
    """Multi-trip kernel vs its jnp oracle: all five outputs bitwise."""
    ub, processed, pool_s, pool_i, theta = state
    qw_raw = jnp.where(qw > 0, qw, 0.0)
    tl = jnp.asarray(trips_left, jnp.int32)
    got = chunk_step_multi_batched(
        idx.doc_terms, idx.doc_weights, qt, qw_raw,
        ub, processed, pool_s, pool_i, theta, tl,
        trips_per_launch=trips, block_budget=budget,
        block_size=idx.block_size, n_live=idx.n_docs,
    )
    want = chunk_step_multi_batched_ref(
        idx.doc_terms, idx.doc_weights, qt, qw,
        ub, processed, pool_s, pool_i, theta, tl,
        trips_per_launch=trips, block_budget=budget,
        block_size=idx.block_size, n_live=idx.n_docs, n_terms=idx.n_terms,
    )
    names = ("pool_s", "pool_i", "theta", "processed", "trips_done")
    for name, g, r in zip(names, got, want):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(r),
            err_msg=f"multi-trip chunk step {name} diverged (bitwise)",
        )
    return got


# --------------------------------------------------------------------------
# interpret-mode degenerate sweeps (op vs jnp body)
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "dims", [c.dims for c in CONTRACT.shape_grid],
    ids=[c.name for c in CONTRACT.shape_grid],
)
def test_chunk_step_sweep(dims):
    """Executes the CONTRACT's exact shape grid (what the checker traces):
    the full B x budget x k cross on the 7-block index — budget 3 is
    non-divisible, 7 == n_blocks — plus the ragged bs=24 degenerate and the
    multi-trip cases (``trips`` dim present: the scalar-prefetched launch
    with heterogeneous per-row trip budgets, including a zero-budget row)."""
    idx = _tiny_index(n_docs=dims["n_docs"], block_size=dims["block_size"])
    rng = np.random.default_rng(dims["B"] * 100 + dims["budget"] * 10 + dims["k"])
    qt, qw = _random_queries(idx, rng, dims["B"], dims["lq"])
    state = _phase1_state(idx, qt, qw, k=dims["k"])
    if "trips" in dims:
        trips = dims["trips"]
        # heterogeneous budgets spanning 0..trips exercise the per-row gate
        trips_left = np.arange(dims["B"], dtype=np.int32) % (trips + 1)
        _assert_multi_step_bitwise(
            idx, qt, qw, state, trips_left, budget=dims["budget"], trips=trips
        )
    else:
        _assert_step_bitwise(idx, qt, qw, state, budget=dims["budget"])


def test_multi_trip_matches_sequential_single_trips():
    """One multi-trip launch == the same trips applied one launch at a time
    (the exact equivalence the engine's trips_per_launch routing relies on)."""
    idx = _tiny_index()
    rng = np.random.default_rng(11)
    qt, qw = _random_queries(idx, rng, 3, 5)
    state = _phase1_state(idx, qt, qw, k=4)
    trips = 4
    got = _assert_multi_step_bitwise(
        idx, qt, qw, state, np.full(3, trips, np.int32), budget=2, trips=trips
    )
    ub = state[0]
    qw_raw = jnp.where(qw > 0, qw, 0.0)
    _, processed, pool_s, pool_i, theta = state
    for _ in range(trips):
        rub = jnp.where(processed, -jnp.inf, ub)
        act = jnp.max(rub, axis=-1, initial=-jnp.inf) > theta
        step = chunk_step_batched(
            idx.doc_terms, idx.doc_weights, qt, qw_raw,
            ub, processed, pool_s, pool_i, theta,
            block_budget=2, block_size=idx.block_size, n_live=idx.n_docs,
        )
        m = act[:, None]
        pool_s = jnp.where(m, step[0], pool_s)
        pool_i = jnp.where(m, step[1], pool_i)
        theta = jnp.where(act, step[2], theta)
        processed = jnp.where(m, step[3], processed)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(pool_s))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(pool_i))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(theta))
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(processed))


def test_multi_trip_early_exit_counts_trips():
    """trips_done stops where a row goes rank-safe or its budget ends; a
    zero-budget row rides through the launch bit-for-bit untouched."""
    idx = _tiny_index()
    rng = np.random.default_rng(12)
    qt, qw = _random_queries(idx, rng, 3, 5)
    ub, processed, pool_s, pool_i, theta = _phase1_state(idx, qt, qw, k=4)
    trips_left = np.array([0, 2, 8], np.int32)
    got = _assert_multi_step_bitwise(
        idx, qt, qw, (ub, processed, pool_s, pool_i, theta), trips_left,
        budget=3, trips=8,
    )
    trips_done = np.asarray(got[4])
    assert trips_done[0] == 0
    assert trips_done[1] <= 2
    # the 7-block index at budget 3 is fully scored in <= 3 trips: row 2's
    # in-kernel early exit must fire well before its 8-trip budget
    assert trips_done[2] < 8
    np.testing.assert_array_equal(np.asarray(got[0])[0], np.asarray(pool_s)[0])
    np.testing.assert_array_equal(np.asarray(got[3])[0], np.asarray(processed)[0])


def test_multi_trip_validates_budget():
    idx = _tiny_index()
    rng = np.random.default_rng(13)
    qt, qw = _random_queries(idx, rng, 2, 4)
    ub, processed, pool_s, pool_i, theta = _phase1_state(idx, qt, qw, k=3)
    with pytest.raises(ValueError, match="trips_per_launch"):
        chunk_step_multi_batched(
            idx.doc_terms, idx.doc_weights, qt, qw,
            ub, processed, pool_s, pool_i, theta,
            jnp.ones((2,), jnp.int32),
            trips_per_launch=0, block_budget=2,
            block_size=idx.block_size, n_live=idx.n_docs,
        )


def test_chunk_step_all_pruned_trip():
    """theta above every remaining ub: nothing is live, the whole state must
    ride through the kernel bit-for-bit unchanged."""
    idx = _tiny_index()
    rng = np.random.default_rng(1)
    qt, qw = _random_queries(idx, rng, 3, 5)
    ub, processed, pool_s, pool_i, _ = _phase1_state(idx, qt, qw, k=4)
    theta = jnp.full((3,), float(jnp.max(ub)) + 1.0, jnp.float32)
    got = _assert_step_bitwise(
        idx, qt, qw, (ub, processed, pool_s, pool_i, theta), budget=3
    )
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(pool_s))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(pool_i))
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(processed))


def test_chunk_step_single_active_row():
    """Rows whose blocks are all processed idle; the one live row advances."""
    idx = _tiny_index()
    rng = np.random.default_rng(2)
    qt, qw = _random_queries(idx, rng, 3, 5)
    ub, processed, pool_s, pool_i, theta = _phase1_state(idx, qt, qw, k=4)
    processed = processed.at[1:, :].set(True)  # only row 0 has work left
    got = _assert_step_bitwise(
        idx, qt, qw, (ub, processed, pool_s, pool_i, theta), budget=2
    )
    np.testing.assert_array_equal(np.asarray(got[0])[1:], np.asarray(pool_s)[1:])
    np.testing.assert_array_equal(np.asarray(got[3])[1:], np.asarray(processed)[1:])
    assert bool((np.asarray(got[3])[0] >= np.asarray(processed)[0]).all())


def test_chunk_step_duplicate_and_zero_weight_terms():
    """Dup query terms sum, zero-weight slots vanish, all-pad rows idle."""
    idx = _tiny_index()
    rng = np.random.default_rng(3)
    qt, qw = (np.array(a) for a in _random_queries(idx, rng, 4, 6))
    qt[:, 1] = qt[:, 0]
    qw[:, 2] = 0.0
    qt[2], qw[2] = idx.n_terms, 0.0  # all-pad row
    qt, qw = jnp.asarray(qt), jnp.asarray(qw)
    state = _phase1_state(idx, qt, qw, k=4)
    _assert_step_bitwise(idx, qt, qw, state, budget=3)


def test_chunk_step_k_at_pool_boundary():
    """k equal to the whole merged width boundary cases: the k-th slot (the
    new theta) comes from the last candidate rank, where an off-by-one in the
    merge shows up first."""
    idx = _tiny_index()
    rng = np.random.default_rng(4)
    qt, qw = _random_queries(idx, rng, 2, 5)
    # k == est_blocks * block_size: the pool exactly at phase-1 capacity
    k = 2 * idx.block_size
    state = _phase1_state(idx, qt, qw, k=k, est_blocks=2)
    _assert_step_bitwise(idx, qt, qw, state, budget=3)


def test_chunk_step_budget_exceeding_blocks_rejected():
    idx = _tiny_index()
    rng = np.random.default_rng(6)
    qt, qw = _random_queries(idx, rng, 2, 4)
    ub, processed, pool_s, pool_i, theta = _phase1_state(idx, qt, qw, k=3)
    with pytest.raises(ValueError, match="n_blocks"):
        chunk_step_batched(
            idx.doc_terms, idx.doc_weights, qt, qw,
            ub, processed, pool_s, pool_i, theta,
            block_budget=idx.n_blocks + 1, block_size=idx.block_size,
            n_live=idx.n_docs,
        )


# --------------------------------------------------------------------------
# engine-level golden parity: fused chunk step vs the jnp oracle
# --------------------------------------------------------------------------


def _assert_engine_parity(index, qt, qw, **kw):
    """fused == split kernels (bitwise) == jnp oracle (ids/stats/scores)."""
    kw.setdefault("max_bm_per_term", max_blocks_per_term(index))
    j = daat_search_batched(index, qt, qw, use_kernels=False, **kw)
    s = daat_search_batched(index, qt, qw, use_kernels=True, **kw)
    f = daat_search_batched(index, qt, qw, use_kernels=True, fused_chunk=True, **kw)
    # the fusion is invisible next to the split kernel mode — bitwise
    np.testing.assert_array_equal(np.asarray(f.doc_ids), np.asarray(s.doc_ids))
    np.testing.assert_array_equal(np.asarray(f.scores), np.asarray(s.scores))
    # and indistinguishable from the jnp oracle in ids + WorkStats
    np.testing.assert_array_equal(np.asarray(f.doc_ids), np.asarray(j.doc_ids))
    np.testing.assert_allclose(
        np.asarray(f.scores), np.asarray(j.scores), rtol=1e-5, atol=1e-6
    )
    for field in ("n_survivors", "blocks_scored", "chunks", "rank_safe"):
        np.testing.assert_array_equal(
            np.asarray(getattr(f.stats, field)),
            np.asarray(getattr(j.stats, field)),
            err_msg=f"WorkStats.{field} diverged between fused and jnp phase 2",
        )
    return f


@pytest.mark.parametrize("exact", [True, False])
def test_engine_fused_chunk_parity(bm25_index, bm25_queries, exact):
    qt, qw = bm25_queries
    _assert_engine_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=2, block_budget=2, exact=exact,
    )


def test_engine_fused_chunk_ragged_batch(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    qt, qw = np.array(qt[:8]), np.array(qw[:8])
    for i in range(qt.shape[0]):
        keep = max(1, qt.shape[1] - i)
        qw[i, keep:] = 0.0
        qt[i, keep:] = bm25_index.n_terms
    _assert_engine_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=2, block_budget=1, exact=True,
    )


def test_engine_fused_chunk_k_exceeds_n_docs():
    idx = _tiny_index(seed=7, n_docs=50, n_terms=30, n_postings=400, block_size=32)
    rng = np.random.default_rng(8)
    qt = jnp.asarray(rng.integers(0, 30, (3, 4)).astype(np.int32))
    qw = jnp.asarray(rng.gamma(1.0, 1.0, (3, 4)).astype(np.float32))
    f = _assert_engine_parity(
        idx, qt, qw, k=60, est_blocks=idx.n_blocks, block_budget=1, exact=True,
    )
    assert bool(np.isneginf(np.asarray(f.scores)[:, 50:]).all())


def test_engine_fused_chunk_max_chunks_cap(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    f = _assert_engine_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=1, block_budget=1, exact=True, max_chunks=1,
    )
    assert int(np.asarray(f.chunks).max()) <= 1


@pytest.mark.parametrize("trips", [2, 3, 8])
def test_engine_multi_trip_parity(bm25_index, bm25_queries, trips):
    """trips_per_launch is invisible: ids/scores/WorkStats bitwise vs the
    per-trip fused mode (which itself is pinned to the jnp oracle above)."""
    qt, qw = bm25_queries
    kw = dict(
        k=10, est_blocks=2, block_budget=2, exact=True,
        max_bm_per_term=max_blocks_per_term(bm25_index),
        use_kernels=True, fused_chunk=True,
    )
    f = daat_search_batched(bm25_index, jnp.asarray(qt), jnp.asarray(qw), **kw)
    m = daat_search_batched(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        trips_per_launch=trips, **kw,
    )
    np.testing.assert_array_equal(np.asarray(m.doc_ids), np.asarray(f.doc_ids))
    np.testing.assert_array_equal(np.asarray(m.scores), np.asarray(f.scores))
    for field in ("n_survivors", "blocks_scored", "chunks", "rank_safe"):
        np.testing.assert_array_equal(
            np.asarray(getattr(m.stats, field)),
            np.asarray(getattr(f.stats, field)),
            err_msg=f"WorkStats.{field} diverged under trips_per_launch={trips}",
        )


def test_engine_multi_trip_anytime_flag_invariant(bm25_index, bm25_queries):
    """exact=False clamps the trip batching to 1: the anytime budget is
    enforced per trip, so trips_per_launch must not change anything."""
    qt, qw = bm25_queries
    kw = dict(
        k=10, est_blocks=2, block_budget=2, exact=False,
        max_bm_per_term=max_blocks_per_term(bm25_index),
        use_kernels=True, fused_chunk=True,
    )
    a = daat_search_batched(bm25_index, jnp.asarray(qt), jnp.asarray(qw), **kw)
    b = daat_search_batched(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw), trips_per_launch=4, **kw
    )
    np.testing.assert_array_equal(np.asarray(b.doc_ids), np.asarray(a.doc_ids))
    np.testing.assert_array_equal(np.asarray(b.scores), np.asarray(a.scores))
    np.testing.assert_array_equal(
        np.asarray(b.stats.chunks), np.asarray(a.stats.chunks)
    )


def test_engine_multi_trip_requires_fused_chunk(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    with pytest.raises(ValueError, match="fused_chunk"):
        daat_search_batched(
            bm25_index, jnp.asarray(qt[:2]), jnp.asarray(qw[:2]),
            k=5, est_blocks=2, block_budget=2,
            max_bm_per_term=max_blocks_per_term(bm25_index),
            use_kernels=True, fused_chunk=False, trips_per_launch=2,
        )


def test_engine_fused_chunk_requires_kernels(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    with pytest.raises(ValueError, match="use_kernels"):
        daat_search_batched(
            bm25_index, jnp.asarray(qt[:2]), jnp.asarray(qw[:2]),
            k=5, est_blocks=2, block_budget=2,
            max_bm_per_term=max_blocks_per_term(bm25_index),
            use_kernels=False, fused_chunk=True,
        )


def test_sharded_fused_chunk_serve_matches_exhaustive(
    tiny_corpus, bm25_collection, bm25_index, bm25_queries
):
    """Doc-sharded DAAT with the fused chunk step on every rank == oracle."""
    import jax

    from repro.core import exhaustive_search
    from repro.serving import make_sharded_serve_step, shard_corpus, stack_indexes

    enc = bm25_collection
    qt, qw = bm25_queries
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards, dps = shard_corpus(
        enc.doc_idx, enc.term_idx, enc.weights, tiny_corpus.n_docs, enc.n_terms, 2
    )
    stacked = stack_indexes(shards)
    serve, _, _ = make_sharded_serve_step(
        mesh,
        k=10,
        rho_per_shard=0,  # unused by the daat engine
        max_segs_per_term=0,
        docs_per_shard=dps,
        engine="daat",
        daat_est_blocks=2,
        daat_block_budget=2,
        max_bm_per_term=stacked.max_bm,
        daat_use_kernels=True,
        daat_fused_chunk=True,
    )
    with mesh:
        ss, si = serve(stacked, jnp.asarray(qt[:8]), jnp.asarray(qw[:8]))
    ex = exhaustive_search(bm25_index, jnp.asarray(qt[:8]), jnp.asarray(qw[:8]), k=10)
    np.testing.assert_allclose(
        np.asarray(ss), np.asarray(ex.scores), rtol=1e-4, atol=1e-4
    )
    assert (np.asarray(si) == np.asarray(ex.doc_ids)).mean() > 0.8


def test_sharded_fused_chunk_requires_kernels():
    import jax

    from repro.serving import make_sharded_serve_step

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="daat_use_kernels"):
        make_sharded_serve_step(
            mesh, k=5, rho_per_shard=0, max_segs_per_term=0, docs_per_shard=100,
            engine="daat", max_bm_per_term=3,
            daat_use_kernels=False, daat_fused_chunk=True,
        )


# --------------------------------------------------------------------------
# hypothesis property (skipped — not the whole module — without hypothesis)
# --------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    _settings = settings(
        max_examples=15,
        deadline=None,
        suppress_health_check=[HealthCheck.function_scoped_fixture, HealthCheck.too_slow],
    )
    _HYPOTHESIS = True
except ImportError:  # deterministic sweeps above still run
    _HYPOTHESIS = False

    def given(**kw):  # noqa: D103 - placeholder so decorators below parse
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def _settings(f):
        return f

    class st:  # noqa: D101
        integers = sampled_from = staticmethod(lambda *a, **k: None)


@_settings
@given(
    seed=st.integers(0, 2**31 - 1),
    B=st.sampled_from([1, 2, 4]),
    budget=st.sampled_from([1, 2, 3, 7]),
    k=st.sampled_from([1, 4]),
    processed_frac=st.sampled_from([0.0, 0.5, 1.0]),
)
def test_prop_chunk_step_bitwise(seed, B, budget, k, processed_frac):
    """Any reachable (and some unreachable) chunk state: kernel == jnp body,
    bitwise, for ids, theta, pool scores, and the processed bitmap."""
    idx = _tiny_index()
    rng = np.random.default_rng(seed)
    qt, qw = _random_queries(idx, rng, B, 5)
    ub, processed, pool_s, pool_i, theta = _phase1_state(idx, qt, qw, k=k)
    # random extra processed blocks model a mid-loop trip (phase 1 marks
    # processed_frac=0's baseline; 1.0 drives the all-pruned degenerate)
    extra = jnp.asarray(rng.random((B, idx.n_blocks)) < processed_frac)
    processed = processed | extra
    _assert_step_bitwise(
        idx, qt, qw, (ub, processed, pool_s, pool_i, theta), budget=budget
    )
