"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real (1-device) CPU; only launch/dryrun.py forces 512."""
import numpy as np
import pytest

from repro.core import build_impact_index, pad_queries
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.models.treatments import apply_treatment


@pytest.fixture(scope="session")
def tiny_corpus():
    return generate_corpus(CorpusConfig(n_docs=400, n_queries=30, n_concepts=80, seed=3))


@pytest.fixture(scope="session")
def bm25_collection(tiny_corpus):
    return apply_treatment(tiny_corpus, "bm25")


@pytest.fixture(scope="session")
def splade_collection(tiny_corpus):
    return apply_treatment(tiny_corpus, "spladev2")


@pytest.fixture(scope="session")
def bm25_index(tiny_corpus, bm25_collection):
    enc = bm25_collection
    return build_impact_index(enc.doc_idx, enc.term_idx, enc.weights, tiny_corpus.n_docs, enc.n_terms)


@pytest.fixture(scope="session")
def bm25_queries(bm25_collection):
    enc = bm25_collection
    max_q = max(len(t) for t in enc.query_terms)
    return pad_queries(enc.query_terms, enc.query_weights, max_q, enc.n_terms)
