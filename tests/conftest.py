"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real (1-device) CPU; only launch/dryrun.py forces 512."""
import numpy as np
import pytest

from repro.core import build_impact_index, pad_queries
from repro.data.synthetic import CorpusConfig, generate_corpus
from repro.models.treatments import apply_treatment


@pytest.fixture(scope="session")
def tiny_corpus():
    return generate_corpus(CorpusConfig(n_docs=400, n_queries=30, n_concepts=80, seed=3))


@pytest.fixture(scope="session")
def bm25_collection(tiny_corpus):
    return apply_treatment(tiny_corpus, "bm25")


@pytest.fixture(scope="session")
def splade_collection(tiny_corpus):
    return apply_treatment(tiny_corpus, "spladev2")


@pytest.fixture(scope="session")
def bm25_index(tiny_corpus, bm25_collection):
    enc = bm25_collection
    return build_impact_index(enc.doc_idx, enc.term_idx, enc.weights, tiny_corpus.n_docs, enc.n_terms)


@pytest.fixture(scope="session")
def bm25_queries(bm25_collection):
    enc = bm25_collection
    max_q = max(len(t) for t in enc.query_terms)
    return pad_queries(enc.query_terms, enc.query_weights, max_q, enc.n_terms)


# The serving CI entry runs the queue/bucketing suite under a fixed,
# derandomized hypothesis profile (HYPOTHESIS_PROFILE=serving-ci) so
# time-policy tests cannot land flaky. No-op when hypothesis is absent
# (tier-1 validation container) or the env var is unset.
try:
    import os as _os

    from hypothesis import HealthCheck as _HealthCheck, settings as _hyp_settings

    _hyp_settings.register_profile(
        "serving-ci",
        derandomize=True,
        max_examples=15,
        deadline=None,
        print_blob=True,
        suppress_health_check=[
            _HealthCheck.function_scoped_fixture,
            _HealthCheck.too_slow,
            _HealthCheck.data_too_large,
        ],
    )
    if _os.environ.get("HYPOTHESIS_PROFILE"):
        _hyp_settings.load_profile(_os.environ["HYPOTHESIS_PROFILE"])
except ImportError:
    pass
