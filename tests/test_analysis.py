"""Seeded-violation tests for ``repro.analysis``: every pass must CATCH.

A static gate that never fires is decoration. Each checker here is fed (a)
the real checked-in registry, which must pass clean, and (b) a deliberately
broken artifact of exactly the failure class it gates — a missing DMA wait,
an over-budget VMEM footprint, a ragged block, a host callback under the
trace — which must produce an actionable violation.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import jaxpr_walk
from repro.analysis.check import _probe_index, main as check_main
from repro.analysis.hot_path import check_dtype_discipline, lint_server, lint_trace
from repro.analysis.kernel_contracts import (
    KernelContract,
    ShapeCase,
    all_contracts,
    check_contract,
)
from repro.serving.scheduler import AnytimeServer, ServingConfig

pytestmark = pytest.mark.analysis

_SDS = jax.ShapeDtypeStruct


# --------------------------------------------------------------------------
# the checked-in registry passes clean
# --------------------------------------------------------------------------

CONTRACTS = all_contracts()


def test_every_kernel_package_declares_a_contract():
    assert set(CONTRACTS) == {
        "block_prune", "block_prune_csr", "block_topk", "chunk_step",
        "impact_scatter", "impact_scatter_topk", "sparse_score",
    }


@pytest.mark.parametrize("name", sorted(CONTRACTS))
def test_checked_in_contract_passes(name):
    violations = check_contract(CONTRACTS[name])
    assert violations == [], "\n".join(str(v) for v in violations)


def test_chunk_step_contract_expects_dma():
    # the double-buffer race class only exists because the copies exist;
    # a refactor that silently drops the DMAs must trip expect_dma
    assert CONTRACTS["chunk_step"].expect_dma


def test_csr_prune_contract_expects_scalar_prefetch():
    # the CSR walk only works because the window offsets arrive via scalar
    # prefetch; a refactor that re-densifies would drop the SMEM operands
    assert CONTRACTS["block_prune_csr"].expect_scalar_prefetch
    assert CONTRACTS["block_prune_csr"].expect_dma
    # chunk_step's grid is mixed: only the multi-trip cases prefetch
    assert any(
        c.expect_scalar_prefetch for c in CONTRACTS["chunk_step"].shape_grid
    )


# --------------------------------------------------------------------------
# seeded violation: missing DMA wait (the chunk_step race class)
# --------------------------------------------------------------------------


def _dma_kernel_jaxpr(wait_before_read: bool):
    """A minimal double-buffer-shaped kernel; optionally drop the wait."""

    def kern(src_hbm, o_ref, buf, sem):
        cp = pltpu.make_async_copy(
            src_hbm.at[pl.ds(0, 8), :], buf.at[0], sem.at[0, 0]
        )
        cp.start()
        if wait_before_read:
            cp.wait()
        o_ref[...] = buf[0]

    f = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
        out_shape=_SDS((8, 128), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, 8, 128), jnp.float32),
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
        interpret=True,
    )
    jx = jax.make_jaxpr(f)(_SDS((16, 128), jnp.float32))
    (eqn,) = jaxpr_walk.find_pallas_calls(jx.jaxpr)
    return eqn.params["jaxpr"]


def test_missing_dma_wait_is_caught():
    report = jaxpr_walk.check_dma_discipline(_dma_kernel_jaxpr(wait_before_read=False))
    assert report.starts == 1 and report.waits == 0
    assert report.violations, "the seeded race must be flagged"
    text = " ".join(report.violations)
    assert "wait" in text and "slot" in text  # actionable, names the slot


def test_disciplined_dma_is_clean():
    report = jaxpr_walk.check_dma_discipline(_dma_kernel_jaxpr(wait_before_read=True))
    assert report.starts == 1 and report.waits == 1
    assert report.violations == []


# --------------------------------------------------------------------------
# seeded violation: destination-slot reuse across revolving-buffer trips
# --------------------------------------------------------------------------


def _dst_reuse_kernel_jaxpr(wait_between: bool):
    """Two copies into the SAME destination slot on DIFFERENT semaphores —
    the trip-loop revolving-buffer race the multi-trip chunk step could hit
    if a trip re-issued a slot's copy before the previous trip drained it."""

    def kern(src_hbm, o_ref, buf, sem):
        c1 = pltpu.make_async_copy(
            src_hbm.at[pl.ds(0, 8), :], buf.at[0], sem.at[0, 0]
        )
        c2 = pltpu.make_async_copy(
            src_hbm.at[pl.ds(8, 8), :], buf.at[0], sem.at[1, 0]
        )
        c1.start()
        if wait_between:
            c1.wait()
        c2.start()
        c2.wait()
        if not wait_between:
            c1.wait()
        o_ref[...] = buf[0]

    f = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
        out_shape=_SDS((8, 128), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, 8, 128), jnp.float32),
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
        interpret=True,
    )
    jx = jax.make_jaxpr(f)(_SDS((16, 128), jnp.float32))
    (eqn,) = jaxpr_walk.find_pallas_calls(jx.jaxpr)
    return eqn.params["jaxpr"]


def test_dst_slot_reuse_is_caught():
    report = jaxpr_walk.check_dma_discipline(_dst_reuse_kernel_jaxpr(wait_between=False))
    assert report.starts == 2
    assert report.violations, "the seeded destination-slot race must be flagged"
    text = " ".join(report.violations)
    assert "destination" in text and "still in flight" in text


def test_dst_slot_reuse_with_wait_is_clean():
    report = jaxpr_walk.check_dma_discipline(_dst_reuse_kernel_jaxpr(wait_between=True))
    assert report.starts == 2 and report.waits == 2
    assert report.violations == []


# --------------------------------------------------------------------------
# seeded violation: scalar prefetch expected but absent
# --------------------------------------------------------------------------


def test_expect_scalar_prefetch_without_prefetch_is_caught():
    no_sp = KernelContract(
        name="seeded_no_scalar_prefetch",
        make_call=_blocked_op,
        expect_scalar_prefetch=True,
        shape_grid=(ShapeCase("aligned", dict(n=128, blk=64)),),
    )
    violations = check_contract(no_sp)
    assert any(v.check == "scalar_prefetch" for v in violations)


def test_scalar_prefetch_expectation_per_case_override():
    # contract-level default False, one case opting in: only that case fires
    mixed = KernelContract(
        name="seeded_mixed_scalar_prefetch",
        make_call=_blocked_op,
        shape_grid=(
            ShapeCase("plain", dict(n=128, blk=64)),
            ShapeCase(
                "wants_prefetch", dict(n=128, blk=64),
                expect_scalar_prefetch=True,
            ),
        ),
    )
    violations = check_contract(mixed)
    sp = [v for v in violations if v.check == "scalar_prefetch"]
    assert [v.case for v in sp] == ["wants_prefetch"]


# --------------------------------------------------------------------------
# seeded violation: the densified [B, Lq, n_blocks] intermediate
# --------------------------------------------------------------------------


def test_densified_blockmax_is_caught():
    from repro.analysis.hot_path import check_no_densified_blockmax

    B, lq, nb = 2, 6, 7
    jx = jax.make_jaxpr(
        lambda qw, rows: jnp.einsum("ql,qlb->qb", qw, rows)
    )(_SDS((B, lq), jnp.float32), _SDS((B, lq, nb), jnp.float32))
    violations = check_no_densified_blockmax(jx, (B, lq, nb), "seeded", "dense")
    assert violations, "the densified intermediate must be flagged"
    assert all(v.check == "dense_blockmax" for v in violations)
    assert "block_prune_csr" not in str(violations[0])  # message is generic
    assert "CSR" in str(violations[0])


def test_daat_phase0_gate_is_clean():
    from repro.analysis.check import run_daat_phase0_checks

    assert run_daat_phase0_checks() == []


# --------------------------------------------------------------------------
# seeded violation: VMEM over budget (with per-operand breakdown)
# --------------------------------------------------------------------------


def _copy_op(dims):
    n = dims["n"]

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    f = pl.pallas_call(kern, out_shape=_SDS((n,), jnp.float32), interpret=True)
    return f, (_SDS((n,), jnp.float32),)


def test_vmem_over_budget_is_caught():
    # 8 MiB f32 in + out, x2 pipeline each = 32 MiB against the 16 MiB core
    hog = KernelContract(
        name="seeded_vmem_hog",
        make_call=_copy_op,
        shape_grid=(ShapeCase("huge", dict(n=1 << 21)),),
    )
    violations = check_contract(hog)
    vmem = [v for v in violations if v.check == "vmem"]
    assert vmem, "an over-budget footprint must be flagged"
    assert "breakdown" in vmem[0].message  # names the offending tile
    assert "x2" in vmem[0].message


def test_vmem_within_budget_is_clean():
    small = KernelContract(
        name="seeded_vmem_small",
        make_call=_copy_op,
        shape_grid=(ShapeCase("tiny", dict(n=1024)),),
    )
    assert check_contract(small) == []


# --------------------------------------------------------------------------
# seeded violation: ragged block / missing DMAs where expected
# --------------------------------------------------------------------------


def _blocked_op(dims):
    n, blk = dims["n"], dims["blk"]

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    f = pl.pallas_call(
        kern,
        grid=(-(-n // blk),),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=_SDS((n,), jnp.float32),
        interpret=True,
    )
    return f, (_SDS((n,), jnp.float32),)


def test_ragged_block_is_caught():
    ragged = KernelContract(
        name="seeded_ragged",
        make_call=_blocked_op,
        shape_grid=(ShapeCase("ragged", dict(n=100, blk=64)),),
    )
    violations = check_contract(ragged)
    assert any(v.check == "divisibility" for v in violations)


def test_expect_dma_without_copies_is_caught():
    no_dma = KernelContract(
        name="seeded_no_dma",
        make_call=_blocked_op,
        expect_dma=True,
        shape_grid=(ShapeCase("aligned", dict(n=128, blk=64)),),
    )
    violations = check_contract(no_dma)
    assert any(v.check == "dma" for v in violations)


# --------------------------------------------------------------------------
# seeded violation: host callback / weak type on a traced serve step
# --------------------------------------------------------------------------


def test_host_callback_on_hot_path_is_caught():
    def served(qt, qw):
        jax.debug.print("theta={t}", t=qw.sum())  # the classic accident
        return qw * 2

    violations, fp = lint_trace(
        served, (_SDS((2, 4), jnp.int32), _SDS((2, 4), jnp.float32)),
        "seeded", "callback",
    )
    assert fp is not None
    assert any(v.check == "host_sync" for v in violations)
    assert "host-side wrapper" in str(violations[0])  # says where it belongs


def test_weak_type_at_boundary_is_caught():
    jx = jax.make_jaxpr(lambda w: w + 1.0)(1.5)  # python scalar leaks in
    violations = check_dtype_discipline(jx, "seeded", "weak")
    assert any(v.check == "weak_type" for v in violations)


def test_pure_hot_path_is_clean():
    violations, fp = lint_trace(
        lambda qt, qw: (qw * 2.0).sum(-1),
        (_SDS((2, 4), jnp.int32), _SDS((2, 4), jnp.float32)),
        "seeded", "pure",
    )
    assert fp is not None and violations == []


# --------------------------------------------------------------------------
# the real serving grid lints clean; executable keys behave
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def probe_index():
    return _probe_index()


@pytest.mark.parametrize(
    "cfg",
    [
        ServingConfig(engine="saat", k=5, rho_ladder=(200, 1000), lq_buckets=(4, 8)),
        ServingConfig(
            engine="daat", k=5, daat_est_blocks=4, daat_block_budget=4,
            daat_use_kernels=True, lq_buckets=(4,),
        ),
    ],
    ids=["saat", "daat_kernels"],
)
def test_server_grid_lints_clean(probe_index, cfg):
    violations = lint_server(AnytimeServer(probe_index, cfg), batch_sizes=(2,))
    assert violations == [], "\n".join(str(v) for v in violations)


def test_executable_keys_distinguish_configs(probe_index):
    base = dict(k=5, rho_ladder=(200,), lq_buckets=(4,))
    s1 = AnytimeServer(probe_index, ServingConfig(engine="saat", **base))
    s2 = AnytimeServer(probe_index, ServingConfig(engine="saat", fused_topk=True, **base))
    s3 = AnytimeServer(probe_index, ServingConfig(engine="saat", **base))
    assert s1.executable_key(4, 2) != s2.executable_key(4, 2)  # flag forks
    assert s1.executable_key(4, 2) == s3.executable_key(4, 2)  # same config aliases
    assert s1.executable_key(4, 2) != s1.executable_key(8, 2)  # bucket forks
    assert s1.executable_key(4, 2) != s1.executable_key(4, 4)  # batch forks


def test_bucketize_canonicalizes_dtypes(probe_index):
    # i64/f64-ish caller input must not fork the compile cache: _bucketize
    # hands the engine strong i32/f32 regardless of what arrives
    server = AnytimeServer(
        probe_index, ServingConfig(engine="saat", k=5, rho_ladder=(200,), lq_buckets=(4,))
    )
    qt = np.zeros((2, 3), np.int16)
    qw = np.zeros((2, 3), np.float16)
    ct, cw, bucket = server._bucketize(qt, qw)
    assert ct.dtype == jnp.int32 and cw.dtype == jnp.float32
    assert bucket == 4


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------


def test_cli_list(capsys):
    assert check_main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "chunk_step" in out and "expect_dma=True" in out
    assert "block_prune_csr" in out


def test_cli_single_contract(capsys):
    assert check_main(["--contract", "block_prune"]) == 0
    assert "0 violations" in capsys.readouterr().out
